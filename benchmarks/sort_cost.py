"""Table 1 — device sort time vs batch size (the one global step FliX pays)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, time_call


def run() -> None:
    lo, hi = (10, 18) if SCALE == "small" else (15, 22)
    sort = jax.jit(jnp.sort)
    rng = np.random.default_rng(0)
    for p in range(lo, hi):
        keys = jnp.asarray(rng.integers(0, 1 << 30, size=1 << p, dtype=np.int32))
        us = time_call(sort, keys)
        emit(f"table1_sort_2^{p}", us, f"n={1 << p}")
