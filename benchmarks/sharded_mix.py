"""Sharded mixed-batch engine: shard count × update ratio × routing mode.

``shard_apply_ops`` (DESIGN.md §11) runs the whole mixed batch under one
``shard_map`` step; this suite measures what the hierarchy costs on this
host.  The grid:

  * **shard count** — 2/4/8 (whatever the device count allows; on a CPU
    host run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
    as the CI ``bench-smoke`` job does).  A single-device ``apply_ops``
    run of the same batch is the baseline every point is normalized to.
  * **update ratio** — 0% (read-only), 50%, 100% (pure updates), the
    fig-style read/update shape inside one batch.
  * **routing mode** — ``replicated`` (broadcast batch, one collective
    round) vs ``a2a`` (sharded ingest, padded all_to_all there and back).

On fake host devices the "speedup" is an honest collective-overhead
number (< 1 — eight XLA CPU shards time-slice one socket); the trend to
watch on real hardware is rep-vs-a2a crossover as the update ratio grows.
``benchmarks.run`` lifts the ``sharded_mix_{rep,a2a}_s*`` /
``sharded_mix_single_*`` pairs into the ``sharded_speedup`` field of
the bench artifact (schema flix-bench-v1, DESIGN.md §7).

Since PR 10 the suite also records the routing *policy* inputs
(DESIGN.md §16): ``sharded_mix_crossover_s*`` (smallest update ratio
where a2a ≤ replicated, plus the full a2a/rep ratio curve) and
``sharded_mix_skew_s*`` (observed max-shard-load / uniform-share over the
swept batches, against ``A2A_CAPACITY_HEADROOM`` and the
``default_a2a_capacity`` it implies — ``covered=1`` means the default
receive buffers absorb the measured skew without a safe-mode retry).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.core import distributed as dist
from repro.core.config import ExecConfig

SHARD_COUNTS = (2, 4, 8)
UPDATE_RATIOS = (0, 50, 100)


def _batch(rng, keys, absent, batch, upd_pct):
    n_upd = batch * upd_pct // 100
    n_ins, n_del = n_upd // 2, n_upd - n_upd // 2
    n_read = batch - n_upd
    n_point, n_succ = n_read // 2, n_read - n_read // 2
    tags = np.concatenate([
        np.full(n_ins, core.OP_INSERT),
        np.full(n_del, core.OP_DELETE),
        np.full(n_point, core.OP_POINT),
        np.full(n_succ, core.OP_SUCCESSOR),
    ]).astype(np.int32)
    bk = np.concatenate([
        absent[:n_ins],
        rng.choice(keys, size=n_del, replace=False).astype(np.int32),
        rng.integers(0, KEY_SPACE, n_point).astype(np.int32),
        rng.integers(0, KEY_SPACE, n_succ).astype(np.int32),
    ]).astype(np.int32)
    bv = np.zeros(batch, np.int32)
    bv[:n_ins] = np.arange(n_ins)
    ops, _ = core.make_ops(tags, bk, bv)
    return ops


def run() -> None:
    rng = np.random.default_rng(33)
    n = BUILD_SIZE
    batch = max(1024, n // 16)
    keys = keyset(rng, n)
    vals = np.arange(n, dtype=np.int32)
    sk = np.sort(keys)
    sv = vals[np.argsort(keys)]
    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE, 4 * batch).astype(np.int32), keys
    )
    st = core.build(keys, vals, node_size=32, nodes_per_bucket=16)

    shard_counts = [s for s in SHARD_COUNTS if s <= len(jax.devices())]
    if not shard_counts:
        emit("sharded_mix_skipped", 0.0, f"devices={len(jax.devices())}")
        return

    batches = {u: _batch(rng, keys, absent, batch, u) for u in UPDATE_RATIOS}
    # the sharded index only depends on the shard count — build each once
    meshes = {s: dist.make_shard_mesh(s) for s in shard_counts}
    indexes = {
        s: dist.shard_build(
            jnp.asarray(sk),
            jnp.asarray(sv),
            meshes[s],
            node_size=32,
            nodes_per_bucket=16,
        )
        for s in shard_counts
    }

    # single-device baseline: the same batch through plain apply_ops
    times: dict[tuple[str, int, int], float] = {}
    for upd, ops in batches.items():
        t = time_call(lambda ops=ops: core.apply_ops(st, ops, config=ExecConfig(impl="reference")))
        emit(
            f"sharded_mix_single_upd{upd}",
            t,
            f"batch={batch};ops_per_s={batch / t * 1e6:.0f}",
        )
        single = t

        for s in shard_counts:
            mesh, idx = meshes[s], indexes[s]
            for mode in ("replicated", "a2a"):
                t_sh = time_call(
                    lambda ops=ops, idx=idx, mesh=mesh, mode=mode: (
                        dist.shard_apply_ops(idx, ops, mesh, config=ExecConfig(routing=mode))
                    )
                )
                times[(mode, s, upd)] = t_sh
                emit(
                    f"sharded_mix_{mode[:3]}_s{s}_upd{upd}",
                    t_sh,
                    f"batch={batch};speedup_vs_single={single / t_sh:.3f}x",
                )

    # routing-policy rows (DESIGN.md §16): where replicated stops paying and
    # the observed key skew that sizes the default a2a receive buffers.
    for s in shard_counts:
        idx = indexes[s]
        # destination shard per op: shard s owns keys in
        # (part_fences[s-1], part_fences[s]] — same searchsorted the a2a
        # router runs on device, replayed on host over the batch keys
        fences = np.asarray(jax.device_get(idx.part_fences))
        skews = []
        for upd, ops in batches.items():
            k = np.asarray(jax.device_get(ops.key))
            k = k[np.asarray(jax.device_get(ops.tag)) != core.OP_NOP]
            dest = np.minimum(
                np.searchsorted(fences, k, side="left"), s - 1
            )
            loads = np.bincount(dest, minlength=s)
            skews.append(loads.max() / (k.size / s))
        skew = max(skews)
        chunk = batch // s  # per-shard ingest chunk in a2a mode
        cap = dist.default_a2a_capacity(chunk, s)
        emit(
            f"sharded_mix_skew_s{s}",
            0.0,
            f"batch={batch};observed_skew={skew:.3f}"
            f";headroom={dist.A2A_CAPACITY_HEADROOM:.1f}"
            f";default_capacity={cap};chunk={chunk}"
            f";covered={int(skew <= dist.A2A_CAPACITY_HEADROOM)}",
        )
        # smallest update ratio where a2a matches/beats replicated on this
        # host; -1 = replicated wins everywhere (watch on real hardware)
        cross = next(
            (
                u
                for u in UPDATE_RATIOS
                if times[("a2a", s, u)] <= times[("replicated", s, u)]
            ),
            -1,
        )
        ratios = ";".join(
            f"a2a_over_rep_upd{u}="
            f"{times[('a2a', s, u)] / times[('replicated', s, u)]:.3f}"
            for u in UPDATE_RATIOS
        )
        emit(f"sharded_mix_crossover_s{s}", 0.0, f"crossover_upd={cross};{ratios}")
