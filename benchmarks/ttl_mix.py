"""TTL as a first-class mixed-batch op: expiry-fraction × TTL-skew sweep.

The caching workload (DESIGN.md §14): per-key deadlines ride a third state
column, EXPIRE is get-or-set-with-TTL in the same sorted batch as every
other op class, and a lazy expiry pre-pass physically reclaims dead rows
at the batch's virtual ``now``.  This suite measures what that costs
inside the engine.  The grid:

  * **expire fraction** — share of the batch that is EXPIRE ops (half
    hits refreshing deadlines, half misses inserting), from
    expire-light (10%) to the memcached-shaped get-or-set-heavy mix
    (90%); the rest is 50% POINT / 25% TTL'd INSERT / 25% DELETE.
  * **TTL skew** — fraction of STORED rows already past their deadline
    at the measured ``now`` (``light`` ≈ 1%, ``heavy`` ≈ 25%), which
    moves the work from deadline bookkeeping to the expiry pre-pass's
    physical reclamation (in-node shift + chain compaction).

Timed forms:

  * ``apply_ops(impl="reference", now=...)`` — the jnp engine running the
    expiry pre-pass + two-plane TTL execution.
  * ``apply_ops(impl="fused", now=...)`` — the compute-to-bucket Pallas
    kernel under the same TTL planes, at one sweep point (interpret mode
    on CPU hosts: the recorded "speedup" < 1 is the honest
    interpret-vs-jnp ratio — the number to watch on real hardware).
  * ``expire_state`` alone — the pre-pass's marginal cost per skew level.

``benchmarks.run`` lifts the ``ttl_mix_fused_*`` / ``ttl_mix_ref_*``
pairs into the ``ttl_fused_speedup`` field of the bench artifact.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.checkpoint.serialize import state_from_pairs
from repro.core.expiry import NO_EXPIRY, expire_state
from repro.core.config import ExecConfig

TTL_SKEW = {"light": 0.01, "heavy": 0.25}  # stored rows already expired
EXPIRE_FRACTIONS = (10, 50, 90)            # percent of the batch
FUSED_POINT = (90, "heavy")                # one interpret-mode fused sample
MAX_RESULTS = 256
NOW = 1 << 20                              # the sweep's virtual clock


def _ttl_state(rng, keys, vals, dead_frac):
    """Stored deadlines: ``dead_frac`` already past NOW, a third due in
    the future, the rest immortal."""
    r = rng.random(len(keys))
    exps = np.full(len(keys), int(NO_EXPIRY), np.int64)
    exps[r < dead_frac] = NOW - rng.integers(1, 1000, int((r < dead_frac).sum()))
    future = (r >= dead_frac) & (r < dead_frac + 0.33)
    exps[future] = NOW + rng.integers(1, 1 << 20, int(future.sum()))
    return state_from_pairs(
        keys, vals, exps.astype(np.int32), node_size=32, nodes_per_bucket=16
    )


def _batch(rng, keys, absent, batch, ef_pct):
    """ef% EXPIRE (half hit / half miss), rest 50/25/25 POINT/INSERT/DEL."""
    n_exp = batch * ef_pct // 100
    n_hit = n_exp // 2
    n_miss = n_exp - n_hit
    n_rest = batch - n_exp
    n_point = n_rest // 2
    n_ins = (n_rest - n_point) // 2
    n_del = n_rest - n_point - n_ins

    hit = rng.choice(keys, size=n_hit, replace=False).astype(np.int32)
    miss = absent[:n_miss]
    ins = absent[n_miss : n_miss + n_ins]
    dels = rng.choice(
        np.setdiff1d(keys, hit), size=n_del, replace=False
    ).astype(np.int32)
    points = rng.integers(0, KEY_SPACE, n_point).astype(np.int32)

    tags = np.concatenate([
        np.full(n_exp, core.OP_EXPIRE), np.full(n_point, core.OP_POINT),
        np.full(n_ins, core.OP_INSERT), np.full(n_del, core.OP_DELETE),
    ]).astype(np.int32)
    bkeys = np.concatenate([hit, miss, points, ins, dels]).astype(np.int32)
    bvals = np.concatenate([
        np.arange(n_exp, dtype=np.int32), np.zeros(n_point, np.int32),
        np.arange(n_ins, dtype=np.int32), np.zeros(n_del, np.int32),
    ]).astype(np.int32)
    bexps = np.full(batch, int(NO_EXPIRY), np.int32)
    bexps[:n_exp] = NOW + rng.integers(1, 1 << 16, n_exp)
    bexps[n_exp + n_point : n_exp + n_point + n_ins] = NOW + rng.integers(
        1, 1 << 16, n_ins
    )
    return (
        jnp.asarray(tags),
        jnp.asarray(bkeys),
        jnp.asarray(bvals),
        jnp.asarray(bexps),
    )


def run() -> None:
    rng = np.random.default_rng(42)
    n = BUILD_SIZE
    batch = max(512, n // 32)
    keys = np.sort(keyset(rng, n))  # state_from_pairs wants sorted triples
    vals = np.arange(n, dtype=np.int32)
    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE, 4 * batch).astype(np.int32), keys
    )

    for skew_name, dead_frac in TTL_SKEW.items():
        st = _ttl_state(rng, keys, vals, dead_frac)

        # the expiry pre-pass alone: reclamation cost per skew level
        t_expire = time_call(lambda: expire_state(st, jnp.int32(NOW)))
        _, n_dead = expire_state(st, jnp.int32(NOW))
        emit(
            f"ttl_mix_expire_pass_{skew_name}",
            t_expire,
            f"reclaimed={int(n_dead)};stored={n}",
        )

        for ef in EXPIRE_FRACTIONS:
            jt, jk, jv, je = _batch(rng, keys, absent, batch, ef)

            def reference():
                ops, _ = core.make_ops(jt, jk, jv, exps=je)
                return core.apply_ops(
                    st, ops, now=NOW, config=ExecConfig(impl="reference", max_results=MAX_RESULTS)
                )

            t_ref = time_call(reference)
            _, res, stats = reference()
            hits = int(jnp.sum(res["value"] != int(core.NOT_FOUND)))
            emit(
                f"ttl_mix_ref_ef{ef}_{skew_name}",
                t_ref,
                f"batch={batch};expired={int(stats['expired'])};hits={hits}",
            )

            if (ef, skew_name) == FUSED_POINT:

                def fused():
                    ops, _ = core.make_ops(jt, jk, jv, exps=je)
                    return core.apply_ops(
                        st, ops, now=NOW, config=ExecConfig(impl="fused", max_results=MAX_RESULTS)
                    )

                t_fused = time_call(fused, iters=1)
                emit(
                    f"ttl_mix_fused_ef{ef}_{skew_name}",
                    t_fused,
                    f"batch={batch};speedup_vs_reference="
                    f"{t_ref / t_fused:.2f}x",
                )
