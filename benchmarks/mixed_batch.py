"""Mixed-operation batch engine: fused kernel vs reference vs per-type.

The paper's execution model is one sorted batch of mixed operations per
step.  This suite sweeps the update ratio (0% = read-only … 100% = pure
updates) on a fixed-size batch and times

  * ``apply_ops(impl="reference")`` — the unified jnp engine: one global
    sort, one bucket routing, but still four device passes over the state
    (insert merge, delete, point, successor),
  * ``apply_ops(impl="fused")`` — the compute-to-bucket Pallas kernel
    (``kernels/flix_apply.py``): one VMEM-resident pass per bucket executes
    the whole update-then-read sequence.  Compiled on TPU; in *interpret
    mode* on this CPU container, where the recorded "speedup" is the honest
    interpret-vs-jnp ratio (< 1) — the number to watch on real hardware.
    Measured at the read-heavy (0%) and update-heavy (100%) sweep ends so
    the interpret-mode cost stays bounded.
  * ``sequential`` — the pre-engine serving path: sort + route the inserts,
    sort + route the deletes, sort the reads, four separate passes.

All three produce identical states and results (tests/test_differential.py),
so the deltas are pure execution-structure overhead — routing/sort cost for
``sequential`` vs ``apply_ops``, HBM sweep count for reference vs fused.
``benchmarks.run`` lifts the ``mixed_batch_apply_fused_upd*`` /
``mixed_batch_apply_ops_upd*`` pairs into the ``apply_ops_fused_speedup``
field of the bench artifact (DESIGN.md §7), and since PR 10 the
``mixed_batch_apply_pipelined_upd*`` rows (double-buffered fused kernel,
``pipeline="on"``) into ``pipelined_speedup`` (DESIGN.md §16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.core.config import ExecConfig

FUSED_SWEEP_POINTS = (0, 100)  # read-heavy and update-heavy ends


def run() -> None:
    rng = np.random.default_rng(21)
    n = BUILD_SIZE
    batch = max(1024, n // 8)
    keys = keyset(rng, n)
    vals = np.arange(n, dtype=np.int32)
    st = core.build(keys, vals, node_size=32, nodes_per_bucket=16)
    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE, 4 * batch).astype(np.int32), keys
    )

    for upd_pct in (0, 25, 50, 75, 100):
        n_upd = batch * upd_pct // 100
        n_ins, n_del = n_upd // 2, n_upd - n_upd // 2
        n_read = batch - n_upd
        n_point, n_succ = n_read // 2, n_read - n_read // 2

        ins = absent[:n_ins]
        dels = rng.choice(keys, size=n_del, replace=False).astype(np.int32)
        points = rng.integers(0, KEY_SPACE, n_point).astype(np.int32)
        succs = rng.integers(0, KEY_SPACE, n_succ).astype(np.int32)

        tags = np.concatenate([
            np.full(n_ins, core.OP_INSERT), np.full(n_del, core.OP_DELETE),
            np.full(n_point, core.OP_POINT), np.full(n_succ, core.OP_SUCCESSOR),
        ]).astype(np.int32)
        bkeys = np.concatenate([ins, dels, points, succs]).astype(np.int32)
        bvals = np.zeros(batch, np.int32)
        bvals[:n_ins] = np.arange(n_ins)
        jt, jk, jv = jnp.asarray(tags), jnp.asarray(bkeys), jnp.asarray(bvals)

        def mixed():
            ops, _ = core.make_ops(jt, jk, jv)
            return core.apply_ops(st, ops, config=ExecConfig(impl="reference"))

        jins_k, jins_v = jnp.asarray(ins), jnp.asarray(bvals[:n_ins])
        jdel = jnp.asarray(dels)
        jpoint, jsucc = jnp.asarray(points), jnp.asarray(succs)

        def sequential():
            s2 = st
            if n_ins:
                sk, sv = core.sort_batch(jins_k, jins_v)
                s2, _ = core.insert(s2, sk, sv)
            if n_del:
                s2, _ = core.delete(s2, jnp.sort(jdel))
            pv = sks = svs = None
            if n_point:
                pv = core.point_query(s2, jnp.sort(jpoint))
            if n_succ:
                sks, svs = core.successor_query(s2, jnp.sort(jsucc))
            return s2, pv, sks, svs

        t_mixed = time_call(mixed)
        t_seq = time_call(sequential)
        emit(
            f"mixed_batch_apply_ops_upd{upd_pct}",
            t_mixed,
            f"batch={batch};ops_per_s={batch / t_mixed * 1e6:.0f}",
        )
        emit(
            f"mixed_batch_sequential_upd{upd_pct}",
            t_seq,
            f"batch={batch};speedup={t_seq / t_mixed:.2f}x",
        )

        if upd_pct in FUSED_SWEEP_POINTS:
            # pipeline="off" IS the pre-pipelining fused path — it stays the
            # fused row so the committed speedup trend is apples-to-apples
            def fused():
                ops, _ = core.make_ops(jt, jk, jv)
                return core.apply_ops(
                    st, ops, config=ExecConfig(impl="fused", pipeline="off")
                )

            t_fused = time_call(fused, iters=1)
            emit(
                f"mixed_batch_apply_fused_upd{upd_pct}",
                t_fused,
                f"batch={batch};speedup_vs_reference={t_mixed / t_fused:.2f}x",
            )

            # double-buffered variant: a real DMA/compute overlap exists only
            # on TPU.  In interpret mode the async copies are emulated
            # serially, so a CPU wall clock of pipeline="on" measures the
            # emulation, not the kernel — on non-TPU hosts the fused time is
            # re-emitted under the pipelined row (ratio exactly 1.0) and the
            # row is an honest "no TPU on this host" marker, while the
            # byte-identity still holds (tests/test_differential.py).
            if jax.default_backend() == "tpu":

                def pipelined():
                    ops, _ = core.make_ops(jt, jk, jv)
                    return core.apply_ops(
                        st, ops, config=ExecConfig(impl="fused", pipeline="on")
                    )

                t_pipe = time_call(pipelined, iters=1)
            else:
                t_pipe = t_fused
            emit(
                f"mixed_batch_apply_pipelined_upd{upd_pct}",
                t_pipe,
                f"batch={batch};speedup_vs_fused={t_fused / t_pipe:.2f}x"
                f";backend={jax.default_backend()}",
            )
