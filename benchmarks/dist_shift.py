"""Fig 11 — distributional shift: X% of the key range gets Y=90% of inserts.

Measures FliX query latency after each of 8 insertion rounds, for X from
uniform (90%) down to 2% — the compute-to-bucket robustness claim.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, emit, make_workload, time_call
from repro import core


def run() -> None:
    n = BUILD_SIZE
    growth = 3 * n
    for x_pct in (0.90, 0.25, 0.06, 0.02):
        rng = np.random.default_rng(6)
        build, updates = make_workload(rng, n, growth, x_pct, 0.90)
        vals = np.arange(n, dtype=np.int32)
        flix = core.build(build, vals, node_size=32, nodes_per_bucket=16)
        per_round = growth // 8
        for rnd in range(8):
            ins = updates[rnd * per_round : (rnd + 1) * per_round]
            iv = np.arange(len(ins), dtype=np.int32)
            sik, siv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
            flix, _ = core.insert_safe(flix, sik, siv)

            live = int(flix.live_keys())
            qk = jnp.asarray(
                np.sort(rng.choice(updates[: (rnd + 1) * per_round], size=n))
            )
            us = time_call(lambda: core.point_query(flix, qk))
            emit(
                f"fig11_x{int(x_pct*100)}_r{rnd}", us,
                f"live={live};max_chain={int(jnp.max(flix.num_nodes))}",
            )
