"""Table 4 — node recovery through restructuring after insert+delete phases."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, emit, make_workload, time_call
from repro import core


def run() -> None:
    n = BUILD_SIZE
    for label, x_pct in (("X25Y90", 0.25), ("X90Y90", 0.90)):
        rng = np.random.default_rng(9)
        build, updates = make_workload(rng, n, 3 * n, x_pct, 0.90)
        vals = np.arange(n, dtype=np.int32)
        flix = core.build(build, vals, node_size=32, nodes_per_bucket=16)

        per_round = (3 * n) // 8
        for rnd in range(8):  # 8 insertion rounds → 300% growth
            ins = updates[rnd * per_round : (rnd + 1) * per_round]
            iv = np.arange(len(ins), dtype=np.int32)
            sik, siv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
            flix, _ = core.insert_safe(flix, sik, siv)
        shuffled = rng.permutation(updates)
        for rnd in range(8):  # 8 deletion rounds
            dels = jnp.asarray(np.sort(shuffled[rnd * per_round : (rnd + 1) * per_round]))
            flix, _ = core.delete(flix, dels)

        nodes_before = int(flix.total_nodes())
        us = time_call(lambda: core.restructure_auto(flix), iters=1)
        flix2 = core.restructure_auto(flix)
        nodes_after = int(flix2.total_nodes())
        rec = nodes_before - nodes_after
        emit(
            f"table4_restructure_{label}", us,
            f"nodes={nodes_before}->{nodes_after};recovered={rec};"
            f"pct={100*rec/max(nodes_before,1):.0f}%",
        )
