"""Fig 8 — rounds of batched deletions (after an insertion phase)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, emit, keyset, time_call
from repro import core
from repro.core.baselines import btree, hash_table as ht, lsm, sorted_array as sa


def run() -> None:
    rng = np.random.default_rng(2)
    n = BUILD_SIZE
    allk = keyset(rng, 2 * n)
    build, extra = allk[:n], allk[n:]
    vals = np.arange(n, dtype=np.int32)
    sk, sv = np.sort(build), vals[np.argsort(build)]

    flix = core.build(build, vals, node_size=32, nodes_per_bucket=16)
    bt = btree.build(build, vals)
    lsmu = lsm.empty_state(chunk=4096, num_levels=lsm_levels(2 * n, 4096))
    lsmu = lsm.insert(lsmu, jnp.asarray(sk), jnp.asarray(sv))
    h = ht.empty_state(capacity=int(2 * n / 0.8))
    h, _ = ht.insert(h, jnp.asarray(sk), jnp.asarray(sv))
    sarr = sa.build(jnp.asarray(sk), jnp.asarray(sv), capacity=2 * n)

    # insert phase (100% growth), then delete it back in 4 rounds
    sik, siv = core.sort_batch(jnp.asarray(extra), jnp.asarray(np.arange(n, dtype=np.int32)))
    flix, _ = core.insert_safe(flix, sik, siv)
    bt = btree.insert(bt, sik, siv)
    lsmu = lsm.insert(lsmu, sik, siv)
    h, _ = ht.insert(h, jnp.asarray(extra), jnp.asarray(np.arange(n, dtype=np.int32)))
    sarr = sa.insert(sarr, sik, siv)

    per_round = n // 4
    dels = np.sort(extra)
    for rnd in range(4):
        dk = jnp.asarray(np.sort(dels[rnd * per_round : (rnd + 1) * per_round]))

        us = time_call(lambda: core.delete(flix, dk))
        flix, _ = core.delete(flix, dk)
        emit(
            f"fig8_delete_r{rnd}_flix_tlbulk",
            us,
            f"live={int(flix.live_keys())},mem={int(flix.memory_bytes())}",
        )

        us = time_call(lambda: btree.delete(bt, dk))
        bt = btree.delete(bt, dk)
        emit(f"fig8_delete_r{rnd}_btree", us)

        us = time_call(lambda: lsm.delete(lsmu, dk))
        lsmu = lsm.delete(lsmu, dk)
        # tombstones never shrink the level arrays: footprint is flat while
        # live keys drain — the contrast row for FliX's restructure_shrink
        emit(
            f"fig8_delete_r{rnd}_lsmu_tombstone",
            us,
            f"mem={int(lsmu.memory_bytes())}",
        )

        us = time_call(lambda: ht.delete(h, dk))
        h = ht.delete(h, dk)
        emit(f"fig8_delete_r{rnd}_hashtable_tombstone", us)

        us = time_call(lambda: sa.delete(sarr, dk))
        sarr = sa.delete(sarr, dk)
        emit(f"fig8_delete_r{rnd}_sortedarray", us)
