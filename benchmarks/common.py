"""Shared benchmark harness utilities.

CPU-host scaling note (DESIGN.md §7): the paper benchmarks on an A6000 at
build sizes 2^24–2^27; this container is a single-CPU JAX host, so the
default sizes are 2^14–2^17 and we measure the same *relative* quantities
(FliX vs baselines, round-over-round dynamics, QTMF orderings).  Every
table prints ``name,us_per_call,derived`` CSV rows so `benchmarks.run`
aggregates uniformly.  Set REPRO_BENCH_SCALE=large for 2^20-size runs.
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
BUILD_SIZE = {"small": 1 << 14, "medium": 1 << 17, "large": 1 << 20}[SCALE]
KEY_SPACE = BUILD_SIZE * 8

# Every emit() row also lands here so ``benchmarks.run`` can serialize the
# whole run as one machine-readable artifact (BENCH_PR2.json, DESIGN.md §7).
RESULTS: list[tuple[str, float, str]] = []


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of fn(*args) in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us: float, derived: str = "") -> None:
    RESULTS.append((name, float(us), derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def keyset(rng: np.random.Generator, n: int, space: int = None):
    space = space or KEY_SPACE
    return rng.choice(space, size=n, replace=False).astype(np.int32)


def make_workload(rng, n_build: int, n_update: int, x_pct: float, y_pct: float):
    """Paper §5.2.1 workloads: X% of the key range gets Y% of the updates."""
    build = np.sort(keyset(rng, n_build + n_update))
    idx = rng.permutation(n_build + n_update)
    build_keys = np.sort(build[idx[:n_build]])
    pool = build[idx[n_build:]]
    lo = int(KEY_SPACE * rng.random() * (1 - x_pct))
    hi = lo + int(KEY_SPACE * x_pct)
    dense = pool[(pool >= lo) & (pool < hi)]
    sparse = pool[(pool < lo) | (pool >= hi)]
    n_dense = min(int(n_update * y_pct), len(dense))
    upd = np.concatenate([dense[:n_dense], sparse[: n_update - n_dense]])
    rng.shuffle(upd)
    return build_keys, upd[:n_update].astype(np.int32)


def lsm_levels(total_keys: int, chunk: int) -> int:
    """Right-sized level count: capacity ≈ 2× the final key count."""
    import math

    need = max(1, math.ceil(total_keys / chunk))
    return max(3, math.ceil(math.log2(need)) + 2)
