"""Durability engine costs: snapshot/replay throughput vs churn.

The DESIGN.md §12 claims under measurement:

  * a **delta** snapshot's write cost is proportional to churn (dirty
    buckets), not index size — the ``durability_snap_*_churn{X}`` rows
    record wall time, and the ``durability_snap_*_bytes_churn{X}`` rows
    record payload volume.  ``benchmarks.run`` lifts the BYTES ratio into
    the gated ``durability_delta_speedup`` map of the bench artifact:
    write volume is a deterministic function of churn, so the regression
    gate never flakes on container fsync jitter the way wall time does;
  * the WAL append (frame + fsync) is a bounded per-batch tax
    (``durability_wal_append``), and replay is much cheaper than the
    original execution (``durability_wal_replay_scan`` measures the pure
    log scan; ``durability_recover`` is the full end-to-end open:
    snapshot load + rebuild + re-execution of the logged tail).

Churn is emulated the way the serving path produces it: the dirty-bucket
set is seeded directly (X% of buckets) between snapshots, so the suite
measures the persistence layer, not ``apply_ops``.
"""

from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks.common import BUILD_SIZE, KEY_SPACE, emit, keyset
from repro.checkpoint import DurableFliX, LocalEngine
from repro.checkpoint import wal as wal_mod
from repro.checkpoint.serialize import state_from_pairs
from repro.checkpoint.wal import WriteAheadLog, encode_ops
from repro.core.ops import OP_INSERT, OpBatch

CHURN_PCTS = (1, 10, 50)
WAL_BATCH = 512
N_REPLAY = 64


def _host_time(fn, *, warmup: int = 1, iters: int = 5) -> float:
    """Median wall microseconds of a host-side (I/O) callable."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def _mark_dirty(dur: DurableFliX, frac: float) -> None:
    nb = dur.state.geometry[0]
    n = max(1, int(nb * frac))
    dur._dirty = set(range(0, nb, max(1, nb // n)))
    dur._all_dirty = False


def run() -> None:
    rng = np.random.default_rng(0)
    keys = np.sort(keyset(rng, BUILD_SIZE))
    vals = np.arange(BUILD_SIZE, dtype=np.int32)

    root = Path(tempfile.mkdtemp(prefix="flix_bench_dur_"))
    try:
        dur = DurableFliX.create(
            root / "snap",
            state_from_pairs(keys, vals),
            engine=LocalEngine(),
            snapshot_every=0,  # snapshots driven manually below
        )
        nb = dur.state.geometry[0]

        for pct in CHURN_PCTS:
            # snapshots are named by seq; advance it so each timed call
            # commits a fresh directory instead of renaming onto the last
            def snap_full():
                dur._seq += 1
                dur.snapshot(full=True)

            def snap_delta():
                dur._seq += 1
                _mark_dirty(dur, pct / 100)
                dur.snapshot(full=False)

            full_us = _host_time(snap_full)
            delta_us = _host_time(snap_delta)
            # payload volume from one committed snapshot of each kind —
            # deterministic, unlike the wall times above
            dur._seq += 1
            full_b = (dur.snapshot(full=True) / "payload.bin").stat().st_size
            dur._seq += 1
            _mark_dirty(dur, pct / 100)
            delta_b = (dur.snapshot(full=False) / "payload.bin").stat().st_size
            n_dirty = max(1, int(nb * pct / 100))
            emit(
                f"durability_snap_full_churn{pct}",
                full_us,
                f"n={BUILD_SIZE};nb={nb}",
            )
            emit(
                f"durability_snap_delta_churn{pct}",
                delta_us,
                f"dirty={n_dirty}/{nb};x{full_us / max(delta_us, 1e-9):.1f}",
            )
            emit(f"durability_snap_full_bytes_churn{pct}", full_b, "bytes")
            emit(
                f"durability_snap_delta_bytes_churn{pct}",
                delta_b,
                f"bytes;x{full_b / max(delta_b, 1e-9):.1f}",
            )
        dur.close()

        # WAL append: frame + write + fsync of one WAL_BATCH-op record
        wal_dir = root / "wal_append"
        wal = WriteAheadLog(wal_dir)
        wal.open_segment(1)
        tag = np.full(WAL_BATCH, OP_INSERT, np.int32)
        wkeys = keyset(rng, WAL_BATCH)
        payload = encode_ops(tag, wkeys, wkeys, 128)
        seq_box = [0]

        def append_one():
            seq_box[0] += 1
            wal.append(seq_box[0], payload)

        emit(
            "durability_wal_append",
            _host_time(append_one, warmup=2, iters=9),
            f"ops={WAL_BATCH};fsync",
        )
        wal.close()

        # replay scan: N_REPLAY records decoded + checksummed, per record
        scan_us = _host_time(lambda: wal_mod.replay(wal_dir), iters=5)
        n_recs = len(wal_mod.replay(wal_dir))
        emit(
            "durability_wal_replay_scan",
            scan_us / max(n_recs, 1),
            f"records={n_recs};per_record",
        )

        # end-to-end recovery: snapshot chain load + rebuild + replay tail
        rec_root = root / "recover"
        rdur = DurableFliX.create(
            rec_root,
            state_from_pairs(keys, vals),
            engine=LocalEngine(),
            snapshot_every=0,
        )
        ins = np.sort(keyset(rng, WAL_BATCH, KEY_SPACE))
        for t in range(1, N_REPLAY // 8 + 1):
            batch = OpBatch.from_host(
                np.full(WAL_BATCH, OP_INSERT, np.int32), ins, ins + t
            )
            rdur.apply(batch)
        rdur.close()
        t0 = time.perf_counter()
        reopened = DurableFliX.open(rec_root, engine=LocalEngine(), snapshot_every=0)
        rec_us = (time.perf_counter() - t0) * 1e6
        emit(
            "durability_recover",
            rec_us,
            f"replayed={reopened.replayed};n={BUILD_SIZE}",
        )
        reopened.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
