"""Fig 12 — unsorted queries: baselines take them natively; FliX pays the
sort and still wins at scale (the paper's fairness experiment)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, emit, keyset, time_call
from repro import core
from repro.core.baselines import btree, hash_table as ht, lsm


def run() -> None:
    rng = np.random.default_rng(7)
    n = BUILD_SIZE
    keys = keyset(rng, n)
    vals = np.arange(n, dtype=np.int32)
    sk, sv = np.sort(keys), vals[np.argsort(keys)]

    flix = core.build(keys, vals, node_size=32, nodes_per_bucket=16)
    bt = btree.build(keys, vals)
    lsmu = lsm.insert(
        lsm.empty_state(chunk=4096, num_levels=lsm_levels(n, 4096)), jnp.asarray(sk), jnp.asarray(sv)
    )
    h = ht.empty_state(capacity=int(n / 0.8) + 64)
    h, _ = ht.insert(h, jnp.asarray(sk), jnp.asarray(sv))

    q_unsorted = jnp.asarray(rng.choice(keys, size=2 * n))

    def flix_with_sort(q):
        return core.point_query(flix, jnp.sort(q))

    us_sort_only = time_call(jax.jit(jnp.sort), q_unsorted)
    emit("fig12_sortcost", us_sort_only, f"q={2*n}")
    emit("fig12_flix_incl_sort", time_call(flix_with_sort, q_unsorted))
    emit("fig12_btree", time_call(lambda q: btree.point_query(bt, q), q_unsorted))
    emit("fig12_lsmu", time_call(lambda q: lsm.point_query(lsmu, q), q_unsorted))
    emit("fig12_hashtable", time_call(lambda q: ht.point_query(h, q), q_unsorted))
