"""Fig 5 — formative sweep: node size (NS) × compute-block assignment.

The paper sweeps NS ∈ {8, 14, 32} × TPB ∈ {1024..128} per insert kernel.
The TPU analogue (DESIGN.md §3): NS stays NS; the TPB axis becomes the
kernel block geometry — nodes-per-bucket here (bucket stripe height), and
block_q/block_b for the Pallas query kernel (kernels bench).  Scores are
normalized per round against the best variant, like the paper's heat map.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, emit, keyset, time_call
from repro import core


def run() -> None:
    rng = np.random.default_rng(4)
    n = BUILD_SIZE // 2
    allk = keyset(rng, 3 * n)
    build, updates = allk[:n], allk[n:]
    vals = np.arange(n, dtype=np.int32)
    per_round = n // 2

    variants = [
        (ns, npb)
        for ns in (8, 14, 16, 32)
        for npb in (4, 8, 16)
    ]
    times = {v: [] for v in variants}
    for ns, npb in variants:
        flix = core.build(build, vals, node_size=ns, nodes_per_bucket=npb)
        for rnd in range(4):
            ins = updates[rnd * per_round : (rnd + 1) * per_round]
            iv = np.arange(per_round, dtype=np.int32)
            sik, siv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
            us = time_call(lambda: core.insert(flix, sik, siv), iters=2)
            flix, _ = core.insert_safe(flix, sik, siv)
            times[(ns, npb)].append(us)

    for rnd in range(4):
        best = min(times[v][rnd] for v in variants)
        for ns, npb in variants:
            us = times[(ns, npb)][rnd]
            emit(
                f"fig5_heatmap_r{rnd}_ns{ns}_npb{npb}", us,
                f"score={us / best:.2f}",
            )
