"""Fig 10 — average query time across (build size × query size) pairs."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.core.baselines import btree, hash_table as ht, lsm


def run() -> None:
    rng = np.random.default_rng(5)
    for bp in (BUILD_SIZE // 4, BUILD_SIZE):
        for qp in (BUILD_SIZE // 4, BUILD_SIZE):
            keys = keyset(rng, bp)
            vals = np.arange(bp, dtype=np.int32)
            sk, sv = np.sort(keys), vals[np.argsort(keys)]
            flix = core.build(keys, vals, node_size=32, nodes_per_bucket=16)
            bt = btree.build(keys, vals)
            lsmu = lsm.insert(
                lsm.empty_state(chunk=4096, num_levels=lsm_levels(bp, 4096)),
                jnp.asarray(sk), jnp.asarray(sv),
            )
            h = ht.empty_state(capacity=int(bp / 0.8) + 64)
            h, _ = ht.insert(h, jnp.asarray(sk), jnp.asarray(sv))

            half = qp // 2
            qhit = rng.choice(keys, size=half)
            qmiss = rng.integers(0, KEY_SPACE, size=qp - half).astype(np.int32)
            q = jnp.asarray(np.sort(np.concatenate([qhit, qmiss])))

            tag = f"fig10_b{bp}_q{qp}"
            emit(f"{tag}_flix", time_call(lambda: core.point_query(flix, q)))
            emit(f"{tag}_btree", time_call(lambda: btree.point_query(bt, q)))
            emit(f"{tag}_lsmu", time_call(lambda: lsm.point_query(lsmu, q)))
            emit(f"{tag}_hashtable", time_call(lambda: ht.point_query(h, q)))
