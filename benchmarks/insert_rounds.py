"""Fig 6/7 — rounds of batched insertions: FliX vs B-tree / LSMu / HT / SA.

4 rounds × 50% of build size each → 200% overall growth, uniform keys
(X=90,Y=90).  Also emits the per-structure memory footprint after the last
round (Fig 7d).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, emit, keyset, time_call
from repro import core
from repro.core.baselines import btree, hash_table as ht, lsm, sorted_array as sa


def run() -> None:
    rng = np.random.default_rng(1)
    n = BUILD_SIZE
    total = n * 3
    allk = keyset(rng, total)
    build, updates = allk[:n], allk[n:]
    vals = np.arange(n, dtype=np.int32)
    sk = np.sort(build)
    sv = vals[np.argsort(build)]
    per_round = n // 2

    flix = core.build(build, vals, node_size=32, nodes_per_bucket=16)
    bt = btree.build(build, vals)
    lsmu = lsm.empty_state(chunk=4096, num_levels=lsm_levels(total, 4096))
    lsmu = lsm.insert(lsmu, jnp.asarray(sk), jnp.asarray(sv))
    h = ht.empty_state(capacity=int(total / 0.8))
    h, _ = ht.insert(h, jnp.asarray(sk), jnp.asarray(sv))
    sarr = sa.build(jnp.asarray(sk), jnp.asarray(sv), capacity=total)

    for rnd in range(4):
        ins = updates[rnd * per_round : (rnd + 1) * per_round]
        iv = np.arange(per_round, dtype=np.int32)
        sik, siv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))

        us = time_call(lambda: core.insert(flix, sik, siv))
        flix, _ = core.insert_safe(flix, sik, siv)
        emit(f"fig7_insert_r{rnd}_flix_tlbulk", us, f"live={int(flix.live_keys())}")

        us = time_call(lambda: btree.insert(bt, sik, siv))
        bt = btree.insert(bt, sik, siv)
        emit(f"fig7_insert_r{rnd}_btree", us)

        us = time_call(lambda: lsm.insert(lsmu, sik, siv))
        lsmu = lsm.insert(lsmu, sik, siv)
        emit(f"fig7_insert_r{rnd}_lsmu", us)

        us = time_call(lambda: ht.insert(h, jnp.asarray(ins), jnp.asarray(iv)))
        h, _ = ht.insert(h, jnp.asarray(ins), jnp.asarray(iv))
        emit(f"fig7_insert_r{rnd}_hashtable", us)

        us = time_call(lambda: sa.insert(sarr, sik, siv))
        sarr = sa.insert(sarr, sik, siv)
        emit(f"fig7_insert_r{rnd}_sortedarray", us)

    emit("fig7d_mem_flix", 0, f"bytes={flix.memory_bytes()}")
    emit("fig7d_mem_btree", 0, f"bytes={bt.memory_bytes()}")
    emit("fig7d_mem_lsmu", 0, f"bytes={lsmu.memory_bytes()}")
    emit("fig7d_mem_hashtable", 0, f"bytes={h.memory_bytes()}")
    emit("fig7d_mem_sortedarray", 0, f"bytes={sarr.memory_bytes()}")
