"""Perf-regression gate: diff a fresh bench artifact against snapshots.

  python -m benchmarks.compare FRESH.json BASELINE.json [BASELINE2.json ...]

Both sides are ``flix-bench-v1`` artifacts (``benchmarks.run`` output /
the committed ``BENCH_PR*.json`` snapshots).  Raw ``us_per_call`` numbers
are host-dependent, so the *gate* only looks at the same-host speedup
ratio maps (``apply_ops_fused_speedup``, ``pipelined_speedup``,
``range_fused_speedup``, ``sharded_speedup``,
``durability_delta_speedup``, ``gateway_goodput_ratio``,
``tiered_degradation_ratio`` — the volume/virtual-clock ratios are
deterministic by construction; the rest divide two same-host wall-clock
sweeps): a key regresses when

    fresh < baseline * (1 - tolerance)

with ``tolerance`` from ``--tolerance`` / ``$REPRO_BENCH_TOL``
(default 0.20).  Keys whose baseline ratio is below ``--min-baseline`` /
``$REPRO_BENCH_MIN_BASELINE`` (default 0.05) are reported but never
gated — interpret-mode Pallas ratios on CPU runners are diagnostics, not
perf promises (DESIGN.md §7).  ``pipelined_speedup`` is additionally held
to an absolute floor of 1.0 (× the same tolerance) on the fresh artifact:
double-buffered-vs-single-buffer is a same-host ratio, so dropping below
1.0 is a pipelining regression on any hardware (DESIGN.md §16).
Later baseline files override earlier ones
key-by-key, so pass snapshots oldest-first.  Keys present on only one
side are reported as ``new``/``missing`` without failing (a suite that
did not run must not trip the gate); a fresh artifact with a non-empty
``failed`` list fails outright — its row maps are truncated.

The delta table lands on stdout and, when ``$GITHUB_STEP_SUMMARY`` is
set, is appended there as Markdown (the CI ``bench-smoke`` job does
this).  Exit status: 0 clean, 1 regression (or truncated artifact).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

SPEEDUP_FIELDS = (
    "apply_ops_fused_speedup",
    "pipelined_speedup",
    "range_fused_speedup",
    "ttl_fused_speedup",
    "sharded_speedup",
    "durability_delta_speedup",
    "gateway_goodput_ratio",
    "tiered_degradation_ratio",
)
SCHEMA = "flix-bench-v1"

# Absolute floors on the fresh artifact, independent of any baseline.
# ``pipelined_speedup`` is double-buffered-vs-single-buffer on the SAME
# host: on TPU the overlap must not lose to the single-buffer path, and on
# CPU hosts the suite re-emits the fused time (ratio exactly 1.0), so a
# value below the floor always means a real pipelining regression — not a
# host difference (DESIGN.md §16).  The gate tolerance applies.
ABSOLUTE_FLOORS = {"pipelined_speedup": 1.0}


def load_artifact(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: expected schema {SCHEMA!r}, got "
                         f"{payload.get('schema')!r}")
    return payload


def collect_speedups(payload: dict) -> dict[str, float]:
    """Flatten the ratio maps to ``field/key -> speedup``."""
    out = {}
    for field in SPEEDUP_FIELDS:
        for key, value in (payload.get(field) or {}).items():
            out[f"{field}/{key}"] = float(value)
    return out


def compare_speedups(
    fresh: dict[str, float],
    baseline: dict[str, float],
    *,
    tolerance: float,
    min_baseline: float,
) -> tuple[list[dict], list[str]]:
    """Return (rows, regressed-key list).  One row per union key."""
    rows, regressions = [], []
    for key in sorted(set(fresh) | set(baseline)):
        new, old = fresh.get(key), baseline.get(key)
        if old is None:
            status = "new"
        elif new is None:
            status = "missing"
        elif old < min_baseline:
            status = "ungated"
        elif new < old * (1.0 - tolerance):
            status = "REGRESSED"
            regressions.append(key)
        else:
            status = "ok"
        delta = (new / old - 1.0) if (new and old) else None
        rows.append(
            {"key": key, "baseline": old, "fresh": new, "delta": delta,
             "status": status}
        )
    return rows, regressions


def render_table(rows: list[dict], *, tolerance: float, min_baseline: float) -> str:
    def fmt(x, spec):
        return format(x, spec) if x is not None else "—"

    lines = [
        "## Bench speedup deltas (flix-bench-v1)",
        "",
        f"gate: fresh < baseline × (1 − {tolerance:.2f}) on keys with "
        f"baseline ≥ {min_baseline:.2f}",
        "",
        "| key | baseline | fresh | delta | status |",
        "| --- | ---: | ---: | ---: | --- |",
    ]
    for r in rows:
        lines.append(
            f"| {r['key']} | {fmt(r['baseline'], '.4f')} | "
            f"{fmt(r['fresh'], '.4f')} | {fmt(r['delta'], '+.1%')} | "
            f"{r['status']} |"
        )
    return "\n".join(lines) + "\n"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="artifact from this run (benchmarks.run)")
    ap.add_argument("baselines", nargs="+",
                    help="committed snapshot(s), oldest first — later files "
                    "override earlier ones key-by-key")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_TOL", "0.20")),
        help="allowed fractional speedup drop before failing "
        "(env REPRO_BENCH_TOL, default 0.20)",
    )
    ap.add_argument(
        "--min-baseline",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_MIN_BASELINE", "0.05")),
        help="baseline ratios below this are reported but not gated "
        "(env REPRO_BENCH_MIN_BASELINE, default 0.05)",
    )
    args = ap.parse_args(argv)

    fresh_payload = load_artifact(args.fresh)
    baseline_map: dict[str, float] = {}
    for path in args.baselines:
        baseline_map.update(collect_speedups(load_artifact(path)))
    fresh_map = collect_speedups(fresh_payload)

    rows, regressions = compare_speedups(
        fresh_map, baseline_map,
        tolerance=args.tolerance, min_baseline=args.min_baseline,
    )
    table = render_table(
        rows, tolerance=args.tolerance, min_baseline=args.min_baseline
    )
    print(table)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(table + "\n")

    floor_violations = []
    for field, floor in ABSOLUTE_FLOORS.items():
        for key, value in fresh_map.items():
            if key.startswith(f"{field}/") and value < floor * (1.0 - args.tolerance):
                floor_violations.append(f"{key}={value:.4f} < floor {floor:.2f}")

    failed_suites = fresh_payload.get("failed") or []
    if failed_suites:
        print(f"FAIL: fresh artifact is truncated (failed suites: "
              f"{failed_suites})", file=sys.stderr)
        return 1
    if floor_violations:
        print(f"FAIL: {len(floor_violations)} absolute-floor violation(s): "
              f"{floor_violations}", file=sys.stderr)
        return 1
    if regressions:
        print(f"FAIL: {len(regressions)} speedup regression(s) beyond "
              f"{args.tolerance:.0%}: {regressions}", file=sys.stderr)
        return 1
    print(f"# gate clean: {len(rows)} keys compared, 0 regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
