"""Fig 13 — successor queries: FliX vs LSMu under increasing deletion rates.

LSMu successor must skip stale/tombstoned entries level by level — the
bounded skip loop degenerates toward a linear scan as deletions accumulate
(the paper reports a ≈69000× gap by round 8).  FliX deletes physically, so
its successor path is deletion-rate-independent.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, emit, keyset, time_call
from repro import core
from repro.core.baselines import lsm


def run() -> None:
    rng = np.random.default_rng(8)
    n = BUILD_SIZE
    keys = keyset(rng, n)
    vals = np.arange(n, dtype=np.int32)
    sk, sv = np.sort(keys), vals[np.argsort(keys)]

    flix = core.build(keys, vals, node_size=32, nodes_per_bucket=16)
    lsmu = lsm.insert(
        lsm.empty_state(chunk=4096, num_levels=lsm_levels(n, 4096)), jnp.asarray(sk), jnp.asarray(sv)
    )

    shuffled = rng.permutation(keys)
    per_round = n // 8
    deleted = 0
    for rnd in range(8):
        dels = jnp.asarray(np.sort(shuffled[rnd * per_round : (rnd + 1) * per_round]))
        flix, _ = core.delete(flix, dels)
        lsmu = lsm.delete(lsmu, dels)
        deleted += per_round

        q = jnp.asarray(np.sort(rng.integers(0, keys.max(), size=n // 4).astype(np.int32)))
        us_f = time_call(lambda: core.successor_query(flix, q))
        # read-only stream form: the suffix-scan cache survives until the
        # next update, so the O(nb) bucket_min scan is paid once per round
        flix_c = core.with_successor_cache(flix)
        us_fc = time_call(lambda: core.successor_query(flix_c, q))
        us_l = time_call(lambda: lsm.successor_query(lsmu, q, max_skips=64))
        emit(f"fig13_succ_r{rnd}_flix", us_f, f"deleted={deleted}")
        emit(
            f"fig13_succ_r{rnd}_flix_cached",
            us_fc,
            f"scan_amortized={us_f/us_fc:.2f}x",
        )
        emit(f"fig13_succ_r{rnd}_lsmu", us_l, f"ratio={us_l/us_f:.1f}x")
