"""Gateway serving benchmarks: goodput under overload + tail latency.

The DESIGN.md §13 claim under measurement: admission control turns
overload into TYPED rejections, not congestion collapse — as offered
load grows past engine capacity the gateway keeps forming full batches,
so **goodput** (committed client requests per virtual tick) holds.  The
gated artifact field is the ratio

    gateway_goodput_ratio[point] = goodput(overload) / goodput(base)

lifted by ``benchmarks.run`` from the ``gateway_goodput_base_<point>`` /
``gateway_goodput_overload_<point>`` row pairs; the acceptance bar is
ratio ≥ 0.8 (in practice ≥ 1: fuller batches).  Everything here runs on
the harness's VIRTUAL clock (``tests/traffic_replay.py``) — the measured
quantities are deterministic request counts and virtual-tick latencies,
so the regression gate never flakes on wall-time jitter; the wall-time
``gateway_wall_us_*`` rows stay ungated records.

The ungated curve rows record the shape: ``gateway_goodput_curve_x{M}``
(goodput at offered-load multiplier M), per-profile shed counts, and
queued-latency percentiles in virtual ticks.
"""

from __future__ import annotations

import sys
import time
from dataclasses import replace
from pathlib import Path

import numpy as np

from benchmarks.common import emit

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tests"))

import traffic_replay as tr  # noqa: E402

TICKS = 16
SEED = 7
BASE_MULT = 1.0
OVERLOAD_MULT = 4.0
CURVE_MULTS = (2.0, 4.0, 8.0)


def _population(mult: float):
    """The default hostile population with offered load scaled ×mult."""
    return [
        replace(
            spec,
            rate=spec.rate * mult,
            burst_size=int(spec.burst_size * mult),
        )
        for spec in tr.default_population(SEED)
    ]


def _run_profile(mult: float):
    idx = tr.make_index()
    gw = tr.make_gateway(idx)
    gw.register_tenant("tenant-hot", rate=24 * mult, burst=48 * mult, weight=3.0)
    gw.register_tenant("tenant-mid", rate=16 * mult, burst=32 * mult)
    t0 = time.perf_counter()
    res = tr.run_traffic(gw, _population(mult), ticks=TICKS, seed=SEED)
    wall_us = (time.perf_counter() - t0) * 1e6
    # the bench reuses the test harness's correctness teeth: a goodput
    # number from a run that double-applied would be meaningless
    tr.assert_exactly_once(res.requests, res.commit_log)
    m = gw.metrics
    shed = sum(
        m["rejected"].get(c, 0) for c in ("RATE_LIMITED", "QUEUE_FULL")
    )
    lat = np.asarray(res.latencies) if res.latencies else np.zeros(1)
    return {
        "goodput": m["committed_requests"] / TICKS,
        "shed": shed,
        "expired": m["expired"],
        "p50": float(np.percentile(lat, 50)),
        "p99": float(np.percentile(lat, 99)),
        "depth_bound": gw.max_queue_ops,
        "wall_us": wall_us,
    }


def run() -> None:
    base = _run_profile(BASE_MULT)
    emit(
        "gateway_goodput_base_mix",
        base["goodput"],
        f"req/tick shed={base['shed']} expired={base['expired']}",
    )
    emit("gateway_latency_p50_base_mix", base["p50"], "virtual ticks")
    emit("gateway_latency_p99_base_mix", base["p99"], "virtual ticks")
    emit("gateway_wall_us_base_mix", base["wall_us"], "ungated wall time")
    for mult in CURVE_MULTS:
        prof = _run_profile(mult)
        point = f"x{mult:g}"
        emit(
            f"gateway_goodput_curve_{point}",
            prof["goodput"],
            f"req/tick shed={prof['shed']} expired={prof['expired']}",
        )
        emit(f"gateway_latency_p99_curve_{point}", prof["p99"], "virtual ticks")
        if mult == OVERLOAD_MULT:
            # the gated pair: same point name as the base row
            emit(
                "gateway_goodput_overload_mix",
                prof["goodput"],
                f"x{OVERLOAD_MULT:g} offered load, shed={prof['shed']}",
            )
            emit("gateway_shed_overload_mix", float(prof["shed"]), "requests")
            emit(
                "gateway_latency_p50_overload_mix", prof["p50"], "virtual ticks"
            )
            emit(
                "gateway_latency_p99_overload_mix", prof["p99"], "virtual ticks"
            )
