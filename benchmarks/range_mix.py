"""RANGE as a first-class mixed-batch op: range-fraction × selectivity sweep.

The paper's central claim over unordered GPU hash tables is that FliX keeps
comparison-based order and therefore answers range queries at all; this
suite measures what that costs inside the batch engine.  The grid:

  * **range fraction** — share of the batch that is RANGE ops (the rest is
    the fig-style serving mix: half POINT reads, a quarter INSERT, a
    quarter DELETE), from range-light (10%) to range-heavy (90%, the
    90/10 read/update shape).
  * **selectivity** — expected stored keys per range (``narrow`` ≈ 16,
    ``wide`` ≈ 256), which moves the work from offset bookkeeping to
    result scatter.

Timed forms:

  * ``apply_ops(impl="reference")`` — the jnp engine (its range phase is
    the dense two-pass oracle: rank fences + exclusive-scan offsets + one
    gather).
  * ``apply_ops(impl="fused")`` — the compute-to-bucket Pallas kernel with
    the in-VMEM range phase, at one sweep point (interpret mode on CPU
    hosts: the recorded "speedup" < 1 is the honest interpret-vs-jnp
    ratio — the number to watch on real hardware).
  * ``flix_range_pallas`` — the standalone two-pass count/scatter kernel on
    a pure range batch, same caveat.

``benchmarks.run`` lifts the ``range_mix_fused_*`` / ``range_mix_ref_*``
pairs into the ``range_fused_speedup`` field of BENCH_PR3.json (DESIGN.md
§7/§10).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.core.config import ExecConfig

SELECTIVITY = {"narrow": 16, "wide": 256}   # expected stored keys per range
RANGE_FRACTIONS = (10, 50, 90)              # percent of the batch
FUSED_POINT = (90, "narrow")                # one interpret-mode fused sample
MAX_RESULTS = 2048                          # per-batch dense output budget


def _batch(rng, keys, absent, batch, rf_pct, span_keys):
    """One mixed batch: rf% RANGE, rest = 50% POINT / 25% INSERT / 25% DEL."""
    n_range = batch * rf_pct // 100
    n_rest = batch - n_range
    n_point = n_rest // 2
    n_ins = (n_rest - n_point) // 2
    n_del = n_rest - n_point - n_ins

    gap = KEY_SPACE // len(keys)            # mean key spacing
    los = rng.integers(0, KEY_SPACE - span_keys * gap, n_range).astype(np.int32)
    his = (los + span_keys * gap).astype(np.int32)
    points = rng.integers(0, KEY_SPACE, n_point).astype(np.int32)
    ins = absent[:n_ins]
    dels = rng.choice(keys, size=n_del, replace=False).astype(np.int32)

    tags = np.concatenate([
        np.full(n_range, core.OP_RANGE), np.full(n_point, core.OP_POINT),
        np.full(n_ins, core.OP_INSERT), np.full(n_del, core.OP_DELETE),
    ]).astype(np.int32)
    bkeys = np.concatenate([los, points, ins, dels]).astype(np.int32)
    bvals = np.concatenate([
        his, np.zeros(n_point, np.int32),
        np.arange(n_ins, dtype=np.int32), np.zeros(n_del, np.int32),
    ]).astype(np.int32)
    return jnp.asarray(tags), jnp.asarray(bkeys), jnp.asarray(bvals)


def run() -> None:
    rng = np.random.default_rng(42)
    n = BUILD_SIZE
    batch = max(512, n // 32)
    keys = keyset(rng, n)
    vals = np.arange(n, dtype=np.int32)
    st = core.build(keys, vals, node_size=32, nodes_per_bucket=16)
    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE, 4 * batch).astype(np.int32), keys
    )

    for sel_name, span in SELECTIVITY.items():
        for rf in RANGE_FRACTIONS:
            jt, jk, jv = _batch(rng, keys, absent, batch, rf, span)

            def reference():
                ops, _ = core.make_ops(jt, jk, jv)
                return core.apply_ops(
                    st, ops, config=ExecConfig(impl="reference", max_results=MAX_RESULTS)
                )

            t_ref = time_call(reference)
            _, res, stats = reference()
            emitted = int(jnp.sum(res["range_count"]))
            emit(
                f"range_mix_ref_rf{rf}_{sel_name}",
                t_ref,
                f"batch={batch};emitted={emitted};"
                f"truncated_ops={int(stats['range_truncated'])}",
            )

            if (rf, sel_name) == FUSED_POINT:

                def fused():
                    ops, _ = core.make_ops(jt, jk, jv)
                    return core.apply_ops(
                        st, ops, config=ExecConfig(impl="fused", max_results=MAX_RESULTS)
                    )

                t_fused = time_call(fused, iters=1)
                emit(
                    f"range_mix_fused_rf{rf}_{sel_name}",
                    t_fused,
                    f"batch={batch};speedup_vs_reference="
                    f"{t_ref / t_fused:.2f}x",
                )

    # standalone two-pass kernel on a pure sorted range batch (narrow)
    from repro.kernels.flix_range import flix_range_pallas

    n_pure = min(256, batch)
    gap = KEY_SPACE // n
    los = np.sort(
        rng.integers(0, KEY_SPACE - 16 * gap, n_pure).astype(np.int32)
    )
    his = (los + 16 * gap).astype(np.int32)
    jlo, jhi = jnp.asarray(los), jnp.asarray(his)

    def standalone():
        return flix_range_pallas(
            st.keys, st.vals, st.mkba, jlo, jhi,
            max_results=MAX_RESULTS, interpret=True,
        )

    t_kernel = time_call(standalone, iters=1)

    import functools
    import jax

    oracle_fn = jax.jit(
        functools.partial(core.dense_range_scan, max_results=MAX_RESULTS)
    )
    ones = jnp.ones((n_pure,), bool)

    def oracle():
        return oracle_fn(st, ones, jlo, jhi)

    t_oracle = time_call(oracle)
    emit(
        "range_mix_kernel_pure256_narrow",
        t_kernel,
        f"oracle_us={t_oracle:.1f};speedup_vs_oracle={t_oracle / t_kernel:.2f}x",
    )
