"""Fig 9 / Fig 2 — query latency (all-hit / all-miss) after each update
round, plus Query-Throughput-per-Memory-Footprint (QTMF)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import lsm_levels, BUILD_SIZE, KEY_SPACE, emit, keyset, time_call
from repro import core
from repro.core.baselines import btree, hash_table as ht, lsm, sorted_array as sa


def run() -> None:
    rng = np.random.default_rng(3)
    n = BUILD_SIZE
    allk = keyset(rng, 2 * n)
    build, extra = allk[:n], allk[n:]
    vals = np.arange(n, dtype=np.int32)
    sk, sv = np.sort(build), vals[np.argsort(build)]

    flix = core.build(build, vals, node_size=32, nodes_per_bucket=16)
    bt = btree.build(build, vals)
    lsmu = lsm.empty_state(chunk=4096, num_levels=lsm_levels(2 * n, 4096))
    lsmu = lsm.insert(lsmu, jnp.asarray(sk), jnp.asarray(sv))
    h = ht.empty_state(capacity=int(2 * n / 0.8))
    h, _ = ht.insert(h, jnp.asarray(sk), jnp.asarray(sv))
    sarr = sa.build(jnp.asarray(sk), jnp.asarray(sv), capacity=2 * n)

    live = set(build.tolist())
    pool = extra.copy()
    per_round = n // 4
    nq = n

    structures = {
        "flix": (lambda q: core.point_query(flix, q), lambda: flix.memory_bytes()),
        "btree": (lambda q: btree.point_query(bt, q), lambda: bt.memory_bytes()),
        "lsmu": (lambda q: lsm.point_query(lsmu, q), lambda: lsmu.memory_bytes()),
        "hashtable": (lambda q: ht.point_query(h, q), lambda: h.memory_bytes()),
        "sortedarray": (lambda q: sa.point_query(sarr, q), lambda: sarr.memory_bytes()),
    }

    # 4 insert rounds then 4 delete rounds; queries after every round
    for rnd in range(8):
        if rnd < 4:
            ins = pool[rnd * per_round : (rnd + 1) * per_round]
            iv = np.arange(len(ins), dtype=np.int32)
            sik, siv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
            flix, _ = core.insert_safe(flix, sik, siv)
            bt = btree.insert(bt, sik, siv)
            lsmu = lsm.insert(lsmu, sik, siv)
            h, _ = ht.insert(h, jnp.asarray(ins), jnp.asarray(iv))
            sarr = sa.insert(sarr, sik, siv)
            live |= set(ins.tolist())
        else:
            dels = np.sort(pool[(rnd - 4) * per_round : (rnd - 3) * per_round])
            dk = jnp.asarray(dels)
            flix, _ = core.delete(flix, dk)
            bt = btree.delete(bt, dk)
            lsmu = lsm.delete(lsmu, dk)
            h = ht.delete(h, dk)
            sarr = sa.delete(sarr, dk)
            live -= set(dels.tolist())

        live_arr = np.fromiter(live, dtype=np.int32)
        hits = jnp.asarray(np.sort(rng.choice(live_arr, size=nq)))
        missable = np.setdiff1d(
            rng.integers(0, KEY_SPACE, size=2 * nq).astype(np.int32), live_arr
        )[:nq]
        misses = jnp.asarray(np.sort(missable))

        for name, (qfn, memfn) in structures.items():
            us_hit = time_call(qfn, hits)
            us_miss = time_call(qfn, misses)
            qtmf = (nq / (us_hit / 1e6)) / memfn()
            emit(f"fig9_q_r{rnd}_hit_{name}", us_hit)
            emit(f"fig9_q_r{rnd}_miss_{name}", us_miss)
            emit(
                f"fig9b_qtmf_r{rnd}_{name}",
                0,
                f"qtmf={qtmf:.3f},mem={int(memfn())}",
            )
