# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver — one module per paper table/figure (DESIGN.md §7).

  python -m benchmarks.run             # everything
  python -m benchmarks.run fig9 fig13  # substring filter
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    build_query_grid,
    delete_rounds,
    dist_shift,
    heatmap,
    insert_rounds,
    mixed_batch,
    query_qtmf,
    restructure_recovery,
    sort_cost,
    successor,
    unsorted_queries,
)

SUITES = {
    "table1_sort": sort_cost,
    "fig5_heatmap": heatmap,
    "fig7_insert_rounds": insert_rounds,
    "fig8_delete_rounds": delete_rounds,
    "fig9_query_qtmf": query_qtmf,
    "fig10_build_query_grid": build_query_grid,
    "fig11_dist_shift": dist_shift,
    "fig12_unsorted_queries": unsorted_queries,
    "fig13_successor": successor,
    "mixed_batch_engine": mixed_batch,
    "table4_restructure": restructure_recovery,
}


def main() -> None:
    filters = sys.argv[1:]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        print(f"# suite {name}", flush=True)
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — keep other suites running
            failed.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
