# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver — one module per paper table/figure (DESIGN.md §7).

  python -m benchmarks.run             # everything
  python -m benchmarks.run fig9 fig13  # substring filter

Besides the CSV rows on stdout, every run writes ``BENCH_PR10.json`` — the
repo's machine-readable perf-trajectory artifact (schema ``flix-bench-v1``,
DESIGN.md §7): per-suite ``name → us_per_call`` maps plus the
fused-vs-reference ``apply_ops`` speedups extracted from the
``mixed_batch`` suite, the pipelined-vs-fused speedups from the same suite
(DESIGN.md §16), the RANGE-op speedups from ``range_mix``, the
TTL-mix speedups from ``ttl_mix``, the sharded-vs-single speedups from
``sharded_mix``, the delta-vs-full snapshot write-volume ratios from
``durability``, the goodput-under-overload ratios from ``gateway``, the
oversubscription-degradation ratios from ``tiered_scale``, and the
deterministic autotuner tile table + sweep record
(``kernels/autotune.py``).  (``BENCH_PR*.json`` in
the repo root are committed per-PR snapshots — ``benchmarks.compare``
diffs against them; don't overwrite them outside a snapshot refresh.)
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

from benchmarks import (
    build_query_grid,
    common,
    delete_rounds,
    dist_shift,
    durability,
    gateway,
    heatmap,
    insert_rounds,
    mixed_batch,
    query_qtmf,
    range_mix,
    restructure_recovery,
    sharded_mix,
    sort_cost,
    successor,
    tiered_scale,
    ttl_mix,
    unsorted_queries,
)

SUITES = {
    "table1_sort": sort_cost,
    "fig5_heatmap": heatmap,
    "fig7_insert_rounds": insert_rounds,
    "fig8_delete_rounds": delete_rounds,
    "fig9_query_qtmf": query_qtmf,
    "fig10_build_query_grid": build_query_grid,
    "fig11_dist_shift": dist_shift,
    "fig12_unsorted_queries": unsorted_queries,
    "fig13_successor": successor,
    "mixed_batch_engine": mixed_batch,
    "range_mix_engine": range_mix,
    "sharded_mix_engine": sharded_mix,
    "ttl_mix_engine": ttl_mix,
    "table4_restructure": restructure_recovery,
    "durability_engine": durability,
    "gateway_engine": gateway,
    "tiered_scale_engine": tiered_scale,
}

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_PR10.json")


def _speedups(
    rows: dict[str, float], fused_prefix: str, ref_prefix: str, key_prefix: str = ""
) -> dict[str, float]:
    """Fused-vs-reference speedup per measured sweep point: every
    ``<fused_prefix><point>`` row is paired with ``<ref_prefix><point>``."""
    out = {}
    for name, us in rows.items():
        if name.startswith(fused_prefix) and us > 0:
            point = name[len(fused_prefix):]
            ref = rows.get(f"{ref_prefix}{point}")
            if ref is not None:
                out[f"{key_prefix}{point}"] = ref / us
    return out


def _sharded_speedups(rows: dict[str, float]) -> dict[str, float]:
    """Sharded-vs-single speedup per sweep point: every
    ``sharded_mix_{rep|a2a}_s{S}_upd{U}`` row is normalized to its
    ``sharded_mix_single_upd{U}`` baseline."""
    out = {}
    for name, us in rows.items():
        if not name.startswith(("sharded_mix_rep_", "sharded_mix_a2a_")) or us <= 0:
            continue
        point = name[len("sharded_mix_"):]          # e.g. rep_s4_upd50
        upd = point.rsplit("_", 1)[-1]              # upd50
        single = rows.get(f"sharded_mix_single_{upd}")
        if single is not None:
            out[point] = single / us
    return out


def _autotune_record() -> dict:
    """Model-mode tile sweep over the bench grid (kernels/autotune.py).

    Pure integer arithmetic — identical on every host — so it is safe to
    embed in the committed artifact and re-derive in CI.  The grid covers
    the suites' build size and the batch sizes the mixed/sharded sweeps
    actually run; geometry matches the bench builds (node_size=32,
    nodes_per_bucket=16)."""
    from repro.kernels.autotune import autotune

    batch = max(1024, common.BUILD_SIZE // 8)
    _, record = autotune(
        (common.BUILD_SIZE // 16, common.BUILD_SIZE),
        (256, batch),
        node_size=32,
        nodes_per_bucket=16,
    )
    return record


def write_bench_json(
    suites: dict[str, dict[str, dict]],
    failed: list[str] = (),
    path: str = BENCH_JSON,
):
    """Serialize the run (schema: DESIGN.md §7, ``flix-bench-v1``)."""
    mixed = {
        name: row["us_per_call"]
        for name, row in suites.get("mixed_batch_engine", {}).items()
    }
    ranges = {
        name: row["us_per_call"]
        for name, row in suites.get("range_mix_engine", {}).items()
    }
    sharded = {
        name: row["us_per_call"]
        for name, row in suites.get("sharded_mix_engine", {}).items()
    }
    durab = {
        name: row["us_per_call"]
        for name, row in suites.get("durability_engine", {}).items()
    }
    gw = {
        name: row["us_per_call"]
        for name, row in suites.get("gateway_engine", {}).items()
    }
    ttl = {
        name: row["us_per_call"]
        for name, row in suites.get("ttl_mix_engine", {}).items()
    }
    tiered = {
        name: row["us_per_call"]
        for name, row in suites.get("tiered_scale_engine", {}).items()
    }
    payload = {
        "schema": "flix-bench-v1",
        "scale": common.SCALE,
        "build_size": common.BUILD_SIZE,
        "suites": suites,
        # non-empty means partial data: these suites threw mid-run, so their
        # row maps are truncated — don't trend against such an artifact
        "failed": list(failed),
        "apply_ops_fused_speedup": _speedups(
            mixed, "mixed_batch_apply_fused_upd", "mixed_batch_apply_ops_upd",
            key_prefix="upd",
        ),
        # double-buffered fused kernel vs the single-buffer fused baseline
        # (the PR9 path, pinned pipeline="off").  On non-TPU hosts the suite
        # re-emits the fused time under the pipelined row, so the ratio is
        # exactly 1.0 — the ≥ 1.0 compare gate then certifies "no
        # regression" portably and the real overlap win shows up on TPU
        "pipelined_speedup": _speedups(
            mixed,
            "mixed_batch_apply_pipelined_upd",
            "mixed_batch_apply_fused_upd",
            key_prefix="upd",
        ),
        # deterministic model-mode tile sweep (kernels/autotune.py): the
        # tuned TileTable rows plus the full per-bucket candidate sweeps,
        # so the artifact documents *why* each tile was chosen
        "autotune": _autotune_record(),
        "range_fused_speedup": _speedups(
            ranges, "range_mix_fused_", "range_mix_ref_"
        ),
        "ttl_fused_speedup": _speedups(
            ttl, "ttl_mix_fused_", "ttl_mix_ref_"
        ),
        "sharded_speedup": _sharded_speedups(sharded),
        # payload-volume ratio (full bytes / delta bytes per churn level):
        # deterministic by construction, so the compare gate never flakes
        # on I/O timing jitter — the wall-time rows stay ungated records
        "durability_delta_speedup": _speedups(
            durab,
            "durability_snap_delta_bytes_churn",
            "durability_snap_full_bytes_churn",
            key_prefix="churn",
        ),
        # goodput(overload)/goodput(base) per traffic point — deterministic
        # request counts on the harness's virtual clock (never wall time),
        # so overload collapsing useful throughput trips the compare gate
        "gateway_goodput_ratio": _speedups(
            gw, "gateway_goodput_base_", "gateway_goodput_overload_"
        ),
        # goodput(10× oversubscribed)/goodput(1×) per read-heavy point —
        # same wall-clock sweep both sides, so the ratio is host-portable
        "tiered_degradation_ratio": _speedups(
            tiered, "tiered_goodput_base_", "tiered_goodput_over_"
        ),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}", flush=True)
    return payload


def main() -> None:
    filters = sys.argv[1:]
    print("name,us_per_call,derived")
    failed = []
    suites: dict[str, dict[str, dict]] = {}
    for name, mod in SUITES.items():
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        mark = len(common.RESULTS)
        print(f"# suite {name}", flush=True)
        try:
            mod.run()
        except Exception:  # noqa: BLE001 — keep other suites running
            failed.append(name)
            traceback.print_exc()
        suites[name] = {
            row_name: {"us_per_call": us, "derived": derived}
            for row_name, us, derived in common.RESULTS[mark:]
        }
        print(f"# suite {name} done in {time.time()-t0:.1f}s", flush=True)
    # a filtered run only writes the artifact when asked for explicitly
    # (REPRO_BENCH_JSON) — otherwise `benchmarks.run fig13` would clobber a
    # committed full-run BENCH_PR2.json with a partial one
    if not filters or "REPRO_BENCH_JSON" in os.environ:
        write_bench_json(suites, failed)
    else:
        print(
            "# filtered run: set REPRO_BENCH_JSON=<path> to write the JSON "
            "artifact",
            flush=True,
        )
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
