"""Tiered residency at 1×–10× oversubscription (DESIGN.md §15).

The claim under measurement: with the index N× larger than the device
budget, a read-heavy serving sweep whose *hot working set* fits the
budget keeps most of its goodput — the prefetch pre-pass promotes the
few buckets each batch touches, the LRU keeps the hot window resident,
and the cold tail stays on the host without being paged per batch.  The
gated artifact field is the ratio

    tiered_degradation_ratio[point] = goodput(10×) / goodput(1×)

lifted by ``benchmarks.run`` from the ``tiered_goodput_base_<point>`` /
``tiered_goodput_over_<point>`` row pairs (goodput = engine ops per
second of wall time, whole-sweep).  The acceptance bar is ratio ≥ 0.5 at
10× oversubscription for the read-heavy points — in practice the ratio
can exceed 1 on this host, because the oversubscribed engine runs the
executors against a working set an order of magnitude smaller than the
full index.

Ungated rows record the shape: ``tiered_goodput_curve_x{M}`` across the
oversubscription sweep, per-M residency/paging counters, and the memory
footprint row pitting FliX's device-resident bytes against the LSM
baseline's (which has no tiering story: its merge levels plus auxiliary
buffer must all stay device-side).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BUILD_SIZE, emit, lsm_levels
from repro.core.config import ExecConfig
from repro import core
from repro.core import TieredFliX, make_ops
from repro.core.baselines import lsm
from repro.core.ops import OP_INSERT, OP_POINT, OP_SUCCESSOR

N = BUILD_SIZE
OVERSUB = (1, 2, 5, 10)
BATCH = 256
ROUNDS = 6
HOT_FRAC = 0.05  # hot window: 5% of the keyspace — fits the 10× budget
POINTS = {"read90": 0.9, "read70": 0.7}  # read fraction per gated point


def _build_state(rng):
    keys = np.arange(0, 2 * N, 2, dtype=np.int32)  # even keys live
    vals = (keys >> 1).astype(np.int32)
    return core.build(keys, vals, node_size=32, nodes_per_bucket=16)


def _batches(rng, read_frac: float):
    """ROUNDS read-heavy batches over a half-overlap rotating hot window."""
    span = 2 * N
    width = max(64, int(span * HOT_FRAC))
    out = []
    for t in range(ROUNDS):
        lo = (t * width // 2) % max(1, span - width)
        window = np.arange(lo, lo + width, dtype=np.int32)
        n_read = int(BATCH * read_frac)
        n_ins = BATCH - n_read
        reads = rng.choice(window, n_read)  # live evens + missing odds
        ins = rng.choice(window[window % 2 == 1], n_ins, replace=False)
        keys = np.concatenate([reads, ins]).astype(np.int32)
        tags = np.concatenate(
            [
                rng.choice(
                    np.array([OP_POINT, OP_SUCCESSOR], np.int32),
                    n_read,
                    p=[0.7, 0.3],
                ),
                np.full(n_ins, OP_INSERT, np.int32),
            ]
        )
        ops, _ = make_ops(tags, keys, (keys * 3 + t).astype(np.int32))
        out.append(ops)
    return out


def _sweep(st, budget, batches):
    """One full serving sweep on a fresh tiered index; returns (ops/s,
    final TieredFliX) — fresh per call because ``apply`` mutates."""
    tiered = TieredFliX.from_state(st, budget_bytes=budget)
    t0 = time.perf_counter()
    for ops in batches:
        tiered.apply(ops, config=ExecConfig(impl="reference"))
    dt = time.perf_counter() - t0
    return (ROUNDS * BATCH) / dt, tiered


def run() -> None:
    rng = np.random.default_rng(15)
    st = _build_state(rng)
    full = st.memory_bytes()

    for point, read_frac in POINTS.items():
        batches = _batches(rng, read_frac)
        goodput = {}
        for m in OVERSUB:
            budget = None if m == 1 else max(1, full // m)
            _sweep(st, budget, batches)  # warmup: compile the apply paths
            g1, t1 = _sweep(st, budget, batches)
            g2, t2 = _sweep(st, budget, batches)
            goodput[m] = max(g1, g2)
            tiered = t2
            emit(
                f"tiered_goodput_curve_x{m}_{point}",
                goodput[m],
                f"ops/s,resident={tiered.memory_bytes_resident()}"
                f",promoted={tiered.promoted_total}"
                f",demoted={tiered.demoted_total}",
            )
        # the gated pair: benchmarks.run lifts over/base into
        # tiered_degradation_ratio[point]
        emit(f"tiered_goodput_base_{point}", goodput[1], "ops/s at 1x")
        emit(f"tiered_goodput_over_{point}", goodput[10], "ops/s at 10x")

    # memory footprint vs the LSM baseline (no tiering story: every merge
    # level plus the auxiliary buffer is device-side by construction)
    keys = np.arange(0, 2 * N, 2, dtype=np.int32)
    lsmu = lsm.empty_state(chunk=4096, num_levels=lsm_levels(2 * N, 4096))
    lsmu = lsm.insert(lsmu, jnp.asarray(keys), jnp.asarray((keys >> 1)))
    budget10 = max(1, full // 10)
    emit(
        "tiered_mem_x10",
        0,
        f"flix_budget={budget10},flix_full={full}"
        f",lsm_full={int(lsmu.memory_bytes())}",
    )
