"""Generic decoder covering all 10 assigned architectures.

One parameter/forward scheme spans the families:

  * dense / vlm / audio — pre-norm GQA attention + SwiGLU MLP blocks,
    full / SWA / local:global masking, optional QKV bias, optional
    bidirectional prefix (the VLM/audio stub embeddings).
  * moe  — same attention; the FFN is the flipped-dispatch MoE layer.
  * ssm  — Mamba-2 (SSD) blocks, attention-free.
  * hybrid — Mamba-2 stack with one *shared* attention block applied every
    ``attn_every`` layers (Zamba-2 scheme: same weights at every point).

Training/prefill scans over stacked layer params (compact HLO, fast
compiles at 512 devices); decode unrolls a Python loop so per-layer caches
can be ragged (ring buffers for SWA/local layers, full for global).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, attention, rms_norm, rope_angles

Params = dict[str, Any]
_BIG = 1 << 30  # "infinite" attention window


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_layer_init(rng, cfg: ModelConfig, scale_out: float, dtype):
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh, f = cfg.resolved_head_dim, cfg.d_ff
    ks = jax.random.split(rng, 8)
    init = jax.nn.initializers.normal(0.02)
    p = {
        "attn_norm": jnp.ones((d,), dtype),
        "wq": init(ks[0], (d, hq * dh), dtype),
        "wk": init(ks[1], (d, hkv * dh), dtype),
        "wv": init(ks[2], (d, hkv * dh), dtype),
        "wo": init(ks[3], (hq * dh, d), dtype) * scale_out,
        "mlp_norm": jnp.ones((d,), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.family == "moe":
        e = cfg.num_experts * cfg.moe_split          # virtual experts
        mf = cfg.moe_d_ff // cfg.moe_split
        p["router"] = init(ks[4], (d, cfg.num_experts), jnp.float32)
        p["w_gate"] = init(ks[5], (e, d, mf), dtype)
        p["w_up"] = init(ks[6], (e, d, mf), dtype)
        p["w_down"] = init(ks[7], (e, mf, d), dtype) * scale_out
        if cfg.num_shared_experts:
            fs = cfg.num_shared_experts * mf
            sk = jax.random.split(ks[4], 3)
            p["shared_gate"] = init(sk[0], (d, fs), dtype)
            p["shared_up"] = init(sk[1], (d, fs), dtype)
            p["shared_down"] = init(sk[2], (fs, d), dtype) * scale_out
    else:
        p["w_gate"] = init(ks[4], (d, f), dtype)
        p["w_up"] = init(ks[5], (d, f), dtype)
        p["w_down"] = init(ks[6], (f, d), dtype) * scale_out
    return p


def _ssm_layer_init(rng, cfg: ModelConfig, scale_out: float, dtype):
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.conv_kernel
    ks = jax.random.split(rng, 8)
    init = jax.nn.initializers.normal(0.02)
    return {
        "norm": jnp.ones((d,), dtype),
        "in_z": init(ks[0], (d, di), dtype),
        "in_x": init(ks[1], (d, di), dtype),
        "in_B": init(ks[2], (d, n), dtype),
        "in_C": init(ks[3], (d, n), dtype),
        "in_dt": init(ks[4], (d, h), dtype),
        "conv_x": init(ks[5], (k, di), dtype),
        "conv_B": init(ks[6], (k, n), dtype),
        "conv_C": init(ks[7], (k, n), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(0) = -1
        "D_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": init(ks[5], (di, d), dtype) * scale_out,
    }


def init_params(rng, cfg: ModelConfig, param_dtype=jnp.float32) -> Params:
    dtype = param_dtype
    scale_out = 1.0 / math.sqrt(2 * cfg.num_layers)
    k_embed, k_layers, k_head, k_shared = jax.random.split(rng, 4)
    init = jax.nn.initializers.normal(0.02)

    params: Params = {
        "embed": init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init(k_head, (cfg.d_model, cfg.vocab_size), dtype)

    if cfg.family in ("ssm", "hybrid"):
        layer_init = partial(_ssm_layer_init, cfg=cfg, scale_out=scale_out, dtype=dtype)
    else:
        layer_init = partial(
            _dense_layer_init, cfg=cfg, scale_out=scale_out, dtype=dtype
        )
    keys = jax.random.split(k_layers, cfg.num_layers)
    params["layers"] = jax.vmap(lambda k: layer_init(k))(keys)

    if cfg.family == "hybrid":
        # the shared transformer block (Zamba-2): one set of weights
        params["shared_attn"] = _dense_layer_init(
            k_shared, cfg, scale_out=scale_out, dtype=dtype
        )
    return params


def layer_is_global(cfg: ModelConfig):
    """Per-layer global-attention flags (host-side numpy: static under jit)."""
    import numpy as np

    idx = np.arange(cfg.num_layers)
    if cfg.attention == "full":
        return np.ones(cfg.num_layers, bool)
    if cfg.attention == "swa":
        return np.zeros(cfg.num_layers, bool)
    r = cfg.local_global_ratio  # r local layers, then 1 global
    return (idx + 1) % (r + 1) == 0


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_block(x, lp, cfg: ModelConfig, positions, is_global, prefix_len, q_chunk):
    B, S, D = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, hq, dh)
    k = k.reshape(B, S, hkv, dh)
    v = v.reshape(B, S, hkv, dh)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = attention(
        q,
        k,
        v,
        positions,
        positions,
        is_global,
        window=cfg.window,
        q_chunk=q_chunk,
        prefix_len=prefix_len,
    )
    return x + out.reshape(B, S, hq * dh) @ lp["wo"]


def _ffn_block(x, lp, cfg: ModelConfig):
    B, S, D = x.shape
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        if cfg.moe_impl == "a2a" and cfg.moe_mesh is not None:
            from repro.models.moe_a2a import moe_ffn_a2a

            y = moe_ffn_a2a(h.reshape(B * S, D), lp, cfg, cfg.moe_mesh).reshape(
                B, S, D
            )
        else:
            y = moe_lib.moe_ffn(h.reshape(B * S, D), lp, cfg).reshape(B, S, D)
    else:
        y = (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]
    return x + y


def _dense_layer(x, lp, cfg, positions, is_global, prefix_len, q_chunk):
    x = _attn_block(x, lp, cfg, positions, is_global, prefix_len, q_chunk)
    return _ffn_block(x, lp, cfg)


def _ssm_layer(x, lp, cfg):
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    y, _ = ssm_lib.mamba2_forward_split(h, lp, cfg)
    return x + y


def forward_hidden(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                 # [B, S_text]
    prefix_embeds: jax.Array | None = None,  # [B, P, D] stub frontend output
    *,
    remat: bool = False,
    q_chunk: int = 512,
    layer_loop: str = "scan",          # "scan" (prod) | "unroll" (analysis)
    act_spec=None,                     # PartitionSpec for the residual stream
) -> jax.Array:
    """Full-sequence forward → post-final-norm hidden [B, S_total, D].

    ``act_spec``: Megatron-SP-style constraint — the residual stream (and
    hence every saved remat checkpoint) shards over the model axis on the
    *sequence* dim; GSPMD inserts the all-gather/reduce-scatter pair around
    attention.  Cuts per-device activation memory by ``tp×``.
    """
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][tokens].astype(compute)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(compute), x], axis=1)
    B, S, D = x.shape
    positions = jnp.arange(S)
    glob = layer_is_global(cfg)

    constrain = (
        (lambda h: jax.lax.with_sharding_constraint(h, act_spec))
        if act_spec is not None
        else (lambda h: h)
    )
    x = constrain(x)
    def cast(t):
        return jax.tree.map(
            lambda a: a.astype(compute)
            if a.dtype in (jnp.float32, jnp.bfloat16)
            else a,
            t,
        )

    if cfg.family in ("ssm", "hybrid"):
        def ssm_step(h, lp):
            return constrain(_ssm_layer(h, cast(lp), cfg)), None

        if remat:
            ssm_step = jax.checkpoint(ssm_step)

        if cfg.family == "ssm":
            if layer_loop == "scan":
                x, _ = jax.lax.scan(ssm_step, x, params["layers"])
            else:
                for i in range(cfg.num_layers):
                    x, _ = ssm_step(x, jax.tree.map(lambda a: a[i], params["layers"]))
        else:
            g = cfg.attn_every
            ngroups = cfg.num_layers // g
            grouped = jax.tree.map(
                lambda a: a.reshape((ngroups, g) + a.shape[1:]), params["layers"]
            )
            shared = cast(params["shared_attn"])

            def group_step(h, glp):
                if layer_loop == "scan":
                    h, _ = jax.lax.scan(ssm_step, h, glp)
                else:
                    for i in range(g):
                        h, _ = ssm_step(h, jax.tree.map(lambda a: a[i], glp))
                h = _dense_layer(
                    h, shared, cfg, positions, jnp.array(True), prefix_len, q_chunk
                )
                return constrain(h), None

            if remat:
                group_step = jax.checkpoint(group_step)
            if layer_loop == "scan":
                x, _ = jax.lax.scan(group_step, x, grouped)
            else:
                for i in range(ngroups):
                    x, _ = group_step(x, jax.tree.map(lambda a: a[i], grouped))
    else:
        def step(h, xs):
            lp, is_g = xs
            return constrain(
                _dense_layer(h, cast(lp), cfg, positions, is_g, prefix_len, q_chunk)
            ), None

        if remat:
            step = jax.checkpoint(step)
        if layer_loop == "scan":
            x, _ = jax.lax.scan(step, x, (params["layers"], jnp.asarray(glob)))
        else:
            for i in range(cfg.num_layers):
                x, _ = step(
                    x,
                    (
                        jax.tree.map(lambda a: a[i], params["layers"]),
                        jnp.asarray(glob[i]),
                    ),
                )

    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    prefix_embeds: jax.Array | None = None,
    *,
    remat: bool = False,
    q_chunk: int = 512,
) -> jax.Array:
    """Full-sequence forward → logits [B, S_total, vocab]."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = forward_hidden(
        params, cfg, tokens, prefix_embeds, remat=remat, q_chunk=q_chunk
    )
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(compute)
    return x @ head


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _cache_len(cfg: ModelConfig, layer_idx: int, max_len: int, glob) -> int:
    if cfg.attention == "full" or bool(glob[layer_idx]):
        return max_len
    return min(cfg.window, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Ragged per-layer cache (ring buffers for local/SWA layers)."""
    glob = layer_is_global(cfg)
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    layers = []
    for i in range(cfg.num_layers):
        if cfg.family in ("ssm", "hybrid"):
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            layers.append(
                {
                    "conv": jnp.zeros(
                        (batch, cfg.conv_kernel - 1, conv_dim), dtype
                    ),
                    "ssm": jnp.zeros(
                        (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                        jnp.float32,
                    ),
                }
            )
        else:
            w = _cache_len(cfg, i, max_len, glob)
            layers.append(
                {
                    "k": jnp.zeros((batch, w, hkv, dh), dtype),
                    "v": jnp.zeros((batch, w, hkv, dh), dtype),
                }
            )
    cache = {"layers": layers, "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        ngroups = cfg.num_layers // cfg.attn_every
        cache["shared_kv"] = [
            {
                "k": jnp.zeros((batch, max_len, hkv, dh), dtype),
                "v": jnp.zeros((batch, max_len, hkv, dh), dtype),
            }
            for _ in range(ngroups)
        ]
    return cache


def _decode_attn(x, lp, cfg: ModelConfig, kv, pos, is_global: bool):
    """One-token attention against a (ring or linear) KV cache."""
    B, _, D = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    W = kv["k"].shape[1]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, 1, hq, dh)
    k = k.reshape(B, 1, hkv, dh)
    v = v.reshape(B, 1, hkv, dh)
    cos, sin = rope_angles(pos[None], dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = pos % W
    kc = jax.lax.dynamic_update_slice_in_dim(
        kv["k"], k.astype(kv["k"].dtype), slot, axis=1
    )
    vc = jax.lax.dynamic_update_slice_in_dim(
        kv["v"], v.astype(kv["v"].dtype), slot, axis=1
    )
    # true token position held by each ring slot
    j = jnp.arange(W)
    k_positions = pos - ((slot - j) % W)
    out = attention(
        q,
        kc,
        vc,
        q_positions=pos[None],
        k_positions=k_positions,
        is_global=jnp.array(is_global),
        window=cfg.window if not is_global else _BIG,
        q_chunk=1,
    )
    x = x + out.reshape(B, 1, hq * dh) @ lp["wo"]
    return x, {"k": kc, "v": vc}


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache,
    token: jax.Array,   # [B] current token ids
):
    """serve_step: one new token against the cache. Returns (logits, cache)."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pos = cache["pos"]
    x = params["embed"][token][:, None].astype(compute)   # [B, 1, D]
    glob = layer_is_global(cfg)
    def cast(t):
        return jax.tree.map(
            lambda a: a.astype(compute)
            if a.dtype in (jnp.float32, jnp.bfloat16)
            else a,
            t,
        )

    new_layers = []
    if cfg.family in ("ssm", "hybrid"):
        new_shared = []
        for i in range(cfg.num_layers):
            lp = cast(jax.tree.map(lambda a: a[i], params["layers"]))
            st = cache["layers"][i]
            h = rms_norm(x[:, 0], lp["norm"], cfg.norm_eps)
            y, conv2, ssm2 = ssm_lib.mamba2_decode_split(
                h, lp, cfg, st["conv"], st["ssm"]
            )
            x = x + y[:, None]
            new_layers.append({"conv": conv2, "ssm": ssm2})
            if cfg.family == "hybrid" and (i + 1) % cfg.attn_every == 0:
                gidx = (i + 1) // cfg.attn_every - 1
                x, kv2 = _decode_attn(
                    x,
                    cast(params["shared_attn"]),
                    cfg,
                    cache["shared_kv"][gidx],
                    pos,
                    is_global=True,
                )
                x = _ffn_block(x, cast(params["shared_attn"]), cfg)
                new_shared.append(kv2)
    else:
        for i in range(cfg.num_layers):
            lp = cast(jax.tree.map(lambda a: a[i], params["layers"]))
            x, kv2 = _decode_attn(
                x, lp, cfg, cache["layers"][i], pos, is_global=bool(glob[i])
            )
            x = _ffn_block(x, lp, cfg)
            new_layers.append(kv2)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(compute)
    logits = x[:, 0] @ head
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if cfg.family == "hybrid":
        new_cache["shared_kv"] = new_shared
    return logits, new_cache
