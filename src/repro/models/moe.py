"""MoE layer with flipped (sort-based) dispatch — FliX integration point.

Tokens are sorted by expert id; each expert (bucket) pulls its contiguous
slice through static per-expert capacity windows (GShard-style capacity so
shapes stay static for pjit; overflow drops are counted).  FLOPs scale with
*active* experts (E × C × D × F), not E × T — unlike the dense one-hot
formulation — so the roofline's MODEL_FLOPS/HLO_FLOPs ratio stays honest.

Expert weights are sharded over the ``model`` axis (expert parallelism);
the dispatch gather/scatter becomes the all-to-all the §Roofline collective
term measures.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def capacity(tokens: int, top_k: int, num_experts: int, factor: float) -> int:
    c = math.ceil(tokens * top_k / num_experts * factor)
    return max(8, math.ceil(c / 8) * 8)


def moe_ffn(x: jax.Array, p: dict, cfg) -> jax.Array:
    """x: [T, D] → [T, D].  Params:

    router [D, E]; w_gate/w_up [E·split, D, F/split]; w_down [E·split, F/split, D];
    shared_gate/shared_up [D, Fs]; shared_down [Fs, D] (when shared experts).

    ``cfg.moe_split`` > 1 splits each expert's FFN into column chunks
    ("virtual experts") so the expert dim matches a larger TP axis; a token
    visits all chunks of its expert and the down-projection partial sums add
    in the combine.  ``cfg.dispatch_spec`` shards the [E, C, ·] dispatch
    intermediates over (expert axis × token axis) — without the token-axis
    constraint every data-parallel replica computes identical expert work
    (the 16× HLO-FLOP inflation in EXPERIMENTS.md §Perf iteration 1).
    """
    T, D = x.shape
    E, k, split = cfg.num_experts, cfg.top_k, cfg.moe_split
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gate = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(gate, k)                   # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    if split > 1:  # expand to virtual experts: e → (e·split … e·split+split-1)
        experts = (
            experts[..., None] * split + jnp.arange(split, dtype=experts.dtype)
        ).reshape(T, k * split)
        weights = jnp.repeat(weights, split, axis=-1)  # partial sums share w
    E_v, k_v = E * split, k * split

    flat_expert = experts.reshape(-1).astype(jnp.int32)         # [T·k_v]
    sort_idx = jnp.argsort(flat_expert, stable=True)
    expert_sorted = flat_expert[sort_idx]
    group_offsets = jnp.searchsorted(
        expert_sorted, jnp.arange(E_v + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    C = capacity(T, k, E, cfg.moe_capacity_factor)  # per (virtual) expert

    constrain3 = (
        (lambda a: jax.lax.with_sharding_constraint(a, cfg.dispatch_spec))
        if cfg.dispatch_spec is not None
        else (lambda a: a)
    )

    # each (virtual) expert pulls its slice through a capacity window
    idx = group_offsets[:-1, None] + jnp.arange(C, dtype=jnp.int32)[None]
    valid = idx < group_offsets[1:, None]                       # [E_v, C]
    slot = jnp.minimum(idx, T * k_v - 1)
    token = sort_idx[slot] // k_v                               # [E_v, C]
    xe = x[token] * valid[..., None].astype(x.dtype)            # [E_v, C, D]
    xe = constrain3(xe)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    h = constrain3(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])             # [E_v, C, D]
    ye = constrain3(ye)

    # combine: weighted scatter-add back to token order
    w_slot = weights.reshape(-1)[sort_idx][slot] * valid        # [E_v, C]
    contrib = (ye * w_slot[..., None]).reshape(E_v * C, D)
    tok_flat = jnp.where(valid, token, T).reshape(E_v * C)      # T = dump row
    y = jnp.zeros((T + 1, D), contrib.dtype).at[tok_flat].add(contrib)[:T]
    if cfg.dispatch_spec is not None:
        # token-sharded combine output → the partial-sum reduction becomes a
        # reduce-scatter over (expert × token) shards instead of a full AR
        from jax.sharding import PartitionSpec as _P

        tok_axes = cfg.dispatch_spec[1]
        y = jax.lax.with_sharding_constraint(y, _P(tok_axes, None))

    if cfg.num_shared_experts:
        hs = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        y = y + hs @ p["shared_down"]
    return y.astype(x.dtype)


def moe_ffn_dense_oracle(x: jax.Array, p: dict, cfg) -> jax.Array:
    """Every expert computes every token; exact combine (tests only)."""
    E, k = cfg.num_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gate = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(gate, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", x, p["w_gate"])) * jnp.einsum(
        "td,edf->etf", x, p["w_up"]
    )
    ye = jnp.einsum("etf,efd->etd", h, p["w_down"])
    oh = jax.nn.one_hot(experts, E, axis=-1)
    y = jnp.einsum("tke,etd,tk->td", oh, ye, weights).astype(x.dtype)
    if cfg.num_shared_experts:
        hs = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        y = y + hs @ p["shared_down"]
    return y
