"""Model registry + parameter init glue for the assigned architectures."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import SHAPES, ModelConfig
from repro.models.frontends import prefix_spec


def get_config(name: str) -> ModelConfig:
    from repro import configs

    return configs.get(name)


def list_archs() -> list[str]:
    from repro import configs

    return sorted(configs.REGISTRY)


def init_params(rng, cfg: ModelConfig, param_dtype=jnp.float32):
    return transformer.init_params(rng, cfg, param_dtype)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def abstract_params(cfg: ModelConfig, param_dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, param_dtype),
        jax.random.PRNGKey(0),
    )


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    if sh["kind"] in ("train", "prefill"):
        text = S - (cfg.frontend_len if cfg.frontend else 0)
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, text), jnp.int32),
        }
        if sh["kind"] == "train":
            specs["targets"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
        pf = prefix_spec(cfg, B)
        if pf is not None:
            specs["prefix_embeds"] = pf
        return specs
    # decode: one new token against a seq_len cache
    return {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}


def abstract_cache(cfg: ModelConfig, shape_name: str, dtype=jnp.bfloat16):
    sh = SHAPES[shape_name]
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, sh["global_batch"], sh["seq_len"], dtype)
    )
