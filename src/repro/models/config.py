"""Architecture configs for the assigned-architecture pool.

``ModelConfig`` describes the *exact* published architecture; ``padded(tp)``
derives the tensor-parallel deployment layout (head padding / kv duplication
— the standard trick inference engines use when ``tp > num_kv_heads``).
Padding inflates HLO FLOPs over MODEL_FLOPS; the roofline report shows the
ratio explicitly (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // num_heads
    # attention flavor
    attention: str = "full"      # full | swa | local_global
    window: int = 4096
    local_global_ratio: int = 0  # N local layers per 1 global (gemma3: 5)
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # deployment transforms (set by padded() / build_cell, not by configs):
    moe_split: int = 1          # virtual-expert split for EP alignment when
                                # tp > num_experts (each expert's FFN splits
                                # into `split` column chunks = virtual experts)
    dispatch_spec: Any = None   # PartitionSpec for [E, C, D] MoE dispatch
                                # intermediates (EP × token-parallel)
    moe_impl: str = "gather"    # "gather" (pjit) | "a2a" (shard_map routing)
    moe_mesh: Any = None        # mesh for the a2a impl (set by build_cell)
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4
    attn_every: int = 0          # hybrid: shared attn block every N ssm layers
    # modality frontend stub
    frontend: str | None = None  # vision_stub | audio_stub
    frontend_len: int = 0        # prefix length supplied by the stub
    # misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def padded(self, tp: int) -> "ModelConfig":
        """Deployment layout for ``tp``-way tensor parallelism.

        kv heads are duplicated up to ``tp`` when ``tp % kv == 0`` (vLLM-style
        replication), otherwise both head counts zero-pad to the next multiple
        of ``tp`` preserving an integral q-per-kv group.
        """
        vocab_pad = math.ceil(self.vocab_size / tp) * tp
        if tp <= 1:
            return self
        # EP alignment: when tp > E, split each expert's FFN into column
        # chunks so the virtual expert count matches the axis (vLLM-style).
        moe_split = 1
        if (
            self.family == "moe"
            and self.num_experts % tp != 0
            and tp % self.num_experts == 0
            and self.moe_d_ff % (tp // self.num_experts) == 0
        ):
            moe_split = tp // self.num_experts
        if self.num_heads == 0:
            return dataclasses.replace(self, vocab_size=vocab_pad)
        hq, hkv = self.num_heads, self.num_kv_heads
        if hkv % tp == 0:
            kv_pad = hkv
        elif tp % hkv == 0:
            kv_pad = tp
        else:
            kv_pad = math.ceil(hkv / tp) * tp
        group = max(1, math.ceil(hq / kv_pad))
        q_pad = kv_pad * group
        return dataclasses.replace(
            self,
            num_heads=q_pad,
            num_kv_heads=kv_pad,
            head_dim=self.resolved_head_dim,
            vocab_size=vocab_pad,
            moe_split=moe_split,
        )

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family (CPU-runnable)."""
        small = dict(
            num_layers=2,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.num_heads else 0,
            window=min(self.window, 16),
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            frontend_len=8 if self.frontend else 0,
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input shape cells (assigned to every architecture)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k is restricted to sub-quadratic archs (DESIGN.md §5): SSM/hybrid
# decode state, or SWA / local:global bounded KV.
LONG_CONTEXT_ARCHS = {
    "mamba2-1.3b",
    "zamba2-2.7b",
    "h2o-danube-3-4b",
    "gemma3-12b",
    "mixtral-8x22b",
}


def cells_for(arch: str) -> list[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes
