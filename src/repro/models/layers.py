"""Shared transformer layers: norms, RoPE, GQA attention, gated MLP.

Attention supports full / sliding-window / per-layer local:global causal
masking, GQA/MQA head grouping, optional QKV bias, and a blockwise
(q-chunked) softmax so the score matrix never materializes at [S, S]
(peak transient = [B, H, q_chunk, S]).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight).astype(dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for the given positions: [..., head_dim//2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., S, H, Dh]; cos/sin: [..., S, Dh//2] (broadcast over H)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(
        x.dtype
    )


def _mask(
    q_pos: jax.Array,      # [Sq]
    k_pos: jax.Array,      # [Sk]
    window: int,
    is_global,             # scalar bool (traced ok)
    prefix_len: int = 0,
):
    """Causal (+windowed when local) mask; bidirectional within the prefix."""
    i = q_pos[:, None]
    j = k_pos[None, :]
    causal = (j <= i) & (j >= 0)  # j < 0 marks unwritten ring-cache slots
    if prefix_len:
        causal |= (i < prefix_len) & (j < prefix_len) & (j >= 0)
    local = causal & (j > i - window)
    return jnp.where(is_global, causal, local)


@partial(jax.jit, static_argnames=("q_chunk", "window", "prefix_len"))
def attention(
    q: jax.Array,          # [B, Sq, Hq, Dh]
    k: jax.Array,          # [B, Sk, Hkv, Dh]
    v: jax.Array,          # [B, Sk, Hkv, Dh]
    q_positions: jax.Array,  # [Sq]
    k_positions: jax.Array,  # [Sk]
    is_global,             # traced scalar bool (layer flavor)
    *,
    window: int,
    q_chunk: int = 512,
    prefix_len: int = 0,
) -> jax.Array:
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = Dh**-0.5
    kq = k.astype(jnp.float32)
    vq = v.astype(jnp.float32)

    q_chunk = min(q_chunk, Sq)
    n_chunks = max(Sq // q_chunk, 1)

    def one_chunk(c):
        qp = jax.lax.dynamic_slice_in_dim(q_positions, c * q_chunk, q_chunk)
        qc = jax.lax.dynamic_slice_in_dim(q, c * q_chunk, q_chunk, axis=1)
        qc = qc.reshape(B, q_chunk, Hkv, G, Dh).astype(jnp.float32)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kq) * scale
        m = _mask(qp, k_positions, window, is_global, prefix_len)
        scores = jnp.where(m[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vq)
        return out.reshape(B, q_chunk, Hq, Dh)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        chunks = jax.lax.map(one_chunk, jnp.arange(n_chunks))
        out = jnp.moveaxis(chunks, 0, 1).reshape(B, Sq, Hq, Dh)
    return out.astype(q.dtype)


def gated_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    """SwiGLU: down( silu(x @ gate) * (x @ up) )."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def softmax_cross_entropy_sharded(
    logits: jax.Array,   # [B, S, V] (V possibly sharded)
    targets: jax.Array,  # [B, S]
    mask: jax.Array | None = None,
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
