"""Assigned-architecture model stack (configs, layers, transformer, MoE, SSM)."""
