"""Explicit all-to-all MoE dispatch (shard_map) — §Perf iteration 4.

The pjit gather/scatter dispatch (models/moe.py) lets GSPMD lower the
cross-shard token gather as per-layer all-gathers of the full activation
tensor (~25 GB/chip/layer on deepseek train_4k).  This module routes tokens
explicitly instead — the *distributed* FliX pattern (core/distributed.py
``shard_apply_ops``'s a2a routing) applied to experts:

  * tokens are sharded over every mesh axis (data × model);
  * expert weights are EP-sharded over ``model`` and replicated over data,
    so a token on device (d, m) only ever needs devices (d, ·) — the
    all-to-all runs along the model axis within each data row;
  * each device sorts its local token-slots by expert (the sorted batch),
    slices per-destination ranges by searchsorted (the fence pull), and
    exchanges fixed-capacity buffers; experts compute locally; results
    return through the inverse all-to-all.

Per-chip bytes per layer ≈ 2 · T_loc · k · D (send + return) — independent
of the token-parallel width — vs the gather formulation's T · D all-gather.

Capacity contract: per-(src,dst) buffer is
``ceil(T_loc · k / n_exp_shards · factor)`` rounded to 8; overflow slots are
dropped (standard capacity-style MoE; the factor is config).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map


def _local_capacity(t_loc: int, k: int, n_shards: int, factor: float) -> int:
    c = math.ceil(t_loc * k / n_shards * factor)
    return max(8, math.ceil(c / 8) * 8)


def moe_ffn_a2a(x: jax.Array, p: dict, cfg, mesh) -> jax.Array:
    """x: [T, D] (token-sharded over all mesh axes) → [T, D]."""
    E, k, split = cfg.num_experts, cfg.top_k, cfg.moe_split
    E_v, k_v = E * split, k * split
    ep_axis = "model"
    token_axes = tuple(a for a in mesh.axis_names)  # tokens over all axes
    n_ep = int(mesh.shape[ep_axis])
    e_loc = E_v // n_ep
    T, D = x.shape
    t_loc = T // int(mesh.devices.size)
    C_pair = _local_capacity(t_loc, k_v, n_ep, cfg.moe_capacity_factor)
    R = n_ep * C_pair  # received slots per device

    def body(x_loc, router, w_gate, w_up, w_down):
        tl = x_loc.shape[0]
        # --- route: top-k + virtual-expert expansion ----------------------
        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        weights, experts = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        weights = weights / jnp.sum(weights, -1, keepdims=True)
        if split > 1:
            experts = (
                experts[..., None] * split
                + jnp.arange(split, dtype=experts.dtype)
            ).reshape(tl, k_v)
            weights = jnp.repeat(weights, split, axis=-1)

        # --- sort the batch by expert (the FliX sorted batch) --------------
        flat_e = experts.reshape(-1).astype(jnp.int32)          # [tl*k_v]
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        tok_sorted = order // k_v
        w_sorted = weights.reshape(-1)[order]

        # --- per-destination slices (fence searchsorted) -------------------
        # destination shard of expert e is e // e_loc
        shard_fences = (
            jnp.arange(1, n_ep + 1, dtype=jnp.int32) * e_loc
        )  # first expert NOT owned by shard s
        ends = jnp.searchsorted(e_sorted, shard_fences, side="left")
        starts = jnp.concatenate([jnp.zeros((1,), ends.dtype), ends[:-1]])

        idx = starts[:, None] + jnp.arange(C_pair, dtype=jnp.int32)[None]
        valid = idx < ends[:, None]                             # [n_ep, C]
        idx_c = jnp.minimum(idx, tl * k_v - 1)
        send_x = jnp.where(
            valid[..., None], x_loc[tok_sorted[idx_c]], 0
        )                                                        # [n_ep, C, D]
        send_e = jnp.where(valid, e_sorted[idx_c], -1)           # local tag
        send_slot = jnp.where(valid, idx_c, -1)                  # for return

        # --- all-to-all along the EP axis ----------------------------------
        recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=False)

        # --- local expert compute: sort received rows by local expert and
        #     pull per-expert capacity windows (FliX again, one level down) --
        my_first = jax.lax.axis_index(ep_axis) * e_loc
        rx = recv_x.reshape(R, D)
        re_raw = recv_e.reshape(R)
        valid_r = re_raw >= 0
        re = jnp.where(valid_r, re_raw - my_first, e_loc)        # pad → end
        order2 = jnp.argsort(re, stable=True)
        rx_s = rx[order2]
        offs = jnp.searchsorted(
            re[order2], jnp.arange(e_loc + 1, dtype=jnp.int32), side="left"
        )
        C_loc = min(R, _local_capacity(R, 1, e_loc, cfg.moe_capacity_factor))
        idx2 = offs[:-1, None] + jnp.arange(C_loc, dtype=jnp.int32)[None]
        valid2 = idx2 < offs[1:, None]                           # [e_loc,C_loc]
        idx2_c = jnp.minimum(idx2, R - 1)
        xe = jnp.where(valid2[..., None], rx_s[idx2_c], 0)       # [e_loc,C_loc,D]

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", xe, w_up
        )
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)               # [e_loc,C_loc,D]

        # scatter back to received-slot order (each row owned by one expert)
        dest = jnp.where(valid2, order2[idx2_c], R).reshape(-1)
        y = (
            jnp.zeros((R + 1, D), ye.dtype)
            .at[dest]
            .add(ye.reshape(e_loc * C_loc, D))[:R]
        )

        # --- return a2a + weighted combine ---------------------------------
        back = jax.lax.all_to_all(
            y.reshape(n_ep, C_pair, D), ep_axis, 0, 0, tiled=False
        )                                                         # [n_ep,C,D]
        contrib = back.reshape(n_ep * C_pair, D) * jnp.where(
            valid, w_sorted[idx_c], 0.0
        ).reshape(-1, 1).astype(back.dtype)
        tok = jnp.where(valid, tok_sorted[idx_c], tl).reshape(-1)
        out = jnp.zeros((tl + 1, D), contrib.dtype).at[tok].add(contrib)[:tl]
        return out.astype(x_loc.dtype)

    y = _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(token_axes, None),
            P(),                           # router replicated
            P(ep_axis, None, None),        # EP expert weights
            P(ep_axis, None, None),
            P(ep_axis, None, None),
        ),
        out_specs=P(token_axes, None),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.num_shared_experts:  # dense, position-wise: no routing needed
        hs = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
        y = y + hs @ p["shared_down"]
    return y
