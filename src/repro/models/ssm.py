"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Training uses the chunked SSD algorithm: quadratic attention-like compute
inside length-``Q`` chunks, linear recurrent state passing between chunks
(associative scan).  Decode is the O(1) recurrent update.  Single B/C group
(n_groups=1), per-head scalar decay A — the published mamba2-1.3b layout.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


def causal_conv1d(u: jax.Array, w: jax.Array, bias: jax.Array | None = None):
    """Depthwise causal conv: u [B, S, C], w [K, C] → [B, S, C]."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    S = u.shape[1]
    y = jnp.zeros_like(u, dtype=jnp.float32)
    for k in range(K):  # K is 4: unrolled shifts beat a conv op here
        y = y + pad[:, k : k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    if bias is not None:
        y = y + bias
    return y.astype(u.dtype)


def ssd_chunked(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H]  (post-softplus, > 0)
    A: jax.Array,    # [H]        (negative)
    Bm: jax.Array,   # [B, S, N]
    Cm: jax.Array,   # [B, S, N]
    *,
    chunk: int,
    init_state: jax.Array | None = None,  # [B, H, P, N]
):
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    assert nc * Q == S, (S, Q)

    f32 = jnp.float32
    xc = x.reshape(B_, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(B_, nc, Q, H).astype(f32)
    Bc = Bm.reshape(B_, nc, Q, N).astype(f32)
    Cc = Cm.reshape(B_, nc, Q, N).astype(f32)

    a = dtc * A.astype(f32)                     # [B, nc, Q, H] log-decay
    cum = jnp.cumsum(a, axis=2)

    # intra-chunk (the "attention-like" quadratic term)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [B,nc,t,s,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    dec = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)
    scores = cb[..., None] * dec * dtc[:, :, None, :, :]
    y = jnp.einsum("bctsh,bcshp->bcthp", scores, xc)

    # chunk-final states
    last = cum[:, :, -1:, :]                              # [B,nc,1,H]
    sdec = jnp.exp(last - cum) * dtc                      # [B,nc,Q,H]
    S_c = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, sdec, xc)

    # inter-chunk recurrence: associative scan over chunks
    chunk_decay = jnp.exp(last[:, :, 0, :])               # [B,nc,H]

    def comb(c1, c2):
        d1, s1 = c1
        d2, s2 = c2
        return d1 * d2, s1 * d2[..., None, None] + s2

    d_in, s_in = jax.lax.associative_scan(comb, (chunk_decay, S_c), axis=1)
    # state entering chunk c = seed·Π(decays of chunks < c) + s_in[c-1]
    seed = (
        jnp.zeros((B_, H, P, N), f32)
        if init_state is None
        else init_state.astype(f32)
    )
    s_prev = jnp.concatenate([jnp.zeros_like(s_in[:, :1]), s_in[:, :-1]], axis=1)
    d_prev = jnp.concatenate([jnp.ones((B_, 1, H), f32), d_in[:, :-1]], axis=1)
    s_enter = seed[:, None] * d_prev[..., None, None] + s_prev

    y_inter = jnp.einsum("bctn,bchpn->bcthp", Cc, s_enter) * jnp.exp(cum)[..., None]
    out = (y + y_inter).reshape(B_, S, H, P)
    final_state = seed * d_in[:, -1][..., None, None] + s_in[:, -1]
    return out.astype(x.dtype), final_state


def ssd_decode_step(
    state: jax.Array,  # [B, H, P, N]
    x: jax.Array,      # [B, H, P]
    dt: jax.Array,     # [B, H]
    A: jax.Array,      # [H]
    Bm: jax.Array,     # [B, N]
    Cm: jax.Array,     # [B, N]
):
    f32 = jnp.float32
    decay = jnp.exp(dt.astype(f32) * A.astype(f32))       # [B, H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(f32), x.astype(f32), Bm.astype(f32))
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state


def mamba2_forward_split(x: jax.Array, p: dict, cfg, init=None):
    """Mamba-2 block with *separated* projections (TP-shardable layout).

    Params: in_z/in_x [D, d_inner], in_B/in_C [D, N], in_dt [D, H],
    conv_x [K, d_inner], conv_B/conv_C [K, N], dt_bias/A_log/D_skip [H],
    norm_w [d_inner], out_proj [d_inner, D].
    x: [B, S, D] → ([B, S, D], final_state [B, H, P, N]).
    """
    B_, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = x @ p["in_z"]
    xs = causal_conv1d(jax.nn.silu(x @ p["in_x"]), p["conv_x"])
    Bm = causal_conv1d(jax.nn.silu(x @ p["in_B"]), p["conv_B"])
    Cm = causal_conv1d(jax.nn.silu(x @ p["in_C"]), p["conv_C"])
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, final_state = ssd_chunked(
        xs.reshape(B_, S, H, P), dt, A, Bm, Cm, chunk=cfg.ssm_chunk, init_state=init
    )
    y = y + xs.reshape(B_, S, H, P) * p["D_skip"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B_, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], final_state


def mamba2_decode_split(x: jax.Array, p: dict, cfg, conv_state, ssm_state):
    """One-token decode for the split layout. x: [B, D].

    conv_state: [B, K-1, d_inner + 2N] (x ++ B ++ C channels).
    Returns (y [B, D], new_conv_state, new_ssm_state).
    """
    B_, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner
    K = cfg.conv_kernel

    z = x @ p["in_z"]
    u = jnp.concatenate(
        [
            jax.nn.silu(x @ p["in_x"]),
            jax.nn.silu(x @ p["in_B"]),
            jax.nn.silu(x @ p["in_C"]),
        ],
        axis=-1,
    )
    window = jnp.concatenate([conv_state, u[:, None]], axis=1)  # [B, K, C]
    w_full = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), w_full.astype(jnp.float32)
    ).astype(x.dtype)
    new_conv_state = window[:, 1:]

    xs, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm_state = ssd_decode_step(ssm_state, xs.reshape(B_, H, P), dt, A, Bm, Cm)
    y = y + xs.reshape(B_, H, P) * p["D_skip"].astype(xs.dtype)[None, :, None]
    y = y.reshape(B_, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv_state, new_ssm_state


def mamba2_forward(x: jax.Array, p: dict, cfg, init=None):
    """Full-sequence Mamba-2 block. x: [B, S, D] → ([B, S, D], final_state)."""
    B_, S, D = x.shape
    d_inner = cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + d_inner + 2 * N], axis=-1)
    xbc = causal_conv1d(jax.nn.silu(xbc), p["conv_w"], p.get("conv_b"))
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, final_state = ssd_chunked(
        xs.reshape(B_, S, H, P), dt, A, Bm, Cm, chunk=cfg.ssm_chunk, init_state=init
    )
    y = y + xs.reshape(B_, S, H, P) * p["D_skip"][None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], final_state


def mamba2_decode(x: jax.Array, p: dict, cfg, conv_state, ssm_state):
    """One-token decode. x: [B, D]; conv_state: [B, K-1, conv_dim]."""
    B_, D = x.shape
    d_inner = cfg.d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.conv_kernel

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + d_inner + 2 * N], axis=-1)
    xbc = jax.nn.silu(xbc)
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # [B, K, C]
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    )
    if p.get("conv_b") is not None:
        conv_out = conv_out + p["conv_b"]
    conv_out = conv_out.astype(x.dtype)
    new_conv_state = window[:, 1:]

    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_ssm_state = ssd_decode_step(ssm_state, xs.reshape(B_, H, P), dt, A, Bm, Cm)
    y = y + xs.reshape(B_, H, P) * p["D_skip"][None, :, None]
    y = y.reshape(B_, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv_state, new_ssm_state
