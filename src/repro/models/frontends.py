"""Modality frontend STUBS (per the assignment: ``[vlm]``/``[audio]`` cells
specify the transformer backbone only; ``input_specs()`` provides
precomputed patch/frame embeddings).

The stubs define the *shapes* the real frontends (SigLIP for paligemma-3b,
EnCodec for musicgen-medium) would emit, and a deterministic synthetic
generator for smoke tests/examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def prefix_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct | None:
    """ShapeDtypeStruct of the stub prefix embeddings (dry-run input)."""
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)


def synthetic_prefix(rng, cfg: ModelConfig, batch: int) -> jax.Array | None:
    """Deterministic fake patch/frame embeddings for CPU smoke tests."""
    if not cfg.frontend:
        return None
    return (
        jax.random.normal(rng, (batch, cfg.frontend_len, cfg.d_model)) * 0.02
    ).astype(jnp.bfloat16)
