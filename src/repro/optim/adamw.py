"""AdamW with parameter-sharded states (states inherit the param specs)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(
        step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros)
    )


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
