"""Sharded optimizer stack: AdamW, global-norm clipping, LR schedules, and
int8 gradient compression with error feedback (for the microbatch
accumulation path — halves the bytes the DP all-reduce moves)."""

from repro.optim.adamw import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.optim.compress import (
    CompressState,
    compress_init,
    decompress_add,
    quantize_grads,
)
