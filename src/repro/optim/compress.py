"""Int8 gradient compression with error feedback.

Used on the microbatch-accumulation path: each microbatch's gradient is
quantized to int8 (per-tensor absmax scale) before being added to the
accumulator, and the quantization error is carried into the next microbatch
(error feedback keeps the scheme unbiased over steps).  At cluster scale the
same quantizer halves/quarters DP all-reduce bytes; in pure-pjit mode the
reduce itself is XLA-inserted, so the quantizer wraps accumulation — the
collective-bytes saving is realized when the accumulator (not raw grads) is
what crosses the wire, which is how the train driver stages it.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressState:
    error: Any  # per-tensor error feedback buffers (f32)


def compress_init(params) -> CompressState:
    return CompressState(
        error=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


def quantize_grads(grads, state: CompressState):
    """→ (int8 tensors, scales, new_state). g_q = round((g+err)/s)."""

    def q(g, err):
        g = g.astype(jnp.float32) + err
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q8 = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_err = g - q8.astype(jnp.float32) * scale
        return q8, scale, new_err

    out = jax.tree.map(q, grads, state.error)
    def tup(i):
        return jax.tree.map(
            lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple)
        )
    return tup(0), tup(1), CompressState(error=tup(2))


def decompress_add(acc, q8, scales):
    return jax.tree.map(
        lambda a, q, s: a + q.astype(jnp.float32) * s, acc, q8, scales
    )
