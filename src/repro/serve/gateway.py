"""Multi-tenant batching gateway over ``KVPageIndex`` (DESIGN.md §13).

The engine consumes one perfectly-formed mixed ``OpBatch`` per step; real
traffic is thousands of small, bursty, retried, duplicated client
requests.  The gateway is the layer between the two, and its headline
contract is robustness, not throughput:

* **exactly-once** — every request carries an idempotency key; a bounded
  dedup window (in-flight tickets + recently-committed keys) makes
  retried or duplicated submissions apply once, including across a
  ``DurableFliX`` crash/recovery boundary (the batch's keys are logged in
  its WAL record and reseeded from :meth:`KVPageIndex.dedup_seed`);
* **admission control** — per-tenant token buckets (rate/burst) and a
  bounded queue depth; whatever cannot be admitted is rejected with a
  TYPED reason and a ``retry_after`` hint instead of queueing unboundedly;
* **deadlines** — a request whose deadline has passed is rejected at
  admission or expired at batch formation, never executed late;
* **weighted fairness** — batch slots are granted by stride scheduling
  over tenant weights, so one hot tenant cannot starve the others;
* **graceful degradation** — when the update path is untrustworthy
  (poisoned durable layer: ``index.healthy`` is False), updates are
  rejected UNAVAILABLE while reads keep flowing (pure-read steps never
  touch the WAL);
* **typed failure mapping** — an engine exception resolves every ticket
  in the batch with ``ENGINE_FAILURE`` (the durable layer rolled the WAL
  back: not applied) or ``UNKNOWN_COMMIT`` (rollback failed: the batch
  may be durable; a retry after reopening resolves via the persisted
  dedup window) — never a lost or double-applied batch.

Everything is driven by an EXPLICIT virtual clock (``now`` arguments):
no threads, no sleeps, deterministic under replay — which is how
``tests/traffic_replay.py`` differential-checks it against a
single-client oracle and how the CI soak stays fast and exact.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

PAGE_BITS = 12  # keep in sync with kv_index.PAGE_BITS

# ---------------------------------------------------------------------------
# rejection taxonomy (typed, stable strings — they cross process boundaries
# in the traffic-replay harness)
# ---------------------------------------------------------------------------

RATE_LIMITED = "RATE_LIMITED"  # tenant token bucket empty; retry_after set
QUEUE_FULL = "QUEUE_FULL"  # admission shed at bounded depth; retry_after set
DEADLINE_EXCEEDED = "DEADLINE_EXCEEDED"  # expired at admission or formation
UNAVAILABLE = "UNAVAILABLE"  # update path degraded / gateway closed
ENGINE_FAILURE = "ENGINE_FAILURE"  # engine raised; WAL rolled back: NOT applied
UNKNOWN_COMMIT = "UNKNOWN_COMMIT"  # rollback failed: MAY be durable; retry
INVALID = "INVALID"  # malformed request (e.g. larger than any batch)
SNAPSHOT_GONE = "SNAPSHOT_GONE"  # pinned version reclaimed; NOT retryable —
# the same as_of can never succeed again; re-issue against a live version

UPDATE_KINDS = ("alloc", "free")
READ_KINDS = ("lookup", "pages")


@dataclass(frozen=True)
class GatewayError:
    code: str
    retry_after: float | None = None
    detail: str = ""

    @property
    def retryable(self) -> bool:
        return self.code in (RATE_LIMITED, QUEUE_FULL, UNKNOWN_COMMIT, UNAVAILABLE)


@dataclass(frozen=True)
class Request:
    """One client micro-request.

    ``kind`` ∈ ``alloc | lookup | free | pages``; the aligned tuples carry
    its payload (``alloc``: seqs/pages/slots, ``lookup``: seqs/pages,
    ``free``/``pages``: seqs).  ``key`` is the idempotency key — client
    retries MUST reuse it; distinct requests MUST NOT share it.

    ``as_of`` pins a READ request to a committed index version
    (``KVPageIndex`` snapshot reads): the result is a consistent cut of
    that version no matter how many batches commit between submit and
    pump.  Updates with ``as_of`` are rejected INVALID, and a pinned
    version that left the retention window rejects SNAPSHOT_GONE
    (non-retryable — re-issue unpinned or against a newer version).
    """

    tenant: str
    key: str
    kind: str
    seqs: tuple
    pages: tuple = ()
    slots: tuple = ()
    deadline: float | None = None
    as_of: int | None = None

    def __post_init__(self):
        if self.kind not in UPDATE_KINDS + READ_KINDS:
            raise ValueError(f"unknown request kind {self.kind!r}")
        if self.kind == "alloc" and not (
            len(self.seqs) == len(self.pages) == len(self.slots)
        ):
            raise ValueError("alloc requires aligned seqs/pages/slots")
        if self.kind == "lookup" and len(self.seqs) != len(self.pages):
            raise ValueError("lookup requires aligned seqs/pages")

    @property
    def is_update(self) -> bool:
        return self.kind in UPDATE_KINDS


class Ticket:
    """Per-request future, resolved by ``pump`` (or synchronously at
    submit for rejections and duplicates).  Single-threaded: ``done``
    flips inside the same virtual-clock turn that resolves it."""

    __slots__ = (
        "request",
        "status",
        "value",
        "error",
        "duplicate",
        "submitted_at",
        "finished_at",
        "commit_seq",
    )

    def __init__(self, request: Request, now: float):
        self.request = request
        self.status = "pending"  # pending | ok | rejected | failed
        self.value = None
        self.error: GatewayError | None = None
        self.duplicate = False
        self.submitted_at = now
        self.finished_at: float | None = None
        self.commit_seq: int | None = None

    @property
    def done(self) -> bool:
        return self.status != "pending"

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def result(self):
        if self.status == "pending":
            raise RuntimeError("ticket not resolved yet — pump the gateway")
        if self.status != "ok":
            raise RuntimeError(f"request failed: {self.error}")
        return self.value

    def _resolve(self, value, *, now: float, seq=None, duplicate=False):
        self.status = "ok"
        self.value = value
        self.commit_seq = seq
        self.duplicate = duplicate
        self.finished_at = now

    def _reject(self, code: str, *, now: float, retry_after=None, detail=""):
        self.status = "rejected"
        self.error = GatewayError(code, retry_after, detail)
        self.finished_at = now

    def _fail(self, code: str, *, now: float, detail=""):
        self.status = "failed"
        self.error = GatewayError(code, detail=detail)
        self.finished_at = now


class _Bucket:
    """Token bucket: ``rate`` tokens/virtual-second, ``burst`` cap."""

    __slots__ = ("rate", "burst", "tokens", "t")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t = now

    def try_take(self, n: float, now: float) -> float | None:
        """Take ``n`` tokens; None on success, else seconds until enough
        tokens accrue (the ``retry_after`` hint)."""
        if now > self.t:
            self.tokens = min(self.burst, self.tokens + (now - self.t) * self.rate)
            self.t = now
        if self.tokens >= n:
            self.tokens -= n
            return None
        if self.rate <= 0:
            return float("inf")
        return (n - self.tokens) / self.rate


@dataclass
class _Tenant:
    name: str
    weight: float
    bucket: _Bucket
    queue: deque = field(default_factory=deque)
    # stride scheduling state: the tenant with the smallest pass goes
    # first; serving `cost` ops advances it by cost/weight
    pass_value: float = 0.0


@dataclass
class PumpReport:
    """What one ``pump`` did — the harness's commit-log record."""

    committed_keys: list
    n_ops: int
    expired: int
    failed_code: str | None
    stats: dict
    commit_seq: int | None


class Gateway:
    """Exactly-once batching frontend over one :class:`KVPageIndex`.

    ``submit`` admits (or rejects) micro-requests; ``pump`` forms ONE
    mixed engine batch under weighted fairness and commits it.  Both take
    the virtual ``now``; nothing in here reads a wall clock.

    ``max_batch_ops`` bounds one engine batch (frees cost ``max_pages``
    ops each — they expand to per-page deletes); ``max_queue_ops`` bounds
    total queued work, the admission-control shed point; ``dedup_window``
    bounds the committed-key memory (a retry older than the window may
    re-apply — clients must not retry past it, and the window is sized
    orders of magnitude above any sane retry horizon).
    """

    def __init__(
        self,
        index,
        *,
        max_batch_ops: int = 256,
        max_queue_ops: int = 2048,
        dedup_window: int = 4096,
        max_pages: int = 64,
        range_budget: int = 256,
        default_rate: float = 64.0,
        default_burst: float = 128.0,
        crash_hook=None,
    ):
        self.index = index
        self.max_batch_ops = int(max_batch_ops)
        self.max_queue_ops = int(max_queue_ops)
        self.dedup_window = int(dedup_window)
        self.max_pages = int(max_pages)
        self.range_budget = int(range_budget)
        self.default_rate = float(default_rate)
        self.default_burst = float(default_burst)
        self._hook = crash_hook or (lambda event: None)
        self._tenants: dict[str, _Tenant] = {}
        self._pending: dict[str, Ticket] = {}  # queued or mid-commit
        self._committed: dict[str, int] = {}  # key -> commit seq (bounded FIFO)
        self._committed_order: deque[str] = deque()
        self._queued_ops = 0
        self._commits = 0
        self._closed = False
        self.metrics = {
            "submitted": 0,
            "admitted": 0,
            "duplicates": 0,
            "committed_ops": 0,
            "committed_requests": 0,
            "batches": 0,
            "expired": 0,
            "engine_failures": 0,
            "restructure_retries": 0,
            "a2a_retries": 0,
            # tiered residency (DESIGN.md §15): page-in/page-out totals and
            # reclaimed bytes accumulate; resident_bytes is a gauge (the
            # latest committed batch's device-tier footprint, 0 single-tier)
            "promoted": 0,
            "demoted": 0,
            "reclaimed_bytes": 0,
            "resident_bytes": 0,
            "rejected": {},
        }
        # recovery: reseed the dedup window from the durable meta trail so
        # a retry of a batch that committed right before the crash (acked
        # or not) resolves as a duplicate instead of re-applying
        for seq, meta in index.dedup_seed():
            for key in (meta or {}).get("keys", ()):
                self._remember(key, int(seq))
        if self._committed_order:
            self._commits = max(self._committed.values())

    # -- tenants ----------------------------------------------------------
    def register_tenant(
        self,
        name: str,
        *,
        rate: float | None = None,
        burst: float | None = None,
        weight: float = 1.0,
        now: float = 0.0,
    ) -> None:
        """Declare a tenant's rate limit and fairness weight.  Unknown
        tenants are auto-registered at defaults on first submit."""
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        bucket = _Bucket(
            self.default_rate if rate is None else rate,
            self.default_burst if burst is None else burst,
            now,
        )
        # a new tenant starts at the max live pass value, not 0 — joining
        # late must not grant a catch-up burst over everyone else
        floor = max((t.pass_value for t in self._tenants.values()), default=0.0)
        self._tenants[name] = _Tenant(name, float(weight), bucket, pass_value=floor)

    def _tenant(self, name: str, now: float) -> _Tenant:
        if name not in self._tenants:
            self.register_tenant(name, now=now)
        return self._tenants[name]

    # -- admission --------------------------------------------------------
    def _cost(self, req: Request) -> int:
        if req.kind == "free":
            return len(req.seqs) * self.max_pages  # expands to per-page deletes
        return max(1, len(req.seqs))

    def _remember(self, key: str, seq: int) -> None:
        if key not in self._committed:
            self._committed_order.append(key)
        self._committed[key] = seq
        while len(self._committed_order) > self.dedup_window:
            self._committed.pop(self._committed_order.popleft(), None)

    @property
    def queue_depth(self) -> int:
        """Currently queued work in engine ops — bounded by
        ``max_queue_ops`` (the admission-control invariant)."""
        return self._queued_ops

    def submit(self, req: Request, *, now: float) -> Ticket:
        """Admit one request.  Always returns a ticket; rejections and
        duplicate-of-committed resolve synchronously, a duplicate of an
        in-flight key returns THE SAME ticket (one commit, many holders).
        """
        self.metrics["submitted"] += 1
        if req.key in self._pending:
            self.metrics["duplicates"] += 1
            return self._pending[req.key]
        tk = Ticket(req, now)
        if req.key in self._committed:
            self.metrics["duplicates"] += 1
            tk._resolve(
                {"applied": True},
                now=now,
                seq=self._committed[req.key],
                duplicate=True,
            )
            return tk
        if self._closed:
            return self._rejected(tk, UNAVAILABLE, now, detail="gateway closed")
        if req.deadline is not None and req.deadline <= now:
            return self._rejected(tk, DEADLINE_EXCEEDED, now)
        if req.is_update and not self.index.healthy:
            return self._rejected(
                tk,
                UNAVAILABLE,
                now,
                retry_after=None,
                detail="update path degraded (read-only mode)",
            )
        cost = self._cost(req)
        if cost > self.max_batch_ops:
            return self._rejected(
                tk, INVALID, now, detail=f"request cost {cost} > max_batch_ops"
            )
        if req.as_of is not None and req.is_update:
            return self._rejected(
                tk, INVALID, now, detail="as_of pins reads; updates cannot use it"
            )
        if self._queued_ops + cost > self.max_queue_ops:
            # shed BEFORE the bucket so the rejected request's tokens are
            # not burned; retry_after ≈ pumps needed to drain the backlog
            drain = self._queued_ops / max(1, self.max_batch_ops)
            return self._rejected(tk, QUEUE_FULL, now, retry_after=max(1.0, drain))
        tenant = self._tenant(req.tenant, now)
        wait = tenant.bucket.try_take(cost, now)
        if wait is not None:
            return self._rejected(tk, RATE_LIMITED, now, retry_after=wait)
        tenant.queue.append(tk)
        self._pending[req.key] = tk
        self._queued_ops += cost
        self.metrics["admitted"] += 1
        return tk

    def _rejected(self, tk: Ticket, code: str, now, *, retry_after=None, detail=""):
        tk._reject(code, now=now, retry_after=retry_after, detail=detail)
        self.metrics["rejected"][code] = self.metrics["rejected"].get(code, 0) + 1
        return tk

    # -- batch formation + commit ----------------------------------------
    def pump(self, *, now: float) -> PumpReport:
        """Form one mixed batch under weighted fairness and commit it.

        Coalescing rules (the ``apply_ops`` one-update-op-per-key
        precondition, DESIGN.md §8): within a batch an alloc key appears
        at most once, a freed sequence excludes allocs of that sequence
        (and repeat frees of it), in either order.  A conflicting request
        blocks its tenant's queue for THIS pump only (per-tenant FIFO is
        what makes retried updates of one key ordered).
        """
        batch: list[Ticket] = []
        expired = 0
        budget = self.max_batch_ops
        blocked: set[str] = set()
        update_keys: set[int] = set()
        alloc_seqs: set[int] = set()
        free_seqs: set[int] = set()
        while budget > 0:
            live = [
                t
                for t in self._tenants.values()
                if t.queue and t.name not in blocked
            ]
            if not live:
                break
            tn = min(live, key=lambda t: (t.pass_value, t.name))
            tk = tn.queue[0]
            req = tk.request
            cost = self._cost(req)
            if req.deadline is not None and req.deadline <= now:
                tn.queue.popleft()
                self._queued_ops -= cost
                del self._pending[req.key]
                self._rejected(tk, DEADLINE_EXCEEDED, now)
                expired += 1
                self.metrics["expired"] += 1
                continue
            if cost > budget or self._conflicts(
                req, update_keys, alloc_seqs, free_seqs
            ):
                blocked.add(tn.name)  # head-of-line: keep tenant FIFO exact
                continue
            tn.queue.popleft()
            self._queued_ops -= cost
            batch.append(tk)
            budget -= cost
            tn.pass_value += cost / tn.weight
            if req.kind == "alloc":
                alloc_seqs.update(req.seqs)
                update_keys.update(
                    (int(s) << PAGE_BITS) | int(p)
                    for s, p in zip(req.seqs, req.pages)
                )
            elif req.kind == "free":
                free_seqs.update(req.seqs)
        if not batch:
            return PumpReport([], 0, expired, None, {}, None)
        return self._commit(batch, expired, now)

    @staticmethod
    def _conflicts(req, update_keys, alloc_seqs, free_seqs) -> bool:
        if req.kind == "alloc":
            if any(int(s) in free_seqs for s in req.seqs):
                return True
            return any(
                ((int(s) << PAGE_BITS) | int(p)) in update_keys
                for s, p in zip(req.seqs, req.pages)
            )
        if req.kind == "free":
            return any(
                int(s) in alloc_seqs or int(s) in free_seqs for s in req.seqs
            )
        return False

    def _commit(self, batch: list[Ticket], expired: int, now: float) -> PumpReport:
        # pinned reads run as separate read-only steps against their pinned
        # version — they cannot share the main step, which serves the LIVE
        # post-update state (update-then-read); grouping by as_of keeps one
        # engine step per distinct pinned version
        pinned: dict[int, list[Ticket]] = {}
        main: list[Ticket] = []
        for tk in batch:
            if tk.request.as_of is not None:
                pinned.setdefault(int(tk.request.as_of), []).append(tk)
            else:
                main.append(tk)

        al_seq, al_page, al_slot = [], [], []
        lu_seq, lu_page = [], []
        fr_seq = []
        rg_lo, rg_hi = [], []
        slices: list[tuple] = []  # per ticket: (kind, start, length)
        for tk in main:
            req = tk.request
            if req.kind == "alloc":
                slices.append(("alloc", 0, 0))
                al_seq += list(req.seqs)
                al_page += list(req.pages)
                al_slot += list(req.slots)
            elif req.kind == "lookup":
                slices.append(("lookup", len(lu_seq), len(req.seqs)))
                lu_seq += list(req.seqs)
                lu_page += list(req.pages)
            elif req.kind == "free":
                slices.append(("free", 0, 0))
                fr_seq += list(req.seqs)
            else:  # pages
                slices.append(("pages", len(rg_lo), len(req.seqs)))
                for s in req.seqs:
                    rg_lo.append(int(s) << PAGE_BITS)
                    rg_hi.append((int(s) + 1) << PAGE_BITS)
        is_update = bool(al_seq or fr_seq)
        n_ops = len(al_seq) + len(lu_seq) + len(fr_seq) + len(rg_lo)
        meta = {"keys": [tk.request.key for tk in main]} if is_update else None
        self._hook("gateway.batch.formed")

        # pinned groups first — each is its own read-only engine step, so a
        # reclaimed version rejects ONLY its own tickets (SNAPSHOT_GONE)
        pinned_keys: list = []
        n_pinned = 0
        for as_of in sorted(pinned):
            n_pinned += self._pinned_step(pinned[as_of], as_of, now, pinned_keys)

        if not main:
            if pinned_keys:
                self._commits += 1
                self.metrics["batches"] += 1
                self.metrics["committed_ops"] += n_pinned
                self.metrics["committed_requests"] += len(pinned_keys)
            return PumpReport(pinned_keys, n_pinned, expired, None, {}, None)
        try:
            step_res = self.index.step(
                allocs=(al_seq, al_page, al_slot) if al_seq else None,
                lookups=(lu_seq, lu_page) if lu_seq else None,
                free_seqs=fr_seq or None,
                ranges=(rg_lo, rg_hi) if rg_lo else None,
                max_pages=self.max_pages,
                range_budget=self.range_budget,
                meta=meta,
            )
            slots, range_out, stats = step_res.slots, step_res.range_out, step_res.stats
        except Exception as e:  # noqa: BLE001 — mapped to typed errors
            # CrashError/KeyboardInterrupt are BaseException: they pass
            # through like the process death they simulate
            unknown = is_update and not self.index.healthy
            code = UNKNOWN_COMMIT if unknown else ENGINE_FAILURE
            for tk in main:
                self._pending.pop(tk.request.key, None)
                tk._fail(code, now=now, detail=str(e))
            self.metrics["engine_failures"] += 1
            self.metrics["rejected"][code] = (
                self.metrics["rejected"].get(code, 0) + len(main)
            )
            return PumpReport(pinned_keys, n_ops + n_pinned, expired, code, {}, None)
        self._hook("gateway.step.done")  # commit is durable; acks not yet out
        self._commits += 1
        seq = self.index.durable_seq if is_update else None
        if seq is None:
            seq = self._commits
        slots_np = np.asarray(slots) if len(lu_seq) else None
        for tk, (kind, start, length) in zip(main, slices):
            if kind == "lookup":
                value = slots_np[start : start + length]
            elif kind == "pages":
                value = self._range_slices(range_out, start, length)
            else:
                value = {"applied": True}
            self._pending.pop(tk.request.key, None)
            self._remember(tk.request.key, seq)
            tk._resolve(value, now=now, seq=seq)
        self._hook("gateway.acked")
        self.metrics["batches"] += 1
        self.metrics["committed_ops"] += n_ops + n_pinned
        self.metrics["committed_requests"] += len(main) + len(pinned_keys)
        self.metrics["restructure_retries"] += int(
            stats.get("restructure_retries", 0)
        )
        self.metrics["a2a_retries"] += int(stats.get("a2a_retries", 0))
        self.metrics["promoted"] += int(stats.get("promoted", 0))
        self.metrics["demoted"] += int(stats.get("demoted", 0))
        self.metrics["reclaimed_bytes"] += int(stats.get("reclaimed_bytes", 0))
        if "resident_bytes" in stats:
            self.metrics["resident_bytes"] = int(stats["resident_bytes"])
        return PumpReport(
            [tk.request.key for tk in main] + pinned_keys,
            n_ops + n_pinned,
            expired,
            None,
            stats,
            seq,
        )

    def _pinned_step(
        self, tks: list[Ticket], as_of: int, now: float, out_keys: list
    ) -> int:
        """Serve one pinned-version group as a read-only ``as_of`` engine
        step; returns the ops served (0 when the whole group rejects)."""
        from repro.serve.kv_index import SnapshotGone

        lu_seq, lu_page = [], []
        rg_lo, rg_hi = [], []
        slices: list[tuple] = []
        for tk in tks:
            req = tk.request
            if req.kind == "lookup":
                slices.append(("lookup", len(lu_seq), len(req.seqs)))
                lu_seq += list(req.seqs)
                lu_page += list(req.pages)
            else:  # pages
                slices.append(("pages", len(rg_lo), len(req.seqs)))
                for s in req.seqs:
                    rg_lo.append(int(s) << PAGE_BITS)
                    rg_hi.append((int(s) + 1) << PAGE_BITS)
        try:
            step_res = self.index.step(
                lookups=(lu_seq, lu_page) if lu_seq else None,
                ranges=(rg_lo, rg_hi) if rg_lo else None,
                max_pages=self.max_pages,
                range_budget=self.range_budget,
                as_of=as_of,
            )
            slots, range_out = step_res.slots, step_res.range_out
        except SnapshotGone as e:
            for tk in tks:
                self._pending.pop(tk.request.key, None)
                self._rejected(tk, SNAPSHOT_GONE, now, detail=str(e))
            return 0
        except ValueError as e:
            # never-committed version / window off: a caller error, typed
            # INVALID so it is visibly non-retryable
            for tk in tks:
                self._pending.pop(tk.request.key, None)
                self._rejected(tk, INVALID, now, detail=str(e))
            return 0
        seq = self._commits + 1
        slots_np = np.asarray(slots) if lu_seq else None
        for tk, (kind, start, length) in zip(tks, slices):
            if kind == "lookup":
                value = slots_np[start : start + length]
            else:
                value = self._range_slices(range_out, start, length)
            self._pending.pop(tk.request.key, None)
            self._remember(tk.request.key, seq)
            tk._resolve(value, now=now, seq=seq)
            out_keys.append(tk.request.key)
        return len(lu_seq) + len(rg_lo)

    @staticmethod
    def _range_slices(range_out, start: int, length: int):
        out = []
        for i in range(start, start + length):
            s = int(np.asarray(range_out["start"][i]))
            c = int(np.asarray(range_out["count"][i]))
            keys = np.asarray(range_out["keys"][s : s + c])
            out.append(
                {
                    "pages": keys & ((1 << PAGE_BITS) - 1),
                    "slots": np.asarray(range_out["vals"][s : s + c]),
                    "count": c,
                }
            )
        return out

    # -- teardown ---------------------------------------------------------
    def drain(self, *, now: float, max_pumps: int = 1_000) -> int:
        """Pump until every queued request resolves (bounded); returns the
        number of pumps.  Deterministic — used by tests and shutdown."""
        pumps = 0
        while self._queued_ops > 0 and pumps < max_pumps:
            report = self.pump(now=now)
            pumps += 1
            if report.n_ops == 0 and report.expired == 0:
                break  # only blocked/conflicting work left and it cannot fit
        return pumps

    def close(self, *, now: float = 0.0) -> None:
        """Reject everything still queued (UNAVAILABLE, retryable after a
        reopen) and close the index.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        for tn in self._tenants.values():
            while tn.queue:
                tk = tn.queue.popleft()
                self._pending.pop(tk.request.key, None)
                self._rejected(tk, UNAVAILABLE, now, detail="gateway closed")
        self._queued_ops = 0
        self.index.close()
