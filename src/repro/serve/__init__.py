"""Serving layer: decode loop + FliX-backed KV request index + the
multi-tenant exactly-once batching gateway (DESIGN.md §13)."""

from repro.serve.gateway import (
    DEADLINE_EXCEEDED,
    ENGINE_FAILURE,
    INVALID,
    QUEUE_FULL,
    RATE_LIMITED,
    SNAPSHOT_GONE,
    UNAVAILABLE,
    UNKNOWN_COMMIT,
    Gateway,
    GatewayError,
    PumpReport,
    Request,
    Ticket,
)
from repro.serve.kv_index import KVPageIndex, SnapshotGone
