"""Serving layer: decode loop + FliX-backed KV request index."""

from repro.serve.kv_index import KVPageIndex
