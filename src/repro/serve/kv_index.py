"""FliX-backed KV page index — the paper's CDS inside an LLM serving plane.

The serving control plane must map (sequence_id, page_no) → cache slot for
batched requests, under continuous allocation (prefill) and freeing
(sequence completion) — exactly the dynamic ordered-map workload FliX is
built for.  Keys are ``seq_id << PAGE_BITS | page_no``, so one successor /
range query enumerates a sequence's pages *in order* (hash tables can't),
and batched frees are physical deletions with immediate slot reclamation —
no tombstone accumulation across the serving day (the paper's §6.5 LSMu
collapse is precisely the failure mode this avoids).

All operations are batched per engine step, matching the paper's batch
execution model: one sorted batch of (allocate | lookup | free) per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    EMPTY,
    NOT_FOUND,
    build,
    delete,
    insert_safe,
    point_query,
    range_query,
    sort_batch,
)

PAGE_BITS = 12  # up to 4096 pages (≈ pages × page_size tokens) per sequence


def _key(seq_ids, page_nos):
    return (seq_ids.astype(jnp.int32) << PAGE_BITS) | page_nos.astype(jnp.int32)


class KVPageIndex:
    """Host-driven wrapper around a FliXState (functional underneath)."""

    def __init__(self, *, node_size: int = 16, nodes_per_bucket: int = 8):
        # seed with one sentinel key (outside the (seq,page) space) so the
        # structure is never empty
        from repro.core import MAX_VALID

        self.state = build(
            jnp.array([MAX_VALID], jnp.int32),
            jnp.array([0], jnp.int32),
            node_size=node_size,
            nodes_per_bucket=nodes_per_bucket,
        )

    def allocate(self, seq_ids, page_nos, slots):
        """Batch-register pages → slots (an engine allocation step)."""
        keys = _key(jnp.asarray(seq_ids), jnp.asarray(page_nos))
        sk, sv = sort_batch(keys, jnp.asarray(slots, jnp.int32))
        self.state, stats = insert_safe(self.state, sk, sv)
        return stats

    def lookup(self, seq_ids, page_nos):
        """Batch lookup → cache slots (NOT_FOUND = -1 for unmapped pages)."""
        keys = _key(jnp.asarray(seq_ids), jnp.asarray(page_nos))
        return point_query(self.state, jnp.sort(keys))[jnp.argsort(jnp.argsort(keys))]

    def pages_of(self, seq_id: int, *, max_pages: int = 256):
        """All (page_no, slot) of a sequence, in order (range query)."""
        lo = jnp.array([seq_id << PAGE_BITS], jnp.int32)
        hi = jnp.array([((seq_id + 1) << PAGE_BITS) - 1], jnp.int32)
        k, v, n = range_query(self.state, lo, hi, max_results=max_pages)
        return k[0] & ((1 << PAGE_BITS) - 1), v[0], n[0]

    def free_sequences(self, seq_ids, *, max_pages: int = 256):
        """Batch-free every page of the given sequences (physical removal)."""
        seq_ids = jnp.asarray(seq_ids, jnp.int32)
        keys = (seq_ids[:, None] << PAGE_BITS) | jnp.arange(
            max_pages, dtype=jnp.int32
        )[None, :]
        self.state, stats = delete(self.state, jnp.sort(keys.reshape(-1)))
        return stats

    def live_pages(self) -> int:
        return int(self.state.live_keys()) - 1  # minus the seed key
