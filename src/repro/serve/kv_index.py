"""FliX-backed KV page index — the paper's CDS inside an LLM serving plane.

The serving control plane must map (sequence_id, page_no) → cache slot for
batched requests, under continuous allocation (prefill) and freeing
(sequence completion) — exactly the dynamic ordered-map workload FliX is
built for.  Keys are ``seq_id << PAGE_BITS | page_no``, so one successor /
range query enumerates a sequence's pages *in order* (hash tables can't),
and batched frees are physical deletions with immediate slot reclamation —
no tombstone accumulation across the serving day (the paper's §6.5 LSMu
collapse is precisely the failure mode this avoids).

Execution matches the paper's batch model exactly: each engine step submits
**one mixed sorted batch** of (allocate | lookup | enumerate | free)
operations through ``core.ops.apply_ops`` — one sort, one bucket routing,
one flipped pass — instead of sorting and routing per op type.  Sequence
page enumeration (``pages_of``) is the RANGE op: ``[seq << PAGE_BITS,
(seq+1) << PAGE_BITS)`` travels in the batch like any other operation, so
there is no engine bypass and an enumeration in an update step observes
that step's allocations and frees (update-then-read).  Batches are padded
to the next power of two so jit traces once per size class, not once per
step.

``shards=N`` range-partitions the index across the first N local devices
and routes every engine step through ``core.distributed.shard_apply_ops``
— same mixed batch, same contract, one ``shard_map`` step — so ``pages_of``
and friends are served across the mesh with no separate distributed code
path (DESIGN.md §11).

Two first-class time features ride the same batch model (DESIGN.md §14):

* **TTL** — ``step(now=...)`` threads the serving plane's virtual clock
  into the engine (rows whose deadline has passed are invisible and
  reclaimed lazily), and ``getsets`` submits get-or-set-with-TTL ops
  (``OP_EXPIRE``) in the same mixed batch as everything else;
* **snapshot reads** — with ``snapshot_window > 0`` every committed
  update step pins a version of the (immutable, functional) state;
  ``step(as_of=v)`` serves reads against that pinned version at its
  pinned clock, byte-identical no matter how many later batches commit,
  until the window slides past it (:class:`SnapshotGone`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    NO_EXPIRY,
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_POINT,
    OP_RANGE,
    apply_ops,
    apply_ops_safe,
    build,
    make_ops,
    unsort,
)
from repro.core.config import _UNSET, ExecConfig, resolve_config

PAGE_BITS = 12  # up to 4096 pages (≈ pages × page_size tokens) per sequence


@dataclasses.dataclass(frozen=True)
class StepResult:
    """One engine step's outcome (:meth:`KVPageIndex.step`).

    * ``slots``     — resolved cache slots aligned with the ``lookups``
      input order followed by the ``getsets`` input order (NOT_FOUND = -1).
    * ``range_out`` — None without ``ranges``, else the dense ``keys`` /
      ``vals`` arrays plus per-op ``start`` / ``count`` aligned with the
      ``ranges`` input order.
    * ``stats``     — the engine step's stats dict (empty for a no-op step).

    Deliberately NOT iterable: the pre-PR-10 positional
    ``(slots, range_out, stats)`` tuple is gone, and stale unpacking should
    fail loudly here rather than silently misbind fields.
    """

    slots: jax.Array
    range_out: dict | None
    stats: dict


class SnapshotGone(LookupError):
    """The requested pinned version slid out of the retention window (its
    buffers were released for reclamation) — the read must be re-issued
    against a live version.  Typed so the gateway can map it to a
    non-retryable ``SNAPSHOT_GONE`` rejection."""


def _key(seq_ids, page_nos):
    return (seq_ids.astype(jnp.int32) << PAGE_BITS) | page_nos.astype(jnp.int32)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class KVPageIndex:
    """Host-driven wrapper around a FliXState (functional underneath).

    ``config`` is the execution strategy for every engine step — one
    :class:`~repro.core.config.ExecConfig` whose ``impl`` picks the
    ``apply_ops`` executor (``"auto"`` = the fused compute-to-bucket kernel
    on TPU, the jnp reference engine elsewhere), whose ``routing`` picks
    the distributed batch mode when sharded, and whose pipeline/tile knobs
    thread to the fused kernel.  The bare ``impl`` / ``routing`` keywords
    are deprecated warn-once shims for it.

    ``shards`` > 0 range-partitions the index over that many local devices
    and serves every step through ``shard_apply_ops`` (replicated routing
    is right for the control-plane batch sizes this index sees).  All
    public methods behave identically.

    ``durability_dir`` switches on the DESIGN.md §12 persistence layer:
    every update step is WAL-logged (fsynced) before execution and
    snapshotted every ``snapshot_every`` steps, and constructing against a
    directory that already holds a durable history *recovers* it (latest
    snapshot + replay) instead of starting empty.  Pure-read steps never
    touch the log.  ``wal_fsync=False`` removes the durability boundary —
    it exists for the negative crash tests, never for serving.

    ``snapshot_window`` > 0 retains that many recent committed versions
    for ``step(as_of=...)`` snapshot reads; it also disables buffer
    donation on update steps (pinned versions alias the pre-update
    buffers, which must stay intact).

    ``device_budget`` (bytes) switches the local engine to the tiered
    residency state (``core.residency.TieredFliX``, DESIGN.md §15): the
    index may grow far beyond the budget, with every step promoting the
    buckets its batch touches and demoting back under the budget after
    commit.  Results and durable bytes are identical to the unbounded
    engine; ``step`` stats additionally carry the residency counters
    (``resident_bytes`` / ``promoted`` / ``demoted`` / ``reclaimed_bytes``).
    Incompatible with ``shards`` (per-shard budgets are planned host-side
    via ``core.distributed.plan_shard_budget``) and with
    ``snapshot_window`` (pinned versions require immutable functional
    states; the tiered handle is mutating).
    """

    def __init__(
        self,
        *,
        node_size: int = 16,
        nodes_per_bucket: int = 8,
        config: ExecConfig | None = None,
        shards: int = 0,
        durability_dir=None,
        snapshot_every: int = 64,
        wal_fsync: bool = True,
        crash_hook=None,
        snapshot_window: int = 0,
        device_budget: int | None = None,
        impl=_UNSET,
        routing=_UNSET,
    ):
        # seed with one sentinel key (outside the (seq,page) space) so the
        # structure is never empty
        from repro.core import MAX_VALID

        self.config = resolve_config("KVPageIndex", config, impl=impl, routing=routing)
        self.impl = self.config.impl
        self.routing = self.config.routing
        self._durable = None
        self._closed = False
        self.snapshot_window = int(snapshot_window)
        self.device_budget = device_budget
        self._version = 0
        self._pins: dict[int, tuple[object, int | None]] = {}
        if device_budget is not None:
            if shards:
                raise ValueError(
                    "device_budget is a single-device residency bound; "
                    "sharded indexes size each shard via plan_shard_budget"
                )
            if snapshot_window:
                raise ValueError(
                    "device_budget and snapshot_window are incompatible: "
                    "pinned versions need immutable functional states"
                )
        seed_keys = jnp.array([MAX_VALID], jnp.int32)
        seed_vals = jnp.array([0], jnp.int32)
        if shards:
            from repro.core.distributed import make_shard_mesh, shard_build

            self.mesh = make_shard_mesh(shards)
            self.sharded = shard_build(
                seed_keys,
                seed_vals,
                self.mesh,
                node_size=node_size,
                nodes_per_bucket=nodes_per_bucket,
            )
            self.state = None
        else:
            self.mesh = None
            self.sharded = None
            self.state = build(
                seed_keys,
                seed_vals,
                node_size=node_size,
                nodes_per_bucket=nodes_per_bucket,
            )
            if device_budget is not None:
                from repro.core.residency import TieredFliX

                self.state = TieredFliX.from_state(
                    self.state, budget_bytes=device_budget
                )
        if durability_dir is not None:
            from repro.checkpoint import (
                DurableFliX,
                LocalEngine,
                ShardEngine,
                TieredEngine,
            )

            if self.mesh is not None:
                engine = ShardEngine(
                    self.mesh,
                    config=self.config,
                    node_size=node_size,
                    nodes_per_bucket=nodes_per_bucket,
                )
            elif device_budget is not None:
                engine = TieredEngine(
                    budget_bytes=device_budget,
                    config=self.config,
                    node_size=node_size,
                    nodes_per_bucket=nodes_per_bucket,
                )
            else:
                engine = LocalEngine(
                    config=self.config,
                    node_size=node_size,
                    nodes_per_bucket=nodes_per_bucket,
                )
            if DurableFliX.exists(durability_dir):
                self._durable = DurableFliX.open(
                    durability_dir,
                    engine=engine,
                    snapshot_every=snapshot_every,
                    fsync=wal_fsync,
                    crash_hook=crash_hook,
                )
            else:
                handle = self.sharded if self.mesh is not None else self.state
                self._durable = DurableFliX.create(
                    durability_dir,
                    handle,
                    engine=engine,
                    snapshot_every=snapshot_every,
                    fsync=wal_fsync,
                    crash_hook=crash_hook,
                )
            self._commit(self._durable.handle)
        if self.snapshot_window:
            live = self.sharded if self.mesh is not None else self.state
            self._pins[0] = (live, None)

    # ---- the engine step: one mixed batch ------------------------------
    def step(
        self,
        *,
        allocs=None,
        lookups=None,
        getsets=None,
        free_seqs=None,
        ranges=None,
        max_pages: int = 256,
        range_budget: int = 256,
        meta=None,
        now: int | None = None,
        as_of: int | None = None,
    ):
        """Submit one engine step's mixed work as a single sorted batch.

        ``allocs``    — (seq_ids, page_nos, slots[, deadlines]): register
                        pages; the optional 4th tuple gives each page an
                        absolute expiry deadline (virtual time).
        ``lookups``   — (seq_ids, page_nos): resolve pages → slots.
        ``getsets``   — (seq_ids, page_nos, slots, deadlines): get-or-set
                        with TTL (``OP_EXPIRE``): a mapped page returns its
                        EXISTING slot and has its deadline refreshed; an
                        unmapped one is registered with the given slot and
                        deadline and returns NOT_FOUND.
        ``free_seqs`` — sequence ids whose pages are all physically freed.
        ``ranges``    — (lo_keys, hi_keys): half-open ``[lo, hi)`` RANGE ops
                        in raw key space, answered against this step's
                        post-update state under the batch's static
                        ``range_budget`` (see ``apply_ops``' truncation
                        contract).

        ``now`` is the step's virtual clock: rows whose deadline has
        passed (``exp <= now``) are reclaimed before the batch's updates
        and invisible to its reads.  On a read-only step the expiry view
        is computed on a throwaway functional copy — nothing is committed
        or logged (sound: expiry is monotone in ``now``).

        ``as_of`` pins the step to a retained committed version
        (``snapshot_window``): the batch must be read-only, runs against
        that version's state at its OWN pinned clock (``now`` must be
        None), and returns byte-identical results for as long as the
        version is retained; a reclaimed version raises
        :class:`SnapshotGone`.

        ``meta`` (JSON-serializable, e.g. the gateway's idempotency keys)
        is logged inside the update batch's WAL record when durability is
        on and ignored otherwise — pure-read steps never log, so meta on a
        read-only step is dropped.

        ``allocs``, ``getsets`` and ``free_seqs`` must not overlap in key
        space within one step: that would put two update ops on one key,
        violating ``apply_ops``' one-update-op-per-key precondition.
        Checked here because the ids are host values anyway.

        Returns a :class:`StepResult` (``slots`` / ``range_out`` /
        ``stats`` — see its docstring for the field contracts).
        """
        # empty op lists are the same as absent ones — callers naturally pass
        # this step's (often empty) completion list every step, and an empty
        # free list must not push a pure-lookup batch onto the update path
        if allocs is not None and len(np.asarray(allocs[0])) == 0:
            allocs = None
        if free_seqs is not None and len(np.asarray(free_seqs)) == 0:
            free_seqs = None
        if lookups is not None and len(np.asarray(lookups[0])) == 0:
            lookups = None
        if getsets is not None and len(np.asarray(getsets[0])) == 0:
            getsets = None
        if ranges is not None and len(np.asarray(ranges[0])) == 0:
            ranges = None
        if allocs is not None and free_seqs is not None:
            overlap = set(np.asarray(allocs[0]).tolist()) & set(
                np.asarray(free_seqs).tolist()
            )
            if overlap:
                raise ValueError(
                    f"sequences {sorted(overlap)} appear in both allocs and "
                    "free_seqs within one step; free them the step after "
                    "their last allocation"
                )
        if getsets is not None:
            gs_keys = {
                (int(s) << PAGE_BITS) | int(p)
                for s, p in zip(np.asarray(getsets[0]), np.asarray(getsets[1]))
            }
            if free_seqs is not None:
                overlap = set(np.asarray(getsets[0]).tolist()) & set(
                    np.asarray(free_seqs).tolist()
                )
                if overlap:
                    raise ValueError(
                        f"sequences {sorted(overlap)} appear in both getsets "
                        "and free_seqs within one step"
                    )
            if allocs is not None:
                al_keys = {
                    (int(s) << PAGE_BITS) | int(p)
                    for s, p in zip(np.asarray(allocs[0]), np.asarray(allocs[1]))
                }
                if al_keys & gs_keys:
                    raise ValueError(
                        "the same page appears in both allocs and getsets "
                        "within one step"
                    )

        pinned = None
        if as_of is not None:
            if allocs is not None or getsets is not None or free_seqs is not None:
                raise ValueError("as_of pins a read-only step; it cannot update")
            if now is not None:
                raise ValueError(
                    "as_of reads run at the pinned version's own clock; "
                    "pass now=None"
                )
            if self.snapshot_window <= 0:
                raise ValueError("snapshot reads require snapshot_window > 0")
            if not (0 <= as_of <= self._version):
                raise ValueError(
                    f"as_of={as_of} was never committed (version={self._version})"
                )
            if as_of not in self._pins:
                raise SnapshotGone(
                    f"version {as_of} left the {self.snapshot_window}-deep "
                    f"retention window (current version {self._version})"
                )
            pinned, now = self._pins[as_of]

        tags, keys, vals, exps = [], [], [], []
        has_ttl = getsets is not None or (allocs is not None and len(allocs) == 4)
        n_alloc = n_lookup = n_getset = 0
        if allocs is not None:
            seq, page, slot = allocs[:3]
            k = _key(jnp.asarray(seq), jnp.asarray(page))
            n_alloc = k.shape[0]
            tags.append(jnp.full((n_alloc,), OP_INSERT, jnp.int32))
            keys.append(k)
            vals.append(jnp.asarray(slot, jnp.int32))
            exps.append(
                jnp.asarray(allocs[3], jnp.int32)
                if len(allocs) == 4
                else jnp.full((n_alloc,), NO_EXPIRY, jnp.int32)
            )
        if lookups is not None:
            seq, page = lookups
            k = _key(jnp.asarray(seq), jnp.asarray(page))
            n_lookup = k.shape[0]
            tags.append(jnp.full((n_lookup,), OP_POINT, jnp.int32))
            keys.append(k)
            vals.append(jnp.zeros((n_lookup,), jnp.int32))
            exps.append(jnp.full((n_lookup,), NO_EXPIRY, jnp.int32))
        if getsets is not None:
            seq, page, slot, deadline = getsets
            k = _key(jnp.asarray(seq), jnp.asarray(page))
            n_getset = k.shape[0]
            tags.append(jnp.full((n_getset,), OP_EXPIRE, jnp.int32))
            keys.append(k)
            vals.append(jnp.asarray(slot, jnp.int32))
            exps.append(jnp.asarray(deadline, jnp.int32))
        if free_seqs is not None:
            seq = jnp.asarray(free_seqs, jnp.int32)
            k = (
                (seq[:, None] << PAGE_BITS)
                | jnp.arange(max_pages, dtype=jnp.int32)[None, :]
            ).reshape(-1)
            tags.append(jnp.full(k.shape, OP_DELETE, jnp.int32))
            keys.append(k)
            vals.append(jnp.zeros(k.shape, jnp.int32))
            exps.append(jnp.full(k.shape, NO_EXPIRY, jnp.int32))
        n_before_range = sum(int(k.shape[0]) for k in keys)
        n_range = 0
        if ranges is not None:
            lo, hi = ranges
            lo = jnp.asarray(lo, jnp.int32)
            n_range = lo.shape[0]
            tags.append(jnp.full((n_range,), OP_RANGE, jnp.int32))
            keys.append(lo)
            vals.append(jnp.asarray(hi, jnp.int32))
            exps.append(jnp.full((n_range,), NO_EXPIRY, jnp.int32))
        if not keys:
            return StepResult(slots=jnp.zeros((0,), jnp.int32), range_out=None, stats={})

        tag = jnp.concatenate(tags)
        key = jnp.concatenate(keys)
        val = jnp.concatenate(vals)
        pad_to = _next_pow2(key.shape[0])
        if self.mesh is not None:
            # a2a routing position-shards the batch: round the padded size
            # up to a shard-count multiple so every chunk is equal
            n_shards = int(self.mesh.shape["shards"])
            pad_to = -(-pad_to // n_shards) * n_shards
        ops, perm = make_ops(
            tag,
            key,
            val,
            exps=jnp.concatenate(exps) if has_ttl else None,
            pad_to=pad_to,
        )
        read_only = n_alloc == 0 and n_getset == 0 and free_seqs is None
        has_ranges = n_range > 0
        if read_only:
            # pure-read step (lookups and/or ranges): the state is
            # untouched, so keep the pre-batch state/index instead of
            # swapping in the engine's pass-through copy.  Always the
            # reference engine here — the fused kernel's update sweep
            # rewrites the whole state, pure waste for an update-free batch
            # (DESIGN.md §9/§10), while the reference lax.cond phases skip
            # it.
            cfg = self.config.replace(
                impl="reference", max_results=range_budget, donate=False
            )
            _, results, stats = self._apply(
                ops, config=cfg, has_ranges=has_ranges, now=now, handle=pinned
            )
        elif n_alloc == 0 and n_getset == 0:
            # only inserts can overflow — free steps skip the restructure-
            # and-retry wrapper (and its host sync), and since no retry can
            # replay the batch, the old state's buffers are donated to the
            # step (fused path; a no-op on CPU) — unless pinned snapshot
            # versions alias them (snapshot_window > 0)
            cfg = self.config.replace(
                max_results=range_budget, donate=self.snapshot_window == 0
            )
            new, results, stats = self._apply(
                ops,
                config=cfg,
                has_updates=True,
                has_ranges=has_ranges,
                meta=meta,
                now=now,
            )
            self._commit(new, bump=True, now=now)
        else:
            # allocation steps go through the safe driver; its retry path
            # regrows (sharded: rebalances fences via shard_restructure —
            # the cluster analogue of §3.5 relaunch) and replays the batch
            cfg = self.config.replace(max_results=range_budget, donate=False)
            new, results, stats = self._apply(
                ops,
                config=cfg,
                safe=True,
                has_updates=True,
                has_ranges=has_ranges,
                meta=meta,
                now=now,
            )
            self._commit(new, bump=True, now=now)
        values = unsort(results["value"], perm[: key.shape[0]])
        range_out = None
        if n_range:
            sub = perm[n_before_range : n_before_range + n_range]
            range_out = {
                "keys": results["range_key"],
                "vals": results["range_val"],
                "start": unsort(results["range_start"], sub),
                "count": unsort(results["range_count"], sub),
            }
        return StepResult(
            slots=values[n_alloc : n_alloc + n_lookup + n_getset],
            range_out=range_out,
            stats=stats,
        )

    def _apply(
        self,
        ops,
        *,
        config: ExecConfig,
        safe=False,
        has_updates=None,
        has_ranges=False,
        meta=None,
        now=None,
        handle=None,
    ):
        """Dispatch one engine batch to the local or sharded executor.

        Same step policy either way (one copy of it, in :meth:`step`):
        ``config`` carries the whole execution strategy for the batch,
        already specialized per step kind (reference-engine reads, donated
        frees, safe allocations).  ``has_updates`` / ``has_ranges`` are the
        host-known batch-composition hints.

        ``handle`` overrides the state the batch runs against (pinned
        snapshot reads — read-only by construction, never committed).

        With durability on, every update batch commits through
        ``DurableFliX.apply`` — WAL-ahead, restructure-and-retry inside —
        so it forfeits donation; pure reads bypass the log entirely.
        """
        if self._durable is not None and (safe or has_updates):
            results, stats = self._durable.apply(
                ops, config=config.replace(donate=False), meta=meta, now=now
            )
            return self._durable.handle, results, stats
        if self.mesh is not None:
            from repro.core.distributed import shard_apply_ops, shard_apply_ops_safe

            sharded = self.sharded if handle is None else handle
            if safe:
                return shard_apply_ops_safe(
                    sharded,
                    ops,
                    self.mesh,
                    config=config.replace(donate=False),
                    has_updates=has_updates,
                    has_ranges=has_ranges,
                    now=now,
                )
            return shard_apply_ops(
                sharded,
                ops,
                self.mesh,
                config=config,
                has_updates=has_updates,
                has_ranges=has_ranges,
                now=now,
            )
        state = self.state if handle is None else handle
        from repro.core.residency import TieredFliX

        if isinstance(state, TieredFliX):
            # the tiered handle mutates in place and carries its own
            # restructure-and-retry; commit=False keeps read-only steps
            # (incl. throwaway expiry views) from changing logical content
            results, stats, _ = state.apply(
                ops,
                config=config,
                now=now,
                commit=bool(safe or has_updates),
            )
            return state, results, stats
        if safe:
            return apply_ops_safe(
                state, ops, config=config.replace(donate=False), now=now,
                has_updates=has_updates,
            )
        return apply_ops(state, ops, config=config, has_updates=has_updates, now=now)

    def _commit(self, new, *, bump: bool = False, now: int | None = None):
        """Install an update step's result (local state or sharded index);
        ``bump`` advances the version counter and, with a retention
        window, pins the committed version (plus its clock) for
        ``step(as_of=...)`` until the window slides past it."""
        if self.mesh is not None:
            self.sharded = new
        else:
            self.state = new
        if bump:
            self._version += 1
            if self.snapshot_window:
                self._pins[self._version] = (new, now)
                low = self._version - self.snapshot_window
                for v in [v for v in self._pins if v <= low]:
                    del self._pins[v]

    # ---- per-type conveniences (each is still one engine step) ---------
    def allocate(self, seq_ids, page_nos, slots):
        """Batch-register pages → slots (an engine allocation step)."""
        return self.step(allocs=(seq_ids, page_nos, slots)).stats

    def lookup(self, seq_ids, page_nos):
        """Batch lookup → cache slots (NOT_FOUND = -1 for unmapped pages)."""
        return self.step(lookups=(seq_ids, page_nos)).slots

    def free_sequences(self, seq_ids, *, max_pages: int = 256):
        """Batch-free every page of the given sequences (physical removal)."""
        return self.step(free_seqs=seq_ids, max_pages=max_pages).stats

    def pages_of(self, seq_id: int, *, max_pages: int = 256):
        """All (page_no, slot) of a sequence, in order (a RANGE engine step).

        Routed through ``apply_ops`` like every other operation — no
        standalone ``range_query`` bypass, so enumeration always reads the
        engine's own state (a cache-carrying read state included) and can
        legally share a batch with updates via :meth:`step`.
        """
        lo = seq_id << PAGE_BITS
        hi = (seq_id + 1) << PAGE_BITS
        rng_out = self.step(ranges=([lo], [hi]), range_budget=max_pages).range_out
        return (
            rng_out["keys"] & ((1 << PAGE_BITS) - 1),
            rng_out["vals"],
            rng_out["count"][0],
        )

    def live_pages(self) -> int:
        state = self.sharded.state if self.mesh is not None else self.state
        return int(state.live_keys()) - 1  # minus the seed key

    def getset(self, seq_ids, page_nos, slots, deadlines, *, now=None):
        """Batch get-or-set with TTL (one ``OP_EXPIRE`` engine step):
        returns the existing slot (deadline refreshed) for mapped pages,
        NOT_FOUND for pages registered by this call."""
        return self.step(getsets=(seq_ids, page_nos, slots, deadlines), now=now).slots

    # ---- snapshot versions ----------------------------------------------
    @property
    def version(self) -> int:
        """Count of committed update steps — the newest ``as_of`` value."""
        return self._version

    @property
    def retained_versions(self) -> list[int]:
        """Versions currently answerable via ``step(as_of=...)``."""
        return sorted(self._pins)

    # ---- residency -------------------------------------------------------
    @property
    def resident_bytes(self) -> int | None:
        """Device-tier footprint of a tiered index (None when single-tier:
        the whole index is device-resident by construction)."""
        from repro.core.residency import TieredFliX

        state = self._durable.handle if self._durable is not None else self.state
        if isinstance(state, TieredFliX):
            return state.memory_bytes_resident()
        return None

    # ---- durability / health -------------------------------------------
    @property
    def durable_seq(self) -> int | None:
        """Last durably committed batch seq (None with durability off)."""
        return self._durable.seq if self._durable is not None else None

    @property
    def healthy(self) -> bool:
        """True while the UPDATE path is trustworthy.

        Goes False when the durable layer is poisoned (live and durable
        state diverged after a failed WAL rollback) or the index is
        closed.  Reads against the live state remain valid either way —
        the serving gateway uses exactly this split for degraded
        read-only routing (DESIGN.md §13).
        """
        if self._closed:
            return False
        return self._durable is None or self._durable.healthy

    def dedup_seed(self) -> list[tuple[int, object]]:
        """The durable ``(seq, meta)`` trail of recent update commits
        (empty with durability off) — what the gateway reseeds its
        idempotency dedup window from after crash recovery."""
        return self._durable.meta_trail() if self._durable is not None else []

    def snapshot(self):
        """Force a snapshot now (durability on); returns its directory.

        Idempotent — a snapshot at the current seq already on disk is
        revalidated, not rewritten — and safe on an unhealthy instance:
        a poisoned durable layer has nothing trustworthy to persist
        beyond the WAL it already holds, so this returns None instead of
        raising from a teardown path (reopening resynchronizes).
        """
        if self._durable is None:
            raise RuntimeError("durability is off (no durability_dir)")
        if not self._durable.healthy:
            return None
        return self._durable.snapshot()

    def close(self):
        """Flush and close the WAL (no-op with durability off).

        Idempotent and safe on a poisoned durable layer: teardown never
        raises on top of the failure that poisoned the instance.
        """
        if self._closed:
            return
        self._closed = True
        if self._durable is not None:
            self._durable.close()
