"""Cross-version jax API compatibility (non-Pallas surface).

Pallas-specific shims live in ``repro.kernels._compat``; mesh axis-type
handling lives in ``repro.launch.mesh.make_mesh_auto``.  This module covers
the rest: jax>=0.6 exposes ``shard_map`` at the top level, while this
container's jax keeps it in ``jax.experimental``.
"""

from __future__ import annotations

import jax

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(*args, **kwargs):  # noqa: F811
        # the replication-check kwarg was renamed check_rep -> check_vma
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(*args, **kwargs)
