import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) cell on the production
16×16 single-pod mesh and the 2×16×16 multi-pod mesh, records
``memory_analysis()`` (proves it fits), ``cost_analysis()`` (FLOPs/bytes for
§Roofline), and the collective-bytes breakdown parsed from the compiled
SPMD module.  Results land in ``experiments/dryrun/*.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse
import json
import re
import sys
import time
import traceback
from collections import defaultdict
from pathlib import Path


OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8,
    "f32": 4,
    "bf16": 2,
    "f16": 2,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "s64": 8,
    "s32": 4,
    "s16": 2,
    "s8": 1,
    "u64": 8,
    "u32": 4,
    "u16": 2,
    "u8": 1,
    "pred": 1,
    "c64": 8,
    "c128": 16,
}


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-type output bytes of every collective op in the compiled module.

    Result shapes sit between '=' and the op token; tuple-shaped results
    (e.g. all-to-all) parse the same way since we cut at the op name."""
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            tok = f" {c}(" if f" {c}(" in line else (
                f" {c}-start(" if f" {c}-start(" in line else None
            )
            if tok:
                seg = line.split(tok, 1)[0]
                if "=" in seg:
                    seg = seg.split("=", 1)[1]
                out[c]["count"] += 1
                out[c]["bytes"] += _shape_bytes(seg)
                break
    return dict(out)


def _analyze(compiled) -> dict:
    ma = compiled.memory_analysis()
    mem = {
        k: int(getattr(ma, k, 0))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    ca = compiled.cost_analysis() or {}
    cost = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    colls = collective_bytes(compiled.as_text())
    return {
        "memory": mem,
        "cost": cost,
        "collectives": colls,
        "collective_bytes_total": sum(v["bytes"] for v in colls.values()),
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    out_dir: Path,
    *,
    suffix: str = "",
    **cell_kwargs,
) -> dict:
    """Compile the production (scan) program for the fit-proof, plus depth-1
    and depth-2 unrolled programs so per-layer FLOPs/collectives can be
    reconstructed (XLA cost analysis counts a scan body exactly once —
    methodology in EXPERIMENTS.md §Dry-run)."""
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell, layer_period

    mesh_name = ("multi" if multi_pod else "single") + suffix
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        cell = build_cell(arch, shape_name, mesh, **cell_kwargs)
        lowered = cell.jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        prod = _analyze(compiled)

        kind = cell.meta["kind"]
        recon = None
        if kind in ("train", "prefill"):
            # depth-reconstruction compiles (small unrolled programs)
            d1 = build_cell(arch, shape_name, mesh, depth_periods=1, **cell_kwargs)
            a1 = _analyze(d1.jitted.lower(*d1.abstract_args).compile())
            d2 = build_cell(arch, shape_name, mesh, depth_periods=2, **cell_kwargs)
            a2 = _analyze(d2.jitted.lower(*d2.abstract_args).compile())
            period = layer_period(cell.cfg)
            n_periods = cell.cfg.num_layers // period
            recon = {
                "n_periods": n_periods,
                "period": period,
                "flops": a1["cost"]["flops"]
                + (n_periods - 1) * (a2["cost"]["flops"] - a1["cost"]["flops"]),
                "bytes_accessed": a1["cost"]["bytes_accessed"]
                + (n_periods - 1)
                * (a2["cost"]["bytes_accessed"] - a1["cost"]["bytes_accessed"]),
                "collective_bytes": a1["collective_bytes_total"]
                + (n_periods - 1)
                * (a2["collective_bytes_total"] - a1["collective_bytes_total"]),
                "depth1": a1,
                "depth2": a2,
            }
        else:
            # decode unrolls every layer: the compiled numbers are exact
            recon = {
                "flops": prod["cost"]["flops"],
                "bytes_accessed": prod["cost"]["bytes_accessed"],
                "collective_bytes": prod["collective_bytes_total"],
            }

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "devices": int(mesh.devices.size),
        "meta": cell.meta,
        **prod,
        "recon": recon,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "ok": True,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    path.write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--suffix", default="", help="variant tag for §Perf runs")
    ap.add_argument("--strategy", default="tp_sp", choices=["tp_sp", "fsdp"])
    ap.add_argument("--no-moe-token-shard", action="store_true")
    ap.add_argument("--moe-impl", default="gather", choices=["gather", "a2a", "auto"])
    ap.add_argument(
        "--override",
        action="append",
        default=[],
        help="cfg field override key=int (repeatable)",
    )
    args = ap.parse_args()
    out_dir = Path(args.out)
    cell_kwargs = dict(
        strategy=args.strategy,
        moe_token_shard=not args.no_moe_token_shard,
        moe_impl=args.moe_impl,
    )
    if args.override:
        cell_kwargs["overrides"] = {
            kv.split("=")[0]: int(kv.split("=")[1]) for kv in args.override
        }

    from repro.models.config import cells_for
    from repro.models.model import list_archs

    if args.all:
        cells = [(a, s) for a in list_archs() for s in cells_for(a)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in cells:
        for m in meshes:
            path = out_dir / f"{arch}__{shape}__{m}{args.suffix}.json"
            if args.skip_existing and path.exists():
                print(f"skip {arch} {shape} {m}", flush=True)
                continue
            try:
                r = run_cell(
                    arch,
                    shape,
                    m == "multi",
                    out_dir,
                    suffix=args.suffix,
                    **cell_kwargs,
                )
                print(
                    f"OK  {arch:18s} {shape:12s} {m:6s} "
                    f"flops={r['cost']['flops']:.3e} "
                    f"coll={r['collective_bytes_total']:.3e}B "
                    f"temp={r['memory']['temp_size_in_bytes']/2**30:.2f}GiB "
                    f"compile={r['compile_s']}s",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — record, continue sweep
                failures.append((arch, shape, m, repr(e)))
                path.write_text(
                    json.dumps(
                        {
                            "arch": arch,
                            "shape": shape,
                            "mesh": m,
                            "ok": False,
                            "error": traceback.format_exc(),
                        },
                        indent=2,
                    )
                )
                print(f"FAIL {arch} {shape} {m}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures", file=sys.stderr)
        sys.exit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()
