"""Serving driver: batched decode with the FliX KV-page control plane.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
        --reduced --batch 4 --steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.model import get_config
from repro.serve.kv_index import KVPageIndex
from repro.core.config import ExecConfig

PAGE_TOKENS = 16  # tokens per KV page tracked by the index


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--index-impl",
        choices=("auto", "reference", "fused"),
        default="auto",
        help="apply_ops executor for the KV page index: the fused "
        "compute-to-bucket kernel, the jnp reference engine, or auto "
        "(fused on TPU, reference elsewhere)",
    )
    ap.add_argument(
        "--shards",
        type=int,
        default=0,
        help="range-partition the KV page index over this many local "
        "devices and serve every engine step through shard_apply_ops "
        "(0 = single-device index)",
    )
    ap.add_argument(
        "--index-routing",
        choices=("replicated", "a2a"),
        default="replicated",
        help="distributed batch routing mode for the sharded index "
        "(DESIGN.md §11); ignored without --shards",
    )
    ap.add_argument(
        "--wal-dir",
        default=None,
        help="durability directory for the KV page index: every update "
        "step is write-ahead logged (fsynced) before execution and the "
        "index recovers from this directory on restart (DESIGN.md §12); "
        "default off",
    )
    ap.add_argument(
        "--snapshot-every",
        type=int,
        default=64,
        help="with --wal-dir, snapshot the index every N update steps "
        "(bounds replay-on-restart to at most N batches)",
    )
    ap.add_argument(
        "--snapshot-window",
        type=int,
        default=0,
        help="retain this many committed index versions for pinned "
        "step(as_of=...) snapshot reads (DESIGN.md §14); 0 disables "
        "versioned reads",
    )
    ap.add_argument(
        "--device-budget",
        type=int,
        default=0,
        help="bound the KV page index's device-resident footprint to this "
        "many bytes (tiered residency, DESIGN.md §15): the index may grow "
        "far beyond the budget, each engine step promotes exactly the "
        "buckets its batch touches and demotes back under the budget "
        "after commit; 0 = single-tier (whole index device-resident). "
        "Incompatible with --shards and --snapshot-window",
    )
    ap.add_argument(
        "--page-ttl",
        type=int,
        default=0,
        help="give each registered KV page an expiry deadline this many "
        "decode steps after its allocation (virtual time = step number); "
        "0 = pages never expire",
    )
    ap.add_argument(
        "--gateway",
        action="store_true",
        help="route index traffic through the multi-tenant batching "
        "gateway (DESIGN.md §13): each sequence submits per-step "
        "micro-requests with idempotency keys; the gateway coalesces "
        "them into the same mixed engine batches, exactly once",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_params(rng, cfg)
    cache = tf.init_cache(cfg, args.batch, args.max_len, dtype=jnp.float32)
    kv_index = KVPageIndex(
        config=ExecConfig(impl=args.index_impl, routing=args.index_routing),
        shards=args.shards,
        durability_dir=args.wal_dir,
        snapshot_every=args.snapshot_every,
        snapshot_window=args.snapshot_window,
        device_budget=args.device_budget or None,
    )
    if args.wal_dir and kv_index.durable_seq:
        print(
            f"recovered KV index from {args.wal_dir} "
            f"(seq {kv_index.durable_seq}, {kv_index.live_pages()} pages)"
        )

    gateway = None
    if args.gateway:
        from repro.serve.gateway import Gateway, Request

        gateway = Gateway(kv_index, default_rate=1e6, default_burst=1e6)

    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))
    token = jax.random.randint(rng, (args.batch,), 0, cfg.vocab_size)
    t0 = time.time()
    for i in range(args.steps):
        logits, cache = step(params, cache, token)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if i % PAGE_TOKENS == 0:  # new KV page per sequence
            page = i // PAGE_TOKENS
            seqs = np.arange(args.batch)
            if gateway is not None:
                # each sequence is its own tenant submitting micro-requests;
                # the gateway coalesces them into ONE mixed engine batch —
                # same sorted-batch execution, now with idempotency keys
                lookups = []
                for b in range(args.batch):
                    gateway.submit(
                        Request(
                            f"seq{b}",
                            f"alloc:{b}:{page}",
                            "alloc",
                            seqs=(b,),
                            pages=(page,),
                            slots=(b * 1000 + page,),
                        ),
                        now=float(i),
                    )
                    lookups.append(
                        gateway.submit(
                            Request(
                                f"seq{b}",
                                f"lookup:{b}:{i}",
                                "lookup",
                                seqs=(b,),
                                pages=(0,),
                            ),
                            now=float(i),
                        )
                    )
                gateway.pump(now=float(i))
                got = np.array([int(np.asarray(t.result())[0]) for t in lookups])
                assert (got == seqs * 1000).all()
            else:
                # one mixed engine step: register the new pages AND resolve
                # each sequence's head page in the same sorted batch
                allocs = (seqs, np.full(args.batch, page), seqs * 1000 + page)
                if args.page_ttl:
                    allocs = (*allocs, np.full(args.batch, i + args.page_ttl))
                slots = kv_index.step(
                    allocs=allocs,
                    lookups=(seqs, np.zeros(args.batch, int)),
                    now=i if args.page_ttl else None,
                ).slots
                # head page (deadline = page_ttl) is visible until its
                # deadline passes, then lazily expired
                expect = (
                    seqs * 1000
                    if args.page_ttl == 0 or args.page_ttl > i
                    else np.full(args.batch, -1)
                )
                assert (np.asarray(slots) == expect).all()
    jax.block_until_ready(token)
    dt = time.time() - t0
    where = (
        f"{args.shards} shards ({args.index_routing})" if args.shards else "1 device"
    )
    print(
        f"decoded {args.steps} steps × batch {args.batch} "
        f"({args.steps*args.batch/dt:.1f} tok/s); "
        f"kv index tracks {kv_index.live_pages()} pages on {where}"
    )
    if args.device_budget:
        rb = kv_index.resident_bytes
        assert rb is not None, "tiered index must report a resident footprint"
        # I7 after commit (one bucket always admitted for tiny budgets)
        state = kv_index._durable.handle if args.wal_dir else kv_index.state
        assert rb <= max(args.device_budget, state.bucket_bytes), (rb, args.device_budget)
        print(
            f"tiered residency ✓ ({rb} device-resident bytes, "
            f"budget {args.device_budget})"
        )
    if args.page_ttl == 0:
        # sanity: page lookups resolve
        got = np.asarray(
            kv_index.lookup(np.arange(args.batch), np.zeros(args.batch, int))
        )
        assert (got == np.arange(args.batch) * 1000).all()
        print("page table lookups consistent ✓")
        # sanity: in-order page enumeration through the engine's RANGE op
        n_pages = (args.steps - 1) // PAGE_TOKENS + 1
        pages, slots, count = kv_index.pages_of(0, max_pages=max(256, n_pages))
        assert int(count) == n_pages, (int(count), n_pages)
        assert np.asarray(pages)[:n_pages].tolist() == list(range(n_pages))
        assert np.asarray(slots)[:n_pages].tolist() == list(range(n_pages))
        print(f"page enumeration in order ✓ ({n_pages} pages for seq 0)")
    else:
        # every registered page's deadline lies before this horizon, so a
        # read at it sees nothing — TTL is governed by the explicit virtual
        # clock, never by when this process happens to run
        horizon = args.steps + args.page_ttl
        gone = kv_index.step(
            lookups=(np.arange(args.batch), np.zeros(args.batch, int)),
            now=horizon,
        ).slots
        assert (np.asarray(gone) == -1).all()
        print(f"page TTLs honored ✓ (head pages invisible at now={horizon})")
    if args.snapshot_window:
        from repro.serve.kv_index import SnapshotGone

        v = kv_index.version
        lo, hi = 0, args.batch << 12
        pinned = kv_index.step(ranges=([lo], [hi]), as_of=v, range_budget=1024).range_out
        base = (
            np.asarray(pinned["keys"]).tobytes()
            + np.asarray(pinned["vals"]).tobytes()
        )
        for extra in range(3):  # three later update batches
            kv_index.step(allocs=([4000 + extra], [0], [extra]))
        if args.snapshot_window > 3:
            again = kv_index.step(
                ranges=([lo], [hi]), as_of=v, range_budget=1024
            ).range_out
            assert (
                np.asarray(again["keys"]).tobytes()
                + np.asarray(again["vals"]).tobytes()
                == base
            )
            print(
                f"pinned snapshot read byte-identical across 3 later "
                f"batches ✓ (as_of={v})"
            )
        else:
            try:
                kv_index.step(ranges=([lo], [hi]), as_of=v, range_budget=1024)
                raise AssertionError("expected SnapshotGone")
            except SnapshotGone:
                print(f"snapshot window slid past version {v} → SNAPSHOT_GONE ✓")
    if gateway is not None:
        # retrying a committed key resolves from the dedup window, no re-apply
        dup = gateway.submit(
            Request("seq0", "alloc:0:0", "alloc", seqs=(0,), pages=(0,), slots=(0,)),
            now=float(args.steps),
        )
        assert dup.ok and dup.duplicate
        m = gateway.metrics
        print(
            f"gateway exactly-once ✓ ({m['committed_requests']} requests in "
            f"{m['batches']} batches, {m['duplicates']} duplicates deduped)"
        )
    if args.wal_dir:
        kv_index.snapshot()
        if gateway is not None:
            gateway.close(now=float(args.steps))
        else:
            kv_index.close()
        print(f"index durable at seq {kv_index.durable_seq} in {args.wal_dir}")


if __name__ == "__main__":
    main()
