"""Roofline analysis (deliverable g).

Reads the dry-run JSONs (``experiments/dryrun``) and derives, per
(arch × shape) on the single-pod mesh:

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_chip / HBM_bw              [s]
    collective term = collective_bytes_per_chip / link_bw      [s]

Conventions (documented in EXPERIMENTS.md §Roofline):
  * the dry-run's cost/collective numbers are per-chip (XLA SPMD modules are
    per-device programs; scan bodies are depth-reconstructed — §Dry-run);
    dividing per-chip work by per-chip peak is identical to the prompt's
    cluster-total / (chips × peak) form.
  * collective bytes = sum of collective op *output* shapes ≈ bytes received
    per chip; link_bw = 50 GB/s ICI.
  * MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode), N analytic from
    the *unpadded* published config (N_active for MoE) — the ratio against
    HLO_FLOPs exposes padding, remat, and dispatch waste.

Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.models.config import SHAPES, ModelConfig
from repro.models.model import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def analytic_params(cfg: ModelConfig, *, active: bool = False) -> int:
    """Parameter count from the published (unpadded) config."""
    D = cfg.d_model
    n = cfg.vocab_size * D  # embed
    if not cfg.tie_embeddings:
        n += D * cfg.vocab_size  # lm head
    dh = cfg.resolved_head_dim

    def dense_attn():
        a = D * cfg.num_heads * dh * 2 + D * cfg.num_kv_heads * dh * 2
        if cfg.qkv_bias:
            a += cfg.num_heads * dh + 2 * cfg.num_kv_heads * dh
        return a

    def dense_mlp(f):
        return 3 * D * f

    if cfg.family in ("ssm", "hybrid"):
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = 2 * D * di + 2 * D * N + D * H + cfg.conv_kernel * (di + 2 * N)
        per += di * D + di + 3 * H
        n += cfg.num_layers * per
        if cfg.family == "hybrid":
            n += dense_attn() + dense_mlp(cfg.d_ff)
        return n

    per = dense_attn()
    if cfg.family == "moe":
        e_used = (cfg.top_k if active else cfg.num_experts)
        per += D * cfg.num_experts                      # router
        per += e_used * 3 * D * cfg.moe_d_ff            # routed experts
        per += cfg.num_shared_experts * 3 * D * cfg.moe_d_ff
    else:
        per += dense_mlp(cfg.d_ff)
    n += cfg.num_layers * per
    return n


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    n = analytic_params(cfg, active=(cfg.family == "moe"))
    if sh["kind"] == "train":
        return 6.0 * n * B * S
    if sh["kind"] == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B  # decode: one token per sequence


def analyze_cell(path: Path) -> dict | None:
    r = json.loads(path.read_text())
    if not r.get("ok"):
        return {"arch": r["arch"], "shape": r["shape"], "ok": False}
    rec = r["recon"]
    chips = r["devices"]
    flops_pd = rec["flops"]
    bytes_pd = rec["bytes_accessed"]
    coll_pd = rec["collective_bytes"]
    t_c = flops_pd / PEAK_FLOPS
    t_m = bytes_pd / HBM_BW
    t_n = coll_pd / LINK_BW
    dominant = max(
        ("compute", t_c), ("memory", t_m), ("collective", t_n), key=lambda kv: kv[1]
    )[0]
    mf = model_flops(r["arch"], r["shape"])
    hlo_total = flops_pd * chips
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "chips": chips,
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": (
            mf / PEAK_FLOPS / chips / max(t_c, t_m, t_n)
            if max(t_c, t_m, t_n) > 0
            else 0.0
        ),
        "temp_gib": r["memory"]["temp_size_in_bytes"] / 2**30,
        "ok": True,
    }


def render_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful FLOP ratio | roofline frac | temp GiB |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED |||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['temp_gib']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DRYRUN_DIR))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()

    rows = []
    for path in sorted(Path(args.dir).glob(f"*__{args.mesh}.json")):
        row = analyze_cell(path)
        if row:
            rows.append(row)
    print(render_table(rows))
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
