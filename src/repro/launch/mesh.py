"""Production meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS *before* the first device query.
"""

from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the jax version has them.

    ``jax.sharding.AxisType`` only exists in newer jax; older releases treat
    every axis as Auto already, so omitting the argument is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))
