"""Shared cell-building logic for the dry-run and roofline tools.

``build_cell(arch, shape, mesh)`` returns the jitted step function plus the
abstract inputs and shardings for one (architecture × input-shape × mesh)
combination — train_step for ``train_*`` shapes, prefill scoring for
``prefill_*``, serve_step (one-token decode against the cache) for
``decode_*`` / ``long_*``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.models import transformer
from repro.models.config import SHAPES, ModelConfig
from repro.models.model import get_config
from repro.train import TrainState, make_train_step, train_state_init
from repro.optim import AdamWState


class Cell(NamedTuple):
    jitted: Any            # jax.jit-wrapped step fn, shardings applied
    abstract_args: tuple   # ShapeDtypeStructs to .lower() with
    cfg: ModelConfig       # tp-padded config
    meta: dict


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _prefix_spec(cfg, B):
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct((B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)


def layer_period(cfg: ModelConfig) -> int:
    """Layers per repeating pattern period (for depth-reconstruction)."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.attention == "local_global":
        return cfg.local_global_ratio + 1
    return 1


def fsdp_param_specs(params, mesh):
    """ZeRO-3-style specs: every param shards its largest trailing dim over
    *all* (data, model) devices; weights are all-gathered per layer at use.
    Wins when per-layer weight bytes < per-layer activation-collective bytes
    (EXPERIMENTS.md §Perf iteration 3)."""
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])

    def spec_for(path, leaf):
        dims = leaf.shape
        for d in reversed(range(len(dims))):
            if dims[d] % n == 0 and dims[d] >= n:
                return P(*([None] * d), axes, *([None] * (len(dims) - d - 1)))
        return P()  # small params (norms, biases) stay replicated

    return jax.tree_util.tree_map_with_path(spec_for, params)


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    loss_chunk: int = 512,
    depth_periods: int | None = None,  # None = production depth (scan);
                                       # k = k pattern periods, unrolled
    seq_shard_acts: bool = True,
    strategy: str = "tp_sp",           # "tp_sp" (TP+Megatron-SP) | "fsdp"
    moe_token_shard: bool = True,      # shard MoE dispatch over the data axis
    moe_impl: str = "gather",          # "gather" | "a2a" | "auto"
    overrides: dict | None = None,     # cfg field overrides (perf sweeps)
) -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    tp = mesh.shape["model"] if strategy == "tp_sp" else 1
    cfg = cfg.padded(mesh.shape["model"]) if strategy == "tp_sp" else cfg.padded(1)
    shp = SHAPES[shape_name]
    B, S = shp["global_batch"], shp["seq_len"]
    kind = shp["kind"]
    daxes = sh.data_axes(mesh)

    layer_loop = "scan"
    if depth_periods is not None:
        period = layer_period(cfg)
        cfg = dataclasses.replace(cfg, num_layers=depth_periods * period)
        layer_loop = "unroll"

    if strategy == "fsdp":
        all_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        act_spec = P(all_axes, None, None)
        batch_axes = all_axes
    else:
        act_spec = P(daxes, "model", None) if seq_shard_acts else None
        batch_axes = daxes
        if moe_impl == "auto":
            # a2a needs the token count to tile the full mesh (train/prefill)
            tokens = B * S
            moe_impl = (
                "a2a"
                if kind in ("train", "prefill") and tokens % mesh.devices.size == 0
                else "gather"
            )
        if cfg.family == "moe" and moe_impl == "a2a":
            cfg = dataclasses.replace(cfg, moe_impl="a2a", moe_mesh=mesh)
        elif cfg.family == "moe" and moe_token_shard:
            cfg = dataclasses.replace(cfg, dispatch_spec=P("model", daxes, None))

    if kind == "train":
        state_abs = jax.eval_shape(
            lambda k: train_state_init(k, cfg), jax.random.PRNGKey(0)
        )
        if strategy == "fsdp":
            pspecs = fsdp_param_specs(state_abs.params, mesh)
        else:
            pspecs = sh.param_specs(cfg, state_abs.params, tp)
        state_specs = TrainState(
            params=pspecs, opt=AdamWState(step=P(), m=pspecs, v=pspecs)
        )
        text = S - (cfg.frontend_len if cfg.frontend else 0)
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((B, text), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, text), jnp.int32),
        }
        if cfg.frontend:
            batch_abs["prefix_embeds"] = _prefix_spec(cfg, B)
        batch_specs = {
            k: P(batch_axes, *([None] * (len(v.shape) - 1)))
            for k, v in batch_abs.items()
        }
        step = make_train_step(
            cfg, loss_chunk=loss_chunk, layer_loop=layer_loop, act_spec=act_spec
        )
        jitted = jax.jit(
            step,
            in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs)),
            donate_argnums=(0,),
        )
        return Cell(jitted, (state_abs, batch_abs), cfg, dict(kind=kind, B=B, S=S))

    # inference paths use bf16 params
    params_abs = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, jnp.bfloat16),
        jax.random.PRNGKey(0),
    )
    pspecs = sh.param_specs(cfg, params_abs, tp)

    if kind == "prefill":
        text = S - (cfg.frontend_len if cfg.frontend else 0)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((B, text), jnp.int32)}
        if cfg.frontend:
            batch_abs["prefix_embeds"] = _prefix_spec(cfg, B)
        batch_specs = sh.input_specs_sharding(mesh, batch_abs)

        def prefill_step(params, batch):
            h = transformer.forward_hidden(
                params,
                cfg,
                batch["tokens"],
                batch.get("prefix_embeds"),
                layer_loop=layer_loop,
                act_spec=act_spec,
            )
            head = (
                params["embed"].T if cfg.tie_embeddings else params["lm_head"]
            ).astype(h.dtype)
            return h[:, -1] @ head  # last-position scoring logits [B, V]

        jitted = jax.jit(
            prefill_step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, batch_specs)),
        )
        return Cell(jitted, (params_abs, batch_abs), cfg, dict(kind=kind, B=B, S=S))

    # decode
    cache_abs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, jnp.bfloat16)
    )
    cache_specs = sh.cache_specs(cfg, cache_abs, mesh, B)
    token_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    dsize = 1
    for a in daxes:
        dsize *= int(mesh.shape[a])
    token_spec = P(daxes) if (B >= dsize and B % dsize == 0) else P()

    def serve_step(params, cache, token):
        return transformer.decode_step(params, cfg, cache, token)

    jitted = jax.jit(
        serve_step,
        in_shardings=(
            _ns(mesh, pspecs),
            _ns(mesh, cache_specs),
            NamedSharding(mesh, token_spec),
        ),
        donate_argnums=(1,),
    )
    return Cell(
        jitted, (params_abs, cache_abs, token_abs), cfg, dict(kind=kind, B=B, S=S)
    )
