"""Production training driver.

Fault-tolerance behaviors (exercised by tests/test_checkpoint.py):
  * resume-from-latest on start (idempotent restarts — preemption safe),
  * async checkpointing every ``--ckpt-every`` steps (atomic commit),
  * elastic restore: the checkpoint stores logical PartitionSpecs, so the
    same command line restores onto a different ``--mesh`` after rescale,
  * the data iterator step rides in the checkpoint manifest.

Usage (CPU example, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.checkpoint import CheckpointManager
from repro.data import DataState, make_batch_iterator
from repro.launch.mesh import make_host_mesh
from repro.models.model import get_config
from repro.optim import AdamWState
from repro.train import TrainState, make_train_step, train_state_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="dataxmodel, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_host_mesh(d, m)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    cfg = cfg.padded(int(mesh.shape["model"]))

    rng = jax.random.PRNGKey(args.seed)
    state = train_state_init(rng, cfg)
    pspecs = sh.param_specs(cfg, state.params, int(mesh.shape["model"]))
    state_specs = TrainState(
        params=pspecs, opt=AdamWState(step=P(), m=pspecs, v=pspecs)
    )
    def ns(t):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
        )
    with mesh:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            state,
            state_specs,
            is_leaf=lambda x: not isinstance(x, (dict, TrainState, AdamWState)),
        )

    data_state = DataState(seed=args.seed)
    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step0, restored, extra = mgr.restore_latest(
            state, mesh=mesh, specs=state_specs
        )
        if step0 is not None:
            state, start_step = restored, step0
            data_state.next_step = extra.get("data_step", step0)
            print(f"resumed from step {step0}")

    it = make_batch_iterator(cfg.vocab_size, args.seq, args.batch, state=data_state)
    step_fn = make_train_step(
        cfg,
        lr=args.lr,
        total_steps=args.steps,
        loss_chunk=min(512, args.seq),
    )
    batch_sharding = {
        "tokens": NamedSharding(mesh, P(sh.data_axes(mesh))),
        "targets": NamedSharding(mesh, P(sh.data_axes(mesh))),
    }
    with mesh:
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        for step, batch in it:
            if step >= args.steps:
                break
            batch = {
                k: jax.device_put(v, batch_sharding[k]) for k, v in batch.items()
            }
            state, metrics = jstep(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {step:5d} loss {loss:.4f} ({dt:.1f}s)", flush=True)
            if mgr and step and step % args.ckpt_every == 0:
                mgr.save(
                    step,
                    state,
                    specs=state_specs,
                    extra={"data_step": data_state.next_step},
                )
        if mgr:
            mgr.save(
                args.steps,
                state,
                specs=state_specs,
                extra={"data_step": data_state.next_step},
            )
            mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
