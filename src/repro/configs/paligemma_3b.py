"""paligemma-3b [vlm] — SigLIP (stub) + gemma backbone [arXiv:2407.07726; hf].

The vision tower is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings ([B, 256, d_model]); the backbone applies a
bidirectional prefix mask over them (PaliGemma's prefix-LM attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    attention="full",
    frontend="vision_stub",
    frontend_len=256,
    rope_theta=10_000.0,
)
