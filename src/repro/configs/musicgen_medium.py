"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

EnCodec is a STUB per the assignment: input_specs() supplies precomputed
conditioning frame embeddings as a prefix; the decoder operates on the
audio-token stream (vocab 2048).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    attention="full",
    frontend="audio_stub",
    frontend_len=64,
    rope_theta=10_000.0,
)
