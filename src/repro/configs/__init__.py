"""Assigned-architecture configs (one module per arch) + registry."""

from repro.configs import (
    deepseek_moe_16b,
    gemma3_12b,
    h2o_danube_3_4b,
    mamba2_1_3b,
    mixtral_8x22b,
    musicgen_medium,
    paligemma_3b,
    qwen2_5_32b,
    starcoder2_15b,
    zamba2_2_7b,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_5_32b,
        starcoder2_15b,
        h2o_danube_3_4b,
        gemma3_12b,
        deepseek_moe_16b,
        mixtral_8x22b,
        zamba2_2_7b,
        paligemma_3b,
        mamba2_1_3b,
        musicgen_medium,
    )
}


def get(name: str):
    return REGISTRY[name]
