"""gemma3-12b [dense] — 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=240,
    attention="local_global",
    local_global_ratio=5,
    window=1024,
    rope_theta=1_000_000.0,
)
