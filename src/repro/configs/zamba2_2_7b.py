"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    attention="full",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    rope_theta=10_000.0,
)
