"""Deterministic, resumable, sharded synthetic data pipeline."""

from repro.data.pipeline import DataState, SyntheticLM, make_batch_iterator
