"""Synthetic LM data pipeline.

Production properties the trainer depends on:
  * **Deterministic**: batch ``i`` is a pure function of (seed, i) — any
    host can regenerate any step, so restarts need no data server handshake.
  * **Resumable**: iterator state is one integer (next step), stored in the
    checkpoint manifest.
  * **Sharded**: each data-parallel host generates only its slice (counter-
    based threefry keys, no cross-host coordination).

The synthetic stream is a Zipf-ish unigram mixture with a repeated-ngram
backbone, so cross-entropy drops measurably within a few hundred steps
(examples/train_lm.py) — enough signal to validate optimization end to end.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataState:
    seed: int
    next_step: int = 0


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0, ngram: int = 8):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.ngram = ngram
        # fixed "language": a bank of n-grams with zipfian unigrams
        rng = np.random.default_rng(seed)
        zipf_p = 1.0 / np.arange(1, vocab_size + 1) ** 1.1
        zipf_p /= zipf_p.sum()
        self.bank = rng.choice(vocab_size, size=(1024, ngram), p=zipf_p).astype(
            np.int32
        )

    def batch(self, step: int, batch_size: int, shard: int = 0, num_shards: int = 1):
        """Tokens for (step, shard): [batch_size // num_shards, seq_len]."""
        rng = np.random.default_rng((self.seed, step, shard))
        rows = batch_size // num_shards
        n_spans = self.seq_len // self.ngram + 1
        idx = rng.integers(0, self.bank.shape[0], size=(rows, n_spans))
        toks = self.bank[idx].reshape(rows, -1)[:, : self.seq_len]
        # sprinkle noise so the task isn't pure memorization
        noise = rng.integers(0, self.vocab_size, size=toks.shape)
        mask = rng.random(toks.shape) < 0.05
        return np.where(mask, noise, toks).astype(np.int32)


def make_batch_iterator(
    vocab_size: int,
    seq_len: int,
    batch_size: int,
    *,
    state: DataState,
    shard: int = 0,
    num_shards: int = 1,
):
    """Yields (step, batch_dict); advances ``state.next_step`` as it goes."""
    src = SyntheticLM(vocab_size, seq_len + 1, seed=state.seed)

    def gen():
        while True:
            step = state.next_step
            toks = src.batch(step, batch_size, shard, num_shards)
            state.next_step = step + 1
            yield step, {
                "tokens": jnp.asarray(toks[:, :-1]),
                "targets": jnp.asarray(toks[:, 1:]),
            }

    return gen()
