"""Deterministic, versioned serialization of ``FliXState`` (DESIGN.md §12).

The durability contract's unit of truth is the **canonical payload**: a
fixed little-endian header followed by the globally sorted live
``(key, value)`` pairs.  Two states with the same *logical* content —
regardless of physical chain layout, geometry, successor-cache presence,
restructure history, or which ``apply_ops`` executor produced them —
serialize to identical bytes (``tests/test_snapshot_determinism.py`` pins
this down).  Everything physical is excluded on purpose:

  * volatile fields (``succ_smin``/``succ_sidx``) are derived caches;
  * ``needs_restructure`` is transient overflow pressure (a recovered
    state is always restructure-clean by construction);
  * geometry / chain fragmentation is a performance artifact — it travels
    in the snapshot *manifest* as a rebuild hint, never in the payload.

Per-bucket **segments** are the incremental unit: bucket ``b``'s segment
is its live pairs in ascending key order.  Fence disjointness (invariant
I3) makes the in-order concatenation of all segments exactly the global
sorted pairs, so a full snapshot's payload *is* the canonical bytes and a
delta snapshot can replace individual bucket segments (DESIGN.md §12).

The header is versioned for schema evolution: readers reject unknown
magic/version instead of misparsing, and a future layout bumps
``FORMAT_VERSION`` while keeping old readers loud.
"""

from __future__ import annotations

import struct
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import build_from_sorted, plan_geometry
from repro.core.expiry import NO_EXPIRY
from repro.core.state import EMPTY, FliXState

MAGIC = b"FLIXSNP1"
MAGIC_DELTA = b"FLIXDLT1"
# v2 (DESIGN.md §14): the payload carries (key, value, expiry) TRIPLES — the
# expiry column is durable logical state (an all-NO_EXPIRY column for states
# without TTLs, so TTL-free payloads stay deterministic too).  v1 payloads
# (pairs) are rejected loudly: no v1 data is retained anywhere.
FORMAT_VERSION = 2
_HEADER = struct.Struct("<8sII")  # magic, version, n_pairs (delta: n_buckets)
HEADER_SIZE = _HEADER.size

_LE32 = np.dtype("<i4")


class SnapshotFormatError(RuntimeError):
    """Raised when canonical bytes fail structural validation."""


def bucket_segments(state: FliXState, buckets=None):
    """Canonical per-bucket segments, host-side.

    Returns ``(lens, seg_keys, seg_vals, seg_exps)``: ``lens[i]`` live
    triples for the ``i``-th requested bucket, with the segments
    concatenated in request order (little-endian int32, each segment
    ascending by key).  States without an expiry column yield an
    all-``NO_EXPIRY`` ``seg_exps`` — logically identical, so the canonical
    bytes do not depend on whether the column is materialized.
    ``buckets=None`` selects every bucket in fence order — the device
    transfer then is O(index); an explicit dirty list fetches only those
    rows, so incremental snapshot cost is O(churn).

    ``state`` may also be a host-side view with numpy array attributes
    (``core.residency.TieredFliX.host_view()``): the canonicalization is
    identical and no device transfer happens at all — a tiered index
    snapshots without ever materializing on device.
    """
    keys, vals, exps = state.keys, state.vals, state.exps
    if buckets is not None:
        if isinstance(keys, np.ndarray):
            sel = np.asarray(buckets, np.int64)
        else:
            sel = jnp.asarray(np.asarray(buckets, np.int32))
        keys, vals = keys[sel], vals[sel]
        exps = None if exps is None else exps[sel]
    k = np.asarray(jax.device_get(keys))
    v = np.asarray(jax.device_get(vals))
    e = (
        np.full_like(k, int(NO_EXPIRY))
        if exps is None
        else np.asarray(jax.device_get(exps))
    )
    d = k.shape[0]
    k = k.reshape(d, -1)
    v = v.reshape(d, -1)
    e = e.reshape(d, -1)
    # chain order (I1+I2) is ascending apart from interior EMPTY padding, so
    # one stable per-row sort canonicalizes: EMPTY (int32 max) lands at the
    # row tail and the live prefix is the bucket's sorted segment
    order = np.argsort(k, axis=1, kind="stable")
    ks = np.take_along_axis(k, order, axis=1)
    vs = np.take_along_axis(v, order, axis=1)
    es = np.take_along_axis(e, order, axis=1)
    mask = ks != EMPTY
    lens = mask.sum(axis=1).astype(np.int32)
    # row-major boolean selection preserves (bucket, ascending-key) order
    return lens, ks[mask].astype(_LE32), vs[mask].astype(_LE32), es[mask].astype(_LE32)


def segment_crcs(lens, seg_keys, seg_vals, seg_exps) -> list[int]:
    """crc32 per bucket segment (keys ++ vals ++ exps bytes) — the
    manifest's per-bucket integrity words, updatable at dirty indices only."""
    out = []
    off = 0
    kb = np.ascontiguousarray(seg_keys)
    vb = np.ascontiguousarray(seg_vals)
    eb = np.ascontiguousarray(seg_exps)
    for n in np.asarray(lens, np.int64):
        chunk = (
            kb[off : off + n].tobytes()
            + vb[off : off + n].tobytes()
            + eb[off : off + n].tobytes()
        )
        out.append(zlib.crc32(chunk))
        off += int(n)
    return out


def pairs_to_bytes(seg_keys, seg_vals, seg_exps=None) -> bytes:
    """Frame sorted live triples as the canonical payload (``seg_exps=None``
    writes the all-NO_EXPIRY column)."""
    ks = np.ascontiguousarray(np.asarray(seg_keys, _LE32))
    vs = np.ascontiguousarray(np.asarray(seg_vals, _LE32))
    es = (
        np.full_like(ks, int(NO_EXPIRY))
        if seg_exps is None
        else np.ascontiguousarray(np.asarray(seg_exps, _LE32))
    )
    if ks.shape != vs.shape or ks.shape != es.shape or ks.ndim != 1:
        raise SnapshotFormatError("keys/vals/exps must be aligned 1-D arrays")
    return (
        _HEADER.pack(MAGIC, FORMAT_VERSION, ks.size)
        + ks.tobytes()
        + vs.tobytes()
        + es.tobytes()
    )


def canonical_state_bytes(state: FliXState) -> bytes:
    """THE deterministic serialization: header + sorted live triples."""
    _, seg_keys, seg_vals, seg_exps = bucket_segments(state)
    return pairs_to_bytes(seg_keys, seg_vals, seg_exps)


def state_digest(state: FliXState) -> str:
    """crc32 (hex) of the canonical payload — a cheap logical-state id."""
    return f"{zlib.crc32(canonical_state_bytes(state)):08x}"


def parse_canonical(data: bytes):
    """Decode a canonical payload back to ``(keys, vals, exps)`` numpy
    arrays, validating the header and framing (strict: trailing bytes
    reject)."""
    if len(data) < HEADER_SIZE:
        raise SnapshotFormatError("payload shorter than header")
    magic, version, n = _HEADER.unpack_from(data)
    if magic != MAGIC:
        raise SnapshotFormatError(f"bad magic {magic!r}")
    if version != FORMAT_VERSION:
        raise SnapshotFormatError(f"unsupported format version {version}")
    need = HEADER_SIZE + 3 * 4 * n
    if len(data) != need:
        raise SnapshotFormatError(f"payload length {len(data)} != {need}")
    keys = np.frombuffer(data, dtype=_LE32, count=n, offset=HEADER_SIZE)
    vals = np.frombuffer(data, dtype=_LE32, count=n, offset=HEADER_SIZE + 4 * n)
    exps = np.frombuffer(data, dtype=_LE32, count=n, offset=HEADER_SIZE + 8 * n)
    if n and not (np.diff(keys.astype(np.int64)) > 0).all():
        raise SnapshotFormatError("canonical keys must be strictly ascending")
    return keys.copy(), vals.copy(), exps.copy()


def pack_delta(bucket_idx, lens, seg_keys, seg_vals, seg_exps=None) -> bytes:
    """Frame a dirty-bucket diff: which buckets changed, their new segment
    lengths, and the replacement segments (concatenated in ``bucket_idx``
    order).  Same header discipline as the full payload."""
    bi = np.ascontiguousarray(np.asarray(bucket_idx, _LE32))
    ln = np.ascontiguousarray(np.asarray(lens, _LE32))
    ks = np.ascontiguousarray(np.asarray(seg_keys, _LE32))
    vs = np.ascontiguousarray(np.asarray(seg_vals, _LE32))
    es = (
        np.full_like(ks, int(NO_EXPIRY))
        if seg_exps is None
        else np.ascontiguousarray(np.asarray(seg_exps, _LE32))
    )
    if bi.shape != ln.shape or bi.ndim != 1 or ks.shape != vs.shape:
        raise SnapshotFormatError("malformed delta arrays")
    if ks.shape != es.shape:
        raise SnapshotFormatError("malformed delta expiry column")
    if int(ln.sum()) != ks.size:
        raise SnapshotFormatError("delta lens do not cover the segments")
    return (
        _HEADER.pack(MAGIC_DELTA, FORMAT_VERSION, bi.size)
        + bi.tobytes()
        + ln.tobytes()
        + ks.tobytes()
        + vs.tobytes()
        + es.tobytes()
    )


def parse_delta(data: bytes):
    """Inverse of :func:`pack_delta` → ``(bucket_idx, lens, keys, vals,
    exps)``."""
    if len(data) < HEADER_SIZE:
        raise SnapshotFormatError("delta payload shorter than header")
    magic, version, d = _HEADER.unpack_from(data)
    if magic != MAGIC_DELTA:
        raise SnapshotFormatError(f"bad delta magic {magic!r}")
    if version != FORMAT_VERSION:
        raise SnapshotFormatError(f"unsupported format version {version}")
    if len(data) < HEADER_SIZE + 8 * d:
        raise SnapshotFormatError("delta payload truncated")
    bi = np.frombuffer(data, _LE32, d, HEADER_SIZE)
    ln = np.frombuffer(data, _LE32, d, HEADER_SIZE + 4 * d)
    n = int(ln.sum())
    need = HEADER_SIZE + 8 * d + 12 * n
    if len(data) != need:
        raise SnapshotFormatError(f"delta payload length {len(data)} != {need}")
    ks = np.frombuffer(data, _LE32, n, HEADER_SIZE + 8 * d)
    vs = np.frombuffer(data, _LE32, n, HEADER_SIZE + 8 * d + 4 * n)
    es = np.frombuffer(data, _LE32, n, HEADER_SIZE + 8 * d + 8 * n)
    return bi.copy(), ln.copy(), ks.copy(), vs.copy(), es.copy()


def state_from_pairs(
    keys,
    vals,
    exps=None,
    *,
    node_size: int = 32,
    nodes_per_bucket: int = 16,
    fill: float = 0.5,
) -> FliXState:
    """Deterministically rebuild a half-full state from sorted live triples.

    The geometry hint (node_size/nodes_per_bucket/fill) comes from the
    snapshot manifest; the bucket count is re-planned from the live count
    (never taken from the manifest — the snapshotted structure may have
    been fuller than ``fill``, and ``build_from_sorted`` requires the
    planned headroom).

    An ``exps`` column that is entirely ``NO_EXPIRY`` (or ``None``)
    rebuilds a state with no materialized expiry column — logically
    identical (the canonical bytes do not distinguish the two), and keeps
    TTL-free recovery on the legacy zero-overhead engine path.
    """
    keys = np.asarray(keys, np.int32)
    vals = np.asarray(vals, np.int32)
    if exps is not None:
        exps = np.asarray(exps, np.int32)
        if not (exps != int(NO_EXPIRY)).any():
            exps = None
    nb, npb, ns = plan_geometry(
        len(keys), node_size=node_size, nodes_per_bucket=nodes_per_bucket, fill=fill
    )
    # quantize the bucket count (next multiple of 8, only ever more
    # headroom) so nearby live counts rebuild into the SAME static shapes
    # — recovery after similar-sized crashes reuses the jit cache instead
    # of recompiling per replanned geometry
    nb = -(-nb // 8) * 8
    built = build_from_sorted(
        jnp.asarray(keys),
        jnp.asarray(vals),
        num_buckets=nb,
        nodes_per_bucket=npb,
        node_size=ns,
        fill=fill,
    )
    if exps is None:
        return built
    import dataclasses

    built_e = build_from_sorted(
        jnp.asarray(keys),
        jnp.asarray(exps),
        num_buckets=nb,
        nodes_per_bucket=npb,
        node_size=ns,
        fill=fill,
    )
    col = jnp.where(built.keys == EMPTY, NO_EXPIRY, built_e.vals)
    return dataclasses.replace(built, exps=col)
