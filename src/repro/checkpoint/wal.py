"""Write-ahead op log: checksummed record framing + torn-tail recovery.

Each engine batch is framed and appended *before* ``apply_ops`` runs
(DESIGN.md §12).  Record layout, all little-endian:

    u32 magic  u64 seq  u32 payload_len  u32 crc32(payload)  payload

The payload is the host-encoded sorted ``OpBatch`` plus its impl-relevant
parameters (``max_results``), so replay re-executes byte-for-byte the
batch that was logged.  Appends go through raw ``os.write`` (no userspace
buffering) and are fsynced before the engine sees the batch — the fsync
return is the durability boundary: an acknowledged op survives any
subsequent crash.

``fsync=False`` deliberately REMOVES that boundary: frames accumulate in
a userspace buffer and reach the filesystem only on rotate/close.  On a
real power failure the un-fsynced page cache is what gets lost; the
userspace buffer reproduces exactly that loss under a plain process
kill, which is how the negative crash-injection tests demonstrate the
suite catches a WAL without a durability boundary.

The log is segmented (``wal_<startseq>.log``, rotated at snapshots) so
retention can drop whole files once a full snapshot covers them.  Replay
tolerates exactly one torn region — an incomplete or checksum-failing
record at the physical tail of the newest segment (a crash mid-append) —
and truncates it; corruption anywhere else is never silently skipped.
"""

from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path

import numpy as np

REC_MAGIC = 0x464C5857  # "FLXW"
_REC_HEADER = struct.Struct("<IQII")  # magic, seq, payload_len, crc32(payload)
REC_HEADER_SIZE = _REC_HEADER.size

_OPS_HEADER = struct.Struct("<II")  # n_ops, max_results
_META_LEN = struct.Struct("<I")  # optional trailing metadata blob length
_LE32 = np.dtype("<i4")

# High bit of the n_ops header word flags the TTL record form: the payload
# additionally carries the batch's virtual clock (one i64 word, sentinel
# ``_NO_NOW`` when the batch ran without an expire pass) and a fourth
# per-op array of expiry deadlines.  Records written without TTL state are
# byte-identical to the pre-§14 framing, so old logs replay unchanged.
_TTL_BIT = 0x80000000
_NOW_WORD = struct.Struct("<q")
_NO_NOW = 2**63 - 1

_SEG_PREFIX = "wal_"
_SEG_SUFFIX = ".log"


class WALCorruptionError(RuntimeError):
    """Unrecoverable log damage (non-tail corruption, or a torn tail with
    truncation disabled)."""


def _noop_hook(event: str) -> None:
    return None


def write_all(fd: int, data) -> None:
    """``os.write`` until every byte lands: a short write that got fsynced
    and acknowledged would become non-tail corruption on the next append,
    which replay refuses wholesale."""
    view = memoryview(data)
    while len(view):
        view = view[os.write(fd, view) :]


def encode_ops(
    tag, key, val, max_results: int, meta: bytes = b"", *, exp=None, now=None
) -> bytes:
    """Frame one sorted batch (host arrays) as a WAL record payload.

    ``meta`` is an opaque caller blob logged WITH the batch — same fsync,
    same crc — so replay hands it back alongside the ops.  The serving
    gateway stores the batch's idempotency keys here: a request is durably
    deduplicable exactly iff its batch is durably replayable (DESIGN.md
    §13).  A record without the trailing length word (pre-§13 history)
    decodes with ``meta = b""``.

    ``exp``/``now`` select the TTL record form (``_TTL_BIT``): the batch's
    per-op expiry deadlines and the virtual clock it executed under are
    logged so replay is time-deterministic — it re-runs each batch at the
    exact ``now`` the live engine used, never the replayer's wall clock.
    With both ``None`` the encoding is byte-identical to the legacy form.
    """
    t = np.ascontiguousarray(np.asarray(tag, _LE32))
    k = np.ascontiguousarray(np.asarray(key, _LE32))
    v = np.ascontiguousarray(np.asarray(val, _LE32))
    if not (t.shape == k.shape == v.shape) or t.ndim != 1:
        raise ValueError("tag/key/val must be aligned 1-D arrays")
    if exp is None and now is None:
        out = (
            _OPS_HEADER.pack(t.size, max_results)
            + t.tobytes()
            + k.tobytes()
            + v.tobytes()
        )
    else:
        if exp is None:
            raise ValueError("TTL record form requires an exp column")
        e = np.ascontiguousarray(np.asarray(exp, _LE32))
        if e.shape != t.shape:
            raise ValueError("exp must align with tag/key/val")
        out = (
            _OPS_HEADER.pack(t.size | _TTL_BIT, max_results)
            + _NOW_WORD.pack(_NO_NOW if now is None else int(now))
            + t.tobytes()
            + k.tobytes()
            + v.tobytes()
            + e.tobytes()
        )
    if meta:
        out += _META_LEN.pack(len(meta)) + meta
    return out


def decode_ops(payload: bytes):
    """Inverse of :func:`encode_ops` →
    ``(tag, key, val, max_results, meta, exp, now)``.

    Legacy (non-TTL) records decode with ``exp is None`` and ``now is
    None``; TTL records yield the logged expiry column and the virtual
    clock (``None`` if the batch ran without an expire pass).
    """
    if len(payload) < _OPS_HEADER.size:
        raise WALCorruptionError("op record shorter than its header")
    raw_n, max_results = _OPS_HEADER.unpack_from(payload)
    has_ttl = bool(raw_n & _TTL_BIT)
    n = raw_n & ~_TTL_BIT
    off = _OPS_HEADER.size
    now = None
    if has_ttl:
        if len(payload) < off + _NOW_WORD.size:
            raise WALCorruptionError("TTL op record missing its clock word")
        (now_raw,) = _NOW_WORD.unpack_from(payload, off)
        now = None if now_raw == _NO_NOW else int(now_raw)
        off += _NOW_WORD.size
    cols = 4 if has_ttl else 3
    need = off + cols * 4 * n
    if len(payload) == need:
        meta = b""
    elif len(payload) >= need + _META_LEN.size:
        (mlen,) = _META_LEN.unpack_from(payload, need)
        if len(payload) != need + _META_LEN.size + mlen:
            raise WALCorruptionError(
                f"op record metadata length {len(payload) - need} != {mlen}"
            )
        meta = payload[need + _META_LEN.size :]
    else:
        raise WALCorruptionError(f"op record length {len(payload)} != {need}")
    tag = np.frombuffer(payload, _LE32, n, off).copy()
    key = np.frombuffer(payload, _LE32, n, off + 4 * n).copy()
    val = np.frombuffer(payload, _LE32, n, off + 8 * n).copy()
    exp = np.frombuffer(payload, _LE32, n, off + 12 * n).copy() if has_ttl else None
    return tag, key, val, int(max_results), meta, exp, now


def segment_files(directory) -> list[tuple[int, Path]]:
    """(start_seq, path) for every segment, ascending by start seq."""
    out = []
    for p in Path(directory).glob(f"{_SEG_PREFIX}*{_SEG_SUFFIX}"):
        try:
            start = int(p.name[len(_SEG_PREFIX) : -len(_SEG_SUFFIX)])
        except ValueError:
            continue
        out.append((start, p))
    return sorted(out)


class WriteAheadLog:
    """Appender for the segmented op log (one per durable instance)."""

    def __init__(self, directory, *, fsync: bool = True, crash_hook=None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = fsync
        self._hook = crash_hook or _noop_hook
        self._fd: int | None = None
        self._buffer = bytearray()

    # -- segment lifecycle ------------------------------------------------
    def open_segment(self, start_seq: int, *, path: Path | None = None) -> None:
        """Start appending to ``wal_<start_seq>.log`` (or reopen ``path``,
        e.g. the recovered newest segment after tail truncation)."""
        self.close()
        target = path or self.dir / f"{_SEG_PREFIX}{start_seq:012d}{_SEG_SUFFIX}"
        self._fd = os.open(target, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        self._fsync_dir()

    def rotate(self, start_seq: int) -> None:
        """Flush + close the current segment and start a fresh one."""
        self.open_segment(start_seq)

    def close(self) -> None:
        if self._fd is None:
            return
        if self._buffer:
            write_all(self._fd, bytes(self._buffer))
            self._buffer.clear()
        os.fsync(self._fd)
        os.close(self._fd)
        self._fd = None

    # -- the append path --------------------------------------------------
    def append(self, seq: int, payload: bytes) -> None:
        """Frame and durably append one record; returns only after the
        record is fsynced (``fsync=True``) — the ack/durability boundary."""
        if self._fd is None:
            raise RuntimeError("no open WAL segment (call open_segment first)")
        frame = (
            _REC_HEADER.pack(REC_MAGIC, seq, len(payload), zlib.crc32(payload))
            + payload
        )
        if not self.fsync:
            # negative-test mode: no durability boundary — a crash loses the
            # whole buffered run of acked records (see module docstring)
            self._buffer += frame
            self._hook("wal.append.buffered")
            return
        # two writes on purpose: the crash hook between them lets the fault
        # harness materialize a genuinely torn (half-written) record
        split = REC_HEADER_SIZE + len(payload) // 2
        write_all(self._fd, frame[:split])
        self._hook("wal.append.partial")
        write_all(self._fd, frame[split:])
        self._hook("wal.append.written")
        os.fsync(self._fd)
        self._hook("wal.append.durable")

    def tell(self) -> int:
        """End offset of the active segment, buffered frames included —
        the rollback point for :meth:`truncate_to`."""
        if self._fd is None:
            raise RuntimeError("no open WAL segment (call open_segment first)")
        return os.fstat(self._fd).st_size + len(self._buffer)

    def truncate_to(self, offset: int) -> None:
        """Roll the active segment back to ``offset``, undoing appends made
        after it.  The one legitimate caller is ``DurableFliX.apply`` when
        the engine fails AFTER the WAL ack: the logged-but-never-executed
        record must not survive into the durable history."""
        if self._fd is None:
            raise RuntimeError("no open WAL segment (call open_segment first)")
        size = os.fstat(self._fd).st_size
        if offset >= size:
            del self._buffer[offset - size :]
            return
        self._buffer.clear()
        os.ftruncate(self._fd, offset)
        os.fsync(self._fd)

    def _fsync_dir(self) -> None:
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)


def replay(directory, *, after_seq: int = 0, truncate_torn: bool = True):
    """Scan every segment in order → list of ``(seq, payload)`` records
    with ``seq > after_seq``.

    A torn tail — an incomplete frame or checksum-failing record at the
    physical end of the NEWEST segment — is the signature of a crash
    mid-append; it is truncated in place (and fsynced) so recovery is
    idempotent, or raises :class:`WALCorruptionError` when
    ``truncate_torn=False``.  Damage anywhere else (a bad record followed
    by readable ones, or in an older segment) always raises: that is
    storage corruption, not a crash artifact, and silently skipping it
    would replay a wrong history.
    """
    segs = segment_files(directory)
    records: list[tuple[int, bytes]] = []
    last_seq = None
    for si, (start, path) in enumerate(segs):
        data = path.read_bytes()
        off = 0
        while off < len(data):
            # a crash mid-append leaves a PREFIX of one valid frame reaching
            # the physical EOF of the newest segment — that, and only that,
            # is a tear.  A damaged record with readable bytes after it (or
            # in an older segment) is storage corruption.
            reason, is_tear, seq = None, False, None
            if off + REC_HEADER_SIZE > len(data):
                reason, is_tear = "incomplete record header", True
            else:
                magic, seq, plen, crc = _REC_HEADER.unpack_from(data, off)
                frame_end = off + REC_HEADER_SIZE + plen
                if magic != REC_MAGIC:
                    reason = f"bad record magic 0x{magic:08x}"
                elif frame_end > len(data):
                    reason, is_tear = "incomplete record payload", True
                else:
                    payload = data[off + REC_HEADER_SIZE : frame_end]
                    if zlib.crc32(payload) != crc:
                        reason = "record checksum mismatch"
                        is_tear = frame_end == len(data)
            if reason is not None:
                is_tear = is_tear and si == len(segs) - 1
                if is_tear and truncate_torn:
                    fd = os.open(path, os.O_WRONLY)
                    try:
                        os.ftruncate(fd, off)
                        os.fsync(fd)
                    finally:
                        os.close(fd)
                    break
                raise WALCorruptionError(
                    f"{path.name} @ {off}: {reason}"
                    + (" (torn tail; truncation disabled)" if is_tear else "")
                )
            if last_seq is not None and seq <= last_seq:
                raise WALCorruptionError(
                    f"{path.name} @ {off}: seq {seq} not increasing "
                    f"(previous {last_seq})"
                )
            last_seq = seq
            if seq > after_seq:
                records.append((seq, payload))
            off += REC_HEADER_SIZE + len(payload)
    return records
