"""Fault-tolerant checkpointing: generic pytree save/restore plus the
engine-aware durable FliX layer (deterministic snapshots + WAL)."""

from repro.checkpoint.durable import (
    DurableFliX,
    EngineBase,
    LocalEngine,
    ShardEngine,
    SnapshotCorruptionError,
    TieredEngine,
    load_snapshot_chain,
)
from repro.checkpoint.manager import (
    CheckpointManager,
    restore_pytree,
    save_pytree,
    tmp_sibling,
)
from repro.checkpoint.serialize import (
    SnapshotFormatError,
    canonical_state_bytes,
    parse_canonical,
    state_digest,
    state_from_pairs,
)
from repro.checkpoint.wal import WALCorruptionError, WriteAheadLog, replay
