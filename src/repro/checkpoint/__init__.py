"""Fault-tolerant checkpointing (save/restore, async, elastic reshard)."""

from repro.checkpoint.manager import CheckpointManager, restore_pytree, save_pytree
