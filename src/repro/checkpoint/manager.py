"""Checkpoint manager: step-granular, atomic, async, elastic.

Fault-tolerance contract (DESIGN.md §6):
  * **atomic commit** — writes go to ``step_XXXX.tmp/`` and are renamed into
    place only after every array + the manifest are fsynced; a crash
    mid-save never corrupts the latest good checkpoint.
  * **async** — ``save(...)`` returns immediately (single writer thread,
    newest-wins queue); the training loop never blocks on I/O.
  * **elastic restore** — arrays are stored *unsharded* (gathered) with
    their logical PartitionSpecs in the manifest; restore takes the *new*
    mesh and re-device_puts with NamedSharding, so a 256-chip checkpoint
    restores onto 512 chips (or a 1-chip dev box) unchanged.
  * **resumable data** — the manifest carries the data-iterator step and
    anything else the caller puts in ``extra``.
  * retention — keeps the last ``keep`` checkpoints, deletes older ones.
"""

from __future__ import annotations

import itertools
import json
import os
import queue
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_TMP_COUNTER = itertools.count()


def tmp_sibling(path: Path) -> Path:
    """A unique scratch sibling for atomic directory commits.

    ``path.with_suffix(".tmp")`` mangles dotted names (``step_0.5k`` →
    ``step_0.tmp``) and collides across concurrent savers; appending a
    ``.tmp-<pid>-<counter>`` suffix to the *full* name does neither.  Names
    containing ``.tmp`` are skipped by every directory listing here, so an
    abandoned scratch dir from a crashed save is inert until its owner (or
    a fresh save of the same target) cleans it up.
    """
    path = Path(path)
    return path.parent / f"{path.name}.tmp-{os.getpid()}-{next(_TMP_COUNTER)}"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(path: Path, tree, *, specs=None, extra: dict | None = None):
    """Synchronous atomic save of a pytree (+ optional PartitionSpecs)."""
    path = Path(path)
    tmp = tmp_sibling(path)
    tmp.mkdir(parents=True)
    try:
        names, leaves, _ = _flatten_with_names(tree)
        arrays = {}
        for i, leaf in enumerate(leaves):
            arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "names": names,
            "extra": extra or {},
            "specs": None,
        }
        if specs is not None:
            _, spec_leaves, _ = _flatten_with_names(specs)
            manifest["specs"] = [repr(s) for s in spec_leaves]
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if path.exists():
            shutil.rmtree(path)
        os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore_pytree(path: Path, like, *, mesh=None, specs=None):
    """Restore into the structure of ``like``; reshard onto ``mesh``/``specs``
    if given (elastic restore onto any mesh)."""
    path = Path(path)
    with open(path / "manifest.json") as f:
        manifest = json.load(f)
    data = np.load(path / "arrays.npz")
    names, leaves, treedef = _flatten_with_names(like)
    assert names == manifest["names"], "checkpoint/model structure mismatch"
    arrays = [data[f"a{i}"] for i in range(len(leaves))]
    restored = jax.tree_util.tree_unflatten(treedef, arrays)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding

        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            restored,
            specs,
            is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)),
        )
    return restored, manifest["extra"]


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._error: Exception | None = None

    # -- async API -----------------------------------------------------
    def save(self, step: int, tree, *, specs=None, extra: dict | None = None):
        """Enqueue an async save; newest request wins if the writer lags."""
        if self._error:
            raise self._error
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        try:
            self._q.put_nowait((step, host_tree, specs, extra))
        except queue.Full:
            try:
                self._q.get_nowait()  # drop the stale pending save
            except queue.Empty:
                pass
            else:
                # the dropped item still counts toward join(); without this
                # a wait() after any superseded save deadlocks
                self._q.task_done()
            self._q.put_nowait((step, host_tree, specs, extra))

    def wait(self):
        self._q.join()
        if self._error:
            raise self._error

    def _run(self):
        while True:
            step, tree, specs, extra = self._q.get()
            try:
                save_pytree(
                    self.dir / f"step_{step:08d}", tree, specs=specs, extra=extra
                )
                self._gc()
            except Exception as e:  # noqa: BLE001 — surface on next call
                self._error = e
            finally:
                self._q.task_done()

    # -- sync API --------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and ".tmp" not in p.name
        )
        return steps[-1] if steps else None

    def restore_latest(self, like, *, mesh=None, specs=None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = restore_pytree(
            self.dir / f"step_{step:08d}", like, mesh=mesh, specs=specs
        )
        return step, tree, extra

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*") if ".tmp" not in p.name)
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)
