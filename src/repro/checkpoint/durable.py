"""Engine-aware durable persistence for a FliX index (DESIGN.md §12).

Commit protocol, per engine batch (WAL-ahead):

  1. frame + append the sorted ``OpBatch`` (with its ``max_results``) to
     the write-ahead log and fsync — the batch is durable *before* the
     engine runs it;
  2. execute it (``apply_ops`` / ``shard_apply_ops`` behind an engine
     adapter, restructure-and-retry included);
  3. fold the batch's update keys into the dirty-bucket set (fences are
     fixed between restructures, so host-side ``searchsorted`` routing is
     exact); a restructure bumps the *fence epoch* and dirties everything;
  4. every ``snapshot_every`` batches, write a snapshot — a dirty-bucket
     delta within an epoch, a full canonical payload after an epoch bump
     or every ``full_every``-th snapshot.

Snapshots are atomic (unique tmp sibling dir, fsync, rename, dir fsync)
and *canonical* (``checkpoint.serialize``): the same logical index always
produces the same payload bytes, so restructures and shard rebalances are
logical no-ops that never need WAL entries of their own.

Recovery (resumable, idempotent):

  1. load the newest crc-verified snapshot chain (full + deltas);
  2. truncate the WAL's torn tail (a crash mid-append);
  3. replay every logged batch after the snapshot through the engine;
  4. reopen the WAL for append — the instance continues exactly where the
     durable history ends.

Crashing *during* recovery is safe: its only write is the idempotent
tail truncation.  ``crash_hook`` is the fault-injection seam — the named
events in ``WriteAheadLog.append`` / ``DurableFliX.snapshot`` are where
``tests/fault_injection.py`` kills the process.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import wal as wal_mod
from repro.checkpoint.manager import tmp_sibling
from repro.checkpoint.serialize import (
    bucket_segments,
    pack_delta,
    pairs_to_bytes,
    parse_canonical,
    parse_delta,
    segment_crcs,
    state_from_pairs,
)
from repro.checkpoint.wal import WriteAheadLog, decode_ops, encode_ops
from repro.core.config import _UNSET, ExecConfig, resolve_config
from repro.core.expiry import NO_EXPIRY
from repro.core.ops import (
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OpBatch,
    apply_ops,
)
from repro.core.restructure import restructure_grow
from repro.core.state import EMPTY

SNAP_FORMAT = "flix-durable-v1"
_SNAP_PREFIX = "snap_"


class SnapshotCorruptionError(RuntimeError):
    """A snapshot failed structural or checksum validation at load."""


def _noop_hook(event: str) -> None:
    return None


# ---------------------------------------------------------------------------
# engine adapters: one batch in, (new handle, results, stats, restructured)
# ---------------------------------------------------------------------------


class EngineBase:
    """Shared engine surface the durability layer talks to.

    Beyond ``rebuild``/``flix``/``apply``, the durable wrapper needs four
    read-only views of the handle.  The defaults go through ``flix()`` (a
    full device state) — correct for the single-device and sharded engines,
    whose handle IS device-resident.  The tiered engine overrides every one
    with host-tier implementations so that durability never forces the full
    index onto the device (DESIGN.md §15: snapshots and recovery are
    tier-oblivious).
    """

    def mkba_host(self, handle) -> np.ndarray:
        """The fence array as host numpy (dirty-bucket routing)."""
        return np.asarray(self.flix(handle).mkba)

    def geometry(self, handle) -> tuple[int, int, int]:
        """(num_buckets, nodes_per_bucket, node_size) of the handle."""
        return self.flix(handle).geometry

    def segments(self, handle, buckets=None):
        """Canonical per-bucket segments (``serialize.bucket_segments``)."""
        return bucket_segments(self.flix(handle), buckets)

    def expired_buckets(self, handle, now) -> np.ndarray | None:
        """Bucket ids holding live rows with deadline ≤ now, or None when
        the state carries no expiry column (pre-apply dirty marking)."""
        pre = self.flix(handle)
        if now is None or pre.exps is None:
            return None
        hit = jnp.any((pre.exps <= jnp.int32(now)) & (pre.keys != EMPTY), axis=(1, 2))
        return np.nonzero(np.asarray(hit))[0]


class LocalEngine(EngineBase):
    """Single-device executor behind the durability layer.

    ``config`` carries the execution strategy (kernel pipeline, tiles, …)
    threaded to every inner ``apply_ops``; ``impl`` remains as a direct
    ctor knob and is folded into it.  The per-batch ``max_results`` is NOT
    part of this config — it is logged per WAL record so replay re-runs
    each batch under its own budget.
    """

    kind = "local"

    def __init__(
        self,
        *,
        impl: str = "auto",
        config: ExecConfig | None = None,
        node_size: int = 32,
        nodes_per_bucket: int = 16,
        fill: float = 0.5,
    ):
        self.config = config if config is not None else ExecConfig(impl=impl)
        if config is not None and impl != "auto":
            self.config = self.config.replace(impl=impl)
        self.impl = self.config.impl
        self.node_size = node_size
        self.nodes_per_bucket = nodes_per_bucket
        self.fill = fill

    def rebuild(self, keys, vals, exps=None, geometry: dict | None = None):
        g = geometry or {}
        return state_from_pairs(
            keys,
            vals,
            exps,
            node_size=g.get("node_size", self.node_size),
            nodes_per_bucket=g.get("nodes_per_bucket", self.nodes_per_bucket),
            fill=g.get("fill", self.fill),
        )

    def flix(self, handle):
        return handle

    def apply(self, handle, ops: OpBatch, *, max_results: int, now=None):
        """``apply_ops`` with the restructure-and-retry loop surfaced: the
        durability layer must KNOW when the fence epoch changed, so it
        drives the retry itself instead of calling ``apply_ops_safe``."""
        cfg = self.config.replace(max_results=max_results, donate=False)
        new, results, stats = apply_ops(handle, ops, config=cfg, now=now)
        restructured = False
        if bool(new.needs_restructure) and not bool(handle.needs_restructure):
            n_ins = int(jnp.sum((ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)))
            grown = restructure_grow(handle, extra_keys=max(n_ins, 1))
            new, results, stats = apply_ops(grown, ops, config=cfg, now=now)
            assert not bool(new.needs_restructure), "post-restructure overflow"
            restructured = True
        stats = dict(stats)
        stats["restructure_retries"] = int(restructured)
        return new, results, stats, restructured


class ShardEngine(EngineBase):
    """Sharded executor (``core.distributed``) behind the durability layer.

    The handle is a ``ShardedFliX``; rebuilds go through ``shard_build``
    (so recovery re-partitions fences from the recovered contents — the
    durable analogue of ``shard_restructure``), and the retry loop mirrors
    ``shard_apply_ops_safe`` while reporting the epoch bump.
    """

    kind = "sharded"

    def __init__(
        self,
        mesh,
        *,
        routing: str = "replicated",
        impl: str = "auto",
        config: ExecConfig | None = None,
        node_size: int = 32,
        nodes_per_bucket: int = 16,
        fill: float = 0.5,
    ):
        self.mesh = mesh
        self.config = (
            config if config is not None else ExecConfig(impl=impl, routing=routing)
        )
        if config is not None:
            if impl != "auto":
                self.config = self.config.replace(impl=impl)
            if routing != "replicated":
                self.config = self.config.replace(routing=routing)
        self.routing = self.config.routing
        self.impl = self.config.impl
        self.node_size = node_size
        self.nodes_per_bucket = nodes_per_bucket
        self.fill = fill

    def rebuild(self, keys, vals, exps=None, geometry: dict | None = None):
        from repro.core.distributed import shard_build

        g = geometry or {}
        if exps is not None:
            exps = np.asarray(exps, np.int32)
            if not (exps != int(NO_EXPIRY)).any():
                exps = None  # all-sentinel column ⇒ TTL-free rebuild
        return shard_build(
            jnp.asarray(np.asarray(keys, np.int32)),
            jnp.asarray(np.asarray(vals, np.int32)),
            self.mesh,
            node_size=g.get("node_size", self.node_size),
            nodes_per_bucket=g.get("nodes_per_bucket", self.nodes_per_bucket),
            fill=g.get("fill", self.fill),
            sorted_exps=None if exps is None else jnp.asarray(exps),
        )

    def flix(self, handle):
        return handle.state

    def apply(self, handle, ops: OpBatch, *, max_results: int, now=None):
        from repro.core.distributed import shard_apply_ops, shard_restructure

        cfg = self.config.replace(max_results=max_results, donate=False)
        new, results, stats = shard_apply_ops(handle, ops, self.mesh, config=cfg, now=now)
        restructured = False
        if bool(new.state.needs_restructure) and not bool(
            handle.state.needs_restructure
        ):
            n_ins = int(jnp.sum((ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)))
            grown = shard_restructure(handle, self.mesh, extra_keys=max(n_ins, 1))
            new, results, stats = shard_apply_ops(
                grown, ops, self.mesh, config=cfg, now=now
            )
            assert not bool(new.state.needs_restructure), "post-restructure overflow"
            restructured = True
        stats = dict(stats)
        stats["restructure_retries"] = int(restructured)
        return new, results, stats, restructured


class TieredEngine(EngineBase):
    """Budget-bounded tiered executor (``core.residency``) behind the
    durability layer.

    The handle is a ``TieredFliX``.  Every hook runs against the host tier:
    recovery rebuilds the mirror with the numpy twin of
    ``state_from_pairs`` (byte-identical layout, zero device allocation),
    snapshots canonicalize the synced mirror, and the pre-apply expired-
    bucket scan reads the residency plane's per-bucket min-deadline
    metadata — so a durable tiered index never needs the full structure to
    fit on device (the restructure relaunch inside ``TieredFliX.apply`` is
    the sole transient exception).
    """

    kind = "tiered"

    def __init__(
        self,
        *,
        budget_bytes: int | None = None,
        impl: str = "auto",
        config: ExecConfig | None = None,
        node_size: int = 32,
        nodes_per_bucket: int = 16,
        fill: float = 0.5,
    ):
        self.budget_bytes = budget_bytes
        self.config = config if config is not None else ExecConfig(impl=impl)
        if config is not None and impl != "auto":
            self.config = self.config.replace(impl=impl)
        self.impl = self.config.impl
        self.node_size = node_size
        self.nodes_per_bucket = nodes_per_bucket
        self.fill = fill

    def rebuild(self, keys, vals, exps=None, geometry: dict | None = None):
        from repro.core.residency import TieredFliX

        g = geometry or {}
        return TieredFliX.from_pairs(
            keys,
            vals,
            exps,
            node_size=g.get("node_size", self.node_size),
            nodes_per_bucket=g.get("nodes_per_bucket", self.nodes_per_bucket),
            fill=g.get("fill", self.fill),
            budget_bytes=self.budget_bytes,
        )

    def flix(self, handle):
        # tests / inspection only: this materializes the full device state,
        # exactly what the overridden hooks below exist to avoid
        return handle.materialize()

    def apply(self, handle, ops: OpBatch, *, max_results: int, now=None):
        results, stats, restructured = handle.apply(
            ops, config=self.config.replace(max_results=max_results), now=now
        )
        return handle, results, stats, restructured

    def mkba_host(self, handle) -> np.ndarray:
        return handle.h_mkba

    def geometry(self, handle) -> tuple[int, int, int]:
        return handle.geometry

    def segments(self, handle, buckets=None):
        return bucket_segments(handle.host_view(), buckets)

    def expired_buckets(self, handle, now) -> np.ndarray | None:
        if now is None or handle.h_exps is None:
            return None
        return handle.expired_buckets(now)


# ---------------------------------------------------------------------------
# snapshot store helpers
# ---------------------------------------------------------------------------


def _snap_name(seq: int) -> str:
    return f"{_SNAP_PREFIX}{seq:012d}"


def _snapshot_dirs(directory: Path) -> list[tuple[int, Path]]:
    """(seq, path) for committed snapshots, ascending; scratch dirs with
    ``.tmp`` in the name are crash leftovers and never listed."""
    out = []
    for p in Path(directory).glob(f"{_SNAP_PREFIX}*"):
        if not p.is_dir() or ".tmp" in p.name:
            continue
        try:
            seq = int(p.name[len(_SNAP_PREFIX) :])
        except ValueError:
            continue
        out.append((seq, p))
    return sorted(out)


def _read_manifest(path: Path) -> dict:
    try:
        with open(path / "manifest.json") as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise SnapshotCorruptionError(f"{path.name}: unreadable manifest: {e}") from e
    if m.get("format") != SNAP_FORMAT:
        raise SnapshotCorruptionError(
            f"{path.name}: format {m.get('format')!r} != {SNAP_FORMAT!r}"
        )
    return m


def _read_payload(path: Path, manifest: dict) -> bytes:
    try:
        data = (path / "payload.bin").read_bytes()
    except OSError as e:
        raise SnapshotCorruptionError(f"{path.name}: unreadable payload: {e}") from e
    if zlib.crc32(data) != manifest["payload_crc"]:
        raise SnapshotCorruptionError(f"{path.name}: payload checksum mismatch")
    return data


def load_snapshot_chain(directory: Path, seq: int):
    """Reconstruct the canonical pairs at snapshot ``seq``: follow the
    delta chain back to its base full snapshot, then replay the diffs
    forward, verifying every checksum on the way.  Returns
    ``(keys, vals, exps, manifest)`` for the requested snapshot."""
    directory = Path(directory)
    chain: list[tuple[Path, dict]] = []
    name = _snap_name(seq)
    while True:
        path = directory / name
        m = _read_manifest(path)
        chain.append((path, m))
        if m["kind"] == "full":
            break
        if m["kind"] != "delta" or not m.get("base"):
            raise SnapshotCorruptionError(f"{path.name}: malformed chain entry")
        name = m["base"]
        if len(chain) > 10_000:
            raise SnapshotCorruptionError("delta chain does not terminate")
    chain.reverse()  # base full first

    base_path, base_m = chain[0]
    epoch = base_m["epoch"]
    keys, vals, exps = parse_canonical(_read_payload(base_path, base_m))
    lens = np.asarray(base_m["seg_lens"], np.int64)
    if int(lens.sum()) != keys.size:
        raise SnapshotCorruptionError(f"{base_path.name}: seg_lens/payload mismatch")
    bounds = np.concatenate([[0], np.cumsum(lens)])
    seg_k = [keys[bounds[b] : bounds[b + 1]] for b in range(len(lens))]
    seg_v = [vals[bounds[b] : bounds[b + 1]] for b in range(len(lens))]
    seg_e = [exps[bounds[b] : bounds[b + 1]] for b in range(len(lens))]

    for path, m in chain[1:]:
        if m["epoch"] != epoch:
            raise SnapshotCorruptionError(
                f"{path.name}: epoch {m['epoch']} != chain epoch {epoch}"
            )
        bi, ln, ks, vs, es = parse_delta(_read_payload(path, m))
        off = 0
        for b, n in zip(bi, ln):
            if not 0 <= b < len(seg_k):
                raise SnapshotCorruptionError(f"{path.name}: bucket {b} out of range")
            seg_k[b] = ks[off : off + n]
            seg_v[b] = vs[off : off + n]
            seg_e[b] = es[off : off + n]
            off += int(n)

    final_m = chain[-1][1]
    want_lens = np.asarray(final_m["seg_lens"], np.int64)
    got_lens = np.array([len(s) for s in seg_k], np.int64)
    if len(want_lens) != len(got_lens) or (want_lens != got_lens).any():
        raise SnapshotCorruptionError(f"{_snap_name(seq)}: reconstructed lens differ")
    flat_k = np.concatenate(seg_k) if seg_k else np.zeros(0, np.int32)
    flat_v = np.concatenate(seg_v) if seg_v else np.zeros(0, np.int32)
    flat_e = np.concatenate(seg_e) if seg_e else np.zeros(0, np.int32)
    crcs = segment_crcs(
        got_lens, flat_k.astype("<i4"), flat_v.astype("<i4"), flat_e.astype("<i4")
    )
    if crcs != list(final_m["bucket_crcs"]):
        raise SnapshotCorruptionError(f"{_snap_name(seq)}: bucket checksum mismatch")
    return (
        flat_k.astype(np.int32),
        flat_v.astype(np.int32),
        flat_e.astype(np.int32),
        final_m,
    )


# ---------------------------------------------------------------------------
# the durable index
# ---------------------------------------------------------------------------


class DurableFliX:
    """WAL-ahead durable wrapper around a FliX engine (DESIGN.md §12).

    Use :meth:`create` for a fresh directory and :meth:`open` to recover;
    ``apply`` is the only mutation path.  ``seq`` counts applied batches
    (0 = the initial snapshot), and every batch whose ``apply`` returned
    is durable: it was fsynced into the WAL before execution.
    """

    def __init__(
        self,
        directory,
        engine,
        handle,
        *,
        seq: int,
        epoch: int,
        snapshot_every: int = 64,
        full_every: int = 8,
        keep_full: int = 2,
        fsync: bool = True,
        crash_hook=None,
        meta_window: int = 256,
    ):
        self.dir = Path(directory)
        self.engine = engine
        self.handle = handle
        self.snapshot_every = snapshot_every
        self.full_every = max(1, full_every)
        self.keep_full = max(1, keep_full)
        self.meta_window = max(0, meta_window)
        self._seq = seq
        self._epoch = epoch
        self._hook = crash_hook or _noop_hook
        self._wal = WriteAheadLog(self.dir, fsync=fsync, crash_hook=self._hook)
        self._dirty: set[int] = set()
        self._all_dirty = True
        self._mkba_host = np.asarray(self.engine.mkba_host(self.handle))
        self._bucket_lens: np.ndarray | None = None
        self._bucket_crcs: list[int] | None = None
        self._snaps_since_full = 0
        self._poisoned: str | None = None
        self._closed = False
        # bounded (seq, meta) trail of recent commits: logged in each WAL
        # record, carried across snapshots via the manifest, rebuilt on
        # open() — the gateway's durable dedup window (DESIGN.md §13)
        self._meta: list[tuple[int, object]] = []

    # -- constructors -----------------------------------------------------
    @staticmethod
    def exists(directory) -> bool:
        d = Path(directory)
        return d.is_dir() and (
            bool(_snapshot_dirs(d)) or bool(wal_mod.segment_files(d))
        )

    @classmethod
    def create(
        cls,
        directory,
        handle,
        *,
        engine=None,
        snapshot_every: int = 64,
        full_every: int = 8,
        keep_full: int = 2,
        fsync: bool = True,
        crash_hook=None,
        meta_window: int = 256,
    ) -> "DurableFliX":
        """Start a durable history at ``seq=0`` from an existing state:
        writes the initial full snapshot and opens the first WAL segment."""
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        if cls.exists(directory):
            raise FileExistsError(
                f"{directory} already holds a durable index — use open()"
            )
        self = cls(
            directory,
            engine or LocalEngine(),
            handle,
            seq=0,
            epoch=0,
            snapshot_every=snapshot_every,
            full_every=full_every,
            keep_full=keep_full,
            fsync=fsync,
            crash_hook=crash_hook,
            meta_window=meta_window,
        )
        self.snapshot(full=True)  # also opens WAL segment seq+1
        return self

    @classmethod
    def open(
        cls,
        directory,
        *,
        engine=None,
        snapshot_every: int = 64,
        full_every: int = 8,
        keep_full: int = 2,
        fsync: bool = True,
        crash_hook=None,
        truncate_torn: bool = True,
        meta_window: int = 256,
    ) -> "DurableFliX":
        """Crash recovery: newest valid snapshot chain + WAL replay.

        Every batch whose append was acknowledged is recovered; a torn
        tail (crash mid-append) is truncated — or, with
        ``truncate_torn=False``, surfaces as ``WALCorruptionError``.
        Recovery itself is crash-safe and idempotent, and rebuilding from
        canonical pairs is an epoch bump (fresh fences), so the first
        snapshot afterwards is automatically full.
        """
        directory = Path(directory)
        engine = engine or LocalEngine()
        snaps = _snapshot_dirs(directory)
        if not snaps:
            raise FileNotFoundError(f"no snapshots under {directory}")
        keys = vals = exps = manifest = None
        errors = []
        for seq, _path in reversed(snaps):
            try:
                keys, vals, exps, manifest = load_snapshot_chain(directory, seq)
                break
            except SnapshotCorruptionError as e:  # fall back to an older one
                errors.append(str(e))
        if manifest is None:
            raise SnapshotCorruptionError(
                f"no loadable snapshot under {directory}: {errors}"
            )

        handle = engine.rebuild(keys, vals, exps, manifest.get("geometry"))
        self = cls(
            directory,
            engine,
            handle,
            seq=manifest["seq"],
            epoch=manifest["epoch"] + 1,  # rebuilt fences = new epoch
            snapshot_every=snapshot_every,
            full_every=full_every,
            keep_full=keep_full,
            fsync=fsync,
            crash_hook=crash_hook,
            meta_window=meta_window,
        )
        # the dedup/meta trail up to the snapshot rides in its manifest;
        # the replayed tail below extends it exactly as live applies did
        for mseq, mobj in manifest.get("meta_window") or []:
            self._record_meta(int(mseq), mobj)
        records = wal_mod.replay(
            directory, after_seq=manifest["seq"], truncate_torn=truncate_torn
        )
        for seq, payload in records:
            tag, key, val, max_results, meta_bytes, exp, wnow = decode_ops(payload)
            ops = OpBatch.from_host(tag, key, val, exp)
            # replay at the LOGGED virtual clock — time-deterministic: the
            # recovered expiry state is what the live engine computed, no
            # matter when (in wall time) recovery runs
            new, _results, _stats, restructured = engine.apply(
                self.handle, ops, max_results=max_results, now=wnow
            )
            self.handle = new
            if restructured:
                # full _bump_epoch, not a bare counter: a replayed
                # restructure moves the fences, and apply()'s dirty-bucket
                # routing reads the refreshed _mkba_host ever after
                self._bump_epoch()
            self._seq = seq
            if meta_bytes:
                self._record_meta(seq, json.loads(meta_bytes.decode()))
        self.replayed = len(records)

        # resume appending where the durable history ends: the newest
        # segment (tail-truncated above) stays the active one
        segs = wal_mod.segment_files(directory)
        if segs:
            self._wal.open_segment(segs[-1][0], path=segs[-1][1])
        else:
            self._wal.open_segment(self._seq + 1)
        if self.snapshot_every and self.replayed >= self.snapshot_every:
            self.snapshot()  # bound the next recovery's replay cost
        return self

    # -- accessors --------------------------------------------------------
    @property
    def seq(self) -> int:
        return self._seq

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def state(self):
        """The engine's current FliXState view (single-device: the handle
        itself; sharded: the global-view state)."""
        return self._flix_state()

    @property
    def healthy(self) -> bool:
        """False once live and durable state have diverged (failed WAL
        rollback) — ``apply``/``snapshot`` are refused; reads of the live
        handle remain valid, and reopening from disk resynchronizes."""
        return self._poisoned is None and not self._closed

    @property
    def poisoned_reason(self) -> str | None:
        return self._poisoned

    def meta_trail(self) -> list[tuple[int, object]]:
        """The bounded ``(seq, meta)`` trail of recent durable commits,
        ascending — everything the last ``meta_window`` metadata-carrying
        batches logged, surviving snapshots and crash recovery."""
        return list(self._meta)

    def _record_meta(self, seq: int, meta: object) -> None:
        if meta is None or self.meta_window == 0:
            return
        self._meta.append((seq, meta))
        if len(self._meta) > self.meta_window:
            del self._meta[: len(self._meta) - self.meta_window]

    def _flix_state(self):
        return self.engine.flix(self.handle)

    # -- the commit path --------------------------------------------------
    def apply(
        self,
        ops: OpBatch,
        *,
        config: ExecConfig | None = None,
        meta=None,
        now: int | None = None,
        max_results=_UNSET,
    ):
        """Durably execute one sorted batch; returns ``(results, stats)``.

        Execution strategy rides on ``config=ExecConfig(...)`` (the bare
        ``max_results`` keyword is a deprecated warn-once shim).  Only its
        ``max_results`` is durable — it is logged per WAL record so replay
        re-runs each batch under its own budget; the rest of the strategy
        (impl, pipeline, tiles) belongs to the live engine and may differ
        at recovery time without changing the recovered state.

        ``now`` is the batch's virtual clock (DESIGN.md §14): it is logged
        in the WAL record alongside any per-op expiry column, so replay
        re-runs the batch at the identical time and recovers the identical
        expiry state — durability never consults the wall clock.

        ``meta`` (any JSON-serializable object, e.g. the gateway's
        idempotency keys) is logged inside the batch's WAL record and kept
        in the bounded :meth:`meta_trail` — it becomes durable in the SAME
        fsync as the ops, so a recovered history always agrees with itself
        about which annotated batches it contains.

        The WAL append (fsynced) precedes execution, so a crash at ANY
        later point replays this batch to the identical logical state —
        the engine never sees an op the log does not already hold.

        If the ENGINE fails (overflow assertion, OOM) the handle is
        unchanged — the engine is functional — so the just-appended record
        is rolled back before re-raising: the durable history must hold
        exactly the batches the live instance executed.  Should that
        rollback itself fail, the instance is poisoned (further apply /
        snapshot refused) because live and durable state have diverged —
        reopening from disk is the only consistent continuation.
        """
        cfg = resolve_config("DurableFliX.apply", config, max_results=max_results)
        mr = cfg.max_results
        self._check_poisoned()
        tag, key, val, exp = ops.to_host()
        if exp is None and now is not None:
            # the record form needs an expiry column to carry the clock;
            # an all-sentinel one is logically "no per-op deadlines"
            exp = np.full(tag.shape, int(NO_EXPIRY), np.int32)
        seq = self._seq + 1
        meta_bytes = b"" if meta is None else json.dumps(meta).encode()
        wal_pos = self._wal.tell()
        self._wal.append(
            seq, encode_ops(tag, key, val, mr, meta_bytes, exp=exp, now=now)
        )
        self._seq = seq

        # buckets holding rows the expire pass is about to reclaim change
        # WITHOUT appearing among the batch's update keys — mark them dirty
        # from the pre-apply state so delta snapshots cover the reclamation
        expired_buckets = self.engine.expired_buckets(self.handle, now)

        try:
            new, results, stats, restructured = self.engine.apply(
                self.handle, ops, max_results=mr, now=now
            )
        except BaseException:
            self._seq = seq - 1
            try:
                self._wal.truncate_to(wal_pos)
            except BaseException:
                self._poisoned = (
                    f"batch seq={seq} was logged but neither executed nor "
                    "rolled back; reopen from disk to resynchronize"
                )
            raise
        self.handle = new
        if restructured:
            self._bump_epoch()
        else:
            upd = (tag == OP_INSERT) | (tag == OP_DELETE) | (tag == OP_EXPIRE)
            if upd.any():
                buckets = np.searchsorted(self._mkba_host, key[upd], side="left")
                self._dirty.update(int(b) for b in np.unique(buckets))
            if expired_buckets is not None:
                self._dirty.update(int(b) for b in expired_buckets)
        self._record_meta(seq, meta)
        self._hook("apply.done")

        if self.snapshot_every and seq % self.snapshot_every == 0:
            self.snapshot()
        return results, stats

    def _bump_epoch(self) -> None:
        self._epoch += 1
        self._all_dirty = True
        self._dirty.clear()
        self._mkba_host = np.asarray(self.engine.mkba_host(self.handle))

    def _check_poisoned(self) -> None:
        if self._poisoned:
            raise RuntimeError(
                f"durable history diverged from live state: {self._poisoned}"
            )
        if self._closed:
            raise RuntimeError("durable index is closed")

    # -- snapshots --------------------------------------------------------
    def snapshot(self, *, full: bool | None = None) -> Path:
        """Write one snapshot at the current seq (atomic commit).

        ``full=None`` picks automatically: full on the first snapshot,
        after an epoch bump (fences moved — the delta partition is void),
        and every ``full_every``-th snapshot; otherwise a dirty-bucket
        delta whose write cost is proportional to churn.
        """
        self._check_poisoned()
        name = _snap_name(self._seq)
        if (self.dir / name).is_dir():
            # a snapshot at this seq is already committed, and seq determines
            # the logical content — forcing another is an idempotent no-op
            # (e.g. close-time snapshot right after an auto-snapshot).  But
            # only after it validates: open() may have fallen back PAST a
            # corrupt snapshot at exactly this seq, and trusting it would
            # leave every future recovery replaying the whole WAL tail.
            try:
                load_snapshot_chain(self.dir, self._seq)
                return self.dir / name
            except SnapshotCorruptionError:
                shutil.rmtree(self.dir / name, ignore_errors=True)
        if full is None:
            full = (
                self._all_dirty
                or self._bucket_lens is None
                or self._snaps_since_full >= self.full_every - 1
            )
        prev_full_name = None
        if not full:
            prev_full_name = self._latest_snap_name()

        if full:
            lens, seg_k, seg_v, seg_e = self.engine.segments(self.handle)
            payload = pairs_to_bytes(seg_k, seg_v, seg_e)
            all_lens = lens
            all_crcs = segment_crcs(lens, seg_k, seg_v, seg_e)
            kind = "full"
        else:
            dirty = sorted(self._dirty)
            lens, seg_k, seg_v, seg_e = self.engine.segments(self.handle, dirty)
            payload = pack_delta(dirty, lens, seg_k, seg_v, seg_e)
            all_lens = np.array(self._bucket_lens, np.int64)
            all_crcs = list(self._bucket_crcs)
            new_crcs = segment_crcs(lens, seg_k, seg_v, seg_e)
            for i, b in enumerate(dirty):
                all_lens[b] = lens[i]
                all_crcs[b] = new_crcs[i]
            kind = "delta"

        nb, npb, ns = self.engine.geometry(self.handle)
        manifest = {
            "format": SNAP_FORMAT,
            "kind": kind,
            "seq": self._seq,
            "epoch": self._epoch,
            "base": prev_full_name,
            "engine": self.engine.kind,
            "geometry": {
                "num_buckets": nb,
                "nodes_per_bucket": npb,
                "node_size": ns,
                "fill": getattr(self.engine, "fill", 0.5),
            },
            "n_live": int(np.asarray(all_lens, np.int64).sum()),
            "seg_lens": [int(x) for x in all_lens],
            "bucket_crcs": [int(c) for c in all_crcs],
            "payload_crc": zlib.crc32(payload),
            # carry the dedup/meta trail across the WAL segments this
            # snapshot retires — open() reseeds from here, then extends
            # with the replayed tail (DESIGN.md §13)
            "meta_window": [[s, m] for s, m in self._meta],
        }

        tmp = tmp_sibling(self.dir / name)
        tmp.mkdir(parents=True)
        try:
            self._write_file(tmp / "payload.bin", payload, split=True)
            self._hook("snap.payload.written")
            self._write_file(
                tmp / "manifest.json",
                json.dumps(manifest, sort_keys=True).encode(),
            )
            self._hook("snap.manifest.written")
            self._hook("snap.before_rename")
            os.rename(tmp, self.dir / name)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._fsync_dir()
        self._hook("snap.committed")

        self._bucket_lens = np.asarray(all_lens, np.int64)
        self._bucket_crcs = list(all_crcs)
        self._dirty.clear()
        self._all_dirty = False
        self._snaps_since_full = 0 if full else self._snaps_since_full + 1
        self._wal.rotate(self._seq + 1)
        self._gc()
        self._hook("snap.gc")
        return self.dir / name

    def _latest_snap_name(self) -> str:
        snaps = _snapshot_dirs(self.dir)
        if not snaps:
            raise RuntimeError("delta snapshot requires an existing base")
        return snaps[-1][1].name

    def _write_file(self, path: Path, data: bytes, *, split: bool = False) -> None:
        fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
        try:
            if split and len(data) > 1:
                # two writes so the crash hook can land mid-payload
                wal_mod.write_all(fd, data[: len(data) // 2])
                self._hook("snap.payload.partial")
                wal_mod.write_all(fd, data[len(data) // 2 :])
            else:
                wal_mod.write_all(fd, data)
            os.fsync(fd)
        finally:
            os.close(fd)

    def _fsync_dir(self) -> None:
        dfd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)

    def _gc(self) -> None:
        """Retention: keep the ``keep_full`` newest full snapshots, every
        delta above the oldest kept full, and the WAL segments needed to
        replay past the oldest kept snapshot.  Deltas below the cutoff are
        unreachable (their chains end in deleted fulls) and fulls below it
        are redundant history."""
        snaps = [
            (seq, p, _read_manifest(p)["kind"]) for seq, p in _snapshot_dirs(self.dir)
        ]
        fulls = [seq for seq, _p, kind in snaps if kind == "full"]
        if len(fulls) <= self.keep_full:
            return
        cutoff = sorted(fulls)[-self.keep_full]
        for seq, p, _kind in snaps:
            if seq < cutoff:
                shutil.rmtree(p, ignore_errors=True)
        segs = wal_mod.segment_files(self.dir)
        for (start, path), nxt in zip(segs, segs[1:]):
            # a segment holds records [start, next_start); all ≤ cutoff are
            # covered by the oldest kept snapshot
            if nxt[0] <= cutoff + 1:
                path.unlink(missing_ok=True)

    def close(self) -> None:
        """Flush and close the WAL.  Idempotent, and safe on a poisoned
        instance: teardown of a diverged index must not raise on top of
        the failure that poisoned it — the durable history on disk is
        already self-consistent, and reopening resynchronizes."""
        if self._closed:
            return
        self._closed = True
        try:
            self._wal.close()
        except OSError:
            if self._poisoned is None:
                raise
