"""train_step: next-token loss + AdamW, built for pjit.

* layers run under scan+remat (compact HLO at 512 devices, activation memory
  bounded to ~one layer),
* the LM head + cross entropy run seq-chunked under jax.checkpoint so the
  [B, S, V] logits never materialize (vocab stays sharded throughout — the
  softmax reductions become XLA partial-reduce + small collectives),
* optional microbatch accumulation with int8 error-feedback compression on
  the accumulator (repro.optim.compress).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import (
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState


def train_state_init(rng, cfg: ModelConfig, param_dtype=jnp.float32) -> TrainState:
    params = transformer.init_params(rng, cfg, param_dtype)
    return TrainState(params=params, opt=adamw_init(params))


def chunked_lm_loss(x, head, targets, mask, *, chunk: int = 512):
    """Cross entropy over seq chunks; logits stay [B, chunk, V-shard]."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @jax.checkpoint
    def one(args):
        xc, tc, mc = args
        logits = (xc @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc), jnp.sum(mc)

    def slice_c(a, i, ln):
        return jax.lax.dynamic_slice_in_dim(a, i, ln, axis=1)

    tot, cnt = 0.0, 0.0
    if n:
        parts = jax.lax.map(
            lambda i: one(
                (
                    slice_c(x, i * chunk, chunk),
                    slice_c(targets, i * chunk, chunk),
                    slice_c(mask, i * chunk, chunk),
                )
            ),
            jnp.arange(n),
        )
        tot, cnt = jnp.sum(parts[0]), jnp.sum(parts[1])
    if rem:
        t2, c2 = one(
            (
                slice_c(x, n * chunk, rem),
                slice_c(targets, n * chunk, rem),
                slice_c(mask, n * chunk, rem),
            )
        )
        tot, cnt = tot + t2, cnt + c2
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(
    cfg: ModelConfig,
    *,
    remat: bool = True,
    loss_chunk: int = 512,
    layer_loop: str = "scan",
    act_spec=None,
):
    def loss_fn(params, batch):
        compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        hidden = transformer.forward_hidden(
            params,
            cfg,
            batch["tokens"],
            batch.get("prefix_embeds"),
            remat=remat,
            layer_loop=layer_loop,
            act_spec=act_spec,
        )
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ).astype(compute)
        targets = batch["targets"]
        St = targets.shape[1]
        text_hidden = hidden[:, -St:, :]
        # next-token objective: position i predicts target i+1
        mask = jnp.ones_like(targets[:, 1:], jnp.float32)
        return chunked_lm_loss(
            text_hidden[:, :-1], head, targets[:, 1:], mask, chunk=loss_chunk
        )

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    *,
    lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    remat: bool = True,
    loss_chunk: int = 512,
    layer_loop: str = "scan",
    act_spec=None,
):
    loss_fn = make_loss_fn(
        cfg,
        remat=remat,
        loss_chunk=loss_chunk,
        layer_loop=layer_loop,
        act_spec=act_spec,
    )
    schedule = cosine_schedule(lr, warmup, total_steps)

    def train_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = adamw_update(state.params, grads, state.opt, schedule)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
