"""Training step factory (loss, remat, microbatching, sharded optimizer)."""

from repro.train.step import (
    TrainState,
    chunked_lm_loss,
    make_train_step,
    train_state_init,
)
