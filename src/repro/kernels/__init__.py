"""Pallas TPU kernels for the perf-critical hot spots (+ jnp oracles).

flix_query      — flipped point-query kernel (compute-to-bucket streaming)
flix_successor  — flipped successor kernel (in-bucket votes + suffix-min fallback)
flix_insert     — TL-Bulk insertion kernel (upsert merge, balanced splits)
flix_delete     — TL-Bulk deletion kernel (mark, compact, reclaim)
flix_apply      — fused mixed-batch apply: merge + delete + post-update reads
                  (point / successor / dense RANGE) in one VMEM-resident
                  pass per bucket (DESIGN.md §9, §10)
flix_range      — standalone two-pass RANGE kernel: compute-to-bucket count,
                  then rank-owned scatter to exclusive-scan offsets (§10)
grouped_matmul  — ragged grouped GEMM over expert slices (flipped MoE)
moe_dispatch    — sort-based dispatch helpers (the sorted-batch step)
ops             — jit'd wrappers with backend dispatch
ref             — pure-jnp oracles for every kernel
"""
