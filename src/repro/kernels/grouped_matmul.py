"""Ragged grouped GEMM over expert slices (MegaBlocks-style, TPU form).

This is the FliX paradigm applied to MoE compute (DESIGN.md §4): tokens are
*sorted by expert* (the sorted batch), ``group_offsets`` are the per-expert
slice boundaries (the MKBA searchsorted), and each expert — a *bucket* —
pulls its contiguous token slice and runs a dense MXU matmul on it.

Grid = (token blocks, F blocks, expert span).  Scalar-prefetched per-block
expert ranges ``elo/ehi`` drive the weight BlockSpec: span steps beyond a
block's real range clamp to the same weight block (no DMA) and skip compute
— identical machinery to the flix_query bucket streaming.

Block shapes: x (BT, D) and w (1, D, BF) are full-depth; with BT=BF=128 and
D ≤ 8192 the VMEM working set is ≤ ~4 MiB in bf16.  MXU dims are 128-aligned
by construction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_F = 128


def _gmm_kernel(
    offs_ref,   # scalar prefetch: [E+1] token offsets per expert
    elo_ref,    # scalar prefetch: [nT] first expert of token block
    ehi_ref,    # scalar prefetch: [nT] last expert of token block
    x_ref,      # [BT, D]
    w_ref,      # [1, D, BF]
    out_ref,    # [BT, BF] f32, revisited across the span dimension
    *,
    block_t: int,
    num_experts: int,
):
    t = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    e = elo_ref[t] + k
    active = e <= ehi_ref[t]

    @pl.when(active)
    def _accumulate():
        e_c = jnp.minimum(e, num_experts - 1)
        row0 = t * block_t
        lo = jnp.clip(offs_ref[e_c] - row0, 0, block_t)
        hi = jnp.clip(offs_ref[e_c + 1] - row0, 0, block_t)
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_t, 1), 0)
        mask = (rows >= lo) & (rows < hi)
        x = jnp.where(mask, x_ref[...], 0).astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        out_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_f", "max_span", "interpret")
)
def grouped_matmul_pallas(
    x: jax.Array,              # [T, D] tokens sorted by group
    w: jax.Array,              # [E, D, F]
    group_offsets: jax.Array,  # [E+1] ascending, offsets[0]=0, offsets[E]=T
    *,
    block_t: int = DEFAULT_BLOCK_T,
    block_f: int = DEFAULT_BLOCK_F,
    max_span: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    T, D = x.shape
    E, _, F = w.shape
    assert T % block_t == 0 and F % block_f == 0, (T, F, block_t, block_f)
    offs = group_offsets.astype(jnp.int32)

    nT = T // block_t
    row0 = jnp.arange(nT, dtype=jnp.int32) * block_t
    # expert range per token block: offsets straddling [row0, row0+BT)
    elo = (jnp.searchsorted(offs, row0, side="right") - 1).astype(jnp.int32)
    ehi = (
        jnp.searchsorted(offs, row0 + block_t - 1, side="right") - 1
    ).astype(jnp.int32)
    elo = jnp.clip(elo, 0, E - 1)
    ehi = jnp.clip(ehi, 0, E - 1)
    span = E if max_span is None else max_span

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nT, F // block_f, span),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda t, f, k, offs, lo, hi: (t, 0)),
            pl.BlockSpec(
                (1, D, block_f),
                lambda t, f, k, offs, lo, hi: (
                    jnp.clip(lo[t] + k, 0, w.shape[0] - 1),
                    0,
                    f,
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_t, block_f), lambda t, f, k, offs, lo, hi: (t, f)
        ),
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, block_t=block_t, num_experts=E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
        ),
    )(offs, elo, ehi, x, w)
