"""Fused compute-to-bucket apply kernel: one VMEM-resident pass per bucket
for the whole mixed batch (the paper's "a bucket does all of its work in one
visit", §4.1, applied across the full operation mix).

``core.ops.apply_ops`` in reference form executes a mixed batch as four
separate device passes — insert merge, delete, point reads, successor reads —
so every bucket stripe crosses HBM four-plus times per step.  This kernel
collapses them: while a bucket stripe is VMEM-resident it

  1. upsert-merges its INSERT slice with original-node-region re-chunking
     (identical formulas to ``flix_insert`` / ``core.insert``),
  2. physically DELETEs its DELETE slice with in-node and chain compaction
     (identical formulas to ``flix_delete`` / ``core.delete``),
  3. answers the batch's POINT and SUCCESSOR ops that fall in the bucket
     against the *post-update* stripe (compare-count votes + one-hot MXU
     gathers, as in ``flix_query`` / ``flix_successor``),
  4. fills the output slots of the batch's RANGE ops whose global key rank
     lands in the bucket — the dense count/scatter contract of
     ``kernels/flix_range`` (DESIGN.md §10), read straight from the
     post-update stripe in the same VMEM residency,

writing the new stripe, the per-bucket metadata, and the per-op results in
one pass.

Grid layout — the established window/bucket-block scheme from
``flix_query`` with one twist: **window 0 sweeps every bucket block** (its
scalar-prefetched bounds are widened to [0, nb_blocks)), which is where the
single full update pass happens; windows ≥ 1 only re-visit the blocks their
own op range touches and *recompute* the update for those stripes.  The
recompute is idempotent — the merge/delete depend only on per-bucket tiles
gathered from the whole batch, not on the window — so revisited stripe
blocks are rewritten with byte-identical data and every flush of an output
block happens after a full in-window rewrite.  Total state traffic is one
full sweep plus boundary revisits, versus ≥ 4 full sweeps for the reference
engine.

The successor out-of-bucket fallback cannot be resolved block-locally, so
the wrapper feeds the same fence-row trick as ``flix_successor``: it derives
the *post-update* per-bucket minimum (min of surviving stripe keys and the
bucket's insert slice — exact because one batch never inserts and deletes
the same key) and suffix-scans it into ``next_key``/``next_val`` rows that
stream through the fence BlockSpec.

RANGE uses the same predict-without-running-the-update trick, extended from
the per-bucket minimum to the whole per-bucket key multiset: the wrapper
sorts (surviving stripe keys minus upsert duplicates) ∪ (insert slice) per
bucket, prefix-sums the live counts into post-update rank fences
``pref[b]``/``pref[b+1]``, resolves every op's ``[lo, hi)`` to full counts
→ clamped segment offsets → one global rank per output slot (the shared
``core.query`` formulas), and streams the rank fences through the fence
BlockSpec.  The kernel then only has to map "rank within my bucket" to a
(node, position) of the stripe it just rebuilt — values come from VMEM, not
from a second state pass.

Tiered residency (DESIGN.md §15): the kernel is *residency-oblivious*.  A
``TieredFliX`` working set arrives here as an ordinary packed ``FliXState``
whose buckets are the promoted subset, re-fenced so ``mkba[-1] ==
MAX_VALID``; because every bucket an op can touch is promoted by the
prefetch pre-pass (``core.ops.touched_buckets``), the searchsorted routing
and the successor/range fence rows are self-contained in the packed view and
nothing below this line knows tiers exist.  The only contract this file owes
the residency plane is the one it already keeps: it never reads or writes a
bucket outside the state it was handed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.flix_query import DEFAULT_BLOCK_Q, _exact_gather_i32
from repro.core.batch import bucket_slices, gather_kv_sublists, gather_sublists
from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, FliXState

DEFAULT_BLOCK_B = 2     # bucket stripes per block (merge masks are O(BB·S²))
_EMPTY = int(jnp.iinfo(jnp.int32).max)
_MISS = -1
_OP_POINT = 2           # mirror core.ops tags as Python literals (kernels
_OP_SUCCESSOR = 3       # must not capture traced constants)
_OP_RANGE = 5


def _stripe_body(
    A,           # [BB, S] stripe keys (VMEM-resident, chain order)
    Av,          # [BB, S] stripe vals
    t_ref,       # [1, QB] op tags for window j
    q_ref,       # [1, QB] sorted op keys for window j
    nmax_ref,    # [BB, npb] per-node max keys (EMPTY when inactive)
    ik_ref,      # [BB, cap] sorted per-bucket INSERT keys (EMPTY-padded)
    iv_ref,      # [BB, cap]
    dk_ref,      # [BB, cap] sorted per-bucket DELETE keys (present only)
    mkba_ref,    # [1, BB] bucket fences for the block
    lf_ref,      # [1, BB] lower fences
    nxk_ref,     # [1, BB] post-update "first key after bucket b" rows
    nxv_ref,     # [1, BB]
    g_ref,       # [1, MR] per-RANGE-slot post-update global rank (-1 unused)
    ps_ref,      # [1, BB] post-update rank fences pref[b]
    pe_ref,      # [1, BB] post-update rank fences pref[b+1]
    okeys_ref,   # [BB, npb*ns] post-update stripes
    ovals_ref,   # [BB, npb*ns]
    ocnt_ref,    # [BB, npb]
    omax_ref,    # [BB, npb]
    onn_ref,     # [BB, 1]
    oflow_ref,   # [BB, 1] bucket overflow flag
    odel_ref,    # [BB, 1] keys physically deleted in this bucket
    resv_ref,    # [1, QB] POINT/SUCCESSOR values / NOT_FOUND
    resk_ref,    # [1, QB] SUCCESSOR keys / EMPTY
    rngk_ref,    # [1, MR] dense RANGE keys / EMPTY (shared across windows)
    rngv_ref,    # [1, MR] dense RANGE vals / NOT_FOUND
    *,
    block_b: int,
    npb: int,
    ns: int,
    cap: int,
):
    """One active stripe block: merge + delete + reads + range gather.

    Shared verbatim by the single-buffer kernel (stripes arrive through the
    automatic BlockSpec pipeline) and the double-buffered kernel (stripes
    arrive via explicit DMA into two-slot scratch) — only where ``A``/``Av``
    come *from* differs, so the two variants cannot diverge numerically.
    """
    S = npb * ns
    bb = block_b
    # ---- phase 1: upsert merge of the INSERT slice (per stripe) ------
    B = ik_ref[...]                            # [BB, cap] incoming
    Bv = iv_ref[...]
    nmax = nmax_ref[...]                       # [BB, npb]

    validA = A != _EMPTY
    validB = B != _EMPTY
    dupA = jnp.any(A[:, :, None] == B[:, None, :], axis=2) & validA
    keepA = validA & ~dupA                     # incoming value wins

    # merged ranks by compare-count (both sides sorted & unique)
    lessA_A = jnp.sum((A[:, None, :] < A[:, :, None]) & keepA[:, None, :], axis=2)
    lessB_A = jnp.sum(
        (B[:, None, :] < A[:, :, None]) & validB[:, None, :], axis=2
    )
    rankA = lessA_A + lessB_A                  # [BB, S]
    lessA_B = jnp.sum((A[:, None, :] < B[:, :, None]) & keepA[:, None, :], axis=2)
    lessB_B = jnp.sum(
        (B[:, None, :] < B[:, :, None]) & validB[:, None, :], axis=2
    )
    rankB = lessA_B + lessB_B                  # [BB, cap]

    # original node regions (fixed boundaries; last region open-ended)
    onn0 = jnp.sum((nmax != _EMPTY).astype(jnp.int32), axis=1)   # [BB]
    onn_c = jnp.maximum(onn0 - 1, 0)

    def region_of(z):
        r = jnp.sum((nmax[:, None, :] < z[:, :, None]).astype(jnp.int32), axis=2)
        return jnp.minimum(r, onn_c[:, None])

    regA = region_of(A)
    regB = region_of(B)

    iota_r = jax.lax.broadcasted_iota(jnp.int32, (bb, npb), 1)
    mA = jnp.sum(
        (regA[:, :, None] == iota_r[:, None, :]) & keepA[:, :, None],
        axis=1,
    )
    mB = jnp.sum(
        (regB[:, :, None] == iota_r[:, None, :]) & validB[:, :, None],
        axis=1,
    )
    m_j = (mA + mB).astype(jnp.int32)          # [BB, npb]
    s_j = (m_j + ns - 1) // ns                 # pieces per region
    f_j = jnp.cumsum(m_j, axis=1) - m_j        # first rank of region
    base_j = jnp.cumsum(s_j, axis=1) - s_j     # first output slot
    total_new = jnp.sum(s_j, axis=1)           # [BB]

    def dest_of(rank, reg, keep):
        # balanced split within each region (same formulas as core/insert)
        oh = reg[:, :, None] == iota_r[:, None, :]
        m_r = jnp.maximum(jnp.sum(jnp.where(oh, m_j[:, None, :], 0), axis=2), 1)
        s_r = jnp.maximum(jnp.sum(jnp.where(oh, s_j[:, None, :], 0), axis=2), 1)
        f_r = jnp.sum(jnp.where(oh, f_j[:, None, :], 0), axis=2)
        b_r = jnp.sum(jnp.where(oh, base_j[:, None, :], 0), axis=2)
        rr = rank - f_r
        piece = (rr * s_r) // m_r
        start = (piece * m_r + s_r - 1) // s_r
        pos = rr - start
        slot = b_r + piece
        return jnp.where(keep & (slot < npb), slot * ns + pos, S)

    destA = dest_of(rankA, regA, keepA)        # [BB, S]
    destB = dest_of(rankB, regB, validB)       # [BB, cap]

    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, 1, S), 2)
    ohA = destA[:, :, None] == lane            # [BB, S, S]
    ohB = destB[:, :, None] == lane            # [BB, cap, S]
    mk = jnp.sum(jnp.where(ohA, A[:, :, None], 0), axis=1) + jnp.sum(
        jnp.where(ohB, B[:, :, None], 0), axis=1
    )
    mv = jnp.sum(jnp.where(ohA, Av[:, :, None], 0), axis=1) + jnp.sum(
        jnp.where(ohB, Bv[:, :, None], 0), axis=1
    )
    filled = jnp.any(ohA, axis=1) | jnp.any(ohB, axis=1)
    mk = jnp.where(filled, mk, _EMPTY)         # [BB, S] merged stripe
    mv = jnp.where(filled, mv, 0)

    # ---- phase 2: physical delete on the merged stripe ---------------
    D = dk_ref[...]                            # [BB, cap]
    hit = jnp.any(mk[:, :, None] == D[:, None, :], axis=2)
    hit &= mk != _EMPTY
    del_cnt = jnp.sum(hit.astype(jnp.int32), axis=1)          # [BB]

    rows = mk.reshape(bb, npb, ns)
    vrows = mv.reshape(bb, npb, ns)
    hitr = hit.reshape(bb, npb, ns)
    keep = (~hitr) & (rows != _EMPTY)
    dest = jnp.cumsum(keep.astype(jnp.int32), axis=2) - 1
    lane_n = jax.lax.broadcasted_iota(jnp.int32, (bb, npb, ns, ns), 3)
    ohc = (dest[..., None] == lane_n) & keep[..., None]
    nk = jnp.sum(jnp.where(ohc, rows[..., None], 0), axis=2)
    nfill = jnp.any(ohc, axis=2)
    nk = jnp.where(nfill, nk, _EMPTY)
    nv = jnp.where(
        nk == _EMPTY, 0, jnp.sum(jnp.where(ohc, vrows[..., None], 0), axis=2)
    )
    cnt = jnp.sum(keep.astype(jnp.int32), axis=2)             # [BB, npb]

    # chain compaction: surviving nodes shift into the lowest slots
    nonempty = cnt > 0
    slot_dest = jnp.cumsum(nonempty.astype(jnp.int32), axis=1) - 1
    slot_lane = jax.lax.broadcasted_iota(jnp.int32, (bb, npb, npb), 2)
    ohs = (slot_dest[:, :, None] == slot_lane) & nonempty[:, :, None]
    fk = jnp.sum(jnp.where(ohs[..., None], nk[:, :, None, :], 0), axis=1)
    fv = jnp.sum(jnp.where(ohs[..., None], nv[:, :, None, :], 0), axis=1)
    row_filled = jnp.any(ohs, axis=1)                         # [BB, npb]
    fk = jnp.where(row_filled[..., None], fk, _EMPTY)
    fv = jnp.where(row_filled[..., None], fv, 0)

    # metadata
    ocnt = jnp.sum((fk != _EMPTY).astype(jnp.int32), axis=2)
    last = jnp.maximum(ocnt - 1, 0)
    lane3 = jax.lax.broadcasted_iota(jnp.int32, (bb, npb, ns), 2)
    omax = jnp.sum(jnp.where(lane3 == last[..., None], fk, 0), axis=2)
    omax = jnp.where(ocnt > 0, omax, _EMPTY)
    onn_new = jnp.sum((ocnt > 0).astype(jnp.int32), axis=1)   # [BB]

    okeys_ref[...] = fk.reshape(bb, S)
    ovals_ref[...] = fv.reshape(bb, S)
    ocnt_ref[...] = ocnt
    omax_ref[...] = omax
    onn_ref[...] = onn_new[:, None]
    oflow_ref[...] = (total_new > npb).astype(jnp.int32)[:, None]
    odel_ref[...] = del_cnt[:, None]

    # ---- phase 3: reads against the post-update stripe ---------------
    t = t_ref[0, :]                            # [QB] op tags
    q = q_ref[0, :]                            # [QB] op keys
    qcol = q[:, None]

    mkba = mkba_ref[0, :][None, :]             # [1, BB]
    b_local = jnp.sum(mkba < qcol, axis=1)     # [QB]
    lf = lf_ref[0, :][None, :]
    b_sel = jnp.minimum(b_local, bb - 1)
    oh_b = (
        jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], bb), 1)
        == b_sel[:, None]
    )
    lf_q = jnp.sum(jnp.where(oh_b, lf, 0), axis=1)
    is_read = (t == _OP_POINT) | (t == _OP_SUCCESSOR)
    mine = (b_local < bb) & (qcol[:, 0] > lf_q) & is_read

    # node by post-update node-max votes, position by key votes
    nmax_rows = _exact_gather_i32(oh_b.astype(jnp.float32), omax)
    nn_q = jnp.sum(jnp.where(oh_b, onn_new[None, :], 0), axis=1)
    nidx = jnp.sum(nmax_rows < qcol, axis=1)
    in_bucket = nidx < nn_q
    nidx_c = jnp.minimum(nidx, npb - 1)

    flat = b_sel * npb + nidx_c
    oh_n = (
        jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], bb * npb), 1)
        == flat[:, None]
    ).astype(jnp.float32)
    krow = _exact_gather_i32(oh_n, fk.reshape(bb * npb, ns))
    vrow = _exact_gather_i32(oh_n, fv.reshape(bb * npb, ns))

    pos = jnp.sum(krow < qcol, axis=1)
    pos_c = jnp.minimum(pos, ns - 1)
    oh_p = (
        jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], ns), 1)
        == pos_c[:, None]
    )
    key_at = jnp.sum(jnp.where(oh_p, krow, 0), axis=1)
    val_at = jnp.sum(jnp.where(oh_p, vrow, 0), axis=1)

    # POINT: hit iff the key is stored post-update
    hit_q = in_bucket & (pos < ns) & (key_at == qcol[:, 0])
    point_res = jnp.where(hit_q, val_at, _MISS)

    # SUCCESSOR: in-bucket candidate, else the post-update fence rows
    nxk = jnp.sum(jnp.where(oh_b, nxk_ref[0, :][None, :], 0), axis=1)
    nxv = jnp.sum(jnp.where(oh_b, nxv_ref[0, :][None, :], 0), axis=1)
    use_in = in_bucket & (pos < ns)
    succ_key = jnp.where(use_in, key_at, nxk)
    succ_val = jnp.where(use_in, val_at, nxv)
    found = succ_key != _EMPTY
    succ_val = jnp.where(found, succ_val, _MISS)

    is_p = t == _OP_POINT
    is_s = t == _OP_SUCCESSOR
    resv_ref[0, :] = jnp.where(
        mine & is_p,
        point_res,
        jnp.where(mine & is_s, succ_val, resv_ref[0, :]),
    )
    resk_ref[0, :] = jnp.where(mine & is_s, succ_key, resk_ref[0, :])

    # ---- phase 4: dense RANGE slots owned by this block's buckets ----
    # slot p carries the post-update global rank of its key; the block
    # claims p iff the rank falls in one of its buckets' [pref[b],
    # pref[b+1]) spans, then maps the in-bucket rank to a (node, pos) of
    # the stripe just rebuilt above (ocnt cumsum = node boundaries).
    # Valid slots are a prefix, so g[0] < 0 ⇔ nothing to emit — batches
    # with no RANGE output skip the gather compute entirely and keep the
    # PR-2 update-only cost (the init above already wrote EMPTY).
    @pl.when(g_ref[0, 0] >= 0)
    def _range_gather():
        g = g_ref[0, :]                        # [MR]
        gcol = g[:, None]
        ps = ps_ref[0, :][None, :]             # [1, BB]
        pe = pe_ref[0, :][None, :]
        bloc = jnp.sum((pe <= gcol).astype(jnp.int32), axis=1)
        bloc_c = jnp.minimum(bloc, bb - 1)
        oh_rb = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], bb), 1)
            == bloc_c[:, None]
        )
        ps_g = jnp.sum(jnp.where(oh_rb, ps, 0), axis=1)
        mine_r = (g >= 0) & (bloc < bb) & (g >= ps_g)
        r = g - ps_g                           # rank within the bucket

        cnt_rows = _exact_gather_i32(oh_rb.astype(jnp.float32), ocnt)
        cum = jnp.cumsum(cnt_rows, axis=1)     # [MR, npb]
        node_r = jnp.sum((cum <= r[:, None]).astype(jnp.int32), axis=1)
        node_rc = jnp.minimum(node_r, npb - 1)
        oh_nd = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], npb), 1)
            == node_rc[:, None]
        )
        base = jnp.sum(jnp.where(oh_nd, cum - cnt_rows, 0), axis=1)
        pos_r = jnp.clip(r - base, 0, ns - 1)

        flat_r = bloc_c * npb + node_rc
        oh_fr = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], bb * npb), 1)
            == flat_r[:, None]
        ).astype(jnp.float32)
        krow_r = _exact_gather_i32(oh_fr, fk.reshape(bb * npb, ns))
        vrow_r = _exact_gather_i32(oh_fr, fv.reshape(bb * npb, ns))
        oh_pr = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], ns), 1)
            == pos_r[:, None]
        )
        kk = jnp.sum(jnp.where(oh_pr, krow_r, 0), axis=1)
        vv = jnp.sum(jnp.where(oh_pr, vrow_r, 0), axis=1)
        rngk_ref[0, :] = jnp.where(mine_r, kk, rngk_ref[0, :])
        rngv_ref[0, :] = jnp.where(mine_r, vv, rngv_ref[0, :])


def _init_outputs(j, i, resv_ref, resk_ref, rngk_ref, rngv_ref):
    @pl.when(i == 0)
    def _init():
        resv_ref[...] = jnp.full_like(resv_ref, _MISS)
        resk_ref[...] = jnp.full_like(resk_ref, _EMPTY)

    # the RANGE output block is shared by every window (its slots belong to
    # buckets, not windows), so it is initialised exactly once — window 0's
    # full sweep then fills every owned slot, later windows rewrite
    # idempotently
    @pl.when((j == 0) & (i == 0))
    def _init_range():
        rngk_ref[...] = jnp.full_like(rngk_ref, _EMPTY)
        rngv_ref[...] = jnp.full_like(rngv_ref, _MISS)


def _apply_kernel(
    lo_ref,      # scalar prefetch: [n_windows] first bucket block of window
    hi_ref,      # scalar prefetch: [n_windows] last  bucket block of window
    t_ref,
    q_ref,
    keys_ref,    # [BB, npb*ns] bucket-block key stripes (auto-pipelined)
    vals_ref,    # [BB, npb*ns]
    *rest,
    block_b: int,
    npb: int,
    ns: int,
    cap: int,
):
    """Single-buffer variant: stripes stream through the BlockSpec pipeline."""
    j = pl.program_id(0)
    i = pl.program_id(1)
    _init_outputs(j, i, *rest[-4:])
    active = (i >= lo_ref[j]) & (i <= hi_ref[j])

    @pl.when(active)
    def _process():
        _stripe_body(
            keys_ref[...], vals_ref[...], t_ref, q_ref, *rest,
            block_b=block_b, npb=npb, ns=ns, cap=cap,
        )


def _apply_kernel_pipelined(
    lo_ref,      # scalar prefetch: [n_windows] first bucket block of window
    hi_ref,      # scalar prefetch: [n_windows] last  bucket block of window
    t_ref,
    q_ref,
    keys_hbm,    # [nb_p, npb*ns] FULL key stripes, HBM-resident (ANY space)
    vals_hbm,    # [nb_p, npb*ns]
    *rest,       # the remaining blocked inputs/outputs, then the scratch:
    #              kscr/vscr [2, BB, S] two-slot VMEM stripes, ksem/vsem
    #              DMA semaphores [2]
    block_b: int,
    npb: int,
    ns: int,
    cap: int,
    nb_blocks: int,
    n_windows: int,
):
    """Double-buffered variant: explicit two-slot bucket-stripe staging.

    The grid is sequential (``dimension_semantics=("arbitrary",
    "arbitrary")``), so scratch persists across steps: at linear step ``s``
    the kernel *starts* the async HBM→VMEM copy of step ``s+1``'s stripe
    block into slot ``(s+1) % 2``, then *waits* on slot ``s % 2`` — whose
    copy was issued one step earlier — and computes from it.  The next
    stripe's DMA therefore overlaps this stripe's merge/delete/read
    compute, which is the PR-10 pipelining contract (DESIGN.md §16).  Block
    indices are clipped exactly as the single-buffer BlockSpec index map
    clips them, and the stripe maths is `_stripe_body`, shared verbatim —
    the two variants are byte-identical by construction.

    The wait is unconditional (inactive steps still staged their block):
    every started copy is consumed, so semaphore counts can never leak into
    a later step.
    """
    j = pl.program_id(0)
    i = pl.program_id(1)
    kscr, vscr, ksem, vsem = rest[-4:]
    rest = rest[:-4]
    step = j * nb_blocks + i
    slot = jax.lax.rem(step, 2)

    def block_of(jj, ii):
        return jnp.clip(ii, lo_ref[jj], hi_ref[jj])

    def copies(b, sl):
        row = pl.ds(b * block_b, block_b)
        return (
            pltpu.make_async_copy(keys_hbm.at[row, :], kscr.at[sl], ksem.at[sl]),
            pltpu.make_async_copy(vals_hbm.at[row, :], vscr.at[sl], vsem.at[sl]),
        )

    @pl.when(step == 0)
    def _warm_up():
        for c in copies(block_of(j, i), slot):
            c.start()

    @pl.when(step + 1 < n_windows * nb_blocks)
    def _prefetch_next():
        nj = jnp.where(i + 1 < nb_blocks, j, j + 1)
        ni = jnp.where(i + 1 < nb_blocks, i + 1, 0)
        for c in copies(block_of(nj, ni), jax.lax.rem(step + 1, 2)):
            c.start()

    for c in copies(block_of(j, i), slot):
        c.wait()

    _init_outputs(j, i, *rest[-4:])
    active = (i >= lo_ref[j]) & (i <= hi_ref[j])

    @pl.when(active)
    def _process():
        _stripe_body(
            kscr[slot], vscr[slot], t_ref, q_ref, *rest,
            block_b=block_b, npb=npb, ns=ns, cap=cap,
        )


def _fused_apply(
    state, tag, key, val, *, block_q, block_b, max_results, interpret, pipeline
):
    """Trace the fused apply: returns (new_state, results, stats)."""
    from repro.core.ops import derive_type_views
    from repro.core.query import (
        _suffix_min_with_index,
        flat_rank,
        point_query,
        range_offsets,
        range_slot_ranks,
    )

    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    cap = state.bucket_capacity
    S = npb * ns
    n = key.shape[0]

    # --- the single routing + derived per-type views (shared with the
    # reference engine, so the routing contract cannot diverge) ------------
    _, _, ins_keys, ins_vals, del_keys, ins_starts, ins_ends = (
        derive_type_views(state, tag, key, val)
    )
    true_counts = (ins_ends - ins_starts).astype(jnp.int32)

    # per-bucket INSERT tiles (keys + aligned vals)
    ik, iv, _, _ = gather_kv_sublists(ins_keys, ins_vals, ins_starts, ins_ends, cap)

    # per-bucket DELETE tiles, pre-filtered to PRESENT keys so each bucket's
    # sublist fits its capacity tile (same trick as flix_delete; filtering
    # against the pre-insert state is exact because one batch never inserts
    # and deletes the same key).
    present = point_query(state, del_keys) != NOT_FOUND
    dk_sorted = jnp.sort(jnp.where(present, del_keys, EMPTY))
    dstarts, dends = bucket_slices(state, dk_sorted)
    dk_tile, _, _ = gather_sublists(dk_sorted, dstarts, dends, cap)

    # --- post-update successor fence rows (one O(nb) suffix scan) ---------
    # surviving stripe minimum: smallest stored key not in the delete batch
    flat_k = state.keys.reshape(nb, S)
    flat_v = state.vals.reshape(nb, S)
    dpos = jnp.searchsorted(del_keys, flat_k.reshape(-1), side="left")
    dpos = jnp.minimum(dpos, jnp.maximum(del_keys.shape[0] - 1, 0))
    dhit = (del_keys[dpos] == flat_k.reshape(-1)) & (flat_k.reshape(-1) != EMPTY)
    masked = jnp.where(dhit.reshape(nb, S), EMPTY, flat_k)
    surv_min = jnp.min(masked, axis=1)
    amin = jnp.argmin(masked, axis=1)
    surv_val = flat_v[jnp.arange(nb), amin]
    ins_min = ik[:, 0]                       # tiles are sorted, EMPTY-padded
    ins_val = iv[:, 0]
    bucket_min = jnp.minimum(surv_min, ins_min)
    # tie (same key upserted) → the incoming value wins
    min_val = jnp.where(ins_min <= surv_min, ins_val, surv_val)
    smin, sidx = _suffix_min_with_index(bucket_min)
    next_key = jnp.concatenate([smin[1:], jnp.array([EMPTY], KEY_DTYPE)])
    next_idx = jnp.concatenate([sidx[1:], jnp.array([0], jnp.int32)])
    next_val = min_val[next_idx]

    # --- post-update RANGE rank fences + per-slot ranks -------------------
    # same predict-without-running-the-update argument as the fence rows,
    # extended to the whole multiset: post-update bucket contents are
    # (survivors minus upsert duplicates) ∪ (insert slice) — exact because
    # one batch never inserts and deletes the same key.  Sorting those rows
    # gives per-bucket rank fences and every op's [lo, hi) full count; the
    # shared core.query formulas then fix the dense output layout.
    is_range = tag == _OP_RANGE

    def _range_plumbing():
        mflat = masked.reshape(-1)
        ipos = jnp.clip(
            jnp.searchsorted(ins_keys, mflat, side="left"), 0, max(n - 1, 0)
        )
        upserted = (ins_keys[ipos] == mflat) & (mflat != EMPTY)
        post_rows = jnp.concatenate(
            [jnp.where(upserted.reshape(nb, S), EMPTY, masked), ik], axis=1
        )
        post_sorted = jnp.sort(post_rows, axis=1)
        live_post = jnp.sum(post_sorted != EMPTY, axis=1).astype(jnp.int32)
        pref_post = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(live_post).astype(jnp.int32)]
        )
        rank_lo = flat_rank(post_sorted, pref_post, state.mkba, key)
        rank_hi = flat_rank(post_sorted, pref_post, state.mkba, val.astype(KEY_DTYPE))
        full = jnp.maximum(rank_hi - rank_lo, 0)
        rstart, remit, total_emit, rtrunc = range_offsets(full, is_range, max_results)
        g = range_slot_ranks(rank_lo, rstart, total_emit, max_results)
        return g, pref_post[:-1], pref_post[1:], rstart, remit, rtrunc

    # a batch with no RANGE ops skips the per-bucket post-state sort and
    # rank scans entirely (lax.cond executes one branch — no host sync, and
    # update-only fused steps keep their PR-2 cost); all slots dead (-1)
    # makes the kernel's pl.when skip the phase-4 gather compute too
    g, ps_row_post, pe_row_post, rstart, remit, rtrunc = jax.lax.cond(
        jnp.any(is_range),
        _range_plumbing,
        lambda: (
            jnp.full((max_results,), -1, jnp.int32),
            jnp.zeros((nb,), jnp.int32),
            jnp.zeros((nb,), jnp.int32),
            jnp.zeros((n,), jnp.int32),
            jnp.zeros((n,), jnp.int32),
            jnp.int32(0),
        ),
    )

    # --- pad buckets to a block multiple (EMPTY stripes merge to EMPTY) ---
    nb_p = pl.cdiv(nb, block_b) * block_b
    keys2d, vals2d, node_max, mkba = flat_k, flat_v, state.node_max, state.mkba
    if nb_p != nb:
        pad = nb_p - nb
        keys2d = jnp.pad(keys2d, ((0, pad), (0, 0)), constant_values=EMPTY)
        vals2d = jnp.pad(vals2d, ((0, pad), (0, 0)))
        node_max = jnp.pad(node_max, ((0, pad), (0, 0)), constant_values=EMPTY)
        mkba = jnp.pad(mkba, (0, pad), constant_values=EMPTY - 1)
        ik = jnp.pad(ik, ((0, pad), (0, 0)), constant_values=EMPTY)
        iv = jnp.pad(iv, ((0, pad), (0, 0)))
        dk_tile = jnp.pad(dk_tile, ((0, pad), (0, 0)), constant_values=EMPTY)
        next_key = jnp.pad(next_key, (0, pad), constant_values=EMPTY)
        next_val = jnp.pad(next_val, (0, pad))
        # padded buckets own no ranks: empty [total, total) spans
        total_post = pe_row_post[-1]
        ps_row_post = jnp.concatenate(
            [ps_row_post, jnp.full((pad,), total_post, jnp.int32)]
        )
        pe_row_post = jnp.concatenate(
            [pe_row_post, jnp.full((pad,), total_post, jnp.int32)]
        )
    lfence = jnp.concatenate(
        [jnp.array([jnp.iinfo(jnp.int32).min], KEY_DTYPE), mkba[:-1]]
    )
    mrp = pl.cdiv(max_results, 128) * 128
    g_row = jnp.pad(g, (0, mrp - max_results), constant_values=-1).reshape(1, mrp)

    # --- pad ops to a window multiple (NOP pads never match) --------------
    qp = pl.cdiv(max(n, 1), block_q) * block_q
    from repro.core.ops import OP_NOP

    tpad = jnp.pad(tag, (0, qp - n), constant_values=OP_NOP)
    qpad = jnp.pad(key.astype(KEY_DTYPE), (0, qp - n), constant_values=EMPTY)
    n_windows = qp // block_q
    t2 = tpad.reshape(n_windows, block_q)
    q2 = qpad.reshape(n_windows, block_q)

    # per-window bucket-block bounds; window 0 widens to the full sweep —
    # that is where every stripe's update pass is guaranteed to happen.
    first_b = jnp.searchsorted(mkba, q2[:, 0], side="left")
    last_b = jnp.searchsorted(mkba, q2[:, -1], side="left")
    nb_blocks = nb_p // block_b
    lo = jnp.minimum(first_b, nb_p - 1).astype(jnp.int32) // block_b
    hi = jnp.minimum(last_b, nb_p - 1).astype(jnp.int32) // block_b
    lo = lo.at[0].set(0)
    hi = hi.at[0].set(nb_blocks - 1)

    mkba_row = mkba.reshape(1, nb_p)
    lf_row = lfence.reshape(1, nb_p)
    nxk_row = next_key.reshape(1, nb_p)
    nxv_row = next_val.reshape(1, nb_p)
    ps_row = ps_row_post.reshape(1, nb_p)
    pe_row = pe_row_post.reshape(1, nb_p)

    def bucket_map(j, i, lo_ref, hi_ref):
        return (jnp.clip(i, lo_ref[j], hi_ref[j]), 0)

    def fence_map(j, i, lo_ref, hi_ref):
        return (0, jnp.clip(i, lo_ref[j], hi_ref[j]))

    def window_map(j, i, lo_ref, hi_ref):
        return (j, 0)

    # the pipelined variant stages the big stripe planes itself: keys/vals
    # stay HBM-resident (ANY memory space) and a two-slot VMEM scratch +
    # DMA semaphore pair per plane double-buffers them across grid steps;
    # everything else keeps the automatic BlockSpec pipeline either way
    if pipeline:
        stripe_specs = [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ]
        scratch_shapes = [
            pltpu.VMEM((2, block_b, S), jnp.int32),
            pltpu.VMEM((2, block_b, S), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ]
        kernel = functools.partial(
            _apply_kernel_pipelined,
            block_b=block_b,
            npb=npb,
            ns=ns,
            cap=cap,
            nb_blocks=nb_blocks,
            n_windows=n_windows,
        )
    else:
        stripe_specs = [
            pl.BlockSpec((block_b, S), bucket_map),
            pl.BlockSpec((block_b, S), bucket_map),
        ]
        scratch_shapes = []
        kernel = functools.partial(
            _apply_kernel, block_b=block_b, npb=npb, ns=ns, cap=cap
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_windows, nb_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q), window_map),
            pl.BlockSpec((1, block_q), window_map),
            *stripe_specs,
            pl.BlockSpec((block_b, npb), bucket_map),
            pl.BlockSpec((block_b, cap), bucket_map),
            pl.BlockSpec((block_b, cap), bucket_map),
            pl.BlockSpec((block_b, cap), bucket_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, mrp), lambda j, i, lo, hi: (0, 0)),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
        ],
        out_specs=[
            pl.BlockSpec((block_b, S), bucket_map),
            pl.BlockSpec((block_b, S), bucket_map),
            pl.BlockSpec((block_b, npb), bucket_map),
            pl.BlockSpec((block_b, npb), bucket_map),
            pl.BlockSpec((block_b, 1), bucket_map),
            pl.BlockSpec((block_b, 1), bucket_map),
            pl.BlockSpec((block_b, 1), bucket_map),
            pl.BlockSpec((1, block_q), window_map),
            pl.BlockSpec((1, block_q), window_map),
            pl.BlockSpec((1, mrp), lambda j, i, lo, hi: (0, 0)),
            pl.BlockSpec((1, mrp), lambda j, i, lo, hi: (0, 0)),
        ],
        scratch_shapes=scratch_shapes,
    )

    (
        okeys,
        ovals,
        ocnt,
        omax,
        onn,
        oflow,
        odel,
        resv,
        resk,
        rngk,
        rngv,
    ) = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb_p, S), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, S), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
            jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
            jax.ShapeDtypeStruct((1, mrp), jnp.int32),
            jax.ShapeDtypeStruct((1, mrp), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
    )(
        lo,
        hi,
        t2,
        q2,
        keys2d,
        vals2d,
        node_max,
        ik,
        iv,
        dk_tile,
        mkba_row,
        lf_row,
        nxk_row,
        nxv_row,
        g_row,
        ps_row,
        pe_row,
    )

    slice_overflow = true_counts > cap
    any_overflow = (jnp.sum(oflow[:nb]) > 0) | jnp.any(slice_overflow)
    new_state = FliXState(
        keys=okeys[:nb].reshape(nb, npb, ns),
        vals=ovals[:nb].reshape(nb, npb, ns),
        node_count=ocnt[:nb],
        node_max=omax[:nb],
        num_nodes=onn[:nb, 0],
        mkba=state.mkba,
        needs_restructure=state.needs_restructure | any_overflow,
    )
    results = {
        "value": resv.reshape(qp)[:n],
        "succ_key": resk.reshape(qp)[:n],
        "range_key": rngk[0, :max_results],
        "range_val": rngv[0, :max_results],
        "range_start": jnp.where(is_range, rstart, 0),
        "range_count": jnp.where(is_range, remit, 0),
    }
    stats = {
        "inserted": jnp.sum(jnp.minimum(true_counts, cap)),
        "deleted": jnp.sum(odel[:nb]),
        "overflowed_buckets": jnp.sum(
            (oflow[:nb, 0] > 0) | slice_overflow
        ),
        "range_truncated": rtrunc,
    }
    return new_state, results, stats


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_b", "max_results", "interpret", "pipeline"),
)
def flix_apply_pallas(
    state: FliXState,
    tag: jax.Array,
    key: jax.Array,
    val: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_b: int = DEFAULT_BLOCK_B,
    max_results: int = 128,
    interpret: bool = False,
    pipeline: bool = False,
):
    """Fused mixed-batch apply.  Same contract as ``core.ops.apply_ops``.

    ``pipeline=True`` selects the double-buffered bucket-stripe variant
    (`_apply_kernel_pipelined`): explicit two-slot scratch + async-copy
    staging so the next stripe's HBM→VMEM transfer overlaps the current
    stripe's compute.  Byte-identical to ``pipeline=False`` — the stripe
    maths is shared — and works in interpret mode, which is how the
    differential suite proves it off-TPU."""
    return _fused_apply(
        state,
        tag,
        key,
        val,
        block_q=block_q,
        block_b=block_b,
        max_results=max_results,
        interpret=interpret,
        pipeline=pipeline,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_b", "max_results", "interpret", "pipeline"),
    donate_argnums=(0,),
)
def flix_apply_pallas_donated(
    state: FliXState,
    tag: jax.Array,
    key: jax.Array,
    val: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_b: int = DEFAULT_BLOCK_B,
    max_results: int = 128,
    interpret: bool = False,
    pipeline: bool = False,
):
    """Donating variant: the input state's buffers are handed to XLA so step
    N+1's stripes reuse step N's allocation instead of copying.  The caller
    must not touch ``state`` afterwards — in particular the restructure-and-
    retry driver (``apply_ops_safe``) must use the non-donating entry, since
    a retry replays the batch on the *pre-batch* state."""
    return _fused_apply(
        state,
        tag,
        key,
        val,
        block_q=block_q,
        block_b=block_b,
        max_results=max_results,
        interpret=interpret,
        pipeline=pipeline,
    )
