"""Deterministic tile autotuner for the fused apply kernel (DESIGN.md §16).

The fused kernel's two tile knobs trade off against each other:

  * ``block_q`` — ops per window.  Larger windows mean fewer grid passes
    over the bucket blocks, but each window revisits every bucket block its
    op span touches, so an oversized window drags cold stripes through VMEM
    for a handful of ops.
  * ``block_b`` — bucket stripes per block.  The merge/delete masks are
    O(block_b · S²), and the double-buffered variant holds **two** stripe
    blocks in VMEM at once, so ``block_b`` is bounded by VMEM long before
    it stops helping amortize grid overhead.

The right point depends on (build_size, batch_size), which is exactly the
:class:`~repro.core.config.TileTable` key.  This module sweeps the
candidate grid per size bucket and records one winner per bucket:

  * **model mode** (default): a closed-form cost model scores every
    candidate — VMEM feasibility, per-step merge cost, window revisit
    traffic, and fixed grid overhead.  Pure integer arithmetic on the
    requested sizes: the same sweep on any host picks the same tiles, which
    is what lets the committed bench artifact embed the table and the
    determinism test pin it.
  * **measure mode** (``measure=True``): wall-clock the fused kernel per
    feasible candidate on a synthetic build and take the best median.
    Opt-in, machine-dependent — for producing a table on real hardware, not
    for CI.

Either way the output is plain data: a ``TileTable`` (drops straight into
``ExecConfig(tile_table=...)``) plus a JSON-ready sweep record that
``benchmarks/run.py`` embeds in the bench artifact.
"""

from __future__ import annotations

import math

from repro.core.config import TileTable, _pow2_bucket

# candidate grid — DEFAULT_BLOCK_Q (flix_query) and DEFAULT_BLOCK_B
# (flix_apply) are both members, so the tuned table can only match or beat
# the static defaults under the model
CANDIDATE_BLOCK_Q = (128, 256, 512)
CANDIDATE_BLOCK_B = (1, 2, 4, 8)

# VMEM budget the model holds a candidate to.  Real TPU cores have ~16 MiB;
# the margin leaves room for the compiler's own temporaries.
VMEM_BUDGET_BYTES = 12 * 2**20
_I32 = 4  # bytes


def vmem_bytes(block_q: int, block_b: int, *, node_size: int, nodes_per_bucket: int,
               max_results: int = 128) -> int:
    """Model of the kernel's VMEM residency for one grid step.

    Counts the double-buffered worst case (two stripe blocks live at once)
    plus the O(block_b · S²) merge one-hots, which dominate everything else
    for realistic S.
    """
    S = node_size * nodes_per_bucket
    cap = S  # bucket_capacity == npb * ns
    stripes = 2 * 2 * block_b * S            # two planes × two slots
    merge = 2 * block_b * S * S              # ohA/mask temporaries [BB, S, S]
    tiles = 3 * block_b * cap                # ik / iv / dk
    meta = 2 * block_b * nodes_per_bucket    # node_max + counts
    window = 4 * block_q                     # tags, keys, resv, resk
    fences = 8 * block_b                     # mkba/lf/nxk/nxv/ps/pe rows
    rng = 3 * max_results
    return _I32 * (stripes + merge + tiles + meta + window + fences + rng)


def model_cost(
    block_q: int,
    block_b: int,
    *,
    build_size: int,
    batch_size: int,
    node_size: int,
    nodes_per_bucket: int,
) -> float:
    """Deterministic cost score for one candidate (lower is better).

    Grid shape: ``n_windows × nb_blocks`` steps.  Window 0 sweeps every
    bucket block (the full update pass); each later window revisits the
    ≈ ``block_q / batch`` fraction of the key space its sorted ops span.
    Active steps pay the O(block_b · S²) merge plus per-op read compute;
    every step — active or not — pays a fixed dispatch overhead, which is
    what large tiles amortize.
    """
    S = node_size * nodes_per_bucket
    nb = max(1, math.ceil(build_size / S))
    nb_p = math.ceil(nb / block_b) * block_b
    nb_blocks = nb_p // block_b
    n = max(1, batch_size)
    n_windows = math.ceil(n / block_q)

    # sorted ops: one window's span of the bucket-block axis
    span = min(nb_blocks, math.ceil(nb_blocks * block_q / n) + 1)
    active = nb_blocks + (n_windows - 1) * span
    total = n_windows * nb_blocks

    merge = block_b * S * S          # phase-1/2 masks per active step
    reads = block_q * (block_b + nodes_per_bucket + node_size)
    step_overhead = 4096             # dispatch + pipeline bubble per step
    return float(active * (merge + reads) + total * step_overhead)


def sweep_bucket(
    build_size: int,
    batch_size: int,
    *,
    node_size: int = 16,
    nodes_per_bucket: int = 8,
    candidates_q=CANDIDATE_BLOCK_Q,
    candidates_b=CANDIDATE_BLOCK_B,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    measure: bool = False,
) -> dict:
    """Score every candidate for one (build, batch) bucket; pick the winner.

    Returns a JSON-ready record: the bucket, every candidate's score and
    feasibility, and the chosen ``(block_q, block_b)``.  Ties break on the
    sorted candidate order, so the sweep is a pure function of its inputs.
    """
    rows = []
    for bq in sorted(candidates_q):
        for bb in sorted(candidates_b):
            vb = vmem_bytes(
                bq, bb, node_size=node_size, nodes_per_bucket=nodes_per_bucket
            )
            feasible = vb <= vmem_budget
            cost = (
                model_cost(
                    bq,
                    bb,
                    build_size=build_size,
                    batch_size=batch_size,
                    node_size=node_size,
                    nodes_per_bucket=nodes_per_bucket,
                )
                if feasible
                else None
            )
            rows.append(
                {
                    "block_q": bq,
                    "block_b": bb,
                    "vmem_bytes": vb,
                    "feasible": feasible,
                    "model_cost": cost,
                }
            )
    feas = [r for r in rows if r["feasible"]]
    if not feas:  # pathological geometry: fall back to the smallest tiles
        feas = [rows[0]]
        feas[0]["model_cost"] = 0.0
    if measure:
        _measure_rows(
            feas,
            build_size=build_size,
            batch_size=batch_size,
            node_size=node_size,
            nodes_per_bucket=nodes_per_bucket,
        )
        key = lambda r: (r["wall_s"], r["block_q"], r["block_b"])
    else:
        key = lambda r: (r["model_cost"], r["block_q"], r["block_b"])
    best = min(feas, key=key)
    return {
        "build_bucket": _pow2_bucket(build_size),
        "batch_bucket": _pow2_bucket(batch_size),
        "block_q": best["block_q"],
        "block_b": best["block_b"],
        "measured": bool(measure),
        "candidates": rows,
    }


def _measure_rows(rows, *, build_size, batch_size, node_size, nodes_per_bucket):
    """Wall-clock each feasible candidate on a synthetic mixed batch
    (opt-in: timings are machine truth, not reproducible model truth)."""
    import time

    import jax
    import numpy as np

    from repro.core.build import build
    from repro.core.config import ExecConfig
    from repro.core.ops import OP_INSERT, OP_POINT, apply_ops, make_ops

    rng = np.random.default_rng(0)
    keys = rng.choice(build_size * 8, size=build_size, replace=False)
    state = build(
        keys, np.arange(build_size),
        node_size=node_size, nodes_per_bucket=nodes_per_bucket,
    )
    half = max(1, batch_size // 2)
    qk = rng.choice(keys, size=half)
    ik = rng.choice(build_size * 8, size=batch_size - half) | 1
    tags = np.concatenate([np.full(half, OP_POINT), np.full(batch_size - half, OP_INSERT)])
    ops, _ = make_ops(tags, np.concatenate([qk, ik]), np.concatenate([qk, ik]))
    for r in rows:
        cfg = ExecConfig(impl="fused", block_q=r["block_q"], block_b=r["block_b"])
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            out = apply_ops(state, ops, config=cfg)
            jax.block_until_ready(out[0].keys)
            times.append(time.perf_counter() - t0)
        r["wall_s"] = sorted(times)[1]


def autotune(
    build_sizes,
    batch_sizes,
    *,
    node_size: int = 16,
    nodes_per_bucket: int = 8,
    candidates_q=CANDIDATE_BLOCK_Q,
    candidates_b=CANDIDATE_BLOCK_B,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    measure: bool = False,
) -> tuple[TileTable, dict]:
    """Sweep the cross product of size buckets → (TileTable, sweep record).

    The table is ready to thread through ``ExecConfig(tile_table=...)``;
    the record is JSON-ready for the bench artifact and round-trips back
    via ``TileTable.from_json(record["table"])``.
    """
    sweeps = []
    entries = {}
    for build in sorted({_pow2_bucket(b) for b in build_sizes}):
        for batch in sorted({_pow2_bucket(q) for q in batch_sizes}):
            rec = sweep_bucket(
                build,
                batch,
                node_size=node_size,
                nodes_per_bucket=nodes_per_bucket,
                candidates_q=candidates_q,
                candidates_b=candidates_b,
                vmem_budget=vmem_budget,
                measure=measure,
            )
            sweeps.append(rec)
            entries[(build, batch)] = (rec["block_q"], rec["block_b"])
    table = TileTable(
        entries=tuple(
            (build, batch, bq, bb)
            for (build, batch), (bq, bb) in sorted(entries.items())
        )
    )
    record = {
        "node_size": node_size,
        "nodes_per_bucket": nodes_per_bucket,
        "vmem_budget_bytes": vmem_budget,
        "measured": bool(measure),
        "table": table.to_json(),
        "sweeps": sweeps,
    }
    return table, record
