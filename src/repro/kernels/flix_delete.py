"""Pallas TPU kernel for TL-Bulk deletion (paper §4.4, Table 3).

Per bucket block, entirely in VMEM:
  1. membership mark: every stored key is compared against the bucket's
     delete sublist (the tile-ballot analogue is a broadcast equality
     reduce),
  2. in-node compaction: survivors shift left by the number of preceding
     deletions (lane cumsum → one-hot reposition),
  3. chain compaction: emptied nodes drop out of the slot order and their
     slots are reclaimed,
  4. metadata (node_count / node_max / num_nodes) recomputed on the fly.

The wrapper materializes per-bucket delete sublists as a padded [nb, L]
tile (the flipped-indexing pull, same boundaries as the jnp path); the
kernel is then a pure bucket-block map with no cross-block traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams

from repro.core.batch import bucket_slices, gather_sublists
from repro.core.state import EMPTY, KEY_DTYPE, FliXState

DEFAULT_BLOCK_B = 4
_EMPTY = int(jnp.iinfo(jnp.int32).max)


def _reposition(rows: jax.Array, dest: jax.Array, keep: jax.Array, width: int):
    """new[i] = rows[j] where dest[j] == i and keep[j]; EMPTY elsewhere.

    rows/dest/keep: [..., width].  One-hot masked-sum (gather-free scatter).
    """
    lane = jax.lax.broadcasted_iota(jnp.int32, rows.shape + (width,), rows.ndim)
    oh = (dest[..., None] == lane) & keep[..., None]
    vals = jnp.where(oh, rows[..., None], 0)
    out = jnp.sum(vals, axis=-2)
    filled = jnp.any(oh, axis=-2)
    return jnp.where(filled, out, _EMPTY)


def _delete_kernel(
    keys_ref,   # [BB, npb, ns]
    vals_ref,   # [BB, npb, ns]
    del_ref,    # [BB, L] sorted per-bucket delete sublists (EMPTY-padded)
    okeys_ref,  # [BB, npb, ns]
    ovals_ref,  # [BB, npb, ns]
    ocnt_ref,   # [BB, npb] int32
    omax_ref,   # [BB, npb] int32
    onn_ref,    # [BB, 1] int32
    *,
    npb: int,
    ns: int,
):
    keys = keys_ref[...]
    vals = vals_ref[...]
    dels = del_ref[...]
    bb = keys.shape[0]

    # 1. membership mark: [BB, npb*ns] vs [BB, L] broadcast equality
    flat = keys.reshape(bb, npb * ns)
    hit = jnp.any(flat[:, :, None] == dels[:, None, :], axis=-1)
    hit &= flat != _EMPTY
    deleted = hit.reshape(bb, npb, ns)

    # 2. in-node compaction: dest = #kept before me (cumsum over the lane)
    keep = (~deleted) & (keys != _EMPTY)
    dest = jnp.cumsum(keep.astype(jnp.int32), axis=-1) - 1
    new_keys = _reposition(keys, dest, keep, ns)
    new_vals = jnp.where(new_keys == _EMPTY, 0, _reposition(vals, dest, keep, ns))
    cnt = jnp.sum(keep.astype(jnp.int32), axis=-1)            # [BB, npb]

    # 3. chain compaction: surviving nodes shift into the lowest slots
    nonempty = cnt > 0
    slot_dest = jnp.cumsum(nonempty.astype(jnp.int32), axis=-1) - 1
    slot_lane = jax.lax.broadcasted_iota(jnp.int32, (bb, npb, npb), 2)
    oh = (slot_dest[:, :, None] == slot_lane) & nonempty[:, :, None]
    # move whole rows: [BB, src npb, dst npb] x [BB, src npb, ns]
    moved_k = jnp.sum(jnp.where(oh[..., None], new_keys[:, :, None, :], 0), axis=1)
    moved_v = jnp.sum(jnp.where(oh[..., None], new_vals[:, :, None, :], 0), axis=1)
    row_filled = jnp.any(oh, axis=1)                          # [BB, npb]
    okeys = jnp.where(row_filled[..., None], moved_k, _EMPTY)
    ovals = jnp.where(row_filled[..., None], moved_v, 0)

    # 4. metadata
    ocnt = jnp.sum((okeys != _EMPTY).astype(jnp.int32), axis=-1)
    last = jnp.maximum(ocnt - 1, 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, npb, ns), 2)
    omax = jnp.sum(jnp.where(lane == last[..., None], okeys, 0), axis=-1)
    omax = jnp.where(ocnt > 0, omax, _EMPTY)

    okeys_ref[...] = okeys
    ovals_ref[...] = ovals
    ocnt_ref[...] = ocnt
    omax_ref[...] = omax
    onn_ref[...] = jnp.sum((ocnt > 0).astype(jnp.int32), axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def flix_delete_pallas(
    state: FliXState,
    sorted_del_keys: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    """TL-Bulk deletion via the Pallas kernel. Returns the new FliXState."""
    from repro.core.query import point_query

    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    cap = state.bucket_capacity
    dk = sorted_del_keys.astype(KEY_DTYPE)
    # pre-filter to PRESENT keys so every bucket's sublist fits its capacity
    # tile (a bucket can't hold more than `cap` live keys, but a raw batch
    # may aim arbitrarily many absent keys at one bucket's range).
    present = point_query(state, dk) != -1
    dk = jnp.sort(jnp.where(present, dk, EMPTY))
    starts, ends = bucket_slices(state, dk)
    del_tile, _, _ = gather_sublists(dk, starts, ends, cap)   # [nb, cap]

    nb_p = pl.cdiv(nb, block_b) * block_b
    keys = state.keys
    vals = state.vals
    if nb_p != nb:
        pad = nb_p - nb
        keys = jnp.pad(keys, ((0, pad), (0, 0), (0, 0)), constant_values=EMPTY)
        vals = jnp.pad(vals, ((0, pad), (0, 0), (0, 0)))
        del_tile = jnp.pad(del_tile, ((0, pad), (0, 0)), constant_values=EMPTY)

    grid = (nb_p // block_b,)
    bmap3 = pl.BlockSpec((block_b, npb, ns), lambda i: (i, 0, 0))
    bmap2 = pl.BlockSpec((block_b, npb), lambda i: (i, 0))

    okeys, ovals, ocnt, omax, onn = pl.pallas_call(
        functools.partial(_delete_kernel, npb=npb, ns=ns),
        grid=grid,
        in_specs=[
            bmap3,
            bmap3,
            pl.BlockSpec((block_b, cap), lambda i: (i, 0)),
        ],
        out_specs=[
            bmap3,
            bmap3,
            bmap2,
            bmap2,
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_p, npb, ns), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, npb, ns), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb_p, 1), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
    )(keys, vals, del_tile)

    return FliXState(
        keys=okeys[:nb],
        vals=ovals[:nb],
        node_count=ocnt[:nb],
        node_max=omax[:nb],
        num_nodes=onn[:nb, 0],
        mkba=state.mkba,
        needs_restructure=state.needs_restructure,
    )
