"""Pallas TPU kernel for FliX flipped point queries (paper §3.3, Figure 4).

Compute-to-bucket mapping on a TPU:

  * grid = (query windows, bucket blocks).  The window dimension is outer,
    so each (1, QB) query block and its output stay VMEM-resident while the
    bucket blocks that window needs stream through.
  * scalar-prefetched per-window bucket-block bounds ``lo[j]``/``hi[j]``
    drive the bucket BlockSpec index_map: steps outside a window's range
    *clamp to the boundary block index*, so Pallas issues **no DMA** for
    them (same-index blocks are not refetched) and ``pl.when`` skips the
    compute — the TPU analogue of the paper's "bucket with no queries
    terminates immediately".
  * inside the kernel every lookup is a compare-count (the tile-ballot
    analogue) plus a one-hot MXU matmul gather: int32 rows are split into
    two exact f16-range halves so the gather is exact in f32 arithmetic —
    this is the TPU-idiomatic replacement for the warp's per-thread gather.

VMEM working set per step: QB queries + one (BB, npb, ns) bucket stripe
(keys+vals) + (BB, npb) node maxes + fences — all shaped by the BlockSpecs
below; defaults (QB=128, BB=8, npb≤32, ns≤64) stay well under 1 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core.state import EMPTY, KEY_DTYPE

DEFAULT_BLOCK_Q = 128   # queries per window
DEFAULT_BLOCK_B = 8     # buckets per bucket block
_MISS = -1              # NOT_FOUND as a Python literal (kernels must not
                        # capture traced constants)


def _exact_gather_i32(onehot_f32: jax.Array, table_i32: jax.Array) -> jax.Array:
    """Exact int32 row gather as two f32 MXU matmuls (hi/lo 16-bit split)."""
    u = table_i32.astype(jnp.uint32)
    lo = (u & jnp.uint32(0xFFFF)).astype(jnp.float32)
    hi = (u >> jnp.uint32(16)).astype(jnp.float32)
    glo = jax.lax.dot(onehot_f32, lo, preferred_element_type=jnp.float32)
    ghi = jax.lax.dot(onehot_f32, hi, preferred_element_type=jnp.float32)
    out = ghi.astype(jnp.uint32) * jnp.uint32(65536) + glo.astype(jnp.uint32)
    return out.astype(jnp.int32)


def _query_kernel(
    lo_ref,      # scalar prefetch: [n_windows] first bucket block of window
    hi_ref,      # scalar prefetch: [n_windows] last  bucket block of window
    q_ref,       # [1, QB] sorted queries for window j
    keys_ref,    # [BB, npb*ns] bucket-block key stripes (chain order)
    vals_ref,    # [BB, npb*ns]
    nmax_ref,    # [BB, npb] per-node max keys (EMPTY when inactive)
    mkba_ref,    # [1, BB] bucket fences for the block
    lf_ref,      # [1, BB] lower fences (previous bucket's mkba)
    out_ref,     # [1, QB] values / NOT_FOUND
    *,
    block_b: int,
    npb: int,
    ns: int,
):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _MISS)

    active = (i >= lo_ref[j]) & (i <= hi_ref[j])

    @pl.when(active)
    def _process():
        blk = jnp.clip(i, lo_ref[j], hi_ref[j])
        q = q_ref[0, :]                                   # [QB]
        qcol = q[:, None]                                 # [QB, 1]

        # which local bucket owns each query (compare-count over fences)
        mkba = mkba_ref[0, :][None, :]                    # [1, BB]
        b_local = jnp.sum(mkba < qcol, axis=1)            # [QB]
        lf = lf_ref[0, :][None, :]
        b_sel = jnp.minimum(b_local, block_b - 1)
        oh_b = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], block_b), 1)
            == b_sel[:, None]
        )
        # ownership: q must exceed its bucket's lower fence and fall in block
        lf_q = jnp.sum(jnp.where(oh_b, lf, 0), axis=1)
        mine = (b_local < block_b) & (qcol[:, 0] > lf_q)

        # locate node: compare-count over the bucket's node maxes
        nmax_rows = _exact_gather_i32(
            oh_b.astype(jnp.float32), nmax_ref[...]
        )                                                  # [QB, npb]
        nidx = jnp.sum(nmax_rows < qcol, axis=1)           # [QB]
        nidx_c = jnp.minimum(nidx, npb - 1)

        # gather the node row (keys+vals) with a flat one-hot over BB*npb
        flat = b_sel * npb + nidx_c                        # [QB]
        oh_n = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], block_b * npb), 1)
            == flat[:, None]
        ).astype(jnp.float32)
        krow = _exact_gather_i32(oh_n, keys_ref[...].reshape(block_b * npb, ns))
        vrow = _exact_gather_i32(oh_n, vals_ref[...].reshape(block_b * npb, ns))

        # in-node position by compare-count; hit iff the key matches
        pos = jnp.sum(krow < qcol, axis=1)
        pos_c = jnp.minimum(pos, ns - 1)
        oh_p = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], ns), 1)
            == pos_c[:, None]
        )
        key_at = jnp.sum(jnp.where(oh_p, krow, 0), axis=1)
        val_at = jnp.sum(jnp.where(oh_p, vrow, 0), axis=1)
        hit = mine & (pos < ns) & (key_at == qcol[:, 0])

        out_ref[0, :] = jnp.where(hit, val_at, out_ref[0, :])


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_b", "interpret"),
)
def flix_point_query_pallas(
    keys3d: jax.Array,      # [nb, npb, ns] int32
    vals3d: jax.Array,      # [nb, npb, ns] int32
    node_max: jax.Array,    # [nb, npb] int32
    mkba: jax.Array,        # [nb] int32
    sorted_queries: jax.Array,  # [Q] int32, ascending
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    nb, npb, ns = keys3d.shape
    qn = sorted_queries.shape[0]

    # pad buckets to a block multiple (EMPTY stripes never match)
    nb_p = pl.cdiv(nb, block_b) * block_b
    if nb_p != nb:
        pad = nb_p - nb
        keys3d = jnp.pad(keys3d, ((0, pad), (0, 0), (0, 0)), constant_values=EMPTY)
        vals3d = jnp.pad(vals3d, ((0, pad), (0, 0), (0, 0)))
        node_max = jnp.pad(node_max, ((0, pad), (0, 0)), constant_values=EMPTY)
        mkba = jnp.pad(mkba, (0, pad), constant_values=EMPTY - 1)
    lfence = jnp.concatenate(
        [jnp.array([jnp.iinfo(jnp.int32).min], KEY_DTYPE), mkba[:-1]]
    )

    # pad queries to a window multiple (EMPTY-1 pads resolve to NOT_FOUND)
    qp = pl.cdiv(max(qn, 1), block_q) * block_q
    q = jnp.pad(
        sorted_queries.astype(KEY_DTYPE), (0, qp - qn), constant_values=EMPTY - 1
    )
    n_windows = qp // block_q
    q2 = q.reshape(n_windows, block_q)

    # per-window bucket-block bounds (the flipped-index pre-pass)
    first_b = jnp.searchsorted(mkba, q2[:, 0], side="left")
    last_b = jnp.searchsorted(mkba, q2[:, -1], side="left")
    lo = jnp.minimum(first_b, nb_p - 1).astype(jnp.int32) // block_b
    hi = jnp.minimum(last_b, nb_p - 1).astype(jnp.int32) // block_b

    nb_blocks = nb_p // block_b
    keys2d = keys3d.reshape(nb_p, npb * ns)
    vals2d = vals3d.reshape(nb_p, npb * ns)
    mkba_row = mkba.reshape(1, nb_p)
    lf_row = lfence.reshape(1, nb_p)

    def bucket_map(j, i, lo_ref, hi_ref):
        return (jnp.clip(i, lo_ref[j], hi_ref[j]), 0)

    def fence_map(j, i, lo_ref, hi_ref):
        return (0, jnp.clip(i, lo_ref[j], hi_ref[j]))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_windows, nb_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
            pl.BlockSpec((block_b, npb * ns), bucket_map),
            pl.BlockSpec((block_b, npb * ns), bucket_map),
            pl.BlockSpec((block_b, npb), bucket_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
    )

    out = pl.pallas_call(
        functools.partial(_query_kernel, block_b=block_b, npb=npb, ns=ns),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
    )(lo, hi, q2, keys2d, vals2d, node_max, mkba_row, lf_row)
    return out.reshape(qp)[:qn]
