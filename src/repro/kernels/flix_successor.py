"""Pallas TPU kernel for FliX flipped successor queries (paper §3.3 applied
to the ordered-CDS capability hash-table competitors lack).

Same compute-to-bucket mapping as ``flix_query``:

  * grid = (query windows, bucket blocks); scalar-prefetched per-window
    bucket-block bounds clamp out-of-range steps so they issue no DMA and
    skip compute,
  * inside the kernel the in-bucket candidate is the standard compare-count
    pair (node by node-max votes, position by key votes) plus exact one-hot
    gathers,
  * the out-of-bucket candidate (bucket's largest present key < q) cannot be
    resolved block-locally — the next non-empty bucket may live in a later
    block — so the wrapper precomputes two per-bucket fence-like rows with
    one O(nb) suffix scan: ``next_key[b]`` / ``next_val[b]`` = the smallest
    key (and its value) stored in any bucket after ``b``.  They stream
    through the same fence BlockSpec as the MKBA row, and the kernel picks
    in-bucket vs next-bucket per query.

Semantics are identical to ``core.query.successor_query``:
returns (succ_key | EMPTY, succ_val | NOT_FOUND) per query.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.flix_query import (
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_Q,
    _exact_gather_i32,
)
from repro.core.state import EMPTY, KEY_DTYPE

_EMPTY = int(jnp.iinfo(jnp.int32).max)
_MISS = -1


def _successor_kernel(
    lo_ref,      # scalar prefetch: [n_windows] first bucket block of window
    hi_ref,      # scalar prefetch: [n_windows] last  bucket block of window
    q_ref,       # [1, QB] sorted queries for window j
    keys_ref,    # [BB, npb*ns] bucket-block key stripes (chain order)
    vals_ref,    # [BB, npb*ns]
    nmax_ref,    # [BB, npb] per-node max keys (EMPTY when inactive)
    mkba_ref,    # [1, BB] bucket fences for the block
    lf_ref,      # [1, BB] lower fences
    nxk_ref,     # [1, BB] smallest key stored after bucket b (EMPTY if none)
    nxv_ref,     # [1, BB] its value
    outk_ref,    # [1, QB] successor keys / EMPTY
    outv_ref,    # [1, QB] successor values / NOT_FOUND
    *,
    block_b: int,
    npb: int,
    ns: int,
):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        outk_ref[...] = jnp.full_like(outk_ref, _EMPTY)
        outv_ref[...] = jnp.full_like(outv_ref, _MISS)

    active = (i >= lo_ref[j]) & (i <= hi_ref[j])

    @pl.when(active)
    def _process():
        q = q_ref[0, :]                                   # [QB]
        qcol = q[:, None]                                 # [QB, 1]

        # which local bucket owns each query (compare-count over fences)
        mkba = mkba_ref[0, :][None, :]                    # [1, BB]
        b_local = jnp.sum(mkba < qcol, axis=1)            # [QB]
        lf = lf_ref[0, :][None, :]
        b_sel = jnp.minimum(b_local, block_b - 1)
        oh_b = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], block_b), 1)
            == b_sel[:, None]
        )
        lf_q = jnp.sum(jnp.where(oh_b, lf, 0), axis=1)
        mine = (b_local < block_b) & (qcol[:, 0] > lf_q)

        # in-bucket candidate: node by node-max votes, position by key votes
        nmax_rows = _exact_gather_i32(
            oh_b.astype(jnp.float32), nmax_ref[...]
        )                                                  # [QB, npb]
        nidx = jnp.sum(nmax_rows < qcol, axis=1)           # [QB]
        n_active = jnp.sum((nmax_rows != _EMPTY).astype(jnp.int32), axis=1)
        in_bucket = nidx < n_active
        nidx_c = jnp.minimum(nidx, npb - 1)

        flat = b_sel * npb + nidx_c                        # [QB]
        oh_n = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], block_b * npb), 1)
            == flat[:, None]
        ).astype(jnp.float32)
        krow = _exact_gather_i32(oh_n, keys_ref[...].reshape(block_b * npb, ns))
        vrow = _exact_gather_i32(oh_n, vals_ref[...].reshape(block_b * npb, ns))

        pos = jnp.sum(krow < qcol, axis=1)
        pos_c = jnp.minimum(pos, ns - 1)
        oh_p = (
            jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], ns), 1)
            == pos_c[:, None]
        )
        in_key = jnp.sum(jnp.where(oh_p, krow, 0), axis=1)
        in_val = jnp.sum(jnp.where(oh_p, vrow, 0), axis=1)

        # out-of-bucket candidate: first key after the owning bucket
        nxk = nxk_ref[0, :][None, :]
        nxv = nxv_ref[0, :][None, :]
        out_key = jnp.sum(jnp.where(oh_b, nxk, 0), axis=1)
        out_val = jnp.sum(jnp.where(oh_b, nxv, 0), axis=1)

        use_in = in_bucket & (pos < ns)
        succ_key = jnp.where(use_in, in_key, out_key)
        succ_val = jnp.where(use_in, in_val, out_val)
        found = succ_key != _EMPTY
        succ_val = jnp.where(found, succ_val, _MISS)

        outk_ref[0, :] = jnp.where(mine, succ_key, outk_ref[0, :])
        outv_ref[0, :] = jnp.where(mine, succ_val, outv_ref[0, :])


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_b", "interpret"),
)
def flix_successor_pallas(
    keys3d: jax.Array,      # [nb, npb, ns] int32
    vals3d: jax.Array,      # [nb, npb, ns] int32
    node_max: jax.Array,    # [nb, npb] int32
    mkba: jax.Array,        # [nb] int32
    sorted_queries: jax.Array,  # [Q] int32, ascending
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    nb, npb, ns = keys3d.shape
    qn = sorted_queries.shape[0]

    # per-bucket "first key after b" rows: one O(nb) suffix scan on the host
    # side of the kernel (the same associative scan successor_query uses).
    from repro.core.query import _suffix_min_with_index

    bucket_min = jnp.where(node_max[:, 0] != EMPTY, keys3d[:, 0, 0], EMPTY)
    head_val = vals3d[:, 0, 0]
    smin, sidx = _suffix_min_with_index(bucket_min)
    next_key = jnp.concatenate([smin[1:], jnp.array([EMPTY], KEY_DTYPE)])
    next_idx = jnp.concatenate([sidx[1:], jnp.array([0], jnp.int32)])
    next_val = head_val[next_idx]

    # pad buckets to a block multiple (EMPTY stripes never match)
    nb_p = pl.cdiv(nb, block_b) * block_b
    if nb_p != nb:
        pad = nb_p - nb
        keys3d = jnp.pad(keys3d, ((0, pad), (0, 0), (0, 0)), constant_values=EMPTY)
        vals3d = jnp.pad(vals3d, ((0, pad), (0, 0), (0, 0)))
        node_max = jnp.pad(node_max, ((0, pad), (0, 0)), constant_values=EMPTY)
        mkba = jnp.pad(mkba, (0, pad), constant_values=EMPTY - 1)
        next_key = jnp.pad(next_key, (0, pad), constant_values=EMPTY)
        next_val = jnp.pad(next_val, (0, pad))
    lfence = jnp.concatenate(
        [jnp.array([jnp.iinfo(jnp.int32).min], KEY_DTYPE), mkba[:-1]]
    )

    # pad queries to a window multiple (MAX_VALID pads are sliced off)
    qp = pl.cdiv(max(qn, 1), block_q) * block_q
    q = jnp.pad(
        sorted_queries.astype(KEY_DTYPE), (0, qp - qn), constant_values=EMPTY - 1
    )
    n_windows = qp // block_q
    q2 = q.reshape(n_windows, block_q)

    first_b = jnp.searchsorted(mkba, q2[:, 0], side="left")
    last_b = jnp.searchsorted(mkba, q2[:, -1], side="left")
    lo = jnp.minimum(first_b, nb_p - 1).astype(jnp.int32) // block_b
    hi = jnp.minimum(last_b, nb_p - 1).astype(jnp.int32) // block_b

    nb_blocks = nb_p // block_b
    keys2d = keys3d.reshape(nb_p, npb * ns)
    vals2d = vals3d.reshape(nb_p, npb * ns)
    mkba_row = mkba.reshape(1, nb_p)
    lf_row = lfence.reshape(1, nb_p)
    nxk_row = next_key.reshape(1, nb_p)
    nxv_row = next_val.reshape(1, nb_p)

    def bucket_map(j, i, lo_ref, hi_ref):
        return (jnp.clip(i, lo_ref[j], hi_ref[j]), 0)

    def fence_map(j, i, lo_ref, hi_ref):
        return (0, jnp.clip(i, lo_ref[j], hi_ref[j]))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_windows, nb_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
            pl.BlockSpec((block_b, npb * ns), bucket_map),
            pl.BlockSpec((block_b, npb * ns), bucket_map),
            pl.BlockSpec((block_b, npb), bucket_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
            pl.BlockSpec((1, block_b), fence_map),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
        ],
    )

    outk, outv = pl.pallas_call(
        functools.partial(_successor_kernel, block_b=block_b, npb=npb, ns=ns),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
            jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
    )(lo, hi, q2, keys2d, vals2d, node_max, mkba_row, lf_row, nxk_row, nxv_row)
    return outk.reshape(qp)[:qn], outv.reshape(qp)[:qn]
