"""Pallas TPU kernel for FliX flipped range queries (the RANGE batch op,
DESIGN.md §10): two compute-to-bucket passes over the bucket stripes.

A RANGE op is ``[lo, hi)``; the batch carries one static ``max_results``
output budget and the results are packed densely at exclusive-scan offsets
(the shared ``core.query`` offset formulas — the same contract the jnp
oracle and the fused apply kernel implement).  The flipped structure:

  * **Pass 1 — count.**  Grid = (op windows, bucket blocks), the
    established ``flix_query`` layout with scalar-prefetched per-window
    block bounds.  Each bucket stripe is the warp analogue: while resident
    it "binary-searches the sorted batch" in compare-count form — every op
    window that intersects the stripe votes, per op, how many of the
    stripe's keys fall in that op's ``[lo, hi)``.  Counts accumulate across
    the stripe blocks a window touches, yielding each op's *full* in-range
    count with no global gather.

  * **Host seam.**  The shared ``range_offsets`` / ``range_slot_ranks``
    formulas turn full counts into clamped segment offsets and one global
    key rank per output slot (rank of ``lo`` itself is one searchsorted +
    compare-count row against the per-bucket sorted rows, as every FliX
    read does).

  * **Pass 2 — scatter.**  Grid = (bucket blocks,).  Each resident stripe
    block claims the output slots whose rank falls inside its live-count
    prefix span (``pref`` fence rows stream through the fence BlockSpec)
    and writes ``(key, val)`` with exact one-hot MXU gathers — a dense,
    globally key-ordered output with no atomics and no second sort.

Wrapper-side preprocessing (per-bucket row sort, live-count prefix sums)
mirrors how ``flix_successor`` precomputes its fence rows: O(nb·cap) jnp
work outside the kernel, none of it per-op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.flix_query import DEFAULT_BLOCK_Q, _exact_gather_i32
from repro.core.state import EMPTY, KEY_DTYPE

DEFAULT_BLOCK_B = 4     # bucket stripes per block (count mask is O(QB·BB·S))
_EMPTY = int(jnp.iinfo(jnp.int32).max)
_MISS = -1


def _range_count_kernel(
    lo_ref,      # scalar prefetch: [n_windows] first bucket block of window
    hi_ref,      # scalar prefetch: [n_windows] last  bucket block of window
    l_ref,       # [1, QB] sorted range lows for window j
    h_ref,       # [1, QB] their (unsorted) exclusive highs
    keys_ref,    # [BB, cap] per-bucket sorted key rows (EMPTY-padded)
    cnt_ref,     # [1, QB] accumulated full in-range counts
    *,
    block_b: int,
    cap: int,
):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    active = (i >= lo_ref[j]) & (i <= hi_ref[j])

    @pl.when(active)
    def _process():
        k = keys_ref[...].reshape(1, block_b * cap)       # [1, BB*cap]
        lo = l_ref[0, :][:, None]                         # [QB, 1]
        h = h_ref[0, :][:, None]
        hit = (k >= lo) & (k < h) & (k != _EMPTY)         # [QB, BB*cap]
        cnt_ref[0, :] = cnt_ref[0, :] + jnp.sum(hit.astype(jnp.int32), axis=1)


def _range_scatter_kernel(
    lo_ref,      # scalar prefetch: [1] first bucket block holding output
    hi_ref,      # scalar prefetch: [1] last  bucket block holding output
    g_ref,       # [1, MR] per-slot global key rank (-1 = unused slot)
    keys_ref,    # [BB, cap] per-bucket sorted key rows
    vals_ref,    # [BB, cap] aligned vals
    ps_ref,      # [1, BB] pref[b]   (rank of the bucket's first key)
    pe_ref,      # [1, BB] pref[b+1] (rank just past its last key)
    outk_ref,    # [1, MR] dense range keys / EMPTY
    outv_ref,    # [1, MR] dense range vals / NOT_FOUND
    *,
    block_b: int,
    cap: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        outk_ref[...] = jnp.full_like(outk_ref, _EMPTY)
        outv_ref[...] = jnp.full_like(outv_ref, _MISS)

    active = (i >= lo_ref[0]) & (i <= hi_ref[0])

    @pl.when(active)
    def _process():
        g = g_ref[0, :]                                   # [MR]
        gcol = g[:, None]
        ps = ps_ref[0, :][None, :]                        # [1, BB]
        pe = pe_ref[0, :][None, :]

        # which local bucket's rank span holds each slot (compare-count over
        # the prefix fences; empty buckets have ps == pe and never own)
        bloc = jnp.sum((pe <= gcol).astype(jnp.int32), axis=1)     # [MR]
        bloc_c = jnp.minimum(bloc, block_b - 1)
        oh_b = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], block_b), 1)
            == bloc_c[:, None]
        )
        ps_g = jnp.sum(jnp.where(oh_b, ps, 0), axis=1)
        mine = (g >= 0) & (bloc < block_b) & (g >= ps_g)

        # in-bucket position: rows are bucket-sorted, so rank maps directly
        pos = jnp.clip(g - ps_g, 0, cap - 1)
        krow = _exact_gather_i32(oh_b.astype(jnp.float32), keys_ref[...])
        vrow = _exact_gather_i32(oh_b.astype(jnp.float32), vals_ref[...])
        oh_p = (
            jax.lax.broadcasted_iota(jnp.int32, (g.shape[0], cap), 1)
            == pos[:, None]
        )
        kk = jnp.sum(jnp.where(oh_p, krow, 0), axis=1)
        vv = jnp.sum(jnp.where(oh_p, vrow, 0), axis=1)

        outk_ref[0, :] = jnp.where(mine, kk, outk_ref[0, :])
        outv_ref[0, :] = jnp.where(mine, vv, outv_ref[0, :])


@functools.partial(
    jax.jit,
    static_argnames=("max_results", "block_q", "block_b", "interpret"),
)
def flix_range_pallas(
    keys3d: jax.Array,      # [nb, npb, ns] int32
    vals3d: jax.Array,      # [nb, npb, ns] int32
    mkba: jax.Array,        # [nb] int32
    sorted_lo: jax.Array,   # [Q] int32, ascending (the batch's one sort)
    hi: jax.Array,          # [Q] int32, aligned exclusive upper bounds
    *,
    max_results: int = 128,
    block_q: int = DEFAULT_BLOCK_Q,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
):
    """Dense ``[lo, hi)`` scans.  Returns ``(keys[max_results],
    vals[max_results], start[Q], count[Q], truncated)`` — byte-identical to
    ``core.query.dense_range_scan`` on the same state."""
    from repro.core.query import flat_rank, range_offsets, range_slot_ranks
    from repro.core.state import sort_bucket_rows

    nb, npb, ns = keys3d.shape
    cap = npb * ns
    qn = sorted_lo.shape[0]

    # per-bucket sorted rows (chain order has interior EMPTY padding)
    flat_k, flat_v = sort_bucket_rows(
        keys3d.reshape(nb, cap), vals3d.reshape(nb, cap)
    )
    live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
    )

    # pad buckets to a block multiple (EMPTY stripes never count or own)
    nb_p = pl.cdiv(nb, block_b) * block_b
    flat_kp, flat_vp, mkba_p = flat_k, flat_v, mkba
    ps_row = pref[:-1]
    pe_row = pref[1:]
    if nb_p != nb:
        pad = nb_p - nb
        flat_kp = jnp.pad(flat_kp, ((0, pad), (0, 0)), constant_values=EMPTY)
        flat_vp = jnp.pad(flat_vp, ((0, pad), (0, 0)))
        mkba_p = jnp.pad(mkba_p, (0, pad), constant_values=EMPTY - 1)
        total = pref[-1]
        ps_row = jnp.concatenate([ps_row, jnp.full((pad,), total, jnp.int32)])
        pe_row = jnp.concatenate([pe_row, jnp.full((pad,), total, jnp.int32)])
    nb_blocks = nb_p // block_b

    # --- pass 1: full in-range counts ------------------------------------
    qp = pl.cdiv(max(qn, 1), block_q) * block_q
    l_pad = jnp.pad(sorted_lo.astype(KEY_DTYPE), (0, qp - qn), constant_values=EMPTY)
    # pad hi with 0, not EMPTY: padded ops are already dead (lo = EMPTY
    # matches no key), and an EMPTY hi would drag a partial last window's
    # max(h2) — and with it the window's block span — to the end of the
    # bucket axis
    h_pad = jnp.pad(hi.astype(KEY_DTYPE), (0, qp - qn), constant_values=0)
    n_windows = qp // block_q
    l2 = l_pad.reshape(n_windows, block_q)
    h2 = h_pad.reshape(n_windows, block_q)

    first_b = jnp.searchsorted(mkba_p, l2[:, 0], side="left")
    last_b = jnp.searchsorted(mkba_p, jnp.max(h2, axis=1) - 1, side="left")
    lo_blk = jnp.minimum(first_b, nb_p - 1).astype(jnp.int32) // block_b
    hi_blk = jnp.minimum(last_b, nb_p - 1).astype(jnp.int32) // block_b
    hi_blk = jnp.maximum(hi_blk, lo_blk)

    def bucket_map(j, i, lo_ref, hi_ref):
        return (jnp.clip(i, lo_ref[j], hi_ref[j]), 0)

    count_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_windows, nb_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
            pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
            pl.BlockSpec((block_b, cap), bucket_map),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda j, i, lo, hi: (j, 0)),
    )
    counts = pl.pallas_call(
        functools.partial(_range_count_kernel, block_b=block_b, cap=cap),
        grid_spec=count_spec,
        out_shape=jax.ShapeDtypeStruct((n_windows, block_q), jnp.int32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        ),
    )(lo_blk, hi_blk, l2, h2, flat_kp)
    full = counts.reshape(qp)[:qn]

    # --- host seam: shared offset/rank formulas --------------------------
    is_range = jnp.ones((qn,), bool)
    start, emit, total_emit, truncated = range_offsets(full, is_range, max_results)
    rank_lo = flat_rank(flat_k, pref, mkba, sorted_lo)
    g = range_slot_ranks(rank_lo, start, total_emit, max_results)

    # --- pass 2: scatter to exclusive-scan offsets -----------------------
    mrp = pl.cdiv(max_results, 128) * 128
    g_row = jnp.pad(g, (0, mrp - max_results), constant_values=-1).reshape(1, mrp)
    # overlapping ranges make per-slot ranks non-monotone — bound the block
    # sweep by the min/max rank over the *valid* slots
    g0 = jnp.min(jnp.where(g_row >= 0, g_row, jnp.iinfo(jnp.int32).max))
    g0 = jnp.clip(g0, 0, pref[-1])
    g_last = jnp.maximum(jnp.max(g_row), 0)
    b_first = jnp.clip(
        jnp.searchsorted(pref, g0, side="right").astype(jnp.int32) - 1, 0, nb - 1
    )
    b_last = jnp.clip(
        jnp.searchsorted(pref, g_last, side="right").astype(jnp.int32) - 1,
        0,
        nb - 1,
    )
    lo2 = (b_first // block_b).reshape(1)
    hi2 = (b_last // block_b).reshape(1)

    def bucket_map1(i, lo_ref, hi_ref):
        return (jnp.clip(i, lo_ref[0], hi_ref[0]), 0)

    def fence_map1(i, lo_ref, hi_ref):
        return (0, jnp.clip(i, lo_ref[0], hi_ref[0]))

    scatter_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb_blocks,),
        in_specs=[
            pl.BlockSpec((1, mrp), lambda i, lo, hi: (0, 0)),
            pl.BlockSpec((block_b, cap), bucket_map1),
            pl.BlockSpec((block_b, cap), bucket_map1),
            pl.BlockSpec((1, block_b), fence_map1),
            pl.BlockSpec((1, block_b), fence_map1),
        ],
        out_specs=[
            pl.BlockSpec((1, mrp), lambda i, lo, hi: (0, 0)),
            pl.BlockSpec((1, mrp), lambda i, lo, hi: (0, 0)),
        ],
    )
    outk, outv = pl.pallas_call(
        functools.partial(_range_scatter_kernel, block_b=block_b, cap=cap),
        grid_spec=scatter_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, mrp), jnp.int32),
            jax.ShapeDtypeStruct((1, mrp), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
    )(
        lo2,
        hi2,
        g_row,
        flat_kp,
        flat_vp,
        ps_row.reshape(1, nb_p),
        pe_row.reshape(1, nb_p),
    )
    return outk[0, :max_results], outv[0, :max_results], start, emit, truncated
