"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode or fall back to the pure-jnp oracle, selected by
``mode``:

  * ``"auto"``      — Pallas-compiled on TPU, jnp oracle elsewhere (prod).
  * ``"pallas"``    — force compiled Pallas (TPU only).
  * ``"interpret"`` — Pallas in interpret mode (kernel-correctness testing).
  * ``"ref"``       — pure-jnp oracle.
"""

from __future__ import annotations

import jax

from repro.core.state import FliXState
from repro.kernels import ref as _ref
from repro.kernels.flix_delete import flix_delete_pallas
from repro.kernels.flix_insert import flix_insert_pallas
from repro.kernels.flix_query import flix_point_query_pallas
from repro.kernels.flix_successor import flix_successor_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "pallas" if _on_tpu() else "ref"
    return mode


def flix_point_query(
    state: FliXState, sorted_queries: jax.Array, *, mode: str = "auto", **blocks
) -> jax.Array:
    mode = _resolve(mode)
    if mode == "ref":
        return _ref.flix_point_query_ref(
            state.keys, state.vals, state.node_max, state.mkba, sorted_queries
        )
    return flix_point_query_pallas(
        state.keys,
        state.vals,
        state.node_max,
        state.mkba,
        sorted_queries,
        interpret=(mode == "interpret"),
        **blocks,
    )


def flix_successor(
    state: FliXState, sorted_queries: jax.Array, *, mode: str = "auto", **blocks
):
    """Successor queries: (succ_key | EMPTY, succ_val | NOT_FOUND)."""
    mode = _resolve(mode)
    if mode == "ref":
        return _ref.flix_successor_ref(
            state.keys, state.vals, state.node_max, state.mkba, sorted_queries
        )
    return flix_successor_pallas(
        state.keys,
        state.vals,
        state.node_max,
        state.mkba,
        sorted_queries,
        interpret=(mode == "interpret"),
        **blocks,
    )


def flix_delete(
    state: FliXState, sorted_del_keys: jax.Array, *, mode: str = "auto", **blocks
) -> FliXState:
    mode = _resolve(mode)
    if mode == "ref":
        from repro.core.delete import delete

        new_state, _ = delete(state, sorted_del_keys)
        return new_state
    return flix_delete_pallas(
        state, sorted_del_keys, interpret=(mode == "interpret"), **blocks
    )


def grouped_matmul(
    x: jax.Array,
    w: jax.Array,
    group_offsets: jax.Array,
    *,
    mode: str = "auto",
    **blocks,
) -> jax.Array:
    mode = _resolve(mode)
    if mode == "ref":
        return _ref.grouped_matmul_ref(x, w, group_offsets)
    return grouped_matmul_pallas(
        x, w, group_offsets, interpret=(mode == "interpret"), **blocks
    )


def flix_insert(
    state: FliXState,
    sorted_keys: jax.Array,
    sorted_vals: jax.Array,
    *,
    mode: str = "auto",
):
    """TL-Bulk insertion. Returns (new_state, per-bucket overflow counts)."""
    mode = _resolve(mode)
    if mode == "ref":
        from repro.core.insert import insert

        new_state, stats = insert(state, sorted_keys, sorted_vals)
        return new_state, stats["overflowed_buckets"]
    return flix_insert_pallas(
        state, sorted_keys, sorted_vals, interpret=(mode == "interpret")
    )


def flix_apply(state: FliXState, ops, *, mode: str = "auto", **blocks):
    """Fused mixed-batch apply (DESIGN.md §9): the whole update-then-read
    sequence in one VMEM-resident pass per bucket.

    ``ops`` is a ``core.ops.OpBatch``.  Returns ``(state', results, stats)``
    with the same contract as ``core.ops.apply_ops`` (whose ``impl=`` kwarg
    is the usual entry point; this wrapper exists for kernel-level mode
    control, e.g. ``mode="interpret"`` in tests).
    """
    mode = _resolve(mode)
    if mode == "ref":
        from repro.core.ops import _apply_ops_reference

        return _apply_ops_reference(state, ops)
    from repro.kernels.flix_apply import flix_apply_pallas

    return flix_apply_pallas(
        state,
        ops.tag,
        ops.key,
        ops.val,
        interpret=(mode == "interpret"),
        **blocks,
    )
