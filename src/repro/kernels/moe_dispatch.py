"""Flipped (sort-based) MoE dispatch — the FliX paradigm on expert routing.

Traditional dispatch is compute-to-operation: every token scatters itself to
its expert.  Here the token batch is *sorted by expert id* (the sorted
operation batch) and every expert — a bucket — *pulls* its contiguous token
slice via the same searchsorted-boundary primitive as `core.batch`.  The
expert FFN then runs as a ragged grouped GEMM over those slices
(`kernels.grouped_matmul`), with coalesced reads exactly like FliX's
per-bucket coalesced updates.

These helpers are pure jnp (XLA path); `models/moe.py` composes them with
the Pallas grouped GEMM when running on real TPU hardware.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DispatchPlan(NamedTuple):
    sort_idx: jax.Array       # [T*k] token-slot order, sorted by expert
    unsort_idx: jax.Array     # [T*k] inverse permutation
    group_offsets: jax.Array  # [E+1] per-expert slice boundaries
    expert_sorted: jax.Array  # [T*k] expert id per sorted slot
    weights: jax.Array        # [T, k] router combine weights


def make_plan(router_logits: jax.Array, top_k: int, num_experts: int) -> DispatchPlan:
    """Route + sort: the 'sort the batch' step of flipped indexing."""
    T = router_logits.shape[0]
    gate = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(gate, top_k)          # [T, k]
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    flat_expert = experts.reshape(-1).astype(jnp.int32)    # [T*k]
    sort_idx = jnp.argsort(flat_expert, stable=True)
    expert_sorted = flat_expert[sort_idx]
    unsort_idx = jnp.argsort(sort_idx, stable=True)
    # bucket boundaries: one searchsorted over expert ids (MKBA analogue)
    group_offsets = jnp.searchsorted(
        expert_sorted, jnp.arange(num_experts + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return DispatchPlan(sort_idx, unsort_idx, group_offsets, expert_sorted, weights)


def dispatch(x: jax.Array, plan: DispatchPlan, top_k: int) -> jax.Array:
    """Gather token rows into expert-contiguous order: [T*k, D]."""
    T, D = x.shape
    token_of_slot = plan.sort_idx // top_k
    return x[token_of_slot]


def combine(y_sorted: jax.Array, plan: DispatchPlan, top_k: int) -> jax.Array:
    """Weighted scatter-add back to token order: [T, D]."""
    Tk = y_sorted.shape[0]
    T = Tk // top_k
    y = y_sorted[plan.unsort_idx].reshape(T, top_k, -1)
    w = plan.weights[..., None].astype(y.dtype)
    return jnp.sum(y * w, axis=1)


def moe_ffn_reference(x, router_logits, w_up, w_down, top_k):
    """Dense oracle: every expert computes every token, one-hot combine."""
    E = w_up.shape[0]
    gate = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(gate, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    h = jnp.einsum("td,edf->etf", x.astype(jnp.float32), w_up.astype(jnp.float32))
    h = jax.nn.silu(h)
    y = jnp.einsum("etf,efd->etd", h, w_down.astype(jnp.float32))  # [E, T, D]
    oh = jax.nn.one_hot(experts, E, axis=-1)                        # [T, k, E]
    return jnp.einsum("tke,etd,tk->td", oh, y, weights)
