"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle takes the same *raw arrays* as its kernel (no FliXState / model
glue) so the kernel sweep tests can drive both sides identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND


def flix_point_query_ref(
    keys3d: jax.Array,
    vals3d: jax.Array,
    node_max: jax.Array,
    mkba: jax.Array,
    sorted_queries: jax.Array,
) -> jax.Array:
    """Oracle for kernels.flix_query (identical math to core.query)."""
    nb, npb, ns = keys3d.shape
    q = sorted_queries.astype(KEY_DTYPE)
    b = jnp.minimum(jnp.searchsorted(mkba, q, side="left"), nb - 1).astype(jnp.int32)
    nmax_rows = node_max[b]
    nidx = jnp.sum(nmax_rows < q[:, None], axis=1).astype(jnp.int32)
    nidx_c = jnp.minimum(nidx, npb - 1)
    rows = keys3d[b, nidx_c]
    pos = jnp.sum(rows < q[:, None], axis=1).astype(jnp.int32)
    pos_c = jnp.minimum(pos, ns - 1)
    key_at = rows[jnp.arange(q.shape[0]), pos_c]
    hit = (pos < ns) & (key_at == q)
    return jnp.where(hit, vals3d[b, nidx_c, pos_c], NOT_FOUND)


def flix_successor_ref(
    keys3d: jax.Array,
    vals3d: jax.Array,
    node_max: jax.Array,
    mkba: jax.Array,
    sorted_queries: jax.Array,
):
    """Oracle for kernels.flix_successor (identical math to core.query's
    ``successor_query``, with ``num_nodes`` derived from ``node_max``)."""
    nb, npb, ns = keys3d.shape
    q = sorted_queries.astype(KEY_DTYPE)
    num_nodes = jnp.sum(node_max != EMPTY, axis=1).astype(jnp.int32)
    b = jnp.minimum(jnp.searchsorted(mkba, q, side="left"), nb - 1).astype(jnp.int32)

    nmax_rows = node_max[b]
    nidx = jnp.sum(nmax_rows < q[:, None], axis=1).astype(jnp.int32)
    in_bucket = nidx < num_nodes[b]
    nidx_c = jnp.minimum(nidx, npb - 1)
    rows = keys3d[b, nidx_c]
    pos = jnp.sum(rows < q[:, None], axis=1).astype(jnp.int32)
    pos_c = jnp.minimum(pos, ns - 1)
    in_key = rows[jnp.arange(q.shape[0]), pos_c]
    in_val = vals3d[b, nidx_c, pos_c]

    from repro.core.query import _suffix_min_with_index

    bucket_min = jnp.where(num_nodes > 0, keys3d[:, 0, 0], EMPTY)
    smin, sidx = _suffix_min_with_index(bucket_min)
    smin_pad = jnp.concatenate([smin, jnp.array([EMPTY], KEY_DTYPE)])
    sidx_pad = jnp.concatenate([sidx, jnp.array([0], jnp.int32)])
    out_key = smin_pad[b + 1]
    out_val = vals3d[sidx_pad[b + 1], 0, 0]

    use_in = in_bucket & (pos < ns)
    succ_key = jnp.where(use_in, in_key, out_key)
    succ_val = jnp.where(use_in, in_val, out_val)
    found = succ_key != EMPTY
    return succ_key, jnp.where(found, succ_val, NOT_FOUND)


def grouped_matmul_ref(
    x: jax.Array,            # [T, D] tokens sorted by group
    w: jax.Array,            # [E, D, F] per-group weights
    group_offsets: jax.Array,  # [E+1] slice boundaries into x
) -> jax.Array:
    """Oracle for kernels.grouped_matmul: out[t] = x[t] @ w[group(t)]."""
    t_idx = jnp.arange(x.shape[0])
    group = (
        jnp.searchsorted(group_offsets, t_idx, side="right").astype(jnp.int32) - 1
    )
    group = jnp.clip(group, 0, w.shape[0] - 1)
    wt = w[group]                                 # [T, D, F]
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32), wt.astype(jnp.float32))


def flix_delete_mark_ref(
    keys2d: jax.Array,          # [nb, npb*ns] bucket stripes (chain order)
    del_tile: jax.Array,        # [nb, L] per-bucket sorted delete sublists
) -> jax.Array:
    """Oracle for kernels.flix_delete's membership-mark stage."""
    pos = jax.vmap(lambda row, xs: jnp.searchsorted(row, xs, side="left"))(
        del_tile, keys2d
    )
    pos_c = jnp.minimum(pos, del_tile.shape[1] - 1)
    return (jnp.take_along_axis(del_tile, pos_c, axis=1) == keys2d) & (
        keys2d != EMPTY
    )
