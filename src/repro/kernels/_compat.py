"""Version compatibility for the Pallas TPU API surface.

Newer jax releases renamed ``pltpu.TPUCompilerParams`` to
``pltpu.CompilerParams``; this container's jax only has the old name.
Every kernel imports ``CompilerParams`` from here so both spellings work.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
