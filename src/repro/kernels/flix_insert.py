"""Pallas TPU kernel for TL-Bulk insertion (paper §4.3.2, Table 2).

Per bucket, entirely in VMEM, matching ``core.insert`` bit-for-bit:

  1. upsert-dedup: stripe keys that reappear in the incoming sublist are
     dropped (the incoming value wins) — broadcast equality, the tile-ballot
     analogue of Table 2's per-thread ownership comparisons,
  2. merged ranks by compare-count (no sort needed in-kernel: both sides are
     sorted, so rank(z) = #kept-stripe< z + #incoming< z),
  3. original node regions keep their boundaries; a region that overflows
     splits into balanced pieces (the batched fixed point of the paper's
     split-in-half rule; identical formulas to core/insert.py),
  4. one-hot reposition into the new stripe + metadata recompute.

The wrapper pulls per-bucket sublists (flipped-indexing boundaries) and
reports per-bucket overflow; callers use the same restructure-and-retry
contract as ``core.insert_safe``.

VMEM per step (BB=1): stripe (npb·ns) + incoming tile (cap) + the [L, S]
reposition mask with L = 2·cap, S = npb·ns — ≈ 2.5 MB at cap 512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams

from repro.core.batch import bucket_slices, gather_kv_sublists
from repro.core.state import KEY_DTYPE, VAL_DTYPE, FliXState

_EMPTY = int(jnp.iinfo(jnp.int32).max)


def _insert_kernel(
    keys_ref,   # [1, npb*ns] stripe (chain order, per-node EMPTY padding)
    vals_ref,   # [1, npb*ns]
    nmax_ref,   # [1, npb] node maxes (EMPTY when inactive)
    ik_ref,     # [1, cap] sorted incoming keys (EMPTY-padded)
    iv_ref,     # [1, cap]
    okeys_ref,  # [1, npb*ns]
    ovals_ref,  # [1, npb*ns]
    ocnt_ref,   # [1, npb]
    omax_ref,   # [1, npb]
    onn_ref,    # [1, 1]
    oflow_ref,  # [1, 1]  bucket overflow flag
    *,
    npb: int,
    ns: int,
    cap: int,
):
    A = keys_ref[0, :]                    # stripe keys  [S]
    Av = vals_ref[0, :]
    B = ik_ref[0, :]                      # incoming     [cap]
    Bv = iv_ref[0, :]
    nmax = nmax_ref[0, :]                 # [npb]
    S = npb * ns

    validA = A != _EMPTY
    validB = B != _EMPTY
    dupA = jnp.any(A[:, None] == B[None, :], axis=1) & validA
    keepA = validA & ~dupA

    # merged ranks by compare-count (both sides sorted & unique)
    lessA_A = jnp.sum((A[None, :] < A[:, None]) & keepA[None, :], axis=1)
    lessB_A = jnp.sum((B[None, :] < A[:, None]) & validB[None, :], axis=1)
    rankA = lessA_A + lessB_A                                   # [S]
    lessA_B = jnp.sum((A[None, :] < B[:, None]) & keepA[None, :], axis=1)
    lessB_B = jnp.sum((B[None, :] < B[:, None]) & validB[None, :], axis=1)
    rankB = lessA_B + lessB_B                                   # [cap]

    # original node regions (fixed boundaries; last region open-ended)
    onn = jnp.sum((nmax != _EMPTY).astype(jnp.int32))
    onn_c = jnp.maximum(onn - 1, 0)

    def region_of(z):
        return jnp.minimum(
            jnp.sum((nmax[None, :] < z[:, None]).astype(jnp.int32), axis=1),
            onn_c,
        )

    regA = region_of(A)
    regB = region_of(B)

    # per-region sizes over kept elements
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (1, npb), 1)[0]
    mA = jnp.sum((regA[:, None] == iota_r[None, :]) & keepA[:, None], axis=0)
    mB = jnp.sum((regB[:, None] == iota_r[None, :]) & validB[:, None], axis=0)
    m_j = (mA + mB).astype(jnp.int32)                            # [npb]
    s_j = (m_j + ns - 1) // ns
    f_j = jnp.cumsum(m_j) - m_j
    base_j = jnp.cumsum(s_j) - s_j
    total_new = jnp.sum(s_j)

    def dest_of(rank, reg, keep):
        # balanced split within each region (same formulas as core/insert)
        oh = reg[:, None] == iota_r[None, :]
        m_r = jnp.maximum(jnp.sum(jnp.where(oh, m_j[None, :], 0), axis=1), 1)
        s_r = jnp.maximum(jnp.sum(jnp.where(oh, s_j[None, :], 0), axis=1), 1)
        f_r = jnp.sum(jnp.where(oh, f_j[None, :], 0), axis=1)
        b_r = jnp.sum(jnp.where(oh, base_j[None, :], 0), axis=1)
        rr = rank - f_r
        piece = (rr * s_r) // m_r
        start = (piece * m_r + s_r - 1) // s_r
        pos = rr - start
        slot = b_r + piece
        return jnp.where(keep & (slot < npb), slot * ns + pos, S)

    destA = dest_of(rankA, regA, keepA)
    destB = dest_of(rankB, regB, validB)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)[0]
    ohA = destA[:, None] == lane[None, :]                        # [S, S]
    ohB = destB[:, None] == lane[None, :]                        # [cap, S]
    nk = jnp.sum(jnp.where(ohA, A[:, None], 0), axis=0) + jnp.sum(
        jnp.where(ohB, B[:, None], 0), axis=0
    )
    nv = jnp.sum(jnp.where(ohA, Av[:, None], 0), axis=0) + jnp.sum(
        jnp.where(ohB, Bv[:, None], 0), axis=0
    )
    filled = jnp.any(ohA, axis=0) | jnp.any(ohB, axis=0)
    nk = jnp.where(filled, nk, _EMPTY)
    nv = jnp.where(filled, nv, 0)

    okeys_ref[0, :] = nk
    ovals_ref[0, :] = nv

    rows = nk.reshape(npb, ns)
    cnt = jnp.sum((rows != _EMPTY).astype(jnp.int32), axis=1)
    last = jnp.maximum(cnt - 1, 0)
    lane2 = jax.lax.broadcasted_iota(jnp.int32, (npb, ns), 1)
    nmax_new = jnp.sum(jnp.where(lane2 == last[:, None], rows, 0), axis=1)
    ocnt_ref[0, :] = cnt
    omax_ref[0, :] = jnp.where(cnt > 0, nmax_new, _EMPTY)
    onn_ref[0, 0] = jnp.sum((cnt > 0).astype(jnp.int32))
    oflow_ref[0, 0] = (total_new > npb).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flix_insert_pallas(
    state: FliXState,
    sorted_keys: jax.Array,
    sorted_vals: jax.Array,
    *,
    interpret: bool = False,
):
    """TL-Bulk insertion via the Pallas kernel.

    Returns (new_state, per-bucket overflow counts).  Same contract as
    ``core.insert``: on overflow the caller retries after restructuring.
    """
    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    cap = state.bucket_capacity
    keys_in = sorted_keys.astype(KEY_DTYPE)
    vals_in = sorted_vals.astype(VAL_DTYPE)

    starts, ends = bucket_slices(state, keys_in)
    ik, iv, _, true_counts = gather_kv_sublists(keys_in, vals_in, starts, ends, cap)

    grid = (nb,)

    def row(i):
        return (i, 0)

    okeys, ovals, ocnt, omax, onn, oflow = pl.pallas_call(
        functools.partial(_insert_kernel, npb=npb, ns=ns, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, npb * ns), row),
            pl.BlockSpec((1, npb * ns), row),
            pl.BlockSpec((1, npb), row),
            pl.BlockSpec((1, cap), row),
            pl.BlockSpec((1, cap), row),
        ],
        out_specs=[
            pl.BlockSpec((1, npb * ns), row),
            pl.BlockSpec((1, npb * ns), row),
            pl.BlockSpec((1, npb), row),
            pl.BlockSpec((1, npb), row),
            pl.BlockSpec((1, 1), row),
            pl.BlockSpec((1, 1), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, npb * ns), jnp.int32),
            jax.ShapeDtypeStruct((nb, npb * ns), jnp.int32),
            jax.ShapeDtypeStruct((nb, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb, npb), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
    )(
        state.keys.reshape(nb, npb * ns),
        state.vals.reshape(nb, npb * ns),
        state.node_max,
        ik,
        iv,
    )

    slice_overflow = true_counts > cap
    any_overflow = (jnp.sum(oflow) > 0) | jnp.any(slice_overflow)
    new_state = FliXState(
        keys=okeys.reshape(nb, npb, ns),
        vals=ovals.reshape(nb, npb, ns),
        node_count=ocnt,
        node_max=omax,
        num_nodes=onn[:, 0],
        mkba=state.mkba,
        needs_restructure=state.needs_restructure | any_overflow,
    )
    return new_state, oflow[:, 0] + slice_overflow.astype(jnp.int32)
