"""Initial build (paper §3.2, Figure 3a).

The sorted build keys are grouped into partitions of ``p = node_size * fill``
(default fill = 1/2 → nodes start half full, leaving headroom for inserts
before splits are needed).  Each partition becomes one bucket holding a
single node; the largest key of each partition is that bucket's MKBA entry.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.state import (
    EMPTY,
    KEY_DTYPE,
    MAX_VALID,
    VAL_DTYPE,
    FliXState,
)


def plan_geometry(
    n_keys: int,
    *,
    node_size: int = 32,
    nodes_per_bucket: int = 16,
    fill: float = 0.5,
) -> tuple[int, int, int]:
    """Host-side geometry: (num_buckets, nodes_per_bucket, node_size)."""
    p = max(1, int(node_size * fill))
    num_buckets = max(1, math.ceil(n_keys / p))
    return num_buckets, nodes_per_bucket, node_size


@partial(
    jax.jit, static_argnames=("num_buckets", "nodes_per_bucket", "node_size", "fill")
)
def build_from_sorted(
    sorted_keys: jax.Array,
    sorted_vals: jax.Array,
    *,
    num_buckets: int,
    nodes_per_bucket: int = 16,
    node_size: int = 32,
    fill: float = 0.5,
) -> FliXState:
    """Build from a sorted, deduplicated key/val batch (EMPTY-padded ok).

    Keys beyond the first ``num_buckets * p`` valid entries must not exist
    (geometry comes from ``plan_geometry``).
    """
    nb, npb, ns = num_buckets, nodes_per_bucket, node_size
    p = max(1, int(ns * fill))

    take = min(sorted_keys.shape[0], nb * p)
    k = jnp.full((nb * p,), EMPTY, dtype=KEY_DTYPE)
    k = k.at[:take].set(sorted_keys[:take].astype(KEY_DTYPE))
    v = jnp.zeros((nb * p,), dtype=VAL_DTYPE)
    v = v.at[:take].set(sorted_vals[:take].astype(VAL_DTYPE))

    bkeys = k.reshape(nb, p)          # partition i → bucket i
    bvals = v.reshape(nb, p)

    keys = jnp.full((nb, npb, ns), EMPTY, dtype=KEY_DTYPE)
    vals = jnp.zeros((nb, npb, ns), dtype=VAL_DTYPE)
    keys = keys.at[:, 0, :p].set(bkeys)
    vals = vals.at[:, 0, :p].set(bvals)

    counts0 = jnp.sum(bkeys != EMPTY, axis=1).astype(jnp.int32)   # [nb]
    node_count = jnp.zeros((nb, npb), jnp.int32).at[:, 0].set(counts0)
    nmax0 = jnp.where(
        counts0 > 0,
        bkeys[jnp.arange(nb), jnp.maximum(counts0 - 1, 0)],
        EMPTY,
    ).astype(KEY_DTYPE)
    node_max = jnp.full((nb, npb), EMPTY, dtype=KEY_DTYPE).at[:, 0].set(nmax0)
    num_nodes = (counts0 > 0).astype(jnp.int32)

    # MKBA: bucket i's fence is its largest build key; the final bucket (and
    # any empty trailing buckets) extend to MAX_VALID so the fences cover the
    # whole key space.  Ensure ascending by propagating a running max.
    mkba = jnp.where(counts0 > 0, nmax0, MAX_VALID).astype(KEY_DTYPE)
    mkba = mkba.at[-1].set(MAX_VALID)
    mkba = jax.lax.associative_scan(jnp.maximum, mkba)

    return FliXState(
        keys=keys,
        vals=vals,
        node_count=node_count,
        node_max=node_max,
        num_nodes=num_nodes,
        mkba=mkba,
        needs_restructure=jnp.array(False),
    )


def build(
    keys,
    vals,
    *,
    node_size: int = 32,
    nodes_per_bucket: int = 16,
    fill: float = 0.5,
) -> FliXState:
    """Convenience host-side build: sorts, dedups, plans geometry, builds."""
    from repro.core.batch import dedup_last_wins, sort_batch

    keys = jnp.asarray(keys, dtype=KEY_DTYPE)
    vals = jnp.asarray(vals, dtype=VAL_DTYPE)
    skeys, svals = sort_batch(keys, vals)
    skeys, svals, count = dedup_last_wins(skeys, svals)
    n = int(count)
    nb, npb, ns = plan_geometry(
        n, node_size=node_size, nodes_per_bucket=nodes_per_bucket, fill=fill
    )
    return build_from_sorted(
        skeys, svals, num_buckets=nb, nodes_per_bucket=npb, node_size=ns, fill=fill
    )
