"""ExecConfig: the one typed execution-configuration surface (DESIGN.md §16).

PRs 2–9 grew six engine entry points — ``apply_ops``, ``apply_ops_safe``,
``shard_apply_ops``, ``shard_apply_ops_safe``, ``TieredFliX.apply``,
``KVPageIndex`` — and each sprouted its own copy of the tuning knobs
(``impl``, ``donate``, ``block_q``/``block_b``, ``max_results``,
``capacity``, ``routing``, validate flags).  The autotuner
(``kernels.autotune``) needs a single place to write its answers into, and
callers need one object they can build once and thread everywhere.  That
object is :class:`ExecConfig`:

  * frozen + hashable — safe as a jit-static carrier and as a cache key;
  * every knob is *execution strategy*, never *semantics*: two runs of the
    same batch under different configs must be byte-identical (the
    differential suite pins this).  Time (``now``) and batch-composition
    hints (``has_updates``/``has_ranges``) are therefore **not** config —
    they stay per-call keywords.

The legacy per-entry-point keywords still work this PR as thin deprecation
shims: passing any of them builds an ``ExecConfig`` and warns once per
entry point (``DeprecationWarning``).  They are removed next PR;
``tools/check_exec_config.py`` gates the repo's own callers off them now.

:class:`TileTable` carries the autotuner's chosen (block_q, block_b) tile
per (build_size, batch_size) bucket.  It is plain data — hashable tuples
in, JSON out — so it round-trips through the bench artifact
(``benchmarks/run.py`` embeds it) and back into an ``ExecConfig``.
"""

from __future__ import annotations

import dataclasses
import warnings

DEFAULT_MAX_RESULTS = 128  # per-batch RANGE output budget (static)

# sentinel distinguishing "caller did not pass this keyword" from any real
# value (None is a real value for block_q/block_b/capacity)
_UNSET = object()


def _pow2_bucket(n: int) -> int:
    """Smallest power of two ≥ n (≥ 1) — the TileTable's size-bucketing."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class TileTable:
    """Autotuned (block_q, block_b) per (build_size, batch_size) bucket.

    ``entries`` rows are ``(build_bucket, batch_bucket, block_q, block_b)``
    with power-of-two buckets; lookups round both sizes *up* to their
    bucket and fall back to the nearest recorded bucket (so a table swept
    at a few sizes still answers everywhere deterministically).
    """

    entries: tuple[tuple[int, int, int, int], ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "entries", tuple(tuple(int(x) for x in row) for row in self.entries)
        )

    def lookup(self, build_size: int, batch_size: int) -> tuple[int, int] | None:
        """The tiles for the nearest recorded bucket (None on an empty table).

        Distance is measured in octaves (log2 space) on both axes, with a
        deterministic tie-break on the sorted entry order.
        """
        if not self.entries:
            return None
        want_b = _pow2_bucket(build_size).bit_length()
        want_q = _pow2_bucket(batch_size).bit_length()
        best = min(
            sorted(self.entries),
            key=lambda row: (
                abs(row[0].bit_length() - want_b) + abs(row[1].bit_length() - want_q),
                row,
            ),
        )
        return best[2], best[3]

    def to_json(self) -> list[list[int]]:
        return [list(row) for row in sorted(self.entries)]

    @classmethod
    def from_json(cls, rows) -> "TileTable":
        return cls(entries=tuple(tuple(int(x) for x in row) for row in rows or ()))


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution strategy for one engine call chain.  Frozen + hashable.

    ``impl``         — ``"auto" | "fused" | "reference"`` executor choice.
    ``pipeline``     — fused-kernel bucket-stripe staging: ``"auto"`` uses
                       the double-buffered DMA kernel on TPU and the
                       single-buffer path elsewhere; ``"on"`` forces the
                       double-buffered kernel (interpret mode included —
                       the differential tests do this); ``"off"`` forces
                       the single-buffer path.  Byte-identical either way.
    ``donate``       — donate input state buffers (fused only; unsafe when
                       a restructure retry may replay the batch).
    ``block_q``      — ops per fused-kernel window (None → default/tile
                       table).
    ``block_b``      — bucket stripes per fused-kernel block (None →
                       default/tile table).
    ``tile_table``   — autotuned tiles consulted when block_q/block_b are
                       None (explicit overrides always win).
    ``max_results``  — per-batch dense RANGE output budget (static).
    ``capacity``     — a2a per-(src, dst) routing capacity (None → policy:
                       ``shard_apply_ops`` uses the never-overflowing chunk
                       size, ``shard_apply_ops_safe`` the skew-derived
                       ``default_a2a_capacity``).
    ``routing``      — sharded routing: ``"replicated" | "a2a"``.
    ``validate``     — run ``check_invariants`` on results (safe drivers).
    ``validate_ranges`` — run ``check_range_results`` (safe drivers).
    """

    impl: str = "auto"
    pipeline: str = "auto"
    donate: bool = False
    block_q: int | None = None
    block_b: int | None = None
    tile_table: TileTable | None = None
    max_results: int = DEFAULT_MAX_RESULTS
    capacity: int | None = None
    routing: str = "replicated"
    validate: bool = False
    validate_ranges: bool = False

    def __post_init__(self):
        if self.impl not in ("auto", "fused", "reference"):
            raise ValueError(f"unknown impl: {self.impl!r}")
        if self.pipeline not in ("auto", "on", "off"):
            raise ValueError(f"unknown pipeline mode: {self.pipeline!r}")
        if self.routing not in ("replicated", "a2a"):
            raise ValueError(f"unknown routing: {self.routing!r}")

    def replace(self, **kw) -> "ExecConfig":
        return dataclasses.replace(self, **kw)

    def resolve_blocks(self, build_size: int, batch_size: int) -> tuple[int | None, int | None]:
        """The (block_q, block_b) to hand the fused kernel: explicit
        overrides win, then the tile table, then (None, None) → kernel
        defaults."""
        bq, bb = self.block_q, self.block_b
        if (bq is None or bb is None) and self.tile_table is not None:
            hit = self.tile_table.lookup(build_size, batch_size)
            if hit is not None:
                bq = bq if bq is not None else hit[0]
                bb = bb if bb is not None else hit[1]
        return bq, bb


# --- legacy-keyword shims ---------------------------------------------------

# entry points that already warned this process (warn once per entry point)
_warned: set[str] = set()


def reset_deprecation_warnings() -> None:
    """Re-arm the warn-once latches (tests asserting the warning use this)."""
    _warned.clear()


def resolve_config(entry: str, config: ExecConfig | None, /, **legacy) -> ExecConfig:
    """Build the effective ExecConfig for an entry point.

    ``legacy`` maps deprecated keyword names to their passed values, with
    :data:`_UNSET` marking "not passed".  Passing any deprecated keyword
    warns once per ``entry`` and is rejected when ``config=`` is also
    given (the two would silently fight otherwise).
    """
    passed = {k: v for k, v in legacy.items() if v is not _UNSET}
    if not passed:
        return config if config is not None else ExecConfig()
    if config is not None:
        raise TypeError(
            f"{entry}: pass config=ExecConfig(...) OR the deprecated keywords "
            f"{sorted(passed)}, not both"
        )
    if entry not in _warned:
        _warned.add(entry)
        warnings.warn(
            f"{entry}: keyword(s) {sorted(passed)} are deprecated — pass "
            f"config=ExecConfig(...) instead (shims drop next release)",
            DeprecationWarning,
            stacklevel=3,
        )
    return ExecConfig(**passed)
