"""FliX core: the paper's flipped-indexing CDS as a composable JAX module."""

from repro.core.state import (
    EMPTY,
    KEY_DTYPE,
    MAX_VALID,
    MIN_KEY,
    NOT_FOUND,
    VAL_DTYPE,
    FliXState,
    empty_state,
)
from repro.core.batch import (
    bucket_of,
    bucket_slices,
    dedup_last_wins,
    gather_kv_sublists,
    gather_sublists,
    sort_batch,
)
from repro.core.build import build, build_from_sorted, plan_geometry
from repro.core.config import (
    ExecConfig,
    TileTable,
    reset_deprecation_warnings,
    resolve_config,
)
from repro.core.query import (
    dense_range_scan,
    point_query,
    range_query,
    successor_query,
    with_successor_cache,
)
from repro.core.insert import insert, insert_safe, insert_with_slices
from repro.core.delete import delete, merge_underfull
from repro.core.expiry import NO_EXPIRY, attach_expiry, bucket_min_exp, expire_state
from repro.core.ops import (
    DEFAULT_MAX_RESULTS,
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_NOP,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
    OpBatch,
    apply_ops,
    apply_ops_safe,
    make_ops,
    touched_buckets,
    unsort,
)
from repro.core.invariants import (
    check_invariants,
    check_range_results,
    check_tiered_invariants,
)
from repro.core.residency import TieredFliX, bucket_device_bytes
from repro.core.restructure import (
    restructure,
    restructure_auto,
    restructure_grow,
    restructure_shrink,
)
