"""FliX data-layer state.

The paper's data layer is a set of *buckets*, each a chain of fixed-capacity
*nodes* with per-node metadata (``maxKey``, ``size``) plus a global
max-key-per-bucket array (MKBA).  On TPU we use a pointerless layout: bucket
``b`` owns node *slots* ``keys[b, 0..num_nodes[b])`` — slot order is chain
order.  "Allocating" a node activates the next slot; "freeing" compacts slots
left.  See DESIGN.md §3 for the GPU→TPU adaptation argument.

Invariants (checked by ``tests/test_invariants.py``):
  I1. within a node, ``keys[b, j, :count]`` is strictly ascending; the rest of
      the row is ``EMPTY``.
  I2. slots are chain-ordered: every key in node ``j`` < every key in ``j+1``.
  I3. every key in bucket ``b`` is ≤ ``mkba[b]`` and > ``mkba[b-1]``.
  I4. ``node_max[b, j]`` equals the largest key of node ``j`` (``EMPTY`` when
      the slot is inactive), so each ``node_max[b]`` row is ascending.
  I5. ``mkba`` is strictly ascending with ``mkba[-1] == MAX_VALID``.

Two further invariants live in other layers: I6 (expiry liveness,
``core/expiry.py``) and I7 (tiered residency: every live row reachable in
exactly one tier, resident bytes ≤ budget after commit —
``core/residency.py`` / ``check_tiered_invariants``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

KEY_DTYPE = jnp.int32
VAL_DTYPE = jnp.int32

EMPTY = jnp.iinfo(jnp.int32).max        # empty slot / inactive-node sentinel
MAX_VALID = EMPTY - 1                   # largest storable key
MIN_KEY = jnp.iinfo(jnp.int32).min      # conceptual lower fence
NOT_FOUND = jnp.int32(-1)               # point-query miss sentinel


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FliXState:
    """Functional FliX instance. All arrays are device arrays (a pytree)."""

    keys: jax.Array        # [nb, npb, ns] KEY_DTYPE, EMPTY-padded
    vals: jax.Array        # [nb, npb, ns] VAL_DTYPE
    node_count: jax.Array  # [nb, npb] int32, keys stored per node slot
    node_max: jax.Array    # [nb, npb] KEY_DTYPE, EMPTY when inactive
    num_nodes: jax.Array   # [nb] int32, active slots per bucket
    mkba: jax.Array        # [nb] KEY_DTYPE, max allowable key per bucket
    needs_restructure: jax.Array  # [] bool, bucket overflow pressure flag

    # Optional successor-fallback cache (``core.query.with_successor_cache``):
    # the padded suffix-min rows over per-bucket minimum present keys,
    # ``succ_smin``/``succ_sidx`` of shape [nb+1].  Every mutating operation
    # (build, insert, delete, restructure, apply) constructs its result state
    # without these fields, so the cache is invalidated by construction; only
    # read-only query streams carry it forward.
    succ_smin: jax.Array | None = None
    succ_sidx: jax.Array | None = None

    # Optional per-key expiry column (``core.expiry``): absolute deadlines in
    # the same virtual-time units as the ``now`` threaded through apply_ops,
    # ``NO_EXPIRY`` (== EMPTY) at empty slots and for keys without a TTL.
    # Unlike the successor cache this is *durable logical state* — it is part
    # of the serialized payload and is NOT dropped by ``drop_volatile``.
    exps: jax.Array | None = None  # [nb, npb, ns] VAL_DTYPE or None

    # ---- static geometry -------------------------------------------------
    @property
    def num_buckets(self) -> int:
        return self.keys.shape[0]

    @property
    def geometry(self) -> tuple[int, int, int]:
        """(num_buckets, nodes_per_bucket, node_size) — the static shape the
        host plans at build/restructure time.  Two states with the same
        geometry and mkba belong to the same *fence epoch*: insert/delete
        never move fences (paper §3.2), so the durability layer's
        dirty-bucket tracking is valid between restructures."""
        return self.keys.shape[0], self.keys.shape[1], self.keys.shape[2]

    def drop_volatile(self) -> "FliXState":
        """This state without its volatile successor-cache fields.

        The cache is derived data (``with_successor_cache`` rebuilds it from
        the resident arrays), so it is excluded from the durable logical
        state: serialization and the reference engine's lax.cond phases both
        need the cache-free pytree structure.
        """
        if self.succ_smin is None and self.succ_sidx is None:
            return self
        return dataclasses.replace(self, succ_smin=None, succ_sidx=None)

    @property
    def nodes_per_bucket(self) -> int:
        return self.keys.shape[1]

    @property
    def node_size(self) -> int:
        return self.keys.shape[2]

    @property
    def bucket_capacity(self) -> int:
        return self.nodes_per_bucket * self.node_size

    # ---- derived metrics -------------------------------------------------
    def live_keys(self) -> jax.Array:
        return jnp.sum(self.node_count)

    def total_nodes(self) -> jax.Array:
        return jnp.sum(self.num_nodes)

    def memory_bytes(self) -> int:
        """Allocated footprint in bytes (QTMF denominator)."""
        total = 0
        for f in dataclasses.fields(self):
            arr = getattr(self, f.name)
            if arr is not None:
                total += arr.size * arr.dtype.itemsize
        return total

    def bucket_memory_bytes(self) -> int:
        """Bytes one bucket contributes across every per-bucket array — the
        page size of the tiered engine's residency accounting (I7): a device
        budget of ``B`` bytes admits ``B // bucket_memory_bytes()`` resident
        buckets."""
        from repro.core.residency import bucket_device_bytes

        return bucket_device_bytes(
            self.nodes_per_bucket, self.node_size, self.exps is not None
        )

    def bucket_lower_fence(self) -> jax.Array:
        """mkba shifted right: bucket b covers keys in (fence[b], mkba[b]]."""
        return jnp.concatenate(
            [jnp.array([MIN_KEY], dtype=KEY_DTYPE), self.mkba[:-1]]
        )


def empty_state(num_buckets: int, nodes_per_bucket: int, node_size: int) -> FliXState:
    """An all-empty FliX instance with the given static geometry."""
    nb, npb, ns = num_buckets, nodes_per_bucket, node_size
    mkba = jnp.full((nb,), MAX_VALID, dtype=KEY_DTYPE)
    # ascending mkba with last = MAX_VALID: spread fences so inserts route
    # everything to the final bucket until a build/restructure assigns ranges.
    # For an empty structure we simply give every bucket the max fence except
    # making them ascending by subtracting offsets is unnecessary: query and
    # routing use searchsorted(side='left'), which tolerates equal fences.
    return FliXState(
        keys=jnp.full((nb, npb, ns), EMPTY, dtype=KEY_DTYPE),
        vals=jnp.zeros((nb, npb, ns), dtype=VAL_DTYPE),
        node_count=jnp.zeros((nb, npb), dtype=jnp.int32),
        node_max=jnp.full((nb, npb), EMPTY, dtype=KEY_DTYPE),
        num_nodes=jnp.zeros((nb,), dtype=jnp.int32),
        mkba=mkba,
        needs_restructure=jnp.array(False),
    )


def sort_bucket_rows(flat_k: jax.Array, flat_v: jax.Array):
    """Sort each [nb, cap] bucket row ascending (vals follow their key).
    EMPTY is int32 max, so padding lands at the end of every row."""
    order = jnp.argsort(flat_k, axis=1, stable=True)
    return (
        jnp.take_along_axis(flat_k, order, axis=1),
        jnp.take_along_axis(flat_v, order, axis=1),
    )


def flatten_bucket_sorted(state: FliXState) -> tuple[jax.Array, jax.Array]:
    """Per-bucket flattened (keys, vals), sorted ascending with EMPTY at end.

    Node rows are already sorted and chain-ordered (I1+I2), but interior
    EMPTY padding breaks global sortedness, so we re-sort each bucket row.
    Shape: [nb, npb*ns].
    """
    nb = state.num_buckets
    return sort_bucket_rows(state.keys.reshape(nb, -1), state.vals.reshape(nb, -1))
