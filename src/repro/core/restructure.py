"""Restructuring (paper §3.5, Figure 3d, Table 4).

Flattens every bucket's chain into single-node buckets, merges underfull
nodes, and re-emits a uniform half-full structure aligned to the *current*
key distribution — bounding both query latency (chain length → 1) and memory
(node recovery).  Entirely device-resident: one global sort + the standard
build; the host only chooses the new static geometry (the analogue of the
paper's kernel relaunch with a new bucket count).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.build import build_from_sorted, plan_geometry
from repro.core.state import EMPTY, FliXState


@partial(
    jax.jit,
    static_argnames=("num_buckets", "nodes_per_bucket", "node_size", "fill"),
)
def restructure(
    state: FliXState,
    *,
    num_buckets: int,
    nodes_per_bucket: int | None = None,
    node_size: int | None = None,
    fill: float = 0.5,
) -> FliXState:
    """Rebuild into the given geometry from the current live contents."""
    npb = nodes_per_bucket or state.nodes_per_bucket
    ns = node_size or state.node_size
    flat_k = state.keys.reshape(-1)
    flat_v = state.vals.reshape(-1)
    order = jnp.argsort(flat_k, stable=True)     # EMPTY sentinels sort last
    built = build_from_sorted(
        flat_k[order],
        flat_v[order],
        num_buckets=num_buckets,
        nodes_per_bucket=npb,
        node_size=ns,
        fill=fill,
    )
    if state.exps is None:
        return built
    # expiry plane: the identical build with the expiry column in the value
    # slot lands the identical layout (build positions depend on keys only).
    from repro.core.expiry import NO_EXPIRY

    flat_e = state.exps.reshape(-1)
    built_e = build_from_sorted(
        flat_k[order],
        flat_e[order],
        num_buckets=num_buckets,
        nodes_per_bucket=npb,
        node_size=ns,
        fill=fill,
    )
    exps = jnp.where(built.keys == EMPTY, NO_EXPIRY, built_e.vals)
    return dataclasses.replace(built, exps=exps)


def plan(state: FliXState, *, extra_keys: int = 0, fill: float = 0.5):
    """Host-side geometry planning from the current live count."""
    live = int(state.live_keys()) + extra_keys
    return plan_geometry(
        live,
        node_size=state.node_size,
        nodes_per_bucket=state.nodes_per_bucket,
        fill=fill,
    )


def restructure_auto(state: FliXState, *, fill: float = 0.5) -> FliXState:
    """Restructure to the geometry the initial build would choose now."""
    nb, npb, ns = plan(state, fill=fill)
    return restructure(
        state, num_buckets=nb, nodes_per_bucket=npb, node_size=ns, fill=fill
    )


def restructure_shrink(
    state: FliXState,
    *,
    fill: float = 0.5,
    nodes_per_bucket: int | None = None,
) -> tuple[FliXState, int]:
    """Compact to the smallest geometry for the current live set, reclaiming
    pages (paper §3.5 "memory reclamation").

    ``restructure_auto`` re-plans the bucket count but keeps the old
    ``nodes_per_bucket``, so a structure that once grew wide never gives
    chain capacity back.  Shrink narrows both axes: the bucket count is
    sized for the live keys at ``fill`` and the chain depth drops to the
    smallest count whose capacity is still ≥ 2× the per-bucket fill (the
    same headroom ``restructure_grow`` relies on, so a shrink never makes
    the very next insert batch overflow-prone).

    Returns ``(new_state, reclaimed_bytes)`` where ``reclaimed_bytes`` is
    the drop in allocated footprint (0 if the structure could not shrink).
    """
    live = int(state.live_keys())
    p = max(1, int(state.node_size * fill))
    nb = max(1, math.ceil(live / p))
    if nodes_per_bucket is None:
        # capacity npb*ns ≥ 2p: content can double before overflow.
        npb = max(2, math.ceil(2 * p / state.node_size))
    else:
        npb = nodes_per_bucket
    new = restructure(
        state,
        num_buckets=nb,
        nodes_per_bucket=npb,
        node_size=state.node_size,
        fill=fill,
    )
    reclaimed = max(0, state.memory_bytes() - new.memory_bytes())
    return new, reclaimed


def restructure_grow(
    state: FliXState, *, extra_keys: int, fill: float = 0.5
) -> FliXState:
    """Restructure sized for ``extra_keys`` more keys (overflow recovery).

    Geometry guarantee used by ``insert_safe``: with ``fill`` ≤ 1/2 the new
    buckets are half full, so a subsequent insert of ``extra_keys`` keys can
    at most double any bucket's content — which fits, since capacity is
    ``nodes_per_bucket/fill ≥ 2×`` the initial fill.  Worst-case skew (every
    new key in one bucket) is additionally covered by sizing the bucket count
    for ``live + extra`` and capping the per-bucket sublist at capacity.
    """
    live = int(state.live_keys())
    p = max(1, int(state.node_size * fill))
    # enough buckets that even if all extra keys land between two adjacent
    # fences, that bucket's merged content (p + extra ≤ capacity) fits.
    nb = max(1, math.ceil((live + extra_keys) / p))
    cap = state.nodes_per_bucket * state.node_size
    if p + extra_keys > cap:
        # pathological skew: widen nodes_per_bucket so one bucket can absorb
        # the whole batch (host-side realloc, mirrors the paper's adaptive
        # compute-to-bucket discussion in §3.4).
        npb = math.ceil((p + extra_keys) / state.node_size)
    else:
        npb = state.nodes_per_bucket
    return restructure(
        state,
        num_buckets=nb,
        nodes_per_bucket=npb,
        node_size=state.node_size,
        fill=fill,
    )
