"""Lazy TTL expiry (DESIGN.md §14).

Per-key absolute expiry deadlines live in an optional third state column
(``FliXState.exps``, same [nb, npb, ns] layout as the value column).  Time is
*never* read from the wall clock: every engine entry point takes an explicit
``now`` scalar and a row is expired iff ``exp <= now`` (a key expires exactly
AT its deadline).  ``NO_EXPIRY`` (== EMPTY == int32 max) marks keys without a
TTL — since ``now`` is a storable value (``now <= MAX_VALID < NO_EXPIRY``),
such rows never expire.

Expiry is *lazy*: ``expire_state`` runs as a pre-pass of the update phase of
``apply_ops`` (before inserts/deletes/reads), physically reclaiming expired
rows with exactly the same in-node + chain compaction as ``core.delete`` so
every downstream executor — reference, fused, sharded — sees a plain FliX
state with the expired rows already gone.  Buckets with no expired rows are
passed through *byte-identical* (not merely value-identical), which keeps the
durability layer's dirty-bucket delta tracking exact.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, FliXState

# Expiry sentinel: "never expires".  Equal to EMPTY so an all-EMPTY expiry
# column is the identity under expiry, and a freshly reclaimed slot holds the
# same sentinel as an empty one.
NO_EXPIRY = EMPTY


@jax.jit
def expire_state(state: FliXState, now: jax.Array):
    """Physically reclaim every row with ``exp <= now``.

    Returns ``(state', n_expired)``.  Mirrors ``core.delete.delete``'s
    compaction (in-node shift-left + chain slot compaction) with the expiry
    column carried alongside keys/vals.  Buckets containing no expired row
    keep their arrays byte-identical to the input.
    """
    assert state.exps is not None, "expire_state needs an expiry column"
    now = jnp.asarray(now, dtype=KEY_DTYPE)

    live = state.keys != EMPTY
    expired = live & (state.exps <= now)  # [nb, npb, ns]

    # in-node compaction: survivors shift left, EMPTY fills the tail.
    masked = jnp.where(expired, EMPTY, state.keys)
    masked_e = jnp.where(expired, NO_EXPIRY, state.exps)
    order = jnp.argsort(masked, axis=2, stable=True)
    new_keys = jnp.take_along_axis(masked, order, axis=2)
    new_vals = jnp.take_along_axis(state.vals, order, axis=2)
    new_exps = jnp.take_along_axis(masked_e, order, axis=2)

    node_count = jnp.sum(new_keys != EMPTY, axis=2).astype(jnp.int32)

    # chain compaction: drop empty nodes, keep chain order.
    empty_slot = node_count == 0
    slot_order = jnp.argsort(empty_slot, axis=1, stable=True)
    new_keys = jnp.take_along_axis(new_keys, slot_order[..., None], axis=1)
    new_vals = jnp.take_along_axis(new_vals, slot_order[..., None], axis=1)
    new_exps = jnp.take_along_axis(new_exps, slot_order[..., None], axis=1)
    node_count = jnp.take_along_axis(node_count, slot_order, axis=1)

    node_max = jnp.where(
        node_count > 0,
        jnp.take_along_axis(
            new_keys, jnp.maximum(node_count - 1, 0)[..., None], axis=2
        )[..., 0],
        EMPTY,
    ).astype(KEY_DTYPE)
    num_nodes = jnp.sum(node_count > 0, axis=1).astype(jnp.int32)

    # untouched buckets stay byte-identical (delta-snapshot dirty tracking
    # relies on this: an unchanged bucket must not change bytes).
    changed = jnp.any(expired, axis=(1, 2))  # [nb]
    c3 = changed[:, None, None]
    c2 = changed[:, None]
    new_state = FliXState(
        keys=jnp.where(c3, new_keys, state.keys),
        vals=jnp.where(c3, new_vals, state.vals),
        node_count=jnp.where(c2, node_count, state.node_count),
        node_max=jnp.where(c2, node_max, state.node_max),
        num_nodes=jnp.where(changed, num_nodes, state.num_nodes),
        mkba=state.mkba,
        needs_restructure=state.needs_restructure,
        exps=jnp.where(c3, new_exps, state.exps),
    )
    return new_state, jnp.sum(expired)


def attach_expiry(state: FliXState, exps: jax.Array | None = None) -> FliXState:
    """State with an expiry column attached (all-NO_EXPIRY when not given)."""
    if state.exps is not None and exps is None:
        return state
    if exps is None:
        exps = jnp.full(state.keys.shape, NO_EXPIRY, dtype=KEY_DTYPE)
    return dataclasses.replace(state, exps=exps)


def bucket_min_exp(state: FliXState) -> jax.Array:
    """Per-bucket minimum live expiry deadline ([nb], ``NO_EXPIRY`` for
    buckets with no live deadline-carrying rows — and for every bucket when
    no expiry column is materialized).

    This is the residency plane's expiry metadata (DESIGN.md §15): the
    tiered engine keeps it fresh for all buckets so its prefetch pre-pass
    can promote exactly the buckets the expire sweep at ``now`` would
    physically change (``min_exp <= now``) without scanning cold tiers.
    """
    if state.exps is None:
        return jnp.full((state.num_buckets,), NO_EXPIRY, dtype=jnp.int32)
    return jnp.min(
        jnp.where(state.keys != EMPTY, state.exps, NO_EXPIRY), axis=(1, 2)
    ).astype(jnp.int32)
