"""Mixed-operation batch engine (the paper's batch execution model, §4.1).

The paper's execution unit is *one sorted batch per step*: the engine sorts
whatever operations arrived — inserts, deletes, point lookups, successor
probes — into a single key-ordered batch, and each bucket pulls *all* of its
work with one binary search.  This module is that engine:

  * ``OpBatch`` — a tagged operation batch (tag, key, val per slot).
  * ``make_ops`` — the one global sort (the only O(N log N) step).
  * ``apply_ops`` — the executor: one ``bucket_slices`` routing of the whole
    mixed batch.  Per-type views are *derived* from it with no second sort:
    order-preserving prefix-count scatters compact the insert/delete keys,
    and the insert phase's slice boundaries come from the single routing via
    prefix counts (``starts_ins = C_ins[starts]``) rather than a second
    fence routing.  The delete phase then uses deletion's flipped
    *whole-batch* membership search (data looks up the batch — no fence
    routing at all), and reads are answered from the updated state by the
    flipped compare-count forms (which binary-search the fences per query,
    as every FliX read does).

Within a batch the semantics are update-then-read:

  1. INSERT ops merge in first (upsert — incoming value wins),
  2. DELETE ops remove physically (present-key hits only),
  3. POINT, SUCCESSOR, and RANGE ops observe the post-update state.

RANGE is the ordered-CDS capability hash tables lack (the paper's central
functionality claim): an op reuses the key column for ``lo`` and the val
column for ``hi`` and answers the half-open ``[lo, hi)``.  Each batch
carries one static ``max_results`` output budget; results are packed
densely at exclusive-scan offsets (earlier sorted ops win the budget, each
op emits a prefix of its smallest in-range keys — deterministic, and
truncation is flagged in ``stats``).  See DESIGN.md §10.

``apply_ops`` has two executors behind one contract (``impl=``): the jnp
*reference* engine — four device passes whose insert path literally shares
``insert_with_slices`` with ``core.insert``, delete path shares
``core.delete``, read paths share ``core.query`` — and the *fused*
compute-to-bucket Pallas kernel (``kernels/flix_apply``, DESIGN.md §9) that
executes the whole update-then-read sequence in one VMEM-resident pass per
bucket.  Both are byte-identical to sequential per-type application
(``insert`` → ``delete`` → ``point_query`` → ``successor_query`` on the
sorted per-type sub-batches); ``tests/test_differential.py`` pins this down.

Precondition: at most one *update* op (INSERT or DELETE) per key per batch
(reads may repeat keys freely) — the same uniqueness contract ``insert``
already imposes.  ``OP_NOP`` slots (key must be ``EMPTY``) let callers pad
batches to a fixed size so jit traces once per geometry.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.batch import bucket_slices
from repro.core.config import (
    _UNSET,
    DEFAULT_MAX_RESULTS as DEFAULT_MAX_RESULTS,  # canonical home: core.config
    ExecConfig as ExecConfig,
    resolve_config,
)
from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, VAL_DTYPE, FliXState

OP_INSERT = 0
OP_DELETE = 1
OP_POINT = 2
OP_SUCCESSOR = 3
OP_NOP = 4  # padding slot; key must be EMPTY so it routes past every bucket
OP_RANGE = 5  # key column = lo, val column = hi; answers [lo, hi)
OP_EXPIRE = 6  # get-or-set with TTL: exp column = absolute deadline; returns
#                the stored value (refreshing its TTL to the op's deadline)
#                when the key is live, else inserts (key, val, exp) and
#                returns NOT_FOUND.  Counts as an update op.  Requires the
#                batch to carry an exp column (DESIGN.md §14).

OP_DTYPE = jnp.int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OpBatch:
    """A key-sorted batch of tagged operations (a pytree of device arrays)."""

    tag: jax.Array  # [N] OP_DTYPE
    key: jax.Array  # [N] KEY_DTYPE, ascending (EMPTY = NOP padding, at end;
    #                 RANGE ops sort by their lo, which lives here)
    val: jax.Array  # [N] VAL_DTYPE (INSERT: value; RANGE: exclusive hi)
    # Optional per-op expiry column (KEY_DTYPE absolute deadlines;
    # NO_EXPIRY for ops without one).  INSERT ops take it as the new key's
    # TTL; EXPIRE ops require it.  ``None`` = legacy TTL-free batch.
    exp: jax.Array | None = None

    @property
    def size(self) -> int:
        return self.key.shape[0]

    def to_host(self):
        """The batch as host numpy arrays ``(tag, key, val, exp)`` — the form
        the write-ahead log frames (``checkpoint.wal``) and the dirty-bucket
        tracker consume (one device transfer, shared by both).  ``exp`` is
        ``None`` for TTL-free batches."""
        import numpy as np

        return (
            np.asarray(jax.device_get(self.tag)),
            np.asarray(jax.device_get(self.key)),
            np.asarray(jax.device_get(self.val)),
            None if self.exp is None else np.asarray(jax.device_get(self.exp)),
        )

    @classmethod
    def from_host(cls, tag, key, val, exp=None) -> "OpBatch":
        """Rehydrate a batch from host arrays *without re-sorting*: WAL
        records store already-sorted batches, and replay must apply exactly
        the bytes that were logged."""
        return cls(
            tag=jnp.asarray(tag, OP_DTYPE),
            key=jnp.asarray(key, KEY_DTYPE),
            val=jnp.asarray(val, VAL_DTYPE),
            exp=None if exp is None else jnp.asarray(exp, KEY_DTYPE),
        )


def make_ops(tags, keys, vals=None, *, exps=None, pad_to: int | None = None):
    """Sort a raw operation list by key into an :class:`OpBatch`.

    This is the engine's one global sort.  Returns ``(ops, perm)`` where
    ``perm[j]`` is the sorted position input op ``j`` landed at, so
    ``sorted_result[perm]`` (= :func:`unsort`) maps per-op results back to
    submission order.

    ``exps`` attaches a per-op expiry-deadline column (sorted and padded
    with ``NO_EXPIRY`` alongside the keys); required for batches containing
    ``OP_EXPIRE`` or TTL'd inserts.

    ``pad_to`` appends ``OP_NOP`` slots up to a fixed size so callers with
    variable-length steps trace one jit program per geometry.
    """
    from repro.core.expiry import NO_EXPIRY

    tags = jnp.asarray(tags, OP_DTYPE)
    keys = jnp.asarray(keys, KEY_DTYPE)
    if vals is None:
        vals = jnp.zeros(keys.shape, VAL_DTYPE)
    vals = jnp.asarray(vals, VAL_DTYPE)
    if exps is not None:
        exps = jnp.asarray(exps, KEY_DTYPE)
    if pad_to is not None and pad_to > keys.shape[0]:
        extra = pad_to - keys.shape[0]
        tags = jnp.concatenate([tags, jnp.full((extra,), OP_NOP, OP_DTYPE)])
        keys = jnp.concatenate([keys, jnp.full((extra,), EMPTY, KEY_DTYPE)])
        vals = jnp.concatenate([vals, jnp.zeros((extra,), VAL_DTYPE)])
        if exps is not None:
            exps = jnp.concatenate([exps, jnp.full((extra,), NO_EXPIRY, KEY_DTYPE)])
    order = jnp.argsort(keys, stable=True)
    # inverse permutation (input position -> sorted position) by O(N) scatter
    perm = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return (
        OpBatch(
            tag=tags[order],
            key=keys[order],
            val=vals[order],
            exp=None if exps is None else exps[order],
        ),
        perm,
    )


def unsort(sorted_result: jax.Array, perm: jax.Array) -> jax.Array:
    """Map a sorted-order result array back to submission order."""
    return sorted_result[perm]


def touched_buckets(mkba_host, tag, key, val, *, live=None, min_exp=None, now=None):
    """Host-side prefetch pre-pass: which buckets a sorted batch can touch.

    The tiered engine (``core.residency``, DESIGN.md §15) promotes exactly
    the buckets whose bytes the executors may consult, so that running the
    *unchanged* executors against the packed resident subset is
    bucket-for-bucket identical to running them against the full state.
    The routing is the same one binary search per key the engine itself
    performs (``bucket_slices`` transposed to the classical direction, with
    the same ``min(b, nb-1)`` clamp the read paths apply).

    Per op type:
      * INSERT / DELETE / POINT / EXPIRE — the op's fence bucket.
      * RANGE — every bucket from ``b(lo)`` through ``b(hi)`` *inclusive*:
        the dense scan's rank arithmetic cancels the live counts of buckets
        entirely outside ``[b(lo), b(hi)]`` but consults every bucket
        inside it.
      * SUCCESSOR — ``b(q)`` plus the forward fence walk up to (and
        including) the first bucket *guaranteed* non-empty after the
        batch's own updates and expiry pass (an insert routed to it, or
        surviving pre-batch rows).  The out-of-bucket fallback reads the
        first non-empty bucket after ``b(q)``; promoting the whole walk
        makes the packed suffix-min agree with the full one.
      * additionally, when ``now`` is given — every bucket whose minimum
        live expiry deadline is ≤ ``now``: the expiry pre-pass physically
        reclaims those rows, so the buckets must be resident to change.

    ``live`` / ``min_exp`` are per-bucket host metadata ([nb] arrays: live
    row count; minimum live expiry deadline, ``NO_EXPIRY`` without TTLs).
    Both are optional, degrading conservatively: without ``live`` only
    inserts can guarantee non-emptiness (longer successor walks); a TTL'd
    caller must supply ``min_exp`` whenever it passes ``now``.

    All inputs are host numpy arrays; returns an [nb] bool mask.
    """
    import numpy as np

    mkba = np.asarray(mkba_host)
    nb = mkba.shape[0]
    tag = np.asarray(tag)
    key = np.asarray(key)
    val = np.asarray(val)
    touched = np.zeros(nb, dtype=bool)

    def b_of(q):
        return np.minimum(np.searchsorted(mkba, q, side="left"), nb - 1)

    simple = (
        (tag == OP_INSERT) | (tag == OP_DELETE) | (tag == OP_POINT) | (tag == OP_EXPIRE)
    )
    if simple.any():
        touched[b_of(key[simple])] = True

    is_range = tag == OP_RANGE
    if is_range.any():
        lo_b = b_of(key[is_range])
        hi_b = b_of(val[is_range])
        touched[lo_b] = True
        touched[hi_b] = True
        ok = lo_b <= hi_b
        if ok.any():
            d = np.zeros(nb + 1, np.int64)
            np.add.at(d, lo_b[ok], 1)
            np.add.at(d, hi_b[ok] + 1, -1)
            touched |= np.cumsum(d[:nb]) > 0

    is_succ = tag == OP_SUCCESSOR
    if is_succ.any():
        n_ins = np.zeros(nb, np.int64)
        upd_ins = ((tag == OP_INSERT) | (tag == OP_EXPIRE)) & (key != EMPTY)
        if upd_ins.any():
            np.add.at(n_ins, b_of(key[upd_ins]), 1)
        guaranteed = n_ins > 0
        if live is not None:
            n_del = np.zeros(nb, np.int64)
            upd_del = (tag == OP_DELETE) & (key != EMPTY)
            if upd_del.any():
                np.add.at(n_del, b_of(key[upd_del]), 1)
            survives = np.asarray(live).astype(np.int64) - n_del > 0
            if now is not None:
                if min_exp is None:
                    survives &= False  # no deadline metadata: nothing is safe
                else:
                    survives &= np.asarray(min_exp).astype(np.int64) > int(now)
            guaranteed |= survives
        b = b_of(key[is_succ])
        touched[b] = True
        # next_g[j] = first guaranteed bucket index ≥ j (nb if none)
        gidx = np.where(guaranteed, np.arange(nb, dtype=np.int64), nb)
        next_g = np.minimum.accumulate(gidx[::-1])[::-1]
        next_g = np.append(next_g, nb)
        starts = b + 1
        inb = starts < nb
        if inb.any():
            s = starts[inb]
            t = next_g[s]
            e = np.where(t < nb, t, nb - 1)  # walk to the end if none
            d = np.zeros(nb + 1, np.int64)
            np.add.at(d, s, 1)
            np.add.at(d, e + 1, -1)
            touched |= np.cumsum(d[:nb]) > 0

    if now is not None and min_exp is not None:
        touched |= np.asarray(min_exp).astype(np.int64) <= int(now)
    return touched


def _compact_by_mask(keys: jax.Array, mask: jax.Array, vals: jax.Array | None = None):
    """Front-pack ``keys[mask]`` preserving order; EMPTY tail.  No sort:
    destinations are a prefix count, so ascending order is preserved."""
    n = keys.shape[0]
    dest = jnp.where(mask, jnp.cumsum(mask) - 1, n)  # n = discard slot
    out_k = jnp.full((n + 1,), EMPTY, KEY_DTYPE).at[dest].set(keys)[:n]
    if vals is None:
        return out_k
    out_v = jnp.zeros((n + 1,), VAL_DTYPE).at[dest].set(vals)[:n]
    return out_k, out_v


def derive_type_views(state: FliXState, tag: jax.Array, key: jax.Array, val: jax.Array):
    """The engine's single routing plus the per-type views derived from it.

    Shared by both executors (``_apply_ops_reference`` and
    ``kernels.flix_apply``) so the routing contract cannot diverge between
    them.  Returns ``(is_ins, is_del, ins_keys, ins_vals, del_keys,
    ins_starts, ins_ends)``: the mixed-batch slice boundaries are mapped to
    insert-slice boundaries by prefix counts — no second sort, no second
    fence routing.
    """
    starts, ends = bucket_slices(state, key)
    is_ins = tag == OP_INSERT
    is_del = tag == OP_DELETE
    ins_keys, ins_vals = _compact_by_mask(key, is_ins, val)
    del_keys = _compact_by_mask(key, is_del)
    c_ins = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(is_ins).astype(jnp.int32)]
    )
    return is_ins, is_del, ins_keys, ins_vals, del_keys, c_ins[starts], c_ins[ends]


@functools.partial(jax.jit, static_argnames=("max_results",))
def _apply_ops_reference(
    state: FliXState, ops: OpBatch, *, max_results: int = DEFAULT_MAX_RESULTS
):
    """Reference engine: five jnp phases (the oracle for the fused kernel)."""
    from repro.core.delete import delete
    from repro.core.insert import insert_with_slices
    from repro.core.query import dense_range_scan, point_query, successor_query

    # drop any successor cache up front: the update phases construct cache-
    # free states, and lax.cond branches must agree on the pytree structure
    state = state.drop_volatile()

    tag, key, val = ops.tag, ops.key, ops.val
    n = key.shape[0]

    # --- the single routing + derived per-type views (no second sort) -----
    (
        is_ins,
        is_del,
        ins_keys,
        ins_vals,
        del_keys,
        ins_starts,
        ins_ends,
    ) = derive_type_views(state, tag, key, val)

    # --- update phase: merge inserts, then physical deletes ---------------
    # an absent op class skips its phase entirely (lax.cond executes one
    # branch), so read-heavy batches don't pay the merge machinery; the
    # differential contract is correspondingly "apply the present types".
    s1, ins_stats = jax.lax.cond(
        jnp.any(is_ins),
        lambda: insert_with_slices(state, ins_keys, ins_vals, ins_starts, ins_ends),
        lambda: (
            state,
            {
                "inserted": jnp.int32(0),
                "nodes_after": jnp.sum(state.num_nodes),
                "splits": jnp.int32(0),
                "overflowed_buckets": jnp.int32(0),
            },
        ),
    )
    s2, del_stats = jax.lax.cond(
        jnp.any(is_del),
        lambda: delete(s1, del_keys),
        lambda: (s1, {"deleted": jnp.int32(0), "nodes_freed": jnp.int32(0)}),
    )

    # --- read phase: flipped compare-count against the updated state ------
    is_point = tag == OP_POINT
    is_succ = tag == OP_SUCCESSOR
    pv = jax.lax.cond(
        jnp.any(is_point),
        lambda: point_query(s2, key),
        lambda: jnp.full((n,), NOT_FOUND, VAL_DTYPE),
    )
    sk, sv = jax.lax.cond(
        jnp.any(is_succ),
        lambda: successor_query(s2, key),
        lambda: (
            jnp.full((n,), EMPTY, KEY_DTYPE),
            jnp.full((n,), NOT_FOUND, VAL_DTYPE),
        ),
    )
    # --- range phase: dense [lo, hi) scans against the updated state ------
    is_range = tag == OP_RANGE
    rk, rv, rstart, rcnt, rtrunc = jax.lax.cond(
        jnp.any(is_range),
        lambda: dense_range_scan(
            s2, is_range, key, val.astype(KEY_DTYPE), max_results=max_results
        ),
        lambda: (
            jnp.full((max_results,), EMPTY, KEY_DTYPE),
            jnp.full((max_results,), NOT_FOUND, VAL_DTYPE),
            jnp.zeros((n,), jnp.int32),
            jnp.zeros((n,), jnp.int32),
            jnp.int32(0),
        ),
    )

    results = {
        "value": jnp.where(is_point, pv, jnp.where(is_succ, sv, NOT_FOUND)),
        "succ_key": jnp.where(is_succ, sk, EMPTY),
        "range_key": rk,
        "range_val": rv,
        "range_start": rstart,
        "range_count": rcnt,
    }
    stats = {
        "inserted": ins_stats["inserted"],
        "deleted": del_stats["deleted"],
        "overflowed_buckets": ins_stats["overflowed_buckets"],
        "range_truncated": rtrunc,
    }
    return s2, results, stats


def _apply_ops_plain(state: FliXState, ops: OpBatch, *, impl: str, cfg: ExecConfig):
    """Dispatch one TTL-free batch to the chosen executor (impl resolved)."""
    if impl == "reference":
        return _apply_ops_reference(state, ops, max_results=cfg.max_results)
    if impl != "fused":
        raise ValueError(f"unknown apply_ops impl: {impl!r}")

    from repro.kernels.flix_apply import (
        DEFAULT_BLOCK_B,
        flix_apply_pallas,
        flix_apply_pallas_donated,
    )
    from repro.kernels.flix_query import DEFAULT_BLOCK_Q

    backend = jax.default_backend()
    fn = (
        flix_apply_pallas_donated
        if cfg.donate and backend != "cpu"
        else flix_apply_pallas
    )
    build_size = state.num_buckets * state.nodes_per_bucket * state.node_size
    block_q, block_b = cfg.resolve_blocks(build_size, ops.size)
    # "auto" pipelining is a backend property: the double-buffered DMA path
    # exists to overlap real HBM→VMEM copies with compute, so it engages on
    # TPU and falls back to the single-buffer kernel elsewhere.  "on"
    # forces it anywhere (interpret mode included — how the differential
    # suite proves byte-identity on CPU); "off" forces the fallback.
    pipeline = (backend == "tpu") if cfg.pipeline == "auto" else (cfg.pipeline == "on")
    return fn(
        state,
        ops.tag,
        ops.key,
        ops.val,
        block_q=block_q or DEFAULT_BLOCK_Q,
        block_b=block_b or DEFAULT_BLOCK_B,
        max_results=cfg.max_results,
        interpret=backend != "tpu",
        pipeline=pipeline,
    )


def _apply_ops_ttl(
    state: FliXState,
    ops: OpBatch,
    *,
    impl: str,
    cfg: ExecConfig,
    now=None,
):
    """TTL-aware batch execution (DESIGN.md §14) over any plain executor.

    Three steps, none of which the executors can see:

      1. *Expire pass* — ``expire_state(state, now)`` physically reclaims
         every row with ``exp <= now`` (skipped when ``now is None``).
      2. *EXPIRE lowering* — OP_EXPIRE ops probe the post-expire pre-update
         state (one ``successor_query``: present ⟺ successor key == op key,
         which unlike POINT distinguishes a stored NOT_FOUND-valued key from
         a miss) and are rewritten to OP_INSERT: on a hit the insert re-puts
         the *stored* value (so the value is unchanged) while the expiry
         plane takes the op's new deadline (TTL refresh); on a miss it
         inserts the op's (val, exp).  Sound because update ops are unique
         per key within a batch, so the probe state is the state the op
         observes.
      3. *Two-plane execution* — the chosen executor runs twice: once on the
         value plane and once on a state whose ``vals`` column holds the
         expiry deadlines.  Every layout decision (insert merge positions,
         delete/expiry compaction orders, restructure flags) is a function
         of keys and tags only, so both planes land byte-identical key
         layouts and the expiry plane's ``vals`` *is* the new expiry column.

    The expiry plane runs first and is never donated; the value plane gets
    the caller's ``donate`` flag (its buffers are shared with the expiry
    plane's inputs, which are dead by then).
    """
    from repro.core.expiry import NO_EXPIRY, attach_expiry, expire_state
    from repro.core.query import successor_query

    state = attach_expiry(state.drop_volatile())
    tag, key, val = ops.tag, ops.key, ops.val
    exp = (
        ops.exp
        if ops.exp is not None
        else jnp.full(key.shape, NO_EXPIRY, KEY_DTYPE)
    )

    if now is not None:
        state, n_expired = expire_state(state, now)
    else:
        n_expired = jnp.int32(0)

    is_exp = tag == OP_EXPIRE
    value_state = dataclasses.replace(state, exps=None)
    exp_state = dataclasses.replace(state, vals=state.exps, exps=None)

    def _probe():
        sk, sv = successor_query(value_state, key)
        return is_exp & (sk == key), sv

    present, stored = jax.lax.cond(
        jnp.any(is_exp),
        _probe,
        lambda: (
            jnp.zeros(key.shape, bool),
            jnp.full(key.shape, NOT_FOUND, VAL_DTYPE),
        ),
    )

    tag2 = jnp.where(is_exp, OP_INSERT, tag)
    val2 = jnp.where(is_exp & present, stored, val)
    is_ins = tag2 == OP_INSERT
    val_e = jnp.where(is_ins, exp, val)  # RANGE hi rides val in both planes

    s2e, _, _ = _apply_ops_plain(
        exp_state,
        OpBatch(tag=tag2, key=key, val=val_e),
        impl=impl,
        cfg=cfg.replace(donate=False),
    )
    s2v, results, stats = _apply_ops_plain(
        value_state, OpBatch(tag=tag2, key=key, val=val2), impl=impl, cfg=cfg
    )

    new_exps = jnp.where(s2v.keys == EMPTY, NO_EXPIRY, s2e.vals)
    new_state = dataclasses.replace(s2v, exps=new_exps)

    results = dict(results)
    results["value"] = jnp.where(
        is_exp, jnp.where(present, stored, NOT_FOUND), results["value"]
    )
    stats = dict(stats)
    stats["expired"] = n_expired
    return new_state, results, stats


def apply_ops(
    state: FliXState,
    ops: OpBatch,
    *,
    config: ExecConfig | None = None,
    has_updates: bool | None = None,
    now=None,
    impl=_UNSET,
    donate=_UNSET,
    block_q=_UNSET,
    block_b=_UNSET,
    max_results=_UNSET,
):
    """Execute one mixed sorted batch.  Returns ``(state', results, stats)``.

    ``results`` is aligned with the sorted batch:
      * ``value``    — POINT: stored value or NOT_FOUND; SUCCESSOR: successor
                       value or NOT_FOUND; other tags: NOT_FOUND.
      * ``succ_key`` — SUCCESSOR: smallest stored key ≥ op key (post-update)
                       or EMPTY; other tags: EMPTY.
      * ``range_key`` / ``range_val`` — the dense ``[max_results]`` RANGE
        output: all range ops' results packed consecutively (post-update,
        key-ordered within each op's segment); EMPTY / NOT_FOUND beyond the
        emitted total.
      * ``range_start`` / ``range_count`` — per-op offset and length of its
        segment in the dense arrays (0 / 0 for non-RANGE ops).  Truncation
        under the budget is deterministic — earlier sorted ops win, each op
        keeps a prefix of its smallest keys — and flagged via
        ``stats["range_truncated"]``.

    ``config`` is the single execution-strategy surface
    (:class:`repro.core.config.ExecConfig`, DESIGN.md §16) — executor
    choice, pipelining, donation, tile sizes, the RANGE budget.  The bare
    keywords below (``impl``, ``donate``, ``block_q``, ``block_b``,
    ``max_results``) are deprecation shims that build one and warn once;
    they drop next release.  ``has_updates`` and ``now`` are *per-call*
    facts about the batch, not strategy, so they stay keywords.

    ``config.impl`` selects the executor:
      * ``"reference"`` — the five jnp phases above (insert merge, delete,
        point, successor, range: ≥ 4 full state sweeps).  The differential
        oracle.
      * ``"fused"``     — the compute-to-bucket Pallas kernel
        (``kernels.flix_apply``): one VMEM-resident pass per bucket does the
        whole update-then-read sequence.  Runs compiled on TPU, in interpret
        mode elsewhere.
      * ``"auto"``      — ``"fused"`` on TPU for batches that contain
        updates, ``"reference"`` otherwise: off-TPU interpret-mode Pallas is
        a correctness tool, not a fast path, and an update-free batch (pure
        point/successor/range reads — e.g. a range-heavy query stream) would
        pay the fused kernel's full state rewrite for nothing (DESIGN.md
        §10).  ``has_updates`` lets drivers that already know the batch
        composition host-side (``serve/kv_index.py`` does) answer that
        check without a device sync; leave it ``None`` to inspect the tags.

    ``config.donate=True`` (fused only) donates the input state's buffers to the
    step so step N+1 reuses step N's allocation instead of copying — the
    caller must not touch ``state`` afterwards, so it is unsuitable when a
    restructure-and-retry may replay the batch (``apply_ops_safe`` never
    donates).  Ignored on CPU, where XLA does not implement donation.

    ``now`` is the engine's only notion of time (DESIGN.md §14): when the
    state or batch carries an expiry column, rows with ``exp <= now`` are
    physically reclaimed before the update phase and OP_EXPIRE ops execute
    get-or-set-with-TTL against the expired state.  ``now=None`` skips the
    expire pass (expiry columns are still maintained).  The engine never
    reads the wall clock — replay with the logged ``now`` is deterministic.

    On bucket overflow the returned state carries ``needs_restructure`` and
    the overflowing buckets are untrustworthy — same contract as ``insert``;
    hosts use :func:`apply_ops_safe`.
    """
    cfg = resolve_config(
        "apply_ops",
        config,
        impl=impl,
        donate=donate,
        block_q=block_q,
        block_b=block_b,
        max_results=max_results,
    )
    impl_r = cfg.impl
    if impl_r == "auto":
        if jax.default_backend() != "tpu":
            impl_r = "reference"
        else:
            if has_updates is None:
                has_updates = bool(
                    jnp.any(
                        (ops.tag == OP_INSERT)
                        | (ops.tag == OP_DELETE)
                        | (ops.tag == OP_EXPIRE)
                    )
                )
            impl_r = "fused" if has_updates else "reference"
    # TTL activation is structural (does an expiry column exist on the state
    # or the batch?), so it is host-decidable even inside shard_map traces.
    if state.exps is not None or ops.exp is not None:
        return _apply_ops_ttl(state, ops, impl=impl_r, cfg=cfg, now=now)
    return _apply_ops_plain(state, ops, impl=impl_r, cfg=cfg)


def apply_ops_safe(
    state: FliXState,
    ops: OpBatch,
    *,
    config: ExecConfig | None = None,
    has_updates: bool | None = None,
    now=None,
    impl=_UNSET,
    max_results=_UNSET,
    validate_ranges=_UNSET,
    validate=_UNSET,
):
    """Host-level driver: apply, restructure-and-retry on overflow.

    Mirrors ``insert_safe`` — restructuring is host-driven because the new
    geometry changes static shapes.  The retry replays the *whole* batch on
    the regrown pre-batch state, which is safe because ``apply_ops`` never
    mutates its input (which is also why this driver never donates).

    ``config.validate_ranges=True`` additionally runs the structural RANGE-result
    checker (``core.invariants.check_range_results``: segments sorted,
    in-bounds, duplicate-free, consecutively packed) on the final results —
    a host-side debugging/testing aid, off on the hot path.
    ``config.validate=True`` runs the full structural invariant checker
    (``check_invariants``, incl. the I6 expiry-liveness check against the
    threaded ``now``) on the result state — same caveat.

    The returned ``stats`` gains ``restructure_retries`` (host int): how
    many times the batch was replayed on a regrown state.  It reflects the
    whole driver run, not just the final attempt — callers that account
    for retry cost (the serving gateway does) read it after the fact.
    """
    from repro.core.restructure import restructure_grow

    cfg = resolve_config(
        "apply_ops_safe",
        config,
        impl=impl,
        max_results=max_results,
        validate_ranges=validate_ranges,
        validate=validate,
    )
    # a retry replays the batch on the pre-batch state — never donate here
    run_cfg = cfg.replace(donate=False, validate=False, validate_ranges=False)
    restructure_retries = 0
    new_state, results, stats = apply_ops(
        state, ops, config=run_cfg, has_updates=has_updates, now=now
    )
    if bool(new_state.needs_restructure) and not bool(state.needs_restructure):
        n_ins = int(jnp.sum((ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)))
        grown = restructure_grow(state, extra_keys=max(n_ins, 1))
        new_state, results, stats = apply_ops(
            grown, ops, config=run_cfg, has_updates=has_updates, now=now
        )
        assert not bool(new_state.needs_restructure), "post-restructure overflow"
        restructure_retries = 1
    stats = dict(stats)
    stats["restructure_retries"] = restructure_retries
    if cfg.validate_ranges:
        from repro.core.invariants import check_range_results

        check_range_results(ops, results, max_results=cfg.max_results)
    if cfg.validate:
        from repro.core.invariants import check_invariants

        check_now = now
        if now is not None and ops.exp is not None:
            # the §14 same-batch edge: a row THIS batch wrote with
            # ``exp <= now`` is legitimately live until the next batch's
            # expiry pre-pass, so liveness-at-now cannot be asserted on
            # the post-state of a batch carrying dead-on-arrival writes
            wrote = (ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)
            if bool(jnp.any(wrote & (ops.exp <= jnp.asarray(now, KEY_DTYPE)))):
                check_now = None
        check_invariants(new_state, now=check_now)
    return new_state, results, stats
