"""Mixed-operation batch engine (the paper's batch execution model, §4.1).

The paper's execution unit is *one sorted batch per step*: the engine sorts
whatever operations arrived — inserts, deletes, point lookups, successor
probes — into a single key-ordered batch, and each bucket pulls *all* of its
work with one binary search.  This module is that engine:

  * ``OpBatch`` — a tagged operation batch (tag, key, val per slot).
  * ``make_ops`` — the one global sort (the only O(N log N) step).
  * ``apply_ops`` — the executor: one ``bucket_slices`` routing of the whole
    mixed batch.  Per-type views are *derived* from it with no second sort:
    order-preserving prefix-count scatters compact the insert/delete keys,
    and the insert phase's slice boundaries come from the single routing via
    prefix counts (``starts_ins = C_ins[starts]``) rather than a second
    fence routing.  The delete phase then uses deletion's flipped
    *whole-batch* membership search (data looks up the batch — no fence
    routing at all), and reads are answered from the updated state by the
    flipped compare-count forms (which binary-search the fences per query,
    as every FliX read does).

Within a batch the semantics are update-then-read:

  1. INSERT ops merge in first (upsert — incoming value wins),
  2. DELETE ops remove physically (present-key hits only),
  3. POINT, SUCCESSOR, and RANGE ops observe the post-update state.

RANGE is the ordered-CDS capability hash tables lack (the paper's central
functionality claim): an op reuses the key column for ``lo`` and the val
column for ``hi`` and answers the half-open ``[lo, hi)``.  Each batch
carries one static ``max_results`` output budget; results are packed
densely at exclusive-scan offsets (earlier sorted ops win the budget, each
op emits a prefix of its smallest in-range keys — deterministic, and
truncation is flagged in ``stats``).  See DESIGN.md §10.

``apply_ops`` has two executors behind one contract (``impl=``): the jnp
*reference* engine — four device passes whose insert path literally shares
``insert_with_slices`` with ``core.insert``, delete path shares
``core.delete``, read paths share ``core.query`` — and the *fused*
compute-to-bucket Pallas kernel (``kernels/flix_apply``, DESIGN.md §9) that
executes the whole update-then-read sequence in one VMEM-resident pass per
bucket.  Both are byte-identical to sequential per-type application
(``insert`` → ``delete`` → ``point_query`` → ``successor_query`` on the
sorted per-type sub-batches); ``tests/test_differential.py`` pins this down.

Precondition: at most one *update* op (INSERT or DELETE) per key per batch
(reads may repeat keys freely) — the same uniqueness contract ``insert``
already imposes.  ``OP_NOP`` slots (key must be ``EMPTY``) let callers pad
batches to a fixed size so jit traces once per geometry.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.batch import bucket_slices
from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, VAL_DTYPE, FliXState

OP_INSERT = 0
OP_DELETE = 1
OP_POINT = 2
OP_SUCCESSOR = 3
OP_NOP = 4  # padding slot; key must be EMPTY so it routes past every bucket
OP_RANGE = 5  # key column = lo, val column = hi; answers [lo, hi)

OP_DTYPE = jnp.int32

DEFAULT_MAX_RESULTS = 128  # per-batch RANGE output budget (static)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OpBatch:
    """A key-sorted batch of tagged operations (a pytree of device arrays)."""

    tag: jax.Array  # [N] OP_DTYPE
    key: jax.Array  # [N] KEY_DTYPE, ascending (EMPTY = NOP padding, at end;
    #                 RANGE ops sort by their lo, which lives here)
    val: jax.Array  # [N] VAL_DTYPE (INSERT: value; RANGE: exclusive hi)

    @property
    def size(self) -> int:
        return self.key.shape[0]

    def to_host(self):
        """The batch as host numpy arrays ``(tag, key, val)`` — the form the
        write-ahead log frames (``checkpoint.wal``) and the dirty-bucket
        tracker consume (one device transfer, shared by both)."""
        import numpy as np

        return (
            np.asarray(jax.device_get(self.tag)),
            np.asarray(jax.device_get(self.key)),
            np.asarray(jax.device_get(self.val)),
        )

    @classmethod
    def from_host(cls, tag, key, val) -> "OpBatch":
        """Rehydrate a batch from host arrays *without re-sorting*: WAL
        records store already-sorted batches, and replay must apply exactly
        the bytes that were logged."""
        return cls(
            tag=jnp.asarray(tag, OP_DTYPE),
            key=jnp.asarray(key, KEY_DTYPE),
            val=jnp.asarray(val, VAL_DTYPE),
        )


def make_ops(tags, keys, vals=None, *, pad_to: int | None = None):
    """Sort a raw operation list by key into an :class:`OpBatch`.

    This is the engine's one global sort.  Returns ``(ops, perm)`` where
    ``perm[j]`` is the sorted position input op ``j`` landed at, so
    ``sorted_result[perm]`` (= :func:`unsort`) maps per-op results back to
    submission order.

    ``pad_to`` appends ``OP_NOP`` slots up to a fixed size so callers with
    variable-length steps trace one jit program per geometry.
    """
    tags = jnp.asarray(tags, OP_DTYPE)
    keys = jnp.asarray(keys, KEY_DTYPE)
    if vals is None:
        vals = jnp.zeros(keys.shape, VAL_DTYPE)
    vals = jnp.asarray(vals, VAL_DTYPE)
    if pad_to is not None and pad_to > keys.shape[0]:
        extra = pad_to - keys.shape[0]
        tags = jnp.concatenate([tags, jnp.full((extra,), OP_NOP, OP_DTYPE)])
        keys = jnp.concatenate([keys, jnp.full((extra,), EMPTY, KEY_DTYPE)])
        vals = jnp.concatenate([vals, jnp.zeros((extra,), VAL_DTYPE)])
    order = jnp.argsort(keys, stable=True)
    # inverse permutation (input position -> sorted position) by O(N) scatter
    perm = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return OpBatch(tag=tags[order], key=keys[order], val=vals[order]), perm


def unsort(sorted_result: jax.Array, perm: jax.Array) -> jax.Array:
    """Map a sorted-order result array back to submission order."""
    return sorted_result[perm]


def _compact_by_mask(keys: jax.Array, mask: jax.Array, vals: jax.Array | None = None):
    """Front-pack ``keys[mask]`` preserving order; EMPTY tail.  No sort:
    destinations are a prefix count, so ascending order is preserved."""
    n = keys.shape[0]
    dest = jnp.where(mask, jnp.cumsum(mask) - 1, n)  # n = discard slot
    out_k = jnp.full((n + 1,), EMPTY, KEY_DTYPE).at[dest].set(keys)[:n]
    if vals is None:
        return out_k
    out_v = jnp.zeros((n + 1,), VAL_DTYPE).at[dest].set(vals)[:n]
    return out_k, out_v


def derive_type_views(state: FliXState, tag: jax.Array, key: jax.Array, val: jax.Array):
    """The engine's single routing plus the per-type views derived from it.

    Shared by both executors (``_apply_ops_reference`` and
    ``kernels.flix_apply``) so the routing contract cannot diverge between
    them.  Returns ``(is_ins, is_del, ins_keys, ins_vals, del_keys,
    ins_starts, ins_ends)``: the mixed-batch slice boundaries are mapped to
    insert-slice boundaries by prefix counts — no second sort, no second
    fence routing.
    """
    starts, ends = bucket_slices(state, key)
    is_ins = tag == OP_INSERT
    is_del = tag == OP_DELETE
    ins_keys, ins_vals = _compact_by_mask(key, is_ins, val)
    del_keys = _compact_by_mask(key, is_del)
    c_ins = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(is_ins).astype(jnp.int32)]
    )
    return is_ins, is_del, ins_keys, ins_vals, del_keys, c_ins[starts], c_ins[ends]


@functools.partial(jax.jit, static_argnames=("max_results",))
def _apply_ops_reference(
    state: FliXState, ops: OpBatch, *, max_results: int = DEFAULT_MAX_RESULTS
):
    """Reference engine: five jnp phases (the oracle for the fused kernel)."""
    from repro.core.delete import delete
    from repro.core.insert import insert_with_slices
    from repro.core.query import dense_range_scan, point_query, successor_query

    # drop any successor cache up front: the update phases construct cache-
    # free states, and lax.cond branches must agree on the pytree structure
    state = state.drop_volatile()

    tag, key, val = ops.tag, ops.key, ops.val
    n = key.shape[0]

    # --- the single routing + derived per-type views (no second sort) -----
    (
        is_ins,
        is_del,
        ins_keys,
        ins_vals,
        del_keys,
        ins_starts,
        ins_ends,
    ) = derive_type_views(state, tag, key, val)

    # --- update phase: merge inserts, then physical deletes ---------------
    # an absent op class skips its phase entirely (lax.cond executes one
    # branch), so read-heavy batches don't pay the merge machinery; the
    # differential contract is correspondingly "apply the present types".
    s1, ins_stats = jax.lax.cond(
        jnp.any(is_ins),
        lambda: insert_with_slices(state, ins_keys, ins_vals, ins_starts, ins_ends),
        lambda: (
            state,
            {
                "inserted": jnp.int32(0),
                "nodes_after": jnp.sum(state.num_nodes),
                "splits": jnp.int32(0),
                "overflowed_buckets": jnp.int32(0),
            },
        ),
    )
    s2, del_stats = jax.lax.cond(
        jnp.any(is_del),
        lambda: delete(s1, del_keys),
        lambda: (s1, {"deleted": jnp.int32(0), "nodes_freed": jnp.int32(0)}),
    )

    # --- read phase: flipped compare-count against the updated state ------
    is_point = tag == OP_POINT
    is_succ = tag == OP_SUCCESSOR
    pv = jax.lax.cond(
        jnp.any(is_point),
        lambda: point_query(s2, key),
        lambda: jnp.full((n,), NOT_FOUND, VAL_DTYPE),
    )
    sk, sv = jax.lax.cond(
        jnp.any(is_succ),
        lambda: successor_query(s2, key),
        lambda: (
            jnp.full((n,), EMPTY, KEY_DTYPE),
            jnp.full((n,), NOT_FOUND, VAL_DTYPE),
        ),
    )
    # --- range phase: dense [lo, hi) scans against the updated state ------
    is_range = tag == OP_RANGE
    rk, rv, rstart, rcnt, rtrunc = jax.lax.cond(
        jnp.any(is_range),
        lambda: dense_range_scan(
            s2, is_range, key, val.astype(KEY_DTYPE), max_results=max_results
        ),
        lambda: (
            jnp.full((max_results,), EMPTY, KEY_DTYPE),
            jnp.full((max_results,), NOT_FOUND, VAL_DTYPE),
            jnp.zeros((n,), jnp.int32),
            jnp.zeros((n,), jnp.int32),
            jnp.int32(0),
        ),
    )

    results = {
        "value": jnp.where(is_point, pv, jnp.where(is_succ, sv, NOT_FOUND)),
        "succ_key": jnp.where(is_succ, sk, EMPTY),
        "range_key": rk,
        "range_val": rv,
        "range_start": rstart,
        "range_count": rcnt,
    }
    stats = {
        "inserted": ins_stats["inserted"],
        "deleted": del_stats["deleted"],
        "overflowed_buckets": ins_stats["overflowed_buckets"],
        "range_truncated": rtrunc,
    }
    return s2, results, stats


def apply_ops(
    state: FliXState,
    ops: OpBatch,
    *,
    impl: str = "auto",
    donate: bool = False,
    block_q: int | None = None,
    block_b: int | None = None,
    max_results: int = DEFAULT_MAX_RESULTS,
    has_updates: bool | None = None,
):
    """Execute one mixed sorted batch.  Returns ``(state', results, stats)``.

    ``results`` is aligned with the sorted batch:
      * ``value``    — POINT: stored value or NOT_FOUND; SUCCESSOR: successor
                       value or NOT_FOUND; other tags: NOT_FOUND.
      * ``succ_key`` — SUCCESSOR: smallest stored key ≥ op key (post-update)
                       or EMPTY; other tags: EMPTY.
      * ``range_key`` / ``range_val`` — the dense ``[max_results]`` RANGE
        output: all range ops' results packed consecutively (post-update,
        key-ordered within each op's segment); EMPTY / NOT_FOUND beyond the
        emitted total.
      * ``range_start`` / ``range_count`` — per-op offset and length of its
        segment in the dense arrays (0 / 0 for non-RANGE ops).  Truncation
        under the budget is deterministic — earlier sorted ops win, each op
        keeps a prefix of its smallest keys — and flagged via
        ``stats["range_truncated"]``.

    ``impl`` selects the executor:
      * ``"reference"`` — the five jnp phases above (insert merge, delete,
        point, successor, range: ≥ 4 full state sweeps).  The differential
        oracle.
      * ``"fused"``     — the compute-to-bucket Pallas kernel
        (``kernels.flix_apply``): one VMEM-resident pass per bucket does the
        whole update-then-read sequence.  Runs compiled on TPU, in interpret
        mode elsewhere.
      * ``"auto"``      — ``"fused"`` on TPU for batches that contain
        updates, ``"reference"`` otherwise: off-TPU interpret-mode Pallas is
        a correctness tool, not a fast path, and an update-free batch (pure
        point/successor/range reads — e.g. a range-heavy query stream) would
        pay the fused kernel's full state rewrite for nothing (DESIGN.md
        §10).  ``has_updates`` lets drivers that already know the batch
        composition host-side (``serve/kv_index.py`` does) answer that
        check without a device sync; leave it ``None`` to inspect the tags.

    ``donate=True`` (fused only) donates the input state's buffers to the
    step so step N+1 reuses step N's allocation instead of copying — the
    caller must not touch ``state`` afterwards, so it is unsuitable when a
    restructure-and-retry may replay the batch (``apply_ops_safe`` never
    donates).  Ignored on CPU, where XLA does not implement donation.

    On bucket overflow the returned state carries ``needs_restructure`` and
    the overflowing buckets are untrustworthy — same contract as ``insert``;
    hosts use :func:`apply_ops_safe`.
    """
    if impl == "auto":
        if jax.default_backend() != "tpu":
            impl = "reference"
        else:
            if has_updates is None:
                has_updates = bool(
                    jnp.any((ops.tag == OP_INSERT) | (ops.tag == OP_DELETE))
                )
            impl = "fused" if has_updates else "reference"
    if impl == "reference":
        return _apply_ops_reference(state, ops, max_results=max_results)
    if impl != "fused":
        raise ValueError(f"unknown apply_ops impl: {impl!r}")

    from repro.kernels.flix_apply import (
        DEFAULT_BLOCK_B,
        flix_apply_pallas,
        flix_apply_pallas_donated,
    )
    from repro.kernels.flix_query import DEFAULT_BLOCK_Q

    backend = jax.default_backend()
    fn = flix_apply_pallas_donated if donate and backend != "cpu" else flix_apply_pallas
    return fn(
        state,
        ops.tag,
        ops.key,
        ops.val,
        block_q=block_q or DEFAULT_BLOCK_Q,
        block_b=block_b or DEFAULT_BLOCK_B,
        max_results=max_results,
        interpret=backend != "tpu",
    )


def apply_ops_safe(
    state: FliXState,
    ops: OpBatch,
    *,
    impl: str = "auto",
    max_results: int = DEFAULT_MAX_RESULTS,
    validate_ranges: bool = False,
    has_updates: bool | None = None,
):
    """Host-level driver: apply, restructure-and-retry on overflow.

    Mirrors ``insert_safe`` — restructuring is host-driven because the new
    geometry changes static shapes.  The retry replays the *whole* batch on
    the regrown pre-batch state, which is safe because ``apply_ops`` never
    mutates its input (which is also why this driver never donates).

    ``validate_ranges=True`` additionally runs the structural RANGE-result
    checker (``core.invariants.check_range_results``: segments sorted,
    in-bounds, duplicate-free, consecutively packed) on the final results —
    a host-side debugging/testing aid, off on the hot path.

    The returned ``stats`` gains ``restructure_retries`` (host int): how
    many times the batch was replayed on a regrown state.  It reflects the
    whole driver run, not just the final attempt — callers that account
    for retry cost (the serving gateway does) read it after the fact.
    """
    from repro.core.restructure import restructure_grow

    restructure_retries = 0
    new_state, results, stats = apply_ops(
        state, ops, impl=impl, max_results=max_results, has_updates=has_updates
    )
    if bool(new_state.needs_restructure) and not bool(state.needs_restructure):
        n_ins = int(jnp.sum(ops.tag == OP_INSERT))
        grown = restructure_grow(state, extra_keys=max(n_ins, 1))
        new_state, results, stats = apply_ops(
            grown,
            ops,
            impl=impl,
            max_results=max_results,
            has_updates=has_updates,
        )
        assert not bool(new_state.needs_restructure), "post-restructure overflow"
        restructure_retries = 1
    stats = dict(stats)
    stats["restructure_retries"] = restructure_retries
    if validate_ranges:
        from repro.core.invariants import check_range_results

        check_range_results(ops, results, max_results=max_results)
    return new_state, results, stats
