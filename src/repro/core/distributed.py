"""Bucket-sharded FliX across a device mesh (the distributed index service).

Buckets are *range-partitioned* across shards (contiguous MKBA ranges per
device), so the flipped paradigm lifts directly to the cluster level: a
shard is just a super-bucket, and a sorted operation batch is routed by the
same fence-searchsorted primitive — each shard pulls its slice.

Since PR 5 the unit of distributed execution is the **mixed batch**:
:func:`shard_apply_ops` runs one whole ``OpBatch`` (POINT / SUCCESSOR /
INSERT / DELETE / RANGE) under a single ``shard_map`` step, with per-shard
compute delegated to ``core.ops.apply_ops`` *unchanged* — including the
``impl="fused"`` compute-to-bucket kernel and buffer donation — so the
hierarchy composes: bucket ⊂ shard ⊂ cluster.  The legacy per-op-type
entry points (``insert``/``delete``/``point_query``/``successor_query``)
are gone.

Two routing modes (DESIGN.md §11):

* ``replicated`` — the sorted batch is broadcast; each shard masks the
  *update* ops to its fence range (reads run everywhere — a successor or
  range answer may live outside the op key's owner shard) and recombines
  with one collective round.  Right for query-dominant workloads where the
  batch is small relative to the structure (the paper's regime).
* ``a2a`` — each shard holds a batch shard; op rows are routed to their
  owner shard by one partition-fence searchsorted driving a padded
  ``all_to_all``, results travel back over the inverse ``all_to_all``.
  Right at ingest scale where batches arrive sharded.  Fixed per-pair
  ``capacity`` keeps shapes static; overflow is counted and surfaced in
  ``stats["a2a_overflow"]`` (the caller re-routes with a bigger capacity —
  ``shard_apply_ops`` never mutates its input, so the retry replays the
  same batch on the same pre-batch index).

RANGE results are recombined into the dense exclusive-scan contract of
DESIGN.md §10 with *global* offsets: per-op local in-range counts are
``all_gather``-ed, an exclusive scan over shards gives each shard its slot
window inside every op's segment, and truncation is applied against the
single global ``max_results`` budget — byte-identical to the single-device
``apply_ops`` output.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.batch import bucket_slices, gather_sublists
from repro.core.build import build_from_sorted
from repro.core.config import _UNSET, ExecConfig, resolve_config
from repro.core.expiry import NO_EXPIRY
from repro.core.ops import (
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_NOP,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
    OpBatch,
    _compact_by_mask,
    apply_ops,
)
from repro.core.query import _suffix_min_with_index, flat_rank, range_offsets
from repro.core.state import (
    EMPTY,
    KEY_DTYPE,
    MIN_KEY,
    NOT_FOUND,
    VAL_DTYPE,
    FliXState,
    flatten_bucket_sorted,
)

# max_results handed to the *inner* apply_ops when the cross-shard range
# phase answers the batch's RANGE ops (the inner dense arrays are ignored)
_INNER_MR = 8


class ShardedFliX(NamedTuple):
    state: FliXState          # bucket dim sharded over ``axis``
    lower_fence: jax.Array    # [n_shards] fence below each shard's range
    part_fences: jax.Array    # [n_shards] upper fence per shard (replicated)
    axis: str


def plan_shard_budget(total_budget: int | None, n_shards: int) -> int | None:
    """Split a global device-memory budget across shards (DESIGN.md §15).

    Buckets are range-partitioned evenly, so the per-shard residency bound
    is simply an even split — each shard's residency plane enforces its
    slice independently and I7 holds globally because shard bucket sets are
    disjoint.  Returns a per-shard byte budget (``None`` = unbounded).
    """
    if total_budget is None:
        return None
    return max(1, int(total_budget) // max(1, n_shards))


def shard_memory_bytes(idx: ShardedFliX) -> int:
    """Total allocated footprint of a sharded index across the mesh —
    the per-shard ``memory_bytes`` summed (every shard holds the same
    static geometry, so this is shards × the per-shard footprint)."""
    return idx.state.memory_bytes() + idx.lower_fence.size * 4 + idx.part_fences.size * 4


def make_shard_mesh(n_shards: int, *, axis: str = "shards") -> jax.sharding.Mesh:
    """A 1-D mesh over the first ``n_shards`` local devices."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for {n_shards} shards, have {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)"
        )
    return jax.sharding.Mesh(np.array(devs[:n_shards]), (axis,))


def shard_build(
    sorted_keys,
    sorted_vals,
    mesh,
    *,
    axis: str = "shards",
    node_size: int = 32,
    nodes_per_bucket: int = 16,
    fill: float = 0.5,
    extra_keys: int = 0,
    sorted_exps=None,
) -> ShardedFliX:
    """Build then range-partition across ``mesh``'s ``axis``.

    ``extra_keys`` over-provisions the bucket count (the distributed
    analogue of ``restructure_grow``'s headroom argument) so a subsequent
    batch of that many inserts cannot overflow a fresh structure.
    ``sorted_exps`` carries the per-key expiry column (sorted alongside the
    keys); the built state then serves the TTL path (DESIGN.md §14).
    """
    n_shards = int(mesh.shape[axis])
    p = max(1, int(node_size * fill))
    n = int(jnp.sum(sorted_keys != EMPTY)) + extra_keys
    per_shard_buckets = max(1, math.ceil(math.ceil(n / p) / n_shards))
    nb = per_shard_buckets * n_shards
    state = build_from_sorted(
        sorted_keys,
        sorted_vals,
        num_buckets=nb,
        nodes_per_bucket=nodes_per_bucket,
        node_size=node_size,
        fill=fill,
    )
    exps = None
    if sorted_exps is not None:
        # expiry plane of the same build: identical layout, exps in vals
        built_e = build_from_sorted(
            sorted_keys,
            jnp.asarray(sorted_exps, KEY_DTYPE),
            num_buckets=nb,
            nodes_per_bucket=nodes_per_bucket,
            node_size=node_size,
            fill=fill,
        )
        exps = jnp.where(state.keys == EMPTY, NO_EXPIRY, built_e.vals)
    part_fences = state.mkba.reshape(n_shards, -1)[:, -1]
    lower_fence = jnp.concatenate([jnp.array([MIN_KEY], KEY_DTYPE), part_fences[:-1]])

    shard3 = NamedSharding(mesh, P(axis, None, None))
    shard2 = NamedSharding(mesh, P(axis, None))
    shard1 = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    state = FliXState(
        keys=jax.device_put(state.keys, shard3),
        vals=jax.device_put(state.vals, shard3),
        node_count=jax.device_put(state.node_count, shard2),
        node_max=jax.device_put(state.node_max, shard2),
        num_nodes=jax.device_put(state.num_nodes, shard1),
        mkba=jax.device_put(state.mkba, shard1),
        needs_restructure=jax.device_put(state.needs_restructure, rep),
        exps=None if exps is None else jax.device_put(exps, shard3),
    )
    return ShardedFliX(
        state=state,
        lower_fence=jax.device_put(lower_fence, shard1),
        part_fences=jax.device_put(part_fences, rep),
        axis=axis,
    )


def shard_restructure(
    idx: ShardedFliX,
    mesh,
    *,
    extra_keys: int = 0,
    fill: float = 0.5,
) -> ShardedFliX:
    """Rebalance partition fences from the live-key distribution.

    The cluster analogue of the paper's §3.5 relaunch: the host pulls the
    live contents, re-plans a uniform geometry for ``live + extra_keys``
    keys, and re-partitions so every shard owns an equal bucket count of an
    evenly-filled structure — skew accumulated since the last build (every
    new tenant hashing into one shard's fence range, say) is erased.

    Host-driven by design, exactly like single-device ``restructure``: the
    new static geometry (bucket count, possibly a widened chain) cannot be
    chosen on device.  Functional — the input index is untouched.
    """
    state = idx.state
    flat_k = np.asarray(jax.device_get(state.keys)).reshape(-1)
    flat_v = np.asarray(jax.device_get(state.vals)).reshape(-1)
    order = np.argsort(flat_k, kind="stable")  # EMPTY sentinels sort last
    sorted_k, sorted_v = flat_k[order], flat_v[order]
    sorted_e = None
    if state.exps is not None:
        sorted_e = np.asarray(jax.device_get(state.exps)).reshape(-1)[order]

    live = int((flat_k != EMPTY).sum())
    p = max(1, int(state.node_size * fill))
    cap = state.nodes_per_bucket * state.node_size
    if p + extra_keys > cap:
        # pathological skew: widen the chain so one bucket can absorb the
        # whole pending batch (mirrors restructure_grow)
        npb = math.ceil((p + extra_keys) / state.node_size)
    else:
        npb = state.nodes_per_bucket
    return shard_build(
        jnp.asarray(sorted_k),
        jnp.asarray(sorted_v),
        mesh,
        axis=idx.axis,
        node_size=state.node_size,
        nodes_per_bucket=npb,
        fill=fill,
        extra_keys=extra_keys,
        sorted_exps=None if sorted_e is None else jnp.asarray(sorted_e),
    )


def shard_live_counts(idx: ShardedFliX, mesh) -> jax.Array:
    """Per-shard live-key counts ``[n_shards]`` (balance diagnostics)."""
    axis = idx.axis

    def body(node_count):
        return jax.lax.all_gather(jnp.sum(node_count).reshape(1), axis).reshape(-1)

    return jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None),),
            out_specs=P(),
            check_vma=False,
        )
    )(idx.state.node_count)


def _state_specs(axis: str, has_ttl: bool = False) -> FliXState:
    return FliXState(
        keys=P(axis, None, None),
        vals=P(axis, None, None),
        node_count=P(axis, None),
        node_max=P(axis, None),
        num_nodes=P(axis),
        mkba=P(axis),
        needs_restructure=P(),
        exps=P(axis, None, None) if has_ttl else None,
    )


def replicate_batch(ops: OpBatch, mesh) -> OpBatch:
    """Place an :class:`OpBatch` fully replicated on ``mesh``."""
    rep = NamedSharding(mesh, P())
    return OpBatch(
        tag=jax.device_put(ops.tag, rep),
        key=jax.device_put(ops.key, rep),
        val=jax.device_put(ops.val, rep),
        exp=None if ops.exp is None else jax.device_put(ops.exp, rep),
    )


def shard_batch(ops: OpBatch, mesh, *, axis: str = "shards") -> OpBatch:
    """Position-shard an :class:`OpBatch` over ``axis`` (a2a-mode input).

    Each shard's chunk must be key-sorted locally (a globally sorted batch
    split into contiguous chunks qualifies); chunks from different shards
    need no mutual order.
    """
    sh = NamedSharding(mesh, P(axis))
    return OpBatch(
        tag=jax.device_put(ops.tag, sh),
        key=jax.device_put(ops.key, sh),
        val=jax.device_put(ops.val, sh),
        exp=None if ops.exp is None else jax.device_put(ops.exp, sh),
    )


def _inverse_permutation(order: jax.Array) -> jax.Array:
    return jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype)
    )


def _post_update_shard_min(state: FliXState):
    """Smallest present key in this shard (EMPTY if none) and its value."""
    bucket_min = jnp.where(state.num_nodes > 0, state.keys[:, 0, 0], EMPTY)
    b = jnp.argmin(bucket_min).astype(jnp.int32)
    m = bucket_min[b]
    v = jnp.where(m != EMPTY, state.vals[b, 0, 0], NOT_FOUND)
    return m, v


def _predict_post_keys(state: FliXState, ins_keys: jax.Array, del_keys: jax.Array):
    """Post-update per-bucket sorted key rows + rank fences, *pre-apply*.

    The fused kernel's predict-without-running-the-update argument
    (``kernels/flix_apply._range_plumbing``) lifted to the shard level: a
    shard's post-update bucket multiset is (surviving stripe keys minus
    upsert duplicates) ∪ (this shard's masked insert keys) — exact because
    one batch never inserts and deletes the same key, and EXPIRE keys count
    as inserts (get-or-set leaves the key present either way).  This is
    what lets the cross-shard RANGE counts collective launch *before* the
    per-shard update pass (DESIGN.md §16): the two touch no shared data
    until the final dense extract.  NOT valid under an expiry pass at
    ``now`` — the caller gates on ``has_now`` and falls back to the
    sequential post-apply phase.

    ``ins_keys``/``del_keys`` are the shard's masked update keys, sorted,
    EMPTY-padded.  Returns ``(post_keys [nb, S+cap], pref [nb+1])``.
    """
    flat_k, _ = flatten_bucket_sorted(state)
    nb, S = flat_k.shape
    cap = state.bucket_capacity
    mflat = flat_k.reshape(-1)
    nk = max(del_keys.shape[0] - 1, 0)
    dpos = jnp.minimum(jnp.searchsorted(del_keys, mflat, side="left"), nk)
    dhit = (del_keys[dpos] == mflat) & (mflat != EMPTY)
    masked = jnp.where(dhit.reshape(nb, S), EMPTY, flat_k)

    ni = max(ins_keys.shape[0] - 1, 0)
    ipos = jnp.minimum(jnp.searchsorted(ins_keys, masked.reshape(-1), side="left"), ni)
    upserted = (ins_keys[ipos] == masked.reshape(-1)) & (masked.reshape(-1) != EMPTY)

    istarts, iends = bucket_slices(state, ins_keys)
    ik, _, _ = gather_sublists(ins_keys, istarts, iends, cap)
    post_rows = jnp.concatenate(
        [jnp.where(upserted.reshape(nb, S), EMPTY, masked), ik], axis=1
    )
    post_keys = jnp.sort(post_rows, axis=1)
    live = jnp.sum(post_keys != EMPTY, axis=1).astype(jnp.int32)
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
    )
    return post_keys, pref


def _range_counts_phase(
    post_keys: jax.Array,
    pref: jax.Array,
    mkba: jax.Array,
    is_range: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    axis: str,
    max_results: int,
):
    """The collective half of cross-shard RANGE: ranks → gathered counts →
    global offsets → per-slot (bucket, rank) sources for this shard.

    The §10 dense exclusive-scan contract with *global* offsets: local
    in-range counts are gathered across shards, an exclusive scan over the
    shard axis gives this shard its slot window inside every op's segment,
    and each emitted slot is filled by exactly one shard.  ``post_keys`` /
    ``pref`` describe the shard's post-update key layout — either read from
    the updated state (sequential path) or predicted pre-apply
    (:func:`_predict_post_keys`, the overlapped path).  ``is_range`` /
    ``lo`` / ``hi`` must be replicated and in global sorted-batch order.
    """
    n = lo.shape[0]
    rank_lo = flat_rank(post_keys, pref, mkba, lo)
    rank_hi = flat_rank(post_keys, pref, mkba, hi)
    local_full = jnp.maximum(rank_hi - rank_lo, 0)
    local_full = jnp.where(is_range, local_full, 0).astype(jnp.int32)

    counts_all = jax.lax.all_gather(local_full, axis)          # [S, N]
    me = jax.lax.axis_index(axis)
    global_full = jnp.sum(counts_all, axis=0)
    prefix_lt = (jnp.cumsum(counts_all, axis=0) - counts_all)[me]

    start, emit, total_emit, truncated = range_offsets(
        global_full, is_range, max_results
    )

    # slot ownership: the shared §10 owner rule, then "is slot p's in-op
    # offset inside MY shard's window [prefix_lt, prefix_lt + local_full)?"
    p = jnp.arange(max_results, dtype=jnp.int32)
    owner = jnp.clip(
        jnp.searchsorted(start, p, side="right").astype(jnp.int32) - 1, 0, n - 1
    )
    j = p - start[owner]
    valid = p < total_emit
    mine = valid & (j >= prefix_lt[owner]) & (j < prefix_lt[owner] + local_full[owner])
    g = rank_lo[owner] + (j - prefix_lt[owner])                # local key rank
    g_c = jnp.where(mine, g, 0)
    nb = post_keys.shape[0]
    src_b = jnp.clip(
        jnp.searchsorted(pref, g_c, side="right").astype(jnp.int32) - 1, 0, nb - 1
    )
    src_p = g_c - pref[src_b]
    return (
        src_b,
        src_p,
        mine,
        valid,
        jnp.where(is_range, start, 0),
        jnp.where(is_range, emit, 0),
        truncated,
    )


def _range_extract_contrib(state: FliXState, src_b, src_p, mine):
    """This shard's additive contribution to the dense RANGE arrays: actual
    post-update bytes at the (bucket, in-bucket rank) sources the counts
    phase resolved.  Exactly one shard owns each emitted slot, so a psum
    recombines (the caller folds it into the single combine psum)."""
    flat_k, flat_v = flatten_bucket_sorted(state)
    src_p = jnp.minimum(src_p, flat_k.shape[1] - 1)  # overflowed buckets are
    #                            untrustworthy anyway (needs_restructure set)
    rk = jnp.where(mine, flat_k[src_b, src_p], 0)
    rv = jnp.where(mine, flat_v[src_b, src_p], 0)
    return rk, rv


def _empty_range_outputs(n: int, max_results: int):
    return (
        jnp.full((max_results,), EMPTY, KEY_DTYPE),
        jnp.full((max_results,), NOT_FOUND, VAL_DTYPE),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.int32(0),
    )


@functools.lru_cache(maxsize=64)
def _build_replicated(
    mesh, axis, inner_cfg, max_results, has_ranges, donate, has_ttl=False, has_now=False
):
    """jit(shard_map)-compiled replicated-routing executor (memoized).

    PR 10 overlap structure (DESIGN.md §16): when the batch has RANGE ops
    and no expiry pass, the cross-shard recombination's *counts* collective
    is issued against the predicted post-update layout BEFORE the per-shard
    update pass — the two touch no shared data until the dense extract — so
    the scheduler is free to run the ``all_gather`` concurrently with the
    update compute.  All POINT/SUCCESSOR/RANGE/stats recombination then
    collapses into a single fused ``psum`` over one contribution pytree
    (plus the one unavoidable ``pmin`` for the successor winner).
    """

    def body(state, lf, tag, key, val, *extra):
        # extra = (exp,) / (exp, now) when the TTL lanes are enabled
        exp = extra[0] if has_ttl else None
        now = extra[1] if has_now else None
        lf = lf[0]
        upper = state.mkba[-1]
        is_upd = (tag == OP_INSERT) | (tag == OP_DELETE) | (tag == OP_EXPIRE)
        is_rng = tag == OP_RANGE
        # updates run on their owner shard only; POINT/SUCCESSOR run
        # everywhere (a successor answer may live past the owner's fence);
        # RANGE is lifted out entirely for the cross-shard phase
        keep = (~is_upd | ((key > lf) & (key <= upper))) & ~is_rng
        mtag = jnp.where(keep, tag, OP_NOP)
        mkey = jnp.where(keep, key, EMPTY)
        mval = jnp.where(keep, val, 0)
        order = jnp.argsort(mkey, stable=True)
        inv = _inverse_permutation(order)
        stag, skey = mtag[order], mkey[order]

        # overlapped RANGE counts phase: issued pre-apply from the predicted
        # post-update layout (invalid under an expiry pass at ``now`` — the
        # prediction cannot see which keys the clock removes)
        overlap = has_ranges and not has_now
        if overlap:
            ins_keys = _compact_by_mask(
                skey, (stag == OP_INSERT) | (stag == OP_EXPIRE)
            )
            del_keys = _compact_by_mask(skey, stag == OP_DELETE)
            post_keys, pref = _predict_post_keys(state, ins_keys, del_keys)
            src_b, src_p, mine, rvalid, rstart, rcnt, rtrunc = _range_counts_phase(
                post_keys,
                pref,
                state.mkba,
                is_rng,
                key,
                val.astype(KEY_DTYPE),
                axis,
                max_results,
            )

        new_state, res, st = apply_ops(
            state,
            OpBatch(
                tag=stag,
                key=skey,
                val=mval[order],
                exp=None
                if exp is None
                else jnp.where(keep, exp, NO_EXPIRY)[order],
            ),
            config=inner_cfg,
            now=now,
        )
        value = res["value"][inv]
        succ_key = res["succ_key"][inv]

        # POINT: at most one shard holds the key, the rest answer NOT_FOUND.
        # EXPIRE recombines the same way: it is masked to its owner shard,
        # whose get-or-set answer comes back through the value lane
        is_point = (tag == OP_POINT) | (tag == OP_EXPIRE)
        hit = is_point & (value != NOT_FOUND)

        # SUCCESSOR: shard-local candidates, global min; shard key ranges
        # are disjoint so the min is attained by exactly one shard
        is_succ = tag == OP_SUCCESSOR
        cand = jnp.where(is_succ, succ_key, EMPTY)
        kmin = jax.lax.pmin(cand, axis)
        winner = is_succ & (cand == kmin) & (cand != EMPTY)

        if has_ranges and not overlap:
            # sequential fallback (TTL with ``now``): counts phase against
            # the actually-updated state
            flat_k, _ = flatten_bucket_sorted(new_state)
            live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)
            pref = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
            )
            src_b, src_p, mine, rvalid, rstart, rcnt, rtrunc = _range_counts_phase(
                flat_k,
                pref,
                new_state.mkba,
                is_rng,
                key,
                val.astype(KEY_DTYPE),
                axis,
                max_results,
            )

        # ONE fused combine psum over the whole contribution pytree
        contrib = {
            "pv": jnp.where(hit, value, 0),
            "n_hit": hit.astype(jnp.int32),
            "sv": jnp.where(winner, value, 0),
            "inserted": st["inserted"],
            "deleted": st["deleted"],
            "overflowed_buckets": st["overflowed_buckets"],
            "restructure": new_state.needs_restructure.astype(jnp.int32),
        }
        if has_ttl:
            contrib["expired"] = st["expired"]
        if has_ranges:
            rk_c, rv_c = _range_extract_contrib(new_state, src_b, src_p, mine)
            contrib["rk"] = rk_c
            contrib["rv"] = rv_c
        summed = jax.lax.psum(contrib, axis)

        point_val = jnp.where(summed["n_hit"] > 0, summed["pv"], NOT_FOUND)
        succ_val = jnp.where(kmin != EMPTY, summed["sv"], NOT_FOUND)
        if has_ranges:
            rk = jnp.where(rvalid, summed["rk"], EMPTY)
            rv = jnp.where(rvalid, summed["rv"], NOT_FOUND)
        else:
            rk, rv, rstart, rcnt, rtrunc = _empty_range_outputs(
                key.shape[0], max_results
            )

        results = {
            "value": jnp.where(
                is_point, point_val, jnp.where(is_succ, succ_val, NOT_FOUND)
            ),
            "succ_key": jnp.where(is_succ, kmin, EMPTY),
            "range_key": rk,
            "range_val": rv,
            "range_start": rstart,
            "range_count": rcnt,
        }
        stats = {
            "inserted": summed["inserted"],
            "deleted": summed["deleted"],
            "overflowed_buckets": summed["overflowed_buckets"],
            "range_truncated": rtrunc,
            "a2a_overflow": jnp.int32(0),
        }
        if has_ttl:
            stats["expired"] = summed["expired"]
        new_state = dataclasses.replace(
            new_state,
            needs_restructure=(summed["restructure"] > 0),
        )
        return new_state, results, stats

    specs = _state_specs(axis, has_ttl)
    rep_results = {
        "value": P(),
        "succ_key": P(),
        "range_key": P(),
        "range_val": P(),
        "range_start": P(),
        "range_count": P(),
    }
    rep_stats = {
        "inserted": P(),
        "deleted": P(),
        "overflowed_buckets": P(),
        "range_truncated": P(),
        "a2a_overflow": P(),
    }
    if has_ttl:
        rep_stats["expired"] = P()
    in_specs = (specs, P(axis), P(), P(), P())
    if has_ttl:
        in_specs += (P(),)
    if has_now:
        in_specs += (P(),)
    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs, rep_results, rep_stats),
        check_vma=False,
    )
    donate_argnums = (0,) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=64)
def _build_a2a(
    mesh,
    axis,
    inner_cfg,
    max_results,
    has_ranges,
    capacity,
    donate,
    has_ttl=False,
    has_now=False,
):
    """jit(shard_map)-compiled a2a-routing executor (memoized).

    Same PR 10 overlap structure as the replicated builder: the RANGE-side
    batch ``all_gather`` depends only on the raw inputs and is hoisted
    before routing; the counts collective runs pre-apply against the
    predicted post-update layout of the *received* rows (gated off under an
    expiry pass at ``now``); recombination is one fused ``psum`` pytree.
    """
    n_shards = int(mesh.shape[axis])

    def body(state, part_fences, tag, key, val, *extra):
        # extra = (exp,) / (exp, now) when the TTL lanes are enabled
        exp = extra[0] if has_ttl else None
        now = extra[1] if has_now else None
        n_local = key.shape[0]
        me = jax.lax.axis_index(axis)
        is_rng = tag == OP_RANGE

        overlap = has_ranges and not has_now
        if has_ranges:
            # gather every shard's RANGE rows up front — depends only on the
            # batch inputs, so it overlaps the routing + update below
            g_tag = jax.lax.all_gather(tag, axis).reshape(-1)
            g_lo = jax.lax.all_gather(key, axis).reshape(-1)
            g_hi = jax.lax.all_gather(val, axis).reshape(-1)
            g_isr = g_tag == OP_RANGE
            gorder = jnp.argsort(jnp.where(g_isr, g_lo, EMPTY), stable=True)
            isr_s = g_isr[gorder]
            q_lo = g_lo[gorder]
            q_hi = g_hi[gorder].astype(KEY_DTYPE)

        # RANGE rows never ride the a2a (the cross-shard phase answers them
        # from the gathered batch); masking them to the EMPTY tail keeps the
        # local sort a valid routing order
        rkey = jnp.where(is_rng, EMPTY, key)
        order = jnp.argsort(rkey, stable=True)
        inv = _inverse_permutation(order)
        s_tag, s_key, s_val = tag[order], rkey[order], val[order]
        s_exp = None if exp is None else exp[order]

        # per-destination slices by one partition-fence searchsorted
        ends = jnp.searchsorted(s_key, part_fences, side="right").astype(jnp.int32)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), ends[:-1]])
        counts = ends - starts
        overflow = jnp.sum(jnp.maximum(counts - capacity, 0))

        idx = starts[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None]
        valid = idx < ends[:, None]
        idx_c = jnp.minimum(idx, n_local - 1)
        send_t = jnp.where(valid, s_tag[idx_c], OP_NOP)
        send_k = jnp.where(valid, s_key[idx_c], EMPTY)
        send_v = jnp.where(valid, s_val[idx_c], 0)

        recv_t = jax.lax.all_to_all(send_t, axis, 0, 0).reshape(-1)
        recv_k = jax.lax.all_to_all(send_k, axis, 0, 0).reshape(-1)
        recv_v = jax.lax.all_to_all(send_v, axis, 0, 0).reshape(-1)
        recv_e = None
        if s_exp is not None:
            # the expiry deadline rides as a fourth send lane; EXPIRE rows
            # route to their owner by key exactly like other update ops
            send_e = jnp.where(valid, s_exp[idx_c], NO_EXPIRY)
            recv_e = jax.lax.all_to_all(send_e, axis, 0, 0).reshape(-1)
        rord = jnp.argsort(recv_k, stable=True)
        rinv = _inverse_permutation(rord)
        r_tag, r_key = recv_t[rord], recv_k[rord]

        if overlap:
            # counts collective pre-apply: the received rows ARE this
            # shard's update batch, so the prediction sees exactly what the
            # update pass will apply
            ins_keys = _compact_by_mask(
                r_key, (r_tag == OP_INSERT) | (r_tag == OP_EXPIRE)
            )
            del_keys = _compact_by_mask(r_key, r_tag == OP_DELETE)
            post_keys, pref = _predict_post_keys(state, ins_keys, del_keys)
            src_b, src_p, mine, rvalid, start_s, emit_s, rtrunc = (
                _range_counts_phase(
                    post_keys, pref, state.mkba, isr_s, q_lo, q_hi, axis, max_results
                )
            )

        new_state, res, st = apply_ops(
            state,
            OpBatch(
                tag=r_tag,
                key=r_key,
                val=recv_v[rord],
                exp=None if recv_e is None else recv_e[rord],
            ),
            config=inner_cfg,
            now=now,
        )
        value_r = res["value"][rinv]
        skey_r = res["succ_key"][rinv]

        # successor fallback across shards: an owner whose local state has
        # no key ≥ q answers with the first non-empty *later* shard's
        # minimum — the §8 fence-row trick one level up the hierarchy
        m, mv = _post_update_shard_min(new_state)
        mins = jax.lax.all_gather(m.reshape(1), axis).reshape(-1)      # [S]
        mvals = jax.lax.all_gather(mv.reshape(1), axis).reshape(-1)
        sufk, sufi = _suffix_min_with_index(mins)
        sufk_pad = jnp.concatenate([sufk, jnp.array([EMPTY], KEY_DTYPE)])
        sufi_pad = jnp.concatenate([sufi, jnp.array([0], jnp.int32)])
        fb_key = sufk_pad[me + 1]
        fb_val = jnp.where(fb_key != EMPTY, mvals[sufi_pad[me + 1]], NOT_FOUND)
        needs_fb = (recv_t == OP_SUCCESSOR) & (skey_r == EMPTY)
        skey_r = jnp.where(needs_fb, fb_key, skey_r)
        value_r = jnp.where(needs_fb, fb_val, value_r)

        # inverse a2a: owner d's row s carries results for the rows source
        # s sent to d, in their original slots
        back_v = jax.lax.all_to_all(value_r.reshape(n_shards, capacity), axis, 0, 0)
        back_sk = jax.lax.all_to_all(skey_r.reshape(n_shards, capacity), axis, 0, 0)
        dest = jnp.where(valid, idx_c, n_local).reshape(-1)
        out_v = (
            jnp.full((n_local + 1,), NOT_FOUND, VAL_DTYPE)
            .at[dest]
            .set(back_v.reshape(-1))[:n_local][inv]
        )
        out_sk = (
            jnp.full((n_local + 1,), EMPTY, KEY_DTYPE)
            .at[dest]
            .set(back_sk.reshape(-1))[:n_local][inv]
        )

        if has_ranges and not overlap:
            # sequential fallback (TTL with ``now``): counts phase against
            # the actually-updated state
            flat_k, _ = flatten_bucket_sorted(new_state)
            live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)
            pref = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
            )
            src_b, src_p, mine, rvalid, start_s, emit_s, rtrunc = (
                _range_counts_phase(
                    flat_k, pref, new_state.mkba, isr_s, q_lo, q_hi, axis, max_results
                )
            )

        # ONE fused combine psum over the whole contribution pytree
        contrib = {
            "inserted": st["inserted"],
            "deleted": st["deleted"],
            "overflowed_buckets": st["overflowed_buckets"],
            "a2a_overflow": overflow.astype(jnp.int32),
            "restructure": new_state.needs_restructure.astype(jnp.int32),
        }
        if has_ttl:
            contrib["expired"] = st["expired"]
        if has_ranges:
            rk_c, rv_c = _range_extract_contrib(new_state, src_b, src_p, mine)
            contrib["rk"] = rk_c
            contrib["rv"] = rv_c
        summed = jax.lax.psum(contrib, axis)

        if has_ranges:
            rk = jnp.where(rvalid, summed["rk"], EMPTY)
            rv = jnp.where(rvalid, summed["rv"], NOT_FOUND)
            # scatter per-op offsets back to this shard's input rows
            gid = gorder
            op_mine = isr_s & (gid // n_local == me)
            back = jnp.where(op_mine, gid - me * n_local, n_local)
            zeros = jnp.zeros((n_local + 1,), jnp.int32)
            rstart = zeros.at[back].set(jnp.where(isr_s, start_s, 0))[:n_local]
            rcnt = zeros.at[back].set(jnp.where(isr_s, emit_s, 0))[:n_local]
        else:
            rk, rv, _, _, rtrunc = _empty_range_outputs(n_local, max_results)
            rstart = jnp.zeros((n_local,), jnp.int32)
            rcnt = jnp.zeros((n_local,), jnp.int32)

        results = {
            "value": out_v,
            "succ_key": out_sk,
            "range_key": rk,
            "range_val": rv,
            "range_start": rstart,
            "range_count": rcnt,
        }
        stats = {
            "inserted": summed["inserted"],
            "deleted": summed["deleted"],
            "overflowed_buckets": summed["overflowed_buckets"],
            "range_truncated": rtrunc,
            "a2a_overflow": summed["a2a_overflow"],
        }
        if has_ttl:
            stats["expired"] = summed["expired"]
        new_state = dataclasses.replace(
            new_state,
            needs_restructure=(summed["restructure"] > 0),
        )
        return new_state, results, stats

    specs = _state_specs(axis, has_ttl)
    out_results = {
        "value": P(axis),
        "succ_key": P(axis),
        "range_key": P(),
        "range_val": P(),
        "range_start": P(axis),
        "range_count": P(axis),
    }
    rep_stats = {
        "inserted": P(),
        "deleted": P(),
        "overflowed_buckets": P(),
        "range_truncated": P(),
        "a2a_overflow": P(),
    }
    if has_ttl:
        rep_stats["expired"] = P()
    in_specs = (specs, P(), P(axis), P(axis), P(axis))
    if has_ttl:
        in_specs += (P(axis),)
    if has_now:
        in_specs += (P(),)
    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs, out_results, rep_stats),
        check_vma=False,
    )
    donate_argnums = (0,) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


# a2a capacity headroom over the uniform per-destination share.  The value
# comes from benchmarks/sharded_mix.py's routing-skew measurement: uniform
# random batches land within ~1.5x of the even share at the sizes the bench
# sweeps, so 2x absorbs the observed skew while sending ~2/S of the
# never-overflowing chunk capacity (the safe driver's doubling retry
# absorbs the pathological remainder).
A2A_CAPACITY_HEADROOM = 2.0


def default_a2a_capacity(
    chunk: int, n_shards: int, *, headroom: float = A2A_CAPACITY_HEADROOM
) -> int:
    """Skew-derived per-(src, dst) a2a capacity for a per-shard batch chunk
    of ``chunk`` rows: the uniform share ``ceil(chunk / n_shards)`` times
    :data:`A2A_CAPACITY_HEADROOM`, clamped to ``chunk`` (which can never
    overflow).  Used by :func:`shard_apply_ops_safe` when the config leaves
    ``capacity`` unset — its doubling retry makes an underestimate cost one
    replay, never correctness."""
    chunk = max(1, int(chunk))
    if n_shards <= 1:
        return chunk
    share = math.ceil(chunk / n_shards)
    return max(1, min(chunk, math.ceil(share * headroom)))


def _inner_config(cfg: ExecConfig, impl: str) -> ExecConfig:
    """The ExecConfig handed to the per-shard inner ``apply_ops``: resolved
    impl, the kernel-tuning knobs threaded through, and the tiny
    ``_INNER_MR`` range budget (the inner dense arrays are ignored — the
    cross-shard phase answers RANGE).  Normalized so the lru-cached builders
    key on exactly the fields that matter."""
    return ExecConfig(
        impl=impl,
        pipeline=cfg.pipeline,
        block_q=cfg.block_q,
        block_b=cfg.block_b,
        tile_table=cfg.tile_table,
        max_results=_INNER_MR,
    )


def shard_apply_ops(
    idx: ShardedFliX,
    ops: OpBatch,
    mesh,
    *,
    config: ExecConfig | None = None,
    has_updates: bool | None = None,
    has_ranges: bool | None = None,
    now=None,
    routing=_UNSET,
    impl=_UNSET,
    max_results=_UNSET,
    donate=_UNSET,
    capacity=_UNSET,
):
    """Execute one mixed sorted batch across the mesh.

    Execution strategy comes in as one ``config=ExecConfig(...)``
    (``routing`` / ``impl`` / ``max_results`` / ``donate`` / ``capacity``
    plus the fused-kernel pipeline and tile knobs threaded to the per-shard
    ``apply_ops``); the trailing keywords are deprecated warn-once shims.
    Per-call facts (``has_updates`` / ``has_ranges`` hints, the TTL clock
    ``now``) stay keywords — they describe the batch, not the strategy.

    Returns ``(idx', results, stats)`` with the single-device ``apply_ops``
    contract (DESIGN.md §11):

    * ``routing="replicated"`` — ``ops`` is one global sorted batch (any
      placement; it is broadcast).  ``results`` is replicated and aligned
      with the sorted batch, byte-identical to ``apply_ops`` on the
      union state.
    * ``routing="a2a"`` — ``ops`` is position-sharded over the mesh axis
      (:func:`shard_batch`), each shard's chunk key-sorted.  ``value`` /
      ``succ_key`` / ``range_start`` / ``range_count`` come back sharded,
      aligned with each shard's input rows; the dense ``range_key`` /
      ``range_val`` arrays and ``stats`` are replicated.  ``capacity``
      bounds rows per (source, destination) pair (default: chunk size,
      which can never overflow); exceeding it is *not* an error — dropped
      rows are counted in ``stats["a2a_overflow"]`` and the caller replays
      the batch on the same (unmutated) ``idx`` with a larger capacity.

    On bucket overflow the returned state carries ``needs_restructure`` —
    hosts use :func:`shard_apply_ops_safe`, whose retry path regrows via
    :func:`shard_restructure`.
    """
    cfg = resolve_config(
        "shard_apply_ops",
        config,
        routing=routing,
        impl=impl,
        max_results=max_results,
        donate=donate,
        capacity=capacity,
    )
    routing = cfg.routing
    impl = cfg.impl
    max_results = cfg.max_results
    capacity = cfg.capacity
    if impl == "auto":
        if jax.default_backend() != "tpu":
            impl = "reference"
        else:
            if has_updates is None:
                has_updates = bool(
                    jnp.any(
                        (ops.tag == OP_INSERT)
                        | (ops.tag == OP_DELETE)
                        | (ops.tag == OP_EXPIRE)
                    )
                )
            impl = "fused" if has_updates else "reference"
    if has_ranges is None:
        has_ranges = bool(jnp.any(ops.tag == OP_RANGE))
    donate_r = cfg.donate and jax.default_backend() != "cpu"
    inner_cfg = _inner_config(cfg, impl)

    # TTL activation is structural, exactly as in single-device apply_ops: a
    # batch-side expiry column promotes the state (attaching an all-NO_EXPIRY
    # sharded column) so the shard_map pytree matches the TTL specs
    has_ttl = idx.state.exps is not None or ops.exp is not None
    if has_ttl and idx.state.exps is None:
        shard3 = NamedSharding(mesh, P(idx.axis, None, None))
        exps = jax.device_put(
            jnp.full(idx.state.keys.shape, NO_EXPIRY, KEY_DTYPE), shard3
        )
        idx = idx._replace(state=dataclasses.replace(idx.state, exps=exps))
    has_now = has_ttl and now is not None
    extra = ()
    if has_ttl:
        exp_col = (
            ops.exp
            if ops.exp is not None
            else jnp.full((ops.size,), NO_EXPIRY, KEY_DTYPE)
        )
        extra = (exp_col,)
        if has_now:
            extra += (jnp.asarray(now, KEY_DTYPE),)

    if routing == "replicated":
        fn = _build_replicated(
            mesh, idx.axis, inner_cfg, max_results, has_ranges, donate_r, has_ttl, has_now
        )
        new_state, results, stats = fn(
            idx.state, idx.lower_fence, ops.tag, ops.key, ops.val, *extra
        )
    else:
        n_shards = int(mesh.shape[idx.axis])
        if ops.size % n_shards:
            raise ValueError(
                f"a2a batch size {ops.size} not divisible by {n_shards} shards"
            )
        if capacity is None:
            capacity = ops.size // n_shards
        fn = _build_a2a(
            mesh,
            idx.axis,
            inner_cfg,
            max_results,
            has_ranges,
            capacity,
            donate_r,
            has_ttl,
            has_now,
        )
        new_state, results, stats = fn(
            idx.state, idx.part_fences, ops.tag, ops.key, ops.val, *extra
        )
    return idx._replace(state=new_state), results, stats


def shard_apply_ops_safe(
    idx: ShardedFliX,
    ops: OpBatch,
    mesh,
    *,
    config: ExecConfig | None = None,
    has_updates: bool | None = None,
    has_ranges: bool | None = None,
    now=None,
    routing=_UNSET,
    impl=_UNSET,
    max_results=_UNSET,
    capacity=_UNSET,
):
    """Host-level driver: apply, restructure-and-retry on bucket overflow.

    Mirrors ``apply_ops_safe`` one level up: the retry replays the *whole*
    batch on a rebalanced (``shard_restructure``-grown) pre-batch index,
    which is safe because :func:`shard_apply_ops` never mutates its input
    (and is also why this driver never donates).  ``has_updates`` /
    ``has_ranges`` let drivers that already know the batch composition
    host-side skip the device syncs (``serve/kv_index.py`` does).
    Execution strategy comes in as one ``config=ExecConfig(...)``; the
    trailing keywords are deprecated warn-once shims.

    Under ``routing="a2a"``, per-pair overflow
    (``stats["a2a_overflow"] > 0``) is ALSO retried here — the documented
    re-route-with-larger-capacity replay, safe for the same
    no-input-mutation reason — doubling the capacity each round up to the
    chunk size, which can never overflow.  When the config leaves
    ``capacity`` unset, the starting point is the skew-derived
    :func:`default_a2a_capacity` rather than the worst-case chunk: ~n_shards
    times less a2a traffic on typical batches, with at most a couple of
    doubling replays on pathological skew.

    The returned ``stats`` surfaces the whole driver run (host ints, so
    the gateway and bench artifact can report them without device syncs):

    * ``restructure_retries``   — bucket-overflow replays on a regrown index;
    * ``a2a_retries``           — capacity re-route replays;
    * ``a2a_overflow_dropped``  — total rows dropped across the retried
      attempts (the final attempt's own ``a2a_overflow`` stays 0 on
      success — this counter is how the retries remain visible).
    """
    cfg = resolve_config(
        "shard_apply_ops_safe",
        config,
        routing=routing,
        impl=impl,
        max_results=max_results,
        capacity=capacity,
    )
    cap = cfg.capacity
    if cfg.routing == "a2a" and cap is None:
        cap = default_a2a_capacity(
            ops.size // int(mesh.shape[idx.axis]), int(mesh.shape[idx.axis])
        )
    # this driver replays batches, so it must own the buffers: never donate
    run_cfg = cfg.replace(donate=False, capacity=cap)
    a2a_retries = 0
    a2a_dropped = 0
    while True:
        new_idx, results, stats = shard_apply_ops(
            idx,
            ops,
            mesh,
            config=run_cfg,
            has_updates=has_updates,
            has_ranges=has_ranges,
            now=now,
        )
        if cfg.routing != "a2a":
            break
        chunk = ops.size // int(mesh.shape[idx.axis])
        overflow = int(stats["a2a_overflow"])
        if overflow == 0 or run_cfg.capacity >= chunk:
            break
        a2a_retries += 1
        a2a_dropped += overflow
        run_cfg = run_cfg.replace(capacity=min(chunk, run_cfg.capacity * 2))
    overflowed = bool(new_idx.state.needs_restructure) and not bool(
        idx.state.needs_restructure
    )
    if overflowed:
        n_ins = int(jnp.sum((ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)))
        grown = shard_restructure(idx, mesh, extra_keys=max(n_ins, 1))
        new_idx, results, stats = shard_apply_ops(
            grown,
            ops,
            mesh,
            config=run_cfg,
            has_updates=has_updates,
            has_ranges=has_ranges,
            now=now,
        )
        assert not bool(new_idx.state.needs_restructure), "post-restructure overflow"
    stats = dict(stats)
    stats["restructure_retries"] = int(overflowed)
    stats["a2a_retries"] = a2a_retries
    stats["a2a_overflow_dropped"] = a2a_dropped
    return new_idx, results, stats
