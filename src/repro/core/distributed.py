"""Bucket-sharded FliX across a device mesh (the distributed index service).

Buckets are *range-partitioned* across shards (contiguous MKBA ranges per
device), so the flipped paradigm lifts directly to the cluster level: a
sorted operation batch is routed by the same fence-searchsorted primitive —
each shard (a super-bucket) pulls its slice.

Two routing modes:
  * ``replicated`` — the sorted batch is broadcast; each shard masks to its
    fence range and processes locally; results combine with one pmax/pmin.
    Two collectives per batch; right for query-dominant workloads where the
    batch is small relative to the structure (the paper's regime).
  * ``a2a`` — each shard holds a batch shard; per-destination slice
    boundaries (searchsorted of the global partition fences) drive a padded
    ``all_to_all``.  Right at 1000-node scale where batches are ingested
    sharded.  Fixed per-pair capacity keeps shapes static; overflow is
    counted and surfaced (the caller re-routes with a bigger capacity).

All ops run under ``shard_map`` over one mesh axis; per-shard compute is the
single-device FliX code unchanged — compute-to-bucket composes across the
hierarchy.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.build import build_from_sorted
from repro.core.delete import delete as local_delete
from repro.core.insert import insert as local_insert
from repro.core.query import point_query as local_point_query
from repro.core.query import successor_query as local_successor
from repro.core.state import EMPTY, KEY_DTYPE, MIN_KEY, NOT_FOUND, VAL_DTYPE, FliXState

from repro.compat import shard_map as _shard_map


class ShardedFliX(NamedTuple):
    state: FliXState          # bucket dim sharded over ``axis``
    lower_fence: jax.Array    # [n_shards] fence below each shard's range
    part_fences: jax.Array    # [n_shards] upper fence per shard (replicated)
    axis: str


def shard_build(
    sorted_keys, sorted_vals, mesh, *, axis: str = "shards",
    node_size: int = 32, nodes_per_bucket: int = 16, fill: float = 0.5,
) -> ShardedFliX:
    """Build then range-partition across ``mesh``'s ``axis``."""
    import math

    n_shards = int(mesh.shape[axis])
    p = max(1, int(node_size * fill))
    n = int(jnp.sum(sorted_keys != EMPTY))
    per_shard_buckets = max(1, math.ceil(math.ceil(n / p) / n_shards))
    nb = per_shard_buckets * n_shards
    state = build_from_sorted(
        sorted_keys, sorted_vals,
        num_buckets=nb, nodes_per_bucket=nodes_per_bucket,
        node_size=node_size, fill=fill,
    )
    part_fences = state.mkba.reshape(n_shards, -1)[:, -1]
    lower_fence = jnp.concatenate(
        [jnp.array([MIN_KEY], KEY_DTYPE), part_fences[:-1]]
    )

    shard3 = NamedSharding(mesh, P(axis, None, None))
    shard2 = NamedSharding(mesh, P(axis, None))
    shard1 = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    state = FliXState(
        keys=jax.device_put(state.keys, shard3),
        vals=jax.device_put(state.vals, shard3),
        node_count=jax.device_put(state.node_count, shard2),
        node_max=jax.device_put(state.node_max, shard2),
        num_nodes=jax.device_put(state.num_nodes, shard1),
        mkba=jax.device_put(state.mkba, shard1),
        needs_restructure=jax.device_put(state.needs_restructure, rep),
    )
    return ShardedFliX(
        state=state,
        lower_fence=jax.device_put(lower_fence, shard1),
        part_fences=jax.device_put(part_fences, rep),
        axis=axis,
    )


def _state_specs(axis: str) -> FliXState:
    return FliXState(
        keys=P(axis, None, None),
        vals=P(axis, None, None),
        node_count=P(axis, None),
        node_max=P(axis, None),
        num_nodes=P(axis),
        mkba=P(axis),
        needs_restructure=P(),
    )


def _mask_to_range(sorted_keys, lower, upper):
    """Keep keys in (lower, upper]; push the rest to an EMPTY tail."""
    in_range = (sorted_keys > lower) & (sorted_keys <= upper)
    masked = jnp.where(in_range, sorted_keys, EMPTY)
    return jnp.sort(masked), in_range


def point_query(idx: ShardedFliX, sorted_queries: jax.Array, mesh) -> jax.Array:
    """Replicated-batch distributed point query (one pmax combine)."""
    axis = idx.axis

    def body(state, lf, queries):
        lf = lf[0]
        res = local_point_query(state, queries)
        upper = state.mkba[-1]
        mine = (queries > lf) & (queries <= upper)
        res = jnp.where(mine, res, NOT_FOUND)
        return jax.lax.pmax(res, axis)

    return jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(_state_specs(axis), P(axis), P()),
            out_specs=P(),
        )
    )(idx.state, idx.lower_fence, sorted_queries.astype(KEY_DTYPE))


def successor_query(idx: ShardedFliX, sorted_queries: jax.Array, mesh):
    """Distributed successor: local candidate per shard, pmin combine."""
    axis = idx.axis

    def body(state, lf, queries):
        lf = lf[0]
        # clamp each query into this shard's range so local successor search
        # starts at the right place for queries from earlier shards
        qc = jnp.clip(queries, lf + 1, EMPTY - 1)
        k, v = local_successor(state, qc)
        # candidates only count when ≥ the original query
        ok = (k != EMPTY) & (k >= queries)
        k = jnp.where(ok, k, EMPTY)
        kmin = jax.lax.pmin(k, axis)
        vsel = jnp.where((k == kmin) & ok, v, NOT_FOUND)
        return kmin, jax.lax.pmax(vsel, axis)

    return jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(_state_specs(axis), P(axis), P()),
            out_specs=(P(), P()),
        )
    )(idx.state, idx.lower_fence, sorted_queries.astype(KEY_DTYPE))


def insert(idx: ShardedFliX, sorted_keys, sorted_vals, mesh) -> ShardedFliX:
    """Replicated-batch distributed insert: each shard takes its range."""
    axis = idx.axis

    def body(state, lf, keys, vals):
        lf = lf[0]
        upper = state.mkba[-1]
        masked, in_range = _mask_to_range(keys, lf, upper)
        order = jnp.argsort(jnp.where(in_range, keys, EMPTY), stable=True)
        new_state, _ = local_insert(state, masked, vals[order])
        flag = jax.lax.pmax(
            new_state.needs_restructure.astype(jnp.int32), axis
        ).astype(bool)
        return dataclasses.replace(new_state, needs_restructure=flag)

    new_state = jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(_state_specs(axis), P(axis), P(), P()),
            out_specs=_state_specs(axis),
        )
    )(idx.state, idx.lower_fence, sorted_keys.astype(KEY_DTYPE), sorted_vals.astype(VAL_DTYPE))
    return idx._replace(state=new_state)


def delete(idx: ShardedFliX, sorted_keys, mesh) -> ShardedFliX:
    axis = idx.axis

    def body(state, lf, keys):
        lf = lf[0]
        masked, _ = _mask_to_range(keys, lf, state.mkba[-1])
        new_state, _ = local_delete(state, masked)
        flag = jax.lax.pmax(
            new_state.needs_restructure.astype(jnp.int32), axis
        ).astype(bool)
        return dataclasses.replace(new_state, needs_restructure=flag)

    new_state = jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(_state_specs(axis), P(axis), P()),
            out_specs=_state_specs(axis),
        )
    )(idx.state, idx.lower_fence, sorted_keys.astype(KEY_DTYPE))
    return idx._replace(state=new_state)


# ---------------------------------------------------------------------------
# all-to-all routing (sharded-ingest mode)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("axis", "capacity", "n_shards"))
def _route_kernel(batch_shard, vals_shard, fences, *, axis, capacity, n_shards):
    """Inside shard_map: route my batch shard to owner shards (padded A2A)."""
    # my keys' destinations via the global partition fences
    ends = jnp.searchsorted(batch_shard, fences, side="right")
    starts = jnp.concatenate([jnp.zeros((1,), ends.dtype), ends[:-1]])
    counts = (ends - starts).astype(jnp.int32)
    overflow = jnp.sum(jnp.maximum(counts - capacity, 0))

    idx = starts[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None]
    valid = idx < ends[:, None]
    idx_c = jnp.minimum(idx, batch_shard.shape[0] - 1)
    send_k = jnp.where(valid, batch_shard[idx_c], EMPTY)        # [S, cap]
    send_v = jnp.where(valid, vals_shard[idx_c], 0)

    recv_k = jax.lax.all_to_all(send_k, axis, 0, 0, tiled=False)
    recv_v = jax.lax.all_to_all(send_v, axis, 0, 0, tiled=False)
    flat_k = recv_k.reshape(-1)
    order = jnp.argsort(flat_k, stable=True)
    return flat_k[order], recv_v.reshape(-1)[order], overflow.reshape(1)


def route_a2a(idx: ShardedFliX, keys_shard, vals_shard, mesh, *, capacity: int):
    """Route a *sharded* sorted batch to owner shards. Returns per-shard
    sorted (keys, vals, overflow) ready for local insert/query."""
    axis = idx.axis
    n_shards = int(mesh.shape[axis])

    def body(keys, vals, fences):
        return _route_kernel(
            keys, vals, fences, axis=axis, capacity=capacity, n_shards=n_shards
        )

    return jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P()),
            out_specs=(P(axis), P(axis), P(axis)),
        )
    )(keys_shard.astype(KEY_DTYPE), vals_shard.astype(VAL_DTYPE), idx.part_fences)
