"""Bucket-sharded FliX across a device mesh (the distributed index service).

Buckets are *range-partitioned* across shards (contiguous MKBA ranges per
device), so the flipped paradigm lifts directly to the cluster level: a
shard is just a super-bucket, and a sorted operation batch is routed by the
same fence-searchsorted primitive — each shard pulls its slice.

Since PR 5 the unit of distributed execution is the **mixed batch**:
:func:`shard_apply_ops` runs one whole ``OpBatch`` (POINT / SUCCESSOR /
INSERT / DELETE / RANGE) under a single ``shard_map`` step, with per-shard
compute delegated to ``core.ops.apply_ops`` *unchanged* — including the
``impl="fused"`` compute-to-bucket kernel and buffer donation — so the
hierarchy composes: bucket ⊂ shard ⊂ cluster.  The legacy per-op-type
entry points (``insert``/``delete``/``point_query``/``successor_query``)
are gone.

Two routing modes (DESIGN.md §11):

* ``replicated`` — the sorted batch is broadcast; each shard masks the
  *update* ops to its fence range (reads run everywhere — a successor or
  range answer may live outside the op key's owner shard) and recombines
  with one collective round.  Right for query-dominant workloads where the
  batch is small relative to the structure (the paper's regime).
* ``a2a`` — each shard holds a batch shard; op rows are routed to their
  owner shard by one partition-fence searchsorted driving a padded
  ``all_to_all``, results travel back over the inverse ``all_to_all``.
  Right at ingest scale where batches arrive sharded.  Fixed per-pair
  ``capacity`` keeps shapes static; overflow is counted and surfaced in
  ``stats["a2a_overflow"]`` (the caller re-routes with a bigger capacity —
  ``shard_apply_ops`` never mutates its input, so the retry replays the
  same batch on the same pre-batch index).

RANGE results are recombined into the dense exclusive-scan contract of
DESIGN.md §10 with *global* offsets: per-op local in-range counts are
``all_gather``-ed, an exclusive scan over shards gives each shard its slot
window inside every op's segment, and truncation is applied against the
single global ``max_results`` budget — byte-identical to the single-device
``apply_ops`` output.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.build import build_from_sorted
from repro.core.expiry import NO_EXPIRY
from repro.core.ops import (
    DEFAULT_MAX_RESULTS,
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_NOP,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
    OpBatch,
    apply_ops,
)
from repro.core.query import _suffix_min_with_index, flat_rank, range_offsets
from repro.core.state import (
    EMPTY,
    KEY_DTYPE,
    MIN_KEY,
    NOT_FOUND,
    VAL_DTYPE,
    FliXState,
    flatten_bucket_sorted,
)

# max_results handed to the *inner* apply_ops when the cross-shard range
# phase answers the batch's RANGE ops (the inner dense arrays are ignored)
_INNER_MR = 8


class ShardedFliX(NamedTuple):
    state: FliXState          # bucket dim sharded over ``axis``
    lower_fence: jax.Array    # [n_shards] fence below each shard's range
    part_fences: jax.Array    # [n_shards] upper fence per shard (replicated)
    axis: str


def plan_shard_budget(total_budget: int | None, n_shards: int) -> int | None:
    """Split a global device-memory budget across shards (DESIGN.md §15).

    Buckets are range-partitioned evenly, so the per-shard residency bound
    is simply an even split — each shard's residency plane enforces its
    slice independently and I7 holds globally because shard bucket sets are
    disjoint.  Returns a per-shard byte budget (``None`` = unbounded).
    """
    if total_budget is None:
        return None
    return max(1, int(total_budget) // max(1, n_shards))


def shard_memory_bytes(idx: ShardedFliX) -> int:
    """Total allocated footprint of a sharded index across the mesh —
    the per-shard ``memory_bytes`` summed (every shard holds the same
    static geometry, so this is shards × the per-shard footprint)."""
    return idx.state.memory_bytes() + idx.lower_fence.size * 4 + idx.part_fences.size * 4


def make_shard_mesh(n_shards: int, *, axis: str = "shards") -> jax.sharding.Mesh:
    """A 1-D mesh over the first ``n_shards`` local devices."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for {n_shards} shards, have {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)"
        )
    return jax.sharding.Mesh(np.array(devs[:n_shards]), (axis,))


def shard_build(
    sorted_keys,
    sorted_vals,
    mesh,
    *,
    axis: str = "shards",
    node_size: int = 32,
    nodes_per_bucket: int = 16,
    fill: float = 0.5,
    extra_keys: int = 0,
    sorted_exps=None,
) -> ShardedFliX:
    """Build then range-partition across ``mesh``'s ``axis``.

    ``extra_keys`` over-provisions the bucket count (the distributed
    analogue of ``restructure_grow``'s headroom argument) so a subsequent
    batch of that many inserts cannot overflow a fresh structure.
    ``sorted_exps`` carries the per-key expiry column (sorted alongside the
    keys); the built state then serves the TTL path (DESIGN.md §14).
    """
    n_shards = int(mesh.shape[axis])
    p = max(1, int(node_size * fill))
    n = int(jnp.sum(sorted_keys != EMPTY)) + extra_keys
    per_shard_buckets = max(1, math.ceil(math.ceil(n / p) / n_shards))
    nb = per_shard_buckets * n_shards
    state = build_from_sorted(
        sorted_keys,
        sorted_vals,
        num_buckets=nb,
        nodes_per_bucket=nodes_per_bucket,
        node_size=node_size,
        fill=fill,
    )
    exps = None
    if sorted_exps is not None:
        # expiry plane of the same build: identical layout, exps in vals
        built_e = build_from_sorted(
            sorted_keys,
            jnp.asarray(sorted_exps, KEY_DTYPE),
            num_buckets=nb,
            nodes_per_bucket=nodes_per_bucket,
            node_size=node_size,
            fill=fill,
        )
        exps = jnp.where(state.keys == EMPTY, NO_EXPIRY, built_e.vals)
    part_fences = state.mkba.reshape(n_shards, -1)[:, -1]
    lower_fence = jnp.concatenate([jnp.array([MIN_KEY], KEY_DTYPE), part_fences[:-1]])

    shard3 = NamedSharding(mesh, P(axis, None, None))
    shard2 = NamedSharding(mesh, P(axis, None))
    shard1 = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    state = FliXState(
        keys=jax.device_put(state.keys, shard3),
        vals=jax.device_put(state.vals, shard3),
        node_count=jax.device_put(state.node_count, shard2),
        node_max=jax.device_put(state.node_max, shard2),
        num_nodes=jax.device_put(state.num_nodes, shard1),
        mkba=jax.device_put(state.mkba, shard1),
        needs_restructure=jax.device_put(state.needs_restructure, rep),
        exps=None if exps is None else jax.device_put(exps, shard3),
    )
    return ShardedFliX(
        state=state,
        lower_fence=jax.device_put(lower_fence, shard1),
        part_fences=jax.device_put(part_fences, rep),
        axis=axis,
    )


def shard_restructure(
    idx: ShardedFliX,
    mesh,
    *,
    extra_keys: int = 0,
    fill: float = 0.5,
) -> ShardedFliX:
    """Rebalance partition fences from the live-key distribution.

    The cluster analogue of the paper's §3.5 relaunch: the host pulls the
    live contents, re-plans a uniform geometry for ``live + extra_keys``
    keys, and re-partitions so every shard owns an equal bucket count of an
    evenly-filled structure — skew accumulated since the last build (every
    new tenant hashing into one shard's fence range, say) is erased.

    Host-driven by design, exactly like single-device ``restructure``: the
    new static geometry (bucket count, possibly a widened chain) cannot be
    chosen on device.  Functional — the input index is untouched.
    """
    state = idx.state
    flat_k = np.asarray(jax.device_get(state.keys)).reshape(-1)
    flat_v = np.asarray(jax.device_get(state.vals)).reshape(-1)
    order = np.argsort(flat_k, kind="stable")  # EMPTY sentinels sort last
    sorted_k, sorted_v = flat_k[order], flat_v[order]
    sorted_e = None
    if state.exps is not None:
        sorted_e = np.asarray(jax.device_get(state.exps)).reshape(-1)[order]

    live = int((flat_k != EMPTY).sum())
    p = max(1, int(state.node_size * fill))
    cap = state.nodes_per_bucket * state.node_size
    if p + extra_keys > cap:
        # pathological skew: widen the chain so one bucket can absorb the
        # whole pending batch (mirrors restructure_grow)
        npb = math.ceil((p + extra_keys) / state.node_size)
    else:
        npb = state.nodes_per_bucket
    return shard_build(
        jnp.asarray(sorted_k),
        jnp.asarray(sorted_v),
        mesh,
        axis=idx.axis,
        node_size=state.node_size,
        nodes_per_bucket=npb,
        fill=fill,
        extra_keys=extra_keys,
        sorted_exps=None if sorted_e is None else jnp.asarray(sorted_e),
    )


def shard_live_counts(idx: ShardedFliX, mesh) -> jax.Array:
    """Per-shard live-key counts ``[n_shards]`` (balance diagnostics)."""
    axis = idx.axis

    def body(node_count):
        return jax.lax.all_gather(jnp.sum(node_count).reshape(1), axis).reshape(-1)

    return jax.jit(
        _shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None),),
            out_specs=P(),
            check_vma=False,
        )
    )(idx.state.node_count)


def _state_specs(axis: str, has_ttl: bool = False) -> FliXState:
    return FliXState(
        keys=P(axis, None, None),
        vals=P(axis, None, None),
        node_count=P(axis, None),
        node_max=P(axis, None),
        num_nodes=P(axis),
        mkba=P(axis),
        needs_restructure=P(),
        exps=P(axis, None, None) if has_ttl else None,
    )


def replicate_batch(ops: OpBatch, mesh) -> OpBatch:
    """Place an :class:`OpBatch` fully replicated on ``mesh``."""
    rep = NamedSharding(mesh, P())
    return OpBatch(
        tag=jax.device_put(ops.tag, rep),
        key=jax.device_put(ops.key, rep),
        val=jax.device_put(ops.val, rep),
        exp=None if ops.exp is None else jax.device_put(ops.exp, rep),
    )


def shard_batch(ops: OpBatch, mesh, *, axis: str = "shards") -> OpBatch:
    """Position-shard an :class:`OpBatch` over ``axis`` (a2a-mode input).

    Each shard's chunk must be key-sorted locally (a globally sorted batch
    split into contiguous chunks qualifies); chunks from different shards
    need no mutual order.
    """
    sh = NamedSharding(mesh, P(axis))
    return OpBatch(
        tag=jax.device_put(ops.tag, sh),
        key=jax.device_put(ops.key, sh),
        val=jax.device_put(ops.val, sh),
        exp=None if ops.exp is None else jax.device_put(ops.exp, sh),
    )


def _inverse_permutation(order: jax.Array) -> jax.Array:
    return jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype)
    )


def _pmax_bool(flag: jax.Array, axis: str) -> jax.Array:
    return jax.lax.pmax(flag.astype(jnp.int32), axis).astype(bool)


def _post_update_shard_min(state: FliXState):
    """Smallest present key in this shard (EMPTY if none) and its value."""
    bucket_min = jnp.where(state.num_nodes > 0, state.keys[:, 0, 0], EMPTY)
    b = jnp.argmin(bucket_min).astype(jnp.int32)
    m = bucket_min[b]
    v = jnp.where(m != EMPTY, state.vals[b, 0, 0], NOT_FOUND)
    return m, v


def _cross_shard_range(
    state: FliXState,
    is_range: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    axis: str,
    max_results: int,
):
    """Answer RANGE ops against the union of all shards' post-update states.

    The §10 dense exclusive-scan contract with *global* offsets: local
    in-range counts are gathered across shards, an exclusive scan over the
    shard axis gives this shard its slot window inside every op's segment,
    and each emitted slot is filled by exactly one shard — so a ``psum``
    recombines the dense arrays.  ``is_range``/``lo``/``hi`` must be
    replicated and in global sorted-batch order; every return value is
    replicated and byte-identical to single-device ``dense_range_scan``.
    """
    n = lo.shape[0]
    flat_k, flat_v = flatten_bucket_sorted(state)
    nb = flat_k.shape[0]
    live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
    )
    rank_lo = flat_rank(flat_k, pref, state.mkba, lo)
    rank_hi = flat_rank(flat_k, pref, state.mkba, hi)
    local_full = jnp.maximum(rank_hi - rank_lo, 0)
    local_full = jnp.where(is_range, local_full, 0).astype(jnp.int32)

    counts_all = jax.lax.all_gather(local_full, axis)          # [S, N]
    me = jax.lax.axis_index(axis)
    global_full = jnp.sum(counts_all, axis=0)
    prefix_lt = (jnp.cumsum(counts_all, axis=0) - counts_all)[me]

    start, emit, total_emit, truncated = range_offsets(
        global_full, is_range, max_results
    )

    # slot ownership: the shared §10 owner rule, then "is slot p's in-op
    # offset inside MY shard's window [prefix_lt, prefix_lt + local_full)?"
    p = jnp.arange(max_results, dtype=jnp.int32)
    owner = jnp.clip(
        jnp.searchsorted(start, p, side="right").astype(jnp.int32) - 1, 0, n - 1
    )
    j = p - start[owner]
    valid = p < total_emit
    mine = valid & (j >= prefix_lt[owner]) & (j < prefix_lt[owner] + local_full[owner])
    g = rank_lo[owner] + (j - prefix_lt[owner])                # local key rank
    g_c = jnp.where(mine, g, 0)
    src_b = jnp.clip(
        jnp.searchsorted(pref, g_c, side="right").astype(jnp.int32) - 1, 0, nb - 1
    )
    src_p = g_c - pref[src_b]
    rk = jax.lax.psum(jnp.where(mine, flat_k[src_b, src_p], 0), axis)
    rv = jax.lax.psum(jnp.where(mine, flat_v[src_b, src_p], 0), axis)
    rk = jnp.where(valid, rk, EMPTY)
    rv = jnp.where(valid, rv, NOT_FOUND)
    return (
        rk,
        rv,
        jnp.where(is_range, start, 0),
        jnp.where(is_range, emit, 0),
        truncated,
    )


def _empty_range_outputs(n: int, max_results: int):
    return (
        jnp.full((max_results,), EMPTY, KEY_DTYPE),
        jnp.full((max_results,), NOT_FOUND, VAL_DTYPE),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.int32(0),
    )


def _combine_stats(ins_stats, axis: str, truncated, a2a_overflow):
    out = {
        "inserted": jax.lax.psum(ins_stats["inserted"], axis),
        "deleted": jax.lax.psum(ins_stats["deleted"], axis),
        "overflowed_buckets": jax.lax.psum(ins_stats["overflowed_buckets"], axis),
        "range_truncated": truncated,
        "a2a_overflow": a2a_overflow,
    }
    if "expired" in ins_stats:
        out["expired"] = jax.lax.psum(ins_stats["expired"], axis)
    return out


@functools.lru_cache(maxsize=64)
def _build_replicated(
    mesh, axis, impl, max_results, has_ranges, donate, has_ttl=False, has_now=False
):
    """jit(shard_map)-compiled replicated-routing executor (memoized)."""

    def body(state, lf, tag, key, val, *extra):
        # extra = (exp,) / (exp, now) when the TTL lanes are enabled
        exp = extra[0] if has_ttl else None
        now = extra[1] if has_now else None
        lf = lf[0]
        upper = state.mkba[-1]
        is_upd = (tag == OP_INSERT) | (tag == OP_DELETE) | (tag == OP_EXPIRE)
        is_rng = tag == OP_RANGE
        # updates run on their owner shard only; POINT/SUCCESSOR run
        # everywhere (a successor answer may live past the owner's fence);
        # RANGE is lifted out entirely for the cross-shard phase
        keep = (~is_upd | ((key > lf) & (key <= upper))) & ~is_rng
        mtag = jnp.where(keep, tag, OP_NOP)
        mkey = jnp.where(keep, key, EMPTY)
        mval = jnp.where(keep, val, 0)
        order = jnp.argsort(mkey, stable=True)
        inv = _inverse_permutation(order)
        new_state, res, st = apply_ops(
            state,
            OpBatch(
                tag=mtag[order],
                key=mkey[order],
                val=mval[order],
                exp=None
                if exp is None
                else jnp.where(keep, exp, NO_EXPIRY)[order],
            ),
            impl=impl,
            max_results=_INNER_MR,
            now=now,
        )
        value = res["value"][inv]
        succ_key = res["succ_key"][inv]

        # POINT: at most one shard holds the key, the rest answer NOT_FOUND.
        # EXPIRE recombines the same way: it is masked to its owner shard,
        # whose get-or-set answer comes back through the value lane
        is_point = (tag == OP_POINT) | (tag == OP_EXPIRE)
        hit = is_point & (value != NOT_FOUND)
        pv = jax.lax.psum(jnp.where(hit, value, 0), axis)
        n_hit = jax.lax.psum(hit.astype(jnp.int32), axis)
        point_val = jnp.where(n_hit > 0, pv, NOT_FOUND)

        # SUCCESSOR: shard-local candidates, global min; shard key ranges
        # are disjoint so the min is attained by exactly one shard
        is_succ = tag == OP_SUCCESSOR
        cand = jnp.where(is_succ, succ_key, EMPTY)
        kmin = jax.lax.pmin(cand, axis)
        winner = is_succ & (cand == kmin) & (cand != EMPTY)
        sv = jax.lax.psum(jnp.where(winner, value, 0), axis)
        succ_val = jnp.where(kmin != EMPTY, sv, NOT_FOUND)

        if has_ranges:
            rk, rv, rstart, rcnt, rtrunc = _cross_shard_range(
                new_state, is_rng, key, val.astype(KEY_DTYPE), axis, max_results
            )
        else:
            rk, rv, rstart, rcnt, rtrunc = _empty_range_outputs(
                key.shape[0], max_results
            )

        results = {
            "value": jnp.where(
                is_point, point_val, jnp.where(is_succ, succ_val, NOT_FOUND)
            ),
            "succ_key": jnp.where(is_succ, kmin, EMPTY),
            "range_key": rk,
            "range_val": rv,
            "range_start": rstart,
            "range_count": rcnt,
        }
        stats = _combine_stats(st, axis, rtrunc, jnp.int32(0))
        new_state = dataclasses.replace(
            new_state,
            needs_restructure=_pmax_bool(new_state.needs_restructure, axis),
        )
        return new_state, results, stats

    specs = _state_specs(axis, has_ttl)
    rep_results = {
        "value": P(),
        "succ_key": P(),
        "range_key": P(),
        "range_val": P(),
        "range_start": P(),
        "range_count": P(),
    }
    rep_stats = {
        "inserted": P(),
        "deleted": P(),
        "overflowed_buckets": P(),
        "range_truncated": P(),
        "a2a_overflow": P(),
    }
    if has_ttl:
        rep_stats["expired"] = P()
    in_specs = (specs, P(axis), P(), P(), P())
    if has_ttl:
        in_specs += (P(),)
    if has_now:
        in_specs += (P(),)
    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs, rep_results, rep_stats),
        check_vma=False,
    )
    donate_argnums = (0,) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


@functools.lru_cache(maxsize=64)
def _build_a2a(
    mesh,
    axis,
    impl,
    max_results,
    has_ranges,
    capacity,
    donate,
    has_ttl=False,
    has_now=False,
):
    """jit(shard_map)-compiled a2a-routing executor (memoized)."""
    n_shards = int(mesh.shape[axis])

    def body(state, part_fences, tag, key, val, *extra):
        # extra = (exp,) / (exp, now) when the TTL lanes are enabled
        exp = extra[0] if has_ttl else None
        now = extra[1] if has_now else None
        n_local = key.shape[0]
        me = jax.lax.axis_index(axis)
        is_rng = tag == OP_RANGE
        # RANGE rows never ride the a2a (the cross-shard phase answers them
        # from the gathered batch); masking them to the EMPTY tail keeps the
        # local sort a valid routing order
        rkey = jnp.where(is_rng, EMPTY, key)
        order = jnp.argsort(rkey, stable=True)
        inv = _inverse_permutation(order)
        s_tag, s_key, s_val = tag[order], rkey[order], val[order]
        s_exp = None if exp is None else exp[order]

        # per-destination slices by one partition-fence searchsorted
        ends = jnp.searchsorted(s_key, part_fences, side="right").astype(jnp.int32)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), ends[:-1]])
        counts = ends - starts
        overflow = jnp.sum(jnp.maximum(counts - capacity, 0))

        idx = starts[:, None] + jnp.arange(capacity, dtype=jnp.int32)[None]
        valid = idx < ends[:, None]
        idx_c = jnp.minimum(idx, n_local - 1)
        send_t = jnp.where(valid, s_tag[idx_c], OP_NOP)
        send_k = jnp.where(valid, s_key[idx_c], EMPTY)
        send_v = jnp.where(valid, s_val[idx_c], 0)

        recv_t = jax.lax.all_to_all(send_t, axis, 0, 0).reshape(-1)
        recv_k = jax.lax.all_to_all(send_k, axis, 0, 0).reshape(-1)
        recv_v = jax.lax.all_to_all(send_v, axis, 0, 0).reshape(-1)
        recv_e = None
        if s_exp is not None:
            # the expiry deadline rides as a fourth send lane; EXPIRE rows
            # route to their owner by key exactly like other update ops
            send_e = jnp.where(valid, s_exp[idx_c], NO_EXPIRY)
            recv_e = jax.lax.all_to_all(send_e, axis, 0, 0).reshape(-1)
        rord = jnp.argsort(recv_k, stable=True)
        rinv = _inverse_permutation(rord)
        new_state, res, st = apply_ops(
            state,
            OpBatch(
                tag=recv_t[rord],
                key=recv_k[rord],
                val=recv_v[rord],
                exp=None if recv_e is None else recv_e[rord],
            ),
            impl=impl,
            max_results=_INNER_MR,
            now=now,
        )
        value_r = res["value"][rinv]
        skey_r = res["succ_key"][rinv]

        # successor fallback across shards: an owner whose local state has
        # no key ≥ q answers with the first non-empty *later* shard's
        # minimum — the §8 fence-row trick one level up the hierarchy
        m, mv = _post_update_shard_min(new_state)
        mins = jax.lax.all_gather(m.reshape(1), axis).reshape(-1)      # [S]
        mvals = jax.lax.all_gather(mv.reshape(1), axis).reshape(-1)
        sufk, sufi = _suffix_min_with_index(mins)
        sufk_pad = jnp.concatenate([sufk, jnp.array([EMPTY], KEY_DTYPE)])
        sufi_pad = jnp.concatenate([sufi, jnp.array([0], jnp.int32)])
        fb_key = sufk_pad[me + 1]
        fb_val = jnp.where(fb_key != EMPTY, mvals[sufi_pad[me + 1]], NOT_FOUND)
        needs_fb = (recv_t == OP_SUCCESSOR) & (skey_r == EMPTY)
        skey_r = jnp.where(needs_fb, fb_key, skey_r)
        value_r = jnp.where(needs_fb, fb_val, value_r)

        # inverse a2a: owner d's row s carries results for the rows source
        # s sent to d, in their original slots
        back_v = jax.lax.all_to_all(value_r.reshape(n_shards, capacity), axis, 0, 0)
        back_sk = jax.lax.all_to_all(skey_r.reshape(n_shards, capacity), axis, 0, 0)
        dest = jnp.where(valid, idx_c, n_local).reshape(-1)
        out_v = (
            jnp.full((n_local + 1,), NOT_FOUND, VAL_DTYPE)
            .at[dest]
            .set(back_v.reshape(-1))[:n_local][inv]
        )
        out_sk = (
            jnp.full((n_local + 1,), EMPTY, KEY_DTYPE)
            .at[dest]
            .set(back_sk.reshape(-1))[:n_local][inv]
        )

        if has_ranges:
            # gather every shard's RANGE rows (tagged with their global
            # input position), order them as make_ops would, and run the
            # global-offset range phase
            g_tag = jax.lax.all_gather(tag, axis).reshape(-1)
            g_lo = jax.lax.all_gather(key, axis).reshape(-1)
            g_hi = jax.lax.all_gather(val, axis).reshape(-1)
            g_isr = g_tag == OP_RANGE
            gorder = jnp.argsort(jnp.where(g_isr, g_lo, EMPTY), stable=True)
            isr_s = g_isr[gorder]
            rk, rv, start_s, emit_s, rtrunc = _cross_shard_range(
                new_state,
                isr_s,
                g_lo[gorder],
                g_hi[gorder].astype(KEY_DTYPE),
                axis,
                max_results,
            )
            # scatter per-op offsets back to this shard's input rows
            gid = gorder
            mine = isr_s & (gid // n_local == me)
            back = jnp.where(mine, gid - me * n_local, n_local)
            zeros = jnp.zeros((n_local + 1,), jnp.int32)
            rstart = zeros.at[back].set(start_s)[:n_local]
            rcnt = zeros.at[back].set(emit_s)[:n_local]
        else:
            rk, rv, _, _, rtrunc = _empty_range_outputs(n_local, max_results)
            rstart = jnp.zeros((n_local,), jnp.int32)
            rcnt = jnp.zeros((n_local,), jnp.int32)

        results = {
            "value": out_v,
            "succ_key": out_sk,
            "range_key": rk,
            "range_val": rv,
            "range_start": rstart,
            "range_count": rcnt,
        }
        stats = _combine_stats(
            st, axis, rtrunc, jax.lax.psum(overflow, axis).astype(jnp.int32)
        )
        new_state = dataclasses.replace(
            new_state,
            needs_restructure=_pmax_bool(new_state.needs_restructure, axis),
        )
        return new_state, results, stats

    specs = _state_specs(axis, has_ttl)
    out_results = {
        "value": P(axis),
        "succ_key": P(axis),
        "range_key": P(),
        "range_val": P(),
        "range_start": P(axis),
        "range_count": P(axis),
    }
    rep_stats = {
        "inserted": P(),
        "deleted": P(),
        "overflowed_buckets": P(),
        "range_truncated": P(),
        "a2a_overflow": P(),
    }
    if has_ttl:
        rep_stats["expired"] = P()
    in_specs = (specs, P(), P(axis), P(axis), P(axis))
    if has_ttl:
        in_specs += (P(axis),)
    if has_now:
        in_specs += (P(),)
    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs, out_results, rep_stats),
        check_vma=False,
    )
    donate_argnums = (0,) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


def shard_apply_ops(
    idx: ShardedFliX,
    ops: OpBatch,
    mesh,
    *,
    routing: str = "replicated",
    impl: str = "auto",
    max_results: int = DEFAULT_MAX_RESULTS,
    donate: bool = False,
    capacity: int | None = None,
    has_updates: bool | None = None,
    has_ranges: bool | None = None,
    now=None,
):
    """Execute one mixed sorted batch across the mesh.

    Returns ``(idx', results, stats)`` with the single-device ``apply_ops``
    contract (DESIGN.md §11):

    * ``routing="replicated"`` — ``ops`` is one global sorted batch (any
      placement; it is broadcast).  ``results`` is replicated and aligned
      with the sorted batch, byte-identical to ``apply_ops`` on the
      union state.
    * ``routing="a2a"`` — ``ops`` is position-sharded over the mesh axis
      (:func:`shard_batch`), each shard's chunk key-sorted.  ``value`` /
      ``succ_key`` / ``range_start`` / ``range_count`` come back sharded,
      aligned with each shard's input rows; the dense ``range_key`` /
      ``range_val`` arrays and ``stats`` are replicated.  ``capacity``
      bounds rows per (source, destination) pair (default: chunk size,
      which can never overflow); exceeding it is *not* an error — dropped
      rows are counted in ``stats["a2a_overflow"]`` and the caller replays
      the batch on the same (unmutated) ``idx`` with a larger capacity.

    ``impl`` / ``donate`` / ``max_results`` are forwarded to the per-shard
    ``apply_ops`` (``impl="auto"`` resolves host-side exactly as on a
    single device; donation hands the sharded state's buffers to the step).
    On bucket overflow the returned state carries ``needs_restructure`` —
    hosts use :func:`shard_apply_ops_safe`, whose retry path regrows via
    :func:`shard_restructure`.
    """
    if routing not in ("replicated", "a2a"):
        raise ValueError(f"unknown routing: {routing!r}")
    if impl == "auto":
        if jax.default_backend() != "tpu":
            impl = "reference"
        else:
            if has_updates is None:
                has_updates = bool(
                    jnp.any(
                        (ops.tag == OP_INSERT)
                        | (ops.tag == OP_DELETE)
                        | (ops.tag == OP_EXPIRE)
                    )
                )
            impl = "fused" if has_updates else "reference"
    if has_ranges is None:
        has_ranges = bool(jnp.any(ops.tag == OP_RANGE))
    donate = donate and jax.default_backend() != "cpu"

    # TTL activation is structural, exactly as in single-device apply_ops: a
    # batch-side expiry column promotes the state (attaching an all-NO_EXPIRY
    # sharded column) so the shard_map pytree matches the TTL specs
    has_ttl = idx.state.exps is not None or ops.exp is not None
    if has_ttl and idx.state.exps is None:
        shard3 = NamedSharding(mesh, P(idx.axis, None, None))
        exps = jax.device_put(
            jnp.full(idx.state.keys.shape, NO_EXPIRY, KEY_DTYPE), shard3
        )
        idx = idx._replace(state=dataclasses.replace(idx.state, exps=exps))
    has_now = has_ttl and now is not None
    extra = ()
    if has_ttl:
        exp_col = (
            ops.exp
            if ops.exp is not None
            else jnp.full((ops.size,), NO_EXPIRY, KEY_DTYPE)
        )
        extra = (exp_col,)
        if has_now:
            extra += (jnp.asarray(now, KEY_DTYPE),)

    if routing == "replicated":
        fn = _build_replicated(
            mesh, idx.axis, impl, max_results, has_ranges, donate, has_ttl, has_now
        )
        new_state, results, stats = fn(
            idx.state, idx.lower_fence, ops.tag, ops.key, ops.val, *extra
        )
    else:
        n_shards = int(mesh.shape[idx.axis])
        if ops.size % n_shards:
            raise ValueError(
                f"a2a batch size {ops.size} not divisible by {n_shards} shards"
            )
        if capacity is None:
            capacity = ops.size // n_shards
        fn = _build_a2a(
            mesh,
            idx.axis,
            impl,
            max_results,
            has_ranges,
            capacity,
            donate,
            has_ttl,
            has_now,
        )
        new_state, results, stats = fn(
            idx.state, idx.part_fences, ops.tag, ops.key, ops.val, *extra
        )
    return idx._replace(state=new_state), results, stats


def shard_apply_ops_safe(
    idx: ShardedFliX,
    ops: OpBatch,
    mesh,
    *,
    routing: str = "replicated",
    impl: str = "auto",
    max_results: int = DEFAULT_MAX_RESULTS,
    capacity: int | None = None,
    has_updates: bool | None = None,
    has_ranges: bool | None = None,
    now=None,
):
    """Host-level driver: apply, restructure-and-retry on bucket overflow.

    Mirrors ``apply_ops_safe`` one level up: the retry replays the *whole*
    batch on a rebalanced (``shard_restructure``-grown) pre-batch index,
    which is safe because :func:`shard_apply_ops` never mutates its input
    (and is also why this driver never donates).  ``has_updates`` /
    ``has_ranges`` let drivers that already know the batch composition
    host-side skip the device syncs (``serve/kv_index.py`` does).

    Under ``routing="a2a"`` with an explicit ``capacity``, per-pair
    overflow (``stats["a2a_overflow"] > 0``) is ALSO retried here — the
    documented re-route-with-larger-capacity replay, safe for the same
    no-input-mutation reason — doubling the capacity each round up to the
    chunk size, which can never overflow.

    The returned ``stats`` surfaces the whole driver run (host ints, so
    the gateway and bench artifact can report them without device syncs):

    * ``restructure_retries``   — bucket-overflow replays on a regrown index;
    * ``a2a_retries``           — capacity re-route replays;
    * ``a2a_overflow_dropped``  — total rows dropped across the retried
      attempts (the final attempt's own ``a2a_overflow`` stays 0 on
      success — this counter is how the retries remain visible).
    """
    a2a_retries = 0
    a2a_dropped = 0
    while True:
        new_idx, results, stats = shard_apply_ops(
            idx,
            ops,
            mesh,
            routing=routing,
            impl=impl,
            max_results=max_results,
            capacity=capacity,
            has_updates=has_updates,
            has_ranges=has_ranges,
            now=now,
        )
        if routing != "a2a" or capacity is None:
            break
        chunk = ops.size // int(mesh.shape[idx.axis])
        overflow = int(stats["a2a_overflow"])
        if overflow == 0 or capacity >= chunk:
            break
        a2a_retries += 1
        a2a_dropped += overflow
        capacity = min(chunk, capacity * 2)
    overflowed = bool(new_idx.state.needs_restructure) and not bool(
        idx.state.needs_restructure
    )
    if overflowed:
        n_ins = int(jnp.sum((ops.tag == OP_INSERT) | (ops.tag == OP_EXPIRE)))
        grown = shard_restructure(idx, mesh, extra_keys=max(n_ins, 1))
        new_idx, results, stats = shard_apply_ops(
            grown,
            ops,
            mesh,
            routing=routing,
            impl=impl,
            max_results=max_results,
            capacity=capacity,
            has_updates=has_updates,
            has_ranges=has_ranges,
            now=now,
        )
        assert not bool(new_idx.state.needs_restructure), "post-restructure overflow"
    stats = dict(stats)
    stats["restructure_retries"] = int(overflowed)
    stats["a2a_retries"] = a2a_retries
    stats["a2a_overflow_dropped"] = a2a_dropped
    return new_idx, results, stats
