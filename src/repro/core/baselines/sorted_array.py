"""GPU Sorted Array baseline: a single sorted (key, val) array.

Updates are full rebuilds (merge + sort), the classic static-GPU-index
pattern the paper's dynamic structures are measured against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, VAL_DTYPE


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SortedArrayState:
    keys: jax.Array  # [cap] sorted, EMPTY-padded tail
    vals: jax.Array  # [cap]

    def live_keys(self):
        return jnp.sum(self.keys != EMPTY)

    def memory_bytes(self) -> int:
        # rebuild requires a same-size merge buffer; count it (paper counts
        # LSM auxiliary buffers the same way).
        return 2 * (self.keys.size * 4 + self.vals.size * 4)


def build(
    sorted_keys: jax.Array, sorted_vals: jax.Array, capacity: int
) -> SortedArrayState:
    k = jnp.full((capacity,), EMPTY, KEY_DTYPE).at[: sorted_keys.shape[0]].set(
        sorted_keys.astype(KEY_DTYPE)
    )
    v = jnp.zeros((capacity,), VAL_DTYPE).at[: sorted_vals.shape[0]].set(
        sorted_vals.astype(VAL_DTYPE)
    )
    order = jnp.argsort(k, stable=True)
    return SortedArrayState(keys=k[order], vals=v[order])


@jax.jit
def point_query(state: SortedArrayState, queries: jax.Array) -> jax.Array:
    q = queries.astype(KEY_DTYPE)
    pos = jnp.searchsorted(state.keys, q, side="left")
    pos_c = jnp.minimum(pos, state.keys.shape[0] - 1)
    hit = state.keys[pos_c] == q
    return jnp.where(hit, state.vals[pos_c], NOT_FOUND)


@jax.jit
def successor_query(state: SortedArrayState, queries: jax.Array):
    q = queries.astype(KEY_DTYPE)
    pos = jnp.searchsorted(state.keys, q, side="left")
    pos_c = jnp.minimum(pos, state.keys.shape[0] - 1)
    k = state.keys[pos_c]
    found = k != EMPTY
    return jnp.where(found, k, EMPTY), jnp.where(found, state.vals[pos_c], NOT_FOUND)


@jax.jit
def insert(state: SortedArrayState, sorted_keys: jax.Array, sorted_vals: jax.Array):
    """Full rebuild: concat + sort + last-wins dedup (upsert)."""
    allk = jnp.concatenate([state.keys, sorted_keys.astype(KEY_DTYPE)])
    allv = jnp.concatenate([state.vals, sorted_vals.astype(VAL_DTYPE)])
    src = jnp.concatenate(
        [
            jnp.zeros(state.keys.shape[0], jnp.int32),
            jnp.ones(sorted_keys.shape[0], jnp.int32),
        ]
    )
    order = jnp.lexsort((src, allk))
    k_s, v_s = allk[order], allv[order]
    keep = jnp.concatenate([k_s[1:] != k_s[:-1], jnp.array([True])])
    keep &= k_s != EMPTY
    masked = jnp.where(keep, k_s, EMPTY)
    order2 = jnp.argsort(masked, stable=True)
    cap = state.keys.shape[0]
    return SortedArrayState(keys=masked[order2][:cap], vals=v_s[order2][:cap])


@jax.jit
def delete(state: SortedArrayState, sorted_keys: jax.Array):
    """Physical removal + compaction (full rebuild)."""
    dq = sorted_keys.astype(KEY_DTYPE)
    pos = jnp.searchsorted(dq, state.keys, side="left")
    pos_c = jnp.minimum(pos, dq.shape[0] - 1)
    hit = (dq[pos_c] == state.keys) & (state.keys != EMPTY)
    masked = jnp.where(hit, EMPTY, state.keys)
    order = jnp.argsort(masked, stable=True)
    return SortedArrayState(keys=masked[order], vals=state.vals[order])
