"""LSMu: the authors' improved GPU LSM-tree (paper §2.2.1, §5.1).

Design reproduced:
  * fixed chunk size ``b``; level ``i`` holds a sorted run of ``b * 2**i``
    pairs; a batch insert pushes chunks through the binary-counter cascade
    (merge-and-carry), exactly the Ashkiani et al. scheme.
  * **LSMu deletions**: locate the key's *newest* occurrence and set its
    value to ``TOMBSTONE`` in place — no duplicate tombstone pairs are
    inserted (the authors' improvement over the original GPU LSM).
  * queries search levels newest→oldest; the first occurrence decides
    (a TOMBSTONE value ⇒ miss).
  * successor queries must skip stale/tombstoned keys, degrading toward a
    linear scan as deletions accumulate (Figure 13's 69000× effect) — the
    bounded skip loop below reproduces that behavior.
  * merging is not in place: the auxiliary buffer proportional to the
    largest level is charged to the memory footprint (Figure 7d).

The cascade occupancy pattern is a binary counter over pushed chunks, so the
host drives which jitted merge runs — mirroring the real implementation's
host-launched merge kernels.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, VAL_DTYPE

TOMBSTONE = jnp.int32(-2)  # value sentinel: logically deleted


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LSMState:
    # level i arrays have shape [b * 2**i]; EMPTY-padded when unoccupied.
    level_keys: tuple[jax.Array, ...]
    level_vals: tuple[jax.Array, ...]
    occupied: jax.Array  # [L] bool

    @property
    def num_levels(self) -> int:
        return len(self.level_keys)

    @property
    def chunk(self) -> int:
        return self.level_keys[0].shape[0]

    def live_keys(self):
        """Upper bound: occupied slots minus tombstones (stale dups remain)."""
        total = jnp.int32(0)
        for k, v in zip(self.level_keys, self.level_vals):
            total += jnp.sum((k != EMPTY) & (v != TOMBSTONE))
        return total

    def memory_bytes(self) -> int:
        total = 0
        for k in self.level_keys:
            total += 2 * k.size * 4
        # auxiliary merge buffer proportional to the largest level
        total += 2 * self.level_keys[-1].size * 4
        return total


def empty_state(chunk: int, num_levels: int) -> LSMState:
    lk = tuple(
        jnp.full((chunk * 2**i,), EMPTY, KEY_DTYPE) for i in range(num_levels)
    )
    lv = tuple(jnp.zeros((chunk * 2**i,), VAL_DTYPE) for i in range(num_levels))
    return LSMState(level_keys=lk, level_vals=lv, occupied=jnp.zeros(num_levels, bool))


@jax.jit
def _merge_runs(k1, v1, k2, v2):
    """Merge two sorted runs; newer run (k1) wins on duplicate keys."""
    allk = jnp.concatenate([k2, k1])
    allv = jnp.concatenate([v2, v1])
    src = jnp.concatenate(
        [jnp.zeros(k2.shape[0], jnp.int32), jnp.ones(k1.shape[0], jnp.int32)]
    )
    order = jnp.lexsort((src, allk))
    k_s, v_s = allk[order], allv[order]
    keep = jnp.concatenate([k_s[1:] != k_s[:-1], jnp.array([True])])
    keep &= k_s != EMPTY
    masked = jnp.where(keep, k_s, EMPTY)
    order2 = jnp.argsort(masked, stable=True)
    return masked[order2], v_s[order2]


def insert(state: LSMState, sorted_keys: jax.Array, sorted_vals: jax.Array) -> LSMState:
    """Push the batch through the cascade, chunk by chunk (host-driven)."""
    b = state.chunk
    n = sorted_keys.shape[0]
    lk = list(state.level_keys)
    lv = list(state.level_vals)
    occ = [bool(x) for x in state.occupied]
    for c0 in range(0, n, b):
        ck = jnp.full((b,), EMPTY, KEY_DTYPE).at[: min(b, n - c0)].set(
            sorted_keys[c0 : c0 + b].astype(KEY_DTYPE)
        )
        cv = jnp.zeros((b,), VAL_DTYPE).at[: min(b, n - c0)].set(
            sorted_vals[c0 : c0 + b].astype(VAL_DTYPE)
        )
        i = 0
        while i < len(lk) and occ[i]:
            # carry is newer than level i's resident run
            merged_k, merged_v = _merge_runs(ck, cv, lk[i], lv[i])
            lk[i] = jnp.full_like(lk[i], EMPTY)
            occ[i] = False
            ck, cv = merged_k, merged_v
            i += 1
        if i >= len(lk):
            raise RuntimeError("LSM levels exhausted; increase num_levels")
        pad = lk[i].shape[0]
        lk[i] = jnp.full((pad,), EMPTY, KEY_DTYPE).at[: ck.shape[0]].set(ck)
        lv[i] = jnp.zeros((pad,), VAL_DTYPE).at[: cv.shape[0]].set(cv)
        occ[i] = True
    return LSMState(
        level_keys=tuple(lk), level_vals=tuple(lv), occupied=jnp.array(occ)
    )


@jax.jit
def point_query(state: LSMState, queries: jax.Array) -> jax.Array:
    """Search every level, newest (smallest) first; first hit decides."""
    q = queries.astype(KEY_DTYPE)
    result = jnp.full(q.shape, NOT_FOUND, VAL_DTYPE)
    decided = jnp.zeros(q.shape, bool)
    for i in range(state.num_levels):
        lk, lv = state.level_keys[i], state.level_vals[i]
        pos = jnp.searchsorted(lk, q, side="left")
        pos_c = jnp.minimum(pos, lk.shape[0] - 1)
        hit = (lk[pos_c] == q) & state.occupied[i]
        val = lv[pos_c]
        newly = hit & ~decided
        result = jnp.where(newly, jnp.where(val == TOMBSTONE, NOT_FOUND, val), result)
        decided |= hit
    return result


@jax.jit
def delete(state: LSMState, sorted_keys: jax.Array) -> LSMState:
    """In-place tombstone at the key's newest occurrence (LSMu semantics)."""
    dq = sorted_keys.astype(KEY_DTYPE)
    decided = jnp.zeros(dq.shape, bool)
    new_vals = []
    for i in range(state.num_levels):
        lk, lv = state.level_keys[i], state.level_vals[i]
        pos = jnp.searchsorted(lk, dq, side="left")
        pos_c = jnp.minimum(pos, lk.shape[0] - 1)
        hit = (lk[pos_c] == dq) & state.occupied[i] & ~decided
        marks = jnp.zeros(lk.shape, bool).at[pos_c].max(hit)  # race-free OR
        lv = jnp.where(marks, TOMBSTONE, lv)
        decided |= hit
        new_vals.append(lv)
    return LSMState(
        level_keys=state.level_keys,
        level_vals=tuple(new_vals),
        occupied=state.occupied,
    )


@partial(jax.jit, static_argnames=("max_skips",))
def successor_query(state: LSMState, queries: jax.Array, *, max_skips: int = 64):
    """Smallest live key ≥ q.  Each round proposes the min candidate across
    levels, then validates it (newest occurrence not tombstoned).  Dead
    candidates force another round — the per-thread skip scan the paper
    blames for LSMu's successor collapse."""
    q0 = queries.astype(KEY_DTYPE)

    def candidate(q):
        best = jnp.full(q.shape, EMPTY, KEY_DTYPE)
        for i in range(state.num_levels):
            lk = state.level_keys[i]
            pos = jnp.searchsorted(lk, q, side="left")
            pos_c = jnp.minimum(pos, lk.shape[0] - 1)
            k = jnp.where(state.occupied[i], lk[pos_c], EMPTY)
            best = jnp.minimum(best, k)
        return best

    def cond(carry):
        _, done, _, it = carry
        return (~jnp.all(done)) & (it < max_skips)

    def body(carry):
        q, done, res, it = carry
        cand = candidate(q)
        exhausted = cand == EMPTY
        val = point_query(state, cand)  # liveness check (newest occurrence)
        live = (val != NOT_FOUND) & ~exhausted
        res = jnp.where(~done & live, cand, res)
        res = jnp.where(~done & exhausted, EMPTY, res)
        done = done | live | exhausted
        q = jnp.where(done, q, cand + 1)
        return (q, done, res, it + 1)

    init = (
        q0,
        jnp.zeros(q0.shape, bool),
        jnp.full(q0.shape, EMPTY, KEY_DTYPE),
        jnp.int32(0),
    )
    qf, done, res, _ = jax.lax.while_loop(cond, body, init)
    vals = point_query(state, jnp.where(res == EMPTY, 0, res))
    return res, jnp.where(res == EMPTY, NOT_FOUND, vals)
