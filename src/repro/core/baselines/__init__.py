"""JAX implementations of the paper's experimental baselines (§5.1).

* ``sorted_array``  — full-rebuild GPU Sorted Array (merge on insert).
* ``lsm``           — LSMu: the authors' improved GPU LSM-tree (levels +
                      cascade merge, in-place value tombstones, successor).
* ``btree``         — B-link-style tree: same data layer as FliX but queries
                      *traverse an index layer* (the comparison the paper's
                      flipped-indexing claim is about) and updates pay index
                      maintenance.
* ``hash_table``    — Warpcore-style open addressing (fixed capacity, load
                      factor, tombstone deletion, probe-chain misses).
"""

from repro.core.baselines import btree, hash_table, lsm, sorted_array  # noqa: F401
