"""B-tree baseline (paper §2.2.2 — Awad et al.'s GPU B-tree).

This is the *index-layer* counterpoint to FliX: the data layer is identical
(bucketed leaves), but every query must traverse a fanout-``f`` separator
tree root→leaf with one gather per level (the warp-cooperative traversal the
paper's Figure 1a depicts), instead of one searchsorted over the batch.
Updates reuse the leaf-level bulk machinery and then *repair the index
layer* (separator arrays rebuilt from leaf maxes) — the maintenance cost the
flipped paradigm eliminates.

Honesty note (DESIGN.md §3): Awad et al. split nodes proactively in place;
our index repair is a rebuild of the separator arrays.  Traversal cost —
what the paper's query comparisons measure — is faithful; update cost is a
structurally-honest stand-in, reported as such in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.build import build as _flix_build
from repro.core.delete import delete as _flix_delete
from repro.core.insert import insert as _flix_insert, insert_safe as _flix_insert_safe
from repro.core.state import KEY_DTYPE, MAX_VALID, NOT_FOUND, FliXState

FANOUT = 16  # paper uses 15 keys + pointers per 128B node; we use 16 lanes


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BTreeState:
    data: FliXState                 # leaves (bucket chains)
    # levels[0] = root separators ... levels[-1] = lowest internal level.
    # level arrays: [n_nodes_at_level * FANOUT] separator keys, EMPTY-padded.
    levels: tuple[jax.Array, ...]

    def live_keys(self):
        return self.data.live_keys()

    def memory_bytes(self) -> int:
        total = self.data.memory_bytes()
        for lv in self.levels:
            total += lv.size * 4
        return total


def _build_index(mkba: jax.Array) -> tuple[jax.Array, ...]:
    """Separator levels over the leaf fences, bottom-up, fanout FANOUT."""
    levels = []
    cur = mkba
    while cur.shape[0] > 1:
        n_nodes = math.ceil(cur.shape[0] / FANOUT)
        padded = jnp.full((n_nodes * FANOUT,), MAX_VALID, KEY_DTYPE)
        padded = padded.at[: cur.shape[0]].set(cur)
        levels.append(padded)
        cur = padded.reshape(n_nodes, FANOUT)[:, -1]
    return tuple(reversed(levels))  # root first


def build(keys, vals, *, node_size: int = 16, nodes_per_bucket: int = 16) -> BTreeState:
    data = _flix_build(
        keys, vals, node_size=node_size, nodes_per_bucket=nodes_per_bucket
    )
    return BTreeState(data=data, levels=_build_index(data.mkba))


@jax.jit
def point_query(state: BTreeState, queries: jax.Array) -> jax.Array:
    """Root→leaf traversal: one gather + compare-count per level per query."""
    q = queries.astype(KEY_DTYPE)
    node = jnp.zeros(q.shape, jnp.int32)  # node index within current level
    for lv in state.levels:
        seps = lv.reshape(-1, FANOUT)[node]            # [Q, FANOUT] gather
        child = jnp.sum(seps < q[:, None], axis=1)     # compare-count
        node = node * FANOUT + child.astype(jnp.int32)
    leaf = jnp.minimum(node, state.data.num_buckets - 1)

    # leaf probe (same data layer as FliX)
    nmax_rows = state.data.node_max[leaf]
    nidx = jnp.sum(nmax_rows < q[:, None], axis=1).astype(jnp.int32)
    in_leaf = nidx < state.data.num_nodes[leaf]
    nidx_c = jnp.minimum(nidx, state.data.nodes_per_bucket - 1)
    rows = state.data.keys[leaf, nidx_c]
    pos = jnp.sum(rows < q[:, None], axis=1).astype(jnp.int32)
    pos_c = jnp.minimum(pos, state.data.node_size - 1)
    hit = in_leaf & (pos < state.data.node_size) & (
        rows[jnp.arange(q.shape[0]), pos_c] == q
    )
    vals = state.data.vals[leaf, nidx_c, pos_c]
    return jnp.where(hit, vals, NOT_FOUND)


def insert(state: BTreeState, sorted_keys, sorted_vals) -> BTreeState:
    data, _ = _flix_insert(state.data, sorted_keys, sorted_vals)
    if bool(data.needs_restructure):
        data, _ = _flix_insert_safe(state.data, sorted_keys, sorted_vals)
    return BTreeState(data=data, levels=_build_index(data.mkba))


def delete(state: BTreeState, sorted_keys) -> BTreeState:
    data, _ = _flix_delete(state.data, sorted_keys)
    return BTreeState(data=data, levels=_build_index(data.mkba))
