"""Warpcore-style GPU hash table baseline (paper §2.2.3).

Open addressing with linear probing, fixed capacity (initialized at a load
factor, per §5.1 at 80%), tombstone-based deletion (marked, not reclaimed
for probe-chain purposes until reinsertion), no ordered operations.

Batched data-parallel emulation of concurrent insertion: each round, every
unplaced key claims its current probe slot via a scatter-min; losers advance
to the next probe distance.  This mirrors the CAS-retry loop of the real
table at batch granularity.  Tombstone slots are reusable for insertion but
do not terminate probe chains — which is exactly why miss-query performance
degrades after deletion rounds (paper §6.1), an effect our benchmarks show.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, VAL_DTYPE

S_EMPTY, S_FULL, S_TOMB = jnp.int8(0), jnp.int8(1), jnp.int8(2)
_MULT = jnp.uint32(2654435761)  # Knuth multiplicative hash


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HashTableState:
    keys: jax.Array   # [cap] KEY_DTYPE
    vals: jax.Array   # [cap] VAL_DTYPE
    slot: jax.Array   # [cap] int8 state

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    def live_keys(self):
        return jnp.sum(self.slot == S_FULL)

    def memory_bytes(self) -> int:
        return self.keys.size * 4 + self.vals.size * 4 + self.slot.size

    def load_factor(self):
        return jnp.mean((self.slot != S_EMPTY).astype(jnp.float32))


def empty_state(capacity: int) -> HashTableState:
    return HashTableState(
        keys=jnp.full((capacity,), EMPTY, KEY_DTYPE),
        vals=jnp.zeros((capacity,), VAL_DTYPE),
        slot=jnp.zeros((capacity,), jnp.int8),
    )


def _hash(keys: jax.Array, capacity: int) -> jax.Array:
    h = keys.astype(jnp.uint32) * _MULT
    return (h % jnp.uint32(capacity)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("max_probe",))
def insert(
    state: HashTableState, keys: jax.Array, vals: jax.Array, *, max_probe: int = 64
):
    """Batched insert/upsert. Batch must be deduplicated."""
    cap = state.capacity
    k = keys.astype(KEY_DTYPE)
    v = vals.astype(VAL_DTYPE)
    h0 = _hash(k, cap)
    valid = k != EMPTY

    def body(carry):
        tk, tv, ts, placed, dist = carry
        idx = (h0 + dist) % cap
        cur_key = tk[idx]
        cur_state = ts[idx]
        # upsert: same key already resident at this probe slot
        match = (cur_state == S_FULL) & (cur_key == k) & ~placed & valid
        tv = tv.at[jnp.where(match, idx, cap)].set(v, mode="drop")
        placed = placed | match
        # claim empty/tomb slots via scatter-min of the key value
        open_slot = cur_state != S_FULL
        want = open_slot & ~placed & valid
        claims = jnp.full((cap,), EMPTY, KEY_DTYPE)
        claims = claims.at[jnp.where(want, idx, cap)].min(k, mode="drop")
        won = want & (claims[idx] == k)
        tk = tk.at[jnp.where(won, idx, cap)].set(k, mode="drop")
        tv = tv.at[jnp.where(won, idx, cap)].set(v, mode="drop")
        ts = ts.at[jnp.where(won, idx, cap)].set(S_FULL, mode="drop")
        placed = placed | won
        return tk, tv, ts, placed, dist + 1

    def cond(carry):
        *_, placed, dist = carry
        return (~jnp.all(placed)) & (dist < max_probe)

    tk, tv, ts, placed, _ = jax.lax.while_loop(
        cond,
        body,
        (state.keys, state.vals, state.slot, ~valid, jnp.int32(0)),
    )
    return HashTableState(keys=tk, vals=tv, slot=ts), jnp.sum(~placed & valid)


@partial(jax.jit, static_argnames=("max_probe",))
def point_query(state: HashTableState, queries: jax.Array, *, max_probe: int = 64):
    cap = state.capacity
    q = queries.astype(KEY_DTYPE)
    h0 = _hash(q, cap)

    def body(carry):
        res, done, dist = carry
        idx = (h0 + dist) % cap
        ck, cs = state.keys[idx], state.slot[idx]
        hit = (cs == S_FULL) & (ck == q)
        miss = cs == S_EMPTY  # tombstones do NOT stop the probe chain
        res = jnp.where(hit & ~done, state.vals[idx], res)
        done = done | hit | miss
        return res, done, dist + 1

    def cond(carry):
        _, done, dist = carry
        return (~jnp.all(done)) & (dist < max_probe)

    res, done, dist = jax.lax.while_loop(
        cond,
        body,
        (
            jnp.full(q.shape, NOT_FOUND, VAL_DTYPE),
            jnp.zeros(q.shape, bool),
            jnp.int32(0),
        ),
    )
    return res


@partial(jax.jit, static_argnames=("max_probe",))
def delete(state: HashTableState, keys: jax.Array, *, max_probe: int = 64):
    """Tombstone the slot holding each key (marked, not reclaimed)."""
    cap = state.capacity
    k = keys.astype(KEY_DTYPE)
    h0 = _hash(k, cap)

    def body(carry):
        ts, done, dist = carry
        idx = (h0 + dist) % cap
        ck, cs = state.keys[idx], ts[idx]
        hit = (cs == S_FULL) & (ck == k)
        miss = cs == S_EMPTY
        ts = ts.at[jnp.where(hit & ~done, idx, cap)].set(S_TOMB, mode="drop")
        done = done | hit | miss
        return ts, done, dist + 1

    def cond(carry):
        _, done, dist = carry
        return (~jnp.all(done)) & (dist < max_probe)

    ts, done, _ = jax.lax.while_loop(
        cond, body, (state.slot, jnp.zeros(k.shape, bool), jnp.int32(0))
    )
    return HashTableState(keys=state.keys, vals=state.vals, slot=ts)
