"""Tiered residency: beyond-HBM FliX state with real page reclamation.

DESIGN.md §15.  The single-tier engine holds every bucket in one device
pytree, so the index must fit in accelerator memory.  ``TieredFliX`` splits
the same logical state across two tiers:

  * **host tier** — a numpy mirror of every bucket's rows, keyed by bucket
    id (the authoritative copy for non-resident buckets);
  * **device tier** — a *packed* ``FliXState`` holding only the resident
    buckets, in fence order, with the packed ``mkba[-1]`` forced to
    ``MAX_VALID`` so the packed state satisfies I5 on its own.

Residency is *physical placement only*: logical content (canonical triple
bytes, query results, stats) is byte-identical to an unconstrained
single-tier oracle — enforced by ``tests/test_tiered.py`` and invariant I7
(``core.invariants.check_tiered_invariants``).

Every ``apply`` runs a host-side **prefetch pre-pass**
(``core.ops.touched_buckets``) that reuses the engine's own fence routing to
compute which buckets the batch can read or write, promotes exactly those
(page-in), runs the *unchanged* executors (``apply_ops``) against the packed
working set, and demotes down to the device budget after commit (LRU
page-out).  Correctness of running the full-state executors on a packed
subset rests on fence disjointness: a bucket's bytes can only influence ops
routed to it (point/insert/delete/expire), rank arithmetic over an
*interval* of buckets (range — the whole interval is promoted), or the
first-non-empty-bucket fallback (successor — the forward walk up to a
guaranteed-surviving bucket is promoted).  Buckets outside the touched set
pass through ``apply_ops`` untouched up to insert-phase padding-value
scrubbing, which the masked comparison contract ignores (padding values are
unreachable through every read path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import plan_geometry
from repro.core.config import _UNSET, ExecConfig, resolve_config
from repro.core.expiry import NO_EXPIRY
from repro.core.ops import (
    OP_EXPIRE,
    OP_INSERT,
    OpBatch,
    apply_ops,
    touched_buckets,
)
from repro.core.restructure import restructure_grow, restructure_shrink
from repro.core.state import EMPTY, MAX_VALID, FliXState


def bucket_device_bytes(nodes_per_bucket: int, node_size: int, has_exps: bool) -> int:
    """Device bytes one bucket occupies across every per-bucket array."""
    cells = nodes_per_bucket * node_size
    per = cells * 4 * (3 if has_exps else 2)     # keys + vals (+ exps)
    per += nodes_per_bucket * 4 * 2              # node_count + node_max
    per += 4 + 4                                 # num_nodes + mkba
    return per


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@jax.jit
def _bucket_meta(state: FliXState):
    """Per-bucket (live row count, min live expiry deadline) for the packed
    working set — the host metadata refresh after a commit."""
    from repro.core.expiry import bucket_min_exp

    live = jnp.sum(state.node_count, axis=1).astype(jnp.int32)
    return live, bucket_min_exp(state)


@jax.jit
def _take_buckets(state: FliXState, idx: jax.Array) -> FliXState:
    """Packed sub-state holding rows ``idx`` (sorted positions), with the
    packed fence array re-closed at ``MAX_VALID`` (I5)."""
    return FliXState(
        keys=state.keys[idx],
        vals=state.vals[idx],
        node_count=state.node_count[idx],
        node_max=state.node_max[idx],
        num_nodes=state.num_nodes[idx],
        mkba=state.mkba[idx].at[-1].set(MAX_VALID),
        needs_restructure=state.needs_restructure,
        exps=None if state.exps is None else state.exps[idx],
    )


def _host_build(keys, vals, exps=None, *, node_size=32, nodes_per_bucket=16, fill=0.5):
    """Numpy mirror of ``checkpoint.serialize.state_from_pairs`` — the same
    deterministic half-full layout, built entirely on the host.

    This is what lets recovery of a tiered index avoid materializing the
    full structure on device: the snapshot's sorted live triples become the
    host-tier mirror directly.  Byte-exact with the device build because
    canonical triples are clean (no padding garbage to propagate): every
    padding cell is EMPTY/0/NO_EXPIRY in both.
    """
    keys = np.asarray(keys, np.int32)
    vals = np.asarray(vals, np.int32)
    if exps is not None:
        exps = np.asarray(exps, np.int32)
        if not (exps != int(NO_EXPIRY)).any():
            exps = None
    nb, npb, ns = plan_geometry(
        len(keys), node_size=node_size, nodes_per_bucket=nodes_per_bucket, fill=fill
    )
    nb = -(-nb // 8) * 8  # same jit-cache quantization as state_from_pairs
    p = max(1, int(ns * fill))

    def one_plane(col, background):
        flat = np.full((nb * p,), background, np.int32)
        take = min(len(col), nb * p)
        flat[:take] = col[:take]
        plane = np.full((nb, npb, ns), background, np.int32)
        plane[:, 0, :p] = flat.reshape(nb, p)
        return plane

    k3 = one_plane(keys, int(EMPTY))
    v3 = one_plane(vals, 0)
    bkeys = k3[:, 0, :p]
    counts0 = (bkeys != int(EMPTY)).sum(axis=1).astype(np.int32)
    node_count = np.zeros((nb, npb), np.int32)
    node_count[:, 0] = counts0
    nmax0 = np.where(
        counts0 > 0, bkeys[np.arange(nb), np.maximum(counts0 - 1, 0)], int(EMPTY)
    ).astype(np.int32)
    node_max = np.full((nb, npb), int(EMPTY), np.int32)
    node_max[:, 0] = nmax0
    num_nodes = (counts0 > 0).astype(np.int32)
    mkba = np.where(counts0 > 0, nmax0, int(MAX_VALID)).astype(np.int32)
    mkba[-1] = int(MAX_VALID)
    mkba = np.maximum.accumulate(mkba)

    e3 = None
    if exps is not None:
        e3 = one_plane(exps, int(NO_EXPIRY))
        e3 = np.where(k3 == int(EMPTY), int(NO_EXPIRY), e3).astype(np.int32)
    return k3, v3, node_count, node_max, num_nodes, mkba, e3


class _HostView:
    """Duck-typed read-only state over host numpy arrays.

    ``checkpoint.serialize.bucket_segments`` and
    ``core.invariants.check_invariants`` only access array attributes (and
    ``jax.device_get``/``np.asarray`` are identity on numpy), so this stands
    in for a ``FliXState`` without a device round-trip.
    """

    def __init__(self, keys, vals, node_count, node_max, num_nodes, mkba, exps):
        self.keys = keys
        self.vals = vals
        self.node_count = node_count
        self.node_max = node_max
        self.num_nodes = num_nodes
        self.mkba = mkba
        self.exps = exps
        self.needs_restructure = np.asarray(False)


class TieredFliX:
    """Host-driven tiered engine: a FliX index whose device footprint is
    bounded by ``budget_bytes`` while the full index lives in host memory.

    Mutating companion class in the style of ``checkpoint.durable
    .DurableFliX`` (NOT a pytree): methods mutate ``self`` and return
    results.  The authority split is the core invariant (I7):

      * buckets in ``resident_ids`` are authoritative **on device** (the
        mirror rows for them may be stale until ``sync()``);
      * every other bucket is authoritative **in the mirror**;
      * per-bucket metadata (``h_live``, ``h_min_exp``) is fresh for ALL
        buckets at all times (refreshed from the packed state post-commit).

    ``budget_bytes=None`` means unbounded (everything may become resident —
    still packed/demand-paged, but never evicted).
    """

    def __init__(
        self,
        keys,
        vals,
        node_count,
        node_max,
        num_nodes,
        mkba,
        exps=None,
        *,
        budget_bytes: int | None = None,
        needs_restructure: bool = False,
    ):
        # owned, writable copies: device_get may hand back read-only views
        self.h_keys = np.array(keys, dtype=np.int32, order="C", copy=True)
        self.h_vals = np.array(vals, dtype=np.int32, order="C", copy=True)
        self.h_node_count = np.array(node_count, dtype=np.int32, order="C", copy=True)
        self.h_node_max = np.array(node_max, dtype=np.int32, order="C", copy=True)
        self.h_num_nodes = np.array(num_nodes, dtype=np.int32, order="C", copy=True)
        self.h_mkba = np.array(mkba, dtype=np.int32, order="C", copy=True)
        self.h_exps = (
            None if exps is None else np.array(exps, dtype=np.int32, order="C", copy=True)
        )
        self.needs_restructure = bool(needs_restructure)
        self.budget_bytes = budget_bytes

        nb = self.h_keys.shape[0]
        self.resident_ids = np.zeros((0,), np.int32)
        self._packed: FliXState | None = None
        self.last_used = np.zeros((nb,), np.int64)
        self._step = 0
        self.promoted_total = 0
        self.demoted_total = 0
        self.reclaimed_total = 0
        self._recompute_meta()

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_state(cls, state: FliXState, *, budget_bytes: int | None = None):
        """Adopt an existing single-tier device state (one full page-out)."""
        st = state.drop_volatile()
        host = jax.device_get(
            (st.keys, st.vals, st.node_count, st.node_max, st.num_nodes, st.mkba)
        )
        exps = None if st.exps is None else np.asarray(jax.device_get(st.exps))
        return cls(
            *host,
            exps,
            budget_bytes=budget_bytes,
            needs_restructure=bool(st.needs_restructure),
        )

    @classmethod
    def from_pairs(
        cls,
        keys,
        vals,
        exps=None,
        *,
        node_size: int = 32,
        nodes_per_bucket: int = 16,
        fill: float = 0.5,
        budget_bytes: int | None = None,
    ):
        """Rebuild from sorted live triples without ever materializing the
        full index on device (host-tier recovery path; byte-identical to
        ``state_from_pairs``)."""
        k3, v3, nc, nm, nn, mk, e3 = _host_build(
            keys,
            vals,
            exps,
            node_size=node_size,
            nodes_per_bucket=nodes_per_bucket,
            fill=fill,
        )
        return cls(k3, v3, nc, nm, nn, mk, e3, budget_bytes=budget_bytes)

    # ---- geometry / accounting -------------------------------------------
    @property
    def num_buckets(self) -> int:
        return self.h_keys.shape[0]

    @property
    def geometry(self) -> tuple[int, int, int]:
        return self.h_keys.shape

    @property
    def node_size(self) -> int:
        return self.h_keys.shape[2]

    @property
    def nodes_per_bucket(self) -> int:
        return self.h_keys.shape[1]

    @property
    def bucket_bytes(self) -> int:
        return bucket_device_bytes(
            self.nodes_per_bucket, self.node_size, self.h_exps is not None
        )

    @property
    def budget_buckets(self) -> int:
        """Resident-set cap in buckets (≥ 1: one bucket must always fit)."""
        nb = self.num_buckets
        if self.budget_bytes is None:
            return nb
        return min(nb, max(1, int(self.budget_bytes) // self.bucket_bytes))

    def memory_bytes_resident(self) -> int:
        """Device-tier footprint (the budget-governed quantity of I7)."""
        return len(self.resident_ids) * self.bucket_bytes

    def live_keys(self) -> int:
        return int(self.h_live.sum())

    # ---- metadata --------------------------------------------------------
    def _recompute_meta(self):
        """Full metadata recompute from the mirror (mirror must be synced)."""
        self.h_live = self.h_node_count.sum(axis=1).astype(np.int32)
        if self.h_exps is None:
            self.h_min_exp = np.full((self.num_buckets,), int(NO_EXPIRY), np.int32)
        else:
            self.h_min_exp = np.where(
                self.h_keys != int(EMPTY), self.h_exps, int(NO_EXPIRY)
            ).min(axis=(1, 2)).astype(np.int32)

    def _refresh_meta(self, ids: np.ndarray):
        """Refresh metadata for the packed working set from device."""
        if self._packed is None or len(ids) == 0:
            return
        live, min_exp = jax.device_get(_bucket_meta(self._packed))
        self.h_live[ids] = live
        self.h_min_exp[ids] = min_exp

    # ---- residency plumbing ----------------------------------------------
    def sync(self):
        """Page resident bucket rows back into the mirror (keeps residency).

        After this the mirror is authoritative for every bucket — the basis
        for host-side serialization, invariant checking, and restructure.
        """
        if self._packed is None or len(self.resident_ids) == 0:
            return
        st = self._packed
        ids = self.resident_ids
        k, v, nc, nm, nn = jax.device_get(
            (st.keys, st.vals, st.node_count, st.node_max, st.num_nodes)
        )
        self.h_keys[ids] = k
        self.h_vals[ids] = v
        self.h_node_count[ids] = nc
        self.h_node_max[ids] = nm
        self.h_num_nodes[ids] = nn
        if st.exps is not None:
            if self.h_exps is None:
                self.h_exps = np.full(self.h_keys.shape, int(NO_EXPIRY), np.int32)
            self.h_exps[ids] = np.asarray(jax.device_get(st.exps))
        # NEVER the packed mkba: its last entry is forced to MAX_VALID.

    def _gather(self, ids: np.ndarray) -> FliXState:
        """Upload mirror rows ``ids`` (sorted) as a packed device state."""
        mk = self.h_mkba[ids].copy()
        mk[-1] = int(MAX_VALID)
        return FliXState(
            keys=jnp.asarray(self.h_keys[ids]),
            vals=jnp.asarray(self.h_vals[ids]),
            node_count=jnp.asarray(self.h_node_count[ids]),
            node_max=jnp.asarray(self.h_node_max[ids]),
            num_nodes=jnp.asarray(self.h_num_nodes[ids]),
            mkba=jnp.asarray(mk),
            needs_restructure=jnp.asarray(self.needs_restructure),
            exps=None if self.h_exps is None else jnp.asarray(self.h_exps[ids]),
        )

    def _pad_working_set(self, ids: np.ndarray) -> np.ndarray:
        """Quantize the working set to min(nb, pow2) distinct buckets so the
        executors trace a bounded number of packed shapes."""
        nb = self.num_buckets
        target = min(nb, _pow2_ceil(max(len(ids), 1)))
        if target <= len(ids):
            return ids
        cold = np.setdiff1d(np.arange(nb, dtype=np.int32), ids, assume_unique=True)
        return np.sort(np.concatenate([ids, cold[: target - len(ids)]]))

    def _evict_to_budget(self) -> int:
        """LRU page-out down to the device budget (I7, post-commit)."""
        r = self.budget_buckets
        ids = self.resident_ids
        if len(ids) <= r or self._packed is None:
            return 0
        # keep the R most recently used (ties → lower bucket id)
        order = np.lexsort((ids, -self.last_used[ids]))
        kept = np.sort(ids[order[:r]])
        evicted = np.sort(ids[order[r:]])
        st = self._packed
        evict_pos = np.searchsorted(ids, evicted).astype(np.int32)
        k, v, nc, nm, nn = jax.device_get(
            (
                st.keys[evict_pos],
                st.vals[evict_pos],
                st.node_count[evict_pos],
                st.node_max[evict_pos],
                st.num_nodes[evict_pos],
            )
        )
        self.h_keys[evicted] = k
        self.h_vals[evicted] = v
        self.h_node_count[evicted] = nc
        self.h_node_max[evicted] = nm
        self.h_num_nodes[evicted] = nn
        if st.exps is not None:
            if self.h_exps is None:
                self.h_exps = np.full(self.h_keys.shape, int(NO_EXPIRY), np.int32)
            self.h_exps[evicted] = np.asarray(jax.device_get(st.exps[evict_pos]))
        kept_pos = jnp.asarray(np.searchsorted(ids, kept).astype(np.int32))
        self._packed = _take_buckets(st, kept_pos)
        self.resident_ids = kept
        self.demoted_total += len(evicted)
        return len(evicted)

    # ---- the engine ------------------------------------------------------
    def apply(
        self,
        ops: OpBatch,
        *,
        config: "ExecConfig | None" = None,
        now: int | None = None,
        commit: bool = True,
        max_results=_UNSET,
        impl=_UNSET,
    ):
        """Prefetch → promote → run the unchanged executors → demote.

        Returns ``(results, stats, restructured)``; mutates ``self``.
        Execution strategy comes in as one ``config=ExecConfig(...)``
        forwarded to the inner ``apply_ops`` (``max_results`` / ``impl``
        are deprecated warn-once shims).  ``commit=False`` runs a read-only
        batch: promotion/demotion still happen (residency is physical
        placement, not logical content) but the post-apply packed bytes are
        discarded — required for expiring reads that must not physically
        reclaim rows.
        """
        cfg = resolve_config(
            "TieredFliX.apply", config, max_results=max_results, impl=impl
        )
        # this engine replays batches on overflow and keeps the packed bytes
        # as its own working set: never donate
        cfg = cfg.replace(donate=False)
        tag, key, val, _ = ops.to_host()
        touched = touched_buckets(
            self.h_mkba,
            tag,
            key,
            val,
            live=self.h_live,
            min_exp=self.h_min_exp,
            now=now,
        )
        t_ids = np.nonzero(touched)[0].astype(np.int32)
        self._step += 1
        self.last_used[t_ids] = self._step

        promoted = 0
        s_ids = self.resident_ids
        if self._packed is not None and np.isin(
            t_ids, s_ids, assume_unique=True
        ).all():
            w_ids = s_ids  # fast path: zero transfers
            packed = self._packed
        else:
            self.sync()
            w_ids = np.union1d(s_ids, t_ids).astype(np.int32)
            w_ids = self._pad_working_set(w_ids)
            promoted = int(len(w_ids) - len(s_ids))
            packed = self._gather(w_ids)
        self.promoted_total += promoted

        new_packed, results, stats = apply_ops(packed, ops, config=cfg, now=now)
        stats = dict(stats)
        restructured = False
        reclaimed = 0

        overflow = bool(new_packed.needs_restructure) and not self.needs_restructure
        if overflow and commit:
            # bucket overflow: the overflowed result is untrustworthy (same
            # contract as apply_ops_safe) — regrow the PRE-batch state from a
            # full materialization and replay.  This is the one tiered
            # operation that transiently needs the whole index on device
            # (same cost class as the paper's restructure relaunch).
            self.resident_ids = w_ids
            self._packed = packed
            full = self.materialize()
            before = full.memory_bytes()
            n_ins = int(((tag == OP_INSERT) | (tag == OP_EXPIRE)).sum())
            grown = restructure_grow(full, extra_keys=max(n_ins, 1))
            new_full, results, stats = apply_ops(grown, ops, config=cfg, now=now)
            assert not bool(new_full.needs_restructure), "post-restructure overflow"
            stats = dict(stats)
            self._install_full(new_full)
            reclaimed = max(0, before - new_full.memory_bytes())
            self.reclaimed_total += reclaimed
            restructured = True
        elif commit:
            self._packed = new_packed
            self.resident_ids = w_ids
            self.needs_restructure = bool(new_packed.needs_restructure)
            if self.h_exps is None and new_packed.exps is not None:
                # TTL plane materialized mid-stream (first batch with exps)
                self.h_exps = np.full(self.h_keys.shape, int(NO_EXPIRY), np.int32)
            self._refresh_meta(w_ids)
        else:
            # read-only: retain the pre-apply packed bytes
            self._packed = packed
            self.resident_ids = w_ids

        demoted = self._evict_to_budget()
        stats["restructure_retries"] = int(restructured)
        stats["promoted"] = promoted
        stats["demoted"] = demoted
        stats["resident_bytes"] = self.memory_bytes_resident()
        stats["reclaimed_bytes"] = reclaimed
        return results, stats, restructured

    # ---- full-state transitions ------------------------------------------
    def materialize(self) -> FliXState:
        """The full single-tier device state (restructure/tests only — this
        is exactly the allocation the tiered engine otherwise avoids)."""
        self.sync()
        return FliXState(
            keys=jnp.asarray(self.h_keys),
            vals=jnp.asarray(self.h_vals),
            node_count=jnp.asarray(self.h_node_count),
            node_max=jnp.asarray(self.h_node_max),
            num_nodes=jnp.asarray(self.h_num_nodes),
            mkba=jnp.asarray(self.h_mkba),
            needs_restructure=jnp.asarray(self.needs_restructure),
            exps=None if self.h_exps is None else jnp.asarray(self.h_exps),
        )

    def _install_full(self, state: FliXState):
        """Replace the whole logical state (post-restructure): page
        everything out to the mirror and reset residency."""
        st = state.drop_volatile()
        k, v, nc, nm, nn, mk = jax.device_get(
            (st.keys, st.vals, st.node_count, st.node_max, st.num_nodes, st.mkba)
        )
        self.h_keys = np.array(k, np.int32, copy=True)
        self.h_vals = np.array(v, np.int32, copy=True)
        self.h_node_count = np.array(nc, np.int32, copy=True)
        self.h_node_max = np.array(nm, np.int32, copy=True)
        self.h_num_nodes = np.array(nn, np.int32, copy=True)
        self.h_mkba = np.array(mk, np.int32, copy=True)
        self.h_exps = (
            None
            if st.exps is None
            else np.array(jax.device_get(st.exps), np.int32, copy=True)
        )
        self.needs_restructure = bool(st.needs_restructure)
        self.resident_ids = np.zeros((0,), np.int32)
        self._packed = None
        self.last_used = np.zeros((self.num_buckets,), np.int64)
        self._recompute_meta()

    def compact(self, *, fill: float = 0.5) -> int:
        """Shrink to the smallest geometry for the live set and reclaim the
        freed pages.  Returns reclaimed bytes."""
        full = self.materialize()
        new, reclaimed = restructure_shrink(full, fill=fill)
        self._install_full(new)
        self.reclaimed_total += reclaimed
        return reclaimed

    # ---- durability / inspection hooks -----------------------------------
    def host_view(self) -> _HostView:
        """Synced read-only numpy view (serialization & invariants)."""
        self.sync()
        return _HostView(
            self.h_keys,
            self.h_vals,
            self.h_node_count,
            self.h_node_max,
            self.h_num_nodes,
            self.h_mkba,
            self.h_exps,
        )

    def expired_buckets(self, now: int) -> np.ndarray:
        """Bucket ids holding at least one live row with deadline ≤ now
        (metadata-only: no device scan, no transfer)."""
        return np.nonzero(self.h_min_exp <= np.int32(now))[0]
