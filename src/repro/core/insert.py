"""Bulk insertion (paper §4.2/4.3, Figure 3b, Table 2 — TL-Bulk semantics).

Per bucket, in one shot (vmapped over buckets; the Pallas kernel form keeps
the bucket stripe in VMEM):

  1. *pull* the bucket's sublist from the sorted update batch (flipped
     indexing: boundaries from ``batch.bucket_slices``),
  2. merge it with the bucket's chain content (upsert: an incoming duplicate
     key overwrites the stored value — the paper's "if not present, insert"
     plus rowID update),
  3. re-chunk each *original node region* into ``ceil(m_j / node_size)``
     balanced pieces.  A region that still fits keeps its node untouched
     (same keys, same boundary); an overflowing region splits into
     even pieces — the batched fixed point of the paper's split-in-half rule.
     Regions are never merged by insertion (merging is restructuring's job),
     so underfull-node accounting matches the paper's.

TPU adaptation note (DESIGN.md §3): the whole bucket stripe is one VMEM
block, so rewriting the stripe costs the same DMA as editing one node — the
paper's node-local shift-right optimization targets GPU cache lines, which do
not exist here.  What we keep is the *work assignment* (compute→bucket) and
the *node-level structure* (bounded nodes, splits, chain order).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.batch import bucket_slices, gather_kv_sublists
from repro.core.state import (
    EMPTY,
    KEY_DTYPE,
    VAL_DTYPE,
    FliXState,
    flatten_bucket_sorted,
)


def _merge_one_bucket(
    ck, cv, ik, iv, onm, onn, *, node_size: int, nodes_per_bucket: int
):
    """Merge one bucket's content (ck/cv) with its incoming sublist (ik/iv).

    Returns new (keys [npb, ns], vals, overflow flag).  All shapes static.
    """
    ns, npb = node_size, nodes_per_bucket
    # upsert-dedup *before* the sort: both sides are sorted with EMPTY tails
    # and unique valid keys, so a stripe key that reappears in the incoming
    # sublist is found by one binary search.  Masking those to EMPTY up front
    # collapses the old two-pass form (lexsort by (key, source) followed by a
    # full argsort of the masked keys) into a single stable sort.
    pos = jnp.searchsorted(ik, ck, side="left")
    pos_c = jnp.minimum(pos, ik.shape[0] - 1)
    dup = (ik[pos_c] == ck) & (ck != EMPTY)    # incoming value wins
    allk = jnp.concatenate([jnp.where(dup, EMPTY, ck), ik])
    allv = jnp.concatenate([cv, iv])
    order = jnp.argsort(allk, stable=True)     # the single sort pass
    mk = allk[order]                           # merged keys, EMPTY tail
    mv = allv[order]
    L = mk.shape[0]
    valid = mk != EMPTY
    m_total = jnp.sum(valid).astype(jnp.int32)

    # --- original node regions -------------------------------------------
    # region j covers (onm[j-1], onm[j]]; keys above the last active node's
    # max fall into the last region (the paper: last node's maxKey grows).
    r = jnp.searchsorted(onm, mk, side="left").astype(jnp.int32)
    r = jnp.minimum(r, jnp.maximum(onn - 1, 0))
    r = jnp.where(valid, r, npb - 1)

    m_j = jnp.zeros((npb,), jnp.int32).at[r].add(valid.astype(jnp.int32))
    s_j = (m_j + ns - 1) // ns                # pieces per region
    f_j = jnp.cumsum(m_j) - m_j               # first rank of region
    base_j = jnp.cumsum(s_j) - s_j            # first output slot of region
    total_new = jnp.sum(s_j).astype(jnp.int32)

    rank = jnp.arange(L, dtype=jnp.int32) - f_j[r]
    m_r = jnp.maximum(m_j[r], 1)
    s_r = jnp.maximum(s_j[r], 1)
    piece = (rank * s_r) // m_r
    piece_start = (piece * m_r + s_r - 1) // s_r
    pos = rank - piece_start
    slot = base_j[r] + piece

    dump = npb * ns
    dest = jnp.where(valid & (slot < npb), slot * ns + pos, dump)
    nk = jnp.full((npb * ns + 1,), EMPTY, KEY_DTYPE).at[dest].set(mk)
    nv = jnp.zeros((npb * ns + 1,), VAL_DTYPE).at[dest].set(mv)
    overflow = total_new > npb
    return (
        nk[:-1].reshape(npb, ns),
        nv[:-1].reshape(npb, ns),
        overflow,
        total_new,
        m_total,
    )


def insert_with_slices(
    state: FliXState,
    sorted_keys: jax.Array,
    sorted_vals: jax.Array,
    starts: jax.Array,
    ends: jax.Array,
):
    """Bulk-insert with precomputed per-bucket slice boundaries.

    The routing (``starts``/``ends`` into the sorted batch) is supplied by
    the caller: :func:`insert` computes it with ``bucket_slices``; the mixed
    batch engine (``core.ops.apply_ops``) derives it from its *single*
    routing of the whole mixed batch via prefix counts.  Both paths hit this
    identical merge code, which is what makes mixed execution byte-identical
    to per-type execution.
    """
    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    cap = state.bucket_capacity
    keys_in = sorted_keys.astype(KEY_DTYPE)
    vals_in = sorted_vals.astype(VAL_DTYPE)

    ik, iv, counts, true_counts = gather_kv_sublists(
        keys_in, vals_in, starts, ends, cap
    )

    ck, cv = flatten_bucket_sorted(state)

    nk, nv, overflow, total_new, m_total = jax.vmap(
        partial(_merge_one_bucket, node_size=ns, nodes_per_bucket=npb)
    )(ck, cv, ik, iv, state.node_max, state.num_nodes)

    slice_overflow = true_counts > cap
    any_overflow = jnp.any(overflow) | jnp.any(slice_overflow)

    node_count = jnp.sum(nk != EMPTY, axis=2).astype(jnp.int32)
    node_max = jnp.where(
        node_count > 0,
        jnp.take_along_axis(
            nk, jnp.maximum(node_count - 1, 0)[..., None], axis=2
        )[..., 0],
        EMPTY,
    ).astype(KEY_DTYPE)
    num_nodes = jnp.sum(node_count > 0, axis=1).astype(jnp.int32)

    new_state = FliXState(
        keys=nk,
        vals=nv,
        node_count=node_count,
        node_max=node_max,
        num_nodes=num_nodes,
        mkba=state.mkba,  # fences fixed until restructuring (paper §3.2)
        needs_restructure=state.needs_restructure | any_overflow,
    )
    stats = {
        "inserted": jnp.sum(jnp.minimum(true_counts, cap)),
        "nodes_after": jnp.sum(num_nodes),
        "splits": jnp.sum(jnp.maximum(num_nodes - state.num_nodes, 0)),
        "overflowed_buckets": jnp.sum(overflow | slice_overflow),
    }
    return new_state, stats


@jax.jit
def insert(state: FliXState, sorted_keys: jax.Array, sorted_vals: jax.Array):
    """Bulk-insert a sorted, deduplicated batch. Returns (state', stats).

    If any bucket overflows its capacity, the returned state's
    ``needs_restructure`` flag is set and *that bucket's contents are not
    trustworthy* — callers use :func:`insert_safe` (or check the flag and
    retry on the original state after restructuring).  ``insert`` itself
    never mutates its input (functional), so retry is always clean.
    """
    starts, ends = bucket_slices(state, sorted_keys.astype(KEY_DTYPE))
    return insert_with_slices(state, sorted_keys, sorted_vals, starts, ends)


def insert_safe(state: FliXState, sorted_keys, sorted_vals):
    """Host-level driver: insert, restructure-and-retry on overflow.

    This is the paper's contract — restructuring is the capacity-management
    mechanism (§3.5); overflow pressure triggers it.  Host-driven because the
    new geometry changes static shapes (like a GPU-side realloc + rebuild).
    """
    from repro.core.restructure import restructure_grow

    new_state, stats = insert(state, sorted_keys, sorted_vals)
    if bool(new_state.needs_restructure):
        n_incoming = int(jnp.sum(sorted_keys != EMPTY))
        grown = restructure_grow(state, extra_keys=n_incoming)
        new_state, stats = insert(grown, sorted_keys, sorted_vals)
        # Geometry from restructure_grow always fits the merged content.
        assert not bool(new_state.needs_restructure), "post-restructure overflow"
    return new_state, stats
