"""Flipped query execution (paper §3.3, Figure 4).

The sorted query batch replaces the index layer: bucket slice boundaries come
from one vectorized searchsorted (``batch.bucket_slices``); inside a bucket,
node location and in-node position are *compare-and-count* reductions — the
TPU analogue of the paper's tile threads each owning one key and voting.

Two execution forms with identical semantics:
  * ``point_query`` / ``successor_query``: fully vectorized jnp (the oracle
    form; also what the CPU benchmarks run).
  * ``kernels/flix_query.py``: the Pallas compute-to-bucket kernel (grid maps
    to bucket blocks, each pulls its query slice).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, NOT_FOUND, FliXState


def _locate(state: FliXState, queries: jax.Array):
    """For each query: (bucket, node-slot, in-node position, key-at-position).

    node-slot is the first active node whose maxKey ≥ q (compare-count over
    the node_max row; inactive slots hold EMPTY so they never match first).
    """
    b = jnp.searchsorted(state.mkba, queries, side="left").astype(jnp.int32)
    b = jnp.minimum(b, state.num_buckets - 1)
    nmax_rows = state.node_max[b]                       # [Q, npb]
    nidx = jnp.sum(nmax_rows < queries[:, None], axis=1).astype(jnp.int32)
    in_bucket = nidx < state.num_nodes[b]
    nidx_c = jnp.minimum(nidx, state.nodes_per_bucket - 1)
    rows = state.keys[b, nidx_c]                        # [Q, ns]
    pos = jnp.sum(rows < queries[:, None], axis=1).astype(jnp.int32)
    pos_c = jnp.minimum(pos, state.node_size - 1)
    key_at = rows[jnp.arange(queries.shape[0]), pos_c]
    return b, nidx_c, pos_c, key_at, in_bucket, pos


@jax.jit
def point_query(state: FliXState, sorted_queries: jax.Array) -> jax.Array:
    """Point lookups for a sorted query batch. Misses return NOT_FOUND."""
    q = sorted_queries.astype(KEY_DTYPE)
    b, nidx, pos, key_at, in_bucket, raw_pos = _locate(state, q)
    hit = in_bucket & (raw_pos < state.node_size) & (key_at == q)
    vals = state.vals[b, nidx, pos]
    return jnp.where(hit, vals, NOT_FOUND)


def _suffix_min_with_index(g: jax.Array):
    """suffix_min[i] = min(g[i:]), plus the index attaining it."""
    n = g.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    def combine(a, b):
        av, ai = a
        bv, bi = b
        take_a = av <= bv
        return jnp.where(take_a, av, bv), jnp.where(take_a, ai, bi)

    rv, ri = jax.lax.associative_scan(combine, (g[::-1], idx[::-1]))
    return rv[::-1], ri[::-1]


def _successor_fence_rows(state: FliXState):
    """Padded suffix-min rows over per-bucket minimum present keys.

    ``smin_pad[b+1]`` is the smallest key stored in any bucket after ``b``
    (EMPTY if none) and ``sidx_pad[b+1]`` the bucket attaining it — the
    successor fallback for queries past their bucket's largest present key.
    """
    bucket_min = jnp.where(state.num_nodes > 0, state.keys[:, 0, 0], EMPTY)  # [nb]
    smin, sidx = _suffix_min_with_index(bucket_min)
    smin_pad = jnp.concatenate([smin, jnp.array([EMPTY], KEY_DTYPE)])
    sidx_pad = jnp.concatenate([sidx, jnp.array([0], jnp.int32)])
    return smin_pad, sidx_pad


_successor_fence_rows_jit = jax.jit(_successor_fence_rows)


def with_successor_cache(state: FliXState) -> FliXState:
    """Return ``state`` carrying the successor suffix-scan cache.

    Read-only query streams call this once and reuse the returned state, so
    every subsequent :func:`successor_query` skips the O(nb) ``bucket_min``
    rebuild + suffix scan.  Mutating operations construct their result state
    without the cache fields, which is the invalidation rule — no flags to
    maintain.  Idempotent.
    """
    if state.succ_smin is not None:
        return state
    smin_pad, sidx_pad = _successor_fence_rows_jit(state)
    return dataclasses.replace(state, succ_smin=smin_pad, succ_sidx=sidx_pad)


@jax.jit
def successor_query(state: FliXState, sorted_queries: jax.Array):
    """Smallest stored key ≥ q (and its value); (EMPTY, NOT_FOUND) if none.

    In-bucket path: compare-count as in point queries.  Out-of-bucket path
    (bucket's largest *present* key < q): suffix-min over per-bucket minimum
    present keys gives the next non-empty bucket in O(1) per query.  A state
    carrying the :func:`with_successor_cache` rows skips that O(nb) scan
    (the branch is structural, so each form jits separately).
    """
    q = sorted_queries.astype(KEY_DTYPE)
    nb, npb = state.num_buckets, state.nodes_per_bucket
    b = jnp.searchsorted(state.mkba, q, side="left").astype(jnp.int32)
    b = jnp.minimum(b, nb - 1)

    # in-bucket candidate
    nmax_rows = state.node_max[b]
    nidx = jnp.sum(nmax_rows < q[:, None], axis=1).astype(jnp.int32)
    in_bucket = nidx < state.num_nodes[b]
    nidx_c = jnp.minimum(nidx, npb - 1)
    rows = state.keys[b, nidx_c]
    pos = jnp.sum(rows < q[:, None], axis=1).astype(jnp.int32)
    pos_c = jnp.minimum(pos, state.node_size - 1)
    in_key = rows[jnp.arange(q.shape[0]), pos_c]
    in_val = state.vals[b, nidx_c, pos_c]

    # out-of-bucket candidate: first non-empty bucket after b
    if state.succ_smin is not None:
        smin_pad, sidx_pad = state.succ_smin, state.succ_sidx
    else:
        smin_pad, sidx_pad = _successor_fence_rows(state)
    out_key = smin_pad[b + 1]
    out_bucket = sidx_pad[b + 1]
    out_val = state.vals[out_bucket, 0, 0]

    use_in = in_bucket & (pos < state.node_size)
    succ_key = jnp.where(use_in, in_key, out_key)
    succ_val = jnp.where(use_in, in_val, out_val)
    found = succ_key != EMPTY
    return succ_key, jnp.where(found, succ_val, NOT_FOUND)


# ---------------------------------------------------------------------------
# Dense half-open range machinery (the RANGE batch op, DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# A RANGE op carries ``[lo, hi)`` and the batch carries one static
# ``max_results`` output budget.  All three executors — the jnp reference
# phase, the standalone two-pass kernel (``kernels/flix_range``), and the
# fused apply kernel (``kernels/flix_apply``) — share the formulas below so
# the output contract cannot diverge: per-op *full* in-range counts are
# exclusive-scanned into densely packed output offsets (earlier sorted ops
# win the budget, each op emits a prefix of its smallest in-range keys), and
# every output slot resolves to one global key rank.


def flat_rank(flat_k: jax.Array, pref: jax.Array, mkba: jax.Array, q: jax.Array):
    """Global rank (count of stored keys < q) per query, from per-bucket
    sorted rows ``flat_k`` [nb, cap] and live-count prefix sums ``pref``
    [nb+1].  One searchsorted to the owning bucket + one compare-count row."""
    nb = flat_k.shape[0]
    b = jnp.minimum(
        jnp.searchsorted(mkba, q.astype(KEY_DTYPE), side="left"), nb - 1
    ).astype(jnp.int32)
    p = jnp.sum(flat_k[b] < q[:, None], axis=1).astype(jnp.int32)
    return pref[b] + p


def range_offsets(full: jax.Array, is_range: jax.Array, max_results: int):
    """Deterministic budget split: exclusive-scan the full counts (sorted
    batch order), clamp to the budget.  Returns ``(start, emit, total_emit,
    truncated)`` — op i's results land at ``[start[i], start[i]+emit[i])``,
    segments tile ``[0, total_emit)`` consecutively, and ``truncated`` counts
    the range ops whose full result set did not fit."""
    full = jnp.where(is_range, full, 0).astype(jnp.int32)
    # guard the int32 scan: any count > budget behaves identically to
    # budget+1 (start/emit are budget-clamped and emit < budget+1 still
    # flags truncation), and the clamp bounds the running sum by
    # N·(budget+1) so whole-keyspace range floods cannot wrap the cumsum
    full = jnp.minimum(full, max_results + 1)
    start_full = jnp.cumsum(full) - full
    start = jnp.minimum(start_full, max_results).astype(jnp.int32)
    emit = jnp.minimum(full, max_results - start).astype(jnp.int32)
    total_emit = jnp.minimum(jnp.sum(full), max_results).astype(jnp.int32)
    truncated = jnp.sum((emit < full) & is_range).astype(jnp.int32)
    return start, emit, total_emit, truncated


def range_slot_ranks(
    rank_lo: jax.Array, start: jax.Array, total_emit: jax.Array, max_results: int
):
    """Per-output-slot global key rank.  Slot p belongs to the last op whose
    (clamped) start ≤ p — zero-width segments share their start with the
    following op, so ``side="right"`` lands on the true owner.  Invalid
    slots (≥ total_emit) get rank -1."""
    p = jnp.arange(max_results, dtype=jnp.int32)
    owner = jnp.clip(
        jnp.searchsorted(start, p, side="right").astype(jnp.int32) - 1,
        0,
        start.shape[0] - 1,
    )
    g = rank_lo[owner] + (p - start[owner])
    return jnp.where(p < total_emit, g, -1)


def dense_range_scan(
    state: FliXState,
    is_range: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    *,
    max_results: int,
):
    """The RANGE oracle: answer every active ``[lo, hi)`` op against
    ``state``, packing results densely at exclusive-scan offsets.

    Returns ``(keys[max_results], vals[max_results], start[N], count[N],
    truncated)``.  Output is globally key-ordered within each op's segment
    (and across segments when the ranges are disjoint); slots beyond the
    emitted total hold EMPTY / NOT_FOUND.
    """
    from repro.core.state import flatten_bucket_sorted

    flat_k, flat_v = flatten_bucket_sorted(state)
    nb = state.num_buckets
    live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
    )
    rank_lo = flat_rank(flat_k, pref, state.mkba, lo)
    rank_hi = flat_rank(flat_k, pref, state.mkba, hi)
    full = jnp.maximum(rank_hi - rank_lo, 0)
    start, emit, total_emit, truncated = range_offsets(full, is_range, max_results)
    g = range_slot_ranks(rank_lo, start, total_emit, max_results)
    valid = g >= 0
    g_c = jnp.where(valid, g, 0)
    src_b = jnp.clip(
        jnp.searchsorted(pref, g_c, side="right").astype(jnp.int32) - 1, 0, nb - 1
    )
    src_p = g_c - pref[src_b]
    rk = jnp.where(valid, flat_k[src_b, src_p], EMPTY)
    rv = jnp.where(valid, flat_v[src_b, src_p], NOT_FOUND)
    return (
        rk,
        rv,
        jnp.where(is_range, start, 0),
        jnp.where(is_range, emit, 0),
        truncated,
    )


@partial(jax.jit, static_argnames=("max_results",))
def range_query(
    state: FliXState, lo: jax.Array, hi: jax.Array, *, max_results: int = 128
):
    """Keys/vals in [lo, hi] per query pair, padded to max_results.

    Bucket-local walk from the successor position of ``lo`` — no global
    argsort.  Bucket order *is* key order (I2/I3), so each bucket only needs
    its own row sorted (``flatten_bucket_sorted``, a parallel per-row sort
    over the short capacity axis); per-bucket live-count prefix sums then
    turn (bucket, in-bucket position) into a global rank, and the walk is a
    pure rank→(bucket, position) gather across chain/bucket boundaries.
    Bonus operation (the paper discusses but does not benchmark range
    queries); used by the serving KV index.
    """
    from repro.core.state import flatten_bucket_sorted

    flat_k, flat_v = flatten_bucket_sorted(state)        # [nb, cap]
    nb = state.num_buckets
    loq = lo.astype(KEY_DTYPE)

    live = jnp.sum(flat_k != EMPTY, axis=1).astype(jnp.int32)            # [nb]
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(live).astype(jnp.int32)]
    )                                                                    # [nb+1]
    total = pref[-1]

    # successor position of lo: owning bucket + compare-count inside it
    b0 = jnp.minimum(
        jnp.searchsorted(state.mkba, loq, side="left"), nb - 1
    ).astype(jnp.int32)
    p0 = jnp.sum(flat_k[b0] < loq[:, None], axis=1).astype(jnp.int32)
    rank0 = pref[b0] + p0            # global rank of the first key ≥ lo

    ranks = rank0[:, None] + jnp.arange(max_results, dtype=jnp.int32)[None, :]
    in_range = ranks < total
    ranks_c = jnp.clip(ranks, 0, jnp.maximum(total - 1, 0))
    rb = jnp.clip(
        jnp.searchsorted(pref, ranks_c, side="right").astype(jnp.int32) - 1,
        0,
        nb - 1,
    )
    rpos = ranks_c - pref[rb]
    rk = flat_k[rb, rpos]
    rv = flat_v[rb, rpos]
    valid = in_range & (rk <= hi[:, None]) & (rk != EMPTY)
    return jnp.where(valid, rk, EMPTY), jnp.where(valid, rv, NOT_FOUND), jnp.sum(
        valid, axis=1
    )
