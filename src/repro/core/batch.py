"""Operation-batch preprocessing (the paper's "Common Steps", §4.1).

Every FliX operation consumes a *sorted* batch.  Sorting is the one global
step (Table 1 of the paper measures its cost); everything downstream is
bucket-local.  ``bucket_slices`` is the flipped-indexing primitive: one
vectorized ``searchsorted`` of the MKBA fences against the sorted batch gives
*every* bucket its slice of operations — the TPU-native form of "each bucket
binary-searches the batch and pulls its keys".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, FliXState


def sort_batch(keys: jax.Array, vals: jax.Array | None = None):
    """Sort an operation batch by key (vals, if given, follow their key)."""
    order = jnp.argsort(keys, stable=True)
    skeys = keys[order]
    if vals is None:
        return skeys
    return skeys, vals[order]


def dedup_last_wins(keys: jax.Array, vals: jax.Array):
    """Deduplicate a *sorted* batch; the last occurrence of a key wins.

    Duplicates are replaced by EMPTY and compacted to the end, preserving
    sortedness of the valid prefix.  Returns (keys, vals, valid_count).
    """
    n = keys.shape[0]
    is_last = jnp.concatenate([keys[1:] != keys[:-1], jnp.array([True])])
    keep = is_last & (keys != EMPTY)
    masked = jnp.where(keep, keys, EMPTY)
    order = jnp.argsort(masked, stable=True)
    return masked[order], vals[order], jnp.sum(keep).astype(jnp.int32)


def bucket_slices(state: FliXState, sorted_batch: jax.Array):
    """Per-bucket [start, end) boundaries into the sorted batch.

    Bucket b owns keys in (mkba[b-1], mkba[b]]:
      start[b] = searchsorted(batch, mkba[b-1], 'right')
      end[b]   = searchsorted(batch, mkba[b],   'right')
    One searchsorted over the fences serves all buckets at once.
    """
    ends = jnp.searchsorted(sorted_batch, state.mkba, side="right")
    starts = jnp.concatenate([jnp.zeros((1,), ends.dtype), ends[:-1]])
    return starts.astype(jnp.int32), ends.astype(jnp.int32)


def bucket_of(state: FliXState, keys: jax.Array) -> jax.Array:
    """Bucket index for each key (the classical direction; used by oracles
    and by baselines — FliX itself routes via ``bucket_slices``)."""
    return jnp.searchsorted(state.mkba, keys, side="left").astype(jnp.int32)


def gather_sublists(
    sorted_batch: jax.Array,
    starts: jax.Array,
    ends: jax.Array,
    max_len: int,
    fill_value=EMPTY,
):
    """Materialize per-bucket sublists as a padded [nb, max_len] tile.

    ``max_len`` is a static bound (≤ bucket capacity for updates).  Entries
    beyond the slice are ``fill_value``.  Also returns per-bucket counts
    (clamped to max_len) and the true counts for overflow detection.
    """
    nb = starts.shape[0]
    true_counts = (ends - starts).astype(jnp.int32)
    counts = jnp.minimum(true_counts, max_len)
    padded = jnp.concatenate(
        [sorted_batch, jnp.full((max_len,), fill_value, sorted_batch.dtype)]
    )
    idx = starts[:, None] + jnp.arange(max_len, dtype=jnp.int32)[None, :]
    idx = jnp.minimum(idx, sorted_batch.shape[0])  # clamp into the pad region
    tile = padded[idx]
    mask = jnp.arange(max_len, dtype=jnp.int32)[None, :] < counts[:, None]
    tile = jnp.where(mask, tile, fill_value)
    return tile, counts, true_counts


def gather_kv_sublists(
    sorted_keys: jax.Array,
    sorted_vals: jax.Array,
    starts: jax.Array,
    ends: jax.Array,
    max_len: int,
):
    """:func:`gather_sublists` for a (key, val) batch: the value tile follows
    its key's slot (0 at EMPTY slots).  Returns (keys, vals, counts,
    true_counts)."""
    tile_k, counts, true_counts = gather_sublists(sorted_keys, starts, ends, max_len)
    padded_v = jnp.concatenate(
        [sorted_vals, jnp.zeros((max_len,), sorted_vals.dtype)]
    )
    idx = starts[:, None] + jnp.arange(max_len, dtype=jnp.int32)[None, :]
    idx = jnp.minimum(idx, sorted_keys.shape[0])
    tile_v = jnp.where(tile_k != EMPTY, padded_v[idx], 0)
    return tile_k, tile_v, counts, true_counts
