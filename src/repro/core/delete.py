"""Bulk deletion (paper §4.4, Table 3 — TL-Bulk deletion with compaction).

FliX deletes *physically and immediately* — no tombstones.  Per bucket:
mark matches against the bucket's delete sublist (compare-count, the tile
ballot analogue), shift survivors left inside each node, drop empty nodes
from the chain, and make their slots available again.  Underfull nodes are
*not* merged here (that is restructuring's job; the paper notes merging on
delete as a future optimization — see ``merge_underfull`` for ours).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import EMPTY, KEY_DTYPE, VAL_DTYPE, FliXState


@jax.jit
def delete(state: FliXState, sorted_keys: jax.Array):
    """Bulk-delete a sorted batch of keys. Returns (state', stats).

    Membership is one binary search of the *whole sorted batch* per stored
    key — the flipped direction (data looks up the batch), with no per-
    bucket tile bound, so arbitrarily skewed batches (e.g. range frees full
    of absent keys) are handled exactly.
    """
    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    dk_batch = sorted_keys.astype(KEY_DTYPE)

    flat_k = state.keys.reshape(-1)
    pos = jnp.searchsorted(dk_batch, flat_k, side="left")
    pos_c = jnp.minimum(pos, dk_batch.shape[0] - 1)
    hit = (dk_batch[pos_c] == flat_k) & (flat_k != EMPTY)
    deleted = hit.reshape(nb, npb, ns)

    # in-node compaction: survivors shift left, EMPTY fills the tail.
    masked = jnp.where(deleted, EMPTY, state.keys)
    order = jnp.argsort(masked, axis=2, stable=True)
    new_keys = jnp.take_along_axis(masked, order, axis=2)
    new_vals = jnp.take_along_axis(state.vals, order, axis=2)

    node_count = jnp.sum(new_keys != EMPTY, axis=2).astype(jnp.int32)

    # chain compaction: drop empty nodes, keep chain order (stable sort by
    # "is-empty"), freeing their slots for future splits.
    empty_slot = node_count == 0
    slot_order = jnp.argsort(empty_slot, axis=1, stable=True)
    new_keys = jnp.take_along_axis(new_keys, slot_order[..., None], axis=1)
    new_vals = jnp.take_along_axis(new_vals, slot_order[..., None], axis=1)
    node_count = jnp.take_along_axis(node_count, slot_order, axis=1)

    node_max = jnp.where(
        node_count > 0,
        jnp.take_along_axis(
            new_keys, jnp.maximum(node_count - 1, 0)[..., None], axis=2
        )[..., 0],
        EMPTY,
    ).astype(KEY_DTYPE)
    num_nodes = jnp.sum(node_count > 0, axis=1).astype(jnp.int32)

    new_state = FliXState(
        keys=new_keys,
        vals=new_vals,
        node_count=node_count,
        node_max=node_max,
        num_nodes=num_nodes,
        mkba=state.mkba,
        needs_restructure=state.needs_restructure,
    )
    stats = {
        "deleted": jnp.sum(deleted),
        "nodes_freed": jnp.sum(state.num_nodes - num_nodes),
    }
    return new_state, stats


@jax.jit
def merge_underfull(state: FliXState):
    """Merge underfull *adjacent* nodes within each bucket (the paper's
    suggested deletion-path optimization, §5.4.1): greedily repack each
    bucket's content into half-full-or-better nodes without touching MKBA.

    Equivalent to a bucket-local restructure; O(bucket) like delete itself.
    """
    from repro.core.state import flatten_bucket_sorted, sort_bucket_rows

    nb, npb, ns = state.num_buckets, state.nodes_per_bucket, state.node_size
    ck, cv = flatten_bucket_sorted(state)          # [nb, cap] sorted, EMPTY tail
    ce = None
    if state.exps is not None:
        # same stable key argsort → same row order as (ck, cv)
        _, ce = sort_bucket_rows(state.keys.reshape(nb, -1), state.exps.reshape(nb, -1))
    live = jnp.sum(ck != EMPTY, axis=1).astype(jnp.int32)     # [nb]
    # repack into ceil(live/ns) balanced pieces (≥ half full except the last)
    i = jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :]
    s = jnp.maximum((live + ns - 1) // ns, 0)
    s_r = jnp.maximum(s, 1)[:, None]
    m_r = jnp.maximum(live, 1)[:, None]
    piece = (i * s_r) // m_r
    piece_start = (piece * m_r + s_r - 1) // s_r
    pos = i - piece_start
    valid = ck != EMPTY
    dump = npb * ns
    dest = jnp.where(valid & (piece < npb), piece * ns + pos, dump)
    nk = jnp.full((nb, npb * ns + 1), EMPTY, KEY_DTYPE)
    nv = jnp.zeros((nb, npb * ns + 1), VAL_DTYPE)
    nk = nk.at[jnp.arange(nb)[:, None], dest].set(ck)
    nv = nv.at[jnp.arange(nb)[:, None], dest].set(cv)
    new_keys = nk[:, :-1].reshape(nb, npb, ns)
    new_vals = nv[:, :-1].reshape(nb, npb, ns)
    new_exps = None
    if ce is not None:
        ne = jnp.full((nb, npb * ns + 1), EMPTY, KEY_DTYPE)  # EMPTY == NO_EXPIRY
        ne = ne.at[jnp.arange(nb)[:, None], dest].set(ce)
        new_exps = ne[:, :-1].reshape(nb, npb, ns)

    node_count = jnp.sum(new_keys != EMPTY, axis=2).astype(jnp.int32)
    node_max = jnp.where(
        node_count > 0,
        jnp.take_along_axis(
            new_keys, jnp.maximum(node_count - 1, 0)[..., None], axis=2
        )[..., 0],
        EMPTY,
    ).astype(KEY_DTYPE)
    num_nodes = jnp.sum(node_count > 0, axis=1).astype(jnp.int32)
    return FliXState(
        keys=new_keys,
        vals=new_vals,
        node_count=node_count,
        node_max=node_max,
        num_nodes=num_nodes,
        mkba=state.mkba,
        needs_restructure=state.needs_restructure,
        exps=new_exps,
    )
