"""Structural invariant checker for FliXState (I1–I6, see state.py).

Host-side (numpy) and O(total slots) — intended for tests and debugging,
not the hot path.  ``check_invariants`` raises ``AssertionError`` with the
first violated invariant; every mutating operation (build, insert, delete,
merge_underfull, restructure, apply_ops) must preserve I1–I5 whenever its
input satisfies them and no overflow was flagged.

I6 (expiry liveness, DESIGN.md §14) applies when the state carries an
expiry column: empty slots must hold ``NO_EXPIRY`` (reclaimed slots are
zeroed to the sentinel, so stale deadlines cannot leak back in), and —
when the caller supplies the engine-threaded ``now`` — no live row may
hold ``exp <= now``: every expired row must have been physically
reclaimed by the update pass, i.e. no read can ever observe one.
"""

from __future__ import annotations

import numpy as np

from repro.core.state import EMPTY, MAX_VALID, NOT_FOUND, FliXState


def check_invariants(st: FliXState, now: int | None = None) -> None:
    """Assert I1–I6 hold for ``st`` (see the state.py module docstring).

    ``now`` enables the liveness half of I6: it must be the same explicit
    virtual time the engine was last stepped with (the checker never reads
    the wall clock).
    """
    keys = np.asarray(st.keys)
    counts = np.asarray(st.node_count)
    nmax = np.asarray(st.node_max)
    nn = np.asarray(st.num_nodes)
    mkba = np.asarray(st.mkba)
    nb, npb, ns = keys.shape
    E = int(EMPTY)
    for b in range(nb):
        prev_max = None
        for j in range(npb):
            row = keys[b, j]
            c = counts[b, j]
            if j >= nn[b]:
                assert c == 0 and (row == E).all(), f"inactive slot {b},{j} dirty"
                continue
            assert c > 0, f"active empty node {b},{j}"
            valid = row[:c]
            assert (np.diff(valid) > 0).all(), f"I1 violated at {b},{j}"
            assert (row[c:] == E).all(), f"I1 padding violated at {b},{j}"
            assert nmax[b, j] == valid[-1], f"I4 violated at {b},{j}"
            if prev_max is not None:
                assert valid[0] > prev_max, f"I2 violated at {b},{j}"
            prev_max = valid[-1]
            lf = mkba[b - 1] if b else np.iinfo(np.int32).min
            assert valid[0] > lf and valid[-1] <= mkba[b], f"I3 violated at {b}"
    assert (np.diff(mkba.astype(np.int64)) >= 0).all(), "I5 violated"
    assert mkba[-1] == int(MAX_VALID), "I5 violated: mkba[-1] != MAX_VALID"
    if st.exps is not None:
        from repro.core.expiry import NO_EXPIRY

        exps = np.asarray(st.exps)
        assert exps.shape == keys.shape, "I6 violated: expiry column shape"
        empty = keys == E
        assert (exps[empty] == int(NO_EXPIRY)).all(), (
            "I6 violated: reclaimed/empty slot carries a stale expiry deadline"
        )
        if now is not None:
            leaked = (~empty) & (exps <= int(now))
            assert not leaked.any(), (
                "I6 violated: live row(s) past their expiry deadline "
                f"(keys {keys[leaked][:8].tolist()} expired at "
                f"{exps[leaked][:8].tolist()} <= now={int(now)})"
            )


def check_tiered_invariants(tiered, now: int | None = None) -> None:
    """Assert I7 for a ``core.residency.TieredFliX`` (DESIGN.md §15).

    I7: every live row is reachable in **exactly one** tier — resident
    buckets are authoritative on device, all others in the host mirror, and
    the assembled full view satisfies I1–I6; the device-tier footprint is
    within the budget after commit (one bucket is always allowed: a smaller
    budget cannot execute any op).  Additionally pins the residency
    bookkeeping the engine's correctness argument relies on: sorted/unique
    resident ids, the packed fence array mirroring the full fences (except
    the forced ``MAX_VALID`` terminator), and fresh per-bucket metadata.
    """
    nb = tiered.num_buckets
    ids = np.asarray(tiered.resident_ids)
    assert (np.diff(ids) > 0).all() if len(ids) > 1 else True, (
        "I7: resident_ids not sorted/unique"
    )
    if len(ids):
        assert ids[0] >= 0 and ids[-1] < nb, "I7: resident id out of range"
    packed = tiered._packed
    if packed is None:
        assert len(ids) == 0, "I7: resident ids without a packed state"
    else:
        assert packed.num_buckets == len(ids), (
            "I7: packed bucket count != resident id count"
        )
        pm = np.asarray(packed.mkba)
        assert (pm[:-1] == np.asarray(tiered.h_mkba)[ids[:-1]]).all(), (
            "I7: packed fences diverge from the full fence array"
        )
        assert pm[-1] == int(MAX_VALID), "I7: packed mkba not MAX_VALID-terminated"
    if tiered.budget_bytes is not None:
        cap = max(int(tiered.budget_bytes), tiered.bucket_bytes)
        assert tiered.memory_bytes_resident() <= cap, (
            f"I7: resident bytes {tiered.memory_bytes_resident()} > budget {cap}"
        )
    # exactly-one-tier: assemble the authoritative full view and check I1–I6
    view = tiered.host_view()  # sync() makes the mirror authoritative
    check_invariants(view, now=now)
    # metadata freshness (the prefetch pre-pass trusts these unconditionally)
    live = np.asarray(view.node_count).sum(axis=1)
    assert (live == np.asarray(tiered.h_live)).all(), "I7: stale live metadata"
    if view.exps is None:
        from repro.core.expiry import NO_EXPIRY

        assert (np.asarray(tiered.h_min_exp) == int(NO_EXPIRY)).all(), (
            "I7: min-expiry metadata without an expiry column"
        )
    else:
        from repro.core.expiry import NO_EXPIRY

        me = np.where(
            np.asarray(view.keys) != int(EMPTY), np.asarray(view.exps), int(NO_EXPIRY)
        ).min(axis=(1, 2))
        assert (me == np.asarray(tiered.h_min_exp)).all(), (
            "I7: stale min-expiry metadata"
        )


def check_range_results(ops, results, *, max_results: int) -> None:
    """Structural checks on a batch's dense RANGE output (DESIGN.md §10).

    For every RANGE op in the sorted batch: its segment of the dense arrays
    is strictly ascending (hence duplicate-free), every key lies inside the
    op's ``[lo, hi)``, segments are packed consecutively from offset 0 in
    batch order, and slots beyond the emitted total hold EMPTY / NOT_FOUND.
    Differential tests pin the *values*; this checker is the cheap
    post-apply sanity used by ``apply_ops_safe(validate_ranges=True)``.
    """
    from repro.core.ops import OP_RANGE

    tag = np.asarray(ops.tag)
    lo = np.asarray(ops.key)
    hi = np.asarray(ops.val)
    keys = np.asarray(results["range_key"])
    vals = np.asarray(results["range_val"])
    start = np.asarray(results["range_start"])
    count = np.asarray(results["range_count"])
    assert keys.shape == (max_results,) and vals.shape == (max_results,)

    is_range = tag == OP_RANGE
    assert (start[~is_range] == 0).all(), "non-RANGE op with a range offset"
    assert (count[~is_range] == 0).all(), "non-RANGE op with range results"

    cursor = 0
    for i in np.nonzero(is_range)[0]:
        c = int(count[i])
        assert 0 <= c <= max_results, f"op {i}: count {c} out of budget"
        assert start[i] == cursor, (
            f"op {i}: segment start {start[i]} != packed cursor {cursor}"
        )
        seg = keys[cursor : cursor + c].astype(np.int64)
        assert (np.diff(seg) > 0).all(), f"op {i}: segment not strictly ascending"
        assert ((seg >= int(lo[i])) & (seg < int(hi[i]))).all(), (
            f"op {i}: key outside [{lo[i]}, {hi[i]})"
        )
        cursor += c
    assert (keys[cursor:] == int(EMPTY)).all(), "dirty keys beyond emitted total"
    assert (vals[cursor:] == int(NOT_FOUND)).all(), "dirty vals beyond emitted total"
