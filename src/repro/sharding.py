"""Logical sharding rules: params, caches, activations, data.

All rules are expressed against *logical axis names* ("data", "model", and
optionally "pod"); meshes of any physical shape map onto them, which is what
makes restarts elastic (checkpoints store PartitionSpecs, not device
layouts — see repro.checkpoint).

Parallelism summary (DESIGN.md §6):
  * DP  — batch over ("pod", "data")
  * TP  — attention heads / FFN columns / vocab over "model"
  * EP  — MoE experts over "model" when E % tp == 0, else TP inside experts
  * SP  — decode KV sequence over "data" when the batch can't fill it
"""

from __future__ import annotations


import jax
import numpy as np
from jax.sharding import PartitionSpec as P

DATA_AXES = ("pod", "data")  # flattened for batch sharding when pod exists


def data_axes(mesh) -> tuple:
    return tuple(a for a in DATA_AXES if a in mesh.axis_names)


def batch_spec(mesh) -> P:
    return P(data_axes(mesh))


def _dense_layer_rules(cfg, tp: int, prefix_dims: int):
    """Specs for one dense/moe attention layer; prefix_dims=1 for stacked
    [L, ...] params, 0 for the unstacked shared block."""
    n = (None,) * prefix_dims
    rules = {
        "attn_norm": P(*n, None),
        "mlp_norm": P(*n, None),
        "wq": P(*n, None, "model"),
        "wk": P(*n, None, "model"),
        "wv": P(*n, None, "model"),
        "bq": P(*n, "model"),
        "bk": P(*n, "model"),
        "bv": P(*n, "model"),
        "wo": P(*n, "model", None),
        "w_gate": P(*n, None, "model"),
        "w_up": P(*n, None, "model"),
        "w_down": P(*n, "model", None),
    }
    if cfg.family == "moe":
        ep = (cfg.num_experts * cfg.moe_split) % tp == 0
        rules.update(
            {
                "router": P(*n, None, None),
                # EP when experts divide tp, else TP on the expert FFN dim
                "w_gate": P(*n, "model", None, None)
                if ep
                else P(*n, None, None, "model"),
                "w_up": P(*n, "model", None, None)
                if ep
                else P(*n, None, None, "model"),
                "w_down": P(*n, "model", None, None)
                if ep
                else P(*n, None, "model", None),
                "shared_gate": P(*n, None, "model"),
                "shared_up": P(*n, None, "model"),
                "shared_down": P(*n, "model", None),
            }
        )
    return rules


def _ssm_layer_rules(prefix_dims: int):
    n = (None,) * prefix_dims
    return {
        "norm": P(*n, None),
        "in_z": P(*n, None, "model"),
        "in_x": P(*n, None, "model"),
        "in_B": P(*n, None, None),
        "in_C": P(*n, None, None),
        "in_dt": P(*n, None, "model"),
        "conv_x": P(*n, None, "model"),
        "conv_B": P(*n, None, None),
        "conv_C": P(*n, None, None),
        "dt_bias": P(*n, "model"),
        "A_log": P(*n, "model"),
        "D_skip": P(*n, "model"),
        "norm_w": P(*n, "model"),
        "out_proj": P(*n, "model", None),
    }


def param_specs(cfg, params, tp: int):
    """PartitionSpec pytree parallel to ``params``."""
    if cfg.family in ("ssm", "hybrid"):
        layer_rules = _ssm_layer_rules(prefix_dims=1)
    else:
        layer_rules = _dense_layer_rules(cfg, tp, prefix_dims=1)
    shared_rules = _dense_layer_rules(cfg, tp, prefix_dims=0)

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        if keys[0] == "embed":
            return P("model", None)
        if keys[0] == "lm_head":
            return P(None, "model")
        if keys[0] == "final_norm":
            return P(None)
        if keys[0] == "layers":
            return layer_rules[keys[1]]
        if keys[0] == "shared_attn":
            return shared_rules[keys[1]]
        raise KeyError(f"no sharding rule for param path {keys}")

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_specs(cfg, cache, mesh, global_batch: int):
    """Per-layer KV / SSM-state specs for the decode cache.

    Batch shards over the data axes when it can fill them; otherwise the KV
    *sequence* dimension shards over "data" (SP decode, long_500k) while
    heads stay on "model".
    """
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    batch_fills = global_batch % dsize == 0 and global_batch >= dsize

    def spec_for(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1]
        if name == "pos":
            return P()
        if name in ("k", "v"):
            if batch_fills:
                return P(daxes, None, "model", None)
            return P(None, daxes, "model", None)
        if name == "ssm":  # [B, H, P, N]
            return P(daxes if batch_fills else None, "model", None, None)
        if name == "conv":  # [B, K-1, C]
            return P(daxes if batch_fills else None, None, "model")
        raise KeyError(f"no cache rule for {keys}")

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def input_specs_sharding(mesh, inputs: dict):
    """Token/prefix inputs: batch over the data axes."""
    daxes = data_axes(mesh)

    def spec_for(name, leaf):
        if leaf.ndim >= 1:
            return P(daxes, *([None] * (leaf.ndim - 1)))
        return P()

    return {k: spec_for(k, v) for k, v in inputs.items()}
