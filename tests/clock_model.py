"""Mocked-clock differential model for TTL/expiry (DESIGN.md §14).

Three pieces, shared by ``test_ttl_property.py`` and ``test_system.py``:

* ``VirtualClock`` — the only source of time in the whole TTL suite.  It
  is a plain integer the tests advance by hand; nothing here (or in the
  engine under test) may consult the wall clock.
* ``TTLModel`` — a pure ``dict`` oracle for the engine's TTL semantics:
  expiry is a pre-pass over the *pre-batch* state (a row is expired iff
  ``exp <= now``, i.e. exactly AT its deadline), then one update pass
  (INSERT sets ``(val, exp)``, DELETE removes, EXPIRE is get-or-set:
  returns the stored value and refreshes the deadline on a hit, inserts
  and returns NOT_FOUND on a miss), then reads against the post-update
  state.  Rows written in the same batch are visible to that batch's
  reads even when their deadline is already past — they fall to the
  NEXT batch's expiry pre-pass.
* ``forbid_wallclock`` — the negative control: while active, any
  ``time.time``/``monotonic``/``perf_counter`` call issued *from a
  ``repro.*`` module* raises.  Callers outside that namespace (JAX's own
  tracing machinery stamps trace events) pass through untouched, so the
  guard trips on exactly the bug it exists for: an engine that derives
  expiry from the wall clock instead of the threaded ``now``.
"""

from __future__ import annotations

import contextlib
import sys
import time

import numpy as np

from repro.core.expiry import NO_EXPIRY
from repro.core.ops import (
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
)
from repro.core.state import EMPTY, NOT_FOUND

UPDATE_TAGS = (OP_INSERT, OP_DELETE, OP_EXPIRE)


class VirtualClock:
    """An explicit integer clock: the tests own time, not the OS."""

    def __init__(self, start: int = 0):
        self.now = int(start)

    def advance(self, dt: int) -> int:
        assert dt >= 0, "the virtual clock never runs backwards"
        self.now += int(dt)
        return self.now


class TTLModel:
    """Dict oracle: ``key -> (val, exp)`` under the §14 batch semantics."""

    def __init__(self, pairs=None):
        # pairs: iterable of (key, val) or (key, val, exp)
        self.data: dict[int, tuple[int, int]] = {}
        for p in pairs or ():
            k, v, *rest = (int(x) for x in p)
            self.data[k] = (v, rest[0] if rest else int(NO_EXPIRY))

    def live(self) -> list[int]:
        return sorted(self.data)

    def expire(self, now: int) -> int:
        """The expiry pre-pass: reclaim every row with ``exp <= now``."""
        dead = [k for k, (_, e) in self.data.items() if e <= now]
        for k in dead:
            del self.data[k]
        return len(dead)

    def apply(self, tags, keys, vals, exps=None, *, now: int | None = None):
        """One mixed batch.  Returns ``(values, n_expired)`` with
        ``values`` in the ORIGINAL op order (compare against
        ``core.unsort(results["value"], perm)``); mutates the model."""
        tags = np.asarray(tags)
        keys = np.asarray(keys)
        vals = np.asarray(vals)
        exps = (
            np.full(keys.shape, int(NO_EXPIRY), np.int64)
            if exps is None
            else np.asarray(exps)
        )
        n_expired = 0 if now is None else self.expire(now)
        values = np.full(len(tags), int(NOT_FOUND), np.int64)
        for i, (t, k, v, e) in enumerate(zip(tags, keys, vals, exps)):
            t, k, v, e = int(t), int(k), int(v), int(e)
            if t == OP_INSERT:
                self.data[k] = (v, e)
            elif t == OP_DELETE:
                self.data.pop(k, None)
            elif t == OP_EXPIRE:
                if k in self.data:  # hit: return stored, refresh deadline
                    stored, _ = self.data[k]
                    self.data[k] = (stored, e)
                    values[i] = stored
                else:  # miss: insert, report the miss
                    self.data[k] = (v, e)
        for i, (t, k) in enumerate(zip(tags, keys)):
            t, k = int(t), int(k)
            if t == OP_POINT:
                values[i] = self.data[k][0] if k in self.data else int(NOT_FOUND)
            elif t == OP_SUCCESSOR:
                succ = [x for x in self.data if x >= k]
                values[i] = self.data[min(succ)][0] if succ else int(NOT_FOUND)
        return values, n_expired

    def range_segments(self, tags, keys, vals, max_results: int):
        """Expected dense RANGE output against the CURRENT (post-apply)
        state, in sorted batch order — mirror of the engine's packing:
        earlier sorted ops win the budget, each op keeps a prefix of its
        smallest keys.  Returns (dense_keys, dense_vals, starts, counts,
        truncated) with starts/counts keyed by original op index."""
        live = np.array(self.live(), dtype=np.int64)
        lv = {k: v for k, (v, _) in self.data.items()}
        order = np.argsort(np.asarray(keys), kind="stable")
        dense_k, dense_v, starts, counts = [], [], {}, {}
        truncated = 0
        cursor = 0
        for i in order:
            if int(tags[i]) != OP_RANGE:
                continue
            lo, hi = int(keys[i]), int(vals[i])
            seg = live[(live >= lo) & (live < hi)]
            n = min(len(seg), max_results - cursor)
            if n < len(seg):
                truncated += 1
            starts[int(i)], counts[int(i)] = cursor, n
            dense_k.extend(int(k) for k in seg[:n])
            dense_v.extend(lv[int(k)] for k in seg[:n])
            cursor += n
        return dense_k, dense_v, starts, counts, truncated


def check_one_update_op_per_key(tags, keys) -> bool:
    """The engine precondition EXPIRE shares with INSERT/DELETE."""
    upd = [int(k) for t, k in zip(tags, keys) if int(t) in UPDATE_TAGS]
    return len(upd) == len(set(upd))


_GUARDED = ("time", "monotonic", "perf_counter", "time_ns", "monotonic_ns")


@contextlib.contextmanager
def forbid_wallclock(namespace: str = "repro"):
    """Fail the test on any wall-clock read from ``namespace`` modules."""
    real = {n: getattr(time, n) for n in _GUARDED}

    def make_guard(name, orig):
        def guard(*args, **kwargs):
            mod = sys._getframe(1).f_globals.get("__name__", "")
            if mod == namespace or mod.startswith(namespace + "."):
                raise AssertionError(
                    f"wall-clock read: time.{name} called from {mod} — "
                    f"TTL expiry must use the threaded virtual `now`"
                )
            return orig(*args, **kwargs)

        return guard

    for n, o in real.items():
        setattr(time, n, make_guard(n, o))
    try:
        yield
    finally:
        for n, o in real.items():
            setattr(time, n, o)


@contextlib.contextmanager
def huge_wallclock(at: int = 1 << 40):
    """Pin ``time.time``/``time_ns`` absurdly far in the future.  If any
    engine layer derived expiry from the wall clock, every TTL'd key
    would vanish instantly; under the virtual clock nothing changes."""
    real = {n: getattr(time, n) for n in ("time", "time_ns")}
    time.time = lambda: float(at)
    time.time_ns = lambda: int(at) * 1_000_000_000
    try:
        yield
    finally:
        for n, o in real.items():
            setattr(time, n, o)


__all__ = [
    "EMPTY",
    "NOT_FOUND",
    "NO_EXPIRY",
    "TTLModel",
    "VirtualClock",
    "check_one_update_op_per_key",
    "forbid_wallclock",
    "huge_wallclock",
]
