"""Hostile-traffic proof of the gateway contract (DESIGN.md §13).

THE property: however the traffic misbehaves — duplicate floods, retry
storms, expired deadlines, overload, a poisoned update path, process
death between WAL fsync and client ack — the served state is
**byte-identical** to a single well-behaved client applying each
committed update exactly once (``tests/traffic_replay.py``'s oracle),
and everything not served is rejected with a TYPED reason.

Lanes:

* a deterministic **soak** (virtual clock, no sleeps — CI-blocking);
* targeted duplicate-submission semantics at every point of the request
  lifecycle: before ack, after ack, after crash recovery;
* admission control: rate limits, bounded queue depth, deadlines,
  weighted fairness shares;
* degraded modes: poisoned durable layer (reads flow, updates typed
  UNAVAILABLE), engine failure mapping (ENGINE_FAILURE vs UNKNOWN_COMMIT);
* the crash matrix: in-process ``CrashAt`` at the gateway commit-path
  hooks × the WAL seam, plus a subprocess SIGKILL run.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import fault_injection as fi
import traffic_replay as tr
from repro.checkpoint.serialize import canonical_state_bytes
from repro.serve.gateway import (
    DEADLINE_EXCEEDED,
    ENGINE_FAILURE,
    INVALID,
    QUEUE_FULL,
    RATE_LIMITED,
    UNAVAILABLE,
    UNKNOWN_COMMIT,
    Request,
)

REPO = Path(__file__).resolve().parents[1]


def _alloc(key, seq, pages=(0,), tenant="t0", deadline=None):
    return Request(
        tenant,
        key,
        "alloc",
        seqs=(seq,) * len(pages),
        pages=tuple(pages),
        slots=tuple(seq * 100 + p for p in pages),
        deadline=deadline,
    )


def _state_bytes(index):
    return canonical_state_bytes(index.state)


# ---------------------------------------------------------------------------
# the soak: hostile population vs single-client oracle (CI-blocking)
# ---------------------------------------------------------------------------


def test_soak_differential_vs_oracle():
    idx = tr.make_index()
    gw = tr.make_gateway(idx)
    gw.register_tenant("tenant-hot", rate=24, burst=48, weight=3.0)
    gw.register_tenant("tenant-mid", rate=16, burst=32)
    res = tr.run_traffic(gw, tr.default_population(0), ticks=20, seed=0)
    upd = tr.assert_exactly_once(res.requests, res.commit_log)
    assert len(upd) > 50  # the soak actually exercised the update path
    assert tr.oracle_state_bytes(res.requests, upd) == _state_bytes(idx)
    m = gw.metrics
    # the population's misbehavior was really seen and really typed
    assert m["duplicates"] > 0  # dup-flood client
    assert m["rejected"].get(RATE_LIMITED, 0) > 0  # hot client over budget
    assert m["rejected"].get(DEADLINE_EXCEEDED, 0) > 0  # straggler
    assert m["engine_failures"] == 0
    # admission-control invariant held throughout (bounded queue)
    assert gw.queue_depth <= gw.max_queue_ops
    # tiny geometry under sustained allocs: the safe path regrew at least
    # once and the retry count SURVIVED into gateway metrics (satellite:
    # restructure_retries through kv_index.step stats)
    assert m["restructure_retries"] >= 1


def test_soak_read_results_are_request_scoped():
    """Each client's ticket resolves with ITS slice: lookups get aligned
    slot arrays, pages get per-seq dicts — spot-checked against direct
    index queries after quiescence."""
    idx = tr.make_index()
    gw = tr.make_gateway(idx)
    gw.submit(_alloc("a", seq=5, pages=(0, 1, 2)), now=0.0)
    gw.pump(now=0.0)
    t_lu = gw.submit(
        Request("t0", "lu", "lookup", seqs=(5, 5, 9), pages=(1, 2, 0)), now=1.0
    )
    t_pg = gw.submit(Request("t1", "pg", "pages", seqs=(5,)), now=1.0)
    gw.pump(now=1.0)
    assert list(np.asarray(t_lu.result())) == [501, 502, -1]
    (pages,) = t_pg.result()
    assert pages["count"] == 3
    assert list(np.asarray(pages["pages"])) == [0, 1, 2]
    assert list(np.asarray(pages["slots"])) == [500, 501, 502]


# ---------------------------------------------------------------------------
# duplicate-submission semantics through the request lifecycle
# ---------------------------------------------------------------------------


def test_duplicate_before_ack_returns_same_ticket():
    gw = tr.make_gateway(tr.make_index())
    t1 = gw.submit(_alloc("k1", 3), now=0.0)
    t2 = gw.submit(_alloc("k1", 3), now=0.0)
    assert t2 is t1  # one commit, many holders
    assert gw.metrics["duplicates"] == 1
    gw.pump(now=0.0)
    assert t1.ok and not t1.duplicate
    assert gw.metrics["committed_requests"] == 1


def test_duplicate_after_ack_resolves_without_recommit():
    idx = tr.make_index()
    gw = tr.make_gateway(idx)
    t1 = gw.submit(_alloc("k1", 3), now=0.0)
    gw.pump(now=0.0)
    before = _state_bytes(idx)
    t2 = gw.submit(_alloc("k1", 3), now=1.0)
    assert t2.ok and t2.duplicate and t2.commit_seq == t1.commit_seq
    assert gw.pump(now=1.0).n_ops == 0  # nothing re-enqueued
    assert _state_bytes(idx) == before
    assert gw.metrics["committed_requests"] == 1


def test_duplicate_across_crash_recovery(tmp_path):
    """The key of a batch committed right before the crash — acked or not
    — must resolve as a duplicate on the REOPENED gateway: the dedup
    window rides inside the WAL records (same fsync as the ops)."""
    d = tmp_path / "wal"
    idx = tr.make_index(durability_dir=d)
    gw = tr.make_gateway(idx)
    gw.submit(_alloc("k1", 3, pages=(0, 1)), now=0.0)
    gw.pump(now=0.0)
    before = _state_bytes(idx)
    # no clean close: simulate process death after the ack
    idx2 = tr.make_index(durability_dir=d)
    gw2 = tr.make_gateway(idx2)
    t = gw2.submit(_alloc("k1", 3, pages=(0, 1)), now=0.0)
    assert t.ok and t.duplicate
    assert gw2.pump(now=0.0).n_ops == 0
    assert _state_bytes(idx2) == before
    # a genuinely new key still applies
    gw2.submit(_alloc("k2", 4), now=1.0)
    assert gw2.pump(now=1.0).committed_keys == ["k2"]
    gw2.close(now=2.0)


def test_dedup_window_is_bounded():
    gw = tr.make_gateway(tr.make_index(), dedup_window=4)
    for i in range(8):
        gw.submit(_alloc(f"k{i}", i), now=float(i))
        gw.pump(now=float(i))
    # only the last 4 keys are remembered; an ancient retry re-applies
    # (documented: clients must not retry past the window)
    assert len(gw._committed) == 4
    assert not gw.submit(_alloc("k0", 0), now=9.0).done  # re-admitted
    assert gw.submit(_alloc("k7", 7), now=9.0).duplicate


# ---------------------------------------------------------------------------
# admission control: deadlines, rate limits, shedding, fairness
# ---------------------------------------------------------------------------


def test_deadline_rejected_at_admission_and_expired_at_formation():
    gw = tr.make_gateway(tr.make_index())
    t1 = gw.submit(_alloc("k1", 1, deadline=5.0), now=5.0)
    assert t1.error.code == DEADLINE_EXCEEDED and not t1.error.retryable
    t2 = gw.submit(_alloc("k2", 2, deadline=3.0), now=0.0)  # queued
    report = gw.pump(now=4.0)  # pumped only after the deadline passed
    assert t2.error.code == DEADLINE_EXCEEDED
    assert report.expired == 1 and report.committed_keys == []
    assert gw.metrics["expired"] == 1
    assert gw.queue_depth == 0  # expired work released its queue budget


def test_rate_limit_typed_with_retry_after_then_refills():
    gw = tr.make_gateway(tr.make_index())
    gw.register_tenant("t0", rate=1.0, burst=2.0, now=0.0)
    assert not gw.submit(_alloc("a", 1), now=0.0).done
    assert not gw.submit(_alloc("b", 2), now=0.0).done
    t3 = gw.submit(_alloc("c", 3), now=0.0)  # bucket empty
    assert t3.error.code == RATE_LIMITED and t3.error.retryable
    assert t3.error.retry_after == pytest.approx(1.0)
    # the client obeys the hint: same key, admitted after the refill
    assert not gw.submit(_alloc("c", 3), now=1.0).done


def test_queue_full_sheds_with_bounded_depth_and_burns_no_tokens():
    gw = tr.make_gateway(tr.make_index(), max_batch_ops=4, max_queue_ops=8)
    for i in range(8):
        assert not gw.submit(_alloc(f"k{i}", i, tenant=f"t{i}"), now=0.0).done
    t = gw.submit(_alloc("k8", 8, tenant="t8"), now=0.0)
    assert t.error.code == QUEUE_FULL and t.error.retryable
    assert t.error.retry_after >= 1.0
    assert gw.queue_depth == 8 <= gw.max_queue_ops
    # the shed did NOT debit t8's bucket: admitted as soon as space exists
    gw.pump(now=0.0)
    assert not gw.submit(_alloc("k8", 8, tenant="t8"), now=0.0).done


def test_oversized_request_is_invalid_not_queued():
    gw = tr.make_gateway(tr.make_index(), max_batch_ops=4, max_pages=8)
    t = gw.submit(Request("t0", "f", "free", seqs=(1,)), now=0.0)  # cost 8
    assert t.error.code == INVALID and not t.error.retryable


def test_weighted_fairness_shares_and_no_starvation():
    """Two saturated tenants at weights 3:1 split a capacity-bound batch
    ~3:1 — and the light tenant is never starved."""
    gw = tr.make_gateway(tr.make_index(), max_batch_ops=8, max_queue_ops=2048)
    gw.register_tenant("heavy", rate=1e9, burst=1e9, weight=3.0, now=0.0)
    gw.register_tenant("light", rate=1e9, burst=1e9, weight=1.0, now=0.0)
    for i in range(40):
        gw.submit(
            Request("heavy", f"h{i}", "lookup", seqs=(i,), pages=(0,)), now=0.0
        )
        gw.submit(
            Request("light", f"l{i}", "lookup", seqs=(i,), pages=(0,)), now=0.0
        )
    report = gw.pump(now=0.0)
    assert len(report.committed_keys) == 8
    heavy = sum(k.startswith("h") for k in report.committed_keys)
    assert heavy == 6  # 3:1 split of 8 slots, exactly (stride is exact)
    for _ in range(3):
        report = gw.pump(now=0.0)
        assert any(k.startswith("l") for k in report.committed_keys)


# ---------------------------------------------------------------------------
# degraded modes and typed failure mapping
# ---------------------------------------------------------------------------


def _poison(idx):
    """Drive the real poisoning path: engine failure + failed rollback."""

    def boom(*a, **k):
        raise RuntimeError("engine OOM")

    def no_rollback(offset):
        raise OSError("disk gone")

    idx._durable.engine.apply = boom
    idx._durable._wal.truncate_to = no_rollback


def test_poisoned_update_path_degrades_to_read_only(tmp_path):
    idx = tr.make_index(durability_dir=tmp_path / "wal")
    gw = tr.make_gateway(idx)
    gw.submit(_alloc("a", 5, pages=(0, 1)), now=0.0)
    gw.pump(now=0.0)
    _poison(idx)
    t = gw.submit(_alloc("b", 6), now=1.0)
    rep = gw.pump(now=1.0)
    # rollback failed mid-commit: the batch MAY be durable → UNKNOWN_COMMIT
    assert t.error.code == UNKNOWN_COMMIT and t.error.retryable
    assert rep.failed_code == UNKNOWN_COMMIT
    assert not idx.healthy
    # updates now shed at ADMISSION, typed and retryable-after-reopen...
    t2 = gw.submit(_alloc("c", 7), now=2.0)
    assert t2.error.code == UNAVAILABLE and "degraded" in t2.error.detail
    # ...while reads keep flowing against the live state (never touch WAL)
    t3 = gw.submit(
        Request("t0", "r", "lookup", seqs=(5, 5), pages=(0, 1)), now=2.0
    )
    gw.pump(now=2.0)
    assert list(np.asarray(t3.result())) == [500, 501]
    # satellite: teardown on a poisoned instance is safe + idempotent
    assert idx.snapshot() is None
    gw.close(now=3.0)
    gw.close(now=3.0)
    idx.close()
    assert not idx.healthy


def test_engine_failure_without_durability_is_typed_and_recoverable():
    idx = tr.make_index()
    gw = tr.make_gateway(idx)
    real_step = idx.step
    idx.step = lambda **k: (_ for _ in ()).throw(RuntimeError("engine OOM"))
    t = gw.submit(_alloc("a", 1), now=0.0)
    rep = gw.pump(now=0.0)
    # no durable layer involved: the step never applied → ENGINE_FAILURE
    assert t.status == "failed" and t.error.code == ENGINE_FAILURE
    assert rep.failed_code == ENGINE_FAILURE
    assert gw.metrics["engine_failures"] == 1
    assert gw.queue_depth == 0  # failed batch released its queue budget
    idx.step = real_step  # transient failure: the SAME key retries fine
    gw.submit(_alloc("a", 1), now=1.0)
    assert gw.pump(now=1.0).committed_keys == ["a"]


def test_close_rejects_queued_and_is_idempotent(tmp_path):
    idx = tr.make_index(durability_dir=tmp_path / "wal")
    gw = tr.make_gateway(idx)
    t = gw.submit(_alloc("a", 1), now=0.0)
    gw.close(now=1.0)
    assert t.error.code == UNAVAILABLE and t.error.retryable
    gw.close(now=1.0)  # idempotent, including index.close underneath
    t2 = gw.submit(_alloc("b", 2), now=2.0)
    assert t2.error.code == UNAVAILABLE and "closed" in t2.error.detail


# ---------------------------------------------------------------------------
# the crash matrix: CrashAt across the gateway commit path × the WAL seam
# ---------------------------------------------------------------------------

CRASH_POINTS = [(e, 2) for e in fi.GATEWAY_EVENTS] + [
    ("wal.append.partial", 3),
    ("wal.append.durable", 3),
    ("apply.done", 4),
]


def _crash_traffic(d, event, count, *, seed=1, ticks=10):
    """Run the population against a durable gateway until the hook fires;
    the CrashError propagates like process death (BaseException)."""
    hook = fi.CrashAt(event, count)
    idx = tr.make_index(durability_dir=d, crash_hook=hook)
    gw = tr.make_gateway(idx, crash_hook=hook)
    try:
        tr.run_traffic(gw, tr.default_population(seed), ticks=ticks, seed=seed)
        return False
    except fi.CrashError:
        return True


def _recover_and_check(d, *, seed=1, ticks=10):
    """Reopen, resubmit EVERYTHING (clients retry all), prove exactly-once
    + byte-identical state vs the oracle over the full commit order."""
    requests = tr.regen_all_requests(tr.default_population(seed), ticks, seed)
    idx = tr.make_index(durability_dir=d)
    gw = tr.make_gateway(idx)
    surviving = tr.surviving_update_commits(idx, requests)
    res = tr.run_traffic(gw, tr.default_population(seed), ticks=ticks, seed=seed)
    full_log = surviving + tr.committed_update_keys(requests, res.commit_log)
    assert len(set(full_log)) == len(full_log), "a key committed twice"
    assert tr.oracle_state_bytes(requests, full_log) == _state_bytes(idx)
    gw.close(now=float(res.end_tick))
    return len(surviving)


@pytest.mark.parametrize("event,count", CRASH_POINTS)
def test_crash_matrix_gateway_commit_path(tmp_path, event, count):
    d = tmp_path / "wal"
    crashed = _crash_traffic(d, event, count)
    assert crashed, f"hook {event}#{count} never fired"
    _recover_and_check(d)


def test_crash_between_commit_and_ack_resolves_as_duplicate(tmp_path):
    """The nastiest window: WAL fsynced (durable) but the client never saw
    the ack.  Its retry on the reopened gateway MUST dedup, not re-apply."""
    d = tmp_path / "wal"
    hook = fi.CrashAt("gateway.step.done", 1)
    idx = tr.make_index(durability_dir=d, crash_hook=hook)
    gw = tr.make_gateway(idx, crash_hook=hook)
    t = gw.submit(_alloc("k1", 3, pages=(0, 1)), now=0.0)
    with pytest.raises(fi.CrashError):
        gw.pump(now=0.0)
    assert not t.done  # committed, never acked
    idx2 = tr.make_index(durability_dir=d)
    before = _state_bytes(idx2)
    gw2 = tr.make_gateway(idx2)
    t2 = gw2.submit(_alloc("k1", 3, pages=(0, 1)), now=0.0)
    assert t2.ok and t2.duplicate
    assert _state_bytes(idx2) == before
    gw2.close(now=1.0)


# genuine process death: one WAL-seam point and one post-commit/pre-ack
# gateway point (the in-process matrix covers the rest cheaply)
SIGKILL_POINTS = [("wal.append.partial", 6), ("gateway.step.done", 8)]


@pytest.mark.parametrize("event,count", SIGKILL_POINTS)
def test_sigkill_subprocess_gateway(tmp_path, event, count):
    d = tmp_path / "wal"
    seed, ticks = 3, 12
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "traffic_replay.py"),
            "--dir",
            str(d),
            "--ticks",
            str(ticks),
            "--seed",
            str(seed),
            "--kill-event",
            event,
            "--kill-count",
            str(count),
        ],
        capture_output=True,
        text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": f"{REPO}/src"},
        cwd=str(REPO),
    )
    assert proc.returncode == -9, f"child not SIGKILLed:\n{proc.stderr}"
    requests = tr.regen_all_requests(tr.default_population(seed), ticks, seed)
    acked = []
    for line in proc.stdout.splitlines():
        if line.startswith("COMMIT "):
            acked.extend(
                k for k in line.split()[1].split(",") if requests[k].is_update
            )
    idx = tr.make_index(durability_dir=d)
    surviving = tr.surviving_update_commits(idx, requests)
    idx.close()
    # every update the child ACKED before dying survived recovery
    missing = [k for k in acked if k not in surviving]
    assert not missing, f"acked updates lost: {missing[:5]}"
    assert _recover_and_check(d, seed=seed, ticks=ticks) == len(surviving)
