"""Sharded mixed-batch engine: ``shard_apply_ops`` parity + a2a overflow.

In-process multi-device tests.  CI's *blocking* fast lane runs this file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a plain
single-device host everything skips (the subprocess variants in
``tests/test_distributed.py`` keep default tier-1 coverage).  The contract
under test (DESIGN.md §11): ``shard_apply_ops`` is byte-identical to
single-device ``apply_ops`` — slots, successor fallbacks, dense RANGE
arrays, stats — for both routing modes on 2/4/8 host devices.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import distributed as dist
from repro.core.config import ExecConfig

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

KEY_SPACE = 100_000
RESULT_KEYS = (
    "value",
    "succ_key",
    "range_key",
    "range_val",
    "range_start",
    "range_count",
)
STAT_KEYS = ("inserted", "deleted", "overflowed_buckets", "range_truncated")


def _build_pair(rng, n=2048, n_shards=4):
    """(single-device state, sharded index, mesh) over the same contents."""
    keys = np.sort(rng.permutation(KEY_SPACE)[:n]).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    st = core.build_from_sorted(
        jnp.asarray(keys),
        jnp.asarray(vals),
        num_buckets=max(1, n // 8),
        nodes_per_bucket=8,
        node_size=16,
    )
    mesh = dist.make_shard_mesh(n_shards)
    idx = dist.shard_build(
        jnp.asarray(keys), jnp.asarray(vals), mesh, node_size=16, nodes_per_bucket=8
    )
    return keys, st, idx, mesh


def _mixed_batch(rng, keys, *, n_ins=128, n_del=128, n_pt=384, n_sc=384, n_rg=64,
                 span=2_000, pad_to=2048):
    """A full-mix sorted batch (RANGE spans drawn wide enough to cross
    shard fences) plus one whole-keyspace range op."""
    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE + 20_000, 4096).astype(np.int32), keys
    )
    ins = absent[:n_ins]
    dels = rng.choice(keys, n_del, replace=False).astype(np.int32)
    pts = rng.integers(0, KEY_SPACE + 20_000, n_pt).astype(np.int32)
    scs = rng.integers(0, KEY_SPACE + 20_000, n_sc).astype(np.int32)
    los = rng.integers(0, KEY_SPACE, n_rg - 1).astype(np.int32)
    his = (los + rng.integers(1, span, n_rg - 1)).astype(np.int32)
    los = np.concatenate([los, [0]]).astype(np.int32)
    his = np.concatenate([his, [KEY_SPACE + 20_000]]).astype(np.int32)
    tags = np.concatenate([
        np.full(n_ins, core.OP_INSERT),
        np.full(n_del, core.OP_DELETE),
        np.full(n_pt, core.OP_POINT),
        np.full(n_sc, core.OP_SUCCESSOR),
        np.full(n_rg, core.OP_RANGE),
    ]).astype(np.int32)
    bk = np.concatenate([ins, dels, pts, scs, los]).astype(np.int32)
    bv = np.concatenate([
        np.arange(n_ins, dtype=np.int32) + 7_000_000,
        np.zeros(n_del, np.int32),
        np.zeros(n_pt, np.int32),
        np.zeros(n_sc, np.int32),
        his,
    ]).astype(np.int32)
    ops, _ = core.make_ops(tags, bk, bv, pad_to=pad_to)
    return ops


def _assert_identical(res, stats, want_res, want_stats, label=""):
    for k in RESULT_KEYS:
        got, want = np.asarray(res[k]), np.asarray(want_res[k])
        bad = np.nonzero(got != want)[0]
        assert bad.size == 0, (label, k, bad[:10], got[bad][:5], want[bad][:5])
    for k in STAT_KEYS:
        assert int(stats[k]) == int(want_stats[k]), (label, k)


def _post_state_parity(new_idx, mesh, single_state, probe_keys):
    """The updated sharded index answers like the updated single state."""
    q = np.sort(probe_keys)
    qops, _ = core.make_ops(np.full(q.shape, core.OP_POINT, np.int32), q)
    _, got, _ = dist.shard_apply_ops(new_idx, qops, mesh, config=ExecConfig(max_results=8))
    _, want, _ = core.apply_ops(single_state, qops, config=ExecConfig(impl="reference", max_results=8))
    assert (np.asarray(got["value"]) == np.asarray(want["value"])).all()


@pytest.mark.parametrize("n_shards", [2, 4, 8])
@pytest.mark.parametrize("routing", ["replicated", "a2a"])
def test_matches_single_device(rng, n_shards, routing):
    keys, st, idx, mesh = _build_pair(rng, n_shards=n_shards)
    ops = _mixed_batch(rng, keys)
    mr = 512
    s2, want_res, want_stats = core.apply_ops(st, ops, config=ExecConfig(impl="reference", max_results=mr))
    new_idx, res, stats = dist.shard_apply_ops(
        idx, ops, mesh, config=ExecConfig(routing=routing, max_results=mr)
    )
    _assert_identical(res, stats, want_res, want_stats, f"{routing}/s{n_shards}")
    assert int(stats["a2a_overflow"]) == 0
    probes = np.concatenate([keys[:512], np.asarray(ops.key)[:256]])
    _post_state_parity(new_idx, mesh, s2, probes)


@pytest.mark.parametrize("routing", ["replicated", "a2a"])
def test_truncation_deterministic_under_global_budget(rng, routing):
    """A tight global max_results budget truncates exactly like one device."""
    keys, st, idx, mesh = _build_pair(rng)
    ops = _mixed_batch(rng, keys, n_rg=96, span=8_000)
    mr = 64  # far below the full result volume -> earlier-op-wins truncation
    _, want_res, want_stats = core.apply_ops(st, ops, config=ExecConfig(impl="reference", max_results=mr))
    assert int(want_stats["range_truncated"]) > 0  # the case is exercised
    _, res, stats = dist.shard_apply_ops(
        idx, ops, mesh, config=ExecConfig(routing=routing, max_results=mr)
    )
    _assert_identical(res, stats, want_res, want_stats, routing)


def test_read_only_and_nop_batches(rng):
    keys, st, idx, mesh = _build_pair(rng)
    ops = _mixed_batch(rng, keys, n_ins=0, n_del=0, n_pt=512, n_sc=512, n_rg=32)
    _, want_res, want_stats = core.apply_ops(st, ops, config=ExecConfig(impl="reference", max_results=256))
    for routing in ("replicated", "a2a"):
        _, res, stats = dist.shard_apply_ops(
            idx, ops, mesh, config=ExecConfig(routing=routing, max_results=256)
        )
        _assert_identical(res, stats, want_res, want_stats, routing)
    # all-NOP padding batch is legal and a no-op
    nops, _ = core.make_ops(
        np.zeros(0, np.int32), np.zeros(0, np.int32), pad_to=64
    )
    for routing in ("replicated", "a2a"):
        new_idx, res, stats = dist.shard_apply_ops(idx, nops, mesh, config=ExecConfig(routing=routing))
        assert int(stats["inserted"]) == 0 and int(stats["deleted"]) == 0
        assert (np.asarray(res["value"]) == int(core.NOT_FOUND)).all()


# ---------------------------------------------------------------------------
# a2a capacity / overflow semantics (DESIGN.md §11)
# ---------------------------------------------------------------------------


def _skewed_batch(rng, idx, n=1024):
    """Every op lands inside shard 0's fence range (adversarial skew)."""
    hi = int(np.asarray(idx.part_fences)[0])
    skewed = rng.integers(0, hi, n).astype(np.int32)
    tags = np.full(n, core.OP_POINT, np.int32)
    tags[: n // 4] = core.OP_SUCCESSOR
    ops, _ = core.make_ops(tags, skewed)
    return ops


def test_a2a_overflow_reported_and_reroute_succeeds(rng):
    keys, st, idx, mesh = _build_pair(rng)
    ops = _skewed_batch(rng, idx)
    # capacity 64 per (src, dst) pair cannot carry 1024 rows to one shard
    _, _, stats = dist.shard_apply_ops(idx, ops, mesh, config=ExecConfig(routing="a2a", capacity=64))
    assert int(stats["a2a_overflow"]) == 1024 - 4 * 64
    # the documented recovery: replay the same batch on the same (unmutated)
    # index with a larger capacity — results now match the replicated mode
    _, res, stats = dist.shard_apply_ops(idx, ops, mesh, config=ExecConfig(routing="a2a", capacity=256))
    assert int(stats["a2a_overflow"]) == 0
    _, want, _ = dist.shard_apply_ops(idx, ops, mesh, config=ExecConfig(routing="replicated"))
    for k in ("value", "succ_key"):
        assert (np.asarray(res[k]) == np.asarray(want[k])).all(), k


def test_safe_driver_surfaces_a2a_retry_stats(rng):
    """The capacity re-route replay is visible in the safe driver's stats:
    ``a2a_retries`` counts replays, ``a2a_overflow_dropped`` the rows the
    failed attempts shed, and the FINAL attempt's own overflow is 0 —
    regression for the counters surviving the retry path (the gateway and
    bench artifact report them; a retry that silently resets them would
    hide every capacity misconfiguration)."""
    keys, st, idx, mesh = _build_pair(rng)
    ops = _skewed_batch(rng, idx)
    new_idx, res, stats = dist.shard_apply_ops_safe(
        idx, ops, mesh, config=ExecConfig(routing="a2a", capacity=64)
    )
    assert int(stats["a2a_retries"]) >= 1
    assert int(stats["a2a_overflow_dropped"]) >= 1024 - 4 * 64
    assert int(stats["a2a_overflow"]) == 0  # final attempt carried everything
    assert int(stats["restructure_retries"]) == 0  # read batch: no regrow
    _, want, _ = dist.shard_apply_ops(idx, ops, mesh, config=ExecConfig(routing="replicated"))
    for k in ("value", "succ_key"):
        assert (np.asarray(res[k]) == np.asarray(want[k])).all(), k


def test_a2a_matches_replicated_on_skew(rng):
    """Replicated vs a2a are byte-identical when all ops hit one shard."""
    keys, st, idx, mesh = _build_pair(rng)
    hi = int(np.asarray(idx.part_fences)[0])
    absent = np.setdiff1d(rng.integers(0, hi, 4096).astype(np.int32), keys)
    n = 256
    tags = np.concatenate([
        np.full(n, core.OP_INSERT),
        np.full(n, core.OP_DELETE),
        np.full(n, core.OP_POINT),
        np.full(n, core.OP_SUCCESSOR),
        np.full(32, core.OP_RANGE),
    ]).astype(np.int32)
    in_shard0 = keys[keys < hi]
    bk = np.concatenate([
        absent[:n],
        rng.choice(in_shard0, n, replace=False),
        rng.integers(0, hi, n),
        rng.integers(0, hi, n),
        rng.integers(0, hi, 32),
    ]).astype(np.int32)
    bv = np.zeros(bk.shape, np.int32)
    bv[:n] = np.arange(n) + 5_000_000
    bv[-32:] = bk[-32:] + 500
    ops, _ = core.make_ops(tags, bk, bv, pad_to=1280)
    _, want_res, want_stats = dist.shard_apply_ops(
        idx, ops, mesh, config=ExecConfig(routing="replicated", max_results=256)
    )
    # default capacity (= chunk size) can never overflow, even at full skew
    _, res, stats = dist.shard_apply_ops(
        idx, ops, mesh, config=ExecConfig(routing="a2a", max_results=256)
    )
    assert int(stats["a2a_overflow"]) == 0
    _assert_identical(res, stats, want_res, want_stats, "skew")


# ---------------------------------------------------------------------------
# shard_restructure (cluster analogue of §3.5 relaunch)
# ---------------------------------------------------------------------------


def test_shard_restructure_rebalances_and_preserves_contents(rng):
    keys, st, idx, mesh = _build_pair(rng)
    hi = int(np.asarray(idx.part_fences)[0])
    extra = np.setdiff1d(rng.integers(0, hi, 6000).astype(np.int32), keys)[:1024]
    iops, _ = core.make_ops(
        np.full(extra.shape, core.OP_INSERT, np.int32),
        np.sort(extra),
        np.arange(extra.shape[0], dtype=np.int32),
    )
    idx2, _, _ = dist.shard_apply_ops_safe(idx, iops, mesh)
    before = np.asarray(dist.shard_live_counts(idx2, mesh))
    idx3 = dist.shard_restructure(idx2, mesh)
    after = np.asarray(dist.shard_live_counts(idx3, mesh))
    assert before.sum() == after.sum() == keys.shape[0] + extra.shape[0]
    assert before.max() > 2 * before.min()  # the skew was real
    assert after.max() - after.min() <= after.mean() * 0.25 + 16  # rebalanced
    # every key still resolves post-rebalance
    probe = np.sort(np.concatenate([keys, extra]))
    qops, _ = core.make_ops(np.full(probe.shape, core.OP_POINT, np.int32), probe)
    _, res, _ = dist.shard_apply_ops(idx3, qops, mesh, config=ExecConfig(max_results=8))
    assert (np.asarray(res["value"]) != int(core.NOT_FOUND)).all()


# ---------------------------------------------------------------------------
# sharded serving (KVPageIndex across the mesh)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing", ["replicated", "a2a"])
def test_sharded_kv_index_serves_like_local(routing):
    from repro.serve.kv_index import KVPageIndex

    kv = KVPageIndex(shards=4, config=ExecConfig(routing=routing))
    ref = KVPageIndex()
    seqs = np.arange(8)
    for idx_obj in (kv, ref):
        idx_obj.allocate(seqs, np.zeros(8, int), seqs * 100)
        idx_obj.allocate(seqs, np.ones(8, int), seqs * 100 + 1)
    got = np.asarray(kv.lookup(seqs, np.ones(8, int)))
    assert (got == np.asarray(ref.lookup(seqs, np.ones(8, int)))).all()
    pg, sl, cnt = kv.pages_of(3)
    assert int(cnt) == 2
    assert np.asarray(pg)[:2].tolist() == [0, 1]
    assert np.asarray(sl)[:2].tolist() == [300, 301]
    kv.free_sequences([3])
    ref.free_sequences([3])
    assert kv.live_pages() == ref.live_pages() == 14
    _, _, cnt = kv.pages_of(3)
    assert int(cnt) == 0
    # a burst large enough to overflow the seed geometry exercises the
    # shard_restructure retry inside shard_apply_ops_safe
    pages = np.arange(600)
    kv.allocate(np.full(600, 50), pages, pages + 9000)
    assert kv.live_pages() == 614
    pg, sl, cnt = kv.pages_of(50, max_pages=1024)
    assert int(cnt) == 600
    assert (np.asarray(sl)[:600] == pages + 9000).all()


# ---------------------------------------------------------------------------
# TTL/expiry parity across the mesh (DESIGN.md §14)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing", ["replicated", "a2a"])
def test_ttl_matches_single_device(rng, routing):
    """The TTL path — expiry pre-pass at the batch's virtual ``now``,
    TTL'd inserts (some dead-on-arrival), and EXPIRE get-or-set — is
    result-identical between ``shard_apply_ops`` and the single-device
    engine, including the psum'd ``expired`` stat."""
    from repro.checkpoint.serialize import state_from_pairs

    n, now = 2048, 1000
    keys = np.sort(rng.permutation(KEY_SPACE)[:n]).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    # a quarter carry deadlines straddling now: some already expired
    exps = np.where(
        rng.random(n) < 0.25, rng.integers(1, 2 * now, n), core.NO_EXPIRY
    ).astype(np.int32)
    st = state_from_pairs(keys, vals, exps, node_size=16, nodes_per_bucket=8)
    mesh = dist.make_shard_mesh(4)
    idx = dist.shard_build(
        jnp.asarray(keys),
        jnp.asarray(vals),
        mesh,
        node_size=16,
        nodes_per_bucket=8,
        sorted_exps=jnp.asarray(exps),
    )

    absent = np.setdiff1d(
        rng.integers(0, KEY_SPACE + 20_000, 4096).astype(np.int32), keys
    )
    ins, gs_miss = absent[:96], absent[96:144]
    gs_hit = rng.choice(keys, 48, replace=False).astype(np.int32)
    dels = rng.choice(
        np.setdiff1d(keys, gs_hit), 96, replace=False
    ).astype(np.int32)
    pts = rng.integers(0, KEY_SPACE, 256).astype(np.int32)
    scs = rng.integers(0, KEY_SPACE, 128).astype(np.int32)
    los = np.concatenate([rng.integers(0, KEY_SPACE, 15), [0]]).astype(np.int32)
    his = np.concatenate(
        [los[:15] + rng.integers(1, 2_000, 15), [KEY_SPACE + 20_000]]
    ).astype(np.int32)
    tags = np.concatenate([
        np.full(96, core.OP_INSERT),
        np.full(96, core.OP_EXPIRE),
        np.full(96, core.OP_DELETE),
        np.full(256, core.OP_POINT),
        np.full(128, core.OP_SUCCESSOR),
        np.full(16, core.OP_RANGE),
    ]).astype(np.int32)
    bk = np.concatenate(
        [ins, gs_miss, gs_hit, dels, pts, scs, los]
    ).astype(np.int32)
    bv = np.concatenate([
        np.arange(96, dtype=np.int32) + 7_000_000,
        np.arange(96, dtype=np.int32) + 8_000_000,
        np.zeros(96 + 256 + 128, np.int32),
        his,
    ]).astype(np.int32)
    bexp = np.concatenate([
        now + rng.integers(-5, 200, 96).astype(np.int32),  # incl. dead rows
        now + rng.integers(1, 200, 96).astype(np.int32),
        np.full(96 + 256 + 128 + 16, core.NO_EXPIRY, np.int32),
    ]).astype(np.int32)
    ops, _ = core.make_ops(tags, bk, bv, exps=jnp.asarray(bexp), pad_to=1024)

    mr = 512
    s2, want_res, want_stats = core.apply_ops(
        st, ops, now=now, config=ExecConfig(impl="reference", max_results=mr)
    )
    new_idx, res, stats = dist.shard_apply_ops(
        idx, ops, mesh, now=now, config=ExecConfig(routing=routing, max_results=mr)
    )
    _assert_identical(res, stats, want_res, want_stats, f"ttl/{routing}")
    assert int(stats["expired"]) == int(want_stats["expired"]) > 0

    # advance the clock: the NEXT batch's pre-pass must reclaim the same
    # rows on both engines (covers deadlines written by this batch)
    later = now + 100
    probe = np.sort(np.concatenate([ins, gs_hit, keys[:256]]))
    qops, _ = core.make_ops(
        np.full(probe.shape, core.OP_POINT, np.int32), probe, pad_to=1024
    )
    _, want2, wstats2 = core.apply_ops(
        s2, qops, now=later, config=ExecConfig(impl="reference", max_results=8)
    )
    _, got2, gstats2 = dist.shard_apply_ops(
        new_idx, qops, mesh, now=later, config=ExecConfig(routing=routing, max_results=8)
    )
    assert (np.asarray(got2["value"]) == np.asarray(want2["value"])).all()
    assert int(gstats2["expired"]) == int(wstats2["expired"]) > 0
