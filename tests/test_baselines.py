"""Baseline structures vs dict oracle + their documented pathologies."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import btree, hash_table as ht, lsm, sorted_array as sa
from repro.core.state import EMPTY, NOT_FOUND


@pytest.fixture
def data(rng):
    universe = rng.permutation(50000).astype(np.int32)
    keys, extra = universe[:2000], universe[2000:4000]
    vals = np.arange(2000, dtype=np.int32)
    return keys, vals, extra, dict(zip(keys.tolist(), vals.tolist()))


def test_sorted_array(data, rng):
    keys, vals, extra, model = data
    sk, sv = np.sort(keys), vals[np.argsort(keys)]
    st = sa.build(jnp.asarray(sk), jnp.asarray(sv), capacity=8192)
    res = np.asarray(sa.point_query(st, jnp.asarray(sk)))
    assert all(res[i] == model[int(sk[i])] for i in range(len(sk)))
    ik = np.sort(extra)
    st = sa.insert(st, jnp.asarray(ik), jnp.asarray(ik))
    res = np.asarray(sa.point_query(st, jnp.asarray(ik)))
    assert (res == ik).all()
    st = sa.delete(st, jnp.asarray(ik[:500]))
    res = np.asarray(sa.point_query(st, jnp.asarray(ik[:500])))
    assert (res == int(NOT_FOUND)).all()


def test_lsm_push_cascade_and_queries(data):
    keys, vals, extra, model = data
    st = lsm.empty_state(chunk=128, num_levels=12)
    sk, sv = np.sort(keys), vals[np.argsort(keys)]
    st = lsm.insert(st, jnp.asarray(sk), jnp.asarray(sv))
    res = np.asarray(lsm.point_query(st, jnp.asarray(sk)))
    assert all(res[i] == model[int(sk[i])] for i in range(len(sk)))
    # newest occurrence wins
    up = sk[:200]
    st = lsm.insert(st, jnp.asarray(up), jnp.asarray(np.full(200, 777, np.int32)))
    res = np.asarray(lsm.point_query(st, jnp.asarray(up)))
    assert (res == 777).all()


def test_lsm_tombstones_and_successor_degradation(data):
    keys, vals, extra, model = data
    st = lsm.empty_state(chunk=128, num_levels=12)
    sk, sv = np.sort(keys), vals[np.argsort(keys)]
    st = lsm.insert(st, jnp.asarray(sk), jnp.asarray(sv))
    dels = np.sort(keys[::2])
    st = lsm.delete(st, jnp.asarray(dels))
    res = np.asarray(lsm.point_query(st, jnp.asarray(dels)))
    assert (res == int(NOT_FOUND)).all()
    # successor must skip tombstoned keys to the next live key
    live = np.setdiff1d(sk, dels)
    q = dels[:100]
    skk, svv = lsm.successor_query(st, jnp.asarray(np.sort(q)), max_skips=64)
    skk = np.asarray(skk)
    for i, qq in enumerate(np.sort(q)):
        j = np.searchsorted(live, qq)
        want = live[j] if j < len(live) else int(EMPTY)
        assert skk[i] == want


def test_btree_traversal(data):
    keys, vals, extra, model = data
    bt = btree.build(keys, vals, node_size=16, nodes_per_bucket=8)
    assert len(bt.levels) >= 1
    sk = np.sort(keys)
    res = np.asarray(btree.point_query(bt, jnp.asarray(sk)))
    assert all(res[i] == model[int(sk[i])] for i in range(len(sk)))
    misses = np.setdiff1d(np.arange(50000, dtype=np.int32), np.concatenate([keys, extra]))[:300]
    res = np.asarray(btree.point_query(bt, jnp.asarray(np.sort(misses))))
    assert (res == int(NOT_FOUND)).all()


def test_hash_table_probe_chains_and_tombstones(data):
    keys, vals, extra, model = data
    # 80% load factor per the paper; probe bound sized for the α=0.8 tail
    MP = 256
    h = ht.empty_state(capacity=int(len(keys) / 0.8))
    h, fails = ht.insert(h, jnp.asarray(keys), jnp.asarray(vals), max_probe=MP)
    assert int(fails) == 0
    res = np.asarray(ht.point_query(h, jnp.asarray(keys), max_probe=MP))
    assert all(res[i] == model[int(keys[i])] for i in range(len(keys)))
    h = ht.delete(h, jnp.asarray(keys[:500]), max_probe=MP)
    res = np.asarray(ht.point_query(h, jnp.asarray(keys[:500]), max_probe=MP))
    assert (res == int(NOT_FOUND)).all()
    # tombstones keep the rest of the probe chain reachable
    res = np.asarray(ht.point_query(h, jnp.asarray(keys[500:]), max_probe=MP))
    assert all(res[i] == model[int(keys[500 + i])] for i in range(len(keys) - 500))
    # and tombstone slots are reusable for new keys
    h, fails = ht.insert(h, jnp.asarray(extra[:500]), jnp.asarray(extra[:500]), max_probe=MP)
    assert int(fails) == 0
    res = np.asarray(ht.point_query(h, jnp.asarray(extra[:500]), max_probe=MP))
    assert (res == extra[:500]).all()
