"""Deterministic hostile-traffic replay harness for the serving gateway
(DESIGN.md §13), shared by ``test_gateway.py`` and runnable directly as a
subprocess child for the SIGKILL matrix.

Three pieces, mirroring ``fault_injection.py`` one layer up:

* a **deterministic client population** — ``gen_requests(spec, t, seed)``
  is a pure function of (client, tick, seed), so an interrupted run, its
  post-recovery resumption, and the oracle all see byte-identical request
  streams.  Specs model the hostile shapes the gateway must absorb:
  skewed rates, synchronized bursts, duplicate floods (the same
  idempotency key submitted k times), stragglers with already-expired or
  about-to-expire deadlines, and retry storms (every retryable rejection
  is resubmitted with the SAME key);
* a **single-client oracle** — ``oracle_state_bytes`` applies each
  committed *update* request exactly once, one engine step each, in
  commit order, on a fresh dedup-free index; the gateway-served state
  must be byte-identical (``canonical_state_bytes``), which is THE
  exactly-once property: however many duplicates/retries arrived, state
  moved once per logical request;
* a **commit log** — every ``pump`` report's committed keys, in order;
  across a crash the surviving prefix is reconstructed from the durable
  dedup window (``KVPageIndex.dedup_seed``), exactly what recovery
  itself trusts.

Run as a script it becomes the crash child::

    python tests/traffic_replay.py --dir D --ticks 30 \
        --kill-event wal.append.partial --kill-count 4

printing ``COMMIT <key,key,...>`` (flushed) after each committed batch —
an update batch whose COMMIT line was printed is durable, so the parent
asserts it survives recovery.
"""

from __future__ import annotations

import argparse
import sys
import zlib
from dataclasses import dataclass
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.checkpoint.serialize import canonical_state_bytes  # noqa: E402
from repro.serve.gateway import Gateway, Request  # noqa: E402
from repro.serve.kv_index import KVPageIndex  # noqa: E402

# tiny geometry: restructures happen inside short workloads and the whole
# soak stays in the fast CI lane
GEOMETRY = dict(node_size=8, nodes_per_bucket=4)
MAX_PAGES = 8  # pages per sequence in the harness (free cost = 8 ops)
SNAPSHOT_EVERY = 4


def make_index(durability_dir=None, crash_hook=None, **kw):
    return KVPageIndex(
        durability_dir=durability_dir,
        snapshot_every=SNAPSHOT_EVERY,
        crash_hook=crash_hook,
        **{**GEOMETRY, **kw},
    )


def make_gateway(index, *, crash_hook=None, **kw):
    defaults = dict(
        max_batch_ops=64,
        max_queue_ops=256,
        dedup_window=4096,
        max_pages=MAX_PAGES,
        range_budget=64,
        default_rate=48.0,
        default_burst=96.0,
    )
    return Gateway(index, crash_hook=crash_hook, **{**defaults, **kw})


# ---------------------------------------------------------------------------
# deterministic client populations
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClientSpec:
    """One emulated client: its tenant, rate shape, and misbehavior."""

    name: str
    tenant: str
    rate: float  # mean fresh requests per tick
    seq_base: int  # private sequence-id space [seq_base, seq_base+seq_span)
    seq_span: int = 32
    burst_every: int = 0  # every N ticks, rate spikes by burst_size
    burst_size: int = 0
    dup_copies: int = 1  # duplicate flood: each request submitted k times
    straggler: bool = False  # tight deadlines that expire under backlog
    update_frac: float = 0.6


def default_population(seed: int = 0) -> list[ClientSpec]:
    """Heterogeneous population: one hot tenant, steady mid-rate tenants,
    a duplicate-flooder, and a straggler — FederNet-style uneven clients."""
    return [
        ClientSpec("hot-0", "tenant-hot", 6.0, 0, burst_every=5, burst_size=12),
        ClientSpec("hot-1", "tenant-hot", 4.0, 100),
        ClientSpec("mid-0", "tenant-mid", 2.0, 200),
        ClientSpec("mid-1", "tenant-mid", 2.0, 300, burst_every=7, burst_size=6),
        ClientSpec("dup-0", "tenant-dup", 1.5, 400, dup_copies=4),
        ClientSpec("strag-0", "tenant-strag", 1.0, 500, straggler=True),
    ]


def _client_rng(spec: ClientSpec, t: int, seed: int) -> np.random.Generator:
    # crc32, not hash(): hash() is salted per process and the child/parent
    # of the SIGKILL matrix must generate identical streams
    return np.random.default_rng(
        [seed, zlib.crc32(spec.name.encode()) & 0x7FFFFFFF, t]
    )


def gen_requests(spec: ClientSpec, t: int, seed: int) -> list[Request]:
    """Client ``spec``'s fresh requests at tick ``t`` — a pure function."""
    rng = _client_rng(spec, t, seed)
    rate = spec.rate
    if spec.burst_every and t and t % spec.burst_every == 0:
        rate += spec.burst_size
    n = int(rng.poisson(rate))
    out = []
    for i in range(n):
        key = f"{spec.name}:{t}:{i}"
        deadline = float(t) + (float(rng.integers(0, 3)) if spec.straggler else 20.0)
        r = rng.random()
        seq = int(spec.seq_base + rng.integers(0, spec.seq_span))
        if r < spec.update_frac * 0.75:  # alloc 1-3 pages of one seq
            k = int(rng.integers(1, 4))
            pages = tuple(
                int(p) for p in rng.choice(MAX_PAGES, size=k, replace=False)
            )
            out.append(
                Request(
                    spec.tenant,
                    key,
                    "alloc",
                    seqs=(seq,) * k,
                    pages=pages,
                    slots=tuple(seq * 100 + p for p in pages),
                    deadline=deadline,
                )
            )
        elif r < spec.update_frac:  # free one seq
            out.append(
                Request(spec.tenant, key, "free", seqs=(seq,), deadline=deadline)
            )
        elif r < spec.update_frac + (1 - spec.update_frac) * 0.7:  # lookups
            k = int(rng.integers(1, 4))
            seqs = tuple(
                int(spec.seq_base + s) for s in rng.integers(0, spec.seq_span, k)
            )
            pages = tuple(int(p) for p in rng.integers(0, MAX_PAGES, k))
            out.append(
                Request(
                    spec.tenant, key, "lookup", seqs=seqs, pages=pages,
                    deadline=deadline,
                )
            )
        else:  # enumerate one seq's pages
            out.append(
                Request(spec.tenant, key, "pages", seqs=(seq,), deadline=deadline)
            )
    return out


# ---------------------------------------------------------------------------
# the replay driver
# ---------------------------------------------------------------------------


@dataclass
class TrafficResult:
    requests: dict  # key -> Request (every request generated)
    commit_log: list  # committed keys, in commit order
    tickets: dict  # key -> final Ticket
    latencies: list  # (finished - submitted) per ok queued ticket
    end_tick: int


def run_traffic(
    gateway: Gateway,
    clients: list[ClientSpec],
    *,
    ticks: int,
    seed: int = 0,
    start_tick: int = 0,
    max_retries: int = 30,
    drain_ticks: int = 40,
    on_commit=None,
) -> TrafficResult:
    """Drive the population through the gateway, retrying every retryable
    rejection with the same idempotency key, then drain.  Deterministic:
    submission order is (retries sorted by key, then clients in list
    order), one pump per tick."""
    requests: dict[str, Request] = {}
    tickets: dict[str, object] = {}
    attempts: dict[str, int] = {}
    retry_at: dict[str, float] = {}
    commit_log: list[str] = []
    latencies: list[float] = []
    resolved: set[str] = set()

    def submit(req: Request, now: float):
        requests.setdefault(req.key, req)
        tk = gateway.submit(req, now=now)
        tickets[req.key] = tk
        attempts[req.key] = attempts.get(req.key, 0) + 1
        return tk

    def settle(now: float):
        """Harvest terminal tickets: record latencies, schedule retries."""
        for key, tk in tickets.items():
            if key in resolved or not tk.done:
                continue
            resolved.add(key)
            if tk.ok and not tk.duplicate and tk.finished_at > tk.submitted_at:
                latencies.append(tk.finished_at - tk.submitted_at)
            if (
                tk.status in ("rejected", "failed")
                and tk.error is not None
                and tk.error.retryable
                and attempts[key] <= max_retries
            ):
                wait = tk.error.retry_after
                retry_at[key] = now + max(1.0, float(wait or 1.0))
                resolved.discard(key)  # retried: not terminal yet

    t = start_tick
    end = start_tick + ticks
    while t < end or (
        t < end + drain_ticks and (retry_at or gateway.queue_depth > 0)
    ):
        now = float(t)
        due = sorted(k for k, when in retry_at.items() if when <= now)
        for key in due:
            del retry_at[key]
            submit(requests[key], now)
        if t < end:
            for spec in clients:
                for req in gen_requests(spec, t, seed):
                    for _copy in range(spec.dup_copies):
                        submit(req, now)
        report = gateway.pump(now=now)
        commit_log.extend(report.committed_keys)
        if on_commit is not None:
            on_commit(report)
        settle(now)
        t += 1
    return TrafficResult(requests, commit_log, tickets, latencies, t)


# ---------------------------------------------------------------------------
# the oracle + exactly-once checks
# ---------------------------------------------------------------------------


def committed_update_keys(requests: dict, commit_log: list) -> list:
    return [k for k in commit_log if k in requests and requests[k].is_update]


def oracle_state_bytes(requests: dict, update_keys_in_order: list) -> bytes:
    """Apply each committed update request EXACTLY ONCE, one engine step
    each, in commit order, on a fresh single-client index — the dedup-free
    baseline the gateway-served state must match byte-for-byte."""
    idx = make_index()
    for key in update_keys_in_order:
        req = requests[key]
        if req.kind == "alloc":
            idx.step(allocs=(list(req.seqs), list(req.pages), list(req.slots)))
        elif req.kind == "free":
            idx.step(free_seqs=list(req.seqs), max_pages=MAX_PAGES)
        else:
            raise AssertionError(f"oracle fed a read request: {key}")
    return canonical_state_bytes(idx.state)


def assert_exactly_once(requests: dict, commit_log: list) -> list:
    """No idempotency key commits twice; returns the update keys."""
    seen = set()
    for k in commit_log:
        assert k not in seen, f"idempotency key {k} committed twice"
        seen.add(k)
    return committed_update_keys(requests, commit_log)


def regen_all_requests(clients, ticks: int, seed: int) -> dict:
    """Every request the population generates in [0, ticks) — how the
    crash-test parent reconstructs the child's streams (pure function)."""
    out: dict[str, Request] = {}
    for t in range(ticks):
        for spec in clients:
            for req in gen_requests(spec, t, seed):
                out[req.key] = req
    return out


def surviving_update_commits(index: KVPageIndex, requests: dict) -> list:
    """Committed UPDATE keys that survived into the durable history, in
    commit order — read from the same dedup trail recovery reseeds.  The
    trail logs every key in the batch (reads too, for ack dedup); only
    update kinds move state, so only they feed the oracle."""
    out = []
    for _seq, meta in index.dedup_seed():
        for k in (meta or {}).get("keys", ()):
            if k in requests and requests[k].is_update:
                out.append(k)
    return out


# ---------------------------------------------------------------------------
# subprocess child for the SIGKILL matrix
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True)
    ap.add_argument("--ticks", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-event", default=None)
    ap.add_argument("--kill-count", type=int, default=1)
    args = ap.parse_args()

    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from fault_injection import KillAt

    hook = KillAt(args.kill_event, args.kill_count) if args.kill_event else None
    index = make_index(durability_dir=args.dir, crash_hook=hook)
    gateway = make_gateway(index, crash_hook=hook)

    def on_commit(report):
        if report.committed_keys:
            print(f"COMMIT {','.join(report.committed_keys)}", flush=True)

    result = run_traffic(
        gateway,
        default_population(args.seed),
        ticks=args.ticks,
        seed=args.seed,
        on_commit=on_commit,
    )
    gateway.close(now=float(result.end_tick))
    print(f"DONE {len(result.commit_log)}", flush=True)


if __name__ == "__main__":
    main()
