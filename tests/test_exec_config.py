"""ExecConfig surface: shims warn once, config+legacy rejected, StepResult
is attribute-only (DESIGN.md §16).

The six entry points — ``apply_ops``, ``apply_ops_safe``,
``shard_apply_ops(_safe)``, ``TieredFliX.apply``, ``KVPageIndex`` — share
one resolution path (``core.config.resolve_config``), so the contract is
proven against the path plus one end-to-end entry point per flavor;
``tools/check_exec_config.py`` separately gates the repo's own callers off
the deprecated keywords.
"""

import warnings

import numpy as np
import pytest

from repro import core
from repro.core.config import (
    ExecConfig,
    TileTable,
    reset_deprecation_warnings,
    resolve_config,
)
from repro.serve.kv_index import KVPageIndex, StepResult


@pytest.fixture(autouse=True)
def _rearm():
    reset_deprecation_warnings()
    yield
    reset_deprecation_warnings()


def _small_state_and_ops(rng):
    keys = rng.choice(5000, size=400, replace=False).astype(np.int32)
    st = core.build(keys, keys, node_size=8, nodes_per_bucket=8)
    q = np.sort(rng.choice(keys, 64)).astype(np.int32)
    ops, _ = core.make_ops(np.full(64, core.OP_POINT, np.int32), q, pad_to=64)
    return st, ops


def test_frozen_hashable_validated():
    cfg = ExecConfig(impl="fused", max_results=64)
    assert hash(cfg) == hash(ExecConfig(impl="fused", max_results=64))
    with pytest.raises(Exception):
        cfg.impl = "reference"  # frozen
    for bad in (dict(impl="nope"), dict(pipeline="maybe"), dict(routing="ring")):
        with pytest.raises(ValueError):
            ExecConfig(**bad)
    # replace returns a new validated instance
    assert cfg.replace(impl="reference").impl == "reference"
    assert cfg.impl == "fused"


def test_legacy_keyword_warns_once_per_entry(rng):
    st, ops = _small_state_and_ops(rng)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        core.apply_ops(st, ops, impl="reference")
        core.apply_ops(st, ops, impl="reference")
        core.apply_ops_safe(st, ops, impl="reference")
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    # once per entry point, not per call
    assert len(deps) == 2
    assert "apply_ops" in str(deps[0].message)
    assert "config=ExecConfig" in str(deps[0].message)
    # re-arming the latch brings the warning back (what this suite relies on)
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        core.apply_ops(st, ops, impl="reference")
    assert len(w2) == 1


def test_config_plus_legacy_rejected(rng):
    st, ops = _small_state_and_ops(rng)
    with pytest.raises(TypeError, match="not both"):
        core.apply_ops(st, ops, config=ExecConfig(), impl="reference")
    with pytest.raises(TypeError, match="not both"):
        KVPageIndex(config=ExecConfig(), impl="reference")


def test_legacy_and_config_paths_agree(rng):
    st, ops = _small_state_and_ops(rng)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        _, res_legacy, _ = core.apply_ops(st, ops, impl="reference", max_results=32)
    _, res_cfg, _ = core.apply_ops(
        st, ops, config=ExecConfig(impl="reference", max_results=32)
    )
    for k in res_legacy:
        np.testing.assert_array_equal(
            np.asarray(res_legacy[k]), np.asarray(res_cfg[k]), err_msg=k
        )


def test_resolve_config_passthrough_and_default():
    cfg = ExecConfig(impl="fused")
    assert resolve_config("x", cfg) is cfg
    assert resolve_config("x", None) == ExecConfig()


def test_kv_page_index_accepts_config(rng):
    idx = KVPageIndex(config=ExecConfig(impl="reference"))
    assert idx.impl == "reference"
    res = idx.step(allocs=([1, 2], [0, 0], [10, 20]), lookups=([1], [0]))
    assert isinstance(res, StepResult)
    assert np.asarray(res.slots).tolist() == [10]
    assert res.range_out is None


def test_step_result_not_iterable():
    """Stale three-tuple unpacking must fail loudly, not silently misbind."""
    r = StepResult(slots=np.zeros(0), range_out=None, stats={})
    with pytest.raises(TypeError):
        a, b, c = r
    with pytest.raises(TypeError):
        r[1]


def test_tile_table_lookup_nearest():
    t = TileTable(entries=((1024, 128, 128, 2), (65536, 1024, 512, 8)))
    assert t.lookup(1000, 100) == (128, 2)          # exact bucket
    assert t.lookup(70000, 2000) == (512, 8)        # rounds up + nearest
    assert t.lookup(8192, 256) == (128, 2)          # octave distance tie-break
    assert TileTable().lookup(1, 1) is None
