"""Crash-injection harness for the durability layer (DESIGN.md §12).

Three pieces, shared by ``test_crash_recovery.py`` and runnable directly
as a subprocess child:

* a **deterministic workload** — ``make_batch_host(t, seed)`` is a pure
  function of the batch seq, so an interrupted run, its resumption, and
  the uninterrupted oracle all generate byte-identical op streams;
* an **oracle** — ``oracle_canonical`` runs the same engine with no
  durability layer at all and records the canonical payload after every
  batch; recovery at seq ``s`` must reproduce ``oracle[s]`` exactly;
* **crash hooks** — ``CrashAt`` raises inside the instrumented points of
  ``WriteAheadLog.append`` / ``DurableFliX.snapshot`` (every file write
  there is a raw ``os.write``, so an exception leaves bytes on disk
  identical to a process death at that instruction), and ``KillAt``
  escalates to a genuine uncatchable ``SIGKILL`` for the subprocess
  matrix.

Run as a script it becomes the child process::

    python tests/fault_injection.py --dir D --batches 8 \
        --kill-event wal.append.partial --kill-count 3

printing ``ACK <seq>`` (flushed) after each durably applied batch, so the
parent knows exactly which batches were acknowledged before the kill.
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.config import ExecConfig  # noqa: E402
from repro.checkpoint import DurableFliX, LocalEngine  # noqa: E402
from repro.checkpoint.serialize import canonical_state_bytes  # noqa: E402
from repro.core.expiry import NO_EXPIRY  # noqa: E402
from repro.core.ops import (  # noqa: E402
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
    OpBatch,
)

# tiny geometry so per-bucket overflow (→ restructure) happens inside a
# short workload, and the whole sweep stays in the fast CI lane
KEY_SPACE = 4096
BATCH = 48
N_INITIAL = 400
GEOMETRY = dict(node_size=8, nodes_per_bucket=4)
SNAPSHOT_EVERY = 3
FULL_EVERY = 2
HEAVY_EVERY = 3  # every 3rd batch is insert-heavy (drives restructure)


def make_engine(**overrides) -> LocalEngine:
    return LocalEngine(**{**GEOMETRY, **overrides})


def initial_pairs(seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.choice(KEY_SPACE, N_INITIAL, replace=False)).astype(np.int32)
    vals = (keys * 7 + 1).astype(np.int32)
    return keys, vals


def make_batch_host(t: int, seed: int = 0):
    """Batch ``t`` of the workload: ``(tag, key, val, max_results)``, host
    arrays sorted by key.  Pure function of ``(t, seed)`` — the whole
    harness depends on that."""
    rng = np.random.default_rng((seed + 1) * 10_000 + t)
    if t % HEAVY_EVERY == 0:
        # insert-heavy AND clustered: 40 fresh keys inside a ~600-wide
        # window span only a handful of buckets, so successive heavy
        # batches overflow a chain and force a mid-workload restructure
        base = 1000  # same window every heavy batch: load accumulates
        keys = np.concatenate(
            [
                base + rng.choice(600, 40, replace=False),
                rng.choice(KEY_SPACE, BATCH - 40, replace=False),
            ]
        ).astype(np.int32)
        tag = np.where(np.arange(BATCH) < 40, OP_INSERT, OP_POINT).astype(np.int32)
    else:
        keys = rng.choice(KEY_SPACE, BATCH, replace=False).astype(np.int32)
        tag = rng.choice(
            np.array([OP_INSERT, OP_DELETE, OP_POINT, OP_SUCCESSOR], np.int32),
            BATCH,
            p=[0.3, 0.25, 0.25, 0.2],
        )
        tag[: 2 + t % 3] = OP_RANGE  # a few ranges ride along
    vals = (keys * 13 + t).astype(np.int32)
    is_range = tag == OP_RANGE
    vals[is_range] = np.minimum(keys[is_range] + 200, KEY_SPACE)  # hi bound
    order = np.argsort(keys, kind="stable")
    max_results = 32 if t % 2 else 64
    return tag[order], keys[order], vals[order], max_results


def oracle_canonical(n_batches: int, seed: int = 0, engine=None) -> list[bytes]:
    """Canonical payload after each seq, uninterrupted: ``oracle[s]`` is
    the expected bytes of any recovery that lands on seq ``s``."""
    engine = engine or make_engine()
    handle = engine.rebuild(*initial_pairs(seed))
    out = [canonical_state_bytes(engine.flix(handle))]
    for t in range(1, n_batches + 1):
        tag, key, val, mr = make_batch_host(t, seed)
        handle, _res, _stats, _r = engine.apply(
            handle, OpBatch.from_host(tag, key, val), max_results=mr
        )
        out.append(canonical_state_bytes(engine.flix(handle)))
    return out


# ---------------------------------------------------------------------------
# the TTL workload (DESIGN.md §14): same determinism contract, plus a
# virtual clock that is itself a pure function of the batch seq — batch t
# executes at now = t * TTL_TICK, the WAL logs that now, and recovery
# replays each batch at its LOGGED clock (never the wall clock), so an
# interrupted run, its resumption, and the oracle reach byte-identical
# expiry state no matter when the processes actually ran.
# ---------------------------------------------------------------------------

TTL_TICK = 16  # virtual time elapsing between consecutive batches


def initial_pairs_ttl(seed: int = 0):
    """Initial pairs with a deadline column: ~40% carry TTLs spread over
    the first half of the workload's clock, the rest never expire."""
    keys, vals = initial_pairs(seed)
    rng = np.random.default_rng((seed + 1) * 77_000)
    exps = np.where(
        rng.random(keys.shape) < 0.4,
        rng.integers(1, 10 * TTL_TICK, keys.shape),
        int(NO_EXPIRY),
    ).astype(np.int32)
    return keys, vals, exps


def make_batch_host_ttl(t: int, seed: int = 0):
    """TTL batch ``t``: ``(tag, key, val, exp, now, max_results)``, host
    arrays sorted by key, ``now = t * TTL_TICK``.  Pure function of
    ``(t, seed)`` — clock included."""
    rng = np.random.default_rng((seed + 3) * 10_000 + t)
    now = t * TTL_TICK
    keys = rng.choice(KEY_SPACE, BATCH, replace=False).astype(np.int32)
    tag = rng.choice(
        np.array([OP_INSERT, OP_EXPIRE, OP_DELETE, OP_POINT, OP_SUCCESSOR], np.int32),
        BATCH,
        p=[0.3, 0.2, 0.15, 0.2, 0.15],
    )
    tag[: 2 + t % 3] = OP_RANGE  # a few ranges ride along
    vals = (keys * 13 + t).astype(np.int32)
    is_range = tag == OP_RANGE
    vals[is_range] = np.minimum(keys[is_range] + 200, KEY_SPACE)  # hi bound
    # deadlines cluster around now: some dead-on-arrival (§14 edge), most
    # fall due within the next few batches, EXPIRE always refreshes forward
    writes = (tag == OP_INSERT) | (tag == OP_EXPIRE)
    exp = np.full(BATCH, int(NO_EXPIRY), np.int32)
    exp[writes] = now + rng.integers(
        -TTL_TICK // 2, 5 * TTL_TICK, int(writes.sum())
    ).astype(np.int32)
    order = np.argsort(keys, kind="stable")
    max_results = 32 if t % 2 else 64
    return tag[order], keys[order], vals[order], exp[order], now, max_results


def oracle_canonical_ttl(n_batches: int, seed: int = 0, engine=None) -> list[bytes]:
    """TTL analogue of ``oracle_canonical``: canonical payload (expiry
    column included) after each seq of the uninterrupted TTL run."""
    engine = engine or make_engine()
    handle = engine.rebuild(*initial_pairs_ttl(seed))
    out = [canonical_state_bytes(engine.flix(handle))]
    for t in range(1, n_batches + 1):
        tag, key, val, exp, now, mr = make_batch_host_ttl(t, seed)
        handle, _res, _stats, _r = engine.apply(
            handle, OpBatch.from_host(tag, key, val, exp), max_results=mr, now=now
        )
        out.append(canonical_state_bytes(engine.flix(handle)))
    return out


def run_workload_ttl(
    directory,
    n_batches: int,
    *,
    seed: int = 0,
    snapshot_every: int = SNAPSHOT_EVERY,
    full_every: int = FULL_EVERY,
    fsync: bool = True,
    crash_hook=None,
    engine=None,
    ack=None,
):
    """TTL analogue of ``run_workload``: create-or-recover in
    ``directory`` and apply TTL batches (each at its own virtual ``now``)
    until seq reaches ``n_batches``."""
    engine = engine or make_engine()
    if DurableFliX.exists(directory):
        dur = DurableFliX.open(
            directory,
            engine=engine,
            snapshot_every=snapshot_every,
            full_every=full_every,
            fsync=fsync,
            crash_hook=crash_hook,
        )
    else:
        dur = DurableFliX.create(
            directory,
            engine.rebuild(*initial_pairs_ttl(seed)),
            engine=engine,
            snapshot_every=snapshot_every,
            full_every=full_every,
            fsync=fsync,
            crash_hook=crash_hook,
        )
    while dur.seq < n_batches:
        tag, key, val, exp, now, mr = make_batch_host_ttl(dur.seq + 1, seed)
        dur.apply(
            OpBatch.from_host(tag, key, val, exp),
            config=ExecConfig(max_results=mr),
            now=now,
        )
        if ack is not None:
            ack(dur.seq)
    dur.close()
    return dur.seq


# ---------------------------------------------------------------------------
# crash hooks
# ---------------------------------------------------------------------------


class CrashError(BaseException):
    """Simulated process death (BaseException: nothing may catch it)."""


# The serving gateway threads the same hook through its commit path
# (tests/traffic_replay.py wires one hook into BOTH layers), so one
# CrashAt/KillAt can fire anywhere between batch formation and client ack:
GATEWAY_EVENTS = (
    "gateway.batch.formed",  # batch built, engine step not yet submitted
    "gateway.step.done",  # step committed (durable if updates), acks not out
    "gateway.acked",  # every ticket in the batch resolved
)


class CrashAt:
    """Fire at the ``count``-th occurrence of ``event``."""

    def __init__(self, event: str, count: int = 1):
        self.event = event
        self.count = count
        self.seen = 0

    def __call__(self, event: str) -> None:
        if event == self.event:
            self.seen += 1
            if self.seen == self.count:
                self.fire()

    def fire(self):
        raise CrashError(f"{self.event}#{self.count}")


class KillAt(CrashAt):
    """Genuine process death: uncatchable, no flushing, no atexit."""

    def fire(self):
        os.kill(os.getpid(), signal.SIGKILL)


# ---------------------------------------------------------------------------
# the workload runner (parent in-process, or subprocess child via __main__)
# ---------------------------------------------------------------------------


def run_workload(
    directory,
    n_batches: int,
    *,
    seed: int = 0,
    snapshot_every: int = SNAPSHOT_EVERY,
    full_every: int = FULL_EVERY,
    fsync: bool = True,
    crash_hook=None,
    engine=None,
    ack=None,
    ret: str = "seq",
):
    """Create-or-recover a durable index in ``directory`` and apply the
    deterministic workload until seq reaches ``n_batches``.  ``ack(seq)``
    fires after each durably applied batch.  Returns the final seq, or the
    still-open instance with ``ret="instance"``."""
    engine = engine or make_engine()
    if DurableFliX.exists(directory):
        dur = DurableFliX.open(
            directory,
            engine=engine,
            snapshot_every=snapshot_every,
            full_every=full_every,
            fsync=fsync,
            crash_hook=crash_hook,
        )
    else:
        dur = DurableFliX.create(
            directory,
            engine.rebuild(*initial_pairs(seed)),
            engine=engine,
            snapshot_every=snapshot_every,
            full_every=full_every,
            fsync=fsync,
            crash_hook=crash_hook,
        )
    while dur.seq < n_batches:
        tag, key, val, mr = make_batch_host(dur.seq + 1, seed)
        dur.apply(OpBatch.from_host(tag, key, val), config=ExecConfig(max_results=mr))
        if ack is not None:
            ack(dur.seq)
    if ret == "instance":
        return dur
    dur.close()
    return dur.seq


def recover_and_check(
    directory,
    oracle: list[bytes],
    *,
    acked: int = 0,
    engine=None,
    snapshot_every: int = SNAPSHOT_EVERY,
    full_every: int = FULL_EVERY,
    **open_kw,
):
    """THE durability property.  Recover and assert:

    1. no acknowledged batch was lost (``seq >= acked``), and
    2. the recovered state is byte-identical to the uninterrupted run at
       that seq (``canonical == oracle[seq]``).

    Returns the recovered seq."""
    dur = DurableFliX.open(
        directory,
        engine=engine or make_engine(),
        snapshot_every=snapshot_every,
        full_every=full_every,
        **open_kw,
    )
    try:
        seq = dur.seq
        assert seq >= acked, f"lost acked batches: recovered {seq} < acked {acked}"
        assert seq < len(oracle), f"recovered seq {seq} beyond oracle"
        got = canonical_state_bytes(dur.state)
        assert got == oracle[seq], f"recovered state at seq {seq} != oracle"
    finally:
        dur.close()
    return seq


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kill-event", default=None)
    ap.add_argument("--kill-count", type=int, default=1)
    ap.add_argument("--no-fsync", action="store_true")
    ap.add_argument("--snapshot-every", type=int, default=SNAPSHOT_EVERY)
    args = ap.parse_args()

    hook = KillAt(args.kill_event, args.kill_count) if args.kill_event else None
    seq = run_workload(
        args.dir,
        args.batches,
        seed=args.seed,
        snapshot_every=args.snapshot_every,
        fsync=not args.no_fsync,
        crash_hook=hook,
        ack=lambda s: print(f"ACK {s}", flush=True),
    )
    print(f"DONE {seq}", flush=True)


if __name__ == "__main__":
    main()
