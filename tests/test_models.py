"""Per-arch smoke tests (reduced configs): forward, train step, decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.models.frontends import synthetic_prefix
from repro.models.model import get_config, init_params, list_archs, param_count
from repro.train import make_train_step, train_state_init

ARCHS = [
    "qwen2.5-32b", "starcoder2-15b", "h2o-danube-3-4b", "gemma3-12b",
    "deepseek-moe-16b", "mixtral-8x22b", "zamba2-2.7b", "paligemma-3b",
    "mamba2-1.3b", "musicgen-medium",
]


def test_registry_complete():
    assert sorted(ARCHS) == list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes right, loss finite."""
    cfg = get_config(arch).reduced(dtype="float32")
    rng = jax.random.PRNGKey(0)
    B, S = 2, 32
    text = S - (cfg.frontend_len if cfg.frontend else 0)
    tokens = jax.random.randint(rng, (B, text), 0, cfg.vocab_size)

    params = init_params(rng, cfg)
    assert param_count(params) > 0
    prefix = synthetic_prefix(rng, cfg, B)
    logits = tf.forward(params, cfg, tokens, prefix)
    assert logits.shape == (B, S if cfg.frontend else text, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    state = train_state_init(rng, cfg)
    step = jax.jit(make_train_step(cfg, loss_chunk=8))
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.zeros((B, cfg.frontend_len, cfg.d_model))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize(
    "arch", ["gemma3-12b", "deepseek-moe-16b", "mamba2-1.3b", "zamba2-2.7b"]
)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced(dtype="float32", moe_capacity_factor=8.0)
    rng = jax.random.PRNGKey(1)
    params = init_params(rng, cfg)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = tf.forward(params, cfg, tokens)
    cache = tf.init_cache(cfg, B, max_len=S, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_swa_ring_buffer_wraparound():
    cfg = get_config("h2o-danube-3-4b").reduced(dtype="float32", window=8)
    rng = jax.random.PRNGKey(2)
    params = init_params(rng, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = tf.forward(params, cfg, tokens)
    cache = tf.init_cache(cfg, B, max_len=S, dtype=jnp.float32)
    assert cache["layers"][0]["k"].shape[1] == 8  # ring, not full length
    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_local_global_cache_sizes():
    cfg = get_config("gemma3-12b").reduced(dtype="float32", num_layers=6, window=8)
    cache = tf.init_cache(cfg, batch=2, max_len=64, dtype=jnp.float32)
    sizes = [c["k"].shape[1] for c in cache["layers"]]
    assert sizes == [8, 8, 8, 8, 8, 64]  # 5 local rings + 1 global


def test_padded_config_preserves_forward_shape():
    cfg = get_config("qwen2.5-32b").reduced(dtype="float32")
    padded = cfg.padded(4)
    assert padded.num_heads % 4 == 0 and padded.num_kv_heads % 4 == 0
    rng = jax.random.PRNGKey(3)
    params = init_params(rng, padded)
    tokens = jax.random.randint(rng, (2, 16), 0, padded.vocab_size)
    logits = tf.forward(params, padded, tokens)
    assert logits.shape == (2, 16, padded.vocab_size)


def test_ssd_chunked_matches_recurrence(rng):
    from repro.models.ssm import ssd_chunked, ssd_decode_step

    B, S, H, P, N = 2, 32, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray((np.abs(rng.normal(size=(B, S, H))) * 0.5 + 0.1).astype(np.float32))
    A = jnp.asarray((-np.abs(rng.normal(size=(H,))) - 0.1).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y, st = ssd_decode_step(st, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    ref = jnp.stack(ys, axis=1)
    out, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st), rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_are_bounded(rng):
    """With generous capacity, flipped-capacity MoE == dense oracle."""
    from repro.models.moe import moe_ffn, moe_ffn_dense_oracle

    cfg = get_config("deepseek-moe-16b").reduced(
        dtype="float32", moe_capacity_factor=8.0
    )
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k = jax.random.split(jax.random.PRNGKey(4), 8)
    p = {
        "router": jax.random.normal(k[0], (D, E)) * 0.1,
        "w_gate": jax.random.normal(k[1], (E, D, F)) * 0.05,
        "w_up": jax.random.normal(k[2], (E, D, F)) * 0.05,
        "w_down": jax.random.normal(k[3], (E, F, D)) * 0.05,
        "shared_gate": jax.random.normal(k[4], (D, cfg.num_shared_experts * F)) * 0.05,
        "shared_up": jax.random.normal(k[5], (D, cfg.num_shared_experts * F)) * 0.05,
        "shared_down": jax.random.normal(k[6], (cfg.num_shared_experts * F, D)) * 0.05,
    }
    x = jax.random.normal(k[7], (64, D))
    got = moe_ffn(x, p, cfg)
    want = moe_ffn_dense_oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-4)
