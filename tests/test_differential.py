"""Differential parity: Pallas kernels vs jnp oracles, mixed vs per-type.

Three families of proofs:

  1. Every Pallas kernel (flix_query, flix_insert, flix_delete,
     flix_successor) matches its jnp oracle bit-for-bit in interpret mode on
     *adversarial* batches — duplicate queries, all-miss batches, boundary
     keys (0 and MAX_VALID), and states with emptied buckets and multi-node
     chains.
  2. ``apply_ops`` on a mixed batch is byte-identical — state arrays and
     per-op results — to sequential per-type application of the present op
     classes (insert → delete → point → successor on sorted sub-batches).
  3. The fused compute-to-bucket apply kernel (``kernels/flix_apply``,
     ``apply_ops(impl="fused")``) matches the reference engine on the same
     adversarial batches across every op-mix ratio — RANGE included, from
     single-class extremes to the fig-style 90/10 read/update shape — with
     byte-identical dense range output, and a RANGE in a mixed batch
     observes that batch's inserts and deletes (update-then-read), incl.
     overflow + restructure retries (live-position vals, like the
     per-kernel proofs: vals at EMPTY slots are unspecified for the jnp
     merge).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.invariants import check_invariants
from repro.core.state import EMPTY, MAX_VALID, NOT_FOUND
from repro.kernels import ref
from repro.kernels.flix_delete import flix_delete_pallas
from repro.kernels.flix_insert import flix_insert_pallas
from repro.kernels.flix_query import flix_point_query_pallas
from repro.kernels.flix_successor import flix_successor_pallas
from repro.core.config import ExecConfig

STATE_FIELDS = ("keys", "vals", "node_count", "node_max", "num_nodes", "mkba")


def _assert_states_identical(a: core.FliXState, b: core.FliXState):
    for f in STATE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )
    assert bool(a.needs_restructure) == bool(b.needs_restructure)


@pytest.fixture
def adversarial(rng):
    """A state with boundary keys, multi-node chains, and emptied buckets."""
    keys = rng.choice(120000, size=2500, replace=False).astype(np.int32)
    keys = np.unique(np.concatenate([keys, [0, int(MAX_VALID)]])).astype(np.int32)
    st = core.build(
        keys, np.arange(len(keys), dtype=np.int32), node_size=8, nodes_per_bucket=8
    )
    # grow chains so several buckets hold multiple nodes
    extra = np.setdiff1d(
        rng.choice(120000, 5000).astype(np.int32), keys
    )[:1500]
    sk, sv = core.sort_batch(
        jnp.asarray(extra), jnp.asarray(np.arange(1500, dtype=np.int32))
    )
    st, _ = core.insert_safe(st, sk, sv)
    # empty out a key range spanning whole buckets
    st, _ = core.delete(st, jnp.asarray(np.arange(30000, 60000, dtype=np.int32)))
    check_invariants(st)
    live = np.unique(np.concatenate([keys, extra]))
    live = live[(live < 30000) | (live >= 60000)].astype(np.int32)
    return st, live


def _adversarial_query_batches(rng, live):
    absent = np.setdiff1d(
        np.arange(0, 130000, 7, dtype=np.int32), live
    )
    return {
        "duplicates": np.sort(np.repeat(rng.choice(live, 40), 8)).astype(np.int32),
        "all_miss": np.sort(rng.choice(absent, 300)).astype(np.int32),
        "boundary": np.array(
            [0, 0, 1, int(MAX_VALID) - 1, int(MAX_VALID), int(MAX_VALID)], np.int32
        ),
        "empty_buckets": np.arange(29000, 61000, 50, dtype=np.int32),
        "mixed": np.sort(
            np.concatenate([rng.choice(live, 200), rng.choice(absent, 200)])
        ).astype(np.int32),
    }


def test_point_query_kernel_adversarial(adversarial, rng):
    st, live = adversarial
    for name, q in _adversarial_query_batches(rng, live).items():
        qj = jnp.asarray(q)
        want = ref.flix_point_query_ref(st.keys, st.vals, st.node_max, st.mkba, qj)
        got = flix_point_query_pallas(
            st.keys, st.vals, st.node_max, st.mkba, qj, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got), err_msg=name)
        # oracle itself agrees with the core form
        np.testing.assert_array_equal(
            np.asarray(want), np.asarray(core.point_query(st, qj)), err_msg=name
        )


def test_successor_kernel_adversarial(adversarial, rng):
    st, live = adversarial
    for name, q in _adversarial_query_batches(rng, live).items():
        qj = jnp.asarray(q)
        wk, wv = ref.flix_successor_ref(st.keys, st.vals, st.node_max, st.mkba, qj)
        gk, gv = flix_successor_pallas(
            st.keys, st.vals, st.node_max, st.mkba, qj, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(wk), np.asarray(gk), err_msg=name)
        np.testing.assert_array_equal(np.asarray(wv), np.asarray(gv), err_msg=name)
        ck, cv = core.successor_query(st, qj)
        np.testing.assert_array_equal(np.asarray(wk), np.asarray(ck), err_msg=name)
        np.testing.assert_array_equal(np.asarray(wv), np.asarray(cv), err_msg=name)


def test_insert_kernel_adversarial(adversarial, rng):
    st, live = adversarial
    absent = np.setdiff1d(np.arange(0, 130000, 11, dtype=np.int32), live)
    batches = {
        # upserts of stored keys mixed with fresh keys, incl. boundary keys
        "upsert_mix": np.concatenate(
            [rng.choice(live, 150, replace=False), absent[:150], [0, int(MAX_VALID)]]
        ),
        # aimed at the emptied bucket range
        "empty_buckets": np.arange(31000, 59000, 120, dtype=np.int32),
    }
    for name, b in batches.items():
        b = np.unique(b).astype(np.int32)
        v = np.arange(len(b), dtype=np.int32) + 7_000_000
        sk, sv = core.sort_batch(jnp.asarray(b), jnp.asarray(v))
        want, _ = core.insert(st, sk, sv)
        got, _ = flix_insert_pallas(st, sk, sv, interpret=True)
        # vals at EMPTY slots are unspecified for the jnp merge (garbage from
        # the re-sort) — compare live positions exactly, like test_kernels
        for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want, f)), np.asarray(getattr(got, f)), err_msg=name
            )
        mask = np.asarray(want.keys) != int(EMPTY)
        np.testing.assert_array_equal(
            np.asarray(want.vals)[mask], np.asarray(got.vals)[mask], err_msg=name
        )
        assert bool(want.needs_restructure) == bool(got.needs_restructure)


def test_delete_kernel_adversarial(adversarial, rng):
    st, live = adversarial
    absent = np.setdiff1d(np.arange(0, 130000, 13, dtype=np.int32), live)
    batches = {
        "all_miss": np.sort(absent[:400]),
        "duplicates": np.sort(np.repeat(rng.choice(live, 60, replace=False), 5)),
        "boundary": np.array([0, int(MAX_VALID)], np.int32),
        "skewed_range": np.arange(60000, 90000, dtype=np.int32),
    }
    for name, b in batches.items():
        bj = jnp.asarray(b.astype(np.int32))
        want, _ = core.delete(st, bj)
        got = flix_delete_pallas(st, bj, interpret=True)
        # vals at freed slots are unspecified (jnp keeps garbage, the kernel
        # zeroes) — compare live positions exactly
        for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want, f)), np.asarray(getattr(got, f)), err_msg=name
            )
        mask = np.asarray(want.keys) != int(EMPTY)
        np.testing.assert_array_equal(
            np.asarray(want.vals)[mask], np.asarray(got.vals)[mask], err_msg=name
        )
        check_invariants(got)


# ---------------------------------------------------------------------------
# apply_ops: mixed == sequential per-type, byte-identical
# ---------------------------------------------------------------------------


def _sequential(state, tags, keys, vals):
    """Reference semantics: apply present op classes in engine order."""
    s = state
    ins = tags == core.OP_INSERT
    if ins.any():
        sk, sv = core.sort_batch(jnp.asarray(keys[ins]), jnp.asarray(vals[ins]))
        s, _ = core.insert(s, sk, sv)
    dels = tags == core.OP_DELETE
    if dels.any():
        s, _ = core.delete(s, jnp.asarray(np.sort(keys[dels])))
    points = np.sort(keys[tags == core.OP_POINT])
    pv = core.point_query(s, jnp.asarray(points)) if points.size else None
    succs = np.sort(keys[tags == core.OP_SUCCESSOR])
    sk_sv = core.successor_query(s, jnp.asarray(succs)) if succs.size else None
    return s, (points, pv), (succs, sk_sv)


def _compare_mixed_vs_sequential(st, tags, keys, vals, *, pad_to=None):
    ops, perm = core.make_ops(tags, keys, vals, pad_to=pad_to)
    s_mixed, res, _ = core.apply_ops(st, ops)
    s_seq, (points, pv), (succs, ssv) = _sequential(st, tags, keys, vals)
    _assert_states_identical(s_mixed, s_seq)

    # results: gather mixed results back to submission order and compare
    # against the sorted per-type query answers
    val_in = np.asarray(core.unsort(res["value"], perm))[: len(keys)]
    key_in = np.asarray(core.unsort(res["succ_key"], perm))[: len(keys)]
    if pv is not None:
        mine = np.sort(val_in[tags == core.OP_POINT])
        np.testing.assert_array_equal(mine, np.sort(np.asarray(pv)))
    if ssv is not None:
        order = np.argsort(keys[tags == core.OP_SUCCESSOR], kind="stable")
        np.testing.assert_array_equal(
            key_in[tags == core.OP_SUCCESSOR][order], np.asarray(ssv[0])
        )
        np.testing.assert_array_equal(
            val_in[tags == core.OP_SUCCESSOR][order], np.asarray(ssv[1])
        )
    # non-read ops report no results
    upd = (tags == core.OP_INSERT) | (tags == core.OP_DELETE)
    assert (val_in[upd] == int(NOT_FOUND)).all()
    assert (key_in[upd] == int(EMPTY)).all()


def test_apply_ops_matches_sequential_full_mix(adversarial, rng):
    st, live = adversarial
    absent = np.setdiff1d(np.arange(0, 130000, 3, dtype=np.int32), live)
    ins = rng.choice(absent, 300, replace=False).astype(np.int32)
    iv = rng.integers(0, 1 << 30, 300).astype(np.int32)
    dels = rng.choice(live, 250, replace=False).astype(np.int32)
    reads = rng.integers(0, 130000, 500).astype(np.int32)
    tags = np.concatenate([
        np.full(300, core.OP_INSERT), np.full(250, core.OP_DELETE),
        np.full(250, core.OP_POINT), np.full(250, core.OP_SUCCESSOR),
    ]).astype(np.int32)
    keys = np.concatenate([ins, dels, reads]).astype(np.int32)
    vals = np.concatenate([iv, np.zeros(750, np.int32)])
    _compare_mixed_vs_sequential(st, tags, keys, vals, pad_to=2048)


@pytest.mark.parametrize(
    "present",
    [
        (core.OP_INSERT,),
        (core.OP_DELETE,),
        (core.OP_POINT,),
        (core.OP_SUCCESSOR,),
        (core.OP_INSERT, core.OP_POINT),
        (core.OP_DELETE, core.OP_SUCCESSOR),
        (core.OP_POINT, core.OP_SUCCESSOR),
    ],
)
def test_apply_ops_partial_mixes(adversarial, rng, present):
    """Absent op classes are skipped — state must match exactly, including
    the lax.cond fast paths (no insert / no delete)."""
    st, live = adversarial
    absent_keys = np.setdiff1d(np.arange(0, 130000, 5, dtype=np.int32), live)
    chunks = {"tags": [], "keys": [], "vals": []}
    pools = {
        core.OP_INSERT: rng.choice(absent_keys, 120, replace=False),
        core.OP_DELETE: rng.choice(live, 120, replace=False),
        core.OP_POINT: rng.integers(0, 130000, 120),
        core.OP_SUCCESSOR: rng.integers(0, 130000, 120),
    }
    for t in present:
        k = pools[t].astype(np.int32)
        chunks["tags"].append(np.full(len(k), t, np.int32))
        chunks["keys"].append(k)
        chunks["vals"].append(
            np.arange(len(k), dtype=np.int32) if t == core.OP_INSERT
            else np.zeros(len(k), np.int32)
        )
    tags = np.concatenate(chunks["tags"])
    keys = np.concatenate(chunks["keys"])
    vals = np.concatenate(chunks["vals"])
    _compare_mixed_vs_sequential(st, tags, keys, vals, pad_to=512)


# ---------------------------------------------------------------------------
# fused apply kernel: apply_ops(impl="fused") == apply_ops(impl="reference")
# ---------------------------------------------------------------------------


def _assert_fused_matches_reference(
    st, tags, keys, vals, *, pad_to, max_results=128, pipeline="auto"
):
    ops, _ = core.make_ops(tags, keys, vals, pad_to=pad_to)
    s_ref, r_ref, stats_ref = core.apply_ops(
        st, ops, config=ExecConfig(impl="reference", max_results=max_results)
    )
    s_f, r_f, stats_f = core.apply_ops(
        st,
        ops,
        config=ExecConfig(impl="fused", max_results=max_results, pipeline=pipeline),
    )
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, f)), np.asarray(getattr(s_f, f)), err_msg=f
        )
    mask = np.asarray(s_ref.keys) != int(EMPTY)
    np.testing.assert_array_equal(
        np.asarray(s_ref.vals)[mask], np.asarray(s_f.vals)[mask]
    )
    assert bool(s_ref.needs_restructure) == bool(s_f.needs_restructure)
    for k in ("value", "succ_key", "range_key", "range_val",
              "range_start", "range_count"):
        np.testing.assert_array_equal(
            np.asarray(r_ref[k]), np.asarray(r_f[k]), err_msg=k
        )
    for k in stats_ref:
        assert int(stats_ref[k]) == int(stats_f[k]), k
    if not bool(s_f.needs_restructure):
        check_invariants(s_f)
        core.check_range_results(ops, r_f, max_results=max_results)
    return ops, r_ref, stats_ref


@pytest.mark.parametrize(
    "present",
    [
        (core.OP_INSERT,),
        (core.OP_DELETE,),
        (core.OP_POINT,),
        (core.OP_SUCCESSOR,),
        (core.OP_RANGE,),
        (core.OP_INSERT, core.OP_POINT),
        (core.OP_DELETE, core.OP_SUCCESSOR),
        (core.OP_POINT, core.OP_SUCCESSOR),
        (core.OP_INSERT, core.OP_RANGE),
        (core.OP_DELETE, core.OP_RANGE),
        (core.OP_RANGE, core.OP_SUCCESSOR),
    ],
)
def test_fused_apply_partial_mixes(adversarial, rng, present):
    """Every op-mix ratio, including the single-class extremes — the fused
    kernel has no per-phase skip conds, so absent classes must fall out of
    the math (empty tiles merge/delete to identity)."""
    st, live = adversarial
    absent_keys = np.setdiff1d(np.arange(0, 130000, 5, dtype=np.int32), live)
    pools = {
        core.OP_INSERT: rng.choice(absent_keys, 120, replace=False),
        core.OP_DELETE: rng.choice(live, 120, replace=False),
        core.OP_POINT: rng.integers(0, 130000, 120),
        core.OP_SUCCESSOR: rng.integers(0, 130000, 120),
        core.OP_RANGE: np.sort(rng.integers(0, 125000, 40)),
    }
    tags, keys, vals = [], [], []
    for t in present:
        k = pools[t].astype(np.int32)
        tags.append(np.full(len(k), t, np.int32))
        keys.append(k)
        if t == core.OP_INSERT:
            vals.append(np.arange(len(k), dtype=np.int32) + 3_000_000)
        elif t == core.OP_RANGE:
            vals.append((k + rng.integers(0, 2000, len(k))).astype(np.int32))
        else:
            vals.append(np.zeros(len(k), np.int32))
    _assert_fused_matches_reference(
        st,
        np.concatenate(tags),
        np.concatenate(keys),
        np.concatenate(vals),
        pad_to=512,
        max_results=256,
    )


def test_fused_apply_full_mix_adversarial(adversarial, rng):
    """Full mix on the adversarial state: upserts of stored keys, deletions,
    duplicate + boundary + emptied-bucket reads, ranges spanning emptied and
    boundary regions, multi-window batch."""
    st, live = adversarial
    absent = np.setdiff1d(np.arange(0, 130000, 3, dtype=np.int32), live)
    ins = np.concatenate(
        [rng.choice(absent, 200, replace=False), rng.choice(live, 100, replace=False)]
    ).astype(np.int32)  # upserts included
    iv = rng.integers(0, 1 << 30, 300).astype(np.int32)
    dels = np.setdiff1d(rng.choice(live, 250, replace=False), ins).astype(np.int32)
    reads = np.concatenate([
        np.repeat(rng.choice(live, 30), 4),
        rng.choice(absent, 100),
        [0, int(MAX_VALID) - 1, int(MAX_VALID)],
        np.arange(29000, 61000, 250),
    ]).astype(np.int32)
    rlo = np.concatenate([
        rng.integers(0, 125000, 24),
        [0, 29500, int(MAX_VALID) - 5],        # boundary + emptied regions
    ]).astype(np.int32)
    rhi = np.concatenate([
        rlo[:24] + rng.integers(0, 3000, 24),
        [50, 60500, int(EMPTY)],
    ]).astype(np.int32)
    tags = np.concatenate([
        np.full(len(ins), core.OP_INSERT),
        np.full(len(dels), core.OP_DELETE),
        np.where(np.arange(len(reads)) % 2 == 0, core.OP_POINT, core.OP_SUCCESSOR),
        np.full(len(rlo), core.OP_RANGE),
    ]).astype(np.int32)
    keys = np.concatenate([ins, dels, reads, rlo]).astype(np.int32)
    vals = np.concatenate(
        [iv, np.zeros(len(dels) + len(reads), np.int32), rhi]
    )
    _assert_fused_matches_reference(
        st, tags, keys, vals, pad_to=2048, max_results=512
    )


def test_fused_apply_overflow_flag_and_state(rng):
    """An overflowing batch: the pre-retry states (untrustworthy buckets
    included) and the restructure flag agree between the two executors."""
    keys = np.arange(0, 640, 10, dtype=np.int32)
    st = core.build(keys, keys, node_size=4, nodes_per_bucket=2)
    flood = np.arange(1, 200, 2, dtype=np.int32)
    tags = np.concatenate([
        np.full(len(flood), core.OP_INSERT),
        np.full(len(keys), core.OP_POINT),
    ]).astype(np.int32)
    bkeys = np.concatenate([flood, keys]).astype(np.int32)
    bvals = np.concatenate([flood, np.zeros(len(keys), np.int32)])
    ops, _ = core.make_ops(tags, bkeys, bvals, pad_to=256)
    s_ref, _, stats_ref = core.apply_ops(st, ops, config=ExecConfig(impl="reference"))
    s_f, _, stats_f = core.apply_ops(st, ops, config=ExecConfig(impl="fused"))
    assert bool(s_ref.needs_restructure) and bool(s_f.needs_restructure)
    assert int(stats_ref["overflowed_buckets"]) == int(stats_f["overflowed_buckets"])
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, f)), np.asarray(getattr(s_f, f)), err_msg=f
        )


def test_fused_apply_range_heavy_90_10(adversarial, rng):
    """The fig-style 90/10 read/update shape with RANGE carrying the read
    side: 90% range+point reads, 10% updates — byte-identical executors."""
    st, live = adversarial
    absent = np.setdiff1d(np.arange(0, 130000, 3, dtype=np.int32), live)
    n = 400
    n_upd = n // 10
    ins = rng.choice(absent, n_upd // 2, replace=False).astype(np.int32)
    dels = rng.choice(live, n_upd - n_upd // 2, replace=False).astype(np.int32)
    n_read = n - n_upd
    n_rng = n_read // 2
    rlo = np.sort(rng.integers(0, 125000, n_rng)).astype(np.int32)
    rhi = (rlo + rng.integers(0, 1500, n_rng)).astype(np.int32)
    points = rng.integers(0, 130000, n_read - n_rng).astype(np.int32)
    tags = np.concatenate([
        np.full(len(ins), core.OP_INSERT),
        np.full(len(dels), core.OP_DELETE),
        np.full(n_rng, core.OP_RANGE),
        np.full(len(points), core.OP_POINT),
    ]).astype(np.int32)
    keys = np.concatenate([ins, dels, rlo, points]).astype(np.int32)
    vals = np.concatenate([
        np.arange(len(ins), dtype=np.int32) + 5_000_000,
        np.zeros(len(dels), np.int32),
        rhi,
        np.zeros(len(points), np.int32),
    ])
    _assert_fused_matches_reference(
        st, tags, keys, vals, pad_to=512, max_results=1024
    )


def test_range_observes_same_batch_updates(adversarial, rng):
    """Update-then-read inside one batch: a RANGE must see that batch's
    inserts and must not see its deletes — on both executors."""
    st, live = adversarial
    absent = np.setdiff1d(np.arange(70000, 90000, 3, dtype=np.int32), live)
    ins = rng.choice(absent, 40, replace=False).astype(np.int32)
    iv = (ins + 1_000_000).astype(np.int32)
    dels = live[(live >= 70000) & (live < 90000)][:40].astype(np.int32)
    # one range covering exactly the churned region, plus tight ranges
    # pinned on individual inserted and deleted keys
    rlo = np.concatenate([[70000], ins[:5], dels[:5]]).astype(np.int32)
    rhi = np.concatenate([[90000], ins[:5] + 1, dels[:5] + 1]).astype(np.int32)
    tags = np.concatenate([
        np.full(len(ins), core.OP_INSERT),
        np.full(len(dels), core.OP_DELETE),
        np.full(len(rlo), core.OP_RANGE),
    ]).astype(np.int32)
    keys = np.concatenate([ins, dels, rlo]).astype(np.int32)
    vals = np.concatenate([iv, np.zeros(len(dels), np.int32), rhi])
    ops, r_ref, _ = _assert_fused_matches_reference(
        st, tags, keys, vals, pad_to=512, max_results=2048
    )
    # model the post-update region contents
    region = set(
        live[(live >= 70000) & (live < 90000)].tolist()
    ) - set(dels.tolist()) | set(ins.tolist())
    t = np.asarray(ops.tag)
    kk, vv = np.asarray(ops.key), np.asarray(ops.val)
    rs = np.asarray(r_ref["range_start"])
    rc = np.asarray(r_ref["range_count"])
    dk = np.asarray(r_ref["range_key"])
    dv = np.asarray(r_ref["range_val"])
    val_of = dict(zip(ins.tolist(), iv.tolist()))
    for i in np.nonzero(t == core.OP_RANGE)[0]:
        seg = dk[rs[i] : rs[i] + rc[i]]
        expect = np.array(
            sorted(k for k in region if kk[i] <= k < vv[i]), np.int32
        )
        np.testing.assert_array_equal(seg, expect, err_msg=f"op {i}")
        for j in range(rc[i]):  # inserted keys carry this batch's values
            k = int(dk[rs[i] + j])
            if k in val_of:
                assert dv[rs[i] + j] == val_of[k]
        assert not set(seg.tolist()) & set(dels.tolist())


def test_apply_ops_safe_overflow_recovery(rng):
    """A flooding mixed batch triggers restructure-and-retry, after which the
    state answers every op of the batch correctly."""
    keys = np.arange(0, 640, 10, dtype=np.int32)
    st = core.build(keys, keys, node_size=4, nodes_per_bucket=2)
    flood = np.arange(1, 200, 2, dtype=np.int32)
    points = np.arange(0, 640, 10, dtype=np.int32)
    tags = np.concatenate([
        np.full(len(flood), core.OP_INSERT), np.full(len(points), core.OP_POINT)
    ]).astype(np.int32)
    ops, perm = core.make_ops(
        tags, np.concatenate([flood, points]),
        np.concatenate([flood, np.zeros(len(points), np.int32)]),
    )
    st2, res, stats = core.apply_ops_safe(st, ops)
    assert not bool(st2.needs_restructure)
    check_invariants(st2)
    res_in = np.asarray(core.unsort(res["value"], perm))
    np.testing.assert_array_equal(res_in[len(flood):], points)
    got = np.asarray(core.point_query(st2, jnp.asarray(np.sort(flood))))
    np.testing.assert_array_equal(got, np.sort(flood))

# ---------------------------------------------------------------------------
# pipelined fused kernel: double-buffered staging == single-buffer, byte-exact
# ---------------------------------------------------------------------------


def _fused_both_pipelines(st, ops, *, max_results=128, now=None):
    """Run the fused executor with the double-buffered kernel forced on and
    forced off; assert the two runs are byte-identical; return the on-run."""
    outs = {}
    for mode in ("on", "off"):
        outs[mode] = core.apply_ops(
            st,
            ops,
            now=now,
            config=ExecConfig(impl="fused", pipeline=mode, max_results=max_results),
        )
    s_on, r_on, t_on = outs["on"]
    s_off, r_off, t_off = outs["off"]
    for f in STATE_FIELDS + (("exps",) if s_on.exps is not None else ()):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_on, f)), np.asarray(getattr(s_off, f)), err_msg=f
        )
    assert bool(s_on.needs_restructure) == bool(s_off.needs_restructure)
    for k in r_on:
        np.testing.assert_array_equal(
            np.asarray(r_on[k]), np.asarray(r_off[k]), err_msg=k
        )
    for k in t_on:
        assert int(t_on[k]) == int(t_off[k]), k
    return outs["on"]


@pytest.mark.parametrize(
    "present",
    [
        (core.OP_INSERT,),
        (core.OP_DELETE,),
        (core.OP_INSERT, core.OP_POINT),
        (core.OP_RANGE, core.OP_SUCCESSOR),
        (core.OP_INSERT, core.OP_DELETE, core.OP_POINT,
         core.OP_SUCCESSOR, core.OP_RANGE),
    ],
)
def test_pipelined_kernel_partial_mixes(adversarial, rng, present):
    """The double-buffered DMA kernel forced on (interpret mode) matches the
    reference engine on the adversarial mixes — same grid as the fused
    proofs, now through the explicit two-slot staging path."""
    st, live = adversarial
    absent_keys = np.setdiff1d(np.arange(0, 130000, 5, dtype=np.int32), live)
    pools = {
        core.OP_INSERT: rng.choice(absent_keys, 120, replace=False),
        core.OP_DELETE: rng.choice(live, 120, replace=False),
        core.OP_POINT: rng.integers(0, 130000, 120),
        core.OP_SUCCESSOR: rng.integers(0, 130000, 120),
        core.OP_RANGE: np.sort(rng.integers(0, 125000, 40)),
    }
    tags, keys, vals = [], [], []
    for t in present:
        k = pools[t].astype(np.int32)
        tags.append(np.full(len(k), t, np.int32))
        keys.append(k)
        if t == core.OP_INSERT:
            vals.append(np.arange(len(k), dtype=np.int32) + 3_000_000)
        elif t == core.OP_RANGE:
            vals.append((k + rng.integers(0, 2000, len(k))).astype(np.int32))
        else:
            vals.append(np.zeros(len(k), np.int32))
    _assert_fused_matches_reference(
        st,
        np.concatenate(tags),
        np.concatenate(keys),
        np.concatenate(vals),
        pad_to=512,
        max_results=256,
        pipeline="on",
    )
    ops, _ = core.make_ops(
        np.concatenate(tags), np.concatenate(keys), np.concatenate(vals), pad_to=512
    )
    _fused_both_pipelines(st, ops, max_results=256)


def test_pipelined_kernel_overflow_restructure(rng):
    """An overflowing batch through the double-buffered kernel: the pre-retry
    state bytes and the restructure flag agree with the single-buffer path,
    and the safe driver recovers identically on top of it."""
    keys = np.arange(0, 640, 10, dtype=np.int32)
    st = core.build(keys, keys, node_size=4, nodes_per_bucket=2)
    flood = np.arange(1, 200, 2, dtype=np.int32)
    tags = np.concatenate([
        np.full(len(flood), core.OP_INSERT),
        np.full(len(keys), core.OP_POINT),
    ]).astype(np.int32)
    bkeys = np.concatenate([flood, keys]).astype(np.int32)
    bvals = np.concatenate([flood, np.zeros(len(keys), np.int32)])
    ops, perm = core.make_ops(tags, bkeys, bvals, pad_to=256)
    s_on, _, _ = _fused_both_pipelines(st, ops)
    assert bool(s_on.needs_restructure)
    s2, res, _ = core.apply_ops_safe(
        st, ops, config=ExecConfig(impl="fused", pipeline="on")
    )
    assert not bool(s2.needs_restructure)
    check_invariants(s2)
    res_in = np.asarray(core.unsort(res["value"], perm))
    np.testing.assert_array_equal(res_in[len(flood) : len(flood) + len(keys)], keys)


def test_pipelined_kernel_ttl_batch(adversarial, rng):
    """TTL batches (expiry column + EXPIRE ops + now) through the pipelined
    kernel: both TTL planes ride the same double-buffered apply, so on/off
    must agree byte-for-byte including the expiry column."""
    from repro.core.expiry import NO_EXPIRY, attach_expiry

    st, live = adversarial
    st = attach_expiry(st)
    absent = np.setdiff1d(np.arange(0, 130000, 7, dtype=np.int32), live)
    now = 100
    ins = rng.choice(absent, 60, replace=False).astype(np.int32)
    exp_new = rng.choice(live, 60, replace=False).astype(np.int32)  # get-or-set
    points = rng.choice(live, 60, replace=False).astype(np.int32)
    rlo = np.sort(rng.integers(0, 125000, 20)).astype(np.int32)
    rhi = (rlo + rng.integers(0, 3000, 20)).astype(np.int32)
    tags = np.concatenate([
        np.full(len(ins), core.OP_INSERT),
        np.full(len(exp_new), core.OP_EXPIRE),
        np.full(len(points), core.OP_POINT),
        np.full(len(rlo), core.OP_RANGE),
    ]).astype(np.int32)
    keys = np.concatenate([ins, exp_new, points, rlo]).astype(np.int32)
    vals = np.concatenate([
        ins + 1_000_000,
        exp_new + 2_000_000,
        np.zeros(len(points), np.int32),
        rhi,
    ]).astype(np.int32)
    exps = np.concatenate([
        now + 5 + (ins % 50),                     # TTL'd inserts
        np.full(len(exp_new), now + 40),          # EXPIRE deadlines
        np.full(len(points) + len(rlo), int(NO_EXPIRY)),
    ]).astype(np.int64)
    ops, _ = core.make_ops(tags, keys, vals, exps=jnp.asarray(exps), pad_to=512)
    s_on, r_on, t_on = _fused_both_pipelines(st, ops, max_results=256, now=now)
    # and the pipelined TTL run matches the reference engine exactly
    s_ref, r_ref, t_ref = core.apply_ops(
        st, ops, now=now, config=ExecConfig(impl="reference", max_results=256)
    )
    for f in ("keys", "exps", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, f)), np.asarray(getattr(s_on, f)), err_msg=f
        )
    mask = np.asarray(s_ref.keys) != int(EMPTY)
    np.testing.assert_array_equal(
        np.asarray(s_ref.vals)[mask], np.asarray(s_on.vals)[mask]
    )
    for k in r_ref:
        np.testing.assert_array_equal(
            np.asarray(r_ref[k]), np.asarray(r_on[k]), err_msg=k
        )
    for k in t_ref:
        assert int(t_ref[k]) == int(t_on[k]), k
