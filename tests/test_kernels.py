"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.kernels import ref
from repro.kernels.flix_delete import flix_delete_pallas
from repro.kernels.flix_query import flix_point_query_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.moe_dispatch import combine, dispatch, make_plan, moe_ffn_reference
from repro.kernels.ops import grouped_matmul


@pytest.mark.parametrize("ns,npb", [(8, 4), (16, 8), (32, 4), (14, 8)])
@pytest.mark.parametrize("block_q,block_b", [(128, 8), (256, 4)])
def test_flix_query_kernel_sweep(rng, ns, npb, block_q, block_b):
    keys = rng.choice(200000, size=4000, replace=False).astype(np.int32)
    vals = np.arange(4000, dtype=np.int32)
    st = core.build(keys, vals, node_size=ns, nodes_per_bucket=npb)
    q = np.sort(
        np.concatenate([keys[:1000], rng.integers(0, 200000, 1000).astype(np.int32)])
    )
    want = ref.flix_point_query_ref(st.keys, st.vals, st.node_max, st.mkba, jnp.asarray(q))
    got = flix_point_query_pallas(
        st.keys, st.vals, st.node_max, st.mkba, jnp.asarray(q),
        block_q=block_q, block_b=block_b, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_flix_query_kernel_after_updates(rng):
    """Kernel correctness on a structure with multi-node chains."""
    keys = rng.choice(100000, size=3000, replace=False).astype(np.int32)
    st = core.build(keys, np.arange(3000, dtype=np.int32), node_size=8, nodes_per_bucket=8)
    extra = np.setdiff1d(rng.choice(100000, 6000).astype(np.int32), keys)[:2000]
    sk, sv = core.sort_batch(jnp.asarray(extra), jnp.asarray(np.arange(2000, dtype=np.int32)))
    st, _ = core.insert_safe(st, sk, sv)
    q = jnp.asarray(np.sort(np.concatenate([keys, extra])))
    want = core.point_query(st, q)
    got = flix_point_query_pallas(
        st.keys, st.vals, st.node_max, st.mkba, q, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("ns,npb", [(8, 4), (16, 8), (14, 8)])
@pytest.mark.parametrize("block_q,block_b", [(128, 8), (256, 4)])
def test_flix_successor_kernel_sweep(rng, ns, npb, block_q, block_b):
    from repro.kernels.flix_successor import flix_successor_pallas

    keys = rng.choice(200000, size=4000, replace=False).astype(np.int32)
    st = core.build(keys, np.arange(4000, dtype=np.int32), node_size=ns, nodes_per_bucket=npb)
    # empty some buckets so the next-bucket fallback crosses block boundaries
    st, _ = core.delete(st, jnp.asarray(np.arange(50000, 90000, dtype=np.int32)))
    q = np.sort(
        np.concatenate([keys[:800], rng.integers(0, 210000, 1200).astype(np.int32)])
    ).astype(np.int32)
    want_k, want_v = core.successor_query(st, jnp.asarray(q))
    got_k, got_v = flix_successor_pallas(
        st.keys, st.vals, st.node_max, st.mkba, jnp.asarray(q),
        block_q=block_q, block_b=block_b, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(want_k), np.asarray(got_k))
    np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))


@pytest.mark.parametrize("ns,npb,block_b", [(8, 4, 4), (16, 8, 2), (32, 8, 8)])
def test_flix_delete_kernel_sweep(rng, ns, npb, block_b):
    keys = rng.choice(50000, size=2000, replace=False).astype(np.int32)
    st = core.build(keys, np.arange(2000, dtype=np.int32), node_size=ns, nodes_per_bucket=npb)
    dels = jnp.asarray(np.sort(keys[::3]))
    want, _ = core.delete(st, dels)
    got = flix_delete_pallas(st, dels, block_b=block_b, interpret=True)
    np.testing.assert_array_equal(np.asarray(want.keys), np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(want.node_count), np.asarray(got.node_count))
    np.testing.assert_array_equal(np.asarray(want.node_max), np.asarray(got.node_max))
    np.testing.assert_array_equal(np.asarray(want.num_nodes), np.asarray(got.num_nodes))


@pytest.mark.parametrize("T,D,F,E", [(256, 128, 256, 4), (512, 64, 128, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(rng, T, D, F, E, dtype):
    sizes = rng.multinomial(T, np.ones(E) / E)
    offs = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    x = jnp.asarray(rng.normal(size=(T, D)), dtype=dtype)
    w = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, dtype=dtype)
    want = ref.grouped_matmul_ref(x, w, jnp.asarray(offs))
    got = grouped_matmul_pallas(
        x, w, jnp.asarray(offs), block_t=128, block_f=64, interpret=True
    )
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), rtol=tol, atol=tol)


def test_grouped_matmul_empty_groups(rng):
    T, D, F, E = 256, 64, 128, 8
    offs = np.array([0, 0, 128, 128, 128, 256, 256, 256, 256], np.int32)
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32))
    want = ref.grouped_matmul_ref(x, w, jnp.asarray(offs))
    got = grouped_matmul_pallas(x, w, jnp.asarray(offs), interpret=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got), rtol=1e-5)


def test_flipped_moe_dispatch_matches_dense(rng):
    T, D, F, E, K = 128, 64, 96, 8, 2
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    logits = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
    w_up = jnp.asarray((rng.normal(size=(E, D, F)) * 0.05).astype(np.float32))
    w_down = jnp.asarray((rng.normal(size=(E, F, D)) * 0.05).astype(np.float32))
    plan = make_plan(logits, K, E)
    xs = dispatch(x, plan, K)
    h = jax.nn.silu(grouped_matmul(xs, w_up, plan.group_offsets, mode="ref"))
    ys = grouped_matmul(h, w_down, plan.group_offsets, mode="ref")
    out = combine(ys, plan, K)
    want = moe_ffn_reference(x, logits, w_up, w_down, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("ns,npb", [(8, 4), (16, 8), (14, 4)])
def test_flix_insert_kernel_sweep(rng, ns, npb):
    from repro.kernels.flix_insert import flix_insert_pallas

    keys = rng.choice(100000, size=2000, replace=False).astype(np.int32)
    st = core.build(keys, np.arange(2000, dtype=np.int32), node_size=ns, nodes_per_bucket=npb)
    extra = np.setdiff1d(rng.choice(100000, 4000).astype(np.int32), keys)[:1500]
    batch = np.concatenate([extra, keys[:300]])          # inserts + upserts
    bv = np.arange(len(batch), dtype=np.int32) + 50000
    sk, sv = core.sort_batch(jnp.asarray(batch), jnp.asarray(bv))
    want, _ = core.insert(st, sk, sv)
    got, oflow = flix_insert_pallas(st, sk, sv, interpret=True)
    np.testing.assert_array_equal(np.asarray(want.keys), np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(want.node_count), np.asarray(got.node_count))
    np.testing.assert_array_equal(np.asarray(want.node_max), np.asarray(got.node_max))
    np.testing.assert_array_equal(np.asarray(want.num_nodes), np.asarray(got.num_nodes))
    mask = np.asarray(want.keys) != np.iinfo(np.int32).max
    np.testing.assert_array_equal(np.asarray(want.vals)[mask], np.asarray(got.vals)[mask])
    assert bool(want.needs_restructure) == bool(got.needs_restructure)


def test_flix_insert_kernel_overflow_flag(rng):
    from repro.kernels.flix_insert import flix_insert_pallas

    st = core.build(
        np.arange(0, 640, 10, dtype=np.int32), np.arange(64, dtype=np.int32),
        node_size=4, nodes_per_bucket=2,
    )
    flood = np.arange(1, 200, 2, dtype=np.int32)
    sk, sv = core.sort_batch(jnp.asarray(flood), jnp.asarray(flood))
    _, oflow = flix_insert_pallas(st, sk, sv, interpret=True)
    assert int(jnp.sum(oflow)) > 0
