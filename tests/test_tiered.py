"""Tiered residency differential proofs (DESIGN.md §15).

THE property: a budget-constrained ``TieredFliX`` — any budget, from
unbounded down to a single resident bucket — is **byte-identical** to the
unconstrained single-tier engine on every workload the repo already uses
to attack the executors.  Identical per-op results, identical live state
(canonical payload), identical shared stats.  Residency is performance
policy, never semantics.

Families of proofs:

* **Budget sweep** — the adversarial mixed batches of
  ``tests/test_differential.py`` (duplicates, all-miss, boundary keys,
  emptied-bucket ranges) run at budgets {unbounded, ~1/10 of the index,
  one bucket} and compare against ``core.apply_ops`` on the full state
  after every batch, with ``check_tiered_invariants`` (I7) in between.
* **Overflow** — clustered insert floods force the grow-and-replay path;
  the tiered engine must land on the same grown geometry and bytes as
  ``apply_ops_safe``.
* **TTL** — expiry-carrying batches with a moving virtual clock; lazy
  reclamation must promote the buckets the expiry pre-pass condemns.
* **Reclamation** — ``restructure_shrink`` and ``TieredFliX.compact``
  return real byte savings without touching the live payload
  (satellite: the nbytes regression test).
* **Cold-tier recovery** — a crashed durable tiered index reopens and
  serves while ``TieredFliX.materialize`` is rigged to explode, proving
  recovery never needs the full index on device.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.checkpoint.serialize import (
    bucket_segments,
    canonical_state_bytes,
    pairs_to_bytes,
    state_from_pairs,
)
from repro.core import (
    EMPTY,
    MAX_VALID,
    NO_EXPIRY,
    TieredFliX,
    apply_ops,
    apply_ops_safe,
    check_invariants,
    check_tiered_invariants,
    make_ops,
    restructure_shrink,
)
from repro.core.distributed import plan_shard_budget
from repro.core.config import ExecConfig
from repro.core.ops import (
    OP_DELETE,
    OP_EXPIRE,
    OP_INSERT,
    OP_POINT,
    OP_RANGE,
    OP_SUCCESSOR,
)
from test_differential import _adversarial_query_batches

GEOM = dict(node_size=8, nodes_per_bucket=8)
SHARED_STATS = ("inserted", "deleted", "overflowed_buckets", "range_truncated")


# ---------------------------------------------------------------------------
# comparison contract (the per-kernel proofs' masked-vals rule): keys,
# counts, fences, exps exact; vals at live positions only — the jnp insert
# zeroes padding vals across ALL buckets while the tiered engine never
# touches unpromoted ones, and padding vals can never reach a result.
# ---------------------------------------------------------------------------


def _assert_tiered_matches(tiered: TieredFliX, oracle: core.FliXState, msg=""):
    hv = tiered.host_view()
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            getattr(hv, f), np.asarray(getattr(oracle, f)), err_msg=f"{msg}:{f}"
        )
    ok = np.asarray(oracle.keys)
    live = ok != EMPTY
    np.testing.assert_array_equal(
        hv.vals[live], np.asarray(oracle.vals)[live], err_msg=f"{msg}:vals"
    )
    if oracle.exps is not None:
        np.testing.assert_array_equal(
            np.where(live, hv.exps, NO_EXPIRY),
            np.where(live, np.asarray(oracle.exps), NO_EXPIRY),
            err_msg=f"{msg}:exps",
        )
    # canonical payload — the durability layer's notion of equality
    assert pairs_to_bytes(*bucket_segments(hv)[1:]) == canonical_state_bytes(
        oracle
    ), f"{msg}:canonical"
    assert bool(hv.needs_restructure) == bool(oracle.needs_restructure), msg


def _assert_results_match(got, want, stats_got, stats_want, msg=""):
    assert set(got) == set(want), msg
    for k in want:
        np.testing.assert_array_equal(
            np.asarray(got[k]), np.asarray(want[k]), err_msg=f"{msg}:{k}"
        )
    for k in SHARED_STATS:
        if k in stats_want:
            assert int(stats_got[k]) == int(stats_want[k]), f"{msg}:stats:{k}"


def _budgets(state):
    full = state.memory_bytes()
    return {"unbounded": None, "tenth": max(1, full // 10), "one_bucket": 1}


@pytest.fixture
def seeded(rng):
    """Adversarial base state: boundary keys, chains, emptied buckets."""
    keys = rng.choice(120000, size=2500, replace=False).astype(np.int32)
    keys = np.unique(np.concatenate([keys, [0, int(MAX_VALID)]])).astype(np.int32)
    st = core.build(keys, np.arange(len(keys), dtype=np.int32), **GEOM)
    st, _ = core.delete(st, jnp.asarray(np.arange(30000, 60000, dtype=np.int32)))
    check_invariants(st)
    live = keys[(keys < 30000) | (keys >= 60000)]
    return st, live


def _mixed_batches(rng, live):
    """Adversarial mixed batches: every op class aimed at the usual traps."""
    out = []
    for name, q in _adversarial_query_batches(rng, live).items():
        n = len(q)
        tags = rng.choice(
            np.array([OP_INSERT, OP_DELETE, OP_POINT, OP_SUCCESSOR], np.int32),
            n,
            p=[0.3, 0.2, 0.3, 0.2],
        )
        tags[: max(1, n // 8)] = OP_RANGE
        # the engine's batch contract (same as the repo's mixed tests):
        # one update per key per batch — duplicated keys keep the update
        # tag only at their first occurrence, the rest become reads
        upd = (tags == OP_INSERT) | (tags == OP_DELETE)
        _, first = np.unique(q[upd], return_index=True)
        keep = np.zeros(int(upd.sum()), bool)
        keep[first] = True
        tags[np.nonzero(upd)[0][~keep]] = OP_POINT
        vals = (q.astype(np.int64) * 13 % 100000).astype(np.int32)
        is_range = tags == OP_RANGE
        vals[is_range] = np.minimum(q[is_range].astype(np.int64) + 5000, 130000).astype(
            np.int32
        )
        out.append((name, tags, q.astype(np.int32), vals))
    return out


def test_budget_sweep_differential(seeded, rng):
    st, live = seeded
    batches = _mixed_batches(rng, live)
    for bname, budget in _budgets(st).items():
        oracle = st
        tiered = TieredFliX.from_state(st, budget_bytes=budget)
        for name, tags, keys, vals in batches:
            ops, perm = make_ops(tags, keys, vals)
            oracle, want, wstats = apply_ops(oracle, ops, config=ExecConfig(impl="reference"))
            got, gstats, _ = tiered.apply(ops, config=ExecConfig(impl="reference"))
            tag = f"{bname}/{name}"
            _assert_results_match(got, want, gstats, wstats, tag)
            _assert_tiered_matches(tiered, oracle, tag)
            check_tiered_invariants(tiered)
        # the budget was honored throughout (one bucket always admitted)
        if budget is not None:
            assert tiered.memory_bytes_resident() <= max(budget, tiered.bucket_bytes)
        if bname == "one_bucket":
            assert tiered.demoted_total > 0  # the sweep actually paged


def test_readonly_batches_leave_mirror_untouched(seeded, rng):
    st, live = seeded
    tiered = TieredFliX.from_state(st, budget_bytes=max(1, st.memory_bytes() // 10))
    before = pairs_to_bytes(*bucket_segments(tiered.host_view())[1:])
    q = np.sort(rng.choice(live, 200)).astype(np.int32)
    tags = np.where(np.arange(200) % 2 == 0, OP_POINT, OP_SUCCESSOR).astype(np.int32)
    ops, _ = make_ops(tags, q, np.zeros(200, np.int32))
    _, want, _ = apply_ops(st, ops, config=ExecConfig(impl="reference"))
    got, stats, _ = tiered.apply(ops, config=ExecConfig(impl="reference"), commit=False)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]))
    assert pairs_to_bytes(*bucket_segments(tiered.host_view())[1:]) == before
    check_tiered_invariants(tiered)
    assert stats["resident_bytes"] <= max(
        max(1, st.memory_bytes() // 10), tiered.bucket_bytes
    )


def test_overflow_grow_replay_matches_safe_oracle(rng):
    # clustered floods into a tiny geometry: overflow → grow → replay
    keys = np.sort(rng.choice(4096, 400, replace=False)).astype(np.int32)
    st = core.build(keys, (keys * 7 + 1).astype(np.int32), node_size=8,
                    nodes_per_bucket=4)
    oracle = st
    tiered = TieredFliX.from_state(st, budget_bytes=max(1, st.memory_bytes() // 8))
    grew = 0
    for t in range(6):
        fresh = 1000 + rng.choice(600, 48, replace=False).astype(np.int32)
        tags = np.full(48, OP_INSERT, np.int32)
        tags[40:] = OP_POINT
        ops, _ = make_ops(tags, fresh, (fresh * 13 + t).astype(np.int32))
        oracle, want, wstats = apply_ops_safe(oracle, ops, config=ExecConfig(impl="reference"))
        got, gstats, restructured = tiered.apply(ops, config=ExecConfig(impl="reference"))
        assert restructured == bool(int(wstats["restructure_retries"])), t
        grew += int(restructured)
        _assert_results_match(got, want, gstats, wstats, f"flood{t}")
        _assert_tiered_matches(tiered, oracle, f"flood{t}")
        check_tiered_invariants(tiered)
    assert grew > 0, "workload must actually trigger the grow path"
    assert tiered.reclaimed_total == 0  # grow never reports reclamation


def test_ttl_parity_with_moving_clock(rng):
    keys = np.sort(rng.choice(8192, 500, replace=False)).astype(np.int32)
    vals = (keys * 3 + 1).astype(np.int32)
    exps = np.where(np.arange(500) % 3 == 0, 40 + (keys % 200), NO_EXPIRY).astype(
        np.int32
    )
    st = state_from_pairs(keys, vals, exps, **GEOM)
    oracle = st
    tiered = TieredFliX.from_state(st, budget_bytes=max(1, st.memory_bytes() // 10))
    for now in (0, 60, 150, 400):
        q = np.sort(rng.choice(8192, 64)).astype(np.int32)
        tags = rng.choice(
            np.array([OP_EXPIRE, OP_POINT, OP_SUCCESSOR], np.int32),
            64,
            p=[0.4, 0.3, 0.3],
        )
        e = np.where(tags == OP_EXPIRE, now + 37 + (q % 50), NO_EXPIRY).astype(
            np.int32
        )
        ops, _ = make_ops(tags, q, (q * 5 + now).astype(np.int32), exps=e)
        oracle, want, wstats = apply_ops(oracle, ops, now=now, config=ExecConfig(impl="reference"))
        got, gstats, _ = tiered.apply(ops, config=ExecConfig(impl="reference"), now=now)
        _assert_results_match(got, want, gstats, wstats, f"now={now}")
        _assert_tiered_matches(tiered, oracle, f"now={now}")
        check_tiered_invariants(tiered, now=now)


# ---------------------------------------------------------------------------
# reclamation (satellite: restructure_shrink + compaction)
# ---------------------------------------------------------------------------


def test_restructure_shrink_reclaims_bytes(rng):
    keys = np.arange(0, 40000, 2, dtype=np.int32)
    st = core.build(keys, (keys // 2).astype(np.int32), **GEOM)
    st, _ = core.delete(st, jnp.asarray(keys[: int(0.9 * len(keys))]))
    payload = canonical_state_bytes(st)
    before = st.memory_bytes()
    new, reclaimed = restructure_shrink(st)
    assert new.memory_bytes() < before, (new.memory_bytes(), before)
    assert reclaimed == before - new.memory_bytes()
    check_invariants(new)
    # geometry-independent canonical payload is untouched
    assert canonical_state_bytes(new) == payload
    # regression: the arrays really are re-materialized smaller
    assert new.keys.nbytes < st.keys.nbytes


def test_tiered_compact_reclaims_and_keeps_parity(rng):
    keys = np.arange(0, 40000, 2, dtype=np.int32)
    st = core.build(keys, (keys // 2).astype(np.int32), **GEOM)
    st, _ = core.delete(st, jnp.asarray(keys[: int(0.9 * len(keys))]))
    oracle, oracle_reclaimed = restructure_shrink(st)
    tiered = TieredFliX.from_state(st, budget_bytes=max(1, st.memory_bytes() // 10))
    reclaimed = tiered.compact()
    assert reclaimed == oracle_reclaimed
    assert tiered.reclaimed_total >= reclaimed
    _assert_tiered_matches(tiered, oracle, "compact")
    check_tiered_invariants(tiered)
    # still serves correctly after compaction, within budget
    q = np.sort(rng.choice(keys, 64)).astype(np.int32)
    ops, _ = make_ops(np.full(64, OP_POINT, np.int32), q, np.zeros(64, np.int32))
    _, want, _ = apply_ops(oracle, ops, config=ExecConfig(impl="reference"))
    got, _, _ = tiered.apply(ops, config=ExecConfig(impl="reference"))
    np.testing.assert_array_equal(np.asarray(got["value"]), np.asarray(want["value"]))


def test_plan_shard_budget():
    assert plan_shard_budget(None, 4) is None
    assert plan_shard_budget(100, 4) == 25
    assert plan_shard_budget(3, 8) == 1  # never starves a shard to zero


# ---------------------------------------------------------------------------
# cold-tier crash recovery: reopening a durable tiered index must never
# materialize the full index on device
# ---------------------------------------------------------------------------


def _serve_workload(kv, rng, steps):
    for t in range(steps):
        seqs = rng.choice(64, 8, replace=False)
        pages = rng.integers(0, 16, 8).astype(np.int64)
        kv.step(allocs=(seqs, pages, seqs * 1000 + pages))


def test_crash_recovery_cold_tier(tmp_path, rng, monkeypatch):
    from repro.serve.kv_index import KVPageIndex

    budget = 8192
    kv = KVPageIndex(
        durability_dir=str(tmp_path), snapshot_every=3, device_budget=budget
    )
    _serve_workload(kv, np.random.default_rng(7), 7)
    # oracle: the same workload on a plain single-tier index
    oracle = KVPageIndex()
    _serve_workload(oracle, np.random.default_rng(7), 7)
    want = canonical_state_bytes(oracle.state)
    del kv  # crash: no close(), recovery replays the WAL tail

    boom = AssertionError("full-index materialization during recovery")

    def _no_materialize(self):
        raise boom

    monkeypatch.setattr(TieredFliX, "materialize", _no_materialize)
    kv2 = KVPageIndex(
        durability_dir=str(tmp_path), snapshot_every=3, device_budget=budget
    )
    handle = kv2._durable.handle
    assert isinstance(handle, TieredFliX)
    # recovered payload is byte-identical to the uninterrupted oracle —
    # proven through the host view, still without touching the device
    assert pairs_to_bytes(*bucket_segments(handle.host_view())[1:]) == want
    assert kv2.resident_bytes is not None
    assert kv2.resident_bytes <= max(budget, handle.bucket_bytes)
    check_tiered_invariants(handle)
    # and it still serves: reads + one more durable update step
    rng2 = np.random.default_rng(7)
    seqs = rng2.choice(64, 8, replace=False)
    got = np.asarray(kv2.lookup(seqs, np.zeros(8, np.int64)))
    exp = np.asarray(oracle.lookup(seqs, np.zeros(8, np.int64)))
    np.testing.assert_array_equal(got, exp)
    kv2.step(allocs=([99], [0], [4242]))
    assert int(np.asarray(kv2.lookup([99], [0]))[0]) == 4242
    kv2.snapshot()
    kv2.close()


def test_gateway_surfaces_residency_metrics(rng):
    from repro.serve.gateway import Gateway, Request
    from repro.serve.kv_index import KVPageIndex

    kv = KVPageIndex(device_budget=8192)
    gw = Gateway(kv, default_rate=1e6, default_burst=1e6)
    for b in range(4):
        gw.submit(
            Request(f"t{b}", f"alloc:{b}", "alloc", seqs=(b,), pages=(0,),
                    slots=(b * 10,)),
            now=0.0,
        )
    gw.pump(now=0.0)
    m = gw.metrics
    assert m["promoted"] >= 1
    assert m["resident_bytes"] > 0
    assert m["resident_bytes"] <= max(8192, kv.state.bucket_bytes)
    assert m["demoted"] >= 0 and m["reclaimed_bytes"] >= 0
