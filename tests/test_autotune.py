"""Autotuner determinism + TileTable plumbing (DESIGN.md §16).

The model-mode sweep must be a pure function of its inputs: the committed
bench artifact embeds the table, the determinism test here re-derives it,
and ``ExecConfig.resolve_blocks`` must hand the fused kernel exactly the
tuned tiles (explicit overrides still winning).
"""

import numpy as np

from repro.core.config import DEFAULT_MAX_RESULTS, ExecConfig, TileTable
from repro.kernels.autotune import (
    CANDIDATE_BLOCK_B,
    CANDIDATE_BLOCK_Q,
    VMEM_BUDGET_BYTES,
    autotune,
    sweep_bucket,
    vmem_bytes,
)

BUILDS = (4096, 65536)
BATCHES = (256, 2048)


def test_sweep_is_deterministic():
    a_table, a_rec = autotune(BUILDS, BATCHES)
    b_table, b_rec = autotune(BUILDS, BATCHES)
    assert a_table == b_table
    assert a_rec == b_rec
    # shuffled/duplicated inputs bucket to the same sweep
    c_table, _ = autotune(BUILDS[::-1] + BUILDS, BATCHES[::-1])
    assert c_table == a_table


def test_sweep_covers_grid_and_respects_vmem():
    table, rec = autotune(BUILDS, BATCHES)
    assert len(table.entries) == len(BUILDS) * len(BATCHES)
    for sweep in rec["sweeps"]:
        assert len(sweep["candidates"]) == len(CANDIDATE_BLOCK_Q) * len(
            CANDIDATE_BLOCK_B
        )
        chosen = next(
            c
            for c in sweep["candidates"]
            if c["block_q"] == sweep["block_q"] and c["block_b"] == sweep["block_b"]
        )
        assert chosen["feasible"]
        assert chosen["vmem_bytes"] <= VMEM_BUDGET_BYTES
        # the winner has the minimum model cost among feasible candidates
        best = min(
            c["model_cost"] for c in sweep["candidates"] if c["feasible"]
        )
        assert chosen["model_cost"] == best


def test_vmem_model_scales_with_tiles():
    small = vmem_bytes(128, 1, node_size=16, nodes_per_bucket=8)
    big = vmem_bytes(512, 8, node_size=16, nodes_per_bucket=8)
    assert big > small > 0


def test_table_roundtrips_artifact_and_execconfig():
    table, rec = autotune(BUILDS, BATCHES)
    # artifact round-trip: JSON rows -> identical table
    assert TileTable.from_json(rec["table"]) == table
    # ExecConfig consults the table when blocks are unset...
    cfg = ExecConfig(tile_table=table)
    for build, batch, bq, bb in table.entries:
        assert cfg.resolve_blocks(build, batch) == (bq, bb)
    # ...explicit overrides always win...
    cfg2 = cfg.replace(block_q=64)
    build, batch, _, bb = table.entries[0]
    assert cfg2.resolve_blocks(build, batch) == (64, bb)
    # ...and off-grid sizes fall back to the nearest bucket, deterministically
    got = cfg.resolve_blocks(3 * BUILDS[-1], 3 * BATCHES[-1])
    assert got == cfg.resolve_blocks(3 * BUILDS[-1], 3 * BATCHES[-1])
    assert got[0] in CANDIDATE_BLOCK_Q and got[1] in CANDIDATE_BLOCK_B


def test_tuned_config_runs_byte_identical(rng):
    """A tile table changes execution strategy only: apply_ops under the
    tuned config matches the kernel-default config byte-for-byte."""
    import jax.numpy as jnp

    from repro import core

    keys = rng.choice(30000, size=1500, replace=False).astype(np.int32)
    st = core.build(keys, np.arange(1500, dtype=np.int32), node_size=8,
                    nodes_per_bucket=8)
    table, _ = autotune([st.num_buckets * st.bucket_capacity], [256])
    q = np.sort(rng.choice(keys, 200)).astype(np.int32)
    ins = np.setdiff1d(np.arange(0, 30000, 11, dtype=np.int32), keys)[:56]
    tags = np.concatenate(
        [np.full(200, core.OP_POINT), np.full(56, core.OP_INSERT)]
    ).astype(np.int32)
    ops, _ = core.make_ops(
        tags, np.concatenate([q, ins]), np.concatenate([q, ins]), pad_to=256
    )
    base = core.apply_ops(st, ops, config=ExecConfig(impl="fused"))
    tuned = core.apply_ops(
        st, ops, config=ExecConfig(impl="fused", tile_table=table)
    )
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base[0], f)), np.asarray(getattr(tuned[0], f))
        )
    mask = np.asarray(base[0].keys) != int(core.EMPTY)
    np.testing.assert_array_equal(
        np.asarray(base[0].vals)[mask], np.asarray(tuned[0].vals)[mask]
    )
    for k in base[1]:
        np.testing.assert_array_equal(
            np.asarray(base[1][k]), np.asarray(tuned[1][k]), err_msg=k
        )
