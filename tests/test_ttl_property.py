"""TTL/expiry differential suite under a mocked virtual clock (DESIGN.md §14).

Pins the TTL contract to the pure-python ``tests/clock_model.py`` oracle:

  * arbitrary mixed batches (INSERT with deadlines, DELETE, EXPIRE
    get-or-set, POINT/SUCCESSOR/RANGE reads) match ``TTLModel`` under an
    explicitly advanced ``VirtualClock`` — TTL set/overwrite/extend,
    expiry exactly AT vs after the deadline, expired keys resurrectable,
    reads never observing an expired row;
  * the fused executor matches the reference executor byte-for-byte on
    TTL batches (keys + expiry columns byte-identical, values compared at
    live slots — the fused kernel zeroes freed value slots, the reference
    leaves garbage; both are outside the logical contract);
  * **negative clock controls** — the whole differential runs with
    ``time.time``/``monotonic``/``perf_counter`` rigged to *fail the test*
    when called from any ``repro.*`` module, and again with the wall
    clock pinned 30k years in the future: if any engine layer derived
    expiry from the OS clock instead of the threaded ``now``, both
    variants would go red.

hypothesis drives the generative sweep when installed; the seeded-rng
fallbacks exercise the same checkers on every container.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.expiry import NO_EXPIRY
from repro.core.state import EMPTY, MAX_VALID, NOT_FOUND
from repro.checkpoint.serialize import state_from_pairs
from repro.core.config import ExecConfig

from clock_model import (
    TTLModel,
    VirtualClock,
    check_one_update_op_per_key,
    forbid_wallclock,
    huge_wallclock,
)

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
    COMMON = dict(deadline=None, suppress_health_check=[HealthCheck.too_slow])
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

KEY_SPACE = 2000
PAD = 128
GEOMETRY = dict(node_size=4, nodes_per_bucket=4)
NO_TTL = int(NO_EXPIRY)


# ---------------------------------------------------------------------------
# workload generation (one shape, two generators: hypothesis / seeded rng)
# ---------------------------------------------------------------------------


def _workload_from_rng(rng, *, n_batches=4, n_build=120):
    """A TTL workload dict: initial pairs + per-batch op lists + clock
    advances.  Deadlines cluster around the clock so every batch sees a
    mix of already-expired, expiring-now, soon, and immortal rows."""
    build_keys = np.sort(rng.choice(KEY_SPACE, n_build, replace=False))
    build = []
    for k in build_keys.tolist():
        ttl = int(rng.integers(1, 120)) if rng.random() < 0.7 else None
        build.append((int(k), ttl))
    batches = []
    for _ in range(n_batches):
        upd = rng.choice(KEY_SPACE, 40, replace=False)
        ins, exp_k, dels = upd[:18], upd[18:30], upd[30:]
        batches.append(
            dict(
                adv=int(rng.integers(0, 40)),
                # (key, ttl): ttl None → NO_EXPIRY, 0 → expires next batch,
                # negative → already past the deadline at insert time
                ins=[
                    (
                        int(k),
                        None
                        if rng.random() < 0.25
                        else int(rng.integers(-10, 60)),
                    )
                    for k in ins.tolist()
                ],
                getset=[
                    (int(k), int(rng.integers(1, 60))) for k in exp_k.tolist()
                ],
                dels=[int(k) for k in dels.tolist()],
                points=[int(k) for k in rng.integers(0, KEY_SPACE, 20)],
                succs=[int(k) for k in rng.integers(0, KEY_SPACE, 12)],
                ranges=[
                    (int(lo), int(span))
                    for lo, span in zip(
                        rng.integers(0, KEY_SPACE, 4),
                        rng.integers(-40, 500, 4),
                    )
                ],
            )
        )
    return dict(build=build, batches=batches)


if HAVE_HYPOTHESIS:
    KEY = st.integers(min_value=0, max_value=KEY_SPACE - 1)
    TTL = st.one_of(st.none(), st.integers(min_value=-10, max_value=60))

    @st.composite
    def ttl_workloads(draw):
        build_keys = draw(
            st.lists(KEY, min_size=1, max_size=100, unique=True)
        )
        build = [
            (k, draw(st.one_of(st.none(), st.integers(1, 120))))
            for k in sorted(build_keys)
        ]
        batches = []
        for _ in range(draw(st.integers(2, 4))):
            upd = draw(
                st.lists(KEY, min_size=3, max_size=36, unique=True)
            )
            third = max(1, len(upd) // 3)
            batches.append(
                dict(
                    adv=draw(st.integers(0, 40)),
                    ins=[(k, draw(TTL)) for k in upd[:third]],
                    getset=[
                        (k, draw(st.integers(1, 60)))
                        for k in upd[third : 2 * third]
                    ],
                    dels=list(upd[2 * third :]),
                    points=draw(st.lists(KEY, max_size=15)),
                    succs=draw(st.lists(KEY, max_size=8)),
                    ranges=draw(
                        st.lists(
                            st.tuples(KEY, st.integers(-40, 500)), max_size=4
                        )
                    ),
                )
            )
        return dict(build=build, batches=batches)


# ---------------------------------------------------------------------------
# the checkers
# ---------------------------------------------------------------------------


def _build_state_and_model(build, start_now=0):
    keys = np.array([k for k, _ in build], np.int32)
    vals = (keys * 7 + 1).astype(np.int32)
    exps = np.array(
        [NO_TTL if ttl is None else start_now + ttl for _, ttl in build],
        np.int32,
    )
    state = state_from_pairs(keys, vals, exps, **GEOMETRY)
    model = TTLModel(zip(keys.tolist(), vals.tolist(), exps.tolist()))
    return state, model


def _batch_arrays(b, now):
    """Flatten one workload batch into (tags, keys, vals, exps) arrays."""
    tags, keys, vals, exps = [], [], [], []

    def add(t, k, v, e):
        tags.append(t), keys.append(k), vals.append(v), exps.append(e)

    for k, ttl in b["ins"]:
        add(core.OP_INSERT, k, k * 13 + now, NO_TTL if ttl is None else now + ttl)
    for k, ttl in b["getset"]:
        add(core.OP_EXPIRE, k, k * 17 + now, now + ttl)
    for k in b["dels"]:
        add(core.OP_DELETE, k, 0, NO_TTL)
    for k in b["points"]:
        add(core.OP_POINT, k, 0, NO_TTL)
    for k in b["succs"]:
        add(core.OP_SUCCESSOR, k, 0, NO_TTL)
    for lo, span in b["ranges"]:
        add(core.OP_RANGE, lo, lo + span, NO_TTL)
    return (
        np.array(tags, np.int32),
        np.array(keys, np.int32),
        np.array(vals, np.int32),
        np.array(exps, np.int32),
    )


def _apply(state, tags, keys, vals, exps, *, now, impl, budget):
    ops, perm = core.make_ops(
        tags, keys, vals, exps=jnp.asarray(exps), pad_to=PAD
    )
    state, res, stats = core.apply_ops_safe(
        state,
        ops,
        now=now,  # I1–I6 incl. expiry liveness at this `now`
         config=ExecConfig(impl=impl, max_results=budget, validate=True, validate_ranges=True)
    )
    values = np.asarray(core.unsort(res["value"], perm))[: len(tags)]
    return state, values, res, stats, perm


def _check_ttl_differential(wl, impl="reference", budget=256):
    """THE property: engine == TTLModel batch-for-batch on one workload."""
    clock = VirtualClock()
    state, model = _build_state_and_model(wl["build"])
    for b in wl["batches"]:
        now = clock.advance(b["adv"])
        tags, keys, vals, exps = _batch_arrays(b, now)
        if not check_one_update_op_per_key(tags, keys):
            continue  # outside the engine precondition
        state, values, res, stats, perm = _apply(
            state, tags, keys, vals, exps, now=now, impl=impl, budget=budget
        )
        want_values, want_expired = model.apply(
            tags, keys, vals, exps, now=now
        )
        np.testing.assert_array_equal(values, want_values)
        assert int(stats["expired"]) == want_expired
        # dense RANGE output vs the model's post-state, packing included
        dk, dv, starts, counts, truncated = model.range_segments(
            tags, keys, vals, budget
        )
        got_k = np.asarray(res["range_key"])
        got_v = np.asarray(res["range_val"])
        np.testing.assert_array_equal(got_k[: len(dk)], np.array(dk, np.int32))
        np.testing.assert_array_equal(got_v[: len(dv)], np.array(dv, np.int32))
        assert (got_k[len(dk) :] == int(EMPTY)).all()
        rs = np.asarray(core.unsort(res["range_start"], perm))[: len(tags)]
        rc = np.asarray(core.unsort(res["range_count"], perm))[: len(tags)]
        for i, s in starts.items():
            assert rs[i] == s and rc[i] == counts[i], (i, rs[i], rc[i])
        assert int(stats["range_truncated"]) == truncated
        # live-set parity: the engine state holds exactly the model's keys
        live = np.asarray(state.keys)
        live = np.sort(live[live != int(EMPTY)])
        np.testing.assert_array_equal(live, np.array(model.live(), np.int32))


def _check_fused_matches_reference(wl, budget=256):
    """Byte-identity between executors on TTL batches: keys + expiry
    columns exact, values at live slots, results and stats exact."""
    clock = VirtualClock()
    s_ref, _ = _build_state_and_model(wl["build"])
    s_f = s_ref
    for b in wl["batches"]:
        now = clock.advance(b["adv"])
        tags, keys, vals, exps = _batch_arrays(b, now)
        if not check_one_update_op_per_key(tags, keys):
            continue
        ops, _ = core.make_ops(
            tags, keys, vals, exps=jnp.asarray(exps), pad_to=PAD
        )
        n_ref, r_ref, t_ref = core.apply_ops(
            s_ref, ops, now=now, config=ExecConfig(impl="reference", max_results=budget)
        )
        if bool(n_ref.needs_restructure):
            return  # overflowed buckets are untrustworthy by contract
        n_f, r_f, t_f = core.apply_ops(
            s_f, ops, now=now, config=ExecConfig(impl="fused", max_results=budget)
        )
        for f in ("keys", "exps", "node_count", "node_max", "num_nodes", "mkba"):
            np.testing.assert_array_equal(
                np.asarray(getattr(n_ref, f)),
                np.asarray(getattr(n_f, f)),
                err_msg=f,
            )
        live = np.asarray(n_ref.keys) != int(EMPTY)
        np.testing.assert_array_equal(
            np.asarray(n_ref.vals)[live], np.asarray(n_f.vals)[live]
        )
        for k in r_ref:
            np.testing.assert_array_equal(
                np.asarray(r_ref[k]), np.asarray(r_f[k]), err_msg=k
            )
        for k in t_ref:
            assert int(t_ref[k]) == int(t_f[k]), k
        s_ref, s_f = n_ref, n_f


# ---------------------------------------------------------------------------
# generative sweeps
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, **COMMON)
    @given(wl=ttl_workloads())
    def test_ttl_matches_model(wl):
        _check_ttl_differential(wl)

    @settings(max_examples=6, **COMMON)
    @given(wl=ttl_workloads())
    def test_ttl_fused_matches_reference(wl):
        _check_fused_matches_reference(wl)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_ttl_matches_model_seeded(seed):
    """Seeded fallback for the hypothesis sweep (runs everywhere)."""
    rng = np.random.default_rng(seed)
    _check_ttl_differential(_workload_from_rng(rng))


@pytest.mark.parametrize("seed", [4, 5])
def test_ttl_matches_model_seeded_tight_budget(seed):
    rng = np.random.default_rng(seed)
    _check_ttl_differential(_workload_from_rng(rng), budget=16)


@pytest.mark.parametrize("seed", [6, 7])
def test_ttl_fused_matches_reference_seeded(seed):
    rng = np.random.default_rng(seed)
    _check_fused_matches_reference(_workload_from_rng(rng))


# ---------------------------------------------------------------------------
# directed TTL semantics
# ---------------------------------------------------------------------------


def _one(state, tag, key, val, exp, *, now, impl="reference"):
    tags = np.array([tag], np.int32)
    state, values, _res, stats, _ = _apply(
        state,
        tags,
        np.array([key], np.int32),
        np.array([val], np.int32),
        np.array([exp], np.int32),
        now=now,
        impl=impl,
        budget=16,
    )
    return state, int(values[0]), stats


def test_ttl_set_overwrite_extend():
    """INSERT sets the deadline, a second INSERT overwrites it, and an
    EXPIRE hit extends it — each governs the key's visibility window."""
    state, _ = _build_state_and_model([(10, 5)])  # key 10 expires at 5
    # overwrite with a later deadline before it fires
    state, _, _ = _one(state, core.OP_INSERT, 10, 111, 20, now=3)
    state, got, stats = _one(state, core.OP_POINT, 10, 0, NO_TTL, now=10)
    assert got == 111 and int(stats["expired"]) == 0  # old deadline gone
    # EXPIRE hit refreshes to 40 and returns the STORED value
    state, got, _ = _one(state, core.OP_EXPIRE, 10, 999, 40, now=15)
    assert got == 111
    state, got, _ = _one(state, core.OP_POINT, 10, 0, NO_TTL, now=30)
    assert got == 111  # alive past the overwritten deadline of 20
    state, got, stats = _one(state, core.OP_POINT, 10, 0, NO_TTL, now=40)
    assert got == int(NOT_FOUND) and int(stats["expired"]) == 1


def test_expiry_exactly_at_deadline():
    """A key expires exactly AT its deadline (``exp <= now``), not after."""
    state, _ = _build_state_and_model([(5, 7)])
    state, got, _ = _one(state, core.OP_POINT, 5, 0, NO_TTL, now=6)
    assert got == 5 * 7 + 1  # one tick before: visible
    state, got, stats = _one(state, core.OP_POINT, 5, 0, NO_TTL, now=7)
    assert got == int(NOT_FOUND) and int(stats["expired"]) == 1


def test_expired_key_resurrectable():
    """Expiry frees the key: a later INSERT stores it fresh."""
    state, _ = _build_state_and_model([(5, 7)])
    state, _, stats = _one(state, core.OP_INSERT, 5, 42, 100, now=50)
    assert int(stats["expired"]) == 1  # the old row died on the way in
    state, got, _ = _one(state, core.OP_POINT, 5, 0, NO_TTL, now=60)
    assert got == 42
    # and an EXPIRE miss resurrects too (get-or-set insert arm)
    state, got, _ = _one(state, core.OP_EXPIRE, 5, 77, 300, now=150)
    assert got == int(NOT_FOUND)  # 42 expired at 100 → miss
    state, got, _ = _one(state, core.OP_POINT, 5, 0, NO_TTL, now=200)
    assert got == 77


def test_reads_never_see_expired_rows():
    """POINT misses, SUCCESSOR skips to the next live key, RANGE excludes."""
    state, _ = _build_state_and_model([(10, 5), (20, None), (30, 5)])
    now = 5
    tags = np.array(
        [core.OP_POINT, core.OP_SUCCESSOR, core.OP_RANGE], np.int32
    )
    keys = np.array([10, 9, 0], np.int32)
    vals = np.array([0, 0, 100], np.int32)
    exps = np.full(3, NO_TTL, np.int32)
    for impl in ("reference", "fused"):
        s2, values, res, stats, _ = _apply(
            state, tags, keys, vals, exps, now=now, impl=impl, budget=16
        )
        assert values[0] == int(NOT_FOUND)  # POINT 10: expired
        assert values[1] == 20 * 7 + 1  # SUCCESSOR 9 skips 10 → 20
        got_k = np.asarray(res["range_key"])
        assert got_k[0] == 20 and got_k[1] == int(EMPTY)  # RANGE sees only 20
        assert int(stats["expired"]) == 2


def test_same_batch_past_deadline_visible_until_next_batch():
    """The §14 edge: a row written with ``exp <= now`` in THIS batch is
    visible to this batch's reads (expiry is a pre-pass over the
    pre-batch state) and reclaimed by the NEXT batch's pre-pass."""
    state, _ = _build_state_and_model([(1, None)])
    now = 50
    tags = np.array([core.OP_INSERT, core.OP_POINT], np.int32)
    keys = np.array([9, 9], np.int32)
    state, values, _res, stats, _ = _apply(
        state,
        tags,
        keys,
        np.array([33, 0], np.int32),
        np.array([now, NO_TTL], np.int32),  # deadline == now: already due
        now=now,
        impl="reference",
        budget=16,
    )
    assert values[1] == 33 and int(stats["expired"]) == 0
    state, got, stats = _one(state, core.OP_POINT, 9, 0, NO_TTL, now=now)
    assert got == int(NOT_FOUND) and int(stats["expired"]) == 1


def test_no_expiry_sentinel_is_immortal():
    """``NO_EXPIRY`` rows survive any storable ``now``."""
    state, _ = _build_state_and_model([(3, None)])
    state, got, stats = _one(
        state, core.OP_POINT, 3, 0, NO_TTL, now=int(MAX_VALID)
    )
    assert got == 3 * 7 + 1 and int(stats["expired"]) == 0


def test_now_none_skips_expiry():
    """Without a clock the engine never expires — columns just ride along."""
    state, _ = _build_state_and_model([(5, 1)])
    ops, perm = core.make_ops(
        np.array([core.OP_POINT], np.int32),
        np.array([5], np.int32),
        np.array([0], np.int32),
        pad_to=8,
    )
    _, res, stats = core.apply_ops(
        state, ops, config=ExecConfig(impl="reference", max_results=8)
    )  # no now=
    assert int(np.asarray(core.unsort(res["value"], perm))[0]) == 5 * 7 + 1
    assert int(stats["expired"]) == 0


def test_expire_get_or_set_in_one_mixed_batch():
    """EXPIRE rides a mixed batch: hits return stored values + refresh,
    misses insert — all under the same sort as the other op classes."""
    state, model = _build_state_and_model([(100, None), (200, 50)])
    now = 10
    tags = np.array(
        [core.OP_EXPIRE, core.OP_EXPIRE, core.OP_INSERT, core.OP_POINT],
        np.int32,
    )
    keys = np.array([100, 150, 300, 200], np.int32)
    vals = np.array([1, 2, 3, 0], np.int32)
    exps = np.array([now + 5, now + 5, NO_TTL, NO_TTL], np.int32)
    state, values, _res, _stats, _ = _apply(
        state, tags, keys, vals, exps, now=now, impl="reference", budget=16
    )
    want, _ = model.apply(tags, keys, vals, exps, now=now)
    np.testing.assert_array_equal(values, want)
    assert values[0] == 100 * 7 + 1  # hit: stored value
    assert values[1] == int(NOT_FOUND)  # miss: inserted
    # the hit's refreshed deadline governs: gone at now+5
    state, got, _ = _one(state, core.OP_POINT, 100, 0, NO_TTL, now=now + 5)
    assert got == int(NOT_FOUND)


# ---------------------------------------------------------------------------
# negative clock controls
# ---------------------------------------------------------------------------


def test_differential_with_wallclock_forbidden():
    """The engine must never read the OS clock: the whole differential
    runs with time.time/monotonic/perf_counter rigged to fail the test
    when called from any repro.* module."""
    rng = np.random.default_rng(11)
    wl = _workload_from_rng(rng, n_batches=3)
    with forbid_wallclock():
        _check_ttl_differential(wl)


def test_wallclock_guard_actually_fires():
    """Prove the guard is live: a wall-clock read from a repro module
    frame raises (otherwise the control above could pass vacuously)."""
    import time

    import repro.core.expiry as expiry_mod

    def from_repro_frame():
        # execute a time.time() call whose calling frame carries the
        # repro module's globals — exactly what an engine-side wall-clock
        # read would look like to the guard
        return eval("time.time()", dict(expiry_mod.__dict__, time=time))

    with forbid_wallclock():
        with pytest.raises(AssertionError, match="wall-clock read"):
            from_repro_frame()


def test_virtual_clock_governs_not_wall_clock():
    """Pin the OS clock 30k years out: TTL'd rows still live and die by
    the virtual ``now`` alone."""
    with huge_wallclock():
        state, _ = _build_state_and_model([(10, 5), (20, None)])
        state, got, stats = _one(state, core.OP_POINT, 10, 0, NO_TTL, now=3)
        assert got == 10 * 7 + 1 and int(stats["expired"]) == 0
        state, got, stats = _one(state, core.OP_POINT, 10, 0, NO_TTL, now=5)
        assert got == int(NOT_FOUND) and int(stats["expired"]) == 1
