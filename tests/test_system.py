"""End-to-end behaviour tests for the paper's system.

Round-trip the full FliX life cycle (the paper's experimental protocol) and
the serving-plane integration (KV page index), plus a short real training
run through the public driver.
"""

import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.state import NOT_FOUND
from repro.serve.kv_index import KVPageIndex

REPO = Path(__file__).resolve().parents[1]


def test_paper_protocol_rounds(rng):
    """Build → 4 insert rounds → queries → 4 delete rounds → restructure."""
    n = 4096
    universe = rng.permutation(200000).astype(np.int32)
    build, pool = universe[:n], universe[n : 3 * n]
    st = core.build(build, np.arange(n, dtype=np.int32), node_size=32, nodes_per_bucket=16)
    model = dict(zip(build.tolist(), range(n)))

    per = n // 2
    for rnd in range(4):
        ins = pool[rnd * per : (rnd + 1) * per]
        iv = np.arange(len(ins), dtype=np.int32) + 1000 * rnd
        sk, sv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
        st, _ = core.insert_safe(st, sk, sv)
        model.update(zip(ins.tolist(), iv.tolist()))
        # all-hit and all-miss query batches after every round (paper §6)
        live = np.array(sorted(model), dtype=np.int32)
        hits = np.sort(rng.choice(live, size=n))
        res = np.asarray(core.point_query(st, jnp.asarray(hits)))
        assert all(res[i] == model[int(hits[i])] for i in range(n))
        misses = np.setdiff1d(rng.integers(0, 200000, 2 * n).astype(np.int32), live)[:n]
        res = np.asarray(core.point_query(st, jnp.asarray(np.sort(misses))))
        assert (res == int(NOT_FOUND)).all()

    for rnd in range(4):
        dels = np.sort(pool[rnd * per : (rnd + 1) * per])
        st, _ = core.delete(st, jnp.asarray(dels))
        for k in dels.tolist():
            model.pop(k)
    assert int(st.live_keys()) == len(model)

    st = core.restructure_auto(st)
    live = np.array(sorted(model), dtype=np.int32)
    res = np.asarray(core.point_query(st, jnp.asarray(live)))
    assert all(res[i] == model[int(live[i])] for i in range(len(live)))


def test_kv_page_index_serving_plane(rng):
    idx = KVPageIndex()
    # three sequences allocate pages across engine steps
    idx.allocate([1, 1, 1, 2, 2, 3], [0, 1, 2, 0, 1, 0], [10, 11, 12, 20, 21, 30])
    slots = np.asarray(idx.lookup([1, 2, 3, 2], [1, 0, 0, 1]))
    assert slots.tolist() == [11, 20, 30, 21]
    pages, slots, count = idx.pages_of(1)
    assert int(count) == 3
    assert np.asarray(slots)[:3].tolist() == [10, 11, 12]
    assert np.asarray(pages)[:3].tolist() == [0, 1, 2]
    # sequence 1 completes: physical free, slots reclaimed
    idx.free_sequences([1])
    assert idx.live_pages() == 3
    assert np.asarray(idx.lookup([1], [0]))[0] == int(NOT_FOUND)
    # slot reuse for a new sequence
    idx.allocate([7, 7], [0, 1], [10, 11])
    assert np.asarray(idx.lookup([7], [1]))[0] == 11


def test_kv_page_index_pages_of_via_engine(rng):
    """Regression for the pages_of engine bypass: enumeration must go
    through ``apply_ops`` — so it works on a cache-carrying read state,
    reflects every preceding engine step, and can share a batch with the
    updates it should observe (update-then-read)."""
    from repro import core
    from repro.serve.kv_index import PAGE_BITS

    idx = KVPageIndex()
    idx.allocate([5, 5, 5, 9], [0, 1, 2, 0], [50, 51, 52, 90])

    # attach the successor cache, as a read-only query stream would; the
    # old bypass ran range_query outside the engine against whatever state
    # object happened to be cached on the wrapper
    idx.state = core.with_successor_cache(idx.state)
    pages, slots, count = idx.pages_of(5)
    assert int(count) == 3
    assert np.asarray(pages)[:3].tolist() == [0, 1, 2]
    assert np.asarray(slots)[:3].tolist() == [50, 51, 52]

    # a later engine step must be visible to the next enumeration
    idx.state = core.with_successor_cache(idx.state)
    idx.free_sequences([5])
    _, _, count = idx.pages_of(5)
    assert int(count) == 0

    # update-then-read inside ONE engine step: the enumeration travels in
    # the same batch as the allocations it observes
    _, rng_out, _ = idx.step(
        allocs=([3, 3], [0, 1], [30, 31]),
        ranges=([3 << PAGE_BITS], [4 << PAGE_BITS]),
    )
    assert int(rng_out["count"][0]) == 2
    got_pages = np.asarray(rng_out["keys"])[:2] & ((1 << PAGE_BITS) - 1)
    assert got_pages.tolist() == [0, 1]
    assert np.asarray(rng_out["vals"])[:2].tolist() == [30, 31]

    # budget truncation surfaces deterministically through the serving API
    pages, slots, count = idx.pages_of(3, max_pages=1)
    assert int(count) == 1 and int(np.asarray(pages)[0]) == 0


@pytest.mark.slow
def test_range_mix_benchmark_cli(tmp_path):
    """The selectivity sweep runs end-to-end and lands in the flix-bench-v1
    artifact with the range speedup map populated."""
    import json

    out = tmp_path / "bench.json"
    env = {
        "PYTHONPATH": f"{REPO}/src",
        "PATH": "/usr/bin:/bin",
        "REPRO_BENCH_JSON": str(out),
    }
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "range_mix"],
        capture_output=True, text=True, env=env, cwd=str(REPO), timeout=3000,
    )
    assert p.returncode == 0, p.stderr
    payload = json.loads(out.read_text())
    assert payload["schema"] == "flix-bench-v1"
    assert not payload["failed"]
    rows = payload["suites"]["range_mix_engine"]
    assert any(name.startswith("range_mix_ref_") for name in rows)
    assert payload["range_fused_speedup"]  # fused/reference pair extracted


@pytest.mark.slow
def test_train_driver_resume_cli(tmp_path):
    """The production driver trains, checkpoints, and resumes (CLI-level)."""
    env = {"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin"}
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "musicgen-medium", "--reduced", "--batch", "4",
        "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ]
    p1 = subprocess.run(
        cmd + ["--steps", "12"], capture_output=True, text=True, env=env,
        cwd=str(REPO), timeout=900,
    )
    assert p1.returncode == 0, p1.stderr
    p2 = subprocess.run(
        cmd + ["--steps", "16"], capture_output=True, text=True, env=env,
        cwd=str(REPO), timeout=900,
    )
    assert p2.returncode == 0, p2.stderr
    assert "resumed from step 12" in p2.stdout
