"""End-to-end behaviour tests for the paper's system.

Round-trip the full FliX life cycle (the paper's experimental protocol) and
the serving-plane integration (KV page index), plus a short real training
run through the public driver.
"""

import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.state import NOT_FOUND
from repro.serve.kv_index import KVPageIndex

REPO = Path(__file__).resolve().parents[1]


def test_paper_protocol_rounds(rng):
    """Build → 4 insert rounds → queries → 4 delete rounds → restructure."""
    n = 4096
    universe = rng.permutation(200000).astype(np.int32)
    build, pool = universe[:n], universe[n : 3 * n]
    st = core.build(build, np.arange(n, dtype=np.int32), node_size=32, nodes_per_bucket=16)
    model = dict(zip(build.tolist(), range(n)))

    per = n // 2
    for rnd in range(4):
        ins = pool[rnd * per : (rnd + 1) * per]
        iv = np.arange(len(ins), dtype=np.int32) + 1000 * rnd
        sk, sv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
        st, _ = core.insert_safe(st, sk, sv)
        model.update(zip(ins.tolist(), iv.tolist()))
        # all-hit and all-miss query batches after every round (paper §6)
        live = np.array(sorted(model), dtype=np.int32)
        hits = np.sort(rng.choice(live, size=n))
        res = np.asarray(core.point_query(st, jnp.asarray(hits)))
        assert all(res[i] == model[int(hits[i])] for i in range(n))
        misses = np.setdiff1d(rng.integers(0, 200000, 2 * n).astype(np.int32), live)[:n]
        res = np.asarray(core.point_query(st, jnp.asarray(np.sort(misses))))
        assert (res == int(NOT_FOUND)).all()

    for rnd in range(4):
        dels = np.sort(pool[rnd * per : (rnd + 1) * per])
        st, _ = core.delete(st, jnp.asarray(dels))
        for k in dels.tolist():
            model.pop(k)
    assert int(st.live_keys()) == len(model)

    st = core.restructure_auto(st)
    live = np.array(sorted(model), dtype=np.int32)
    res = np.asarray(core.point_query(st, jnp.asarray(live)))
    assert all(res[i] == model[int(live[i])] for i in range(len(live)))


def test_kv_page_index_serving_plane(rng):
    idx = KVPageIndex()
    # three sequences allocate pages across engine steps
    idx.allocate([1, 1, 1, 2, 2, 3], [0, 1, 2, 0, 1, 0], [10, 11, 12, 20, 21, 30])
    slots = np.asarray(idx.lookup([1, 2, 3, 2], [1, 0, 0, 1]))
    assert slots.tolist() == [11, 20, 30, 21]
    pages, slots, count = idx.pages_of(1)
    assert int(count) == 3
    assert np.asarray(slots)[:3].tolist() == [10, 11, 12]
    assert np.asarray(pages)[:3].tolist() == [0, 1, 2]
    # sequence 1 completes: physical free, slots reclaimed
    idx.free_sequences([1])
    assert idx.live_pages() == 3
    assert np.asarray(idx.lookup([1], [0]))[0] == int(NOT_FOUND)
    # slot reuse for a new sequence
    idx.allocate([7, 7], [0, 1], [10, 11])
    assert np.asarray(idx.lookup([7], [1]))[0] == 11


def test_kv_page_index_pages_of_via_engine(rng):
    """Regression for the pages_of engine bypass: enumeration must go
    through ``apply_ops`` — so it works on a cache-carrying read state,
    reflects every preceding engine step, and can share a batch with the
    updates it should observe (update-then-read)."""
    from repro import core
    from repro.serve.kv_index import PAGE_BITS

    idx = KVPageIndex()
    idx.allocate([5, 5, 5, 9], [0, 1, 2, 0], [50, 51, 52, 90])

    # attach the successor cache, as a read-only query stream would; the
    # old bypass ran range_query outside the engine against whatever state
    # object happened to be cached on the wrapper
    idx.state = core.with_successor_cache(idx.state)
    pages, slots, count = idx.pages_of(5)
    assert int(count) == 3
    assert np.asarray(pages)[:3].tolist() == [0, 1, 2]
    assert np.asarray(slots)[:3].tolist() == [50, 51, 52]

    # a later engine step must be visible to the next enumeration
    idx.state = core.with_successor_cache(idx.state)
    idx.free_sequences([5])
    _, _, count = idx.pages_of(5)
    assert int(count) == 0

    # update-then-read inside ONE engine step: the enumeration travels in
    # the same batch as the allocations it observes
    rng_out = idx.step(
        allocs=([3, 3], [0, 1], [30, 31]),
        ranges=([3 << PAGE_BITS], [4 << PAGE_BITS]),
    ).range_out
    assert int(rng_out["count"][0]) == 2
    got_pages = np.asarray(rng_out["keys"])[:2] & ((1 << PAGE_BITS) - 1)
    assert got_pages.tolist() == [0, 1]
    assert np.asarray(rng_out["vals"])[:2].tolist() == [30, 31]

    # budget truncation surfaces deterministically through the serving API
    pages, slots, count = idx.pages_of(3, max_pages=1)
    assert int(count) == 1 and int(np.asarray(pages)[0]) == 0


@pytest.mark.slow
def test_range_mix_benchmark_cli(tmp_path):
    """The selectivity sweep runs end-to-end and lands in the flix-bench-v1
    artifact with the range speedup map populated."""
    import json

    out = tmp_path / "bench.json"
    env = {
        "PYTHONPATH": f"{REPO}/src",
        "PATH": "/usr/bin:/bin",
        "REPRO_BENCH_JSON": str(out),
    }
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "range_mix"],
        capture_output=True, text=True, env=env, cwd=str(REPO), timeout=3000,
    )
    assert p.returncode == 0, p.stderr
    payload = json.loads(out.read_text())
    assert payload["schema"] == "flix-bench-v1"
    assert not payload["failed"]
    rows = payload["suites"]["range_mix_engine"]
    assert any(name.startswith("range_mix_ref_") for name in rows)
    assert payload["range_fused_speedup"]  # fused/reference pair extracted


@pytest.mark.slow
def test_train_driver_resume_cli(tmp_path):
    """The production driver trains, checkpoints, and resumes (CLI-level)."""
    env = {"PYTHONPATH": f"{REPO}/src", "PATH": "/usr/bin:/bin"}
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "musicgen-medium", "--reduced", "--batch", "4",
        "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ]
    p1 = subprocess.run(
        cmd + ["--steps", "12"], capture_output=True, text=True, env=env,
        cwd=str(REPO), timeout=900,
    )
    assert p1.returncode == 0, p1.stderr
    p2 = subprocess.run(
        cmd + ["--steps", "16"], capture_output=True, text=True, env=env,
        cwd=str(REPO), timeout=900,
    )
    assert p2.returncode == 0, p2.stderr
    assert "resumed from step 12" in p2.stdout


# ---------------------------------------------------------------------------
# versioned snapshot reads (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _range_bytes(idx, as_of=None, hi=1 << 20):
    rr = idx.step(ranges=([0], [hi]), as_of=as_of, range_budget=512).range_out
    return np.asarray(rr["keys"]).tobytes() + np.asarray(rr["vals"]).tobytes()


def test_pinned_range_byte_identical_across_later_batches():
    """THE snapshot-read property: a RANGE pinned to ``as_of=v`` returns
    byte-identical output while ≥3 later update batches commit, and the
    unpinned read sees every later batch."""
    from repro.serve.kv_index import SnapshotGone

    idx = KVPageIndex(snapshot_window=8)
    seqs = np.arange(6)
    idx.allocate(seqs, np.zeros(6, int), seqs * 100)
    v = idx.version
    base = _range_bytes(idx, as_of=v)
    assert base == _range_bytes(idx)  # pin of the head == live view
    for extra in range(4):  # four later update batches
        idx.step(allocs=([50 + extra], [0], [9000 + extra]))
        assert _range_bytes(idx, as_of=v) == base  # still the old cut
        assert _range_bytes(idx) != base  # live view moved on
    assert idx.version == v + 4
    assert v in idx.retained_versions
    # updates can never ride a pinned read
    with pytest.raises(ValueError):
        idx.step(allocs=([99], [0], [1]), as_of=v)
    # a version that never existed is rejected loudly, not silently stale
    with pytest.raises(ValueError):
        idx.step(ranges=([0], [4]), as_of=idx.version + 1)
    # slide the window past v: the pin is reclaimed, typed as such
    for extra in range(8):
        idx.step(allocs=([70 + extra], [0], [1]))
    with pytest.raises(SnapshotGone):
        idx.step(ranges=([0], [4]), as_of=v)
    assert v not in idx.retained_versions


def test_pinned_read_replays_at_pinned_clock():
    """A pin captures its commit's virtual ``now``: pinned reads keep
    seeing rows that expire in LATER batches (the snapshot is a
    consistent cut in both key space and time)."""
    idx = KVPageIndex(snapshot_window=8)
    seqs = np.arange(4)
    # pages with deadline 10, registered at now=0
    idx.step(allocs=(seqs, np.zeros(4, int), seqs * 100, np.full(4, 10)), now=0)
    v = idx.version
    base = _range_bytes(idx, as_of=v)
    # the clock passes the deadline in a later LIVE batch: live view
    # expires the pages, the pinned cut still holds them
    idx.step(allocs=([9], [0], [900], [999]), now=50)
    assert _range_bytes(idx, as_of=v) == base
    got = idx.step(lookups=(seqs, np.zeros(4, int)), now=50).slots
    assert (np.asarray(got) == -1).all()  # live view: all expired


def test_gateway_snapshot_gone_is_typed_and_final():
    """Per-request ``as_of`` through the gateway: pinned lookups resolve
    against the pinned version; once the window slides past it the
    rejection is SNAPSHOT_GONE and non-retryable (the same as_of can
    never succeed again); updates with as_of are INVALID."""
    from repro.serve import SNAPSHOT_GONE, INVALID, Gateway, Request

    idx = KVPageIndex(snapshot_window=2)
    gw = Gateway(idx, default_rate=1e6, default_burst=1e6)
    gw.submit(
        Request("a", "al0", "alloc", seqs=(1,), pages=(0,), slots=(10,)), now=0.0
    )
    gw.pump(now=0.0)
    v = idx.version
    # pinned lookup + live update coalesce into the same pump
    t_pin = gw.submit(
        Request("a", "r1", "lookup", seqs=(1,), pages=(0,), as_of=v), now=1.0
    )
    gw.submit(
        Request("b", "al1", "alloc", seqs=(1,), pages=(1,), slots=(11,)), now=1.0
    )
    gw.pump(now=1.0)
    assert t_pin.ok and int(np.asarray(t_pin.value)[0]) == 10
    # updates cannot pin
    t_bad = gw.submit(
        Request("a", "al2", "alloc", seqs=(2,), pages=(0,), slots=(5,), as_of=v),
        now=2.0,
    )
    assert t_bad.error.code == INVALID and not t_bad.error.retryable
    # slide the window past v with more committed updates
    for i in range(3):
        gw.submit(
            Request("b", f"al{3+i}", "alloc", seqs=(3 + i,), pages=(0,), slots=(i,)),
            now=2.0 + i,
        )
        gw.pump(now=2.0 + i)
    t_gone = gw.submit(
        Request("a", "r2", "lookup", seqs=(1,), pages=(0,), as_of=v), now=9.0
    )
    gw.pump(now=9.0)
    assert t_gone.error.code == SNAPSHOT_GONE and not t_gone.error.retryable


# ---------------------------------------------------------------------------
# TTL durability: crash recovery replays at the LOGGED clock (§14)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "event,count",
    [
        ("wal.append.partial", 2),
        ("apply.done", 3),
        # count 2: the first payload write is create()'s initial full
        # snapshot — killing there leaves nothing to recover (a fresh
        # create is the documented restart path, not recovery)
        ("snap.payload.partial", 2),
    ],
)
def test_ttl_crash_recovery_replays_at_logged_clock(tmp_path, event, count):
    """Kill the TTL workload mid-flight: recovery must replay each WAL
    batch at the ``now`` logged IN its record — never the wall clock, or
    the recovered expiry state would depend on when recovery ran.  The
    recovered canonical payload (expiry column included) must be
    byte-identical to the uninterrupted oracle at the recovered seq, and
    resuming to completion must land on the oracle's final bytes."""
    import fault_injection as fi

    n = 8
    oracle = fi.oracle_canonical_ttl(n)
    d = tmp_path / "ttl"
    acked = []
    try:
        fi.run_workload_ttl(
            d, n, crash_hook=fi.CrashAt(event, count), ack=acked.append
        )
        raise AssertionError(f"hook {event}#{count} never fired")
    except fi.CrashError:
        pass
    seq = fi.recover_and_check(d, oracle, acked=max(acked, default=0))
    assert seq <= n
    fi.run_workload_ttl(d, n)
    assert fi.recover_and_check(d, oracle, acked=n) == n
