"""Property-based RANGE suite: hypothesis-generated adversarial batches.

Pins the three executors to one contract (DESIGN.md §10):

  * the jnp reference phase (``dense_range_scan`` via ``apply_ops``)
    matches a python dict/sorted-list model under arbitrary mixed batches —
    empty ranges, ``lo == hi``, inverted bounds, ranges spanning bucket
    boundaries, ranges covering keys deleted (or inserted) in the *same*
    batch, and budget overflow;
  * the standalone two-pass kernel (``kernels/flix_range``) and the fused
    apply kernel match the oracle element-for-element (interpret mode);
  * truncation under ``max_results`` is deterministic (same batch → same
    bytes) and flagged via ``stats["range_truncated"]``.

Geometries are kept tiny so the interpret-mode Pallas comparisons stay
inside the fast CI job.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.state import EMPTY, NOT_FOUND
from repro.kernels.flix_range import flix_range_pallas
from repro.core.config import ExecConfig

# hypothesis drives the wide generative sweep in CI (requirements-dev.txt);
# without it the seeded-rng fallbacks below still exercise every property,
# so this module never goes dark on a minimal container.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
    KEY = st.integers(min_value=0, max_value=4000)
    SPAN = st.integers(min_value=-50, max_value=600)  # negative → inverted
    COMMON = dict(deadline=None, suppress_health_check=[HealthCheck.too_slow])
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False


def _model_segments(post: dict, tags, keys, vals, max_results):
    """Expected dense output from a python model, in sorted batch order."""
    live = np.array(sorted(post), dtype=np.int64)
    order = np.argsort(keys, kind="stable")
    dense_k, dense_v, starts, counts = [], [], {}, {}
    truncated = 0
    cursor = 0
    for i in order:
        if tags[i] != core.OP_RANGE:
            continue
        lo, hi = int(keys[i]), int(vals[i])
        seg = live[(live >= lo) & (live < hi)]
        n = min(len(seg), max_results - cursor)
        if n < len(seg):
            truncated += 1
        starts[i], counts[i] = cursor, n
        dense_k.extend(int(k) for k in seg[:n])
        dense_v.extend(post[int(k)] for k in seg[:n])
        cursor += n
    return dense_k, dense_v, starts, counts, truncated


def _build_batch(build, inserts, deletes, ranges):
    """A mixed batch + its python post-state model (update-then-read)."""
    bkeys = np.array(sorted(set(build)), dtype=np.int32)
    bvals = np.arange(len(bkeys), dtype=np.int32)
    state = core.build(bkeys, bvals, node_size=4, nodes_per_bucket=4)
    post = dict(zip(bkeys.tolist(), bvals.tolist()))

    ins = np.array(sorted(set(inserts)), dtype=np.int32)
    dels = np.array(
        sorted(set(deletes) - set(ins.tolist())), dtype=np.int32
    )  # one update op per key
    iv = ins + 100_000
    for k, v in zip(ins.tolist(), iv.tolist()):
        post[k] = v
    for k in dels.tolist():
        post.pop(k, None)

    los = np.array([lo for lo, _ in ranges], dtype=np.int32)
    his = np.array([lo + span for lo, span in ranges], dtype=np.int32)
    tags = np.concatenate([
        np.full(len(ins), core.OP_INSERT),
        np.full(len(dels), core.OP_DELETE),
        np.full(len(los), core.OP_RANGE),
    ]).astype(np.int32)
    keys = np.concatenate([ins, dels, los]).astype(np.int32)
    vals = np.concatenate([iv, np.zeros(len(dels), np.int32), his]).astype(
        np.int32
    )
    return state, post, tags, keys, vals


def _check_reference_matches_model(build, inserts, deletes, ranges, budget):
    """The oracle == dict model, including same-batch update visibility,
    empty/inverted ranges, and deterministic budget truncation."""
    state, post, tags, keys, vals = _build_batch(build, inserts, deletes, ranges)
    ops, perm = core.make_ops(tags, keys, vals, pad_to=256)
    _, res, stats = core.apply_ops_safe(
        state, ops, config=ExecConfig(impl="reference", max_results=budget, validate_ranges=True)
    )
    dk, dv, starts, counts, truncated = _model_segments(
        post, tags, keys, vals, budget
    )
    got_k = np.asarray(res["range_key"])
    got_v = np.asarray(res["range_val"])
    np.testing.assert_array_equal(got_k[: len(dk)], np.array(dk, np.int32))
    np.testing.assert_array_equal(got_v[: len(dv)], np.array(dv, np.int32))
    assert (got_k[len(dk):] == int(EMPTY)).all()
    assert (got_v[len(dv):] == int(NOT_FOUND)).all()
    rs = np.asarray(core.unsort(res["range_start"], perm))[: len(keys)]
    rc = np.asarray(core.unsort(res["range_count"], perm))[: len(keys)]
    for i, s in starts.items():
        assert rs[i] == s and rc[i] == counts[i], (i, rs[i], rc[i])
    assert int(stats["range_truncated"]) == truncated


def _check_standalone_kernel_matches_oracle(build, ranges, budget):
    """flix_range_pallas (two-pass count/scatter) == dense_range_scan,
    element for element, on a static state."""
    bkeys = np.array(sorted(set(build)), dtype=np.int32)
    state = core.build(
        bkeys, np.arange(len(bkeys), dtype=np.int32),
        node_size=4, nodes_per_bucket=4,
    )
    raw_lo = np.array([lo for lo, _ in ranges], np.int32)
    order = np.argsort(raw_lo, kind="stable")
    los = raw_lo[order]
    his = np.array([lo + span for lo, span in ranges], np.int32)[order]
    gk, gv, gs, gc, gt = flix_range_pallas(
        state.keys, state.vals, state.mkba,
        jnp.asarray(los), jnp.asarray(his),
        max_results=budget, interpret=True,
    )
    wk, wv, ws, wc, wt = core.dense_range_scan(
        state, jnp.ones((len(los),), bool), jnp.asarray(los), jnp.asarray(his),
        max_results=budget,
    )
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    assert int(gt) == int(wt)


def _check_fused_matches_reference(build, inserts, deletes, ranges, budget):
    """apply_ops(impl="fused") == impl="reference" byte-for-byte on mixed
    batches containing RANGE (interpret mode)."""
    state, _, tags, keys, vals = _build_batch(build, inserts, deletes, ranges)
    ops, _ = core.make_ops(tags, keys, vals, pad_to=128)
    s_ref, r_ref, t_ref = core.apply_ops(
        state, ops, config=ExecConfig(impl="reference", max_results=budget)
    )
    if bool(s_ref.needs_restructure):
        return  # overflowed buckets are untrustworthy by contract
    s_f, r_f, t_f = core.apply_ops(state, ops, config=ExecConfig(impl="fused", max_results=budget))
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, f)), np.asarray(getattr(s_f, f)), err_msg=f
        )
    for k in ("range_key", "range_val", "range_start", "range_count"):
        np.testing.assert_array_equal(
            np.asarray(r_ref[k]), np.asarray(r_f[k]), err_msg=k
        )
    for k in t_ref:
        assert int(t_ref[k]) == int(t_f[k]), k


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, **COMMON)
    @given(
        build=st.lists(KEY, min_size=1, max_size=150),
        inserts=st.lists(KEY, max_size=30),
        deletes=st.lists(KEY, max_size=30),
        ranges=st.lists(st.tuples(KEY, SPAN), min_size=1, max_size=12),
        budget=st.sampled_from([8, 32, 128]),
    )
    def test_reference_range_matches_model(
        build, inserts, deletes, ranges, budget
    ):
        _check_reference_matches_model(build, inserts, deletes, ranges, budget)

    @settings(max_examples=8, **COMMON)
    @given(
        build=st.lists(KEY, min_size=1, max_size=120),
        ranges=st.lists(st.tuples(KEY, SPAN), min_size=1, max_size=10),
        budget=st.sampled_from([16, 64]),
    )
    def test_standalone_kernel_matches_oracle(build, ranges, budget):
        _check_standalone_kernel_matches_oracle(build, ranges, budget)

    @settings(max_examples=6, **COMMON)
    @given(
        build=st.lists(KEY, min_size=1, max_size=100),
        inserts=st.lists(KEY, max_size=15),
        deletes=st.lists(KEY, max_size=15),
        ranges=st.lists(st.tuples(KEY, SPAN), min_size=1, max_size=6),
        budget=st.sampled_from([16, 64]),
    )
    def test_fused_range_matches_reference(
        build, inserts, deletes, ranges, budget
    ):
        _check_fused_matches_reference(build, inserts, deletes, ranges, budget)


def _random_case(rng, *, n_build, n_ins, n_del, n_range):
    """One adversarial case: random batch + hand-planted edge ranges."""
    build = rng.choice(4000, size=n_build, replace=False).tolist()
    inserts = rng.choice(4000, size=n_ins, replace=False).tolist()
    deletes = rng.choice(build, size=min(n_del, n_build), replace=False).tolist()
    ranges = [
        (int(lo), int(span))
        for lo, span in zip(
            rng.integers(0, 4000, n_range), rng.integers(-50, 600, n_range)
        )
    ]
    # always include the structured edges: empty, lo==hi, inverted, covering
    # a key deleted in this batch, and a full-span range
    if deletes:
        ranges.append((int(deletes[0]), 1))
    ranges.extend([(100, 0), (200, -10), (0, 4000)])
    return build, inserts, deletes, ranges


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_reference_range_matches_model_seeded(seed):
    """Seeded fallback for the hypothesis sweep (runs everywhere)."""
    rng = np.random.default_rng(seed)
    build, inserts, deletes, ranges = _random_case(
        rng, n_build=140, n_ins=25, n_del=25, n_range=10
    )
    for budget in (8, 32, 128):
        _check_reference_matches_model(build, inserts, deletes, ranges, budget)


@pytest.mark.parametrize("seed", [4, 5])
def test_standalone_kernel_matches_oracle_seeded(seed):
    rng = np.random.default_rng(seed)
    build, _, _, ranges = _random_case(
        rng, n_build=110, n_ins=0, n_del=0, n_range=8
    )
    _check_standalone_kernel_matches_oracle(build, ranges, 64)


@pytest.mark.parametrize("seed", [6, 7])
def test_fused_range_matches_reference_seeded(seed):
    rng = np.random.default_rng(seed)
    build, inserts, deletes, ranges = _random_case(
        rng, n_build=90, n_ins=12, n_del=12, n_range=5
    )
    _check_fused_matches_reference(build, inserts, deletes, ranges, 64)


def test_truncation_deterministic_and_flagged(rng):
    """Re-running an over-budget batch yields identical bytes on both
    executors, and the truncation flag fires exactly when results are cut."""
    keys = np.sort(rng.choice(50000, 1500, replace=False)).astype(np.int32)
    st_ = core.build(keys, np.arange(1500, dtype=np.int32),
                     node_size=8, nodes_per_bucket=8)
    los = np.sort(rng.choice(40000, 12)).astype(np.int32)
    his = (los + 8000).astype(np.int32)  # far more hits than any budget
    tags = np.full(12, core.OP_RANGE, np.int32)
    ops, _ = core.make_ops(tags, los, his, pad_to=16)
    runs = []
    for impl in ("reference", "fused", "reference"):
        _, res, stats = core.apply_ops(st_, ops, config=ExecConfig(impl=impl, max_results=64))
        assert int(stats["range_truncated"]) > 0
        runs.append({k: np.asarray(v) for k, v in res.items()})
    for k in ("range_key", "range_val", "range_start", "range_count"):
        np.testing.assert_array_equal(runs[0][k], runs[1][k], err_msg=k)
        np.testing.assert_array_equal(runs[0][k], runs[2][k], err_msg=k)
    # earlier sorted ops win the budget: segments tile [0, 64) exactly
    rc = runs[0]["range_count"]
    assert rc.sum() == 64
    # an under-budget run of the same batch is complete and unflagged
    _, res_big, stats_big = core.apply_ops(
        st_, ops, config=ExecConfig(impl="reference", max_results=4096)
    )
    assert int(stats_big["range_truncated"]) == 0
    n_total = int(np.asarray(res_big["range_count"]).sum())
    assert n_total > 64


def test_bucket_boundary_ranges(rng):
    """Ranges whose [lo, hi) endpoints sit exactly on bucket fences."""
    keys = np.arange(0, 6000, 3, dtype=np.int32)
    st_ = core.build(keys, keys, node_size=8, nodes_per_bucket=4)
    mk = np.asarray(st_.mkba)[:-1]
    mk = mk[(mk > 0) & (mk < 6000)][:6].astype(np.int64)
    los = np.concatenate([mk, mk + 1]).astype(np.int32)
    his = np.concatenate([mk + 1, mk + 500]).astype(np.int32)
    tags = np.full(len(los), core.OP_RANGE, np.int32)
    ops, _ = core.make_ops(tags, los, his, pad_to=16)
    _, res, _ = core.apply_ops(st_, ops, config=ExecConfig(impl="reference", max_results=1024))
    core.check_range_results(ops, res, max_results=1024)
    _, res_f, _ = core.apply_ops(st_, ops, config=ExecConfig(impl="fused", max_results=1024))
    for k in ("range_key", "range_val", "range_start", "range_count"):
        np.testing.assert_array_equal(
            np.asarray(res[k]), np.asarray(res_f[k]), err_msg=k
        )
    # model check: a fence key [mkba, mkba+1) is exactly its bucket max
    live = set(keys.tolist())
    t = np.asarray(ops.tag)
    kk, vv = np.asarray(ops.key), np.asarray(ops.val)
    rc = np.asarray(res["range_count"])
    for i in np.nonzero(t == core.OP_RANGE)[0]:
        expect = sum(1 for k in live if kk[i] <= k < vv[i])
        assert rc[i] == expect, (i, rc[i], expect)
