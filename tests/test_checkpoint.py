"""Checkpoint manager: atomic commit, async, retention, resume, elastic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path, tree):
    save_pytree(tmp_path / "ck", tree, extra={"data_step": 7})
    restored, extra = restore_pytree(tmp_path / "ck", tree)
    assert extra["data_step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(restored["nested"]["b"]), np.asarray(tree["nested"]["b"])
    )


def test_structure_mismatch_rejected(tmp_path, tree):
    save_pytree(tmp_path / "ck", tree)
    with pytest.raises(AssertionError):
        restore_pytree(tmp_path / "ck", {"wrong": tree["a"]})


def test_atomic_commit_no_partial_state(tmp_path, tree):
    """A leftover .tmp dir (simulated crash) must not shadow a good ckpt."""
    save_pytree(tmp_path / "ck", tree)
    # simulate a crashed later save
    (tmp_path / "ck2.tmp").mkdir()
    (tmp_path / "ck2.tmp" / "garbage").write_text("crash")
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() is None  # tmp dirs are never listed
    restored, _ = restore_pytree(tmp_path / "ck", tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_manager_async_save_retention_resume(tmp_path, tree):
    mgr = CheckpointManager(tmp_path / "run", keep=2)
    for step in (10, 20, 30, 40):
        t = jax.tree.map(lambda a: a + step, tree)
        mgr.save(step, t, extra={"data_step": step})
        mgr.wait()
    assert mgr.latest_step() == 40
    steps = sorted(p.name for p in (tmp_path / "run").glob("step_*"))
    assert len(steps) == 2  # retention
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 40 and extra["data_step"] == 40
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(tree["a"]) + 40
    )


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 4×2 mesh, restore onto 2×4 and 8×1 — elastic restart."""
    from tests.conftest import run_with_devices

    out = run_with_devices(
        f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_pytree, restore_pytree

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        specs = {{"w": P("data", "model")}}
        from repro.launch.mesh import make_mesh_auto
        mesh1 = make_mesh_auto((4, 2), ("data", "model"))
        sharded = jax.tree.map(
            lambda a: jax.device_put(a, NamedSharding(mesh1, P("data", "model"))), tree)
        save_pytree("{tmp_path}/ck", sharded, specs=specs, extra={{}})

        for shape in ((2, 4), (8, 1), (1, 1)):
            mesh2 = make_mesh_auto(shape, ("data", "model"))
            restored, _ = restore_pytree("{tmp_path}/ck", tree, mesh=mesh2, specs=specs)
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            assert restored["w"].sharding.mesh.shape["data"] == shape[0]
        print("ELASTIC_OK")
        """,
        n_devices=8,
    )
    assert "ELASTIC_OK" in out
