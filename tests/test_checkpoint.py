"""Checkpoint manager: atomic commit, async, retention, resume, elastic."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    restore_pytree,
    save_pytree,
    tmp_sibling,
)
from repro.checkpoint import manager as manager_mod


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path, tree):
    save_pytree(tmp_path / "ck", tree, extra={"data_step": 7})
    restored, extra = restore_pytree(tmp_path / "ck", tree)
    assert extra["data_step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(
        np.asarray(restored["nested"]["b"]), np.asarray(tree["nested"]["b"])
    )


def test_structure_mismatch_rejected(tmp_path, tree):
    save_pytree(tmp_path / "ck", tree)
    with pytest.raises(AssertionError):
        restore_pytree(tmp_path / "ck", {"wrong": tree["a"]})


def test_atomic_commit_no_partial_state(tmp_path, tree):
    """A leftover .tmp dir (simulated crash) must not shadow a good ckpt."""
    save_pytree(tmp_path / "ck", tree)
    # simulate a crashed later save
    (tmp_path / "ck2.tmp").mkdir()
    (tmp_path / "ck2.tmp" / "garbage").write_text("crash")
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() is None  # tmp dirs are never listed
    restored, _ = restore_pytree(tmp_path / "ck", tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_manager_async_save_retention_resume(tmp_path, tree):
    mgr = CheckpointManager(tmp_path / "run", keep=2)
    for step in (10, 20, 30, 40):
        t = jax.tree.map(lambda a: a + step, tree)
        mgr.save(step, t, extra={"data_step": step})
        mgr.wait()
    assert mgr.latest_step() == 40
    steps = sorted(p.name for p in (tmp_path / "run").glob("step_*"))
    assert len(steps) == 2  # retention
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 40 and extra["data_step"] == 40
    np.testing.assert_array_equal(
        np.asarray(restored["a"]), np.asarray(tree["a"]) + 40
    )


def test_dotted_path_save_roundtrip(tmp_path, tree):
    """Targets with dots in the name commit correctly.  The old scratch
    naming (``with_suffix(".tmp")``) mangled ``step_0.5k`` to ``step_0.tmp``
    — the commit rename then restored the wrong directory name."""
    for name in ("step_0.5k", "step_1.5k", "ck.v2.final"):
        save_pytree(tmp_path / name, tree, extra={"name": name})
        _, extra = restore_pytree(tmp_path / name, tree)
        assert extra["name"] == name
    # nothing left behind but the committed dirs
    leftovers = [p.name for p in tmp_path.iterdir() if ".tmp" in p.name]
    assert leftovers == []


def test_tmp_sibling_unique_and_name_preserving(tmp_path):
    """Scratch names keep the FULL target name (dots included) and never
    collide — concurrent savers of dotted siblings used to race on the
    same ``with_suffix`` scratch path."""
    a = tmp_sibling(tmp_path / "step_0.5k")
    b = tmp_sibling(tmp_path / "step_0.5k")
    c = tmp_sibling(tmp_path / "step_0.9k")
    assert a != b  # unique per call, even for the same target
    assert len({a, b, c}) == 3
    for t in (a, b, c):
        assert t.parent == tmp_path
        assert t.name.startswith("step_0.") and ".tmp-" in t.name
    # distinct dotted targets can no longer alias each other's scratch dir
    assert not c.name.startswith("step_0.5k") or "step_0.9k" in c.name


def test_retention_keeps_exactly_newest(tmp_path, tree):
    mgr = CheckpointManager(tmp_path / "run", keep=3)
    for step in range(1, 8):
        mgr.save(step, tree)
        mgr.wait()
    kept = sorted(p.name for p in (tmp_path / "run").glob("step_*"))
    assert kept == [f"step_{s:08d}" for s in (5, 6, 7)]
    assert mgr.latest_step() == 7


class _GatedSave:
    """A save_pytree stand-in the worker thread blocks on — makes the
    async queue's interleavings deterministic without sleeps."""

    def __init__(self):
        self.started = threading.Event()  # worker entered a save
        self.release = threading.Event()  # allow it to finish
        self.saved = []

    def __call__(self, path, tree, *, specs=None, extra=None):
        self.started.set()
        assert self.release.wait(timeout=30)
        save_pytree(path, tree, specs=specs, extra=extra)
        self.saved.append(path.name)


def test_async_queue_newest_wins(tmp_path, tree, monkeypatch):
    """While the writer is busy, queued saves are superseded: only the
    newest pending request is ever written."""
    gate = _GatedSave()
    monkeypatch.setattr(manager_mod, "save_pytree", gate)
    mgr = CheckpointManager(tmp_path / "run", keep=10)
    mgr.save(1, tree)
    assert gate.started.wait(timeout=30)  # worker is inside save(1)
    mgr.save(2, tree)  # pending
    mgr.save(3, tree)  # supersedes 2
    mgr.save(4, tree)  # supersedes 3
    gate.release.set()
    mgr.wait()
    assert gate.saved == ["step_00000001", "step_00000004"]
    assert mgr.latest_step() == 4


def test_wait_drains_before_restore(tmp_path, tree, monkeypatch):
    """restore_latest after wait() must see the save that was in flight —
    and before wait() the commit genuinely hasn't happened."""
    gate = _GatedSave()
    monkeypatch.setattr(manager_mod, "save_pytree", gate)
    mgr = CheckpointManager(tmp_path / "run")
    mgr.save(5, tree, extra={"data_step": 5})
    assert gate.started.wait(timeout=30)
    assert mgr.latest_step() is None  # still uncommitted
    gate.release.set()
    mgr.wait()
    step, _restored, extra = mgr.restore_latest(tree)
    assert step == 5 and extra["data_step"] == 5


def test_failed_save_leaves_no_scratch(tmp_path, tree):
    """An exception mid-save cleans up its scratch dir and never commits."""

    class Boom:
        def __array__(self, *a, **k):
            raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        save_pytree(tmp_path / "ck", {"a": Boom()})
    assert list(tmp_path.iterdir()) == []  # no ck, no .tmp-* leftovers


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 4×2 mesh, restore onto 2×4 and 8×1 — elastic restart."""
    from tests.conftest import run_with_devices

    out = run_with_devices(
        f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_pytree, restore_pytree

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        specs = {{"w": P("data", "model")}}
        from repro.launch.mesh import make_mesh_auto
        mesh1 = make_mesh_auto((4, 2), ("data", "model"))
        sharded = jax.tree.map(
            lambda a: jax.device_put(a, NamedSharding(mesh1, P("data", "model"))), tree)
        save_pytree("{tmp_path}/ck", sharded, specs=specs, extra={{}})

        for shape in ((2, 4), (8, 1), (1, 1)):
            mesh2 = make_mesh_auto(shape, ("data", "model"))
            restored, _ = restore_pytree("{tmp_path}/ck", tree, mesh=mesh2, specs=specs)
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            assert restored["w"].sharding.mesh.shape["data"] == shape[0]
        print("ELASTIC_OK")
        """,
        n_devices=8,
    )
    assert "ELASTIC_OK" in out
