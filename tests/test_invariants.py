"""Structural invariants I1–I6 under randomized operation sequences.

``state.py`` documents the invariants and this file checks them: every
mutating operation must map an invariant-satisfying state to an
invariant-satisfying state (overflow-flagged states excepted — their
contents are declared untrustworthy until restructuring).  The reusable
checker lives in ``repro.core.invariants`` so kernels and drivers can
assert it too.

I6 (expiry liveness, DESIGN.md §14) gets its own positive + negative
block at the bottom: the checker must accept every engine-produced TTL
state and *reject* a hand-corrupted one — a leaked expired row (live key
past its deadline at the threaded ``now``) and a stale deadline parked
on an empty slot both raise.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.invariants import check_invariants
from repro.core.config import ExecConfig


def _rand_state(rng, n=2000, ns=8, npb=8, space=100000):
    keys = rng.choice(space, size=n, replace=False).astype(np.int32)
    vals = np.arange(n, dtype=np.int32)
    st = core.build(keys, vals, node_size=ns, nodes_per_bucket=npb)
    return st, dict(zip(keys.tolist(), vals.tolist()))


def test_empty_and_built_states_satisfy_invariants(rng):
    check_invariants(core.empty_state(4, 4, 8))
    st, model = _rand_state(rng)
    check_invariants(st)
    assert int(st.live_keys()) == len(model)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_insert_delete_sequences(seed):
    rng = np.random.default_rng(seed)
    st, model = _rand_state(rng, n=1500)
    space = np.arange(100000, dtype=np.int32)
    for step in range(6):
        if step % 2 == 0:
            pool = np.setdiff1d(space, np.array(sorted(model), np.int32))
            ins = rng.choice(pool, size=400, replace=False).astype(np.int32)
            iv = rng.integers(0, 1 << 30, size=400).astype(np.int32)
            sk, sv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
            st, _ = core.insert_safe(st, sk, sv)
            model.update(zip(ins.tolist(), iv.tolist()))
        else:
            live = np.array(sorted(model), np.int32)
            dels = rng.choice(live, size=min(500, len(live)), replace=False)
            st, _ = core.delete(st, jnp.asarray(np.sort(dels)))
            for k in dels.tolist():
                model.pop(k)
        check_invariants(st)
        assert int(st.live_keys()) == len(model)


def test_restructure_preserves_invariants(rng):
    st, model = _rand_state(rng)
    live = np.array(sorted(model), np.int32)
    st, _ = core.delete(st, jnp.asarray(live[::2]))
    for k in live[::2].tolist():
        del model[k]
    for fn in (core.merge_underfull, core.restructure_auto):
        st2 = fn(st)
        check_invariants(st2)
        assert int(st2.live_keys()) == len(model)


@pytest.mark.parametrize("seed", [3, 4])
def test_mixed_apply_ops_sequences(seed):
    """apply_ops_safe preserves I1–I5 across randomized mixed steps, and
    every step's RANGE output passes the structural range checker (sorted,
    in-bounds, duplicate-free, consecutively packed —
    ``validate_ranges=True`` wires ``check_range_results`` in)."""
    rng = np.random.default_rng(seed)
    st, model = _rand_state(rng, n=1200)
    space = np.arange(100000, dtype=np.int32)
    for _ in range(4):
        live = np.array(sorted(model), np.int32)
        pool = np.setdiff1d(space, live)
        ins = rng.choice(pool, size=200, replace=False).astype(np.int32)
        iv = rng.integers(0, 1 << 30, size=200).astype(np.int32)
        dels = rng.choice(live, size=150, replace=False).astype(np.int32)
        reads = rng.integers(0, 100000, size=300).astype(np.int32)
        rlo = np.sort(rng.integers(0, 95000, size=20)).astype(np.int32)
        rhi = (rlo + rng.integers(0, 5000, size=20)).astype(np.int32)
        tags = np.concatenate([
            np.full(200, core.OP_INSERT), np.full(150, core.OP_DELETE),
            np.full(150, core.OP_POINT), np.full(150, core.OP_SUCCESSOR),
            np.full(20, core.OP_RANGE),
        ]).astype(np.int32)
        keys = np.concatenate([ins, dels, reads, rlo]).astype(np.int32)
        vals = np.concatenate([iv, np.zeros(450, np.int32), rhi])
        ops, _ = core.make_ops(tags, keys, vals, pad_to=1024)
        st, results, stats = core.apply_ops_safe(
            st, ops, config=ExecConfig(max_results=256, validate_ranges=True)
        )
        model.update(zip(ins.tolist(), iv.tolist()))
        for k in dels.tolist():
            model.pop(k)
        check_invariants(st)
        assert int(st.live_keys()) == len(model)
        assert int(stats["inserted"]) == 200
        assert int(stats["deleted"]) == 150
        # every emitted range key is live in the post-apply state
        emitted = int(np.asarray(results["range_count"]).sum())
        got = np.asarray(results["range_key"])[:emitted]
        assert all(int(k) in model for k in got)


def test_check_range_results_catches_violations(rng):
    """The checker actually rejects malformed dense output."""
    st, _ = _rand_state(rng, n=400)
    rlo = np.array([100, 5000], np.int32)
    rhi = np.array([4000, 60000], np.int32)
    ops, _ = core.make_ops(
        np.full(2, core.OP_RANGE, np.int32), rlo, rhi, pad_to=4
    )
    _, results, _ = core.apply_ops(st, ops, config=ExecConfig(impl="reference", max_results=64))
    core.check_range_results(ops, results, max_results=64)
    bad = dict(results)
    bad["range_key"] = np.asarray(results["range_key"]).copy()
    c0 = int(np.asarray(results["range_count"])[np.asarray(ops.tag) == core.OP_RANGE][0])
    if c0 >= 2:
        bad["range_key"][[0, 1]] = bad["range_key"][[1, 0]]  # break sortedness
        with pytest.raises(AssertionError):
            core.check_range_results(ops, bad, max_results=64)
    bad2 = dict(results)
    bad2["range_count"] = np.asarray(results["range_count"]).copy()
    bad2["range_count"][np.argmax(np.asarray(ops.tag) == core.OP_RANGE)] += 1
    with pytest.raises(AssertionError):
        core.check_range_results(ops, bad2, max_results=64)


# ---------------------------------------------------------------------------
# I6: expiry liveness (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _ttl_state(rng, *, now=100):
    """A TTL state the engine itself produced and already expired at
    ``now`` — every surviving deadline is > now by construction."""
    from repro.checkpoint.serialize import state_from_pairs

    keys = np.sort(rng.choice(5000, 300, replace=False)).astype(np.int32)
    vals = (keys * 3).astype(np.int32)
    exps = np.where(
        rng.random(300) < 0.5, now + rng.integers(1, 500, 300), core.NO_EXPIRY
    ).astype(np.int32)
    st = state_from_pairs(keys, vals, exps, node_size=8, nodes_per_bucket=4)
    ops, _ = core.make_ops(
        np.array([core.OP_POINT], np.int32),
        np.array([0], np.int32),
        np.array([0], np.int32),
        pad_to=8,
    )
    st, _, _ = core.apply_ops(st, ops, now=now, config=ExecConfig(impl="reference"))
    return st


def test_i6_accepts_engine_produced_ttl_states(rng):
    """Positive control: post-expiry states pass I6 at the stepped now
    (and at any earlier now — expiry is monotone)."""
    st = _ttl_state(rng, now=100)
    check_invariants(st, now=100)
    check_invariants(st, now=0)
    check_invariants(st)  # structural half only


def test_i6_rejects_leaked_expired_row(rng):
    """A live row whose deadline is <= now must have been reclaimed —
    planting one makes the checker raise."""
    import dataclasses

    import jax.numpy as jnp2

    st = _ttl_state(rng, now=100)
    keys = np.asarray(st.keys)
    b, j, s = np.argwhere(keys != int(core.EMPTY))[0]
    bad_exps = np.asarray(st.exps).copy()
    bad_exps[b, j, s] = 100  # exp <= now: expired but still live
    bad = dataclasses.replace(st, exps=jnp2.asarray(bad_exps))
    with pytest.raises(AssertionError, match="past their expiry deadline"):
        check_invariants(bad, now=100)
    # without a clock the liveness half is (correctly) unjudgeable
    check_invariants(bad, now=99)
    check_invariants(bad)


def test_i6_rejects_stale_deadline_on_empty_slot(rng):
    """Reclaimed/empty slots must hold NO_EXPIRY so a stale deadline can
    never leak onto a future occupant of the slot."""
    import dataclasses

    import jax.numpy as jnp2

    st = _ttl_state(rng, now=100)
    keys = np.asarray(st.keys)
    b, j, s = np.argwhere(keys == int(core.EMPTY))[0]
    bad_exps = np.asarray(st.exps).copy()
    bad_exps[b, j, s] = 12345
    bad = dataclasses.replace(st, exps=jnp2.asarray(bad_exps))
    with pytest.raises(AssertionError, match="stale expiry deadline"):
        check_invariants(bad)  # structural: fails even without a now


def test_i6_wired_through_apply_ops_safe(rng):
    """``apply_ops_safe(validate=True, now=...)`` runs the I6 check on
    every validated step — including the §14 same-batch edge, where a
    batch writing a dead-on-arrival row must NOT false-positive."""
    from repro.checkpoint.serialize import state_from_pairs

    st = state_from_pairs(
        np.array([10, 20], np.int32),
        np.array([1, 2], np.int32),
        np.array([500, core.NO_EXPIRY], np.int32),
        node_size=4,
        nodes_per_bucket=4,
    )
    now = 50
    tags = np.array([core.OP_INSERT, core.OP_POINT], np.int32)
    keys = np.array([30, 30], np.int32)
    vals = np.array([3, 0], np.int32)
    exps = np.array([now, core.NO_EXPIRY], np.int32)  # deadline == now
    ops, perm = core.make_ops(tags, keys, vals, exps=jnp.asarray(exps), pad_to=8)
    st, res, _ = core.apply_ops_safe(
        st, ops, now=now, config=ExecConfig(impl="reference", validate=True)
    )
    assert int(np.asarray(core.unsort(res["value"], perm))[1]) == 3
    # next batch's pre-pass reclaims it; liveness IS asserted there
    ops2, _ = core.make_ops(
        np.array([core.OP_NOP], np.int32),
        np.array([0], np.int32),
        np.array([0], np.int32),
        pad_to=8,
    )
    st, _, stats = core.apply_ops_safe(
        st, ops2, now=now, config=ExecConfig(impl="reference", validate=True)
    )
    assert int(stats["expired"]) == 1
    check_invariants(st, now=now)


def test_overflowed_state_recovers_via_restructure(rng):
    """Overflow marks the state; restructuring restores the invariants."""
    keys = np.arange(0, 640, 10, dtype=np.int32)
    st = core.build(keys, keys, node_size=4, nodes_per_bucket=2)
    flood = np.arange(1, 200, 2, dtype=np.int32)
    sk, sv = core.sort_batch(jnp.asarray(flood), jnp.asarray(flood))
    st1, _ = core.insert(st, sk, sv)
    assert bool(st1.needs_restructure)
    st2, _ = core.insert_safe(st, sk, sv)
    check_invariants(st2)
