"""Distributed FliX + sharded train step on 8 fake host devices.

These run in subprocesses so the main test process keeps its single real
device (smoke tests must not see 512 devices — launcher contract)."""

import pytest

from tests.conftest import run_with_devices

pytestmark = pytest.mark.slow  # subprocess multi-device runs


def test_shard_apply_ops_end_to_end():
    """Mixed batch through shard_apply_ops == dict model, both routings,
    on a model-checked insert → delete → read sequence (8 shards)."""
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro import core
        from repro.core import distributed as dist

        mesh = dist.make_shard_mesh(8)
        rng = np.random.default_rng(11)
        universe = rng.permutation(200000).astype(np.int32)
        keys, extra = universe[:4000], universe[4000:6000]
        vals = np.arange(4000, dtype=np.int32)
        sk = np.sort(keys); sv = vals[np.argsort(keys)]
        model = dict(zip(keys.tolist(), vals.tolist()))

        idx = dist.shard_build(jnp.asarray(sk), jnp.asarray(sv), mesh, node_size=16, nodes_per_bucket=8)

        # one mixed batch: insert `extra`, delete a third of `keys`, and
        # read points + successors in the same step (update-then-read)
        dels = keys[::3]
        n_pt, n_sc = 400, 200
        pts = rng.integers(0, 200000, n_pt).astype(np.int32)
        sq = rng.integers(0, 200001, n_sc).astype(np.int32)
        tags = np.concatenate([
            np.full(extra.shape, core.OP_INSERT), np.full(dels.shape, core.OP_DELETE),
            np.full(n_pt, core.OP_POINT), np.full(n_sc, core.OP_SUCCESSOR)]).astype(np.int32)
        bk = np.concatenate([extra, dels, pts, sq]).astype(np.int32)
        bv = np.zeros(bk.shape, np.int32); bv[:extra.shape[0]] = np.arange(extra.shape[0]) + 500000
        ops, perm = core.make_ops(tags, bk, bv, pad_to=4096)
        for k, v in zip(extra, bv[:extra.shape[0]]): model[int(k)] = int(v)
        for k in dels: del model[int(k)]
        live = np.array(sorted(model))
        EMPTY = np.iinfo(np.int32).max

        for routing in ("replicated", "a2a"):
            _, res, stats = dist.shard_apply_ops(idx, ops, mesh, routing=routing)
            assert int(stats["inserted"]) == extra.shape[0]
            assert int(stats["deleted"]) == dels.shape[0]
            value = np.asarray(core.unsort(res["value"], perm[:bk.shape[0]]))
            skk = np.asarray(core.unsort(res["succ_key"], perm[:bk.shape[0]]))
            o = extra.shape[0] + dels.shape[0]
            for i, q in enumerate(pts):
                assert value[o + i] == model.get(int(q), -1), (q, value[o + i])
            for i, q in enumerate(sq):
                j = np.searchsorted(live, q)
                want = live[j] if j < len(live) else EMPTY
                assert skk[o + n_pt + i] == want, (q, skk[o + n_pt + i], want)
                if j < len(live):
                    assert value[o + n_pt + i] == model[int(live[j])]
            print(f"{routing} ok")
        print("DIST_ENGINE_OK")
        """
    )
    assert "DIST_ENGINE_OK" in out


def test_shard_apply_ops_a2a_overflow_surfaced():
    """Skewed batch over a tight per-pair capacity reports overflow; the
    re-route with a larger capacity matches replicated byte-for-byte."""
    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro import core
        from repro.core import distributed as dist

        mesh = dist.make_shard_mesh(8)
        rng = np.random.default_rng(13)
        keys = np.sort(rng.permutation(100000)[:4000]).astype(np.int32)
        idx = dist.shard_build(jnp.asarray(keys), jnp.asarray(keys), mesh, node_size=16, nodes_per_bucket=8)

        hi = int(np.asarray(idx.part_fences)[0])  # everything -> shard 0
        q = rng.integers(0, hi, 2048).astype(np.int32)
        ops, perm = core.make_ops(np.full(2048, core.OP_POINT, np.int32), q)
        _, _, stats = dist.shard_apply_ops(idx, ops, mesh, routing="a2a", capacity=64)
        assert int(stats["a2a_overflow"]) == 2048 - 8 * 64, int(stats["a2a_overflow"])
        _, res, stats = dist.shard_apply_ops(idx, ops, mesh, routing="a2a", capacity=256)
        assert int(stats["a2a_overflow"]) == 0
        _, want, _ = dist.shard_apply_ops(idx, ops, mesh, routing="replicated")
        assert (np.asarray(res["value"]) == np.asarray(want["value"])).all()
        print("A2A_OVERFLOW_OK")
        """
    )
    assert "A2A_OVERFLOW_OK" in out


def test_sharded_kv_index_subprocess():
    """KVPageIndex(shards=4): engine-served pages_of across the mesh."""
    out = run_with_devices(
        """
        import numpy as np
        from repro.serve.kv_index import KVPageIndex

        kv = KVPageIndex(shards=4)
        seqs = np.arange(6)
        kv.allocate(seqs, np.zeros(6, int), seqs * 10)
        kv.allocate(seqs, np.ones(6, int), seqs * 10 + 1)
        assert (np.asarray(kv.lookup(seqs, np.ones(6, int))) == seqs * 10 + 1).all()
        pg, sl, cnt = kv.pages_of(2)
        assert int(cnt) == 2 and np.asarray(sl)[:2].tolist() == [20, 21]
        kv.free_sequences([2])
        assert kv.live_pages() == 10
        print("KV_SHARDED_OK")
        """
    )
    assert "KV_SHARDED_OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    """Same model, same data: 4x2-sharded loss == single-device loss."""
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.models.model import get_config
        from repro.train import make_train_step, train_state_init, TrainState
        from repro.optim import AdamWState
        from repro import sharding as sh

        cfg = get_config("h2o-danube-3-4b").reduced(dtype="float32")
        rng = jax.random.PRNGKey(0)
        state = train_state_init(rng, cfg)
        tokens = jax.random.randint(rng, (8, 64), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "targets": tokens}
        step = make_train_step(cfg, loss_chunk=16)

        _, m1 = jax.jit(step)(state, batch)
        loss_single = float(m1["loss"])

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((4, 2), ("data", "model"))
        pspecs = sh.param_specs(cfg, state.params, tp=2)
        sspecs = TrainState(params=pspecs, opt=AdamWState(step=P(), m=pspecs, v=pspecs))
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        with mesh:
            jstep = jax.jit(step, in_shardings=(ns(sspecs), ns(sh.input_specs_sharding(mesh, batch))))
            _, m2 = jstep(state, batch)
        loss_sharded = float(m2["loss"])
        assert abs(loss_single - loss_sharded) < 1e-3, (loss_single, loss_sharded)
        print("SHARDED_TRAIN_OK", loss_single, loss_sharded)
        """
    )
    assert "SHARDED_TRAIN_OK" in out


def test_tiny_dryrun_cell_compiles():
    """build_cell lowers + compiles on an 8-device mesh (dryrun smoke)."""
    out = run_with_devices(
        """
        import jax
        from repro.launch.steps import build_cell
        import repro.models.config as mc
        import dataclasses

        # shrink the shape table so the tiny mesh compiles fast
        mc.SHAPES["train_4k"] = dict(kind="train", seq_len=256, global_batch=8)
        mc.SHAPES["decode_32k"] = dict(kind="decode", seq_len=512, global_batch=8)
        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((4, 2), ("data", "model"))
        import repro.models.model as mm
        from repro.models.model import get_config
        real = get_config("musicgen-medium").reduced(dtype="bfloat16")
        import repro.configs as configs
        configs.REGISTRY["musicgen-medium"] = real
        with mesh:
            for shape in ("train_4k", "decode_32k"):
                cell = build_cell("musicgen-medium", shape, mesh, loss_chunk=64)
                compiled = cell.jitted.lower(*cell.abstract_args).compile()
                assert compiled.cost_analysis() is not None
        print("DRYRUN_SMOKE_OK")
        """
    )
    assert "DRYRUN_SMOKE_OK" in out


def test_gradient_compression_error_feedback():
    """int8 EF quantizer: accumulated quantized grads ≈ true sum over steps."""
    import jax.numpy as jnp
    import numpy as np

    from repro.optim import compress_init, decompress_add, quantize_grads

    rng = np.random.default_rng(5)
    params = {"w": jnp.zeros((64, 64))}
    state = compress_init(params)
    true_sum = np.zeros((64, 64), np.float32)
    acc = {"w": jnp.zeros((64, 64))}
    for i in range(16):
        g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
        true_sum += np.asarray(g["w"])
        q8, scales, state = quantize_grads(g, state)
        assert q8["w"].dtype == jnp.int8  # 4× fewer bytes on the wire
        acc = decompress_add(acc, q8, scales)
    rel = np.abs(np.asarray(acc["w"]) - true_sum).max() / np.abs(true_sum).max()
    assert rel < 0.02, rel


def test_moe_a2a_matches_dense_oracle():
    """shard_map all-to-all MoE dispatch (§Perf iteration 4): exact vs the
    dense oracle, including virtual-expert split and gradients."""
    out = run_with_devices(
        """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.model import get_config
        from repro.models.moe import moe_ffn_dense_oracle
        from repro.models.moe_a2a import moe_ffn_a2a

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((4, 2), ("data", "model"))
        cfg = get_config("deepseek-moe-16b").reduced(dtype="float32", moe_capacity_factor=8.0)
        cfg = dataclasses.replace(cfg, num_experts=4, top_k=2)
        D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
        k = jax.random.split(jax.random.PRNGKey(4), 8)
        p = {
            "router": jax.random.normal(k[0], (D, E)) * 0.1,
            "w_gate": jax.random.normal(k[1], (E, D, F)) * 0.05,
            "w_up": jax.random.normal(k[2], (E, D, F)) * 0.05,
            "w_down": jax.random.normal(k[3], (E, F, D)) * 0.05,
            "shared_gate": jax.random.normal(k[4], (D, F)) * 0.05,
            "shared_up": jax.random.normal(k[5], (D, F)) * 0.05,
            "shared_down": jax.random.normal(k[6], (F, D)) * 0.05,
        }
        cfg = dataclasses.replace(cfg, num_shared_experts=1)
        x = jax.random.normal(k[7], (64, D))
        with mesh:
            got = jax.jit(lambda x, p: moe_ffn_a2a(x, p, cfg, mesh))(x, p)
        want = moe_ffn_dense_oracle(x, p, cfg)
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 2e-4, err

        # split=2 virtual experts, same math
        cfg2 = dataclasses.replace(cfg, moe_split=2)
        def split_w(w, axis):
            a, b = jnp.split(w, 2, axis=axis)
            return jnp.stack([a, b], axis=1).reshape((E * 2,) + a.shape[1:])
        p2 = dict(p)
        p2["w_gate"] = split_w(p["w_gate"], 2)
        p2["w_up"] = split_w(p["w_up"], 2)
        p2["w_down"] = split_w(p["w_down"], 1)
        with mesh:
            got2 = jax.jit(lambda x, p: moe_ffn_a2a(x, p, cfg2, mesh))(x, p2)
        assert float(jnp.max(jnp.abs(got2 - want))) < 2e-4

        # differentiable end to end
        def loss(p, x):
            return jnp.sum(moe_ffn_a2a(x, p, cfg, mesh) ** 2)
        with mesh:
            g = jax.jit(jax.grad(loss))(p, x)
        gn = float(jnp.sqrt(sum(jnp.sum(v.astype(jnp.float32)**2) for v in jax.tree.leaves(g))))
        assert np.isfinite(gn) and gn > 0
        print("MOE_A2A_OK")
        """
    )
    assert "MOE_A2A_OK" in out
