"""Serialization determinism: identical logical state → identical bytes.

The durability contract (DESIGN.md §12) hangs off one invariant: the
canonical payload is a function of *logical content only*.  These tests
drive equal logical states down every physically-different path the
engine has and require byte-for-byte equal serializations:

* fused vs reference ``apply_ops`` executor;
* with vs without the successor cache (volatile fields);
* pre- vs post-restructure (grow AND shrink) at equal logical state;
* insertion-order / batch-split independence (same final content via
  different op histories);
* a state freshly rebuilt from its own canonical bytes (round trip).

Plus the format discipline: versioned header, strict parsing, corrupt or
trailing bytes rejected.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.checkpoint.serialize import (
    MAGIC,
    SnapshotFormatError,
    bucket_segments,
    canonical_state_bytes,
    pairs_to_bytes,
    parse_canonical,
    segment_crcs,
    state_from_pairs,
)
from repro.core.query import with_successor_cache
from repro.core.restructure import restructure_auto, restructure_grow
from repro.core.config import ExecConfig

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
    COMMON = dict(deadline=None, suppress_health_check=[HealthCheck.too_slow])
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

KEY_SPACE = 4096


def _state(rng, n=300, **geom):
    keys = np.sort(rng.choice(KEY_SPACE, n, replace=False)).astype(np.int32)
    vals = (keys * 3 + 1).astype(np.int32)
    return state_from_pairs(
        keys, vals, **{**dict(node_size=8, nodes_per_bucket=4), **geom}
    )


def _mixed_ops(rng, n=64):
    keys = rng.choice(KEY_SPACE, n, replace=False).astype(np.int32)
    tag = rng.choice(
        np.array([core.OP_INSERT, core.OP_DELETE, core.OP_POINT], np.int32),
        n,
        p=[0.45, 0.3, 0.25],
    )
    vals = (keys * 11 + 5).astype(np.int32)
    order = np.argsort(keys, kind="stable")
    ops, _ = core.make_ops(
        jnp.asarray(tag[order]), jnp.asarray(keys[order]), jnp.asarray(vals[order])
    )
    return ops


def test_fused_and_reference_serialize_identically(rng):
    st0 = _state(rng)
    ops = _mixed_ops(rng)
    ref, _, _ = core.apply_ops(st0, ops, config=ExecConfig(impl="reference"))
    fus, _, _ = core.apply_ops(st0, ops, config=ExecConfig(impl="fused"))
    assert canonical_state_bytes(ref) == canonical_state_bytes(fus)


def test_successor_cache_is_invisible(rng):
    st0 = _state(rng)
    cached = with_successor_cache(st0)
    assert cached.succ_smin is not None
    assert canonical_state_bytes(cached) == canonical_state_bytes(st0)
    # and after an update batch on the cached state (cache dropped/rebuilt)
    ops = _mixed_ops(rng)
    a, _, _ = core.apply_ops(st0, ops, config=ExecConfig(impl="reference"))
    b, _, _ = core.apply_ops(cached, ops, config=ExecConfig(impl="reference"))
    assert canonical_state_bytes(a) == canonical_state_bytes(b)


def test_restructure_is_a_logical_noop(rng):
    st0 = _state(rng)
    base = canonical_state_bytes(st0)
    grown = restructure_grow(st0, extra_keys=500)
    assert grown.keys.shape != st0.keys.shape  # physically different
    assert canonical_state_bytes(grown) == base
    shrunk = restructure_auto(grown)  # re-plan for live count: shrink back
    assert shrunk.keys.shape[0] < grown.keys.shape[0]
    assert canonical_state_bytes(shrunk) == base
    # ...and the same batch applied pre- vs post-restructure converges
    ops = _mixed_ops(rng)
    a, _, _ = core.apply_ops(st0, ops, config=ExecConfig(impl="reference"))
    b, _, _ = core.apply_ops(grown, ops, config=ExecConfig(impl="reference"))
    assert canonical_state_bytes(a) == canonical_state_bytes(b)


def test_batch_split_independence(rng):
    """One 64-op batch vs the same ops as two 32-op batches (split at the
    key median, preserving per-batch sortedness) — same bytes."""
    st0 = _state(rng)
    keys = rng.choice(KEY_SPACE, 64, replace=False).astype(np.int32)
    keys.sort()
    tag = rng.choice(np.array([core.OP_INSERT, core.OP_DELETE], np.int32), 64)
    vals = (keys * 5 + 2).astype(np.int32)

    def run(*chunks):
        s = st0
        for lo, hi in chunks:
            ops, _ = core.make_ops(
                jnp.asarray(tag[lo:hi]),
                jnp.asarray(keys[lo:hi]),
                jnp.asarray(vals[lo:hi]),
            )
            s, _, _ = core.apply_ops(s, ops, config=ExecConfig(impl="reference"))
        return canonical_state_bytes(s)

    assert run((0, 64)) == run((0, 32), (32, 64))


def test_roundtrip_through_canonical_bytes(rng):
    st0 = _state(rng)
    ops = _mixed_ops(rng)
    s1, _, _ = core.apply_ops(st0, ops, config=ExecConfig(impl="reference"))
    data = canonical_state_bytes(s1)
    keys, vals, exps = parse_canonical(data)
    rebuilt = state_from_pairs(keys, vals, exps)
    assert canonical_state_bytes(rebuilt) == data


def test_geometry_does_not_leak_into_bytes(rng):
    """The same pairs built under three different geometries serialize
    identically — the payload really is logical-content-only."""
    r = np.random.default_rng(5)
    keys = np.sort(r.choice(KEY_SPACE, 200, replace=False)).astype(np.int32)
    vals = keys + 9
    variants = [
        state_from_pairs(keys, vals, node_size=8, nodes_per_bucket=4),
        state_from_pairs(keys, vals, node_size=16, nodes_per_bucket=8),
        state_from_pairs(keys, vals, node_size=32, nodes_per_bucket=2),
    ]
    payloads = {canonical_state_bytes(v) for v in variants}
    assert len(payloads) == 1


# ---------------------------------------------------------------------------
# format discipline
# ---------------------------------------------------------------------------


def test_header_versioned_and_strict(rng):
    st0 = _state(rng, n=50)
    data = canonical_state_bytes(st0)
    assert data[:8] == MAGIC
    k, v, _e = parse_canonical(data)
    assert len(k) == 50 and (np.diff(k.astype(np.int64)) > 0).all()
    with pytest.raises(SnapshotFormatError):
        parse_canonical(data + b"\x00")  # trailing bytes
    with pytest.raises(SnapshotFormatError):
        parse_canonical(b"NOTMAGIC" + data[8:])
    bad_version = data[:8] + b"\x63\x00\x00\x00" + data[12:]
    with pytest.raises(SnapshotFormatError):
        parse_canonical(bad_version)
    with pytest.raises(SnapshotFormatError):
        parse_canonical(data[: len(data) - 4])  # truncated payload


def test_unsorted_payload_rejected():
    with pytest.raises(SnapshotFormatError):
        parse_canonical(
            pairs_to_bytes(np.array([5, 3], "<i4"), np.array([1, 2], "<i4"))
        )


def test_segment_concat_is_canonical_payload(rng):
    """Fence disjointness: per-bucket segments concatenated in order ARE
    the canonical payload, and per-bucket crcs match a direct recompute —
    the identity delta snapshots rely on."""
    st0 = _state(rng)
    lens, seg_k, seg_v, seg_e = bucket_segments(st0)
    assert pairs_to_bytes(seg_k, seg_v, seg_e) == canonical_state_bytes(st0)
    crcs = segment_crcs(lens, seg_k, seg_v, seg_e)
    assert len(crcs) == st0.keys.shape[0]
    # a partial fetch of a few buckets matches the full fetch's slices
    sel = [0, 2, len(lens) - 1]
    plens, pk, pv, _pe = bucket_segments(st0, sel)
    bounds = np.concatenate([[0], np.cumsum(lens)])
    off = 0
    for i, b in enumerate(sel):
        assert plens[i] == lens[b]
        np.testing.assert_array_equal(
            pk[off : off + plens[i]], seg_k[bounds[b] : bounds[b + 1]]
        )
        off += int(plens[i])


# ---------------------------------------------------------------------------
# generative sweep: arbitrary op histories, every path pair
# ---------------------------------------------------------------------------


def _apply_seq(st0, seqs, impl, cache_every=0):
    s = st0
    for i, (tag, keys, vals) in enumerate(seqs):
        if cache_every and i % cache_every == 0:
            s = with_successor_cache(s)
        ops, _ = core.make_ops(
            jnp.asarray(tag), jnp.asarray(keys), jnp.asarray(vals)
        )
        s, _, _ = core.apply_ops(s, ops, config=ExecConfig(impl=impl))
    return s


def _gen_history(seed, n_batches=3, n=48):
    r = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        keys = r.choice(KEY_SPACE, n, replace=False).astype(np.int32)
        keys.sort()
        tag = r.choice(
            np.array([core.OP_INSERT, core.OP_DELETE, core.OP_POINT], np.int32), n
        )
        out.append((tag, keys, (keys * 7 + 3).astype(np.int32)))
    return out


def _determinism_case(seed):
    r = np.random.default_rng(seed)
    st0 = _state(r)
    hist = _gen_history(seed)
    a = _apply_seq(st0, hist, "reference")
    b = _apply_seq(st0, hist, "fused")
    c = _apply_seq(restructure_grow(st0, extra_keys=300), hist, "reference")
    d = _apply_seq(st0, hist, "reference", cache_every=2)
    payloads = {canonical_state_bytes(s) for s in (a, b, c, d)}
    assert len(payloads) == 1, f"paths diverged for seed {seed}"


if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(max_examples=15, **COMMON)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_property_path_independent_bytes(seed):
        _determinism_case(seed)

else:  # pragma: no cover - minimal containers

    @pytest.mark.slow
    def test_property_path_independent_bytes_fallback():
        for seed in np.random.default_rng(11).integers(0, 2**31 - 1, 6):
            _determinism_case(int(seed))


def test_path_independent_bytes_smoke():
    """One deterministic instance of the property in the fast lane."""
    _determinism_case(12345)
