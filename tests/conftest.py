import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device counts here — smoke tests must see the
# single real device.  Multi-device tests spawn subprocesses (helpers below).

import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with fake host devices.

    The device-count flag only applies to the CPU platform, so the child
    is pinned to it (inheriting the parent's JAX_PLATFORMS when set) —
    otherwise a host with an installed accelerator plugin but no device
    spends minutes in backend probing before every one of these tests.
    """
    import os

    prelude = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"\n'
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={
            "PYTHONPATH": f"{REPO}/src",
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        },
        cwd=str(REPO),
    )
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
