"""Crash-injection proof of the durability contract (DESIGN.md §12).

THE property, for every kill point: after recovery, (1) no acknowledged
batch is lost, and (2) the recovered index is **byte-identical** (canonical
payload) to an uninterrupted run at the recovered seq.  Kill points cover
mid-log-append (half a record on disk), post-fsync/pre-apply,
mid-snapshot-payload, pre-rename, post-commit/pre-GC — and land before,
during, and after the workload's mid-run restructure (batch 9 regrows the
geometry, so recovery replays across an epoch bump).

Three escalating harnesses share ``tests/fault_injection.py``:

* a deterministic kill-point **matrix** (every instrumented event × two
  occurrence counts) using in-process ``CrashError`` — raw ``os.write``
  framing means the bytes on disk equal a process death at that point;
* **byte-offset** torn-tail properties straight against the WAL file;
* a bounded **subprocess SIGKILL** matrix — genuine uncatchable process
  death, acked batches read back from flushed ``ACK`` lines.

Negative controls prove the suite has teeth: with ``fsync=False`` the
property *demonstrably fails* (acked batches vanish), and with tail
truncation disabled recovery refuses a torn log outright.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import fault_injection as fi
from repro.core.config import ExecConfig
from repro.checkpoint import (
    DurableFliX,
    SnapshotCorruptionError,
    WALCorruptionError,
    load_snapshot_chain,
)
from repro.checkpoint.serialize import canonical_state_bytes
from repro.checkpoint.wal import REC_HEADER_SIZE, WriteAheadLog, replay
from repro.core.ops import OpBatch

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
    COMMON = dict(deadline=None, suppress_health_check=[HealthCheck.too_slow])
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

N_BATCHES = 10  # restructure fires at batch 9 (see fault_injection)
RESUME_BATCHES = 12  # resume tests run past N_BATCHES; oracle covers both

KILL_EVENTS = (
    "wal.append.partial",  # half a record on disk, no fsync → torn tail
    "wal.append.written",  # full record on disk, fsync not yet returned
    "wal.append.durable",  # fsynced but never applied → replay must run it
    "apply.done",  # applied, possibly pre-snapshot
    "snap.payload.partial",  # half-written snapshot payload in the tmp dir
    "snap.payload.written",
    "snap.manifest.written",
    "snap.before_rename",  # complete tmp dir, never committed
    "snap.committed",  # renamed, WAL not yet rotated / GC'd
    "snap.gc",
)


import functools


@functools.lru_cache(maxsize=1)
def _cached_oracle():
    return fi.oracle_canonical(RESUME_BATCHES)


@pytest.fixture(scope="module")
def oracle():
    """Canonical payload after each seq of the uninterrupted workload."""
    return _cached_oracle()


def _crash_run(tmp, event, count, *, n=N_BATCHES, fsync=True):
    """Run the workload until the hook fires (or completion); returns
    ``(crashed, acked)``."""
    acked = [0]
    try:
        fi.run_workload(
            tmp,
            n,
            fsync=fsync,
            crash_hook=fi.CrashAt(event, count),
            ack=lambda s: acked.__setitem__(0, s),
        )
        return False, acked[0]
    except fi.CrashError:
        return True, acked[0]


def _check_recovery(tmp, oracle, acked):
    if not DurableFliX.exists(tmp):
        # killed before the very first snapshot committed: nothing was
        # ever acknowledged, so an empty directory is a correct outcome
        assert acked == 0
        return 0
    return fi.recover_and_check(tmp, oracle, acked=acked)


# ---------------------------------------------------------------------------
# the deterministic kill-point matrix (fast lane, blocking in CI)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("event", KILL_EVENTS)
@pytest.mark.parametrize("count", [1, 3])
def test_kill_matrix_recovers_byte_identical(tmp_path, oracle, event, count):
    d = tmp_path / "wal"
    crashed, acked = _crash_run(d, event, count)
    seq = _check_recovery(d, oracle, acked)
    if not crashed:  # hook never fired that often — full run must match
        assert seq == N_BATCHES


def test_kill_during_restructure_window(tmp_path, oracle):
    """Kill right after the batch that regrows the geometry: recovery
    replays across the restructure (an epoch bump) and must still land on
    the oracle bytes — restructures are logical no-ops."""
    d = tmp_path / "wal"
    crashed, acked = _crash_run(d, "apply.done", 9)
    assert crashed and acked >= 8
    seq = _check_recovery(d, oracle, acked)
    assert seq >= 9


def test_double_crash_and_resume_to_completion(tmp_path, oracle):
    """Crash → resume → crash again (mid-snapshot) → resume → finish: the
    final state matches the uninterrupted oracle exactly."""
    d = tmp_path / "wal"
    crashed, acked = _crash_run(d, "wal.append.partial", 4)
    assert crashed
    fi.recover_and_check(d, oracle, acked=acked)
    crashed2, acked2 = _crash_run(d, "snap.payload.partial", 1)
    fi.recover_and_check(d, oracle, acked=acked2)
    # third run completes the workload
    final = fi.run_workload(d, N_BATCHES)
    assert final == N_BATCHES
    assert fi.recover_and_check(d, oracle, acked=N_BATCHES) == N_BATCHES


def test_crash_during_recovery_snapshot(tmp_path, oracle):
    """open() snapshots when the replayed tail is long; a crash *inside
    recovery* must leave the directory recoverable (recovery's only write
    is idempotent tail truncation + an atomic snapshot)."""
    d = tmp_path / "wal"
    # die right after batch 5's apply: snapshot at 3, WAL holds 4..5;
    # lower snapshot_every below the replay length so open() snapshots
    crashed, acked = _crash_run(d, "apply.done", 5)
    assert crashed and acked == 4  # batch 5 applied but ack never ran
    with pytest.raises(fi.CrashError):
        DurableFliX.open(
            d,
            engine=fi.make_engine(),
            snapshot_every=2,
            full_every=fi.FULL_EVERY,
            crash_hook=fi.CrashAt("snap.payload.partial", 1),
        )
    seq = fi.recover_and_check(d, oracle, acked=acked)
    assert seq == 5


def test_forced_snapshot_at_committed_seq_is_noop(tmp_path, oracle):
    """A close-time snapshot right after an auto-snapshot (or right after
    create) lands on a seq that already has a committed snapshot dir —
    that must be an idempotent no-op, not a rename onto a non-empty dir."""
    d = tmp_path / "wal"
    dur = fi.run_workload(d, 0, ret="instance")
    p0 = dur.snapshot()  # seq 0: create() already snapshotted
    assert p0.name.endswith("0" * 12) and dur.seq == 0
    dur.close()
    final = fi.run_workload(d, fi.SNAPSHOT_EVERY, ret="instance")
    before = sorted(x.name for x in d.iterdir())
    p = final.snapshot()  # auto-snapshot just fired at this seq
    assert p.is_dir()
    assert sorted(x.name for x in d.iterdir()) == before
    final.close()
    fi.recover_and_check(d, oracle, acked=fi.SNAPSHOT_EVERY)


def test_replayed_restructure_refreshes_fences_for_deltas(tmp_path, oracle):
    """Recovery that REPLAYS the restructure batch must refresh the host
    fence cache, because the SAME instance keeps running and takes a
    dirty-bucket delta snapshot: a stale cache routes updates to
    pre-restructure bucket ids, so the delta misses truly-dirty buckets
    yet passes every checksum — recovery from it is silently wrong."""
    d = tmp_path / "wal"
    acked = [0]
    with pytest.raises(fi.CrashError):
        fi.run_workload(
            d,
            9,
            snapshot_every=100,  # the only snapshot on disk stays seq 0
            crash_hook=fi.CrashAt("apply.done", 9),
            ack=lambda s: acked.__setitem__(0, s),
        )
    assert acked[0] == 8
    # open() replays 1..9 including the batch-9 restructure and snapshots
    # (full) at 9; the same instance then applies 10..12, auto-snapshotting
    # a dirty-bucket delta at 12 — recovery from that delta is the proof
    final = fi.run_workload(d, RESUME_BATCHES)
    assert final == RESUME_BATCHES
    assert fi.recover_and_check(d, oracle, acked=RESUME_BATCHES) == RESUME_BATCHES


def test_engine_failure_rolls_back_the_wal_record(tmp_path, oracle):
    """apply() logs the batch BEFORE the engine runs it; if the engine
    then fails, the logged-but-never-executed record must be rolled back —
    otherwise recovery replays a batch the live instance never applied and
    the next append reuses its seq."""
    d = tmp_path / "wal"
    dur = fi.run_workload(d, 4, ret="instance")
    try:
        tag, key, val, mr = fi.make_batch_host(5)
        real_apply = dur.engine.apply

        def boom(*a, **k):
            raise RuntimeError("engine OOM")

        dur.engine.apply = boom
        with pytest.raises(RuntimeError, match="engine OOM"):
            dur.apply(OpBatch.from_host(tag, key, val), config=ExecConfig(max_results=mr))
        dur.engine.apply = real_apply
        assert dur.seq == 4  # rolled back: the instance stays usable
        dur.apply(OpBatch.from_host(tag, key, val), config=ExecConfig(max_results=mr))
        assert dur.seq == 5
    finally:
        dur.close()
    # a surviving phantom record would make replay see seq 5 twice
    assert fi.recover_and_check(d, oracle, acked=5) == 5


def test_engine_failure_with_failed_rollback_poisons(tmp_path, oracle):
    """If the rollback itself fails, live and durable state have diverged:
    the instance must refuse further apply/snapshot, and reopening from
    disk resynchronizes by replaying the logged batch."""
    d = tmp_path / "wal"
    dur = fi.run_workload(d, 2, ret="instance")
    try:

        def boom(*a, **k):
            raise RuntimeError("engine OOM")

        def no_rollback(offset):
            raise OSError("disk gone")

        dur.engine.apply = boom
        dur._wal.truncate_to = no_rollback
        tag, key, val, mr = fi.make_batch_host(3)
        with pytest.raises(RuntimeError, match="engine OOM"):
            dur.apply(OpBatch.from_host(tag, key, val), config=ExecConfig(max_results=mr))
        with pytest.raises(RuntimeError, match="diverged"):
            dur.apply(OpBatch.from_host(tag, key, val), config=ExecConfig(max_results=mr))
        with pytest.raises(RuntimeError, match="diverged"):
            dur.snapshot()
    finally:
        dur.close()
    # the durable history is still self-consistent: batch 3 was logged, so
    # recovery replays it and lands on the oracle at seq 3
    assert fi.recover_and_check(d, oracle, acked=2) == 3


def test_recovery_snapshot_replaces_corrupt_dir_at_its_seq(tmp_path, oracle):
    """open() falls back past a corrupt newest snapshot and replays the
    WAL to exactly that seq; its recovery-time snapshot must REWRITE the
    corrupt dir instead of early-returning it as already committed —
    otherwise every later recovery pays the whole replay again."""
    d = tmp_path / "wal"
    fi.run_workload(d, 6)  # auto-snapshots at 3 and 6
    snap = d / "snap_000000000006"
    blob = bytearray((snap / "payload.bin").read_bytes())
    blob[0] ^= 0xFF
    (snap / "payload.bin").write_bytes(bytes(blob))
    with pytest.raises(SnapshotCorruptionError):
        load_snapshot_chain(d, 6)
    # recovery falls back to seq 3, replays 4..6 (>= snapshot_every) and
    # snapshots at 6 — over the corrupt dir
    assert fi.recover_and_check(d, oracle, acked=6) == 6
    _keys, _vals, _exps, m = load_snapshot_chain(d, 6)  # validates cleanly now
    assert m["seq"] == 6


# ---------------------------------------------------------------------------
# generative sweep (hypothesis when available, seeded fallback otherwise)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @settings(max_examples=30, **COMMON)
    @given(
        event=st.sampled_from(KILL_EVENTS),
        count=st.integers(min_value=1, max_value=8),
    )
    def test_property_any_kill_point_recovers(tmp_path_factory, event, count):
        oracle = _cached_oracle()
        d = tmp_path_factory.mktemp("sweep") / "wal"
        _, acked = _crash_run(d, event, count)
        _check_recovery(d, oracle, acked)

else:  # pragma: no cover - minimal containers

    @pytest.mark.slow
    def test_property_any_kill_point_recovers_fallback(tmp_path, oracle):
        rng = np.random.default_rng(7)
        for i in range(12):
            event = KILL_EVENTS[int(rng.integers(len(KILL_EVENTS)))]
            count = int(rng.integers(1, 9))
            d = tmp_path / f"wal{i}"
            _, acked = _crash_run(d, event, count)
            _check_recovery(d, oracle, acked)


# ---------------------------------------------------------------------------
# byte-offset torn-tail properties (file-level, no engine in the loop)
# ---------------------------------------------------------------------------


def _fill_wal(d, n=6):
    """A single-segment WAL of ``n`` records; returns frame end offsets."""
    wal = WriteAheadLog(d)
    wal.open_segment(1)
    ends, off = [], 0
    for s in range(1, n + 1):
        payload = bytes([s]) * (20 + 7 * s)
        wal.append(s, payload)
        off += REC_HEADER_SIZE + len(payload)
        ends.append(off)
    wal.close()
    return ends


def _seg_path(d):
    return d / "wal_000000000001.log"


@pytest.mark.parametrize("cut", [1, 7, 15, 16, 17, 40, 99, 150, -1, -17])
def test_truncation_at_any_byte_keeps_valid_prefix(tmp_path, cut):
    """Chopping the segment at ANY byte offset (a torn tail) must recover
    exactly the records whose frames lie fully below the cut."""
    ends = _fill_wal(tmp_path)
    data = _seg_path(tmp_path).read_bytes()
    cut = cut % len(data)
    _seg_path(tmp_path).write_bytes(data[:cut])
    recs = replay(tmp_path)
    want = sum(1 for e in ends if e <= cut)
    assert [s for s, _ in recs] == list(range(1, want + 1))
    # idempotent: the tear was truncated away, a second scan is clean
    assert len(replay(tmp_path)) == want


def test_short_os_writes_still_frame_whole_records(tmp_path, monkeypatch):
    """``os.write`` may land fewer bytes than asked; the append path must
    loop until the frame is complete — a short write that got fsynced and
    acked would later read as non-tail corruption."""
    from repro.checkpoint import wal as wal_mod

    real_write = os.write
    with monkeypatch.context() as mp:
        mp.setattr(wal_mod.os, "write", lambda fd, b: real_write(fd, bytes(b)[:7]))
        ends = _fill_wal(tmp_path, n=4)
    assert _seg_path(tmp_path).stat().st_size == ends[-1]
    assert [s for s, _ in replay(tmp_path)] == [1, 2, 3, 4]


def test_corruption_mid_log_raises(tmp_path):
    """A damaged record with valid records AFTER it is storage corruption,
    not a crash artifact — replay must refuse, never silently skip."""
    _fill_wal(tmp_path)
    p = _seg_path(tmp_path)
    data = bytearray(p.read_bytes())
    data[REC_HEADER_SIZE + 3] ^= 0xFF  # inside record 1's payload
    p.write_bytes(bytes(data))
    with pytest.raises(WALCorruptionError):
        replay(tmp_path)


def test_corruption_at_tail_is_a_tear(tmp_path):
    """The same bit flip in the FINAL record reaches EOF → torn tail →
    truncated, keeping every earlier record."""
    ends = _fill_wal(tmp_path)
    p = _seg_path(tmp_path)
    data = bytearray(p.read_bytes())
    data[ends[-2] + REC_HEADER_SIZE + 1] ^= 0xFF
    p.write_bytes(bytes(data))
    assert [s for s, _ in replay(tmp_path)] == [1, 2, 3, 4, 5]


def test_corruption_in_old_segment_never_truncates(tmp_path):
    """Tail damage in a NON-newest segment is not a tear (no crash writes
    there) — replay refuses instead of dropping records."""
    wal = WriteAheadLog(tmp_path)
    wal.open_segment(1)
    wal.append(1, b"a" * 30)
    wal.rotate(2)
    wal.append(2, b"b" * 30)
    wal.close()
    p = _seg_path(tmp_path)
    data = p.read_bytes()
    p.write_bytes(data[:-5])  # tear in the OLD segment
    with pytest.raises(WALCorruptionError):
        replay(tmp_path)


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, **COMMON)
    @given(cut=st.integers(min_value=0, max_value=10_000))
    def test_property_truncation_any_offset(tmp_path_factory, cut):
        d = tmp_path_factory.mktemp("torn")
        ends = _fill_wal(d)
        p = _seg_path(d)
        data = p.read_bytes()
        cut = cut % (len(data) + 1)
        p.write_bytes(data[:cut])
        want = sum(1 for e in ends if e <= cut)
        assert [s for s, _ in replay(d)] == list(range(1, want + 1))


# ---------------------------------------------------------------------------
# negative controls: the suite must CATCH a broken durability boundary
# ---------------------------------------------------------------------------


def test_negative_no_fsync_loses_acked_batches(tmp_path, oracle):
    """With the WAL's fsync disabled, a crash after several acknowledged
    batches loses them — recovery lands BELOW the acked seq, i.e. the
    byte-identity property would fail.  This is the proof the positive
    tests are actually sensitive to the fsync."""
    d = tmp_path / "wal"
    crashed, acked = _crash_run(d, "apply.done", 5, fsync=False)
    assert crashed and acked >= 4
    dur = DurableFliX.open(
        d,
        engine=fi.make_engine(),
        snapshot_every=fi.SNAPSHOT_EVERY,
        full_every=fi.FULL_EVERY,
    )
    try:
        # batches 4..5 were acked but only buffered: gone
        assert dur.seq < acked, "un-fsynced WAL unexpectedly durable"
        assert canonical_state_bytes(dur.state) != oracle[acked]
        assert canonical_state_bytes(dur.state) == oracle[dur.seq]
    finally:
        dur.close()


def test_negative_truncation_disabled_refuses_torn_tail(tmp_path, oracle):
    """With tail truncation off, recovery must raise on a mid-append crash
    instead of silently dropping the torn record."""
    d = tmp_path / "wal"
    crashed, acked = _crash_run(d, "wal.append.partial", 5)
    assert crashed
    with pytest.raises(WALCorruptionError):
        DurableFliX.open(d, engine=fi.make_engine(), truncate_torn=False)
    # ...and the default policy recovers the same directory fine
    fi.recover_and_check(d, oracle, acked=acked)


# ---------------------------------------------------------------------------
# subprocess SIGKILL matrix: genuine process death
# ---------------------------------------------------------------------------

REPO = Path(__file__).resolve().parents[1]

SIGKILL_POINTS = [
    ("wal.append.partial", 4),
    ("wal.append.durable", 6),
    ("snap.payload.partial", 2),
    ("snap.before_rename", 2),
]

# children stop short of the restructure batch: the in-process matrix
# covers that window, and skipping it keeps each cold-jit subprocess cheap
CHILD_BATCHES = 6


def _spawn_child(d, *extra):
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tests" / "fault_injection.py"),
            "--dir",
            str(d),
            "--batches",
            str(CHILD_BATCHES),
            *extra,
        ],
        capture_output=True,
        text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": f"{REPO}/src"},
        cwd=str(REPO),
    )
    acks = [
        int(line.split()[1])
        for line in proc.stdout.splitlines()
        if line.startswith("ACK ")
    ]
    return proc, max(acks, default=0)


@pytest.mark.parametrize("event,count", SIGKILL_POINTS)
def test_sigkill_subprocess_recovers(tmp_path, oracle, event, count):
    d = tmp_path / "wal"
    proc, acked = _spawn_child(d, "--kill-event", event, "--kill-count", str(count))
    assert proc.returncode == -9, f"child not SIGKILLed:\n{proc.stderr}"
    seq = _check_recovery(d, oracle, acked)
    assert seq >= acked


def test_sigkill_no_fsync_negative(tmp_path):
    """SIGKILL + fsync disabled: the userspace-buffered records die with
    the process — acked batches are genuinely lost."""
    d = tmp_path / "wal"
    proc, acked = _spawn_child(
        d, "--kill-event", "apply.done", "--kill-count", "5", "--no-fsync"
    )
    assert proc.returncode == -9
    assert acked >= 4
    dur = DurableFliX.open(d, engine=fi.make_engine())
    try:
        assert dur.seq < acked
    finally:
        dur.close()


# ---------------------------------------------------------------------------
# sharded recovery: same WAL, ShardEngine rebuild + replay across the mesh
# ---------------------------------------------------------------------------


def test_sharded_recovery_matches_local_oracle(tmp_path, oracle):
    """Crash a SHARDED durable index and recover it: canonical bytes must
    match the single-device oracle (the durability layer is engine-blind —
    logical content is all that persists).  The kill lands right after the
    clustered heavy batch overflows a shard and ``shard_restructure``
    rebalances fences, so recovery replays across that rebalance.  Runs in
    a subprocess with fake host devices, kept minimal (2 shards, 4
    batches) because every shard_map geometry is a cold compile there."""
    from conftest import run_with_devices

    d = tmp_path / "wal"
    out = run_with_devices(
        f"""
        import sys
        sys.path.insert(0, r"{REPO}/tests")
        import fault_injection as fi
        from repro.checkpoint import DurableFliX, ShardEngine
        from repro.checkpoint.serialize import canonical_state_bytes
        from repro.core.distributed import make_shard_mesh

        mesh = make_shard_mesh(2)
        eng = ShardEngine(mesh, **fi.GEOMETRY)
        acked = [0]
        try:
            fi.run_workload(r"{d}", 4, engine=eng,
                            crash_hook=fi.CrashAt("apply.done", 4),
                            ack=lambda s: acked.__setitem__(0, s))
        except fi.CrashError:
            pass
        dur = DurableFliX.open(r"{d}", engine=ShardEngine(mesh, **fi.GEOMETRY))
        print("SEQ", dur.seq, "ACKED", acked[0], flush=True)
        print("DIGEST", canonical_state_bytes(dur.state).hex(), flush=True)
        dur.close()
        """,
        n_devices=2,
    )
    seq = acked = digest = None
    for line in out.splitlines():
        if line.startswith("SEQ "):
            _, seq, _, acked = line.split()
        elif line.startswith("DIGEST "):
            digest = line.split()[1]
    assert seq is not None and digest is not None, f"child output:\n{out}"
    seq, acked = int(seq), int(acked)
    assert seq >= acked
    assert bytes.fromhex(digest) == oracle[seq]
