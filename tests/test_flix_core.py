"""FliX core vs a Python dict oracle + structural invariants I1–I5."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.invariants import check_invariants
from repro.core.state import EMPTY, NOT_FOUND
from repro.core.config import ExecConfig


@pytest.fixture
def built(rng):
    keys = rng.choice(100000, size=3000, replace=False).astype(np.int32)
    vals = np.arange(3000, dtype=np.int32)
    st = core.build(keys, vals, node_size=8, nodes_per_bucket=8)
    return st, dict(zip(keys.tolist(), vals.tolist()))


def test_build_invariants(built):
    st, model = built
    check_invariants(st)
    assert int(st.live_keys()) == len(model)


def test_point_query_hits_and_misses(built, rng):
    st, model = built
    live = np.array(sorted(model), dtype=np.int32)
    res = np.asarray(core.point_query(st, jnp.asarray(live)))
    assert all(res[i] == model[int(live[i])] for i in range(len(live)))
    misses = np.setdiff1d(
        rng.integers(0, 100000, 500).astype(np.int32), live
    )
    res = np.asarray(core.point_query(st, jnp.asarray(np.sort(misses))))
    assert (res == int(NOT_FOUND)).all()


def test_insert_rounds_with_splits(built, rng):
    st, model = built
    pool = np.setdiff1d(np.arange(100000, dtype=np.int32), list(model))
    for rnd in range(3):
        ins = rng.choice(pool, size=1500, replace=False).astype(np.int32)
        pool = np.setdiff1d(pool, ins)
        iv = rng.integers(0, 1 << 30, size=1500).astype(np.int32)
        sk, sv = core.sort_batch(jnp.asarray(ins), jnp.asarray(iv))
        st, _ = core.insert_safe(st, sk, sv)
        for k, v in zip(ins.tolist(), iv.tolist()):
            model[k] = v
        check_invariants(st)
        assert int(st.live_keys()) == len(model)
    live = np.array(sorted(model), dtype=np.int32)
    res = np.asarray(core.point_query(st, jnp.asarray(live)))
    assert all(res[i] == model[int(live[i])] for i in range(len(live)))


def test_upsert_overwrites(built):
    st, model = built
    some = np.array(sorted(model)[:100], dtype=np.int32)
    nv = jnp.full((100,), 424242, jnp.int32)
    st, _ = core.insert(st, jnp.asarray(some), nv)
    res = np.asarray(core.point_query(st, jnp.asarray(some)))
    assert (res == 424242).all()
    assert int(st.live_keys()) == len(model)  # no duplicates created


def test_delete_physical_and_compaction(built, rng):
    st, model = built
    live = np.array(sorted(model), dtype=np.int32)
    dels = live[::3]
    nodes_before = int(st.total_nodes())
    st, stats = core.delete(st, jnp.asarray(dels))
    assert int(stats["deleted"]) == len(dels)
    check_invariants(st)
    res = np.asarray(core.point_query(st, jnp.asarray(dels)))
    assert (res == int(NOT_FOUND)).all()
    keep = np.setdiff1d(live, dels)
    res = np.asarray(core.point_query(st, jnp.asarray(keep)))
    assert all(res[i] == model[int(keep[i])] for i in range(len(keep)))


def test_delete_everything(built):
    st, model = built
    live = np.array(sorted(model), dtype=np.int32)
    st, _ = core.delete(st, jnp.asarray(live))
    assert int(st.live_keys()) == 0
    check_invariants(st)
    res = np.asarray(core.point_query(st, jnp.asarray(live[:50])))
    assert (res == int(NOT_FOUND)).all()


def test_successor(built, rng):
    st, model = built
    live = np.array(sorted(model), dtype=np.int32)
    q = np.sort(rng.integers(0, 100001, size=400).astype(np.int32))
    sk, sv = core.successor_query(st, jnp.asarray(q))
    sk, sv = np.asarray(sk), np.asarray(sv)
    for i, qq in enumerate(q):
        j = np.searchsorted(live, qq)
        if j < len(live):
            assert sk[i] == live[j] and sv[i] == model[int(live[j])]
        else:
            assert sk[i] == int(EMPTY) and sv[i] == int(NOT_FOUND)


def test_successor_cache_identical_and_invalidated(built, rng):
    st, model = built
    q = jnp.asarray(np.sort(rng.integers(0, 100001, size=400).astype(np.int32)))
    k0, v0 = core.successor_query(st, q)

    stc = core.with_successor_cache(st)
    assert stc.succ_smin is not None
    assert core.with_successor_cache(stc) is stc  # idempotent
    k1, v1 = core.successor_query(stc, q)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))

    # every mutating op constructs its result without the cache fields —
    # invalidation by construction
    sk, sv = core.sort_batch(
        jnp.asarray(np.array([7, 13, 19], np.int32)),
        jnp.asarray(np.arange(3, dtype=np.int32)),
    )
    st_ins, _ = core.insert(stc, sk, sv)
    assert st_ins.succ_smin is None
    st_del, _ = core.delete(stc, jnp.asarray(np.array([7], np.int32)))
    assert st_del.succ_smin is None
    assert core.restructure_auto(stc).succ_smin is None

    # a cached state flows through both apply_ops executors unchanged
    tags = np.full(64, core.OP_SUCCESSOR, np.int32)
    bkeys = np.sort(rng.integers(0, 100001, 64).astype(np.int32))
    ops, perm = core.make_ops(tags, bkeys, np.zeros(64, np.int32))
    for impl in ("reference", "fused"):
        s2, res, _ = core.apply_ops(stc, ops, config=ExecConfig(impl=impl))
        assert s2.succ_smin is None
        got = np.asarray(core.unsort(res["succ_key"], perm))
        want, _ = core.successor_query(st, jnp.asarray(bkeys))
        np.testing.assert_array_equal(got, np.asarray(want), err_msg=impl)


def test_range_query(built):
    st, model = built
    live = sorted(model)
    lo, hi = live[100], live[160]
    k, v, n = core.range_query(
        st, jnp.array([lo], jnp.int32), jnp.array([hi], jnp.int32), max_results=128
    )
    want = [x for x in live if lo <= x <= hi]
    got = [int(x) for x in np.asarray(k[0])[: int(n[0])]]
    assert got == want


def test_restructure_flattens_and_preserves(built, rng):
    st, model = built
    pool = np.setdiff1d(np.arange(100000, dtype=np.int32), list(model))
    ins = rng.choice(pool, size=4000, replace=False).astype(np.int32)
    sk, sv = core.sort_batch(jnp.asarray(ins), jnp.asarray(np.arange(4000, dtype=np.int32)))
    st, _ = core.insert_safe(st, sk, sv)
    for i, k in enumerate(ins.tolist()):
        model[k] = i
    live = np.array(sorted(model), dtype=np.int32)
    dels = live[::2]
    st, _ = core.delete(st, jnp.asarray(dels))
    for k in dels.tolist():
        del model[k]

    st2 = core.restructure_auto(st)
    check_invariants(st2)
    assert int(st2.live_keys()) == len(model)
    # restructuring flattens chains to single (half-full) nodes
    assert int(jnp.max(st2.num_nodes)) == 1
    live = np.array(sorted(model), dtype=np.int32)
    res = np.asarray(core.point_query(st2, jnp.asarray(live)))
    assert all(res[i] == model[int(live[i])] for i in range(len(live)))


def test_merge_underfull(built, rng):
    st, model = built
    live = np.array(sorted(model), dtype=np.int32)
    st, _ = core.delete(st, jnp.asarray(live[::2]))
    for k in live[::2].tolist():
        del model[k]
    before = int(st.total_nodes())
    st2 = core.merge_underfull(st)
    check_invariants(st2)
    assert int(st2.total_nodes()) <= before
    assert int(st2.live_keys()) == len(model)


def test_overflow_triggers_safe_restructure(rng):
    keys = np.arange(0, 640, 10, dtype=np.int32)  # 64 keys
    st = core.build(keys, keys, node_size=4, nodes_per_bucket=2)
    # flood one bucket's range → overflow → insert_safe must regrow
    flood = np.arange(1, 200, 2, dtype=np.int32)
    sk, sv = core.sort_batch(jnp.asarray(flood), jnp.asarray(flood))
    st1, _ = core.insert(st, sk, sv)
    assert bool(st1.needs_restructure)
    st2, _ = core.insert_safe(st, sk, sv)
    assert not bool(st2.needs_restructure)
    res = np.asarray(core.point_query(st2, jnp.asarray(np.sort(flood))))
    assert (res == np.sort(flood)).all()


def test_skewed_delete_batch_with_many_absent_keys(rng):
    """Regression: a delete batch aiming thousands of absent keys at one
    bucket's range must still remove the present ones exactly."""
    keys = np.arange(0, 100000, 100, dtype=np.int32)  # 1000 sparse keys
    st = core.build(keys, keys, node_size=8, nodes_per_bucket=4)
    # delete range [0, 5000): 50 present keys buried in 5000 candidates
    dels = jnp.asarray(np.arange(0, 5000, dtype=np.int32))
    st, stats = core.delete(st, dels)
    assert int(stats["deleted"]) == 50
    res = np.asarray(core.point_query(st, jnp.asarray(keys[:50])))
    assert (res == int(NOT_FOUND)).all()
    res = np.asarray(core.point_query(st, jnp.asarray(keys[50:])))
    assert (res == keys[50:]).all()
    check_invariants(st)


def test_skewed_delete_kernel_matches(rng):
    from repro.kernels.flix_delete import flix_delete_pallas

    keys = np.arange(0, 100000, 100, dtype=np.int32)
    st = core.build(keys, keys, node_size=8, nodes_per_bucket=4)
    dels = jnp.asarray(np.arange(0, 5000, dtype=np.int32))
    want, _ = core.delete(st, dels)
    got = flix_delete_pallas(st, dels, interpret=True)
    np.testing.assert_array_equal(np.asarray(want.keys), np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(want.num_nodes), np.asarray(got.num_nodes))
