"""Hypothesis property tests: FliX == dict under arbitrary op sequences."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro import core
from repro.core.state import EMPTY, NOT_FOUND

KEY = st.integers(min_value=0, max_value=5000)


def _unique(xs):
    return np.array(sorted(set(xs)), dtype=np.int32)


@settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    build=st.lists(KEY, min_size=1, max_size=200),
    ops=st.lists(
        st.tuples(
            st.sampled_from(["insert", "delete", "upsert"]),
            st.lists(KEY, min_size=1, max_size=60),
        ),
        max_size=6,
    ),
    probes=st.lists(KEY, min_size=1, max_size=60),
)
def test_flix_matches_dict(build, ops, probes):
    bkeys = _unique(build)
    bvals = np.arange(len(bkeys), dtype=np.int32)
    state = core.build(bkeys, bvals, node_size=4, nodes_per_bucket=4)
    model = dict(zip(bkeys.tolist(), bvals.tolist()))

    tag = 1000
    for op, keys in ops:
        ks = _unique(keys)
        if op == "delete":
            state, _ = core.delete(state, jnp.asarray(ks))
            for k in ks.tolist():
                model.pop(k, None)
        else:
            if op == "upsert" and model:
                ks = _unique(list(model)[: len(ks)])
            vs = np.full(len(ks), tag, dtype=np.int32)
            tag += 1
            state, _ = core.insert_safe(state, jnp.asarray(ks), jnp.asarray(vs))
            for k in ks.tolist():
                model[k] = int(vs[0])

    assert int(state.live_keys()) == len(model)

    q = _unique(probes)
    res = np.asarray(core.point_query(state, jnp.asarray(q)))
    for i, k in enumerate(q.tolist()):
        assert res[i] == model.get(k, int(NOT_FOUND)), (k, res[i], model.get(k))

    sk, sv = core.successor_query(state, jnp.asarray(q))
    sk, sv = np.asarray(sk), np.asarray(sv)
    live = np.array(sorted(model), dtype=np.int32)
    for i, k in enumerate(q.tolist()):
        j = np.searchsorted(live, k)
        if j < len(live):
            assert sk[i] == live[j] and sv[i] == model[int(live[j])]
        else:
            assert sk[i] == int(EMPTY)


@settings(max_examples=15, deadline=None)
@given(
    keys=st.lists(KEY, min_size=1, max_size=300),
    ns=st.sampled_from([4, 8, 14]),
    npb=st.sampled_from([2, 4, 8]),
)
def test_restructure_identity(keys, ns, npb):
    """Restructure never changes the mapping, for any geometry."""
    ks = _unique(keys)
    vs = np.arange(len(ks), dtype=np.int32)
    state = core.build(ks, vs, node_size=ns, nodes_per_bucket=npb)
    st2 = core.restructure_auto(state)
    res = np.asarray(core.point_query(st2, jnp.asarray(ks)))
    assert (res == vs).all()
    assert int(st2.live_keys()) == len(ks)


@settings(max_examples=15, deadline=None)
@given(batch=st.lists(st.tuples(KEY, KEY), min_size=1, max_size=100))
def test_dedup_last_wins(batch):
    keys = np.array([k for k, _ in batch], dtype=np.int32)
    vals = np.array([v for _, v in batch], dtype=np.int32)
    sk, sv = core.sort_batch(jnp.asarray(keys), jnp.asarray(vals))
    dk, dv, count = core.dedup_last_wins(sk, sv)
    model = {}
    for k, v in batch:
        model[k] = v
    assert int(count) == len(model)
    dk, dv = np.asarray(dk), np.asarray(dv)
    for i in range(int(count)):
        assert model[int(dk[i])] == int(dv[i])
    assert (dk[int(count):] == int(EMPTY)).all()
