"""Restructure paths that the rest of the suite never exercises.

Two cold paths from ``core.restructure`` / ``core.ops``:

  1. ``restructure_grow``'s pathological-skew *widening* branch: when a
     single bucket may have to absorb the whole incoming batch
     (``p + extra_keys > cap``), the host widens ``nodes_per_bucket`` so one
     bucket can hold it — the §3.4 adaptive compute-to-bucket analogue.
  2. ``apply_ops_safe``'s restructure-and-replay round trip: a mixed batch
     that overflows mid-mix is replayed in full on the regrown pre-batch
     state, and every op class of the batch must come back correct.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core.invariants import check_invariants
from repro.core.restructure import restructure_grow
from repro.core.state import EMPTY, NOT_FOUND
from repro.core.config import ExecConfig


def _tiny_state():
    """cap = 8 (node_size 4 × npb 2), p = 2 — easy to overflow."""
    keys = np.arange(0, 1000, 50, dtype=np.int32)  # 20 keys, spread out
    return core.build(keys, keys, node_size=4, nodes_per_bucket=2), keys


def test_restructure_grow_widening_branch():
    st, keys = _tiny_state()
    cap = st.bucket_capacity
    p = st.node_size // 2
    extra = 100
    assert p + extra > cap, "precondition: this must hit the widening branch"

    grown = restructure_grow(st, extra_keys=extra)
    # geometry: nodes_per_bucket widened so one bucket can absorb the batch
    assert grown.nodes_per_bucket == math.ceil((p + extra) / st.node_size)
    assert grown.nodes_per_bucket > st.nodes_per_bucket
    assert grown.num_buckets == max(1, math.ceil((len(keys) + extra) / p))
    check_invariants(grown)
    # contents preserved
    got = np.asarray(core.point_query(grown, jnp.asarray(keys)))
    np.testing.assert_array_equal(got, keys)


def test_widening_branch_absorbs_single_bucket_flood():
    """All extra keys landing between two adjacent fences must fit after the
    widening restructure — the exact skew the branch exists for."""
    st, keys = _tiny_state()
    flood = np.arange(101, 148, dtype=np.int32)  # 47 keys inside one gap
    assert st.node_size // 2 + len(flood) > st.bucket_capacity

    sk, sv = core.sort_batch(jnp.asarray(flood), jnp.asarray(flood * 2))
    st1, _ = core.insert(st, sk, sv)
    assert bool(st1.needs_restructure)  # the direct insert must overflow

    st2, _ = core.insert_safe(st, sk, sv)
    assert not bool(st2.needs_restructure)
    assert st2.nodes_per_bucket > st.nodes_per_bucket
    check_invariants(st2)
    allk = np.sort(np.concatenate([keys, flood]))
    got = np.asarray(core.point_query(st2, jnp.asarray(allk)))
    want = np.where(np.isin(allk, flood), allk * 2, allk)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["reference", "fused"])
def test_apply_ops_safe_replay_full_mix(impl):
    """A mid-mix overflow triggers restructure-and-replay; afterwards every
    op class of the batch (insert, delete, point, successor) is correct."""
    st, keys = _tiny_state()
    flood = np.arange(1, 200, 2, dtype=np.int32)          # overflowing inserts
    dels = keys[::4].astype(np.int32)                     # present deletions
    points = keys[1::4].astype(np.int32)                  # survivors
    succs = (keys[2::4] + 1).astype(np.int32)             # between stored keys

    tags = np.concatenate([
        np.full(len(flood), core.OP_INSERT),
        np.full(len(dels), core.OP_DELETE),
        np.full(len(points), core.OP_POINT),
        np.full(len(succs), core.OP_SUCCESSOR),
    ]).astype(np.int32)
    bkeys = np.concatenate([flood, dels, points, succs]).astype(np.int32)
    bvals = np.concatenate(
        [flood * 10, np.zeros(len(dels) + len(points) + len(succs), np.int32)]
    )
    ops, perm = core.make_ops(tags, bkeys, bvals, pad_to=256)

    st2, res, stats = core.apply_ops_safe(st, ops, config=ExecConfig(impl=impl))
    assert not bool(st2.needs_restructure)
    check_invariants(st2)

    res_v = np.asarray(core.unsort(res["value"], perm))[: len(bkeys)]
    res_k = np.asarray(core.unsort(res["succ_key"], perm))[: len(bkeys)]

    # point results observe the post-update state (deletes already applied)
    np.testing.assert_array_equal(res_v[tags == core.OP_POINT], points)
    # successor results: model = (stored ∪ flood) − dels, next key ≥ q
    model = np.sort(
        np.setdiff1d(np.union1d(keys.astype(np.int64), flood), dels)
    )
    for q, sk_got, sv_got in zip(
        succs,
        res_k[tags == core.OP_SUCCESSOR],
        res_v[tags == core.OP_SUCCESSOR],
    ):
        j = np.searchsorted(model, q)
        want_k = int(model[j])
        assert sk_got == want_k
        assert sv_got == (want_k * 10 if want_k in flood else want_k)
    # post-state: floods stored, deletions gone
    got = np.asarray(core.point_query(st2, jnp.asarray(np.sort(flood))))
    np.testing.assert_array_equal(got, np.sort(flood) * 10)
    gone = np.asarray(core.point_query(st2, jnp.asarray(np.sort(dels))))
    assert (gone == int(NOT_FOUND)).all()
    assert int(stats["inserted"]) == len(flood)
    assert int(stats["deleted"]) == len(dels)
    # the retry is VISIBLE: the replay must not reset the surfaced counter
    # (the gateway metrics and bench rows report it — DESIGN.md §13)
    assert int(stats["restructure_retries"]) == 1


def test_apply_ops_safe_counter_zero_without_overflow():
    """The surfaced retry counter exists (as 0) on the no-retry path too,
    so downstream accumulation never KeyErrors."""
    st, keys = _tiny_state()
    ops, _ = core.make_ops(
        np.full(4, core.OP_POINT, np.int32), keys[:4].astype(np.int32)
    )
    _, _, stats = core.apply_ops_safe(st, ops)
    assert int(stats["restructure_retries"]) == 0


def test_apply_ops_safe_replay_reference_fused_identical():
    """The replayed (post-restructure) states of both executors match."""
    st, keys = _tiny_state()
    flood = np.arange(3, 150, 2, dtype=np.int32)
    tags = np.concatenate([
        np.full(len(flood), core.OP_INSERT),
        np.full(len(keys), core.OP_SUCCESSOR),
    ]).astype(np.int32)
    bkeys = np.concatenate([flood, keys]).astype(np.int32)
    bvals = np.concatenate([flood, np.zeros(len(keys), np.int32)])
    ops, _ = core.make_ops(tags, bkeys, bvals, pad_to=256)

    s_ref, r_ref, _ = core.apply_ops_safe(st, ops, config=ExecConfig(impl="reference"))
    s_f, r_f, _ = core.apply_ops_safe(st, ops, config=ExecConfig(impl="fused"))
    for f in ("keys", "node_count", "node_max", "num_nodes", "mkba"):
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, f)), np.asarray(getattr(s_f, f)), err_msg=f
        )
    mask = np.asarray(s_ref.keys) != int(EMPTY)
    np.testing.assert_array_equal(
        np.asarray(s_ref.vals)[mask], np.asarray(s_f.vals)[mask]
    )
    np.testing.assert_array_equal(
        np.asarray(r_ref["value"]), np.asarray(r_f["value"])
    )
    np.testing.assert_array_equal(
        np.asarray(r_ref["succ_key"]), np.asarray(r_f["succ_key"])
    )
