"""Guard tests for the perf-regression gate (``benchmarks.compare``).

The CI contract: ``bench-smoke`` must demonstrably *fail* on an injected
regression while a clean run stays green.  These tests pin the gate's
decision logic host-side so a silent comparator bug cannot neuter the CI
step that re-checks the same thing end-to-end.
"""

import json

import pytest

from benchmarks import compare


def _artifact(speedups, *, failed=(), field="sharded_speedup"):
    return {
        "schema": "flix-bench-v1",
        "scale": "small",
        "build_size": 1 << 14,
        "suites": {},
        "failed": list(failed),
        "apply_ops_fused_speedup": {},
        "range_fused_speedup": {},
        field: dict(speedups),
    }


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_clean_run_is_green(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _artifact({"rep_s4_upd50": 0.50}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"rep_s4_upd50": 0.48}))
    assert compare.main([fresh, base]) == 0
    assert "REGRESSED" not in capsys.readouterr().out


def test_injected_regression_fails(tmp_path, capsys):
    """A fresh ratio 10x below the snapshot must trip the gate."""
    base = _write(tmp_path, "base.json", _artifact({"rep_s4_upd50": 0.50}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"rep_s4_upd50": 0.05}))
    assert compare.main([fresh, base]) == 1
    out = capsys.readouterr()
    assert "REGRESSED" in out.out
    assert "sharded_speedup/rep_s4_upd50" in out.err


def test_tolerance_boundary_and_env(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json", _artifact({"k": 1.00}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"k": 0.75}))
    # 25% drop: beyond the default 20% tolerance, inside a 30% one
    assert compare.main([fresh, base]) == 1
    assert compare.main([fresh, base, "--tolerance", "0.30"]) == 0
    monkeypatch.setenv("REPRO_BENCH_TOL", "0.30")
    assert compare.main([fresh, base]) == 0


def test_tiny_baselines_are_reported_not_gated(tmp_path, capsys):
    """Interpret-mode ratios below the floor never fail the gate."""
    base = _write(tmp_path, "base.json", _artifact({"upd100": 0.035},
                                                   field="apply_ops_fused_speedup"))
    fresh = _write(tmp_path, "fresh.json", _artifact({"upd100": 0.001},
                                                     field="apply_ops_fused_speedup"))
    assert compare.main([fresh, base]) == 0
    assert "ungated" in capsys.readouterr().out


def test_missing_and_new_keys_do_not_fail(tmp_path):
    base = _write(tmp_path, "base.json", _artifact({"only_old": 0.9}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"only_new": 0.9}))
    assert compare.main([fresh, base]) == 0


def test_later_baselines_override_earlier(tmp_path):
    """Snapshots are passed oldest-first; the newest value gates."""
    old = _write(tmp_path, "old.json", _artifact({"k": 2.0}))
    new = _write(tmp_path, "new.json", _artifact({"k": 0.5}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"k": 0.5}))
    assert compare.main([fresh, old, new]) == 0    # newest baseline wins
    assert compare.main([fresh, new, old]) == 1    # stale ordering regresses


def test_truncated_fresh_artifact_fails(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _artifact({"k": 0.5}))
    fresh = _write(
        tmp_path, "fresh.json", _artifact({"k": 0.5}, failed=["range_mix_engine"])
    )
    assert compare.main([fresh, base]) == 1
    assert "truncated" in capsys.readouterr().err


def test_step_summary_written(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json", _artifact({"k": 0.5}))
    fresh = _write(tmp_path, "fresh.json", _artifact({"k": 0.5}))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert compare.main([fresh, base]) == 0
    text = summary.read_text()
    assert "Bench speedup deltas" in text and "| sharded_speedup/k |" in text


def test_pipelined_absolute_floor(tmp_path, capsys):
    """``pipelined_speedup`` gates against the 1.0 floor even with no
    baseline key at all — double-buffering losing to single-buffer on the
    same host is a regression on any hardware (DESIGN.md §16)."""
    base = _write(tmp_path, "base.json", _artifact({}))  # no pipelined keys
    bad = _write(
        tmp_path, "bad.json", _artifact({"upd0": 0.70}, field="pipelined_speedup")
    )
    assert compare.main([bad, base]) == 1
    assert "floor" in capsys.readouterr().err
    # exactly 1.0 (the CPU-host fallback value) and floor-minus-tolerance pass
    ok = _write(
        tmp_path, "ok.json", _artifact({"upd0": 1.0}, field="pipelined_speedup")
    )
    assert compare.main([ok, base]) == 0
    near = _write(
        tmp_path, "near.json", _artifact({"upd0": 0.85}, field="pipelined_speedup")
    )
    assert compare.main([near, base]) == 0


def test_schema_mismatch_rejected(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "other"}))
    good = _write(tmp_path, "good.json", _artifact({}))
    with pytest.raises(SystemExit):
        compare.main([str(bad), good])
