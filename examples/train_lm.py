"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the same production train_step / data pipeline / checkpointing as the
cluster launcher, on whatever devices exist.  Loss drops from ~ln(V) to
well below it within the run — the optimization path is real.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax

from repro.data import DataState, make_batch_iterator
from repro.models.model import get_config, param_count
from repro.train import make_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # a ~100M-param member of the assigned family (musicgen-medium scaffold)
    cfg = dataclasses.replace(
        get_config("musicgen-medium"),
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=8192, frontend=None,
        frontend_len=0, dtype="float32",
    )
    rng = jax.random.PRNGKey(0)
    state = train_state_init(rng, cfg)
    print(f"model: {param_count(state.params)/1e6:.1f}M params")

    step_fn = jax.jit(
        make_train_step(
            cfg, lr=3e-4, warmup=50, total_steps=args.steps, loss_chunk=128
        ),
        donate_argnums=(0,),
    )
    it = make_batch_iterator(
        cfg.vocab_size, args.seq, args.batch, state=DataState(seed=0)
    )
    t0, first_loss = time.time(), None
    for step, batch in it:
        if step >= args.steps:
            break
        state, m = step_fn(state, batch)
        if step % 25 == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            first_loss = first_loss or loss
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  ({tok_s:,.0f} tok/s)", flush=True)
    print(f"loss: {first_loss:.3f} → {float(m['loss']):.3f} ✓")


if __name__ == "__main__":
    main()
