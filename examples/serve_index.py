"""Batched index serving: FliX as the KV-page control plane of an engine.

Simulates an LLM-serving day: sequences arrive, allocate KV pages as they
decode, complete, and free — with batched index ops every engine step and
zero tombstone accumulation (the paper's long-running-execution claim).

    PYTHONPATH=src python examples/serve_index.py
"""

import numpy as np

from repro.serve.kv_index import PAGE_BITS, KVPageIndex

rng = np.random.default_rng(0)
idx = KVPageIndex(node_size=32, nodes_per_bucket=8)

next_seq = 0
next_slot = 0
active: dict[int, int] = {}  # seq_id -> pages allocated

for step in range(50):
    # admissions: a few new sequences join
    for _ in range(rng.integers(1, 4)):
        active[next_seq] = 0
        next_seq += 1

    # every active sequence decodes; every 4 tokens it needs a new page
    seqs, pages, slots = [], [], []
    for s in list(active):
        if rng.random() < 0.5:
            seqs.append(s)
            pages.append(active[s])
            slots.append(next_slot)
            active[s] += 1
            next_slot += 1
    # completions: sequences that didn't allocate this step may finish
    alloc_set = set(seqs)
    done = [
        s for s in active
        if active[s] > 0 and s not in alloc_set and rng.random() < 0.15
    ]

    # ONE mixed engine step: allocations, this step's page-table lookups,
    # physical frees, AND an in-order page enumeration (RANGE op) travel in
    # a single sorted batch (core.apply_ops) — update-then-read semantics
    # means the lookups and the enumeration already see this step's
    # allocations and frees.
    if seqs or done:
        probe = seqs[0] if seqs else done[0]
        res = idx.step(
            allocs=(seqs, pages, slots) if seqs else None,
            lookups=(seqs, pages) if seqs else None,
            free_seqs=done if done else None,
            ranges=([probe << PAGE_BITS], [(probe + 1) << PAGE_BITS]),
        )
        got, rng_out = res.slots, res.range_out
        if seqs:
            assert (np.asarray(got) == np.array(slots)).all()
        n_expect = 0 if probe in done else active[probe]
        assert int(rng_out["count"][0]) == n_expect, (probe, n_expect)
        got_pages = np.asarray(rng_out["keys"])[:n_expect] & ((1 << PAGE_BITS) - 1)
        assert got_pages.tolist() == list(range(n_expect))  # in order
    for s in done:
        del active[s]

    if step % 10 == 0:
        print(
            f"step {step:3d}: active={len(active):3d} live_pages={idx.live_pages():5d} "
            f"index_mem={idx.state.memory_bytes()/2**10:.0f} KiB"
        )

# verify final state consistency
total = sum(active.values())
assert idx.live_pages() == total, (idx.live_pages(), total)
print(f"final: {len(active)} active sequences, {total} pages — index consistent ✓")
