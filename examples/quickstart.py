"""Quickstart: the FliX index end to end in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro import core

rng = np.random.default_rng(0)

# ---- build: sorted keys → half-full bucketed data layer -------------------
keys = rng.choice(1_000_000, size=50_000, replace=False).astype(np.int32)
row_ids = np.arange(50_000, dtype=np.int32)
index = core.build(keys, row_ids, node_size=32, nodes_per_bucket=16)
print(f"built: {index.num_buckets} buckets, {int(index.live_keys())} keys, "
      f"{index.memory_bytes()/2**20:.1f} MiB")

# ---- flipped point queries: sort the batch, buckets pull their slices -----
queries = np.sort(rng.choice(keys, size=10_000))
values = core.point_query(index, jnp.asarray(queries))
assert (np.asarray(values) >= 0).all()
print(f"10k point queries: all hits ✓")

misses = np.sort(np.setdiff1d(rng.integers(0, 1_000_000, 10_000), keys)).astype(np.int32)
assert (np.asarray(core.point_query(index, jnp.asarray(misses))) == -1).all()
print(f"{len(misses)} point queries: all misses ✓")

# ---- batched insert (TL-Bulk semantics: per-bucket merge + splits) --------
new_keys = np.setdiff1d(rng.integers(0, 1_000_000, 30_000), keys)[:20_000].astype(np.int32)
sk, sv = core.sort_batch(jnp.asarray(new_keys), jnp.asarray(new_keys))
index, stats = core.insert_safe(index, sk, sv)
print(f"inserted {int(stats['inserted'])} keys "
      f"({int(stats['splits'])} node splits), live={int(index.live_keys())}")

# ---- successor queries (ordered-map superpower) ----------------------------
probe = jnp.asarray(np.sort(rng.integers(0, 1_000_000, 5)).astype(np.int32))
succ_k, succ_v = core.successor_query(index, probe)
for q, k in zip(np.asarray(probe), np.asarray(succ_k)):
    print(f"  successor({q}) = {k}")

# ---- batched delete: physical removal, no tombstones -----------------------
live = np.sort(np.concatenate([keys, new_keys]))
dels = jnp.asarray(live[~(np.arange(len(live)) % 3 == 0)])  # delete 2/3
index, dstats = core.delete(index, dels)
print(f"deleted {int(dstats['deleted'])} keys, "
      f"freed {int(dstats['nodes_freed'])} nodes, live={int(index.live_keys())}")

# ---- restructure: flatten chains, merge underfull nodes --------------------
before = int(index.total_nodes())
index = core.restructure_auto(index)
print(f"restructure: {before} → {int(index.total_nodes())} nodes "
      f"(recovered {before - int(index.total_nodes())}, "
      f"{index.memory_bytes()/2**20:.1f} MiB)")
