"""Flipped MoE dispatch demo: the FliX paradigm applied to expert routing.

Shows the exact correspondence (DESIGN.md §4):
    sorted op batch        ↔ tokens sorted by expert id
    MKBA fence searchsorted ↔ per-expert group offsets
    bucket pulls its slice  ↔ expert's contiguous token slice (grouped GEMM)

    PYTHONPATH=src python examples/moe_routing.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_dispatch import combine, dispatch, make_plan, moe_ffn_reference
from repro.kernels.ops import grouped_matmul

T, D, F, E, K = 512, 256, 512, 8, 2
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
logits = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
w_up = jnp.asarray((rng.normal(size=(E, D, F)) * 0.05).astype(np.float32))
w_down = jnp.asarray((rng.normal(size=(E, F, D)) * 0.05).astype(np.float32))

# 1. route + sort — "sort the operation batch"
plan = make_plan(logits, K, E)
sizes = np.diff(np.asarray(plan.group_offsets))
print("tokens per expert (each expert pulls a contiguous slice):")
for e, s in enumerate(sizes):
    print(f"  expert {e}: {s:4d} tokens  [{int(plan.group_offsets[e])}:{int(plan.group_offsets[e+1])})")

# 2. each expert pulls its slice and runs a dense MXU matmul
xs = dispatch(x, plan, K)
h = jax.nn.silu(grouped_matmul(xs, w_up, plan.group_offsets, mode="ref"))
ys = grouped_matmul(h, w_down, plan.group_offsets, mode="ref")

# 3. weighted combine back to token order
out = combine(ys, plan, K)

# matches the dense every-expert-computes-every-token oracle
want = moe_ffn_reference(x, logits, w_up, w_down, K)
err = float(jnp.max(jnp.abs(out - want)))
print(f"\nflipped dispatch vs dense oracle: max err {err:.2e} ✓")

# FLOPs: flipped computes E slices of ~T*K/E tokens; dense computes E*T
flipped = 2 * 2 * T * K * D * F
dense = 2 * 2 * T * E * D * F
print(f"FLOPs: flipped {flipped/1e9:.2f} GF vs dense {dense/1e9:.2f} GF "
      f"({dense/flipped:.0f}× saved)")
