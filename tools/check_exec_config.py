#!/usr/bin/env python
"""Gate the repo's own callers off the deprecated ExecConfig keywords.

The PR-10 API migration keeps the legacy per-entry-point keywords
(``impl``, ``donate``, ``block_q``, ``block_b``, ``max_results``,
``capacity``, ``routing``, ``validate``, ``validate_ranges``) alive as
warn-once shims for *external* callers, but the repo itself must be fully
on ``config=ExecConfig(...)`` so the shims can drop next release.  This
check walks every in-repo Python file with ``ast`` and fails on any call
to an engine entry point that still passes a deprecated keyword.

Exemptions: ``src/repro/core/`` (the shim implementation itself) and
``tests/test_exec_config.py`` (which proves the shims warn).

    python tools/check_exec_config.py          # from the repo root
"""

from __future__ import annotations

import ast
import pathlib
import sys

DEPRECATED = {
    "impl",
    "donate",
    "block_q",
    "block_b",
    "max_results",
    "capacity",
    "routing",
    "validate",
    "validate_ranges",
}
ENTRY_POINTS = {
    "apply_ops",
    "apply_ops_safe",
    "shard_apply_ops",
    "shard_apply_ops_safe",
    "KVPageIndex",
    "apply",  # DurableFliX.apply / TieredFliX.apply (see APPLY_ALLOWED)
}
# ``apply`` is matched by bare method name, which also catches the internal
# ``EngineBase.apply`` adapter seam (checkpoint/durable.py) — there
# ``max_results`` is a required keyword carrying per-record replay data, not
# a shim.  Syntactically indistinguishable, so ``max_results`` on ``apply``
# is left to the runtime warn-once shim instead of this static gate.
APPLY_ALLOWED = {"max_results"}
EXEMPT = ("src/repro/core/", "tests/test_exec_config.py")
SCAN_DIRS = ("src", "tests", "examples", "benchmarks", "tools")


def callee_name(node: ast.Call) -> str | None:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def check_file(path: pathlib.Path) -> list[str]:
    try:
        tree = ast.parse(path.read_text())
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: syntax error: {e.msg}"]
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = callee_name(node)
        if name not in ENTRY_POINTS:
            continue
        deprecated = DEPRECATED - APPLY_ALLOWED if name == "apply" else DEPRECATED
        bad = sorted(k.arg for k in node.keywords if k.arg in deprecated)
        if bad:
            out.append(
                f"{path}:{node.lineno}: {callee_name(node)}() passes deprecated "
                f"keyword(s) {bad} — use config=ExecConfig(...)"
            )
    return out


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    violations = []
    for d in SCAN_DIRS:
        for p in sorted((root / d).rglob("*.py")):
            rel = p.relative_to(root).as_posix()
            if "__pycache__" in rel or any(rel.startswith(e) or rel == e for e in EXEMPT):
                continue
            violations += check_file(p)
    if violations:
        print("\n".join(violations))
        print(f"\n{len(violations)} deprecated-keyword call site(s).")
        return 1
    print("exec-config check: all in-repo callers use config=ExecConfig(...)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
